"""Observability layer: tracing, exact-int metrics, exporters, no-op pins.

Acceptance bars:

* **no-op pin** — with ``ExecutionContext.obs`` unset (or a
  :class:`~repro.obs.NullTracer` attached) every timeline, journal byte,
  and pinned sha is bit-identical to the pre-observability stack; with a
  live bundle attached the *run* is still bit-identical — hooks only read
  already-computed integers;
* **byte determinism** — two identical seeded 240-request constrained-pool
  runs export byte-identical JSONL span logs and Prometheus snapshots;
* **exact agreement** — scraped counters/histograms reconcile with
  :class:`~repro.serving.sim.ServiceReport` /
  :func:`~repro.serving.qos.slo_report` integers with ``==``, deadline
  accounting included;
* **Chrome export** — one thread lane per drive (plus the queue lane), one
  process per fleet shard, loadable ``trace_event`` JSON;
* the fleet differential pin rides along: an instrumented
  ``replica-affinity`` outage run reproduces the uninstrumented sha while
  its spans cover every shard.
"""

import hashlib
import json

import pytest

from repro.core import ExecutionContext
from repro.obs import (
    KernelProfile,
    MetricsRegistry,
    NullTracer,
    Observability,
    Span,
    Tracer,
    chrome_trace,
    prometheus_text,
    spans_jsonl,
    write_chrome_trace,
    write_prometheus,
    write_spans_jsonl,
)
from repro.serving import (
    DriveCosts,
    RetryPolicy,
    ShardOutage,
    demo_library,
    poisson_trace,
    serve_trace,
)

pytestmark = pytest.mark.obs

SEED = 20260731
COSTS = DriveCosts(mount=150_000, unmount=60_000, load_seek=30_000)

#: the PR-7 no-fault pins (test_faults/test_fleet carry the same table):
#: instrumented runs must reproduce them bit-for-bit.
NO_FAULT_BASELINE = {
    "fifo": ("1a79c55063c3f802", 56_368_550_889),
    "accumulate": ("df9ed258ac816c37", 3_809_190_213),
    "preempt": ("668366586042762a", 7_347_259_813),
}

#: the instrumented fleet outage run must reproduce the uninstrumented one.
FLEET_PIN = ("9c548a4ade5a1de6", 1_016_256_963, 120, 0, 17)


def build_library():
    return demo_library(SEED)


def build_trace(n_requests=240, rate=250_000):
    return poisson_trace(
        build_library(), n_requests=n_requests, mean_interarrival=rate, seed=SEED
    )


def _served_sha(report):
    served = tuple(
        (r.req_id, r.arrival, r.dispatched, r.completed) for r in report.served
    )
    return hashlib.sha256(repr(served).encode()).hexdigest()[:16]


def _timeline(report):
    return [
        (r.req_id, r.arrival, r.dispatched, r.completed, r.faulted)
        for r in report.served
    ] + [(f.req_id, f.failed_at, f.reason) for f in report.failed]


def _pool_run(obs=None, trace=None, n_drives=3, **kw):
    lib = build_library()
    ctx = lib.context if obs is None else lib.context.replace(obs=obs)
    return serve_trace(
        lib, trace if trace is not None else build_trace(), "accumulate",
        window=400_000, policy="dp", n_drives=n_drives, drive_costs=COSTS,
        context=ctx, **kw,
    )


# ---------------------------------------------------------------------------
# tracer
# ---------------------------------------------------------------------------
def test_tracer_records_in_emission_order():
    tr = Tracer()
    tr.span("batch", 10, 50, track="drive0", tape="T1")
    tr.event("arrival", 30, track="queue", req=7)
    assert len(tr) == 2
    a, b = tr.spans
    assert (a.name, a.t0, a.t1, a.seq, a.track) == ("batch", 10, 50, 0, "drive0")
    assert a.attrs == {"tape": "T1"} and a.duration == 40 and not a.instant
    assert b.instant and b.seq == 1 and b.attrs == {"req": 7}
    assert a.wall_ns is None  # wall clocks are opt-in
    with pytest.raises(ValueError, match="ends before it starts"):
        tr.span("bad", 5, 4)


def test_tracer_wall_stamps_are_opt_in():
    tr = Tracer(wall=True)
    tr.span("s", 0, 1)
    assert isinstance(tr.spans[0].wall_ns, int)


def test_null_tracer_records_nothing():
    tr = NullTracer()
    tr.span("s", 0, 1)
    tr.event("e", 2)
    assert len(tr) == 0 and spans_jsonl(tr) == ""


# ---------------------------------------------------------------------------
# metrics registry
# ---------------------------------------------------------------------------
def test_registry_counters_gauges_histograms():
    m = MetricsRegistry()
    m.inc("served_total")
    m.inc("served_total", 3, policy="dp")
    m.gauge("depth", 4)
    m.gauge("depth", 2)  # last write wins
    for v in (10, 30, 20):
        m.observe("sojourn", v)
    assert m.counter("served_total") == 1
    assert m.counter("served_total", policy="dp") == 3
    assert m.counter("missing") == 0
    assert m.gauge_value("depth") == 2 and m.gauge_value("nope") is None
    assert m.samples("sojourn") == [10, 30, 20]
    assert m.quantile("sojourn", 1, 2) == 20  # exact nearest-rank median
    assert [v for _, v in m.counters_named("served_total")] == [1, 3]
    assert len(m) == 4


def test_registry_rejects_floats_bools_and_negatives():
    m = MetricsRegistry()
    with pytest.raises(TypeError, match="exact integers"):
        m.inc("c", 1.5)
    with pytest.raises(TypeError, match="exact integers"):
        m.observe("h", True)
    with pytest.raises(TypeError, match="exact integers"):
        m.gauge("g", 0.0)
    with pytest.raises(ValueError, match="cannot decrease"):
        m.inc("c", -1)


def test_snapshot_and_prometheus_are_deterministic():
    def build():
        m = MetricsRegistry()
        m.inc("b_total", 2, policy="dp")
        m.inc("a_total")
        m.gauge("g", 7, shard="0")
        m.observe("h", 5)
        m.observe("h", 9)
        return m

    a, b = build(), build()
    assert a.snapshot() == b.snapshot()
    assert prometheus_text(a) == prometheus_text(b)
    snap = a.snapshot()
    assert snap["counters"] == {"a_total": 1, 'b_total{policy="dp"}': 2}
    assert snap["histograms"]["h"]["sum"] == 14
    assert snap["histograms"]["h"]["count"] == 2
    text = prometheus_text(a)
    assert "# TYPE a_total counter" in text
    assert 'g{shard="0"} 7' in text
    assert 'h{quantile="0.5"} 5' in text and "h_sum 14" in text


# ---------------------------------------------------------------------------
# bundle + context plumbing
# ---------------------------------------------------------------------------
def test_empty_bundle_recorders_are_noop_safe():
    obs = Observability()  # all None
    obs.span("s", 0, 1)
    obs.event("e", 2)
    obs.inc("c")
    obs.gauge("g", 1)
    obs.observe("h", 1)
    armed = Observability.enabled()
    assert armed.tracer is not None and armed.metrics is not None
    assert armed.kernel is not None and not armed.kernel.wall
    armed.inc("c", 2)
    assert armed.metrics.counter("c") == 2


def test_context_validates_obs_field():
    assert ExecutionContext().obs is None
    ctx = ExecutionContext(obs=Observability.enabled())
    assert ctx.obs.tracer is not None
    assert ctx.replace(obs=None).obs is None
    with pytest.raises(TypeError, match="obs"):
        ExecutionContext(obs=42)


# ---------------------------------------------------------------------------
# no-op pins: instrumentation never changes a run
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("admission", sorted(NO_FAULT_BASELINE))
def test_instrumented_runs_reproduce_pins(admission):
    sha, total = NO_FAULT_BASELINE[admission]
    trace = build_trace()

    def run(obs):
        lib = build_library()
        ctx = lib.context if obs is None else lib.context.replace(obs=obs)
        return serve_trace(
            lib, trace, admission, window=400_000, policy="dp", n_drives=2,
            drive_costs=COSTS, context=ctx,
        )

    bare = run(None)
    assert (_served_sha(bare), bare.total_sojourn) == (sha, total)
    for obs in (Observability.enabled(),
                Observability(tracer=NullTracer())):
        instrumented = run(obs)
        assert (_served_sha(instrumented), instrumented.total_sojourn) == (
            sha, total,
        )
        assert _timeline(instrumented) == _timeline(bare)
        assert instrumented.summary() == bare.summary()


def test_journal_bytes_identical_with_obs(tmp_path):
    trace = build_trace(60)
    bare = tmp_path / "bare.journal"
    _pool_run(trace=trace, journal=str(bare))
    inst = tmp_path / "inst.journal"
    _pool_run(Observability.enabled(), trace=trace, journal=str(inst))
    assert inst.read_bytes() == bare.read_bytes()


# ---------------------------------------------------------------------------
# acceptance: byte-deterministic exports on the seeded 240-request run
# ---------------------------------------------------------------------------
def test_span_log_is_byte_deterministic(tmp_path):
    runs = []
    for _ in range(2):
        obs = Observability.enabled()
        _pool_run(obs)
        runs.append(obs)
    assert spans_jsonl(runs[0].tracer) == spans_jsonl(runs[1].tracer)
    assert prometheus_text(runs[0].metrics) == prometheus_text(runs[1].metrics)
    assert len(runs[0].tracer) > 0
    # the file exporters round-trip the same bytes
    p = tmp_path / "spans.jsonl"
    n = write_spans_jsonl(runs[0].tracer, p)
    assert n == len(runs[0].tracer)
    assert p.read_text() == spans_jsonl(runs[0].tracer)
    for line in p.read_text().splitlines():
        row = json.loads(line)
        assert list(row) == sorted(row)  # sorted keys, byte-stable
    write_prometheus(runs[0].metrics, tmp_path / "m.prom")
    assert (tmp_path / "m.prom").read_text() == prometheus_text(runs[0].metrics)


def test_prometheus_counters_match_report_exactly():
    from repro.data.traces import qos_poisson_trace, to_requests
    from repro.serving.qos import int_quantile, slo_report

    records = qos_poisson_trace(
        build_library(), n_requests=240, mean_interarrival=250_000,
        seed=SEED, tightness=8_000_000,
    )
    qtrace, qos = to_requests(records, build_library())
    obs = Observability.enabled()
    lib = build_library()
    report = serve_trace(
        lib, qtrace, "slack-accumulate", window=400_000, policy="dp",
        n_drives=3, drive_costs=COSTS, qos=qos,
        context=lib.context.replace(obs=obs),
    )
    s = report.summary()
    m = obs.metrics
    assert m.counter("requests_arrived_total") == len(qtrace)
    assert m.counter("requests_served_total") == report.n_served
    assert m.counter("batches_total") == s["n_batches"]
    assert m.counter("cells_evaluated_total") == s["cells_evaluated"]
    assert m.counter("cells_reused_total") == s["cells_reused"]
    assert m.counter("mount_delay_total") == s["mount_time"]
    assert m.counter("cache_hits_total", cache="SolveCache") == s["cache"]["hits"]
    assert m.counter("cache_misses_total", cache="SolveCache") == s["cache"]["misses"]
    # deadline accounting: same integers the report and SLO summary carry
    assert m.counter("deadlines_total") == report.n_deadlines == s["n_deadlines"]
    assert m.counter("deadline_misses_total") == report.n_missed == s["n_missed"]
    # the sojourn histogram IS the report's distribution
    sojourns = m.samples("sojourn")
    assert len(sojourns) == report.n_served
    assert sum(sojourns) == report.total_sojourn
    # recorded in event order; the report re-sorts rows — same multiset
    assert sorted(sojourns) == sorted(r.sojourn for r in report.served)
    # scraped quantiles == the SLO report's exact nearest-rank quantiles
    slo = slo_report(report)
    assert m.quantile("sojourn", 1, 2) == slo.overall.p50_sojourn
    assert m.quantile("sojourn", 99, 100) == slo.overall.p99_sojourn
    assert m.quantile("sojourn", 99, 100) == int_quantile(sojourns, 99, 100)
    assert slo.overall.n_missed == m.counter("deadline_misses_total")


def test_chrome_trace_has_one_lane_per_drive():
    obs = Observability.enabled()
    _pool_run(obs)
    doc = chrome_trace(obs.tracer)
    events = doc["traceEvents"]
    threads = {
        e["args"]["name"] for e in events
        if e["ph"] == "M" and e["name"] == "thread_name"
    }
    assert {"drive0", "drive1", "drive2", "queue"} <= threads
    procs = {
        e["args"]["name"] for e in events
        if e["ph"] == "M" and e["name"] == "process_name"
    }
    assert procs == {"shard0"}  # standalone run: one process
    batches = [e for e in events if e["ph"] == "X" and e["name"] == "batch"]
    assert batches and all(e["dur"] > 0 for e in batches)
    assert any(e["ph"] == "i" for e in events)  # instants export too


def test_chrome_trace_round_trips_as_json(tmp_path):
    obs = Observability.enabled()
    _pool_run(obs, trace=build_trace(40))
    p = tmp_path / "trace.chrome.json"
    write_chrome_trace(obs.tracer, p)
    doc = json.loads(p.read_text())
    assert doc == chrome_trace(obs.tracer)


# ---------------------------------------------------------------------------
# fleet: differential pin + per-shard spans
# ---------------------------------------------------------------------------
def _fleet_run(obs=None):
    from repro.core import FleetOptions
    from repro.fleet import demo_fleet, fleet_catalog, serve_fleet_trace

    libs, rmap = demo_fleet(SEED, n_shards=3, replicas=2)
    trace = poisson_trace(
        fleet_catalog(libs, rmap), n_requests=120, mean_interarrival=30_000,
        seed=SEED,
    )
    libs, rmap = demo_fleet(SEED, n_shards=3, replicas=2)
    ctx = ExecutionContext(
        fleet=FleetOptions(n_shards=3, placement="replica-affinity", replicas=2),
        obs=obs,
    )
    return serve_fleet_trace(
        libs, trace, "accumulate", replica_map=rmap,
        outages=(ShardOutage(at=1_500_000, shard=1),), window=400_000,
        n_drives=2, drive_costs=COSTS, retry=RetryPolicy(on_exhausted="drop"),
        context=ctx,
    )


def test_fleet_instrumented_run_reproduces_pin():
    sha, total, n_served, n_failed, n_rerouted = FLEET_PIN
    bare = _fleet_run()
    assert (_served_sha(bare.merged), bare.total_sojourn) == (sha, total)
    obs = Observability.enabled()
    fr = _fleet_run(obs)
    assert (_served_sha(fr.merged), fr.total_sojourn) == (sha, total)
    assert (fr.n_served, fr.n_failed, fr.n_rerouted) == (
        n_served, n_failed, n_rerouted,
    )
    assert _timeline(fr.merged) == _timeline(bare.merged)
    m = obs.metrics
    # routing counters reconcile with the report's routes, exactly
    routed = sum(v for _, v in m.counters_named("fleet_routed_total"))
    rerouted = sum(v for _, v in m.counters_named("fleet_rerouted_total"))
    assert routed == fr.n_served + fr.n_failed  # every arrival routed once
    assert routed + rerouted == sum(fr.routes.values())
    assert rerouted == fr.n_rerouted
    assert m.counter("fleet_outages_total") == 1
    # per-shard rollup gauges match the per-shard reports
    for i, shard in enumerate(fr.shards):
        assert m.gauge_value("shard_served", shard=str(i)) == shard.n_served
    # spans cover every shard; each shard's drives get their own lanes
    shards_seen = {sp.shard for sp in obs.tracer.spans}
    assert shards_seen == {0, 1, 2}
    doc = chrome_trace(obs.tracer)
    procs = {
        e["args"]["name"] for e in doc["traceEvents"]
        if e["ph"] == "M" and e["name"] == "process_name"
    }
    assert procs == {"shard0", "shard1", "shard2"}
    tracks = {sp.track for sp in obs.tracer.spans}
    assert {"drive0", "drive1", "queue", "router"} <= tracks


# ---------------------------------------------------------------------------
# kernel profiling
# ---------------------------------------------------------------------------
def test_kernel_profile_cold_vs_warm_and_waste():
    prof = KernelProfile(wall=False)
    sig = (4, 8, 2, "int32", True, 0, False, None)
    prof.record(signature=sig, n_instances=2, R_pad=4, S_pad=8, B_pad=2,
                real_cells=100, interpret=True)
    prof.record(signature=sig, n_instances=1, R_pad=4, S_pad=8, B_pad=2,
                real_cells=40, interpret=True)
    first, second = prof.launches
    assert first.cold and not second.cold  # same signature: compiled once
    assert first.padded_cells == 2 * 4 * 4 * 8 == 256
    assert first.waste == (156, 256)  # exact fraction, no floats
    assert first.wall_ns is None
    s = prof.summary()
    assert s["n_launches"] == 2 and s["n_cold"] == 1
    assert s["real_cells"] == 140 and s["padded_cells"] == 512
    assert s["wasted_cells"] == 512 - 140


def test_kernel_profile_captures_device_launches():
    obs = Observability.enabled(wall=True)  # compile/execute wall is opt-in
    lib = build_library()
    report = serve_trace(
        lib, build_trace(40), "batched", window=400_000, policy="dp",
        n_drives=2, drive_costs=COSTS,
        context=lib.context.replace(backend="pallas-interpret", obs=obs),
    )
    assert report.n_served == 40
    prof = obs.kernel
    assert len(prof.launches) > 0
    for rec in prof.launches:
        assert rec.padded_cells >= rec.real_cells > 0
        wasted, padded = rec.waste  # exact fraction (wasted, padded)
        assert 0 <= wasted < padded
        assert rec.interpret
        assert isinstance(rec.wall_ns, int) and rec.wall_ns > 0
    assert prof.summary()["n_instances"] >= len(prof.launches)
    # a cold launch (first of its bucket signature) pays compilation; re-use
    # of the same bucket is marked warm
    assert any(rec.cold for rec in prof.launches)

"""ExecutionContext surface: defaulting, immutability, validation — and the
deprecation shims (old ``backend=``/``cache=`` keyword paths must emit
``DeprecationWarning`` yet stay bit-identical to the context API).

The shim tests are marked ``shims``: CI runs the rest of the suite under
``-W error::DeprecationWarning`` (proving every in-repo caller is migrated)
and exercises the shims in a separate allowed-warning leg.
"""

import dataclasses
import warnings

import numpy as np
import pytest

from conftest import random_instance
from repro.core import (
    DEFAULT_CONTEXT,
    ExecutionContext,
    SolveCache,
    get_solver,
    resolve_context,
    solve,
    solve_batch,
)

DEV = ExecutionContext(backend="pallas-interpret")


# ---------------------------------------------------------------------------
# defaulting / immutability / validation
# ---------------------------------------------------------------------------
def test_context_defaults():
    ctx = ExecutionContext()
    assert ctx.backend == "python"
    assert ctx.cache is None
    assert ctx.bucketed is True
    assert ctx.cand_tile is None
    assert ctx.numeric_policy == "strict"
    assert ctx == DEFAULT_CONTEXT


def test_context_is_immutable():
    ctx = ExecutionContext()
    with pytest.raises(dataclasses.FrozenInstanceError):
        ctx.backend = "pallas"
    with pytest.raises(dataclasses.FrozenInstanceError):
        ctx.numeric_policy = "f64"


def test_context_replace_derives_without_mutating():
    base = ExecutionContext(cache=SolveCache())
    derived = base.replace(backend="pallas-interpret", numeric_policy="f64")
    assert derived.backend == "pallas-interpret"
    assert derived.numeric_policy == "f64"
    assert derived.cache is base.cache  # shared memo, not copied
    assert base.backend == "python" and base.numeric_policy == "strict"


def test_context_validates_fields():
    with pytest.raises(KeyError, match="unknown backend"):
        ExecutionContext(backend="cuda")
    with pytest.raises(ValueError, match="numeric_policy"):
        ExecutionContext(numeric_policy="f16")
    with pytest.raises(ValueError, match="cand_tile"):
        ExecutionContext(cand_tile=0)


def test_resolve_context_precedence():
    ctx = ExecutionContext(backend="pallas-interpret")
    assert resolve_context(ctx) is ctx
    assert resolve_context(None) == DEFAULT_CONTEXT
    base = ExecutionContext(numeric_policy="f64")
    assert resolve_context(None, default=base) is base
    with pytest.raises(TypeError, match="not both"):
        resolve_context(ctx, backend="python")


def test_new_api_emits_no_deprecation_warning(rng):
    inst = random_instance(rng, hi=6)
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        res = solve(inst, policy="dp", context=DEV)
        [batch_res] = solve_batch([inst], policy="dp", context=DEV)
        assert res.cost == batch_res.cost


# ---------------------------------------------------------------------------
# deprecation shims: warn, then forward bit-identically
# ---------------------------------------------------------------------------
@pytest.mark.shims
def test_resolve_context_legacy_keywords_warn_and_fold():
    cache = SolveCache()
    with pytest.warns(DeprecationWarning, match="backend/cache"):
        ctx = resolve_context(None, backend="pallas-interpret", cache=cache)
    assert ctx.backend == "pallas-interpret" and ctx.cache is cache


@pytest.mark.shims
def test_solve_shim_bit_identical(rng):
    inst = random_instance(rng, hi=8)
    new = solve(inst, policy="dp", context=DEV)
    with pytest.warns(DeprecationWarning):
        old = solve(inst, policy="dp", backend="pallas-interpret")
    assert (old.cost, old.detours, old.backend) == (new.cost, new.detours, new.backend)


@pytest.mark.shims
def test_solve_batch_shim_bit_identical_with_cache(rng):
    insts = [random_instance(rng, hi=7) for _ in range(4)]
    cache_old, cache_new = SolveCache(), SolveCache()
    new = solve_batch(insts, policy="dp", context=ExecutionContext(cache=cache_new))
    with pytest.warns(DeprecationWarning):
        old = solve_batch(insts, policy="dp", cache=cache_old)
    assert [(r.cost, r.detours) for r in old] == [(r.cost, r.detours) for r in new]
    assert cache_old.stats() == cache_new.stats()


@pytest.mark.shims
def test_solver_backend_string_shim(rng):
    inst = random_instance(rng, hi=6)
    solver = get_solver("dp")
    new = solver.solve(inst, DEV)
    with pytest.warns(DeprecationWarning, match="backend string"):
        old = solver.solve(inst, "pallas-interpret")
    assert (old.cost, old.detours) == (new.cost, new.detours)
    with pytest.warns(DeprecationWarning, match="backend string"):
        [old_b] = solver.solve_batch([inst], "pallas-interpret")
    assert (old_b.cost, old_b.detours) == (new.cost, new.detours)


@pytest.mark.shims
def test_schedule_reads_shim_bit_identical():
    from repro.storage.tape import Tape, schedule_reads

    rng = np.random.default_rng(3)
    t = Tape("T0", capacity=400_000, u_turn=700)
    for i in range(10):
        t.append(f"f{i}", int(rng.integers(1_000, 30_000)))
    reqs = {f"f{i}": 1 + i % 3 for i in range(0, 10, 2)}
    new = schedule_reads(t, reqs, policy="dp", context=DEV)
    with pytest.warns(DeprecationWarning):
        old = schedule_reads(t, reqs, policy="dp", backend="pallas-interpret")
    assert old == new


@pytest.mark.shims
def test_tape_library_cache_kwarg_shim():
    from repro.storage.tape import TapeLibrary

    cache = SolveCache()
    with pytest.warns(DeprecationWarning):
        lib = TapeLibrary(capacity_per_tape=100_000, u_turn=500, cache=cache)
    assert lib.cache is cache and lib.context.cache is cache
    for i in range(4):
        lib.store(f"f{i}", 20_000)
    reqs = {f"f{i}": 1 for i in range(4)}
    new = lib.schedule(reqs, policy="dp")  # library context: no warning
    with pytest.warns(DeprecationWarning):
        old = lib.schedule(reqs, policy="dp", backend="python")
    assert old == new
    assert cache.hits > 0  # second plan re-hit the library memo


@pytest.mark.shims
def test_plan_restore_shim_bit_identical():
    from repro.distributed.checkpoint import plan_restore
    from repro.storage.tape import TapeLibrary

    lib = TapeLibrary(capacity_per_tape=200_000, u_turn=900)
    shards = [lib.store(f"s{i}", 30_000).name for i in range(8)]
    new = plan_restore(lib, shards, 2, policy="dp", context=DEV)
    with pytest.warns(DeprecationWarning):
        old = plan_restore(lib, shards, 2, policy="dp",
                           backend="pallas-interpret")
    assert old == new


@pytest.mark.shims
def test_serve_trace_shim_bit_identical():
    from repro.serving.queue import serve_trace
    from repro.serving.sim import demo_library, poisson_trace

    trace = poisson_trace(demo_library(1), 60, 200_000, seed=1)
    cache_old, cache_new = SolveCache(), SolveCache()
    new = serve_trace(
        demo_library(1), trace, "accumulate", window=300_000, policy="dp",
        context=ExecutionContext(cache=cache_new),
    )
    with pytest.warns(DeprecationWarning):
        old = serve_trace(
            demo_library(1), trace, "accumulate", window=300_000, policy="dp",
            cache=cache_old,
        )
    assert old.summary() == new.summary()
    assert [r.completed for r in old.served] == [r.completed for r in new.served]

"""Distributed substrate tests: sharding rules, optimizer, checkpointing,
fault tolerance, gradient compression.  Mesh-shape logic is tested with an
AbstractMesh (no devices needed)."""

import dataclasses
import pathlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import AbstractMesh, PartitionSpec as P

from repro.configs import ARCHS, reduced
from repro.distributed.compression import (
    compressed_grads,
    init_error_feedback,
    int8_compress,
    int8_decompress,
    topk_compress,
    topk_decompress,
)
from repro.distributed.checkpoint import load_checkpoint, save_checkpoint
from repro.distributed.fault_tolerance import (
    StragglerMonitor,
    remesh_plan,
    should_checkpoint,
)
from repro.distributed.sharding import (
    batch_pspecs,
    cache_pspecs,
    param_pspecs,
    safe_pspec,
)
from repro.launch.specs import abstract_params, input_specs
from repro.configs.base import SHAPES

MESH = AbstractMesh((("data", 16), ("model", 16)))
MESH3 = AbstractMesh((("pod", 2), ("data", 16), ("model", 16)))


def _axis_size(s, mesh):
    axes = s if isinstance(s, tuple) else (s,)
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


@pytest.mark.parametrize("arch_id", sorted(ARCHS))
def test_param_specs_divide_production_mesh(arch_id):
    """Every parameter PartitionSpec must divide at full production scale
    (after the divisibility guard)."""
    cfg = ARCHS[arch_id]
    params = abstract_params(cfg)
    specs = param_pspecs(params)

    def check(leaf, spec):
        guarded = safe_pspec(spec, leaf.shape, MESH)
        for ax, s in enumerate(guarded):
            if s is not None:
                assert leaf.shape[ax] % _axis_size(s, MESH) == 0

    jax.tree.map(check, params, specs, is_leaf=lambda x: isinstance(x, P))


def test_param_specs_shard_the_big_leaves():
    """The guard must not silently replicate the dominant parameters."""
    cfg = ARCHS["granite-8b"]
    params = abstract_params(cfg)
    specs = param_pspecs(params)
    flat = jax.tree_util.tree_flatten_with_path(params)[0]
    sflat = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
    for (path, leaf), spec in zip(flat, sflat):
        guarded = safe_pspec(spec, leaf.shape, MESH)
        if leaf.size * 4 > 64 * 2**20:  # every leaf > 64 MB must be sharded
            assert any(s is not None for s in guarded), (path, leaf.shape)


def test_cache_specs_divide(rng=None):
    for arch_id in ("granite-8b", "jamba-v0.1-52b", "deepseek-v2-236b", "xlstm-1.3b"):
        cfg = ARCHS[arch_id]
        specs = input_specs(cfg, SHAPES["decode_32k"])
        cspecs = cache_pspecs(specs["cache"], MESH)

        def check(leaf, spec):
            for ax, s in enumerate(spec):
                if s is not None:
                    assert leaf.shape[ax] % _axis_size(s, MESH) == 0, (arch_id, leaf.shape, spec)

        jax.tree.map(check, specs["cache"], cspecs, is_leaf=lambda x: isinstance(x, P))


def test_batch_specs_replicate_unshardable_batch():
    batch = {"tokens": jax.ShapeDtypeStruct((1, 1), jnp.int32)}
    specs = batch_pspecs(batch, MESH)
    assert specs["tokens"] == P(None, None)
    batch = {"tokens": jax.ShapeDtypeStruct((256, 4096), jnp.int32)}
    specs = batch_pspecs(batch, MESH)
    assert specs["tokens"][0] in ("data", ("data",))


def test_safe_pspec_multipod():
    s = safe_pspec(P(("pod", "data"), None), (32, 128), MESH3)
    assert s == P(("pod", "data"), None)
    s = safe_pspec(P(("pod", "data"), None), (16, 128), MESH3)
    assert s == P(None, None)


# ---------------------------------------------------------------------------
# optimizer + training loop behaviour
# ---------------------------------------------------------------------------
def test_training_reduces_loss():
    from repro.training.optimizer import OptConfig
    from repro.training.train_step import init_train_state, make_train_step

    cfg = dataclasses.replace(
        reduced(ARCHS["granite-8b"], periods=1), vocab_size=64, remat=False
    )
    params, opt = init_train_state(jax.random.PRNGKey(0), cfg)
    step = jax.jit(make_train_step(cfg, OptConfig(learning_rate=1e-2, warmup_steps=2, total_steps=60)))
    tokens = jax.random.randint(jax.random.PRNGKey(1), (4, 16), 0, cfg.vocab_size)
    batch = {"tokens": tokens}  # overfit one batch
    losses = []
    for _ in range(25):
        params, opt, m = step(params, opt, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] * 0.7, losses[:3] + losses[-3:]
    assert int(opt["step"]) == 25


def test_microbatching_matches_full_batch():
    from repro.training.optimizer import OptConfig
    from repro.training.train_step import init_train_state, make_train_step

    cfg = dataclasses.replace(
        reduced(ARCHS["qwen2.5-3b"], periods=1),
        vocab_size=64, remat=False, compute_dtype="float32",
    )
    params, opt = init_train_state(jax.random.PRNGKey(0), cfg)
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (8, 16), 0, cfg.vocab_size)}
    oc = OptConfig(warmup_steps=1, total_steps=10)
    p1, _, m1 = make_train_step(cfg, oc, microbatches=1)(params, opt, batch)
    p2, _, m2 = make_train_step(cfg, oc, microbatches=4)(params, opt, batch)
    # same gradients up to accumulation-order rounding
    err = max(
        float(jnp.max(jnp.abs(a - b)))
        for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2))
    )
    assert err < 2e-5, err


# ---------------------------------------------------------------------------
# checkpoint/restart
# ---------------------------------------------------------------------------
def test_checkpoint_roundtrip_bitexact(tmp_path: pathlib.Path):
    from repro.training.optimizer import OptConfig
    from repro.training.train_step import init_train_state, make_train_step

    cfg = dataclasses.replace(reduced(ARCHS["qwen2.5-3b"], periods=1), vocab_size=64, remat=False)
    params, opt = init_train_state(jax.random.PRNGKey(0), cfg)
    step = jax.jit(make_train_step(cfg, OptConfig(warmup_steps=1, total_steps=50)))
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(2), (4, 16), 0, cfg.vocab_size)}

    # run 3 steps, checkpoint, run 2 more -> reference
    for _ in range(3):
        params, opt, _ = step(params, opt, batch)
    save_checkpoint(tmp_path / "ck", 3, params=params, opt_state=opt)
    ref_params, ref_opt = params, opt
    for _ in range(2):
        ref_params, ref_opt, _ = step(ref_params, ref_opt, batch)

    # "crash", restore, continue -> must be bit-exact
    step_no, trees = load_checkpoint(tmp_path / "ck", params=params, opt_state=opt)
    assert step_no == 3
    r_params, r_opt = trees["params"], trees["opt_state"]
    for _ in range(2):
        r_params, r_opt, _ = step(r_params, r_opt, batch)
    for a, b in zip(jax.tree.leaves(ref_params), jax.tree.leaves(r_params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# fault tolerance + compression
# ---------------------------------------------------------------------------
def test_straggler_detection():
    mon = StragglerMonitor()
    for s in range(6):
        for w in range(8):
            mon.record(f"w{w}", s, 1.0 if w else 3.5)  # w0 is slow
    assert mon.stragglers(threshold=2.0) == ["w0"]
    assert mon.dead(current_step=10) == [f"w{i}" for i in range(8)]
    assert should_checkpoint(7, every=100, alarms=["w0"])
    assert should_checkpoint(200, every=100, alarms=[])
    assert not should_checkpoint(7, every=100, alarms=[])


def test_remesh_preserves_model_axis():
    plan = remesh_plan(alive_devices=240, old_shape=(16, 16))
    assert plan.shape == (15, 16)
    assert not plan.reshard_model_axis
    assert plan.devices_used == 240
    assert plan.batch_scale == pytest.approx(15 / 16)


def test_remesh_degraded_mode():
    plan = remesh_plan(alive_devices=12, old_shape=(16, 16))
    assert plan.reshard_model_axis
    assert plan.shape == (1, 8) or plan.shape[-1] == 8


def test_remesh_multipod():
    plan = remesh_plan(alive_devices=384, old_shape=(2, 16, 16),
                       axis_names=("pod", "data", "model"))
    assert plan.shape[-1] == 16
    assert plan.devices_used <= 384
    assert not plan.reshard_model_axis


def test_topk_compression_roundtrip():
    g = jnp.array([0.0, 5.0, -3.0, 0.1, 0.01, 2.0])
    vals, idx = topk_compress(g, ratio=0.5)
    rec = topk_decompress(vals, idx, g.shape)
    np.testing.assert_allclose(np.sort(np.abs(np.asarray(rec)))[-3:], [2.0, 3.0, 5.0])


def test_int8_compression_error_bounded():
    g = jax.random.normal(jax.random.PRNGKey(0), (128,))
    q, s = int8_compress(g)
    rec = int8_decompress(q, s)
    assert float(jnp.max(jnp.abs(rec - g))) <= float(s) * 0.5 + 1e-6


def test_error_feedback_preserves_signal():
    """With error feedback, repeated compression passes the full gradient
    through over time (sum of effective grads ~ sum of true grads)."""
    g = {"w": jax.random.normal(jax.random.PRNGKey(0), (64,))}
    ef = init_error_feedback(g)
    total = jnp.zeros((64,))
    T = 50
    for _ in range(T):
        eff, ef, ratio = compressed_grads(g, ef, method="topk", ratio=0.1)
        total = total + eff["w"]
    # exact telescoping identity of error feedback: transmitted = T*g - e_T
    np.testing.assert_allclose(
        np.asarray(total),
        T * np.asarray(g["w"]) - np.asarray(ef["w"]),
        rtol=1e-4, atol=1e-4,
    )
    # the dominant half of the gradient mass is transmitted near-exactly
    gw = np.abs(np.asarray(g["w"]))
    big = gw >= np.median(gw)
    err = np.abs(np.asarray(total / T) - np.asarray(g["w"]))
    assert (err[big] <= gw[big] * 0.35 + 1e-3).all()
    assert ratio == pytest.approx(0.2)

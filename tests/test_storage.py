"""Tape tier + LTSP-scheduled reads + tape-backed checkpoint restore."""

import numpy as np
import pytest

from repro.core import evaluate_detours
from repro.distributed.checkpoint import archive_to_tape, plan_restore
from repro.storage.tape import Tape, TapeLibrary, schedule_reads


def _tape_with_files(n=20, seed=0):
    rng = np.random.default_rng(seed)
    t = Tape("T0", capacity=10_000_000, u_turn=1000)
    for i in range(n):
        t.append(f"f{i:03d}", int(rng.integers(1000, 400_000)))
    return t


def test_tape_layout_disjoint():
    t = _tape_with_files()
    fs = sorted(t.files.values(), key=lambda f: f.left)
    for a, b in zip(fs, fs[1:]):
        assert a.right <= b.left or a.right == b.left


def test_schedule_reads_policies_ranked():
    rng = np.random.default_rng(1)
    t = _tape_with_files(25, seed=1)
    names = list(t.files)
    reqs = {n: int(rng.integers(1, 20)) for n in rng.choice(names, 12, replace=False)}
    plans = {p: schedule_reads(t, reqs, policy=p) for p in ("dp", "simpledp", "logdp1", "gs", "nodetour")}
    opt = plans["dp"].total_cost
    for p, plan in plans.items():
        assert plan.total_cost >= opt
        assert plan.virtual_lb <= opt
        assert sorted(plan.order) == sorted(reqs)  # every file served once
    assert plans["simpledp"].total_cost <= plans["gs"].total_cost


def test_schedule_order_consistent_with_service_times():
    t = _tape_with_files(10, seed=2)
    reqs = {n: 2 for n in list(t.files)[:6]}
    plan = schedule_reads(t, reqs, policy="dp")
    times = [plan.service_time[n] for n in plan.order]
    assert times == sorted(times)


def test_library_multi_tape_scheduling():
    lib = TapeLibrary(capacity_per_tape=1_000_000, u_turn=500)
    for i in range(30):
        lib.store(f"shard{i:02d}", 90_000)  # ~11 shards per tape
    assert len(lib.tapes) >= 3
    reqs = {f"shard{i:02d}": 1 + i % 3 for i in range(30)}
    plans = lib.schedule(reqs, policy="simpledp")
    assert sum(len(p.order) for p in plans) == 30
    assert {t.tape_id for t in lib.tapes} >= {p.tape_id for p in plans}


def test_tape_backed_checkpoint_restore_plan():
    """DP-planned restore beats the naive no-detour sweep on mean arrival."""
    import jax
    from repro.configs import ARCHS, reduced
    from repro.models.model import init_model

    cfg = reduced(ARCHS["qwen2.5-3b"], periods=1)
    params = init_model(jax.random.PRNGKey(0), cfg)
    lib = TapeLibrary(capacity_per_tape=10**9, u_turn=10_000)
    shards = archive_to_tape(lib, "step100", params)
    assert len(shards) == len(jax.tree.leaves(params))

    # 2 pods consume every shard; a few hot shards have extra consumers
    consumers = {s: 2 for s in shards}
    for s in shards[::5]:
        consumers[s] = 8
    dp_plans = plan_restore(lib, shards, consumers, policy="dp")
    naive_plans = plan_restore(lib, shards, consumers, policy="nodetour")
    dp_cost = sum(p.total_cost for p in dp_plans)
    naive_cost = sum(p.total_cost for p in naive_plans)
    assert dp_cost <= naive_cost

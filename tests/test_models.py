"""Per-architecture smoke tests (reduced configs) + decode parity + MoE
properties.  Everything runs on CPU with the same code paths the dry-run
lowers at production scale."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, SHAPES, reduced, runnable_shapes
from repro.models.common import ModelConfig, apply_moe, init_moe
from repro.models.model import (
    decode_step,
    encode,
    forward,
    init_cache,
    init_model,
    warm_cross_cache,
)

KEY = jax.random.PRNGKey(0)


def _memory_for(cfg, B, dtype=jnp.bfloat16):
    if cfg.num_vision_tokens:
        return jax.random.normal(KEY, (B, cfg.num_vision_tokens, cfg.d_model), dtype=dtype)
    return None


@pytest.mark.parametrize("arch_id", sorted(ARCHS))
def test_smoke_forward_and_train_shapes(arch_id):
    cfg = reduced(ARCHS[arch_id])
    B, L = 2, 32
    params = init_model(KEY, cfg)
    tokens = jax.random.randint(KEY, (B, L), 0, cfg.vocab_size)
    memory = _memory_for(cfg, B)
    if cfg.enc_layers:
        enc_in = jax.random.normal(KEY, (B, cfg.num_enc_frames, cfg.d_model), dtype=jnp.bfloat16)
        memory = encode(params, cfg, enc_in)
        assert memory.shape == (B, cfg.num_enc_frames, cfg.d_model)
    logits, aux = forward(params, cfg, tokens, memory=memory)
    assert logits.shape == (B, L, cfg.vocab_size)
    assert logits.dtype == jnp.float32
    assert not bool(jnp.isnan(logits).any())
    assert not bool(jnp.isnan(aux))


@pytest.mark.parametrize("arch_id", sorted(ARCHS))
def test_smoke_one_train_step(arch_id):
    from repro.training.optimizer import OptConfig
    from repro.training.train_step import init_train_state, make_train_step

    cfg = dataclasses.replace(reduced(ARCHS[arch_id], periods=1), remat=False)
    B, L = 2, 16
    params, opt_state = init_train_state(KEY, cfg)
    batch = {"tokens": jax.random.randint(KEY, (B, L), 0, cfg.vocab_size)}
    if cfg.enc_layers:
        batch["enc_embeds"] = jax.random.normal(
            KEY, (B, cfg.num_enc_frames, cfg.d_model), dtype=jnp.bfloat16
        )
    if cfg.num_vision_tokens:
        batch["vision_embeds"] = jax.random.normal(
            KEY, (B, cfg.num_vision_tokens, cfg.d_model), dtype=jnp.bfloat16
        )
    step = make_train_step(cfg, OptConfig(warmup_steps=1, total_steps=10))
    new_params, new_opt, metrics = step(params, opt_state, batch)
    assert float(metrics["loss"]) > 0 and np.isfinite(float(metrics["loss"]))
    assert float(metrics["grad_norm"]) > 0
    # parameters actually moved
    moved = jax.tree.map(
        lambda a, b: bool(jnp.any(a != b)), params, new_params
    )
    assert any(jax.tree.leaves(moved))


@pytest.mark.parametrize(
    "arch_id",
    ["granite-8b", "jamba-v0.1-52b", "xlstm-1.3b", "deepseek-v2-236b",
     "seamless-m4t-large-v2", "llama-3.2-vision-90b"],
)
def test_decode_matches_forward(arch_id):
    """Step-by-step cached decode reproduces the full-sequence forward."""
    cfg = dataclasses.replace(
        reduced(ARCHS[arch_id]),
        compute_dtype="float32",
        mamba_chunk=8,
        capacity_factor=16.0,  # avoid prefill/decode capacity-drop mismatch
    )
    B, L = 2, 16
    params = init_model(KEY, cfg)
    tokens = jax.random.randint(KEY, (B, L), 0, cfg.vocab_size)
    memory = _memory_for(cfg, B, dtype=jnp.float32)
    if cfg.enc_layers:
        enc_in = jax.random.normal(KEY, (B, cfg.num_enc_frames, cfg.d_model), dtype=jnp.float32)
        memory = encode(params, cfg, enc_in)
    full, _ = forward(params, cfg, tokens, memory=memory)
    cache = init_cache(cfg, B, max_len=L)
    if memory is not None:
        cache = warm_cross_cache(params, cfg, cache, memory)
    outs = []
    for t in range(L):
        lg, cache = decode_step(params, cfg, tokens[:, t : t + 1], cache, jnp.int32(t))
        outs.append(lg[:, 0])
    dec = jnp.stack(outs, axis=1)
    rel = float(jnp.max(jnp.abs(dec - full))) / float(jnp.max(jnp.abs(full)))
    assert rel < 2e-3, rel


def test_scan_equals_unrolled():
    """cfg.scan_layers only changes compilation strategy, not the math."""
    cfg = dataclasses.replace(reduced(ARCHS["granite-8b"]), compute_dtype="float32")
    params = init_model(KEY, cfg)
    tokens = jax.random.randint(KEY, (2, 16), 0, cfg.vocab_size)
    a, _ = forward(params, cfg, tokens)
    b, _ = forward(params, dataclasses.replace(cfg, scan_layers=False), tokens)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# MoE properties
# ---------------------------------------------------------------------------
def _moe_cfg(**kw):
    base = dict(
        arch_id="t", family="moe", num_layers=1, d_model=16, num_heads=2,
        num_kv_heads=2, d_ff=32, vocab_size=64, num_experts=4, top_k=2,
        moe_d_ff=32, compute_dtype="float32", capacity_factor=8.0,
    )
    base.update(kw)
    return ModelConfig(**base)


def test_moe_identical_tokens_identical_outputs():
    cfg = _moe_cfg()
    p = init_moe(KEY, cfg)
    x = jnp.broadcast_to(jax.random.normal(KEY, (1, 1, 16)), (2, 8, 16))
    y, aux = apply_moe(p, x, cfg)
    np.testing.assert_allclose(
        np.asarray(y), np.broadcast_to(np.asarray(y[:1, :1]), y.shape), rtol=2e-5, atol=2e-5
    )
    assert float(aux) >= 1.0 - 1e-6  # aux loss is >= 1 at any routing


def test_moe_capacity_drops_tokens():
    cfg = _moe_cfg(capacity_factor=0.25)
    p = init_moe(KEY, cfg)
    x = jax.random.normal(KEY, (2, 16, 16))
    y, _ = apply_moe(p, x, cfg)
    assert np.isfinite(np.asarray(y)).all()


def test_runnable_shapes_rules():
    assert "long_500k" not in runnable_shapes(ARCHS["granite-8b"])
    assert "long_500k" in runnable_shapes(ARCHS["jamba-v0.1-52b"])
    assert "long_500k" in runnable_shapes(ARCHS["xlstm-1.3b"])
    assert set(runnable_shapes(ARCHS["yi-34b"])) == {"train_4k", "prefill_32k", "decode_32k"}
    assert len(SHAPES) == 4 and len(ARCHS) == 10


def test_moe_gather_dispatch_equals_scatter():
    """The permutation-gather dispatch (custom VJP) is exactly the scatter
    path: forward, parameter grads and input grads, with and without drops."""
    import jax

    for cf in (8.0, 0.3):
        cfg0 = _moe_cfg(capacity_factor=cf, num_shared_experts=1)
        cfg1 = dataclasses.replace(cfg0, moe_gather_dispatch=True)
        p = init_moe(KEY, cfg0)
        x = jax.random.normal(KEY, (2, 8, 16))
        y0, a0 = apply_moe(p, x, cfg0)
        y1, a1 = apply_moe(p, x, cfg1)
        np.testing.assert_allclose(np.asarray(y0), np.asarray(y1), rtol=1e-5, atol=1e-6)
        assert float(a0) == float(a1)
        g0 = jax.grad(lambda pp: (apply_moe(pp, x, cfg0)[0] ** 2).sum())(p)
        g1 = jax.grad(lambda pp: (apply_moe(pp, x, cfg1)[0] ** 2).sum())(p)
        for a, b in zip(jax.tree.leaves(g0), jax.tree.leaves(g1)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-6)
        gx0 = jax.grad(lambda xx: (apply_moe(p, xx, cfg0)[0] ** 2).sum())(x)
        gx1 = jax.grad(lambda xx: (apply_moe(p, xx, cfg1)[0] ** 2).sum())(x)
        np.testing.assert_allclose(np.asarray(gx0), np.asarray(gx1), rtol=1e-5, atol=1e-6)


def test_chunked_attention_equals_dense():
    from repro.models.common import causal_attention, chunked_causal_attention

    q = jax.random.normal(KEY, (2, 64, 4, 8))
    k = jax.random.normal(jax.random.PRNGKey(1), (2, 64, 2, 8))
    v = jax.random.normal(jax.random.PRNGKey(2), (2, 64, 2, 8))
    a = causal_attention(q, k, v, scale=8**-0.5)
    for chunk in (8, 16, 32):
        b = chunked_causal_attention(q, k, v, scale=8**-0.5, chunk=chunk)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-6)
    ga = jax.grad(lambda q: causal_attention(q, k, v, scale=8**-0.5).sum())(q)
    gb = jax.grad(lambda q: chunked_causal_attention(q, k, v, scale=8**-0.5, chunk=16).sum())(q)
    np.testing.assert_allclose(np.asarray(ga), np.asarray(gb), rtol=1e-5, atol=1e-6)


def test_perf_knobs_preserve_forward():
    """Every perf knob combination produces the same logits as the baseline
    (they change HLO structure, never math)."""
    cfg = dataclasses.replace(reduced(ARCHS["granite-8b"]), compute_dtype="float32")
    params = init_model(KEY, cfg)
    tokens = jax.random.randint(KEY, (2, 32), 0, cfg.vocab_size)
    base, _ = forward(params, cfg, tokens)
    for kw in (
        {"remat_policy": "dots"},
        {"remat_policy": "none"},
        {"attn_q_chunk": 8},
        {"logits_bf16_ce": True},  # logits stay f32-accurate in f32 compute
    ):
        variant = dataclasses.replace(cfg, **kw)
        out, _ = forward(params, variant, tokens)
        np.testing.assert_allclose(
            np.asarray(base), np.asarray(out), rtol=1e-4, atol=1e-4
        ), kw

"""Solver engine: registry behaviour, backend parity (Pallas-interpret vs the
exact Python DP, traceback included), batched solving, and the iterative DP's
independence from the interpreter recursion limit."""

import sys

import numpy as np
import pytest

from conftest import random_instance
from repro.core import (
    ALGORITHMS,
    dp_schedule,
    evaluate_detours,
    get_solver,
    list_solvers,
    lower_bound_gap,
    make_instance,
    schedule_makespan,
    solve,
    solve_batch,
    virtual_lb,
)
from repro.core import ExecutionContext, SolveCache, UnsupportedBackendError
from repro.core.solver import BACKENDS, DPSolver, register_solver

POLICIES = [
    "nodetour", "gs", "fgs", "nfgs", "lognfgs5",
    "logdp1", "logdp5", "simpledp", "dp",
]

DEV = ExecutionContext(backend="pallas-interpret")


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------
def test_registry_has_all_nine_policies():
    assert list_solvers() == POLICIES
    assert sorted(ALGORITHMS) == sorted(POLICIES)


def test_unknown_policy_and_backend_raise(rng):
    inst = random_instance(rng, hi=5)
    with pytest.raises(KeyError, match="unknown policy"):
        solve(inst, policy="nope")
    with pytest.raises(KeyError, match="unknown backend"):
        ExecutionContext(backend="cuda")
    # list heuristics have no device backend: loud error
    with pytest.raises(ValueError, match="backend"):
        solve(inst, policy="gs", context=DEV)


DEVICE_POLICIES = {"logdp1", "logdp5", "dp", "simpledp"}


def test_supports_device_capability_flag_all_nine_policies():
    """The registry capability flag matches the advertised backends for every
    policy: the DP family and SIMPLEDP have a device path, heuristics not."""
    for name in POLICIES:
        solver = get_solver(name)
        expected = name in DEVICE_POLICIES
        assert solver.supports_device is expected, name
        assert ("pallas" in solver.backends) is expected, name
        assert ("pallas-interpret" in solver.backends) is expected, name
        assert "python" in solver.backends, name


def test_unsupported_backend_error_is_typed_and_message_stable(rng):
    """Device backends on python-only policies raise the typed error with the
    documented message, via solve() AND solve_batch(), for all nine."""
    inst = random_instance(rng, hi=5)
    for name in POLICIES:
        solver = get_solver(name)
        for backend in ("pallas", "pallas-interpret"):
            if solver.supports_device:
                continue
            expected_msg = (
                f"policy {name!r} has no {backend!r} backend "
                f"(supported: {solver.backends})"
            )
            ctx = ExecutionContext(backend=backend)
            with pytest.raises(UnsupportedBackendError) as ei:
                solve(inst, policy=name, context=ctx)
            assert str(ei.value) == expected_msg, name
            assert isinstance(ei.value, ValueError)  # old callers keep working
            assert (ei.value.policy, ei.value.backend) == (name, backend)
            with pytest.raises(UnsupportedBackendError) as ei:
                solve_batch([inst, inst], policy=name, context=ctx)
            assert str(ei.value) == expected_msg, name


def test_unsupported_backend_batch_fails_before_any_solve(rng):
    """A python-only policy on a device backend must be all-or-nothing
    through solve_batch: no partial solving, no cache-miss pollution before
    the raise."""
    insts = [random_instance(rng, hi=5) for _ in range(3)]
    cache = SolveCache()
    with pytest.raises(UnsupportedBackendError):
        solve_batch(insts, policy="gs", context=DEV.replace(cache=cache))
    assert cache.stats() == {"hits": 0, "misses": 0, "entries": 0, "warm_entries": 0}
    with pytest.raises(UnsupportedBackendError):
        solve(
            insts[0], policy="nfgs",
            context=ExecutionContext(backend="pallas", cache=cache),
        )
    assert cache.stats() == {"hits": 0, "misses": 0, "entries": 0, "warm_entries": 0}


def test_register_custom_solver(rng):
    s = DPSolver("dp-span3", span_policy=lambda n: 3, description="test-only")
    register_solver(s)
    try:
        inst = random_instance(rng, hi=8)
        res = solve(inst, policy="dp-span3")
        assert res.cost == dp_schedule(inst, span=3)[0]
        with pytest.raises(ValueError):
            register_solver(DPSolver("dp-span3"))
    finally:
        from repro.core.solver import _REGISTRY

        _REGISTRY.pop("dp-span3")


def test_algorithms_shim_returns_detours(rng):
    inst = random_instance(rng, hi=6)
    for name, algo in ALGORITHMS.items():
        dets = algo(inst)
        assert isinstance(dets, list)
        assert evaluate_detours(inst, dets) == solve(inst, policy=name).cost


# ---------------------------------------------------------------------------
# reported cost == simulator-scored cost for every policy (python backend)
# ---------------------------------------------------------------------------
def test_all_policies_cost_matches_simulator(rng):
    for _ in range(6):
        inst = random_instance(rng, hi=18)
        for policy in POLICIES:
            res = solve(inst, policy=policy)
            assert res.cost == evaluate_detours(inst, res.detours), policy
            assert res.cost >= virtual_lb(inst)


def test_all_policies_cost_matches_simulator_on_bench_dataset():
    from repro.data import BENCH_PROFILE, generate_instance

    for seed in range(4):
        inst = generate_instance(BENCH_PROFILE, seed=20210917 + seed, u_turn=1000)
        opt = None
        for policy in POLICIES:
            res = solve(inst, policy=policy)
            assert res.cost == evaluate_detours(inst, res.detours), policy
            if policy == "dp":
                opt = res.cost
        assert opt is not None and all(
            solve(inst, policy=p).cost >= opt for p in ("gs", "nodetour")
        )


# ---------------------------------------------------------------------------
# Pallas backend parity: full (cost, detours) vs the exact DP
# ---------------------------------------------------------------------------
def test_pallas_interpret_parity_50_instances():
    """>= 50 random instances: device detour cost == exact optimum.

    Mix of U = 0 and U > 0 instances, coordinates up to ~2**19 in the tail
    (int32-table-safe with small multiplicities), every instance exercising
    the argmin-plane traceback.
    """
    rng = np.random.default_rng(20260731)
    checked = 0
    with_u = 0
    for trial in range(52):
        if trial % 4 == 0:  # large coordinates, small n: stress magnitudes
            R = int(rng.integers(2, 7))
            sizes = rng.integers(1, 2**16, size=R)
            gaps = rng.integers(0, 2**16, size=R + 1)
            mult = rng.integers(1, 3, size=R)
            u = int(rng.integers(0, 2**14))
        else:
            R = int(rng.integers(2, 11))
            sizes = rng.integers(1, 60, size=R)
            gaps = rng.integers(0, 50, size=R + 1)
            mult = rng.integers(1, 6, size=R)
            u = int(rng.integers(0, 40))
        left, pos = [], int(gaps[0])
        for i in range(R):
            left.append(pos)
            pos += int(sizes[i] + gaps[i + 1])
        inst = make_instance(left, sizes, mult, m=pos, u_turn=u)
        with_u += u > 0

        opt, _ = dp_schedule(inst)
        res = solve(inst, policy="dp", context=DEV)
        assert res.cost == opt, (trial, res.cost, opt)
        assert evaluate_detours(inst, res.detours) == opt, (trial, res.detours)
        checked += 1
    assert checked >= 50
    assert with_u >= 10  # the U-turn penalty path is genuinely exercised


def test_pallas_interpret_logdp_span_parity(rng):
    for _ in range(8):
        inst = random_instance(rng, hi=10)
        for policy in ("logdp1", "logdp5"):
            py = solve(inst, policy=policy)
            dev = solve(inst, policy=policy, context=DEV)
            assert dev.cost == py.cost, policy
            assert evaluate_detours(inst, dev.detours) == py.cost


def test_pallas_interpret_simpledp_bit_parity(rng):
    """SIMPLEDP rides the wavefront's disjoint candidate clip: cost *and*
    detours must be bit-identical to the dedicated 2-D python recursion, and
    stay sandwiched between the exact DP and the heuristics."""
    from repro.core import simpledp_schedule

    checked = 0
    for _ in range(25):
        inst = random_instance(rng, lo=1, hi=14)
        py_cost, py_dets = simpledp_schedule(inst)
        dev = solve(inst, policy="simpledp", context=DEV)
        assert (dev.cost, dev.detours) == (py_cost, py_dets)
        assert evaluate_detours(inst, dev.detours) == dev.cost
        assert dp_schedule(inst)[0] <= dev.cost
        checked += 1
    assert checked >= 25
    # batched simpledp device solving is bit-identical too
    insts = [random_instance(rng, lo=1, hi=12) for _ in range(8)]
    for inst, res in zip(insts, solve_batch(insts, policy="simpledp", context=DEV)):
        assert (res.cost, res.detours) == simpledp_schedule(inst)


def test_solve_batch_one_launch_matches_per_instance(rng):
    insts = [random_instance(rng, lo=1, hi=9) for _ in range(6)]
    batched = solve_batch(insts, policy="dp", context=DEV)
    for inst, res in zip(insts, batched):
        assert res.cost == dp_schedule(inst)[0]
        assert evaluate_detours(inst, res.detours) == res.cost
        assert res.backend == "pallas-interpret"


# int32-guard + gcd-rescaling coverage lives in tests/test_batching.py
# (test_rescale_accepts_tape_block_granularity_coordinates,
#  test_guard_still_rejects_unrescalable_instances).


# ---------------------------------------------------------------------------
# storage integration: backend selector through schedule_reads / TapeLibrary
# ---------------------------------------------------------------------------
def test_schedule_reads_backend_selector():
    from repro.storage.tape import Tape, schedule_reads

    rng = np.random.default_rng(5)
    t = Tape("T0", capacity=500_000, u_turn=900)
    for i in range(12):
        t.append(f"f{i:02d}", int(rng.integers(1_000, 40_000)))
    reqs = {f"f{i:02d}": int(rng.integers(1, 5)) for i in range(0, 12, 2)}
    py = schedule_reads(t, reqs, policy="dp")
    dev = schedule_reads(t, reqs, policy="dp", context=DEV)
    assert dev.total_cost == py.total_cost
    assert dev.service_time == py.service_time
    assert dev.backend == "pallas-interpret"


def test_library_schedule_batches_on_device():
    from repro.storage.tape import TapeLibrary

    lib = TapeLibrary(capacity_per_tape=120_000, u_turn=500)
    for i in range(12):
        lib.store(f"shard{i:02d}", 25_000)  # ~4 shards per tape
    assert len(lib.tapes) >= 3
    reqs = {f"shard{i:02d}": 1 + i % 3 for i in range(12)}
    py = lib.schedule(reqs, policy="dp")
    dev = lib.schedule(reqs, policy="dp", context=DEV)
    assert [p.total_cost for p in py] == [p.total_cost for p in dev]
    assert sum(len(p.order) for p in dev) == 12


# ---------------------------------------------------------------------------
# iterative DP: no recursion-limit dependence
# ---------------------------------------------------------------------------
def test_dp_runs_under_tiny_recursion_limit():
    """The seed's recursive DP needed ~10x n_req stack depth; the iterative
    rewrite must solve an R >> limit instance without touching the limit."""
    R = 150
    rng = np.random.default_rng(9)
    sizes = rng.integers(1, 4, size=R)
    gaps = rng.integers(0, 3, size=R + 1)
    left, pos = [], int(gaps[0])
    for i in range(R):
        left.append(pos)
        pos += int(sizes[i] + gaps[i + 1])
    inst = make_instance(left, sizes, np.ones(R, np.int64), m=pos, u_turn=2)

    old = sys.getrecursionlimit()
    sys.setrecursionlimit(120)
    try:
        from repro.core import simpledp_schedule

        opt, dets = dp_schedule(inst, span=4)
        sdp, sdets = simpledp_schedule(inst)
    finally:
        sys.setrecursionlimit(old)
    assert opt == evaluate_detours(inst, dets)
    assert sdp == evaluate_detours(inst, sdets)
    import repro.core.dp

    src = open(repro.core.dp.__file__).read()
    assert "setrecursionlimit" not in src


# ---------------------------------------------------------------------------
# satellite: schedule metric exports
# ---------------------------------------------------------------------------
def test_schedule_metric_exports(rng):
    inst = random_instance(rng, hi=8)
    res = solve(inst, policy="dp")
    mk = schedule_makespan(inst, res.detours)
    assert mk >= max(inst.m - int(inst.left[0]), 1)
    gap = lower_bound_gap(inst, res.cost)
    assert gap >= 1.0 or virtual_lb(inst) == 0

"""Fleet federation: placement, replica routing, outages, journal merge.

Acceptance bars:

* **differential pin** — a one-shard ``single`` federation on the seeded
  240-request constrained-pool trace is *bit-identical* to a standalone
  :class:`~repro.serving.queue.OnlineTapeServer` for every pinned admission
  policy (same sha over served timelines, same total sojourn, byte-identical
  write-ahead journal): the fleet layer adds nothing to the default path;
* placement strategies route only to replica holders, deterministically,
  and conserve requests (served + failed == trace) with and without an
  injected :class:`~repro.serving.ShardOutage`;
* a whole-shard outage re-routes orphaned queued requests to surviving
  replicas (marked ``faulted``) and ``replica-affinity`` completes at least
  as many requests as oblivious ``static-hash``;
* **journal-merge determinism** — truncating any shard's journal at *every*
  cut point and running :func:`~repro.fleet.recover_fleet` re-executes the
  federation byte-identically (all shard journals complete to the
  uninterrupted bytes, the merged report matches);
* trace schema v2: the optional ``library`` field round-trips, v1 files
  keep their exact bytes, and a v1 file smuggling the field is rejected.
"""

import dataclasses
import hashlib
from pathlib import Path

import pytest

from repro.core import ExecutionContext, FleetOptions
from repro.data.traces import (
    TRACE_SCHEMA,
    TRACE_SCHEMA_V2,
    TraceRecord,
    qos_poisson_trace,
    read_trace,
    write_trace,
)
from repro.fleet import (
    PLACEMENTS,
    FleetServer,
    FleetView,
    ReplicaMap,
    demo_fleet,
    fleet_catalog,
    get_placement,
    list_placements,
    merge_journals,
    merge_reports,
    recover_fleet,
    register_placement,
    serve_fleet_trace,
    shard_journal_path,
)
from repro.serving import (
    DriveCosts,
    JournalReplayError,
    RetryPolicy,
    ShardOutage,
    demo_library,
    poisson_trace,
    serve_trace,
)

pytestmark = pytest.mark.fleet

SEED = 20260731
COSTS = DriveCosts(mount=150_000, unmount=60_000, load_seek=30_000)

#: the PR-8 no-fault pins from test_faults.NO_FAULT_BASELINE: the one-shard
#: ``single`` federation must reproduce them bit-for-bit.
NO_FAULT_BASELINE = {
    "fifo": ("1a79c55063c3f802", 56_368_550_889),
    "accumulate": ("df9ed258ac816c37", 3_809_190_213),
    "preempt": ("668366586042762a", 7_347_259_813),
    "fifo-global": ("1a79c55063c3f802", 56_368_550_889),
    "per-drive-accumulate": ("df9ed258ac816c37", 3_809_190_213),
    "batched": ("df9ed258ac816c37", 3_809_190_213),
}


def build_library():
    return demo_library(SEED)


def build_trace(n_requests=240, rate=250_000):
    return poisson_trace(
        build_library(), n_requests=n_requests, mean_interarrival=rate, seed=SEED
    )


def build_fleet(n_shards=3, replicas=2):
    return demo_fleet(SEED, n_shards=n_shards, replicas=replicas)


def fleet_trace(libs, rmap, n_requests=120, rate=30_000):
    return poisson_trace(
        fleet_catalog(libs, rmap), n_requests=n_requests,
        mean_interarrival=rate, seed=SEED,
    )


def _served_sha(report):
    served = tuple(
        (r.req_id, r.arrival, r.dispatched, r.completed) for r in report.served
    )
    return hashlib.sha256(repr(served).encode()).hexdigest()[:16]


def _timeline(report):
    return [
        (r.req_id, r.arrival, r.dispatched, r.completed, r.faulted)
        for r in report.served
    ] + [(f.req_id, f.failed_at, f.reason) for f in report.failed]


# ---------------------------------------------------------------------------
# acceptance: the one-shard `single` fleet is bit-identical to no fleet
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("admission", sorted(NO_FAULT_BASELINE))
def test_single_placement_matches_standalone_pin(admission):
    sha, total = NO_FAULT_BASELINE[admission]
    fr = serve_fleet_trace(
        [build_library()], build_trace(), admission, placement="single",
        window=400_000, policy="dp", n_drives=2, drive_costs=COSTS,
    )
    assert (_served_sha(fr.merged), fr.merged.total_sojourn) == (sha, total)
    standalone = serve_trace(
        build_library(), build_trace(), admission, window=400_000,
        policy="dp", n_drives=2, drive_costs=COSTS,
    )
    assert _timeline(fr.merged) == _timeline(standalone)
    assert _timeline(fr.shards[0]) == _timeline(standalone)
    assert fr.placement == "single" and fr.n_shards == 1
    assert fr.routes == {0: len(build_trace())} and fr.n_rerouted == 0
    # the merged summary is the standalone summary plus the fleet block
    merged_summary = fr.summary()
    assert merged_summary.pop("fleet")["n_shards"] == 1
    assert merged_summary == standalone.summary()


def test_single_placement_journal_is_byte_identical(tmp_path):
    """The degenerate federation's write-ahead journal must be the
    standalone server's journal, byte for byte."""
    solo = tmp_path / "solo.journal"
    serve_trace(
        build_library(), build_trace(), "accumulate", window=400_000,
        policy="dp", n_drives=2, drive_costs=COSTS, journal=str(solo),
    )
    base = tmp_path / "fleet.journal"
    serve_fleet_trace(
        [build_library()], build_trace(), "accumulate", placement="single",
        window=400_000, policy="dp", n_drives=2, drive_costs=COSTS,
        journal=str(base),
    )
    assert Path(shard_journal_path(base, 0)).read_bytes() == solo.read_bytes()


# ---------------------------------------------------------------------------
# replica map
# ---------------------------------------------------------------------------
def test_replica_map_from_demo_fleet():
    libs, rmap = build_fleet(n_shards=3, replicas=2)
    assert len(rmap) == 48
    for name, holders in rmap.holders_of.items():
        assert len(holders) == 2
        assert list(holders) == sorted(set(holders))
        # every replica is the same logical object: identical stored size
        sizes = {libs[s].tape_of(name).files[name].size for s in holders}
        assert len(sizes) == 1
    # file i's construction-time origin shard i % n_shards always holds it;
    # ReplicaMap.primary is the lowest-indexed holder
    for i in range(48):
        name = f"obj{i:04d}"
        assert i % 3 in rmap.holders(name)
        assert rmap.primary(name) == min(rmap.holders(name))
    rmap.validate(libs)


def test_replica_map_validation_errors():
    libs, _ = build_fleet(n_shards=2, replicas=1)
    with pytest.raises(ValueError, match="no replica holders"):
        ReplicaMap({"f": ()})
    with pytest.raises(ValueError, match="sorted and unique"):
        ReplicaMap({"f": (1, 0)})
    with pytest.raises(ValueError, match="negative"):
        ReplicaMap({"f": (-1,)})
    with pytest.raises(ValueError, match="only 2 shard"):
        ReplicaMap({"obj0000": (0, 5)}).validate(libs)
    with pytest.raises(ValueError, match="does not store"):
        ReplicaMap({"obj0000": (0, 1)}).validate(libs)  # obj0000 lives on 0
    with pytest.raises(ValueError, match="not stored on any shard"):
        ReplicaMap.from_libraries(libs).holders("nope")


def test_fleet_catalog_maps_primaries():
    libs, rmap = build_fleet(n_shards=2, replicas=1)
    cat = fleet_catalog(libs, rmap)
    assert cat.location["obj0000"] == libs[0].location["obj0000"]
    assert cat.location["obj0001"] == libs[1].location["obj0001"]
    assert set(cat.location) == set(rmap.holders_of)


def test_demo_fleet_validates_replication():
    with pytest.raises(ValueError, match="replicas"):
        demo_fleet(SEED, n_shards=2, replicas=3)
    with pytest.raises(ValueError, match="n_shards"):
        demo_fleet(SEED, n_shards=0)


# ---------------------------------------------------------------------------
# placement registry
# ---------------------------------------------------------------------------
def test_placement_registry():
    assert list_placements() == sorted(PLACEMENTS)
    assert {"single", "static-hash", "least-loaded", "replica-affinity"} <= set(
        PLACEMENTS
    )
    assert get_placement("static-hash").name == "static-hash"
    inst = get_placement("least-loaded")
    assert get_placement(inst) is inst  # instances pass through
    with pytest.raises(ValueError, match="unknown placement"):
        get_placement("round-robin")
    with pytest.raises(TypeError, match="not a PlacementStrategy"):
        get_placement(42)


def test_register_custom_placement():
    class EverySecond:
        name = "every-second"
        dynamic = False

        def pick(self, name, candidates, view):
            return candidates[-1]

    try:
        register_placement(EverySecond)
        assert get_placement("every-second").pick("f", (0, 1), None) == 1
    finally:
        PLACEMENTS.pop("every-second", None)
    with pytest.raises(ValueError, match="string name"):
        register_placement(object)


def test_static_hash_is_stable_and_feasible():
    pl = get_placement("static-hash")
    view = FleetView(now=0, shards=())
    for name in ("obj0000", "obj0017", "anything"):
        picks = {pl.pick(name, (0, 2, 5), view) for _ in range(3)}
        assert len(picks) == 1 and picks <= {0, 2, 5}
    # a different candidate set re-ranges the same hash
    assert pl.pick("obj0000", (3,), view) == 3


# ---------------------------------------------------------------------------
# construction validation
# ---------------------------------------------------------------------------
def test_single_with_many_shards_raises():
    libs, rmap = build_fleet(n_shards=2, replicas=1)
    with pytest.raises(ValueError, match="one-shard NoOp default"):
        FleetServer(libs, placement="single", replica_map=rmap)


def test_context_fleet_options_must_agree_on_shard_count():
    libs, rmap = build_fleet(n_shards=2, replicas=1)
    ctx = ExecutionContext(fleet=FleetOptions(n_shards=3, placement="least-loaded"))
    with pytest.raises(ValueError, match="context.fleet says 3"):
        FleetServer(libs, replica_map=rmap, context=ctx)
    # an agreeing context supplies the placement when none is given
    ctx = ExecutionContext(fleet=FleetOptions(n_shards=2, placement="least-loaded"))
    fleet = FleetServer(libs, replica_map=rmap, context=ctx)
    assert fleet.placement.name == "least-loaded"


def test_outage_validation():
    libs, rmap = build_fleet(n_shards=2, replicas=1)
    with pytest.raises(ValueError, match="only 2 shard"):
        FleetServer(libs, placement="static-hash", replica_map=rmap,
                    outages=(ShardOutage(at=10, shard=5),))
    with pytest.raises(TypeError, match="ShardOutage"):
        FleetServer(libs, placement="static-hash", replica_map=rmap,
                    outages=("shard-1",))


def test_fleet_options_validate():
    with pytest.raises(ValueError):
        FleetOptions(n_shards=0)
    with pytest.raises(ValueError):
        FleetOptions(n_shards=2, replicas=0)
    with pytest.raises(ValueError):
        FleetOptions(n_shards=2, replicas=3)
    opts = FleetOptions(n_shards=2, replicas=2).replace(placement="static-hash")
    assert opts.placement == "static-hash" and opts.n_shards == 2


def test_unknown_file_fails_fast():
    libs, rmap = build_fleet(n_shards=2, replicas=1)
    trace = fleet_trace(libs, rmap, n_requests=4)
    ghost = dataclasses.replace(trace[0], name="ghost", req_id=999)
    with pytest.raises(ValueError, match="not stored on any shard"):
        serve_fleet_trace(libs, trace + [ghost], placement="static-hash",
                          replica_map=rmap, n_drives=2)


# ---------------------------------------------------------------------------
# routing: determinism, feasibility, conservation
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("placement", ["static-hash", "least-loaded",
                                       "replica-affinity"])
def test_placements_conserve_and_repeat(placement):
    libs, rmap = build_fleet()
    trace = fleet_trace(libs, rmap)
    runs = []
    for _ in range(2):
        libs, rmap = build_fleet()
        fr = serve_fleet_trace(
            libs, trace, "accumulate", placement=placement, replica_map=rmap,
            window=400_000, n_drives=2, drive_costs=COSTS,
        )
        assert fr.n_served + fr.n_failed == len(trace)
        assert fr.n_failed == 0  # healthy fleet loses nothing
        assert sum(fr.routes.values()) == len(trace)
        # every shard served only files it actually holds
        for i, shard_report in enumerate(fr.shards):
            for r in shard_report.served:
                assert i in rmap.holders(r.name)
        runs.append((_timeline(fr.merged), fr.routes, _served_sha(fr.merged)))
    assert runs[0] == runs[1]  # same trace + config => bit-identical


def test_merged_report_sums_shards():
    libs, rmap = build_fleet()
    trace = fleet_trace(libs, rmap)
    fr = serve_fleet_trace(
        libs, trace, "accumulate", placement="least-loaded", replica_map=rmap,
        window=400_000, n_drives=2, drive_costs=COSTS,
    )
    assert fr.n_served == sum(r.n_served for r in fr.shards)
    assert fr.merged.horizon == max(r.horizon for r in fr.shards)
    assert fr.total_sojourn == sum(r.total_sojourn for r in fr.shards)
    assert len(fr.merged.batches) == sum(len(r.batches) for r in fr.shards)
    # served rows are globally ordered by (completed, req_id)
    keys = [(r.completed, r.req_id) for r in fr.merged.served]
    assert keys == sorted(keys)
    pool = fr.merged.pool_stats
    assert pool["n_drives"] == sum(r.pool_stats["n_drives"] for r in fr.shards)


def test_merge_reports_with_empty_shard_reports():
    """A shard that saw no arrivals merges as a no-op: counters add zero,
    the horizon stays the busy shard's, and an all-empty federation merges
    to an exactly-empty report (not an error)."""
    libs, rmap = build_fleet(n_shards=2, replicas=1)
    trace = fleet_trace(libs, rmap, n_requests=24)
    busy = serve_trace(libs[0], [r for r in trace if 0 == rmap.primary(r.name)],
                       "accumulate", window=400_000, n_drives=2,
                       drive_costs=COSTS)
    idle = serve_trace(libs[1], [], "accumulate", window=400_000, n_drives=2,
                       drive_costs=COSTS)
    merged = merge_reports([busy, idle])
    assert merged.n_served == busy.n_served and merged.n_failed == 0
    assert merged.horizon == busy.horizon
    assert merged.total_sojourn == busy.total_sojourn
    assert _timeline(merged) == _timeline(busy)
    # pool stats still sum: the idle pool contributes its configured drives
    assert merged.pool_stats["n_drives"] == 4
    assert merged.pool_stats["mounts"] == busy.pool_stats["mounts"]
    # an all-empty federation is a valid (empty) report
    empty = merge_reports([idle, idle])
    assert empty.n_served == 0 and empty.horizon == 0 and empty.served == []


def test_merge_reports_zero_completions_nonzero_drops():
    """A federation that drops *everything* (every shard dark at t=0) still
    merges exactly: zero served rows, every request a typed failure, and
    the summary's sojourn quantiles read as zeros instead of dividing by
    an empty distribution."""
    libs, rmap = build_fleet(n_shards=2, replicas=1)
    trace = fleet_trace(libs, rmap, n_requests=24)
    fr = serve_fleet_trace(
        libs, trace, "accumulate", placement="static-hash", replica_map=rmap,
        outages=(ShardOutage(at=0, shard=0), ShardOutage(at=0, shard=1)),
        window=400_000, n_drives=2, drive_costs=COSTS,
        retry=RetryPolicy(on_exhausted="drop"),
    )
    merged = fr.merged
    assert merged.n_served == 0 and merged.n_failed == len(trace)
    assert merged.total_sojourn == 0
    # failed rows re-sort under the single-server order (failed_at, req_id)
    keys = [(f.failed_at, f.req_id) for f in merged.failed]
    assert keys == sorted(keys)
    s = fr.summary()
    assert s["n_served"] == 0 and s["mean_sojourn"] == 0
    assert s["p50_sojourn"] == 0 and s["p99_sojourn"] == 0


def test_merge_reports_sums_fault_stats():
    """Merged ``fault_stats`` is the key-wise sum of the per-shard dicts,
    and stays absent when absent on every shard."""
    libs, rmap = build_fleet()
    trace = fleet_trace(libs, rmap)
    fr = serve_fleet_trace(
        libs, trace, "accumulate", placement="replica-affinity",
        replica_map=rmap, outages=(ShardOutage(at=1_500_000, shard=1),),
        window=400_000, n_drives=2, drive_costs=COSTS,
        retry=RetryPolicy(on_exhausted="drop"),
    )
    per_shard = [r.fault_stats for r in fr.shards if r.fault_stats]
    assert per_shard, "the outage must have produced fault accounting"
    want: dict = {}
    for d in per_shard:
        for k, v in d.items():
            want[k] = want.get(k, 0) + v
    assert fr.merged.fault_stats == want
    # fault-free federation: the section stays absent, not zero-filled
    libs, rmap = build_fleet()
    calm = serve_fleet_trace(
        libs, trace, "accumulate", placement="replica-affinity",
        replica_map=rmap, window=400_000, n_drives=2, drive_costs=COSTS,
    )
    assert calm.merged.fault_stats is None
    assert all(r.fault_stats is None for r in calm.shards)


def test_merge_reports_rejects_mixed_configs():
    libs, rmap = build_fleet(n_shards=2, replicas=1)
    trace = fleet_trace(libs, rmap, n_requests=24)
    a = serve_trace(libs[0], [r for r in trace if 0 == rmap.primary(r.name)],
                    "accumulate", window=400_000, n_drives=2, drive_costs=COSTS)
    b = serve_trace(libs[1], [r for r in trace if 1 == rmap.primary(r.name)],
                    "fifo", n_drives=2, drive_costs=COSTS)
    with pytest.raises(ValueError, match="disagrees on admission"):
        merge_reports([a, b])
    with pytest.raises(ValueError, match="at least one"):
        merge_reports([])


# ---------------------------------------------------------------------------
# shared fault domain: a whole shard goes dark
# ---------------------------------------------------------------------------
def test_outage_reroutes_orphans_to_surviving_replicas():
    outages = (ShardOutage(at=1_500_000, shard=1),)
    results = {}
    for placement in ("static-hash", "replica-affinity"):
        libs, rmap = build_fleet()
        trace = fleet_trace(libs, rmap)
        results[placement] = serve_fleet_trace(
            libs, trace, "accumulate", placement=placement, replica_map=rmap,
            outages=outages, window=400_000, n_drives=2, drive_costs=COSTS,
            retry=RetryPolicy(on_exhausted="drop"),
        )
    affinity, static = results["replica-affinity"], results["static-hash"]
    # the outage orphaned queued work that had replicas elsewhere
    assert affinity.n_rerouted > 0
    rerouted = [r for r in affinity.merged.served if r.faulted]
    assert len(rerouted) >= affinity.n_rerouted  # every orphan completed
    # replica routing strictly dominates oblivious hashing under the outage
    assert affinity.n_served > static.n_served
    assert affinity.n_failed == 0
    assert static.n_failed > 0  # kept hashing into the dark shard
    # the dark shard dispatched nothing after the outage instant
    for r in affinity.shards[1].served:
        assert r.dispatched < outages[0].at
    assert affinity.shards[1].pool_stats["alive_drives"] == 0
    assert affinity.shards[1].pool_stats["drive_failures"] == 2
    # conservation still holds, failures included
    for fr in results.values():
        assert fr.n_served + fr.n_failed == len(trace)


def test_outage_before_arrivals_routes_away_immediately():
    """An outage at t strikes before same-instant arrivals are routed, so a
    dynamic placement never routes a live arrival into the dark shard."""
    libs, rmap = build_fleet(n_shards=2, replicas=2)
    trace = fleet_trace(libs, rmap, n_requests=40)
    fr = serve_fleet_trace(
        libs, trace, "accumulate", placement="least-loaded", replica_map=rmap,
        outages=(ShardOutage(at=0, shard=0),), window=400_000, n_drives=2,
        drive_costs=COSTS, retry=RetryPolicy(on_exhausted="drop"),
    )
    # with 2-way replication every file survives on shard 1
    assert fr.n_served == len(trace) and fr.n_failed == 0
    assert fr.shards[0].n_served == 0


# ---------------------------------------------------------------------------
# acceptance: journal-merge determinism from every cut point (satellite)
# ---------------------------------------------------------------------------
def _journaled_run(tmp_path, libs, rmap, trace, outages, journal=None):
    return serve_fleet_trace(
        libs, trace, "accumulate", placement="replica-affinity",
        replica_map=rmap, outages=outages, window=400_000, n_drives=2,
        drive_costs=COSTS, retry=RetryPolicy(on_exhausted="drop"),
        journal=journal,
    )


def test_recover_fleet_from_every_cut_point(tmp_path):
    n_shards = 2
    libs, rmap = build_fleet(n_shards=n_shards, replicas=2)
    trace = fleet_trace(libs, rmap, n_requests=60)
    outages = (ShardOutage(at=1_500_000, shard=0),)
    base = tmp_path / "fleet.journal"
    reference = _journaled_run(tmp_path, libs, rmap, trace, outages, str(base))
    ref_bytes = {
        i: Path(shard_journal_path(base, i)).read_bytes()
        for i in range(n_shards)
    }
    ref_timeline = _timeline(reference.merged)
    for shard in range(n_shards):
        n = len(ref_bytes[shard])
        for cut in (0, 10, n // 3, n // 2, n - 5, n):
            for i in range(n_shards):  # restore both, then tear one
                Path(shard_journal_path(base, i)).write_bytes(ref_bytes[i])
            Path(shard_journal_path(base, shard)).write_bytes(
                ref_bytes[shard][:cut]
            )
            libs, rmap = build_fleet(n_shards=n_shards, replicas=2)
            recovered = recover_fleet(
                libs, trace, str(base), "accumulate",
                placement="replica-affinity", replica_map=rmap,
                outages=outages, window=400_000, n_drives=2,
                drive_costs=COSTS, retry=RetryPolicy(on_exhausted="drop"),
            )
            assert _timeline(recovered.merged) == ref_timeline, (
                f"shard {shard} cut at byte {cut} diverged"
            )
            for i in range(n_shards):
                assert (
                    Path(shard_journal_path(base, i)).read_bytes()
                    == ref_bytes[i]
                ), f"shard {i} journal not byte-identical (cut {cut})"


def test_recover_fleet_rejects_foreign_journal(tmp_path):
    libs, rmap = build_fleet(n_shards=2, replicas=2)
    trace = fleet_trace(libs, rmap, n_requests=60)
    base = tmp_path / "fleet.journal"
    _journaled_run(tmp_path, libs, rmap, trace, (), str(base))
    libs, rmap = build_fleet(n_shards=2, replicas=2)
    with pytest.raises(JournalReplayError):
        recover_fleet(
            libs, fleet_trace(libs, rmap, n_requests=60, rate=25_000),
            str(base), "accumulate", placement="replica-affinity",
            replica_map=rmap, window=400_000, n_drives=2, drive_costs=COSTS,
            retry=RetryPolicy(on_exhausted="drop"),
        )


def test_merge_journals_is_deterministic(tmp_path):
    n_shards = 2
    libs, rmap = build_fleet(n_shards=n_shards, replicas=2)
    trace = fleet_trace(libs, rmap, n_requests=60)
    base = tmp_path / "fleet.journal"
    _journaled_run(tmp_path, libs, rmap, trace,
                   (ShardOutage(at=1_500_000, shard=0),), str(base))
    stream = merge_journals(base, n_shards)
    assert stream == merge_journals(base, n_shards)
    assert all("shard" in ev for ev in stream)
    assert {ev["shard"] for ev in stream} == {0, 1}
    assert stream[0]["ev"] == "start" and stream[-1]["ev"] == "end"
    # timed events are globally ordered by (t, shard)
    timed = [ev for ev in stream if ev["ev"] not in ("start", "end")]
    keys = [(ev["t"], ev["shard"]) for ev in timed]
    assert keys == sorted(keys)
    with pytest.raises(ValueError, match="n_shards"):
        merge_journals(base, 0)


# ---------------------------------------------------------------------------
# trace schema v2: the optional origin-library label (satellite)
# ---------------------------------------------------------------------------
def test_v1_trace_bytes_are_unchanged(tmp_path):
    recs = qos_poisson_trace(build_library(), n_requests=12,
                             mean_interarrival=50_000, seed=SEED)
    assert all(r.library is None for r in recs)
    path = write_trace(tmp_path / "t.jsonl", recs)
    text = path.read_text()
    assert TRACE_SCHEMA in text.splitlines()[0]
    assert "library" not in text  # absent field stays absent on disk
    assert read_trace(path) == recs


def test_v2_trace_round_trips_library_labels(tmp_path):
    recs = qos_poisson_trace(
        build_library(), n_requests=12, mean_interarrival=50_000, seed=SEED,
        libraries=("shard0", "shard1", "shard2"),
    )
    assert all(r.library in {"shard0", "shard1", "shard2"} for r in recs)
    path = write_trace(tmp_path / "t.jsonl", recs)
    assert TRACE_SCHEMA_V2 in path.read_text().splitlines()[0]
    assert read_trace(path) == recs


def test_v1_file_with_library_field_is_rejected(tmp_path):
    path = tmp_path / "bad.jsonl"
    path.write_text(
        '{"schema":"ltsp-trace/v1"}\n'
        '{"arrival":0,"tape":"t0","file":"f","library":"shard0"}\n'
    )
    with pytest.raises(ValueError, match="needs a 'ltsp-trace/v2' header"):
        read_trace(path)
    with pytest.raises(ValueError, match="non-empty label"):
        TraceRecord(arrival=0, tape="t0", file="f", library="")


def test_library_draw_is_independent_of_the_workload():
    plain = qos_poisson_trace(build_library(), n_requests=24,
                              mean_interarrival=50_000, seed=SEED)
    labelled = qos_poisson_trace(
        build_library(), n_requests=24, mean_interarrival=50_000, seed=SEED,
        libraries=("a", "b"),
    )
    # the label draw is a separate seeded stream: arrivals, files, classes
    # and deadlines are untouched
    assert [dataclasses.replace(r, library=None) for r in labelled] == plain
    again = qos_poisson_trace(
        build_library(), n_requests=24, mean_interarrival=50_000, seed=SEED,
        libraries=("a", "b"),
    )
    assert labelled == again  # seeded: deterministic
    assert {r.library for r in labelled} == {"a", "b"}
    with pytest.raises(ValueError, match="non-empty"):
        qos_poisson_trace(build_library(), n_requests=4,
                          mean_interarrival=50_000, seed=SEED, libraries=())

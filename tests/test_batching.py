"""Size-bucketed batch planner, coordinate rescaling, and the solve memo
cache: bucketed ``solve_batch`` must be bit-identical to per-instance solving
(cost *and* detours), empty/single batches take their fast paths, gcd
rescaling widens the int32 device envelope, and cache hits never alias."""

import numpy as np
import pytest

from repro.core import (
    ExecutionContext,
    SolveCache,
    dp_schedule,
    evaluate_detours,
    make_instance,
    solve,
    solve_batch,
)

from repro.kernels.ltsp_dp.ops import (
    bucket_shape,
    ltsp_solve_batch,
    ltsp_solve_instance,
    plan_buckets,
    prepare_batch,
    rescale_instance,
)

DEV = ExecutionContext(backend="pallas-interpret")


def _hetero_instance(rng):
    """Mixed-size instance: n_req from 2..20, multiplicities up to 8."""
    R = int(rng.integers(2, 21))
    sizes = rng.integers(1, 50, size=R)
    gaps = rng.integers(0, 40, size=R + 1)
    left, pos = [], int(gaps[0])
    for i in range(R):
        left.append(pos)
        pos += int(sizes[i] + gaps[i + 1])
    mult = rng.integers(1, 8, size=R)
    u = int(rng.integers(0, 40)) if rng.random() < 0.7 else 0
    return make_instance(left, sizes, mult, m=pos, u_turn=u)


# ---------------------------------------------------------------------------
# bucketed batching: bit-identical to per-instance solving
# ---------------------------------------------------------------------------
def test_bucketed_batch_bit_identical_to_per_instance_50_instances():
    """>= 50 random heterogeneous instances in one bucketed batch call:
    (cost, detours) must be *bit-identical* to solving each instance alone on
    the same backend, and every cost must equal the exact python optimum."""
    rng = np.random.default_rng(20260801)
    insts = [_hetero_instance(rng) for _ in range(52)]
    assert len({i.n_req for i in insts}) > 5  # genuinely heterogeneous
    assert sum(i.u_turn > 0 for i in insts) >= 10

    batched = ltsp_solve_batch(insts)
    assert len(plan_buckets([rescale_instance(i)[0] for i in insts])) >= 2
    for trial, (inst, (cost, dets)) in enumerate(zip(insts, batched)):
        solo = ltsp_solve_instance(inst)
        assert (cost, dets) == solo, trial
        assert cost == dp_schedule(inst)[0], trial
        assert evaluate_detours(inst, dets) == cost, trial


def test_bucketed_matches_seed_style_padded_launch(rng):
    insts = [_hetero_instance(rng) for _ in range(8)]
    assert ltsp_solve_batch(insts, bucketed=True) == ltsp_solve_batch(
        insts, bucketed=False
    )


def test_solver_engine_batch_goes_through_buckets(rng):
    insts = [_hetero_instance(rng) for _ in range(7)]
    dev = solve_batch(insts, policy="dp", context=DEV)
    for inst, res in zip(insts, dev):
        assert res.cost == dp_schedule(inst)[0]
        assert evaluate_detours(inst, res.detours) == res.cost


# ---------------------------------------------------------------------------
# fast paths: empty and single-instance batches
# ---------------------------------------------------------------------------
def test_empty_batch_returns_empty():
    assert ltsp_solve_batch([]) == []
    assert solve_batch([], policy="dp", context=DEV) == []
    assert solve_batch([], policy="gs") == []


def test_prepare_batch_empty_raises_cleanly():
    with pytest.raises(ValueError, match="at least one instance"):
        prepare_batch([])


def test_single_instance_batch_matches_solve(rng):
    inst = _hetero_instance(rng)
    [res] = solve_batch([inst], policy="dp", context=DEV)
    alone = solve(inst, policy="dp", context=DEV)
    assert (res.cost, res.detours) == (alone.cost, alone.detours)


# ---------------------------------------------------------------------------
# bucket rounding policy
# ---------------------------------------------------------------------------
def test_bucket_shape_rounding(rng):
    for inst in (make_instance([0], [5], [1]), make_instance([0, 9], [5, 5], [1, 1])):
        R_pad, S_pad = bucket_shape(inst)
        assert R_pad >= inst.n_req and (R_pad & (R_pad - 1)) == 0
        assert S_pad >= inst.n + 1 and S_pad % 128 == 0
        assert ((S_pad // 128) & (S_pad // 128 - 1)) == 0
    big = make_instance([0, 10], [5, 5], [100, 100])  # n = 200 -> S bucket 256
    assert bucket_shape(big)[1] == 256


# ---------------------------------------------------------------------------
# coordinate rescaling: gcd + shift widens the int32 device envelope
# ---------------------------------------------------------------------------
def test_rescale_accepts_tape_block_granularity_coordinates():
    """Byte-scale coordinates on a block grid used to trip the int32 guard;
    gcd rescaling must now solve them exactly on the device backend."""
    inst = make_instance([0, 2 * 10**9], [10**6, 10**6], [3, 3], u_turn=10**7)
    scaled, g = rescale_instance(inst)
    assert g == 10**6 and scaled.m == scaled.right[-1]
    res = solve(inst, policy="dp", context=DEV)
    py = solve(inst, policy="dp")
    assert (res.cost, res.detours) == (py.cost, py.detours)
    assert evaluate_detours(inst, res.detours) == res.cost


def test_rescale_shift_handles_far_offset_layouts():
    """Files far from tape start but close together: the shift (not the gcd)
    does the work, because DP terms only ever see coordinate differences."""
    base = 17 * 10**12 + 5  # odd offset, gcd with coords is 1 without shift
    inst = make_instance([base, base + 40], [10, 20], [2, 3], u_turn=8)
    scaled, g = rescale_instance(inst)
    assert int(scaled.left[0]) == 0 and scaled.m <= 70
    res = solve(inst, policy="dp", context=DEV)
    assert res.cost == dp_schedule(inst)[0]


def test_guard_still_rejects_unrescalable_instances():
    """Coprime huge coordinates cannot be gcd-reduced: the strict guard must
    raise with the rescaling + f64 hint."""
    bad = make_instance(
        [0, 2 * 10**9 + 1], [10**6 + 1, 10**6 + 3], [3, 3], u_turn=10**7 + 1
    )
    with pytest.raises(ValueError, match="int32") as ei:
        solve(bad, policy="dp", context=DEV)
    assert "f64" in str(ei.value)  # the error teaches the escape hatch
    # exact python backend still fine
    py = solve(bad, policy="dp")
    assert py.cost == evaluate_detours(bad, py.detours)


# ---------------------------------------------------------------------------
# numeric_policy="f64": exact interpret fallback past the int32 guard
# ---------------------------------------------------------------------------
def _coprime_instance():
    """Byte-scale coprime layout: gcd/shift rescaling cannot save int32."""
    return make_instance(
        [0, 2 * 10**9 + 1], [10**6 + 1, 10**6 + 3], [3, 3], u_turn=10**7 + 1
    )


def test_f64_fallback_is_bit_exact_in_domain():
    """Within the < 2**53 exactness domain the f64 interpret table must be
    bit-identical (cost AND detours) to the exact python DP, for the full DP
    and for SIMPLEDP's disjoint clip."""
    from repro.core import simpledp_schedule

    bad = _coprime_instance()
    f64 = DEV.replace(numeric_policy="f64")
    for policy, oracle in (("dp", dp_schedule), ("simpledp", simpledp_schedule)):
        res = solve(bad, policy=policy, context=f64)
        assert (res.cost, res.detours) == oracle(bad), policy
        assert evaluate_detours(bad, res.detours) == res.cost


def test_f64_fallback_only_reroutes_guard_failures(rng):
    """int32-safe instances must keep taking the int32 launches: an f64
    context changes nothing for them (bit-identical batch, order kept)."""
    import jax

    good = [_hetero_instance(rng) for _ in range(3)]
    bad = _coprime_instance()
    batch = [good[0], bad, good[1], good[2]]
    res = solve_batch(batch, policy="dp", context=DEV.replace(numeric_policy="f64"))
    strict = solve_batch(good, policy="dp", context=DEV)
    assert [(r.cost, r.detours) for r in (res[0], res[2], res[3])] == [
        (r.cost, r.detours) for r in strict
    ]
    assert res[1].cost == dp_schedule(bad)[0]
    # the scoped x64 context never leaks into global jax state
    assert not jax.config.jax_enable_x64


def test_f64_guard_rejects_beyond_exactness_domain():
    """Past 2**53 the float64 table could round: must raise, not lie."""
    huge = make_instance(
        [0, 2 * 10**15 + 1], [10**6 + 1, 10**6 + 3], [3, 3], u_turn=10**7 + 1
    )
    with pytest.raises(ValueError, match="2\\*\\*53"):
        solve(huge, policy="dp", context=DEV.replace(numeric_policy="f64"))
    # python remains the unbounded-exactness backend
    py = solve(huge, policy="dp")
    assert py.cost == evaluate_detours(huge, py.detours)


def test_rescale_is_exact_not_approximate(rng):
    """Scaled-table reconstruction g * T_root must be exact on instances
    whose gcd is > 1 by construction."""
    for _ in range(5):
        inst0 = _hetero_instance(rng)
        k = int(rng.integers(2, 9))
        inst = make_instance(
            left=np.asarray(inst0.left) * k,
            size=(np.asarray(inst0.right) - np.asarray(inst0.left)) * k,
            mult=inst0.mult,
            m=inst0.m * k,
            u_turn=inst0.u_turn * k,
        )
        assert rescale_instance(inst)[1] % k == 0
        assert solve(inst, policy="dp", context=DEV).cost == (
            dp_schedule(inst)[0]
        )


# ---------------------------------------------------------------------------
# partial batches: typed per-instance failures, cache never polluted
# ---------------------------------------------------------------------------
def test_partial_batch_solves_good_and_types_bad(rng):
    """``partial=True`` must solve the good instances bit-identically, park
    a typed :class:`FailedSolve` at each failing position, and never let a
    failure touch the cache (regression: an aborted whole-batch launch used
    to throw away the good instances' work)."""
    from repro.core.solver import FailedSolve

    good = [_hetero_instance(rng) for _ in range(3)]
    bad = _coprime_instance()
    batch = [good[0], bad, good[1], good[2]]

    # strict device policy: the bad instance trips the int32 guard
    with pytest.raises(ValueError, match="int32"):
        solve_batch(batch, policy="dp", context=DEV)

    cache = SolveCache()
    ctx = DEV.replace(cache=cache)
    res = solve_batch(batch, policy="dp", context=ctx, partial=True)
    assert isinstance(res[1], FailedSolve)
    assert res[1].policy == "dp" and res[1].index == 1
    assert isinstance(res[1].error, ValueError)
    direct = [solve(i, policy="dp", context=DEV) for i in good]
    assert [(r.cost, r.detours) for r in (res[0], res[2], res[3])] == [
        (r.cost, r.detours) for r in direct
    ]
    # only the three good results were cached; the failure left no entry
    assert cache.stats()["entries"] == 3
    assert cache.get(bad, "dp", "pallas-interpret") is None
    # re-running serves the good ones from the memo, re-fails the bad one
    again = solve_batch(batch, policy="dp", context=ctx, partial=True)
    assert cache.stats()["hits"] == 3
    assert isinstance(again[1], FailedSolve)


def test_partial_without_cache_and_all_good(rng):
    """``partial=True`` on an all-good batch is bit-identical to the strict
    path, with or without a memo on the context."""
    insts = [_hetero_instance(rng) for _ in range(4)]
    strict = solve_batch(insts, policy="dp", context=DEV)
    relaxed = solve_batch(insts, policy="dp", context=DEV, partial=True)
    assert [(r.cost, r.detours) for r in strict] == [
        (r.cost, r.detours) for r in relaxed
    ]


# ---------------------------------------------------------------------------
# solve memo cache
# ---------------------------------------------------------------------------
def test_cache_hit_is_equal_and_counted(rng):
    cache = SolveCache()
    inst = _hetero_instance(rng)
    r1 = solve(inst, policy="dp", context=DEV.replace(cache=cache))
    r2 = solve(inst, policy="dp", context=DEV.replace(cache=cache))
    assert cache.stats() == {"hits": 1, "misses": 1, "entries": 1, "warm_entries": 0}
    assert (r1.cost, r1.detours) == (r2.cost, r2.detours)


def test_cache_hit_never_aliases(rng):
    """Mutating a returned schedule or the instance after a hit must not
    corrupt the cached entry or serve a stale result."""
    cache = SolveCache()
    inst = _hetero_instance(rng)
    first = solve(inst, policy="dp", context=ExecutionContext(cache=cache))
    hit = solve(inst, policy="dp", context=ExecutionContext(cache=cache))
    assert hit.detours is not first.detours
    hit.detours.append((999, 999))  # vandalise the returned copy
    clean = solve(inst, policy="dp", context=ExecutionContext(cache=cache))
    assert clean.detours == first.detours

    # mutate the instance in place: the content-derived key must miss, and
    # the fresh solve must reflect the new instance, not the cached one
    misses_before = cache.misses
    inst.mult[0] += 3
    fresh = solve(inst, policy="dp", context=ExecutionContext(cache=cache))
    assert cache.misses == misses_before + 1
    assert fresh.cost == dp_schedule(inst)[0]
    assert fresh.cost == evaluate_detours(inst, fresh.detours)


def test_cache_batch_only_solves_misses(rng):
    cache = SolveCache()
    insts = [_hetero_instance(rng) for _ in range(5)]
    a = solve_batch(insts, policy="dp", context=ExecutionContext(cache=cache))
    extra = _hetero_instance(rng)
    b = solve_batch(insts + [extra], policy="dp", context=ExecutionContext(cache=cache))
    assert cache.hits == 5 and cache.misses == 6
    assert [r.cost for r in b[:5]] == [r.cost for r in a]
    assert b[5].cost == dp_schedule(extra)[0]


def test_cache_keys_separate_policies_and_backends(rng):
    cache = SolveCache()
    inst = _hetero_instance(rng)
    dp = solve(inst, policy="dp", context=ExecutionContext(cache=cache))
    sdp = solve(inst, policy="simpledp", context=ExecutionContext(cache=cache))
    assert cache.misses == 2  # different policies never share entries
    assert dp.cost <= sdp.cost
    dev = solve(inst, policy="dp", context=DEV.replace(cache=cache))
    assert cache.misses == 3 and dev.backend == "pallas-interpret"


def test_cache_eviction_is_bounded(rng):
    cache = SolveCache(maxsize=3)
    for _ in range(6):
        solve(_hetero_instance(rng), policy="gs", context=ExecutionContext(cache=cache))
    assert len(cache) == 3 and cache.misses == 6


def test_cache_lru_eviction_order(rng):
    """Least-recently-*used* goes first: a get() refreshes recency, so the
    untouched entry is the one evicted when the bound is crossed."""
    cache = SolveCache(maxsize=3)
    a, b, c, d = (_hetero_instance(rng) for _ in range(4))
    for inst in (a, b, c):
        solve(inst, policy="gs", context=ExecutionContext(cache=cache))
    solve(a, policy="gs", context=ExecutionContext(cache=cache))  # refresh a: LRU order is now b, c, a
    solve(d, policy="gs", context=ExecutionContext(cache=cache))  # evicts b
    assert len(cache) == 3
    assert cache.get(b, "gs", "python") is None  # evicted -> miss
    for inst in (a, c, d):  # everything else still resident
        assert cache.get(inst, "gs", "python") is not None
    # and the eviction is strictly in recency order: after the gets above the
    # stalest entry is a, so inserting a fresh one must evict a, not c or d
    e = _hetero_instance(rng)
    cache.get(c, "gs", "python")
    solve(e, policy="gs", context=ExecutionContext(cache=cache))
    assert cache.get(a, "gs", "python") is None
    assert cache.get(c, "gs", "python") is not None


def test_cache_key_isolation_is_total(rng):
    """Entries never leak across policy or backend for the same instance."""
    cache = SolveCache()
    inst = _hetero_instance(rng)
    combos = [("dp", "python"), ("dp", "pallas-interpret"), ("gs", "python"),
              ("simpledp", "python")]
    for policy, backend in combos:
        solve(inst, policy=policy, context=ExecutionContext(backend=backend, cache=cache))
    assert len(cache) == len(combos) and cache.misses == len(combos)
    for policy, backend in combos:
        hit = cache.get(inst, policy, backend)
        assert hit is not None
        assert (hit.policy, hit.backend) == (policy, backend)
    # unseen combination for the same instance: miss, never a cross-key hit
    assert cache.get(inst, "nodetour", "python") is None


def test_cache_hit_returns_equal_but_not_aliased_detours(rng):
    """Every hit materialises a fresh, equal detour list — never the stored
    tuple and never a previously returned list."""
    cache = SolveCache()
    inst = _hetero_instance(rng)
    first = solve(inst, policy="dp", context=ExecutionContext(cache=cache))
    h1 = cache.get(inst, "dp", "python")
    h2 = cache.get(inst, "dp", "python")
    assert h1.detours == h2.detours == first.detours
    assert h1.detours is not h2.detours
    assert h1.detours is not first.detours
    assert all(isinstance(d, tuple) for d in h1.detours)


def test_library_schedule_uses_cache(rng):
    from repro.storage.tape import TapeLibrary

    lib = TapeLibrary(capacity_per_tape=150_000, u_turn=700,
                      context=ExecutionContext(cache=SolveCache()))
    for i in range(9):
        lib.store(f"f{i}", 30_000)
    reqs = {f"f{i}": 1 + i % 2 for i in range(9)}
    p1 = lib.schedule(reqs, policy="dp")
    assert lib.cache.hits == 0 and lib.cache.misses > 0
    p2 = lib.schedule(reqs, policy="dp")
    assert lib.cache.hits == lib.cache.misses  # full re-plan from the memo
    assert [p.total_cost for p in p1] == [p.total_cost for p in p2]
    assert [p.order for p in p1] == [p.order for p in p2]

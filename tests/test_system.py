"""End-to-end behaviour tests: tiny train -> checkpoint -> crash -> restore ->
serve, with the tape tier scheduling the restore reads (the paper's algorithm
embedded in the full system loop)."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCHS, reduced
from repro.distributed.checkpoint import (
    archive_to_tape,
    load_checkpoint,
    plan_restore,
    save_checkpoint,
)
from repro.serving.serve import make_serve_step
from repro.storage.tape import TapeLibrary
from repro.training.optimizer import OptConfig
from repro.training.train_step import init_train_state, make_train_step


def test_end_to_end_train_crash_restore_serve(tmp_path):
    cfg = dataclasses.replace(
        reduced(ARCHS["granite-8b"], periods=1), vocab_size=128, remat=False
    )
    params, opt = init_train_state(jax.random.PRNGKey(0), cfg)
    step = jax.jit(make_train_step(cfg, OptConfig(learning_rate=5e-3, warmup_steps=2, total_steps=40)))
    rngs = jax.random.split(jax.random.PRNGKey(1), 16)
    batches = [
        {"tokens": jax.random.randint(r, (4, 16), 0, cfg.vocab_size)} for r in rngs
    ]

    # train 6 steps, checkpointing at step 4
    for i in range(6):
        params, opt, metrics = step(params, opt, batches[i])
        if i == 3:
            save_checkpoint(tmp_path / "ck", i + 1, params=params, opt_state=opt)
            # archive to the tape tier as well
            lib = TapeLibrary(capacity_per_tape=10**9, u_turn=5_000)
            shards = archive_to_tape(lib, "ck4", params)

    # crash: restore from step 4 and replay -> identical trajectory
    step_no, trees = load_checkpoint(tmp_path / "ck", params=params, opt_state=opt)
    assert step_no == 4
    p2, o2 = trees["params"], trees["opt_state"]
    for i in range(4, 6):
        p2, o2, _ = step(p2, o2, batches[i])
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    # the archived restore is scheduled by the paper's DP and beats FIFO sweep
    plans_dp = plan_restore(lib, shards, consumers_per_shard=2, policy="dp")
    plans_nd = plan_restore(lib, shards, consumers_per_shard=2, policy="nodetour")
    assert sum(p.total_cost for p in plans_dp) <= sum(p.total_cost for p in plans_nd)

    # serve a few greedy tokens from the restored params
    from repro.models.model import init_cache

    serve = jax.jit(make_serve_step(cfg))
    cache = init_cache(cfg, batch=2, max_len=32)
    tok = jnp.zeros((2, 1), jnp.int32)
    for t in range(5):
        tok, logits, cache = serve(p2, cache, tok, jnp.int32(t))
        assert tok.shape == (2, 1)
        assert not bool(jnp.isnan(logits).any())

"""Fault injection + recovery layer: failover, retries, degradation, WAL.

The acceptance bars (all on exact integer virtual time):

* with no fault plan and no journal, every admission's timeline is pinned
  **bit-identically** against the PR-4/PR-6 constants (the same sha +
  total-sojourn pins :mod:`test_qos` uses) — even when a ``RetryPolicy``
  is supplied, since retries only act when faults fire;
* under seeded fault profiles every request is either served or recorded
  as a typed :class:`~repro.serving.FailedRequest` — nothing vanishes —
  and two runs of the same plan are bit-identical;
* transient mount failures charge the retry backoff in exact virtual
  time; media faults abort at the exact head-touch instant and retry;
  drive hard-failures requeue survivors deterministically and remount the
  cartridge on surviving capacity;
* the solver degradation chain lands bit-identical results to a direct
  solve on the fallback tier;
* a truncated write-ahead journal recovers to the bit-identical report
  and rebuilds the byte-identical journal, at every cut point.
"""

import hashlib

import pytest

from repro.core.solver import (
    DEGRADATION_CHAIN,
    ExecutionContext,
    SolveCache,
    SolverUnavailableError,
    TransientSolverError,
    degraded_backends,
    solve,
    solve_warm_degraded,
)
from repro.serving import (
    FAIL_STOP,
    DriveCosts,
    DriveFailure,
    EventJournal,
    FaultInjector,
    FaultPlan,
    JournalReplayError,
    MediaFault,
    MediaReadError,
    MountFailedError,
    MountFault,
    NoDriveAvailableError,
    QoSSpec,
    RetryPolicy,
    SolverFault,
    demo_library,
    poisson_trace,
    recover_server,
    seeded_fault_plan,
    serve_trace,
    slo_report,
)

from conftest import random_instance

pytestmark = pytest.mark.faults

SEED = 20260731
COSTS = DriveCosts(mount=150_000, unmount=60_000, load_seek=30_000)

#: same differential pins as test_qos.PR4_BASELINE: the fault layer must
#: keep the no-fault timelines bit-identical on the seeded 240-request
#: constrained-pool trace (n_drives=2, COSTS, window=400_000, policy="dp").
NO_FAULT_BASELINE = {
    "fifo": ("1a79c55063c3f802", 56_368_550_889),
    "accumulate": ("df9ed258ac816c37", 3_809_190_213),
    "preempt": ("668366586042762a", 7_347_259_813),
    "fifo-global": ("1a79c55063c3f802", 56_368_550_889),
    "per-drive-accumulate": ("df9ed258ac816c37", 3_809_190_213),
    "batched": ("df9ed258ac816c37", 3_809_190_213),
}


def build_library():
    return demo_library(SEED)


def build_trace(n_requests=240, rate=250_000):
    return poisson_trace(
        build_library(), n_requests=n_requests, mean_interarrival=rate, seed=SEED
    )


def small_library():
    return demo_library(7)


def small_trace(n_requests=24):
    return poisson_trace(small_library(), n_requests=n_requests,
                         mean_interarrival=40_000, seed=7)


def _served_sha(report):
    served = tuple(
        (r.req_id, r.arrival, r.dispatched, r.completed) for r in report.served
    )
    return hashlib.sha256(repr(served).encode()).hexdigest()[:16]


def _timeline(report):
    return [
        (r.req_id, r.arrival, r.dispatched, r.completed, r.faulted)
        for r in report.served
    ]


def serve_small(admission="accumulate", trace=None, **kwargs):
    return serve_trace(
        small_library(),
        small_trace() if trace is None else trace,
        admission,
        window=200_000,
        n_drives=2,
        **kwargs,
    )


# ---------------------------------------------------------------------------
# acceptance: no fault plan + no journal stays bit-identical (differential)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("admission", sorted(NO_FAULT_BASELINE))
def test_no_fault_path_matches_pin(admission):
    sha, total = NO_FAULT_BASELINE[admission]
    report = serve_trace(
        build_library(), build_trace(), admission, window=400_000, policy="dp",
        n_drives=2, drive_costs=COSTS,
    )
    assert (_served_sha(report), report.total_sojourn) == (sha, total)
    assert report.fault_stats is None
    assert report.n_failed == 0
    for key in ("faults", "n_failed", "n_faulted", "completion_rate"):
        assert key not in report.summary()


@pytest.mark.parametrize("admission", ["accumulate", "batched", "preempt"])
def test_retry_policy_alone_is_invisible(admission):
    """A RetryPolicy without faults must not perturb a single integer."""
    sha, total = NO_FAULT_BASELINE[admission]
    report = serve_trace(
        build_library(), build_trace(), admission, window=400_000, policy="dp",
        n_drives=2, drive_costs=COSTS,
        retry=RetryPolicy(max_attempts=5, backoff_base=123),
    )
    assert (_served_sha(report), report.total_sojourn) == (sha, total)
    # the policy was given, so the stats block appears -- and is all zero
    assert report.fault_stats == {
        "drive_failures": 0, "mount_retries": 0, "media_aborts": 0,
        "solver_faults": 0, "fallbacks": 0, "requeued": 0, "retry_delay": 0,
    }
    assert report.summary()["completion_rate"] == 1.0


def test_empty_plan_is_fault_free():
    a = serve_small()
    b = serve_small(faults=FaultPlan())
    assert _timeline(a) == _timeline(b)
    assert b.fault_stats is None


# ---------------------------------------------------------------------------
# seeded profiles: nothing vanishes, runs are deterministic
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("admission", [
    "fifo", "accumulate", "preempt", "fifo-global", "per-drive-accumulate",
    "batched",
])
@pytest.mark.parametrize("seed", [3, 11])
def test_seeded_profile_conserves_requests(admission, seed):
    trace = small_trace()
    plan = seeded_fault_plan(small_library(), trace, seed=seed, n_drives=2)
    assert plan  # non-empty by construction on this library
    report = serve_small(admission, trace=trace, faults=plan,
                         retry=RetryPolicy(on_exhausted="drop"))
    assert report.n_served + report.n_failed == len(trace)
    assert {f.reason for f in report.failed} <= {
        "mount-failed", "media-error", "drive-failure", "solver-failed",
        "no-drive",
    }
    stats = report.fault_stats
    assert stats is not None and stats["drive_failures"] >= 1
    # determinism: the same plan replays bit-identically
    again = serve_small(admission, trace=small_trace(),
                        faults=seeded_fault_plan(
                            small_library(), small_trace(), seed=seed, n_drives=2),
                        retry=RetryPolicy(on_exhausted="drop"))
    assert _timeline(again) == _timeline(report)
    assert again.fault_stats == stats


def test_seeded_profile_with_failover_serves_everything():
    trace = small_trace()
    plan = seeded_fault_plan(small_library(), trace, seed=3, n_drives=2)
    report = serve_small(trace=trace, faults=plan, retry=RetryPolicy())
    assert report.n_served == len(trace) and report.n_failed == 0
    assert report.completion_rate == 1.0
    assert report.n_faulted >= 1  # retried/requeued requests are flagged
    s = report.summary()
    assert s["completion_rate"] == 1.0 and s["faults"] == report.fault_stats


# ---------------------------------------------------------------------------
# drive hard-failure: failover, requeue order, all-drives-dead
# ---------------------------------------------------------------------------
def _first_service_start(report):
    b = report.batches[0]
    return b.dispatched + b.mount_delay


def test_drive_failover_requeues_and_remounts():
    trace = small_trace()
    base = serve_small(trace=trace)
    # fail drive 0 mid-flight through its first batch
    at = _first_service_start(base) + 1
    plan = FaultPlan(drive_failures=(DriveFailure(at=at, drive=0),))
    report = serve_small(trace=small_trace(), faults=plan, retry=RetryPolicy())
    assert report.n_served == len(trace) and report.n_failed == 0
    aborted = [b for b in report.batches if b.aborted_by == "drive-failure"]
    assert len(aborted) == 1 and aborted[0].drive == 0
    assert report.fault_stats["drive_failures"] == 1
    assert report.fault_stats["requeued"] >= 1
    # the aborted cartridge was re-served on the surviving drive
    retried = [b for b in report.batches
               if b.tape_id == aborted[0].tape_id and b.dispatched >= at]
    assert retried and all(b.drive == 1 for b in retried)
    assert all(b.drive == 1 for b in report.batches if b.dispatched >= at)
    # requeued survivors are flagged
    requeued_ids = {r.req_id for r in report.served if r.faulted}
    assert requeued_ids


def test_drive_failover_requeue_order_deterministic():
    trace = small_trace()
    at = _first_service_start(serve_small(trace=trace)) + 1
    plan = FaultPlan(drive_failures=(DriveFailure(at=at, drive=0),))
    runs = [serve_small(trace=small_trace(), faults=plan, retry=RetryPolicy())
            for _ in range(2)]
    assert _timeline(runs[0]) == _timeline(runs[1])
    # requeued requests keep original arrivals: batches stay arrival-sorted
    # within each cartridge after the failure
    for rep in runs:
        for r in rep.served:
            assert r.dispatched >= r.arrival


def test_all_drives_failed_raises_typed_with_queues_intact():
    trace = small_trace()
    plan = FaultPlan(drive_failures=(
        DriveFailure(at=1, drive=0), DriveFailure(at=1, drive=1),
    ))
    with pytest.raises(NoDriveAvailableError) as err:
        serve_small(trace=trace, faults=plan, retry=RetryPolicy())
    assert err.value.n_queued > 0


def test_all_drives_failed_drop_records_typed_failures():
    trace = small_trace()
    plan = FaultPlan(drive_failures=(
        DriveFailure(at=1, drive=0), DriveFailure(at=1, drive=1),
    ))
    report = serve_small(trace=trace, faults=plan,
                         retry=RetryPolicy(on_exhausted="drop"))
    assert report.n_served == 0
    assert report.n_failed == len(trace)
    assert all(f.reason in ("drive-failure", "no-drive") for f in report.failed)
    assert report.completion_rate == 0.0
    # failures are deterministic and ordered by (arrival, req_id)
    ids = [f.req_id for f in report.failed]
    assert ids == sorted(ids)


def test_fail_stop_drops_inflight_survivors():
    trace = small_trace()
    at = _first_service_start(serve_small(trace=trace)) + 1
    plan = FaultPlan(drive_failures=(DriveFailure(at=at, drive=0),))
    report = serve_small(trace=small_trace(), faults=plan, retry=FAIL_STOP)
    assert report.n_failed >= 1
    assert all(f.reason == "drive-failure" for f in report.failed)
    assert report.n_served + report.n_failed == len(trace)
    assert report.fault_stats["requeued"] == 0


def test_plan_failing_unknown_drive_rejected():
    plan = FaultPlan(drive_failures=(DriveFailure(at=1, drive=7),))
    with pytest.raises(ValueError, match="fails drive 7"):
        serve_small(faults=plan)


# ---------------------------------------------------------------------------
# transient mount failures: exact backoff, exhaustion
# ---------------------------------------------------------------------------
def test_mount_retry_charges_exact_backoff():
    trace = small_trace()
    base = serve_small(trace=trace)
    tid = base.batches[0].tape_id
    retry = RetryPolicy(backoff_base=10_000, backoff_factor=2)
    plan = FaultPlan(mount_faults=(MountFault(tid, count=2),))
    report = serve_small(trace=small_trace(), faults=plan, retry=retry)
    assert report.n_served == len(trace)
    first = report.batches[0]
    assert first.tape_id == tid and first.mount_retries == 2
    # two failed attempts charge backoff(1) + backoff(2) = 30_000 exactly
    assert first.mount_delay == base.batches[0].mount_delay + 30_000
    assert report.fault_stats["mount_retries"] == 2
    assert report.fault_stats["retry_delay"] == 30_000
    # every request of the delayed batch is attributed as faulted
    flagged = {r.req_id for r in report.served if r.faulted}
    assert flagged


def test_mount_exhaustion_raises_typed():
    trace = small_trace()
    tid = serve_small(trace=trace).batches[0].tape_id
    plan = FaultPlan(mount_faults=(MountFault(tid, count=99),))
    with pytest.raises(MountFailedError) as err:
        serve_small(trace=small_trace(), faults=plan,
                    retry=RetryPolicy(mount_attempts=2))
    assert err.value.tape_id == tid and err.value.attempts == 2


def test_mount_exhaustion_drop_records_failures():
    trace = small_trace()
    tid = serve_small(trace=trace).batches[0].tape_id
    plan = FaultPlan(mount_faults=(MountFault(tid, count=99),))
    report = serve_small(trace=small_trace(), faults=plan,
                         retry=RetryPolicy(mount_attempts=2,
                                           on_exhausted="drop"))
    dropped = [f for f in report.failed if f.reason == "mount-failed"]
    assert dropped and all(f.tape_id == tid for f in dropped)
    assert report.n_served + report.n_failed == len(trace)


# ---------------------------------------------------------------------------
# media faults: abort at the touch instant, retry, exhaustion
# ---------------------------------------------------------------------------
def _whole_tape_fault(library, tape_id, count=1):
    tape = next(t for t in library.tapes if t.tape_id == tape_id)
    return MediaFault(tape_id, 0, tape.used, count=count)


def test_media_fault_aborts_and_retries():
    trace = small_trace()
    base = serve_small(trace=trace)
    tid = base.batches[0].tape_id
    plan = FaultPlan(media_faults=(_whole_tape_fault(small_library(), tid),))
    report = serve_small(trace=small_trace(), faults=plan, retry=RetryPolicy())
    assert report.n_served == len(trace) and report.n_failed == 0
    aborted = [b for b in report.batches if b.aborted_by == "media-error"]
    assert len(aborted) == 1 and aborted[0].tape_id == tid
    assert report.fault_stats["media_aborts"] == 1
    assert report.fault_stats["retry_delay"] >= 10_000  # backoff charged
    # the retry read happened on the same cartridge, later
    assert any(b.tape_id == tid and b.dispatched > aborted[0].dispatched
               for b in report.batches)


def test_media_exhaustion_raises_typed():
    trace = small_trace()
    tid = serve_small(trace=trace).batches[0].tape_id
    plan = FaultPlan(
        media_faults=(_whole_tape_fault(small_library(), tid, count=99),)
    )
    with pytest.raises(MediaReadError) as err:
        serve_small(trace=small_trace(), faults=plan,
                    retry=RetryPolicy(media_attempts=2))
    assert err.value.span[0] == tid


def test_media_exhaustion_drop_records_failures():
    trace = small_trace()
    tid = serve_small(trace=trace).batches[0].tape_id
    plan = FaultPlan(
        media_faults=(_whole_tape_fault(small_library(), tid, count=99),)
    )
    report = serve_small(trace=small_trace(), faults=plan,
                         retry=RetryPolicy(media_attempts=2,
                                           on_exhausted="drop"))
    assert any(f.reason == "media-error" for f in report.failed)
    assert report.n_served + report.n_failed == len(trace)


def test_media_abort_lands_inside_service_window():
    trace = small_trace()
    base = serve_small(trace=trace)
    tid = base.batches[0].tape_id
    plan = FaultPlan(media_faults=(_whole_tape_fault(small_library(), tid),))
    report = serve_small(trace=small_trace(), faults=plan, retry=RetryPolicy())
    aborted = next(b for b in report.batches if b.aborted_by == "media-error")
    # completions standing on the aborted batch all precede the retry batch
    assert aborted.n_completed < aborted.n_requests or aborted.n_requests == 0


# ---------------------------------------------------------------------------
# solver degradation chain (engine level)
# ---------------------------------------------------------------------------
def test_degradation_chain_suffixes():
    assert DEGRADATION_CHAIN == ("pallas", "pallas-interpret", "python")
    assert degraded_backends("pallas") == DEGRADATION_CHAIN
    assert degraded_backends("python") == ("python",)
    with pytest.raises(ValueError):
        degraded_backends("cuda")


class _FailTiers:
    """fault_hook failing given backends a fixed number of times."""

    def __init__(self, budget):
        self.budget = dict(budget)
        self.calls = []

    def __call__(self, backend):
        self.calls.append(backend)
        if self.budget.get(backend, 0) > 0:
            self.budget[backend] -= 1
            raise TransientSolverError(backend)


def test_degraded_solve_is_bit_identical_to_fallback_tier(rng):
    for _ in range(5):
        inst = random_instance(rng, lo=3, hi=12)
        direct = solve(inst, "dp", context=ExecutionContext(backend="python"))
        hook = _FailTiers({"pallas-interpret": 1})
        res, warm, stats, rec = solve_warm_degraded(
            inst, "dp", context=ExecutionContext(backend="pallas-interpret"),
            warm=None, fault_hook=hook,
        )
        assert rec.requested == "pallas-interpret" and rec.used == "python"
        assert rec.fell_back and rec.n_faults == 1
        assert warm is None  # warm state never survives a fault
        assert (res.cost, tuple(map(tuple, res.detours))) == (
            direct.cost, tuple(map(tuple, direct.detours))
        )


def test_degraded_retry_same_tier_without_fallback(rng):
    inst = random_instance(rng, lo=3, hi=10)
    hook = _FailTiers({"python": 1})
    res, warm, stats, rec = solve_warm_degraded(
        inst, "dp", context=ExecutionContext(backend="python"),
        warm=None, fault_hook=hook, attempts_per_backend=2,
    )
    assert not rec.fell_back and rec.used == "python"
    assert rec.failed == ("python",) and rec.n_faults == 1
    direct = solve(inst, "dp", context=ExecutionContext(backend="python"))
    assert res.cost == direct.cost


def test_degraded_exhaustion_raises_typed(rng):
    inst = random_instance(rng, lo=3, hi=8)
    hook = _FailTiers({"python": 99})
    with pytest.raises(SolverUnavailableError) as err:
        solve_warm_degraded(
            inst, "dp", context=ExecutionContext(backend="python"),
            warm=None, fault_hook=hook, attempts_per_backend=3,
        )
    assert err.value.failed == ("python", "python", "python")


@pytest.mark.parametrize("admission", ["accumulate", "batched"])
def test_server_solver_exhaustion_drops_or_raises(admission):
    trace = small_trace()
    plan = FaultPlan(solver_faults=(SolverFault("python", count=99),))
    # drop policy: the faulted tick's requests become typed failures
    report = serve_small(admission, trace=trace, faults=plan, retry=FAIL_STOP)
    dropped = [f for f in report.failed if f.reason == "solver-failed"]
    assert dropped
    assert report.n_served + report.n_failed == len(trace)
    # error policy: the typed chain-exhaustion error surfaces
    with pytest.raises(SolverUnavailableError):
        serve_small(admission, trace=small_trace(), faults=plan,
                    retry=RetryPolicy(solver_attempts=1))


def test_server_solver_fault_degrades_bit_identically():
    """A serving run whose solves fault lands the no-fault timeline."""
    trace = small_trace()
    base = serve_small(trace=trace)
    plan = FaultPlan(solver_faults=(SolverFault("python", count=2),))
    report = serve_small(trace=small_trace(), faults=plan, retry=RetryPolicy())
    # solver retries are virtual-time-free: the timeline is bit-identical
    assert [(r.req_id, r.arrival, r.dispatched, r.completed)
            for r in report.served] == [
        (r.req_id, r.arrival, r.dispatched, r.completed) for r in base.served
    ]
    assert report.fault_stats["solver_faults"] == 2


# ---------------------------------------------------------------------------
# QoS: failover keeps deadline accounting consistent, misses attributed
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("admission", ["edf-global", "slack-accumulate"])
def test_qos_failover_deadline_accounting(admission):
    trace = small_trace()
    qos = {r.req_id: QoSSpec(deadline=r.time + 2_000_000, qos_class="batch")
           for r in trace}
    base = serve_small(admission, trace=trace, qos=qos)
    at = _first_service_start(base) + 1
    plan = FaultPlan(drive_failures=(DriveFailure(at=at, drive=0),))
    report = serve_small(admission, trace=small_trace(), qos=qos,
                         faults=plan, retry=RetryPolicy())
    assert report.n_served == len(trace)
    slo = slo_report(report, qos)
    base_slo = slo_report(base, qos)
    # exact-int invariants hold under failover
    assert slo.overall.n == len(trace)
    assert slo.n_deadlines == len(trace)
    assert 0 <= slo.n_missed_faulted <= slo.n_missed
    assert slo.overall.total_lateness >= 0
    # fault-caused misses are exactly the missed requests a fault touched
    faulted = {r.req_id for r in report.served if r.faulted}
    missed_faulted = sum(
        1 for r in report.served
        if r.completed > qos[r.req_id].deadline and r.req_id in faulted
    )
    assert slo.n_missed_faulted == missed_faulted
    assert slo.summary()["n_missed_faulted"] == missed_faulted
    # the no-fault run attributes nothing to faults
    assert base_slo.n_missed_faulted == 0


# ---------------------------------------------------------------------------
# write-ahead journal: torn-tail recovery, bit-identical resume
# ---------------------------------------------------------------------------
def _run_with_journal(tmp_path, name, **kwargs):
    path = tmp_path / name
    report = serve_small(trace=small_trace(), journal=str(path), **kwargs)
    return report, path


def test_journal_recovery_bit_identical_at_every_cut(tmp_path):
    full, path = _run_with_journal(tmp_path, "journal.jsonl")
    data = path.read_bytes()
    assert data.endswith(b"\n") and data.count(b"\n") >= 10
    cuts = [0, 10, len(data) // 3, len(data) // 2, len(data) - 5, len(data)]
    for cut in cuts:
        p = tmp_path / f"cut{cut}.jsonl"
        p.write_bytes(data[:cut])
        report = recover_server(
            small_library(), small_trace(), str(p),
            admission="accumulate", window=200_000, n_drives=2,
        )
        assert _served_sha(report) == _served_sha(full), cut
        assert report.total_sojourn == full.total_sojourn
        assert p.read_bytes() == data, cut  # journal rebuilt byte-identically


def test_journal_recovery_under_faults(tmp_path):
    plan = seeded_fault_plan(small_library(), small_trace(), seed=3, n_drives=2)
    full, path = _run_with_journal(tmp_path, "jf.jsonl",
                                   faults=plan, retry=RetryPolicy())
    data = path.read_bytes()
    p = tmp_path / "jf_cut.jsonl"
    p.write_bytes(data[: len(data) // 2])
    report = recover_server(
        small_library(), small_trace(), str(p),
        admission="accumulate", window=200_000, n_drives=2,
        faults=plan, retry=RetryPolicy(),
    )
    assert _timeline(report) == _timeline(full)
    assert report.fault_stats == full.fault_stats
    assert p.read_bytes() == data


def test_journal_tolerates_torn_tail(tmp_path):
    _, path = _run_with_journal(tmp_path, "torn.jsonl")
    with open(path, "ab") as fh:
        fh.write(b'{"ev": "torn-mid-wri')  # no newline: torn write
    events = EventJournal.load(path)
    assert events and events[-1]["ev"] == "end"


def test_journal_stops_at_corrupt_interior_line(tmp_path):
    _, path = _run_with_journal(tmp_path, "corrupt.jsonl")
    lines = path.read_bytes().splitlines(keepends=True)
    lines[3] = b"}}}not json{{{\n"
    path.write_bytes(b"".join(lines))
    events = EventJournal.load(path)
    assert len(events) == 3  # the suffix past a tear is untrustworthy


def test_journal_tolerates_newline_terminated_invalid_json(tmp_path):
    """A corrupt line that *is* newline-terminated but explodes json.loads
    (a deeply nested ``[[[[...`` run raises RecursionError, not ValueError)
    must truncate like any other tear — before the fix it escaped the
    except clause and killed recovery."""
    full, path = _run_with_journal(tmp_path, "deep.jsonl")
    data = path.read_bytes()
    lines = data.splitlines(keepends=True)
    poison = b"[" * 200_000 + b"\n"  # valid JSON prefix, blows the C parser
    path.write_bytes(b"".join(lines[:4]) + poison + b"".join(lines[4:]))
    events = EventJournal.load(path)
    assert len(events) == 4  # cut at the poison line; the suffix is dropped
    # ... and recovery from the poisoned journal completes bit-identically,
    # rebuilding the byte-identical journal past the cut point
    report = recover_server(
        small_library(), small_trace(), str(path),
        admission="accumulate", window=200_000, n_drives=2,
    )
    assert _served_sha(report) == _served_sha(full)
    assert path.read_bytes() == data


def test_journal_foreign_run_raises(tmp_path):
    _, path = _run_with_journal(tmp_path, "foreign.jsonl")
    other = poisson_trace(small_library(), n_requests=24,
                          mean_interarrival=40_000, seed=99)
    with pytest.raises(JournalReplayError):
        recover_server(small_library(), other, str(path),
                       admission="accumulate", window=200_000, n_drives=2)


def test_journal_records_the_event_stream(tmp_path):
    report, path = _run_with_journal(tmp_path, "stream.jsonl")
    events = EventJournal.load(path)
    kinds = [e["ev"] for e in events]
    assert kinds[0] == "start" and kinds[-1] == "end"
    assert kinds.count("enqueue") == 24
    served = [r for e in events if e["ev"] == "serve" for r in e["reqs"]]
    assert len(served) == report.n_served
    end = events[-1]
    assert end["n_served"] == report.n_served
    assert end["total_sojourn"] == report.total_sojourn


# ---------------------------------------------------------------------------
# plan / injector unit behaviour
# ---------------------------------------------------------------------------
def test_fault_records_validate():
    with pytest.raises(ValueError):
        DriveFailure(at=-1, drive=0)
    with pytest.raises(ValueError):
        MountFault("T", count=0)
    with pytest.raises(ValueError):
        MediaFault("T", lo=5, hi=2)
    with pytest.raises(ValueError):
        SolverFault("python", count=0)
    assert not FaultPlan()
    assert FaultPlan(mount_faults=(MountFault("T"),))


def test_injector_consumes_budgets():
    plan = FaultPlan(
        mount_faults=(MountFault("A", count=2),),
        solver_faults=(SolverFault("python", count=1),),
    )
    inj = FaultInjector(plan)
    assert inj.mount_fails("A") and inj.mount_fails("A")
    assert not inj.mount_fails("A") and not inj.mount_fails("B")
    assert inj.solver_fails("python") and not inj.solver_fails("python")
    with pytest.raises(TransientSolverError):
        FaultInjector(plan).solver_hook("python")
    assert inj.remaining() == {"drive": 0, "mount": 0, "media": 0, "solver": 0}
    assert inj.fired == {"drive": 0, "mount": 2, "media": 0, "solver": 1}


def test_seeded_plan_is_deterministic_and_in_range():
    trace = small_trace()
    a = seeded_fault_plan(small_library(), trace, seed=5, n_drives=2)
    b = seeded_fault_plan(small_library(), trace, seed=5, n_drives=2)
    assert a == b
    horizon = max(r.time for r in trace)
    for f in a.drive_failures:
        assert 0 <= f.drive < 2
        assert horizon // 4 <= f.at <= (3 * horizon) // 4
    assert seeded_fault_plan(
        small_library(), trace, seed=5, n_drives=2, drive_failures=5
    ).drive_failures.__len__() <= 2  # clamped to the pool


def test_retry_policy_validates_and_computes():
    p = RetryPolicy(backoff_base=100, backoff_factor=3)
    assert p.backoff(1) == 100 and p.backoff(3) == 900
    assert p.attempts("mount") == 3
    assert RetryPolicy(media_attempts=7).attempts("media") == 7
    with pytest.raises(ValueError):
        RetryPolicy(max_attempts=0)
    with pytest.raises(ValueError):
        RetryPolicy(on_exhausted="panic")
    with pytest.raises(ValueError):
        p.backoff(0)
    assert FAIL_STOP.max_attempts == 1 and not FAIL_STOP.failover

"""Load-adaptive solver selection + overload control.

The acceptance bars (all on exact integer virtual time):

* with ``selector``/``preempt_urgent``/``class_weights`` unset, every
  timeline is **bit-identical** to the selector-less code path — pinned
  differentially (plain run == ``selector=None`` == ``selector="fixed"``);
* the selector registry (``fixed`` / ``depth-threshold`` / ``cost-model``)
  resolves by name or instance, validates ladders against the solver
  registry, and ``predict_cells`` scales recorded timings exactly;
* per-tick policy switching is hysteresis-damped by the *server* (selectors
  stay stateless), warm states never alias across policies, and a priced
  :class:`~repro.core.ComputeBudget` delays dispatch by the exact charged
  cells;
* deadline-aware cross-cartridge preemption aborts a lax batch for an
  urgent arrival, and class weights re-order service without touching the
  reported (true-deadline) SLOs;
* the adaptive tier composes with the PR-7 fault layer: under an identical
  fault plan the ``fixed`` selector reproduces the selector-less run bit
  for bit (same retries, same backoff charges, same warm invalidations),
  and the ``cost-model`` selector still conserves every request.
"""

import hashlib

import pytest

from repro.core import (
    DEFAULT_LADDER,
    ComputeBudget,
    CostModelSelector,
    DepthThresholdSelector,
    ExecutionContext,
    FixedSelector,
    LoadView,
    SolverSelector,
    get_selector,
    list_selectors,
    predict_cells,
    register_selector,
)
from repro.core.solver import _SELECTORS
from repro.serving import (
    DriveCosts,
    QoSSpec,
    Request,
    RetryPolicy,
    demo_library,
    poisson_trace,
    serve_trace,
    slo_report,
)
from repro.serving.faults import seeded_fault_plan
from repro.storage.tape import TapeLibrary

pytestmark = pytest.mark.adaptive

SEED = 20260731
COSTS = DriveCosts(mount=150_000, unmount=60_000, load_seek=30_000)


def build_library(n_files=40):
    return demo_library(SEED, n_files=n_files)


def build_trace(n_requests=120, rate=150_000):
    return poisson_trace(
        build_library(), n_requests=n_requests, mean_interarrival=rate, seed=SEED
    )


def _timeline(report):
    return (
        [(r.req_id, r.arrival, r.dispatched, r.completed) for r in report.served],
        sorted(
            (b.tape_id, b.drive, b.dispatched, b.mount_delay, b.n_requests,
             b.solver_cost, b.rewind, b.preempted)
            for b in report.batches
        ),
    )


def _served_sha(report):
    served = tuple(
        (r.req_id, r.arrival, r.dispatched, r.completed) for r in report.served
    )
    return hashlib.sha256(repr(served).encode()).hexdigest()[:16]


# ---------------------------------------------------------------------------
# ComputeBudget: validation, exact rational charging, context plumbing
# ---------------------------------------------------------------------------
def test_compute_budget_validates_and_charges_exactly():
    b = ComputeBudget(solve_time_num=3, solve_time_den=2)
    assert b.charge(7) == 10  # 21 // 2, exact integer floor
    assert b.charge(0) == 0
    assert ComputeBudget().charge(10**9) == 0  # default pricing is free
    assert b.replace(per_tick=500).per_tick == 500
    assert b.replace(per_tick=500).solve_time_num == 3  # others preserved
    for bad in (
        dict(solve_time_num=-1),
        dict(solve_time_den=0),
        dict(per_tick=0),
        dict(shallow_depth=0),
        dict(shallow_depth=9, deep_depth=8),
        dict(hysteresis=0),
    ):
        with pytest.raises(ValueError):
            ComputeBudget(**bad)


def test_execution_context_carries_budget():
    b = ComputeBudget(per_tick=64)
    ctx = ExecutionContext(budget=b)
    assert ctx.budget is b
    assert ExecutionContext().budget is None  # opt-in: absent by default
    assert ctx.replace(backend="python").budget is b
    with pytest.raises(TypeError, match="budget"):
        ExecutionContext(budget=42)


# ---------------------------------------------------------------------------
# selector registry + predict_cells
# ---------------------------------------------------------------------------
def test_selector_registry_resolves_names_and_instances():
    assert list_selectors() == ("fixed", "depth-threshold", "cost-model")
    assert get_selector("cost-model").name == "cost-model"
    custom = FixedSelector(policy="nfgs")
    assert get_selector(custom) is custom  # instances pass through
    assert isinstance(get_selector("fixed"), SolverSelector)
    with pytest.raises(KeyError, match="unknown selector"):
        get_selector("oracle")
    with pytest.raises(TypeError, match="selector"):
        get_selector(object())
    with pytest.raises(ValueError, match="already registered"):
        register_selector(FixedSelector())
    # replace=True swaps in place and keeps registration order
    register_selector(FixedSelector(), replace=True)
    assert list_selectors() == ("fixed", "depth-threshold", "cost-model")


def test_selector_ladders_validate_against_solver_registry():
    with pytest.raises(KeyError):
        DepthThresholdSelector(ladder=("dp", "ghost"))
    with pytest.raises(ValueError, match="ladder"):
        CostModelSelector(ladder=())
    with pytest.raises(KeyError):
        FixedSelector(policy="ghost")
    with pytest.raises(ValueError, match="name"):
        register_selector(object())


def test_predict_cells_priors_and_observed_scaling():
    # analytic priors by solver kind: heuristic 0, restricted ~n^2 log n,
    # exact DP n^3
    assert predict_cells("nfgs", 10) == 0
    assert predict_cells("logdp1", 10) == 10 * 10 * (10).bit_length()
    assert predict_cells("dp", 10) == 1_000
    assert predict_cells("dp", 0) == 0
    # an observation replaces the prior: exact integer ratio scaling
    timings = {"dp": (4_000, 8_000)}  # 0.5 cells per n^3 observed
    assert predict_cells("dp", 10, timings) == 500
    assert predict_cells("dp", 10, {"dp": (0, 8_000)}) == 0
    # zero-cube observations fall back to the prior instead of dividing
    assert predict_cells("dp", 10, {"dp": (5, 0)}) == 1_000
    with pytest.raises(KeyError):
        predict_cells("ghost", 4)


def test_selector_unit_choices():
    b = ComputeBudget(shallow_depth=4, deep_depth=16)
    dt = DepthThresholdSelector()
    assert dt.select(LoadView(depth=4, n_requests=4), b) == "dp"
    assert dt.select(LoadView(depth=10, n_requests=4), b) == "logdp1"
    assert dt.select(LoadView(depth=16, n_requests=4), b) == "nfgs"
    cm = CostModelSelector()
    free = ComputeBudget()  # per_tick None: always the most exact tier
    assert cm.select(LoadView(depth=99, n_requests=50), free) == "dp"
    tight = ComputeBudget(per_tick=100)
    assert cm.select(LoadView(depth=1, n_requests=4), tight) == "dp"  # 64 <= 100
    # n=5: dp prior 125 > 100, logdp1 prior 5*5*3 = 75 <= 100
    assert cm.select(LoadView(depth=1, n_requests=5), tight) == "logdp1"
    assert cm.select(LoadView(depth=1, n_requests=40), tight) == "nfgs"
    # recorded timings steer the model: dp observed cheap -> picked again
    cheap = LoadView(depth=1, n_requests=40, timings={"dp": (10, 64_000)})
    assert cm.select(cheap, tight) == "dp"
    assert FixedSelector().select(LoadView(depth=9, n_requests=9), b) is None
    assert FixedSelector(policy="nfgs").select(
        LoadView(depth=0, n_requests=1), b
    ) == "nfgs"
    assert DEFAULT_LADDER == ("dp", "logdp1", "nfgs")


# ---------------------------------------------------------------------------
# acceptance: selector unset stays bit-identical (differential pin)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("admission", ["per-drive-accumulate", "preempt"])
def test_selector_unset_and_fixed_are_bit_identical(admission):
    trace = build_trace()
    kw = dict(window=400_000, policy="dp", n_drives=2, drive_costs=COSTS)
    plain = serve_trace(build_library(), trace, admission, **kw)
    explicit_none = serve_trace(
        build_library(), trace, admission, selector=None, **kw
    )
    fixed = serve_trace(
        build_library(), trace, admission, selector="fixed", **kw
    )
    assert _timeline(plain) == _timeline(explicit_none) == _timeline(fixed)
    # report keys: the adaptive block appears only when a selector is set
    assert "policy_mix" not in plain.summary()
    assert plain.summary().get("selector") is None
    s = fixed.summary()
    assert s["selector"] == "fixed"
    assert s["policy_mix"] == {"dp": len(fixed.batches)}
    assert s["total_solve_delay"] == 0  # default budget charges nothing
    assert all(b.policy_used == "dp" for b in fixed.batches)
    assert all(b.policy_used is None for b in plain.batches)


def test_default_budget_with_selector_changes_nothing_but_attribution():
    """A selector without pricing (default ComputeBudget) may still switch
    policies; with a single-policy ladder it must reproduce the pinned
    timeline exactly while attributing every batch."""
    trace = build_trace(n_requests=80)
    kw = dict(window=400_000, policy="dp", n_drives=2, drive_costs=COSTS)
    plain = serve_trace(build_library(), trace, "per-drive-accumulate", **kw)
    attributed = serve_trace(
        build_library(), trace, "per-drive-accumulate",
        selector=FixedSelector(policy="dp"), **kw
    )
    assert _timeline(plain) == _timeline(attributed)
    assert all(b.solve_delay == 0 for b in attributed.batches)


# ---------------------------------------------------------------------------
# adaptive serving: switching, hysteresis, pricing, warm-key isolation
# ---------------------------------------------------------------------------
def test_depth_threshold_selector_switches_policies_under_load():
    trace = build_trace(n_requests=160)  # depth crosses both thresholds
    budget = ComputeBudget(shallow_depth=2, deep_depth=6, hysteresis=1)
    report = serve_trace(
        build_library(), trace, "per-drive-accumulate", window=400_000,
        policy="dp", selector="depth-threshold", n_drives=2,
        drive_costs=COSTS, context=build_library().context.replace(budget=budget),
    )
    mix = report.policy_mix
    assert sum(mix.values()) == len(report.batches)
    assert len(mix) >= 2, mix  # actually adapted
    assert report.summary()["all_verified"]
    assert {b.policy_used for b in report.batches} == set(mix)


def test_hysteresis_damps_switching():
    """The same load served under hysteresis=1 vs a huge hysteresis: the
    damped run can never confirm a switch, so every batch keeps the
    configured policy; the eager run switches at least once."""
    trace = build_trace(n_requests=160)

    def run(hysteresis):
        budget = ComputeBudget(
            shallow_depth=2, deep_depth=6, hysteresis=hysteresis
        )
        return serve_trace(
            build_library(), trace, "per-drive-accumulate", window=400_000,
            policy="dp", selector="depth-threshold", n_drives=2,
            drive_costs=COSTS,
            context=build_library().context.replace(budget=budget),
        )

    eager = run(1)
    damped = run(10**6)
    assert len(eager.policy_mix) >= 2
    assert set(damped.policy_mix) == {"dp"}  # switch never confirmed
    # hysteresis only gates the switch instant, not correctness
    assert damped.summary()["all_verified"]
    assert damped.n_served == eager.n_served == 160


def test_priced_budget_delays_dispatch_exactly():
    """solve_delay = charge(cells_evaluated), batch by batch, and the total
    lands in the summary.  The free-budget run is the control."""
    trace = build_trace(n_requests=80)
    kw = dict(window=400_000, policy="dp", selector="fixed", n_drives=2,
              drive_costs=COSTS, warm_start=False)
    budget = ComputeBudget(solve_time_num=7, solve_time_den=3)
    priced = serve_trace(
        build_library(), trace, "per-drive-accumulate",
        context=build_library().context.replace(budget=budget), **kw
    )
    free = serve_trace(build_library(), trace, "per-drive-accumulate", **kw)
    assert priced.total_solve_delay > 0
    assert priced.summary()["total_solve_delay"] == priced.total_solve_delay
    for b in priced.batches:
        assert b.solve_delay == budget.charge(b.cells_evaluated)
    assert all(b.solve_delay == 0 for b in free.batches)
    # priced solves start later: total sojourn strictly grows
    assert priced.total_sojourn > free.total_sojourn


def test_cost_model_selector_serves_and_records_timings():
    trace = build_trace(n_requests=160, rate=30_000)
    budget = ComputeBudget(solve_time_num=10_000, per_tick=120, hysteresis=1)
    report = serve_trace(
        build_library(), trace, "per-drive-accumulate", window=400_000,
        policy="dp", selector="cost-model", n_drives=2, drive_costs=COSTS,
        context=build_library().context.replace(budget=budget),
        warm_start=False,
    )
    assert report.n_served == 160
    assert report.summary()["all_verified"]
    mix = report.policy_mix
    assert sum(mix.values()) == len(report.batches)
    assert len(mix) >= 2, mix  # the budget prices dp out under load
    # determinism: the adaptive run replays bit-identically
    again = serve_trace(
        build_library(), trace, "per-drive-accumulate", window=400_000,
        policy="dp", selector="cost-model", n_drives=2, drive_costs=COSTS,
        context=build_library().context.replace(budget=budget),
        warm_start=False,
    )
    assert _timeline(report) == _timeline(again)
    assert again.policy_mix == mix


def test_warm_states_do_not_alias_across_policies():
    """Per-tick switching with warm starts on: warm tables are keyed by
    (tape, policy), so a warm dp table is never fed to nfgs or vice versa.
    The observable contract: the adaptive warm run emits exactly the same
    timeline as the adaptive cold run (warm start is a work optimisation,
    never a scheduling change), which fails loudly if states alias."""
    trace = build_trace(n_requests=160)
    budget = ComputeBudget(shallow_depth=2, deep_depth=6, hysteresis=1)

    def run(warm):
        return serve_trace(
            build_library(), trace, "per-drive-accumulate", window=400_000,
            policy="dp", selector="depth-threshold", n_drives=2,
            drive_costs=COSTS, warm_start=warm,
            context=build_library().context.replace(budget=budget),
        )

    warm, cold = run(True), run(False)
    assert len(warm.policy_mix) >= 2  # the run really interleaves policies
    assert _timeline(warm) == _timeline(cold)
    assert warm.policy_mix == cold.policy_mix
    assert warm.cells_evaluated <= cold.cells_evaluated


def test_selector_validation_errors():
    trace = build_trace(n_requests=20)
    with pytest.raises(KeyError, match="unknown selector"):
        serve_trace(build_library(), trace, "accumulate", window=400_000,
                    selector="ghost")


# ---------------------------------------------------------------------------
# cross-cartridge urgent preemption + class-weighted service
# ---------------------------------------------------------------------------
def _two_tape_library():
    lib = TapeLibrary(capacity_per_tape=100_000, u_turn=100)
    for name in ("a0", "a1", "a2"):
        lib.store(name, 30_000)  # tape A fills up
    lib.store("b0", 2_000)  # tape B
    return lib


def test_urgent_arrival_preempts_lax_cross_cartridge_batch():
    """One drive, a long lax batch in flight on tape A; an urgent tape-B
    deadline arrives and cannot mount.  With preempt_urgent the A batch is
    aborted (kept completions, rewind accounted), B is served in time;
    without it the arrival waits out the batch and misses."""
    lib = _two_tape_library()
    tape_a, tape_b = lib.location["a0"], lib.location["b0"]
    trace = [
        Request(time=0, req_id=0, tape_id=tape_a, name="a0"),
        Request(time=0, req_id=1, tape_id=tape_a, name="a1"),
        Request(time=0, req_id=2, tape_id=tape_a, name="a2"),
        Request(time=5_000, req_id=3, tape_id=tape_b, name="b0"),
    ]
    qos = {3: QoSSpec(deadline=30_000, qos_class="interactive")}
    kw = dict(window=0, policy="dp", n_drives=1, qos=qos)

    def run(**extra):
        return serve_trace(_two_tape_library(), list(trace), "edf-global",
                           **kw, **extra)

    waited = run()
    preempted = run(preempt_urgent=True)
    assert waited.n_preemptions == 0
    assert preempted.n_preemptions >= 1
    assert any(b.preempted for b in preempted.batches)
    done_w = {r.req_id: r.completed for r in waited.served}
    done_p = {r.req_id: r.completed for r in preempted.served}
    assert done_p[3] < done_w[3]  # the urgent request jumps the batch
    assert done_p[3] <= 30_000 < done_w[3]  # ...and only preemption meets it
    assert preempted.n_served == 4  # aborted work is re-queued, not lost
    assert preempted.summary()["all_verified"]


def test_preempt_urgent_requires_deadline_admission():
    trace = build_trace(n_requests=10)
    with pytest.raises(ValueError, match="preempt_urgent"):
        serve_trace(build_library(), trace, "per-drive-accumulate",
                    window=400_000, preempt_urgent=True)


def test_preempt_urgent_ignores_best_effort_and_lax_arrivals():
    """Best-effort arrivals (and arrivals no tighter than every pending
    deadline) never abort a batch: the run is bit-identical to the
    non-preempting one."""
    lib = _two_tape_library()
    tape_a, tape_b = lib.location["a0"], lib.location["b0"]
    trace = [
        Request(time=0, req_id=0, tape_id=tape_a, name="a0"),
        Request(time=0, req_id=1, tape_id=tape_a, name="a1"),
        Request(time=5_000, req_id=2, tape_id=tape_b, name="b0"),
    ]
    # in-flight work carries the *tighter* deadline; the arrival is laxer
    qos = {0: QoSSpec(deadline=20_000), 1: QoSSpec(deadline=20_000),
           2: QoSSpec(deadline=10**9)}
    kw = dict(window=0, policy="dp", n_drives=1, qos=qos)
    a = serve_trace(_two_tape_library(), list(trace), "edf-global", **kw)
    b = serve_trace(_two_tape_library(), list(trace), "edf-global",
                    preempt_urgent=True, **kw)
    assert b.n_preemptions == 0
    assert _timeline(a) == _timeline(b)


def test_class_weights_spend_batch_slack_to_protect_interactive():
    """Weighting the batch class (+slack on its *scheduling* deadline)
    re-orders service in favour of interactive requests without touching
    the reported SLO denominators (slo_report reads true deadlines)."""
    trace, qos = _weighted_qos_trace()
    kw = dict(window=400_000, policy="dp", n_drives=2, drive_costs=COSTS,
              qos=qos)
    plain = serve_trace(build_library(), trace, "edf-global", **kw)
    weighted = serve_trace(build_library(), trace, "edf-global",
                           class_weights={"batch": 8_000_000}, **kw)
    slo_p, slo_w = slo_report(plain), slo_report(weighted)
    inter_p = slo_p.for_class("interactive")
    inter_w = slo_w.for_class("interactive")
    assert inter_w.n_missed <= inter_p.n_missed  # protected class
    assert _timeline(plain) != _timeline(weighted)  # weights really re-order
    # denominators judge true deadlines, not the weighted scheduling ones
    assert slo_w.n_deadlines == slo_p.n_deadlines
    assert slo_w.overall.n == slo_p.overall.n
    # weights are scheduling-only: a zero weight map is the identity
    zero = serve_trace(build_library(), trace, "edf-global",
                       class_weights={}, **kw)
    assert _timeline(zero) == _timeline(plain)


def _weighted_qos_trace(n_requests=160):
    from repro.data.traces import qos_poisson_trace, to_requests

    records = qos_poisson_trace(
        build_library(), n_requests=n_requests, mean_interarrival=100_000,
        seed=SEED, tightness=8_000_000,
    )
    return to_requests(records, build_library())


def test_class_weights_validate():
    trace = build_trace(n_requests=10)
    with pytest.raises(ValueError, match="class weight"):
        serve_trace(build_library(), trace, "edf-global",
                    class_weights={"batch": -5})
    with pytest.raises(ValueError, match="class weight"):
        serve_trace(build_library(), trace, "edf-global",
                    class_weights={"batch": 1.5})


# ---------------------------------------------------------------------------
# composition with the PR-7 fault layer (satellite: no double-charging)
# ---------------------------------------------------------------------------
def _fault_kw():
    lib = build_library()
    trace = build_trace(n_requests=96, rate=100_000)
    plan = seeded_fault_plan(lib, trace, seed=3, n_drives=2,
                             drive_failures=1, mount_faults=1,
                             media_faults=1, solver_faults=2)
    return trace, plan


def test_fixed_selector_is_bit_identical_under_faults():
    """Same fault plan, selector-less vs ``fixed`` selector: identical
    timelines, identical fault counters — proving the adaptive plumbing
    neither double-charges retry/backoff nor double-invalidates warm state
    on the default path."""
    trace, plan = _fault_kw()
    kw = dict(window=400_000, policy="dp", n_drives=2, drive_costs=COSTS,
              faults=plan, retry=RetryPolicy())
    plain = serve_trace(build_library(), trace, "per-drive-accumulate", **kw)
    fixed = serve_trace(build_library(), trace, "per-drive-accumulate",
                        selector="fixed", **kw)
    assert _timeline(plain) == _timeline(fixed)
    assert plain.fault_stats == fixed.fault_stats
    assert plain.n_failed == fixed.n_failed
    assert [b.degraded_to for b in plain.batches] == [
        b.degraded_to for b in fixed.batches
    ]


def test_cost_model_selector_composes_with_fault_layer():
    """Adaptive selection under drive failures, mount faults, media errors
    and solver faults: every request is conserved (served or typed-failed),
    the oracle verifies every batch, degradation composes with selection
    (a degraded batch still carries its selector attribution), and the run
    replays deterministically."""
    trace, plan = _fault_kw()
    budget = ComputeBudget(solve_time_num=10_000, per_tick=120, hysteresis=1)

    def run():
        return serve_trace(
            build_library(), trace, "per-drive-accumulate", window=400_000,
            policy="dp", selector="cost-model", n_drives=2, drive_costs=COSTS,
            context=build_library().context.replace(budget=budget),
            warm_start=False, faults=plan, retry=RetryPolicy(),
        )

    report = run()
    assert report.n_served + report.n_failed == len(trace)
    assert report.summary()["all_verified"]
    assert sum(report.policy_mix.values()) == len(report.batches)
    assert all(b.policy_used is not None for b in report.batches)
    again = run()
    assert _timeline(report) == _timeline(again)
    assert report.fault_stats == again.fault_stats
    assert report.policy_mix == again.policy_mix


def test_preempt_urgent_composes_with_faults_and_selector():
    """The full stack at once: QoS admission + urgent preemption + class
    weights + adaptive selection + fault injection.  Requests stay
    conserved and the run replays bit-identically."""
    from repro.data.traces import qos_poisson_trace, to_requests

    records = qos_poisson_trace(
        build_library(), n_requests=96, mean_interarrival=100_000,
        seed=SEED, tightness=8_000_000,
    )
    trace, qos = to_requests(records, build_library())
    plan = seeded_fault_plan(build_library(), trace, seed=3, n_drives=2,
                             drive_failures=1, mount_faults=1)
    budget = ComputeBudget(solve_time_num=10_000, per_tick=120, hysteresis=1)

    def run():
        return serve_trace(
            build_library(), trace, "slack-accumulate", window=400_000,
            policy="dp", selector="cost-model", n_drives=2, drive_costs=COSTS,
            qos=qos, preempt_urgent=True,
            class_weights={"batch": 4_000_000},
            context=build_library().context.replace(budget=budget),
            warm_start=False, faults=plan, retry=RetryPolicy(),
        )

    a, b = run(), run()
    assert a.n_served + a.n_failed == len(trace)
    assert a.summary()["all_verified"]
    assert _timeline(a) == _timeline(b)
    assert a.fault_stats == b.fault_stats


# keep the registry clean for other modules importing this one
def teardown_module(module):
    _SELECTORS["fixed"] = FixedSelector()

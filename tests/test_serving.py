"""Online tape-serving subsystem: queue service vs the simulator oracle.

The acceptance bar for the subsystem (all on *virtual* time — nothing here
reads a wall clock):

* on a seeded arrival trace (>= 200 requests, >= 4 cartridges) the
  accumulate-then-solve admission with the exact DP achieves strictly lower
  mean service time than per-request FIFO solving;
* every schedule the queue service emits passes
  :func:`repro.core.verify.verify_schedule`, and the simulator's independent
  cost recomputation equals the solver-reported cost exactly;
* runs are bit-deterministic given the trace and configuration.
"""

import pytest

from repro.core import ExecutionContext, SolveCache, evaluate_detours, solve
from repro.core.verify import verify_schedule
from repro.serving.queue import ADMISSIONS, OnlineTapeServer, serve_trace
from repro.serving.sim import (
    Request,
    demo_library,
    head_position,
    poisson_trace,
    replay_schedule,
    rewind_time,
)
from repro.storage.tape import PendingQueue, TapeLibrary

SEED = 20260731


def build_library() -> TapeLibrary:
    return demo_library(SEED)


def build_trace(n_requests=240, rate=250_000):
    return poisson_trace(
        build_library(), n_requests=n_requests, mean_interarrival=rate, seed=SEED
    )


# ---------------------------------------------------------------------------
# the headline claim: batching beats per-request FIFO, asserted exactly
# ---------------------------------------------------------------------------
def test_accumulate_then_solve_beats_fifo_on_seeded_trace():
    """>= 200 requests over >= 4 cartridges: accumulate+exact-DP must achieve
    strictly lower mean (here: total, same denominator) sojourn than FIFO."""
    trace = build_trace(n_requests=240)
    assert len(trace) >= 200
    assert len({r.tape_id for r in trace}) >= 4

    fifo = serve_trace(build_library(), trace, "fifo", policy="dp")
    acc = serve_trace(build_library(), trace, "accumulate", window=400_000, policy="dp")
    assert fifo.n_served == acc.n_served == len(trace)
    assert acc.total_sojourn < fifo.total_sojourn  # exact-int strict win
    assert acc.mean_sojourn < fifo.mean_sojourn
    # FIFO solves one batch per request; accumulate solves far fewer
    assert len(fifo.batches) == len(trace)
    assert len(acc.batches) < len(trace) // 2


def test_every_emitted_schedule_passes_oracle():
    """Per-batch: verify_schedule passes and replay cost == solver cost.

    Runs with ``verify=False`` so the per-batch ``verified`` flag is a real
    observation (the enforcing ``verify=True`` path would have raised before
    recording a failing batch), then re-runs enforced for identical results.
    """
    trace = build_trace(n_requests=220)
    for admission in ADMISSIONS:
        unenforced = serve_trace(
            build_library(), trace, admission, window=300_000, policy="dp",
            verify=False,
        )
        assert unenforced.batches, admission
        for batch in unenforced.batches:
            assert batch.verified, admission
            assert batch.solver_cost == batch.replay_cost, admission
        enforced = serve_trace(
            build_library(), trace, admission, window=300_000, policy="dp"
        )
        assert enforced.summary() == unenforced.summary()


def test_service_is_deterministic():
    trace = build_trace(n_requests=210)
    runs = [
        serve_trace(build_library(), trace, "preempt", policy="dp").summary()
        for _ in range(2)
    ]
    assert runs[0] == runs[1]


# ---------------------------------------------------------------------------
# admission-policy semantics
# ---------------------------------------------------------------------------
def test_fifo_serves_per_tape_in_arrival_order():
    trace = build_trace(n_requests=120)
    report = serve_trace(build_library(), trace, "fifo", policy="dp")
    per_tape: dict[str, list] = {}
    for r in sorted(report.served, key=lambda r: r.dispatched):
        per_tape.setdefault(r.tape_id, []).append(r.arrival)
    for tape_id, arrivals in per_tape.items():
        assert arrivals == sorted(arrivals), tape_id
    assert all(b.n_requests == 1 for b in report.batches)


def test_accumulate_window_batches_everything_within_window():
    """A window larger than the whole trace horizon -> one batch per tape."""
    trace = build_trace(n_requests=100)
    horizon = trace[-1].time
    report = serve_trace(
        build_library(), trace, "accumulate", window=horizon + 1, policy="dp"
    )
    assert report.n_served == 100
    assert len(report.batches) == len({r.tape_id for r in trace})
    assert report.n_preemptions == 0


def test_preempt_requeues_and_still_serves_everything():
    trace = build_trace(n_requests=240, rate=150_000)
    report = serve_trace(build_library(), trace, "preempt", policy="dp")
    assert report.n_served == len(trace)
    assert sorted(r.req_id for r in report.served) == [r.req_id for r in trace]
    assert report.n_preemptions > 0
    preempted = [b for b in report.batches if b.preempted]
    assert preempted and all(b.n_completed is not None for b in preempted)
    # a request is never served twice and never lost
    assert len({r.req_id for r in report.served}) == len(trace)


def test_unknown_admission_rejected():
    with pytest.raises(ValueError, match="admission"):
        OnlineTapeServer(build_library(), "lifo")


def test_queue_service_works_with_any_policy_backend_combo():
    trace = build_trace(n_requests=60)
    costs = {}
    for policy, backend in [
        ("nodetour", "python"),
        ("simpledp", "python"),
        ("dp", "python"),
        ("dp", "pallas-interpret"),
    ]:
        report = serve_trace(
            build_library(), trace, "accumulate", window=400_000,
            policy=policy, context=ExecutionContext(backend=backend),
        )
        assert report.n_served == 60
        costs[(policy, backend)] = report.total_sojourn
    # the two dp backends must agree exactly; nodetour can only be worse
    assert costs[("dp", "python")] == costs[("dp", "pallas-interpret")]
    assert costs[("dp", "python")] <= costs[("nodetour", "python")]


def test_cache_shared_across_dispatches():
    """Re-running the same trace against the library cache re-hits the memo."""
    trace = build_trace(n_requests=80)
    cache = SolveCache()
    ctx = ExecutionContext(cache=cache)
    first = serve_trace(build_library(), trace, "accumulate", window=300_000,
                        policy="dp", context=ctx)
    misses = cache.misses
    second = serve_trace(build_library(), trace, "accumulate", window=300_000,
                         policy="dp", context=ctx)
    assert cache.misses == misses  # all batch multisets already memoised
    assert cache.hits >= len(second.batches)
    assert first.total_sojourn == second.total_sojourn


# ---------------------------------------------------------------------------
# simulator primitives
# ---------------------------------------------------------------------------
def test_replay_makespan_and_head_position(rng):
    from conftest import random_instance

    for _ in range(10):
        inst = random_instance(rng, lo=2, hi=12)
        res = solve(inst, policy="dp")
        rep = replay_schedule(inst, res.detours)
        assert rep.cost == res.cost == evaluate_detours(inst, res.detours)
        assert rep.makespan == max(rep.service_time)
        # trajectory starts at the load point and is piecewise consistent
        assert head_position(rep.legs, 0) == inst.m
        assert head_position(rep.legs, rep.makespan) == rep.head_at_makespan
        assert rep.n_uturns >= 1
        # rewind returns to the load point, zero iff already there
        rw = rewind_time(inst.m, inst.u_turn, rep.head_at_makespan)
        assert rw == 0 or rw >= inst.m - rep.head_at_makespan


def test_poisson_trace_is_seeded_and_routed():
    lib = build_library()
    a = poisson_trace(lib, 50, 100_000, seed=1)
    b = poisson_trace(lib, 50, 100_000, seed=1)
    c = poisson_trace(lib, 50, 100_000, seed=2)
    assert a == b
    assert a != c
    assert all(lib.location[r.name] == r.tape_id for r in a)
    assert [r.time for r in a] == sorted(r.time for r in a)


def test_pending_queue_orders_by_arrival():
    q = PendingQueue()
    reqs = [
        Request(time=30, req_id=2, tape_id="T", name="c"),
        Request(time=10, req_id=0, tape_id="T", name="a"),
        Request(time=10, req_id=1, tape_id="T", name="b"),
    ]
    for r in reqs:
        q.push(r)
    assert len(q) == 3
    assert q.peek().req_id == 0
    assert q.pop().req_id == 0
    # a preempted older request re-enters ahead of newer pending ones
    q.push(Request(time=5, req_id=9, tape_id="T", name="z"))
    assert [r.req_id for r in q.drain()] == [9, 1, 2]
    assert len(q) == 0
    with pytest.raises(IndexError):
        q.pop()


def test_verify_schedule_catches_cost_lies(rng):
    from conftest import random_instance

    inst = random_instance(rng, lo=2, hi=8)
    res = solve(inst, policy="dp")
    assert verify_schedule(inst, res.detours, cost=res.cost) == res.cost
    with pytest.raises(ValueError, match="claimed cost"):
        verify_schedule(inst, res.detours, cost=res.cost - 1)

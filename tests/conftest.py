"""Shared test fixtures: random LTSP instance generators.

``hypothesis`` is an optional dependency: when it is installed (e.g. in CI)
the property-based tests run in full; when it is absent the suite must still
collect and run, so this module exports compatible stand-ins —
:func:`given`/:func:`settings` decorators that mark the test as skipped and a
:func:`ltsp_instances` placeholder strategy.  The plain-``numpy`` generators
(:func:`random_instance`, the ``rng`` fixture) never depend on hypothesis.
"""

import numpy as np
import pytest

from repro.core import make_instance

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st

    HAS_HYPOTHESIS = True
except ImportError:  # pragma: no cover - exercised when hypothesis is absent
    st = None
    HAS_HYPOTHESIS = False

    def given(*_args, **_kwargs):
        """Stand-in for :func:`hypothesis.given`: skip the test."""

        def deco(fn):
            return pytest.mark.skip(reason="hypothesis not installed")(fn)

        return deco

    def settings(*_args, **_kwargs):
        """Stand-in for :func:`hypothesis.settings`: identity decorator."""

        def deco(fn):
            return fn

        return deco


if HAS_HYPOTHESIS:

    @st.composite
    def ltsp_instances(draw, min_files=1, max_files=6, max_size=25, max_mult=6, max_u=15):
        """Random valid LTSP instance (integer coordinates, disjoint files)."""
        R = draw(st.integers(min_files, max_files))
        sizes = [draw(st.integers(1, max_size)) for _ in range(R)]
        gaps = [draw(st.integers(0, max_size)) for _ in range(R + 1)]
        left, pos = [], gaps[0]
        for i in range(R):
            left.append(pos)
            pos += sizes[i] + gaps[i + 1]
        mult = [draw(st.integers(1, max_mult)) for _ in range(R)]
        u = draw(st.integers(0, max_u))
        return make_instance(left, sizes, mult, m=pos, u_turn=u)

else:

    def ltsp_instances(**_kwargs):
        """Placeholder strategy; tests using it are skipped via :func:`given`."""
        return None


def random_instance(rng: np.random.Generator, lo=2, hi=30, max_u=30):
    R = int(rng.integers(lo, hi))
    sizes = rng.integers(1, 50, size=R)
    gaps = rng.integers(0, 40, size=R + 1)
    left, pos = [], int(gaps[0])
    for i in range(R):
        left.append(pos)
        pos += int(sizes[i] + gaps[i + 1])
    mult = rng.integers(1, 10, size=R)
    return make_instance(left, sizes, mult, m=pos, u_turn=int(rng.integers(0, max_u)))


@pytest.fixture
def rng():
    return np.random.default_rng(12345)

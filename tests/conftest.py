"""Shared test fixtures: random LTSP instance generators.

``hypothesis`` is an optional dependency: when it is installed (e.g. in CI)
the property-based tests run in full; when it is absent the suite must still
collect and run, so this module exports compatible stand-ins —
:func:`given`/:func:`settings` decorators that mark the test as skipped and a
:func:`ltsp_instances` placeholder strategy.  The plain-``numpy`` generators
(:func:`random_instance`, the ``rng`` fixture) never depend on hypothesis.

The property suite (``tests/test_properties.py``) uses the stronger
:func:`instances_property` decorator instead: with hypothesis it is
``@given(ltsp_instances(...))`` (profiles ``ci`` — derandomized, fixed
example budget, selected via ``HYPOTHESIS_PROFILE=ci`` — and ``dev``); without
hypothesis it *runs* the test over a fixed number of seeded
:func:`fallback_instances` draws instead of skipping, so the differential
properties always execute.
"""

import os

import numpy as np
import pytest

from repro.core import make_instance

try:
    from hypothesis import HealthCheck, given, settings
    from hypothesis import strategies as st

    HAS_HYPOTHESIS = True
    settings.register_profile(
        "ci",
        derandomize=True,
        max_examples=50,
        deadline=None,
        suppress_health_check=[HealthCheck.too_slow],
    )
    settings.register_profile("dev", deadline=None)
    settings.load_profile(os.environ.get("HYPOTHESIS_PROFILE", "dev"))
except ImportError:  # pragma: no cover - exercised when hypothesis is absent
    st = None
    HAS_HYPOTHESIS = False

    def given(*_args, **_kwargs):
        """Stand-in for :func:`hypothesis.given`: skip the test."""

        def deco(fn):
            return pytest.mark.skip(reason="hypothesis not installed")(fn)

        return deco

    def settings(*_args, **_kwargs):
        """Stand-in for :func:`hypothesis.settings`: identity decorator."""

        def deco(fn):
            return fn

        return deco


if HAS_HYPOTHESIS:

    @st.composite
    def ltsp_instances(
        draw,
        min_files=1,
        max_files=6,
        max_size=25,
        max_mult=6,
        max_u=15,
        min_u=0,
        max_head_offset=0,
    ):
        """Random valid LTSP instance (integer coordinates, disjoint files).

        Gaps may be zero (adjacent files), ``min_u`` forces a U-turn penalty,
        and ``max_head_offset`` adds dead tape right of the last file so the
        head start ``m`` is strictly beyond every request.
        """
        R = draw(st.integers(min_files, max_files))
        sizes = [draw(st.integers(1, max_size)) for _ in range(R)]
        gaps = [draw(st.integers(0, max_size)) for _ in range(R + 1)]
        left, pos = [], gaps[0]
        for i in range(R):
            left.append(pos)
            pos += sizes[i] + gaps[i + 1]
        mult = [draw(st.integers(1, max_mult)) for _ in range(R)]
        u = draw(st.integers(min_u, max_u))
        m = pos + draw(st.integers(0, max_head_offset))
        return make_instance(left, sizes, mult, m=m, u_turn=u)

else:

    def ltsp_instances(**_kwargs):
        """Placeholder strategy; tests using it are skipped via :func:`given`."""
        return None


def fallback_instances(
    n,
    seed=20260731,
    min_files=1,
    max_files=6,
    max_size=25,
    max_mult=6,
    max_u=15,
    min_u=0,
    max_head_offset=0,
):
    """Seeded stand-in for the :func:`ltsp_instances` strategy.

    Mirrors the strategy's shape (adjacent files via zero gaps, optional
    forced U-turn penalty, optional head offset) with plain ``numpy``
    randomness, so the property suite runs — not skips — when hypothesis is
    absent.  Deterministic given ``seed``.
    """
    rng = np.random.default_rng(seed)
    out = []
    for _ in range(n):
        R = int(rng.integers(min_files, max_files + 1))
        sizes = rng.integers(1, max_size + 1, size=R)
        # half the draws use dense layouts (many zero gaps -> adjacent files)
        hi_gap = max_size if rng.random() < 0.5 else 1
        gaps = rng.integers(0, hi_gap + 1, size=R + 1)
        left, pos = [], int(gaps[0])
        for i in range(R):
            left.append(pos)
            pos += int(sizes[i] + gaps[i + 1])
        mult = rng.integers(1, max_mult + 1, size=R)
        u = int(rng.integers(min_u, max_u + 1))
        m = pos + int(rng.integers(0, max_head_offset + 1))
        out.append(make_instance(left, sizes, mult, m=m, u_turn=u))
    return out


def instances_property(n_fallback=25, seed=20260731, max_examples=None, **strategy_kw):
    """Property decorator for tests taking a single ``inst`` argument.

    With hypothesis: ``@given(ltsp_instances(**strategy_kw))`` under the
    active profile (``max_examples`` optionally pinned).  Without: the test
    body runs over ``n_fallback`` seeded :func:`fallback_instances` draws.
    """
    if HAS_HYPOTHESIS:

        def deco(fn):
            wrapped = fn
            if max_examples is not None:
                wrapped = settings(max_examples=max_examples)(wrapped)
            return given(ltsp_instances(**strategy_kw))(wrapped)

        return deco

    def deco(fn):
        def wrapper():
            for inst in fallback_instances(n_fallback, seed=seed, **strategy_kw):
                fn(inst)

        # keep identity for pytest reporting, but NOT the signature: pytest
        # would otherwise look for an ``inst`` fixture
        wrapper.__name__ = fn.__name__
        wrapper.__qualname__ = fn.__qualname__
        wrapper.__doc__ = fn.__doc__
        return wrapper

    return deco


def random_instance(rng: np.random.Generator, lo=2, hi=30, max_u=30):
    R = int(rng.integers(lo, hi))
    sizes = rng.integers(1, 50, size=R)
    gaps = rng.integers(0, 40, size=R + 1)
    left, pos = [], int(gaps[0])
    for i in range(R):
        left.append(pos)
        pos += int(sizes[i] + gaps[i + 1])
    mult = rng.integers(1, 10, size=R)
    return make_instance(left, sizes, mult, m=pos, u_turn=int(rng.integers(0, max_u)))


@pytest.fixture
def rng():
    return np.random.default_rng(12345)

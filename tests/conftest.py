"""Shared test fixtures: random LTSP instance strategies (hypothesis)."""

import numpy as np
import pytest
from hypothesis import strategies as st

from repro.core import make_instance


@st.composite
def ltsp_instances(draw, min_files=1, max_files=6, max_size=25, max_mult=6, max_u=15):
    """Random valid LTSP instance (integer coordinates, disjoint files)."""
    R = draw(st.integers(min_files, max_files))
    sizes = [draw(st.integers(1, max_size)) for _ in range(R)]
    gaps = [draw(st.integers(0, max_size)) for _ in range(R + 1)]
    left, pos = [], gaps[0]
    for i in range(R):
        left.append(pos)
        pos += sizes[i] + gaps[i + 1]
    mult = [draw(st.integers(1, max_mult)) for _ in range(R)]
    u = draw(st.integers(0, max_u))
    return make_instance(left, sizes, mult, m=pos, u_turn=u)


def random_instance(rng: np.random.Generator, lo=2, hi=30, max_u=30):
    R = int(rng.integers(lo, hi))
    sizes = rng.integers(1, 50, size=R)
    gaps = rng.integers(0, 40, size=R + 1)
    left, pos = [], int(gaps[0])
    for i in range(R):
        left.append(pos)
        pos += int(sizes[i] + gaps[i + 1])
    mult = rng.integers(1, 10, size=R)
    return make_instance(left, sizes, mult, m=pos, u_turn=int(rng.integers(0, max_u)))


@pytest.fixture
def rng():
    return np.random.default_rng(12345)

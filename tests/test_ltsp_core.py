"""Core LTSP algorithm tests: DP optimality, heuristic invariants, paper
worst-case families, and hypothesis property tests of the simulator."""

import numpy as np
import pytest

from conftest import given, ltsp_instances, random_instance, settings
from repro.core import (
    ALGORITHMS,
    dp_schedule,
    evaluate_detours,
    gs,
    logdp_schedule,
    make_instance,
    nfgs,
    no_detour,
    service_times,
    simpledp_schedule,
    virtual_lb,
)
from repro.core.verify import bruteforce_laminar, bruteforce_trajectory
from repro.data import (
    SMALL_PROFILE,
    generate_instance,
    gs_worst_case,
    logdp_worst_case,
    simpledp_worst_case,
)


# ---------------------------------------------------------------------------
# exactness against two independent oracles
# ---------------------------------------------------------------------------
@settings(max_examples=120, deadline=None)
@given(ltsp_instances(max_files=5))
def test_dp_matches_trajectory_oracle(inst):
    opt, dets = dp_schedule(inst)
    assert opt == bruteforce_trajectory(inst)
    # reconstructed schedule realises the claimed cost exactly
    assert evaluate_detours(inst, dets) == opt


@settings(max_examples=40, deadline=None)
@given(ltsp_instances(min_files=2, max_files=4))
def test_dp_matches_laminar_enumeration(inst):
    opt, _ = dp_schedule(inst)
    assert opt == bruteforce_laminar(inst)[0]


@settings(max_examples=80, deadline=None)
@given(ltsp_instances(max_files=6))
def test_virtual_lb_is_lower_bound(inst):
    assert virtual_lb(inst) <= dp_schedule(inst)[0]


# ---------------------------------------------------------------------------
# heuristic dominance invariants (paper §4-§5)
# ---------------------------------------------------------------------------
def test_heuristic_dominance(rng):
    for _ in range(25):
        inst = random_instance(rng)
        costs = {n: evaluate_detours(inst, a(inst)) for n, a in ALGORITHMS.items()}
        opt = costs["dp"]
        for name, c in costs.items():
            assert opt <= c, (name, costs)
        # restricted DPs still dominate the greedy family they generalise
        assert costs["logdp1"] <= costs["gs"]
        assert costs["logdp5"] <= costs["logdp1"]
        assert costs["simpledp"] <= costs["gs"]
        assert costs["fgs"] <= costs["gs"]
        assert costs["nfgs"] <= costs["gs"]  # paper's corrected-NFGS property


def test_single_file_instance():
    inst = make_instance([5], [3], [4], m=20, u_turn=7)
    opt, dets = dp_schedule(inst)
    assert dets == []
    # head: 20 -> 5 (15), U-turn (7), read (3)
    assert opt == 4 * (15 + 7 + 3) == virtual_lb(inst)


def test_u_turn_penalty_disables_detours():
    """With a huge U the optimal schedule degenerates to NODETOUR."""
    inst = make_instance([0, 50], [5, 5], [10, 1], m=100, u_turn=10_000)
    opt, dets = dp_schedule(inst)
    assert dets == []
    assert opt == evaluate_detours(inst, no_detour(inst))


def test_zero_u_detour_worthwhile():
    """Urgent right file: detour beats sweeping (U=0)."""
    inst = make_instance([0, 90], [1, 5], [1, 100], m=100, u_turn=0)
    opt, dets = dp_schedule(inst)
    assert (1, 1) in dets
    assert opt < evaluate_detours(inst, no_detour(inst))


# ---------------------------------------------------------------------------
# paper worst-case families
# ---------------------------------------------------------------------------
def test_gs_worst_case_ratio_approaches_3():
    inst = gs_worst_case(big=20_000, requests=20_000)
    ratio = evaluate_detours(inst, gs(inst)) / dp_schedule(inst)[0]
    assert ratio > 2.99


def test_simpledp_lower_bound_5_3():
    r_prev = 0.0
    for z in (10, 20, 40):
        inst = simpledp_worst_case(z)
        opt, dopt = dp_schedule(inst)
        sdp, _ = simpledp_schedule(inst)
        ratio = sdp / opt
        assert ratio >= r_prev  # approaches 5/3 from below
        r_prev = ratio
    assert 1.5 < r_prev < 5 / 3 + 1e-9
    # the optimum on this family uses intertwined detours
    assert any(
        a1 < a2 <= b2 < b1 for (a1, b1) in dopt for (a2, b2) in dopt if (a1, b1) != (a2, b2)
    )


def test_logdp_worst_case_ratio_grows_toward_3():
    inst = logdp_worst_case(z=16)
    opt, _ = dp_schedule(inst)
    lg, _ = logdp_schedule(inst, lam=1.0)
    assert lg / opt > 2.3
    assert lg >= opt


def test_simpledp_within_3x(rng):
    """Lemma 2 upper bound: SIMPLEDP <= 3 OPT for any U."""
    for _ in range(30):
        inst = random_instance(rng, max_u=200)
        opt, _ = dp_schedule(inst)
        sdp, _ = simpledp_schedule(inst)
        assert sdp <= 3 * opt


# ---------------------------------------------------------------------------
# simulator properties
# ---------------------------------------------------------------------------
@settings(max_examples=60, deadline=None)
@given(ltsp_instances())
def test_service_times_well_formed(inst):
    for algo in (no_detour, gs, nfgs):
        t = service_times(inst, algo(inst))
        assert (t >= 0).all()
        # every file is served no earlier than a virtual dedicated head could
        virt = inst.m - inst.left + (inst.right - inst.left) + inst.u_turn
        assert (t >= virt).all()


def test_dataset_generator_valid():
    for i in range(8):
        inst = generate_instance(SMALL_PROFILE, seed=100 + i)
        inst.validate()
        assert inst.n_req >= 2
        assert inst.n >= inst.n_req

"""Pallas LTSP-DP kernel: shape/dtype sweep vs the pure-jnp oracle and the
exact integer DP (f32 is exact for the small-integer instances used here)."""

import numpy as np
import pytest

from conftest import random_instance
from repro.core import dp_schedule, make_instance
from repro.kernels.ltsp_dp.ops import ltsp_dp_table, ltsp_opt_instance, prepare_arrays
from repro.kernels.ltsp_dp.ref import ltsp_dp_table_ref, ltsp_opt_ref


def _small_instance(rng, R):
    sizes = rng.integers(1, 9, size=R)
    gaps = rng.integers(0, 6, size=R + 1)
    left, pos = [], int(gaps[0])
    for i in range(R):
        left.append(pos)
        pos += int(sizes[i] + gaps[i + 1])
    mult = rng.integers(1, 4, size=R)
    return make_instance(left, sizes, mult, m=pos, u_turn=int(rng.integers(0, 5)))


@pytest.mark.parametrize("R", [2, 3, 5, 9, 14])
def test_kernel_matches_ref_exactly(R, rng):
    inst = _small_instance(rng, R)
    l, r, x, nl, S = prepare_arrays(inst)
    T_kernel = ltsp_dp_table(l, r, x, nl, float(inst.u_turn), S, interpret=True)
    T_ref = ltsp_dp_table_ref(l, r, x, nl, float(inst.u_turn), S)
    np.testing.assert_array_equal(np.asarray(T_kernel), np.asarray(T_ref))


@pytest.mark.parametrize("seed", [0, 1, 2, 3])
def test_kernel_opt_equals_exact_dp(seed):
    rng = np.random.default_rng(seed)
    inst = _small_instance(rng, int(rng.integers(2, 10)))
    opt_exact, _ = dp_schedule(inst)
    assert ltsp_opt_instance(inst) == float(opt_exact)


def test_ref_opt_equals_exact_dp(rng):
    inst = _small_instance(rng, 7)
    l, r, x, nl, S = prepare_arrays(inst)
    v = ltsp_opt_ref(l, r, x, nl, float(inst.u_turn), float(inst.m), S)
    assert float(v) == float(dp_schedule(inst)[0])


@pytest.mark.parametrize("cand_tile", [2, 4, 8])
def test_kernel_banded_scan_matches_full_tile(rng, cand_tile):
    """The chunked banded candidate scan (cand_tile < R - 1) must reproduce
    the single-tile path bit-for-bit — values AND argmin planes (tie-breaks
    included), with and without a span restriction."""
    import jax.numpy as jnp

    from repro.kernels.ltsp_dp.ltsp_dp import ltsp_dp_tables

    inst = _small_instance(rng, 11)
    l, r, x, nl, S = prepare_arrays(inst)
    u = jnp.asarray([float(inst.u_turn)], l.dtype)
    args = (l[None], r[None], x[None], nl[None], u)
    for span in (None, 3):
        T_full, C_full = ltsp_dp_tables(*args, S=S, span=span)
        T_band, C_band = ltsp_dp_tables(*args, S=S, span=span, cand_tile=cand_tile)
        np.testing.assert_array_equal(np.asarray(T_band), np.asarray(T_full))
        np.testing.assert_array_equal(np.asarray(C_band), np.asarray(C_full))


def test_kernel_s_padding_invariance(rng):
    """Padding the skip-count axis must not change reachable cells."""
    inst = _small_instance(rng, 6)
    l, r, x, nl, S = prepare_arrays(inst)
    T1 = ltsp_dp_table(l, r, x, nl, float(inst.u_turn), S, interpret=True)
    T2 = ltsp_dp_table(l, r, x, nl, float(inst.u_turn), S + 128, interpret=True)
    R = inst.n_req
    # reachable skip counts never exceed n; compare that slab
    n = inst.n
    np.testing.assert_array_equal(
        np.asarray(T1[..., : n + 1]), np.asarray(T2[..., : n + 1])
    )

"""Property-based differential suite over random LTSP instances.

Strategies (``conftest.ltsp_instances`` / seeded ``fallback_instances`` when
hypothesis is absent — the suite *runs* either way) cover head offsets beyond
the last file, adjacent files (zero gaps), forced U-turn penalties, and the
degenerate inputs the model must reject (zero-length files, overlapping
files).  Properties asserted on every draw:

* the exact DP's cost is <= every heuristic's / restricted DP's cost;
* every reported cost is >= *VirtualLB* (``lower_bound_gap >= 1``);
* python and pallas-interpret backends are bit-identical (cost *and*
  detours) for the DP family;
* every emitted schedule passes :func:`repro.core.verify.verify_schedule` —
  structural validity plus the discrete-event simulator's independent cost
  recomputation agreeing exactly with the solver-reported cost.
"""

import numpy as np
import pytest

from conftest import HAS_HYPOTHESIS, fallback_instances, instances_property
from repro.core import (
    ExecutionContext,
    evaluate_detours,
    list_solvers,
    lower_bound_gap,
    make_instance,
    solve,
    virtual_lb,
)
from repro.core.verify import verify_schedule
from repro.serving.sim import replay_schedule

#: policies with a device path (simpledp rides the wavefront's disjoint clip)
DP_FAMILY = ("dp", "logdp1", "logdp5", "simpledp")
DEV = ExecutionContext(backend="pallas-interpret")


# ---------------------------------------------------------------------------
# differential properties
# ---------------------------------------------------------------------------
@instances_property(n_fallback=30, max_u=20, min_u=1, max_head_offset=30)
def test_exact_dp_minimises_over_all_policies(inst):
    """DP <= every policy; every cost is simulator-exact and >= VirtualLB."""
    costs = {}
    for policy in list_solvers():
        res = solve(inst, policy=policy)
        assert res.cost == evaluate_detours(inst, res.detours), policy
        assert verify_schedule(inst, res.detours, cost=res.cost) == res.cost
        costs[policy] = res.cost
    assert all(costs["dp"] <= c for c in costs.values()), costs
    # restricted DPs relax toward the exact DP as the span grows
    assert costs["dp"] <= costs["logdp5"] <= costs["logdp1"]


@instances_property(n_fallback=30, max_head_offset=40)
def test_lower_bound_gap_well_defined(inst):
    """Costs dominate VirtualLB: gap >= 1 whenever the bound is positive."""
    lb = virtual_lb(inst)
    assert lb >= 0
    for policy in ("dp", "simpledp", "nodetour"):
        cost = solve(inst, policy=policy).cost
        assert cost >= lb
        gap = lower_bound_gap(inst, cost)
        assert gap >= 1.0 or lb == 0


@instances_property(n_fallback=10, max_examples=15, max_files=5, max_size=12, min_u=1)
def test_python_pallas_interpret_bit_parity(inst):
    """Device backend == python backend, cost *and* detours, DP family."""
    for policy in DP_FAMILY:
        py = solve(inst, policy=policy)
        dev = solve(inst, policy=policy, context=DEV)
        assert (dev.cost, dev.detours) == (py.cost, py.detours), policy
        assert verify_schedule(inst, dev.detours, cost=dev.cost) == py.cost


@instances_property(n_fallback=25, max_u=18, max_head_offset=25)
def test_replay_oracle_agrees_with_inline_evaluator(inst):
    """The discrete-event replay and the inline evaluator agree on arbitrary
    (even unhelpful) detour lists, not just solver output."""
    R = inst.n_req
    rng = np.random.default_rng(int(inst.m) + R)
    for _ in range(4):
        a = int(rng.integers(0, R))
        dets = [(a, int(rng.integers(a, R)))]
        if rng.random() < 0.5:
            a2 = int(rng.integers(0, R))
            dets.append((a2, int(rng.integers(a2, R))))
        rep = replay_schedule(inst, dets)
        assert rep.cost == evaluate_detours(inst, dets), dets
        assert rep.makespan == max(rep.service_time)


# ---------------------------------------------------------------------------
# degenerate-input properties (model validation)
# ---------------------------------------------------------------------------
def test_zero_length_files_rejected():
    """Zero-length files violate the model (positive read time) and must be
    rejected at construction for any placement."""
    rng = np.random.default_rng(20260731)
    for _ in range(25):
        R = int(rng.integers(1, 6))
        sizes = rng.integers(1, 20, size=R)
        sizes[int(rng.integers(0, R))] = 0  # one zero-length file
        gaps = rng.integers(0, 10, size=R + 1)
        left, pos = [], int(gaps[0])
        for i in range(R):
            left.append(pos)
            pos += int(sizes[i] + gaps[i + 1])
        with pytest.raises(AssertionError, match="positive size"):
            make_instance(left, sizes, rng.integers(1, 4, size=R), m=pos)


def test_overlapping_or_duplicate_files_rejected():
    """Files sharing tape (duplicate positions / overlaps) must be rejected."""
    rng = np.random.default_rng(20260801)
    for _ in range(25):
        R = int(rng.integers(2, 6))
        inst_ok = fallback_instances(1, seed=int(rng.integers(2**31)),
                                     min_files=R, max_files=R)[0]
        left = inst_ok.left.tolist()
        sizes = (inst_ok.right - inst_ok.left).tolist()
        k = int(rng.integers(1, R))
        if rng.random() < 0.5 or sizes[k - 1] < 2:
            left[k] = left[k - 1]  # duplicate position
        else:
            # strict partial overlap: left[k-1] < left[k] < right[k-1]
            left[k] = left[k - 1] + sizes[k - 1] // 2
        with pytest.raises(AssertionError, match="disjoint"):
            make_instance(left, sizes, inst_ok.mult, u_turn=3)


def test_verify_schedule_rejects_malformed_detours():
    inst = fallback_instances(1, seed=7, min_files=3, max_files=3)[0]
    with pytest.raises(ValueError, match="out of range"):
        verify_schedule(inst, [(0, 3)])
    with pytest.raises(ValueError, match="out of range"):
        verify_schedule(inst, [(-1, 1)])
    with pytest.raises(ValueError, match="claimed cost"):
        verify_schedule(inst, [], cost=solve(inst, policy="nodetour").cost + 1)


def test_fallback_strategy_covers_required_regimes():
    """The seeded fallback must exercise what the issue demands: adjacent
    files, positive U-turn penalties, and head offsets beyond the last file."""
    insts = fallback_instances(40, seed=123, min_u=0, max_u=10, max_head_offset=20)
    assert any(
        (i.n_req > 1 and (i.left[1:] == i.right[:-1]).any()) for i in insts
    ), "no adjacent files drawn"
    assert any(i.u_turn > 0 for i in insts)
    assert any(i.m > int(i.right[-1]) for i in insts)


def test_suite_mode_is_reported():
    """Sanity marker: which mode this run executed in (visible via -rA)."""
    assert HAS_HYPOTHESIS in (True, False)

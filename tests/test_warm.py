"""Warm-start incremental re-solve: bit-identity and work-counter bars.

The acceptance bars for warm-started solving (all exact, no tolerances):

* ``dp_schedule_warm`` with *any* warm state — aligned, misaligned, stale,
  or ``None`` — returns ``(cost, detours)`` bit-identical to the cold
  ``dp_schedule``, across chained request-multiset perturbations;
* the device path (``pallas-interpret``) reuses the dense table/argmin
  planes captured by a cold device solve and stays bit-identical to the
  exact python DP on perturbed re-solves;
* warm states cut work: over perturbation chains ``cells_reused`` is
  strictly positive and warm ``cells_evaluated`` stays below cold;
* the serving loop with ``warm_start=True`` (the default) emits the same
  schedules, timelines, and sojourns as ``warm_start=False`` — only the
  work counters may differ.
"""

import hashlib

import numpy as np

from repro.core import (
    ExecutionContext,
    SolveCache,
    dp_schedule,
    dp_schedule_warm,
    make_instance,
    solve,
    solve_batch,
    solve_batch_warm,
    solve_warm,
)
from repro.serving.queue import serve_trace
from repro.serving.sim import demo_library, poisson_trace

from conftest import random_instance

SEED = 20260731
DEV = ExecutionContext(backend="pallas-interpret")


# ---------------------------------------------------------------------------
# instance perturbations: the shapes serving admission actually produces
# ---------------------------------------------------------------------------
def perturb(inst, rng, ops=None):
    """A valid sibling instance: one request added, completed, or aborted.

    ``ops`` restricts the move set (``"bump"`` = one more request on an
    already-requested file, ``"drop"`` = a requested file leaves the set,
    ``"insert"`` = a brand-new file is requested in a free gap).
    """
    left = [int(v) for v in inst.left]
    right = [int(v) for v in inst.right]
    mult = [int(v) for v in inst.mult]
    R = len(left)
    moves = list(ops) if ops is not None else []
    if ops is None:
        moves = ["bump"]
        if R > 1:
            moves.append("drop")
    gaps = []
    prev = 0
    for i in range(R):
        if left[i] - prev >= 2:
            gaps.append((prev, left[i]))
        prev = right[i]
    if inst.m - prev >= 2:
        gaps.append((prev, inst.m))
    if gaps and ops is None:
        moves.append("insert")
    op = moves[int(rng.integers(0, len(moves)))]
    if op == "bump":
        mult[int(rng.integers(0, R))] += 1
    elif op == "drop":
        i = int(rng.integers(0, R))
        del left[i], right[i], mult[i]
    else:  # insert into a free gap
        lo, hi = gaps[int(rng.integers(0, len(gaps)))]
        a = lo + int(rng.integers(0, hi - lo - 1))
        b = a + 1 + int(rng.integers(0, hi - a - 1))
        i = 0
        while i < len(left) and left[i] < a:
            i += 1
        left.insert(i, a)
        right.insert(i, b)
        mult.insert(i, 1 + int(rng.integers(0, 3)))
    sizes = [r - l for l, r in zip(left, right)]
    return make_instance(left, sizes, mult, m=inst.m, u_turn=inst.u_turn)


# ---------------------------------------------------------------------------
# python path: differential vs cold over chained perturbations
# ---------------------------------------------------------------------------
def test_warm_chain_bit_identical_and_reuses(rng):
    """Warm re-solve == cold solve on every chain step; reuse is real."""
    total_reused = total_cold = total_warm = 0
    for _ in range(25):
        inst = random_instance(rng, lo=3, hi=12)
        warm = None
        for step in range(4):
            cold_cost, cold_det = dp_schedule(inst)
            cost, det, warm, stats = dp_schedule_warm(inst, warm=warm)
            assert (cost, det) == (cold_cost, cold_det)
            if step == 0:
                assert stats.cells_reused == 0  # nothing to reuse yet
            else:
                _, _, _, cold_stats = dp_schedule_warm(inst)
                total_cold += cold_stats.cells_evaluated
                total_warm += stats.cells_evaluated
                total_reused += stats.cells_reused
            inst = perturb(inst, rng)
    assert total_reused > 0
    assert total_warm < total_cold  # strictly less DP work over the chains


def test_warm_against_unrelated_instance_is_safe(rng):
    """A warm state from a different cartridge must not change results."""
    for _ in range(20):
        a = random_instance(rng, lo=2, hi=10)
        b = random_instance(rng, lo=2, hi=10)
        _, _, warm_a, _ = dp_schedule_warm(a)
        cost, det, _, _ = dp_schedule_warm(b, warm=warm_a)
        assert (cost, det) == dp_schedule(b)


def test_warm_mult_bump_reuses_cells(rng):
    """The single-request-arrival shape must reuse on instances with R>=4."""
    reused = 0
    for _ in range(10):
        inst = random_instance(rng, lo=6, hi=14)
        _, _, warm, _ = dp_schedule_warm(inst)
        bumped = perturb(inst, rng, ops=["bump"])
        cost, det, _, stats = dp_schedule_warm(bumped, warm=warm)
        assert (cost, det) == dp_schedule(bumped)
        reused += stats.cells_reused
    assert reused > 0


def test_solve_warm_matches_solve_and_counts(rng):
    """Module-level solve_warm: result identity + cache-hit short circuit."""
    cache = SolveCache()
    ctx = ExecutionContext(cache=cache)
    inst = random_instance(rng, lo=4, hi=10)
    plain = solve(inst, policy="dp")
    r1, w1, s1 = solve_warm(inst, policy="dp", context=ctx)
    assert (r1.cost, r1.detours) == (plain.cost, plain.detours)
    assert s1.mode == "cold" and s1.cells_evaluated > 0 and w1 is not None
    # identical multiset -> memo hit: zero DP work, incoming state kept
    r2, w2, s2 = solve_warm(inst, policy="dp", context=ctx, warm=w1)
    assert (r2.cost, r2.detours) == (plain.cost, plain.detours)
    assert s2.mode == "cache" and s2.cells_evaluated == 0
    assert w2 is w1


def test_solve_warm_unsupported_policy_falls_back(rng):
    """Policies without warm support still solve, flagged honestly."""
    inst = random_instance(rng, lo=3, hi=8)
    for policy in ("simpledp", "gs"):
        plain = solve(inst, policy=policy)
        res, warm, stats = solve_warm(inst, policy=policy)
        assert (res.cost, res.detours) == (plain.cost, plain.detours)
        assert stats.mode == "unsupported" and warm is None


def test_solve_batch_warm_matches_solve_batch(rng):
    insts = [random_instance(rng, lo=3, hi=10) for _ in range(6)]
    cold = solve_batch(insts, policy="dp")
    results, warms, stats = solve_batch_warm(insts, policy="dp")
    assert [(r.cost, r.detours) for r in results] == [
        (r.cost, r.detours) for r in cold
    ]
    assert all(w is not None for w in warms)
    # perturbed second round, threading the states back in
    rng2 = np.random.default_rng(7)
    bumped = [perturb(i, rng2) for i in insts]
    cold2 = solve_batch(bumped, policy="dp")
    results2, _, stats2 = solve_batch_warm(bumped, policy="dp", warms=warms)
    assert [(r.cost, r.detours) for r in results2] == [
        (r.cost, r.detours) for r in cold2
    ]
    assert sum(s.cells_reused for s in stats2) > 0


# ---------------------------------------------------------------------------
# device path: dense-plane reuse from a cold device solve
# ---------------------------------------------------------------------------
def test_device_warm_bit_identical_to_python(rng):
    """Cold device solve -> captured dense planes -> warm perturbed re-solve
    must equal the exact python DP bit for bit, and reuse cells."""
    reused = 0
    for _ in range(6):
        inst = random_instance(rng, lo=4, hi=9)
        res, warm, stats = solve_warm(inst, policy="dp", context=DEV)
        oracle = solve(inst, policy="dp")
        assert (res.cost, res.detours) == (oracle.cost, oracle.detours)
        assert stats.mode == "cold" and stats.cells_evaluated > 0
        for _ in range(2):
            inst = perturb(inst, rng, ops=["bump", "drop"])
            oracle = solve(inst, policy="dp")
            res, warm, stats = solve_warm(
                inst, policy="dp", context=DEV, warm=warm
            )
            assert (res.cost, res.detours) == (oracle.cost, oracle.detours)
            reused += stats.cells_reused
    assert reused > 0


def test_device_batch_warm_mixed_alignment(rng):
    """A batch mixing warm-aligned and fresh instances stays exact."""
    insts = [random_instance(rng, lo=4, hi=8) for _ in range(4)]
    _, warms, _ = solve_batch_warm(insts, policy="dp", context=DEV)
    rng2 = np.random.default_rng(11)
    nxt = [perturb(i, rng2, ops=["bump"]) for i in insts[:2]] + [
        random_instance(rng, lo=4, hi=8) for _ in range(2)
    ]
    cold = solve_batch(nxt, policy="dp")
    results, _, stats = solve_batch_warm(
        nxt, policy="dp", context=DEV, warms=warms[:2] + [None, None]
    )
    assert [(r.cost, r.detours) for r in results] == [
        (r.cost, r.detours) for r in cold
    ]
    assert all(s.cells_evaluated > 0 or s.cells_reused > 0 for s in stats)


# ---------------------------------------------------------------------------
# serving loop: warm-start on (default) vs off — schedules bit-identical
# ---------------------------------------------------------------------------
def _served_sha(report):
    served = tuple(
        (r.req_id, r.arrival, r.dispatched, r.completed) for r in report.served
    )
    return hashlib.sha256(repr(served).encode()).hexdigest()[:16]


WORK_KEYS = ("warm_start", "cells_evaluated", "cells_reused", "cells_per_batch")


def test_serving_warm_vs_cold_bit_identical():
    """Every admission that re-solves: warm on/off differ only in work."""
    lib = demo_library(SEED)
    trace = poisson_trace(lib, n_requests=220, mean_interarrival=250_000,
                          seed=SEED)
    for admission in ("accumulate", "preempt", "batched", "slack-accumulate"):
        w = serve_trace(demo_library(SEED), trace, admission, window=300_000,
                        policy="dp", warm_start=True)
        c = serve_trace(demo_library(SEED), trace, admission, window=300_000,
                        policy="dp", warm_start=False)
        assert _served_sha(w) == _served_sha(c), admission
        ws, cs = w.summary(), c.summary()
        for key in WORK_KEYS + ("cache",):
            ws.pop(key, None)
            cs.pop(key, None)
        assert ws == cs, admission
        assert w.cells_reused > 0, admission
        assert w.cells_evaluated < c.cells_evaluated, admission
        assert c.cells_reused == 0, admission  # cold runs must not reuse

"""QoS subsystem: deadline-aware admissions, mount schedulers, trace replay.

The acceptance bars (all on exact integer virtual time):

* with QoS unset, the ``lowest-numbered`` scheduler + existing admissions
  reproduce the PR-4 results **bit-identically** — pinned differentially
  against constants captured from the PR-4 code on the seeded 240-request
  constrained-pool trace;
* on the seeded deadline sweep, ``edf-global`` and ``slack-accumulate``
  achieve strictly fewer deadline misses than ``fifo-global`` at every
  swept tightness;
* a JSONL trace round-trips bit-exactly through write -> read -> replay;
* greedy vs ``lru`` vs ``lookahead`` mount scheduling is deterministic and
  oracle-verified on the constrained pool.
"""

import hashlib

import pytest

from repro.data.traces import (
    TRACE_SCHEMA,
    TraceRecord,
    qos_poisson_trace,
    read_trace,
    records_of,
    to_requests,
    write_trace,
)
from repro.serving import (
    ADMISSIONS,
    LEGACY_ADMISSIONS,
    MOUNT_SCHEDULERS,
    POOL_ADMISSIONS,
    QOS_ADMISSIONS,
    DriveCosts,
    DrivePool,
    LookaheadScheduler,
    MountView,
    OnlineTapeServer,
    QoSSpec,
    demo_library,
    int_quantile,
    poisson_trace,
    resolve_scheduler,
    serve_trace,
    slo_report,
)
from repro.storage.tape import TapeLibrary

pytestmark = pytest.mark.qos

SEED = 20260731
COSTS = DriveCosts(mount=150_000, unmount=60_000, load_seek=30_000)

#: PR-4 timelines on the seeded 240-request constrained-pool trace
#: (n_drives=2, COSTS, window=400_000, policy="dp"), captured by running the
#: pre-QoS code: sha256[:16] of the (req_id, arrival, dispatched, completed)
#: served tuple plus the exact total sojourn.  The QoS-unset default path
#: must keep reproducing these bit for bit.
PR4_BASELINE = {
    "fifo": ("1a79c55063c3f802", 56_368_550_889),
    "accumulate": ("df9ed258ac816c37", 3_809_190_213),
    "preempt": ("668366586042762a", 7_347_259_813),
    "fifo-global": ("1a79c55063c3f802", 56_368_550_889),
    "per-drive-accumulate": ("df9ed258ac816c37", 3_809_190_213),
    "batched": ("df9ed258ac816c37", 3_809_190_213),
}


def build_library():
    return demo_library(SEED)


def build_trace(n_requests=240, rate=250_000):
    return poisson_trace(
        build_library(), n_requests=n_requests, mean_interarrival=rate, seed=SEED
    )


def build_qos_trace(tightness, n_requests=240, rate=250_000, seed=SEED):
    records = qos_poisson_trace(
        demo_library(seed), n_requests=n_requests, mean_interarrival=rate,
        seed=seed, tightness=tightness,
    )
    return to_requests(records, demo_library(seed))


def _served_sha(report):
    served = tuple(
        (r.req_id, r.arrival, r.dispatched, r.completed) for r in report.served
    )
    return hashlib.sha256(repr(served).encode()).hexdigest()[:16]


def _timeline(report):
    return (
        [(r.req_id, r.arrival, r.dispatched, r.completed) for r in report.served],
        sorted(
            (b.tape_id, b.drive, b.dispatched, b.mount_delay, b.n_requests,
             b.solver_cost, b.rewind, b.preempted)
            for b in report.batches
        ),
    )


# ---------------------------------------------------------------------------
# acceptance: QoS unset reproduces PR 4 bit-identically (differential pin)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("admission", sorted(PR4_BASELINE))
def test_qos_unset_default_path_matches_pr4_pin(admission):
    trace = build_trace()
    sha, total = PR4_BASELINE[admission]
    report = serve_trace(
        build_library(), trace, admission, window=400_000, policy="dp",
        n_drives=2, drive_costs=COSTS, mount_scheduler="lowest-numbered",
    )
    assert report.scheduler == "greedy"  # lowest-numbered aliases the default
    assert (_served_sha(report), report.total_sojourn) == (sha, total)
    # the implicit default spells the same run
    default = serve_trace(
        build_library(), trace, admission, window=400_000, policy="dp",
        n_drives=2, drive_costs=COSTS,
    )
    assert _timeline(default) == _timeline(report)


@pytest.mark.parametrize(
    "qos_admission,baseline",
    [("edf-global", "fifo-global"), ("slack-accumulate", "per-drive-accumulate")],
)
def test_qos_admissions_without_deadlines_alias_their_baselines(
    qos_admission, baseline
):
    """With no QoS map the deadline-aware admissions degrade to their
    deadline-blind counterparts bit for bit (deadline order == arrival
    order, no window collapse)."""
    trace = build_trace(n_requests=200)
    kw = dict(window=300_000, policy="dp", n_drives=2, drive_costs=COSTS)
    a = serve_trace(build_library(), trace, baseline, **kw)
    b = serve_trace(build_library(), trace, qos_admission, **kw)
    assert _timeline(a) == _timeline(b)


# ---------------------------------------------------------------------------
# acceptance: the seeded deadline sweep, exact virtual-time miss counts
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("tightness", [2_000_000, 8_000_000, 32_000_000])
def test_deadline_aware_admissions_strictly_beat_fifo(tightness):
    trace, qos = build_qos_trace(tightness)
    missed = {}
    for admission in ("fifo-global", "edf-global", "slack-accumulate"):
        report = serve_trace(
            build_library(), trace, admission,
            window=400_000 if admission == "slack-accumulate" else 0,
            policy="dp", qos=qos,
        )
        assert report.n_served == len(trace)
        assert report.n_deadlines == len(trace)  # every request has a deadline
        missed[admission] = report.n_missed  # exact int
    assert missed["edf-global"] < missed["fifo-global"]
    assert missed["slack-accumulate"] < missed["fifo-global"]


@pytest.mark.parametrize("seed", [1, 3, 8, 13, 42])
@pytest.mark.parametrize("tightness", [4_000_000, 16_000_000])
def test_edf_never_raises_miss_rate_vs_fifo(seed, tightness):
    """Property (seeded): EDF-with-expiry-demotion never serves more
    requests late than FIFO order on tight-deadline traces."""
    trace, qos = build_qos_trace(tightness, n_requests=200, seed=seed)
    reports = {
        admission: serve_trace(
            demo_library(seed), trace, admission, policy="dp", qos=qos
        )
        for admission in ("fifo-global", "edf-global")
    }
    assert (
        reports["edf-global"].n_missed <= reports["fifo-global"].n_missed
    ), (seed, tightness)
    # same denominator: the miss-rate comparison is the count comparison
    assert (
        reports["edf-global"].n_deadlines == reports["fifo-global"].n_deadlines
    )


def test_slack_accumulate_collapses_the_hold_window():
    """A deadline arriving mid-hold re-arms the wake timer to the collapse
    instant (earliest live deadline - window): the plain accumulate run
    holds the full window and misses, slack-accumulate dispatches the whole
    queue early enough that the deadline is still reachable."""
    from repro.serving import Request

    def build():
        lib = TapeLibrary(capacity_per_tape=10_000, u_turn=100)
        lib.store("a", 2_000)
        lib.store("b", 2_000)
        return lib

    tape_id = build().location["a"]
    # req 0 is best-effort; req 1 lands mid-hold with a deadline of 25_000.
    # Serving both from the load point takes ~14_100, so the deadline is
    # comfortable at the collapse instant and hopeless after a 20_000 hold.
    trace = [
        Request(time=0, req_id=0, tape_id=tape_id, name="a"),
        Request(time=100, req_id=1, tape_id=tape_id, name="b"),
    ]
    qos = {1: QoSSpec(deadline=25_000)}
    held = serve_trace(
        build(), trace, "per-drive-accumulate", window=20_000, policy="dp",
        qos=qos,
    )
    assert held.batches[0].dispatched == 20_000  # full hold: arrival + window
    assert held.n_missed == 1
    eager = serve_trace(
        build(), trace, "slack-accumulate", window=20_000, policy="dp", qos=qos
    )
    # collapse instant = deadline - window = 5_000, one batch of both reads
    assert eager.batches[0].dispatched == 5_000
    assert eager.batches[0].n_requests == 2
    assert eager.n_missed == 0
    assert eager.total_sojourn < held.total_sojourn


def test_edf_demotes_expired_deadlines():
    """A request whose deadline already passed must not outrank a still
    meetable one: the lost request is served last, the meetable one on
    time."""
    from repro.serving import Request

    lib = TapeLibrary(capacity_per_tape=10_000, u_turn=100)
    lib.store("early", 1_000)
    lib.store("late", 1_000)
    lib.store("first", 1_000)
    tid = lib.location["early"]
    # req 0 occupies the single drive; by the time it completes (~7k+),
    # req 1's deadline (100) is long expired while req 2's (40_000) is live
    trace = [
        Request(time=0, req_id=0, tape_id=tid, name="first"),
        Request(time=10, req_id=1, tape_id=tid, name="early"),
        Request(time=20, req_id=2, tape_id=tid, name="late"),
    ]
    qos = {1: QoSSpec(deadline=100), 2: QoSSpec(deadline=40_000)}
    report = serve_trace(lib, trace, "edf-global", policy="dp", qos=qos, n_drives=1)
    done = {r.req_id: r.completed for r in report.served}
    assert done[2] < done[1]  # expired req 1 demoted behind live req 2
    assert done[2] <= 40_000  # the live deadline is met
    assert report.n_missed == 1  # only the already-lost request misses


# ---------------------------------------------------------------------------
# SLO reporting: exact nearest-rank quantiles, per-class joins
# ---------------------------------------------------------------------------
def test_int_quantile_is_exact_nearest_rank():
    vals = [10, 20, 30, 40]
    assert int_quantile(vals, 1, 2) == 20  # ceil(0.5*4)=2nd
    assert int_quantile(vals, 99, 100) == 40
    assert int_quantile(vals, 0, 1) == 10
    assert int_quantile([7], 99, 100) == 7
    assert int_quantile([], 1, 2) == 0
    # 99 ints: p99 rank = ceil(0.99*99) = 99 -> the max, exactly
    assert int_quantile(list(range(99)), 99, 100) == 98
    with pytest.raises(ValueError, match="quantile"):
        int_quantile(vals, 3, 2)


def test_int_quantile_edge_cases_pinned():
    """Regression pins on the exact nearest-rank boundaries: p99 of 100
    ordered ints is the 99th element (not the max), the 0-quantile and the
    1-quantile are the extremes, singletons and all-equal inputs are fixed
    points, and an unsorted input sorts first."""
    # rank = ceil(0.99*100) = 99 -> the 99th order statistic, NOT 100
    assert int_quantile(list(range(1, 101)), 99, 100) == 99
    assert int_quantile(list(range(1, 101)), 1, 1) == 100
    assert int_quantile(list(range(1, 101)), 0, 100) == 1
    assert int_quantile([5], 0, 1) == 5
    assert int_quantile([5], 1, 1) == 5
    assert int_quantile([3, 3, 3, 3], 99, 100) == 3
    assert int_quantile([40, 10, 30, 20], 1, 2) == 20  # sorts, not positional
    # generators are consumed exactly once, like any Iterable
    assert int_quantile((v for v in (9, 1, 5)), 1, 2) == 5
    with pytest.raises(ValueError, match="quantile"):
        int_quantile([1], 1, 0)
    with pytest.raises(ValueError, match="quantile"):
        int_quantile([1], -1, 2)


def test_class_with_deadlines_but_zero_completions_is_reported():
    """A class whose deadline-carrying work was entirely dropped by the
    fault layer must appear in the SLO report with miss_rate 1.0 — before
    the fix it vanished (no served rows) and its misses were uncounted."""
    from types import SimpleNamespace

    served = [
        SimpleNamespace(req_id=0, sojourn=1_000, completed=5_000, faulted=False),
    ]
    failed = [SimpleNamespace(req_id=1), SimpleNamespace(req_id=2),
              SimpleNamespace(req_id=3)]
    qos = {
        0: QoSSpec(deadline=9_000, qos_class="batch"),
        1: QoSSpec(deadline=2_000, qos_class="interactive"),
        2: QoSSpec(deadline=3_000, qos_class="interactive"),
        3: QoSSpec(qos_class="interactive"),  # best-effort drop: not a miss
    }
    report = SimpleNamespace(
        admission="edf-global", scheduler="greedy", served=served, failed=failed
    )
    slo = slo_report(report, qos)
    inter = slo.for_class("interactive")
    assert inter.n == 0  # nothing completed...
    assert inter.n_failed == 3
    assert inter.n_deadlines == 2  # ...but the dropped deadlines still count
    assert inter.n_missed == 2
    assert inter.miss_rate == 1.0
    assert inter.n_missed_faulted == 0  # faulted-miss attribution: served only
    assert (inter.p50_sojourn, inter.total_lateness, inter.max_lateness) == (0, 0, 0)
    batch = slo.for_class("batch")
    assert (batch.n, batch.n_failed, batch.n_missed) == (1, 0, 0)
    assert slo.overall.n_deadlines == 3 and slo.overall.n_missed == 2
    assert slo.n_failed == 3
    s = slo.summary()
    assert s["n_failed"] == 3
    assert s["classes"]["interactive"]["n_failed"] == 3
    assert s["classes"]["interactive"]["miss_rate"] == 1.0


def test_edf_global_tie_break_is_deterministic_and_pinned():
    """Equal live deadlines break by (arrival, req_id): the documented total
    order of ``_edf_key``.  Three same-deadline requests on one busy drive
    must serve in arrival order, and re-running the serve is bit-identical."""
    from repro.serving import Request

    def build():
        lib = TapeLibrary(capacity_per_tape=10_000, u_turn=100)
        for name in ("first", "a", "b", "c"):
            lib.store(name, 1_000)
        return lib

    tid = build().location["first"]
    trace = [
        Request(time=0, req_id=0, tape_id=tid, name="first"),
        # identical deadlines, distinct arrivals: tie broken by arrival
        Request(time=30, req_id=3, tape_id=tid, name="c"),
        Request(time=10, req_id=1, tape_id=tid, name="a"),
        Request(time=10, req_id=2, tape_id=tid, name="b"),
    ]
    qos = {i: QoSSpec(deadline=90_000) for i in (1, 2, 3)}
    runs = [
        serve_trace(build(), trace, "edf-global", policy="dp", qos=qos,
                    n_drives=1)
        for _ in range(2)
    ]
    assert _timeline(runs[0]) == _timeline(runs[1])
    done = {r.req_id: r.completed for r in runs[0].served}
    # arrival order among the tie; equal arrivals fall back to req_id order
    assert done[1] < done[2] < done[3]


def test_edf_seeded_duplicate_deadline_regression():
    """Seeded trace with every deadline collapsed onto a handful of values:
    the serve is deterministic across repeats and across request shuffles
    restricted to equal-(deadline, arrival) groups (req_id still orders)."""
    trace, qos = build_qos_trace(8_000_000, n_requests=120)
    bucket = 4_000_000
    squashed = {
        rid: QoSSpec(
            deadline=None if s.deadline is None
            else -(-s.deadline // bucket) * bucket,  # ceil onto the grid
            qos_class=s.qos_class,
        )
        for rid, s in qos.items()
    }
    runs = [
        serve_trace(build_library(), trace, "edf-global", policy="dp",
                    qos=squashed, n_drives=2, drive_costs=COSTS)
        for _ in range(2)
    ]
    assert _timeline(runs[0]) == _timeline(runs[1])
    assert runs[0].summary()["all_verified"]


def test_slack_accumulate_wake_rearm_dedupes_equal_deadlines():
    """A second request with the *same* deadline arriving mid-hold must not
    clobber or double-arm the wake timer: the queue still dispatches once,
    at the first collapse instant, with every queued request aboard."""
    from repro.serving import Request

    def build():
        lib = TapeLibrary(capacity_per_tape=10_000, u_turn=100)
        for name in ("a", "b", "c"):
            lib.store(name, 2_000)
        return lib

    tid = build().location["a"]
    trace = [
        Request(time=0, req_id=0, tape_id=tid, name="a"),
        Request(time=100, req_id=1, tape_id=tid, name="b"),
        Request(time=200, req_id=2, tape_id=tid, name="c"),  # same deadline
    ]
    qos = {1: QoSSpec(deadline=25_000), 2: QoSSpec(deadline=25_000)}
    report = serve_trace(
        build(), trace, "slack-accumulate", window=20_000, policy="dp",
        qos=qos,
    )
    # collapse instant = 25_000 - 20_000; req 2's arrival re-arms to the
    # same instant (deduped), not a second, later batch
    assert [b.dispatched for b in report.batches] == [5_000]
    assert report.batches[0].n_requests == 3
    assert report.n_missed == 0


def test_qos_spec_validation_and_slack():
    spec = QoSSpec(deadline=1_000, qos_class="interactive")
    assert spec.slack(400) == 600
    assert spec.slack(1_500) == -500
    assert QoSSpec().slack(123) is None
    with pytest.raises(ValueError, match="deadline"):
        QoSSpec(deadline=-1)
    with pytest.raises(ValueError, match="qos_class"):
        QoSSpec(qos_class="")


def test_slo_report_joins_classes_exactly():
    trace, qos = build_qos_trace(8_000_000, n_requests=160)
    report = serve_trace(
        build_library(), trace, "slack-accumulate", window=400_000,
        policy="dp", qos=qos,
    )
    slo = slo_report(report)
    assert slo.admission == "slack-accumulate"
    assert sum(c.n for c in slo.classes) == slo.overall.n == report.n_served
    assert sum(c.n_missed for c in slo.classes) == slo.n_missed == report.n_missed
    assert slo.n_deadlines == report.n_deadlines
    # per-class quantiles recompute exactly from the served rows
    by_class = {}
    for r in report.served:
        by_class.setdefault(qos[r.req_id].qos_class, []).append(r.sojourn)
    for c in slo.classes:
        assert c.p50_sojourn == int_quantile(by_class[c.qos_class], 1, 2)
        assert c.p99_sojourn == int_quantile(by_class[c.qos_class], 99, 100)
    with pytest.raises(KeyError):
        slo.for_class("no-such-class")
    # summary() mirrors the exact fields
    s = slo.summary()
    assert s["n_missed"] == slo.n_missed
    assert set(s["classes"]) == {c.qos_class for c in slo.classes}


def test_service_report_surfaces_quantiles_and_misses():
    trace, qos = build_qos_trace(8_000_000, n_requests=120)
    report = serve_trace(build_library(), trace, "accumulate",
                         window=400_000, policy="dp", qos=qos)
    s = report.summary()
    for key in ("p50_sojourn", "p95_sojourn", "p99_sojourn", "scheduler",
                "n_deadlines", "n_missed", "miss_rate"):
        assert key in s, key
    assert s["n_missed"] == report.n_missed
    # QoS-unset reports stay miss-free and keep the quantile keys
    plain = serve_trace(build_library(), build_trace(n_requests=60),
                        "accumulate", window=400_000, policy="dp")
    ps = plain.summary()
    assert "p50_sojourn" in ps and "p99_sojourn" in ps
    assert "n_missed" not in ps and plain.n_missed == 0


# ---------------------------------------------------------------------------
# mount schedulers: unit determinism + serving determinism/oracle
# ---------------------------------------------------------------------------
def test_mount_schedulers_diverge_deterministically_at_unit_level():
    """3 drives, cartridge A re-used recently: greedy evicts drive 0,
    LRU evicts the least-recently-acquired drive, lookahead keeps the
    cartridge with the deepest queue."""

    def pool_with_history(scheduler):
        pool = DrivePool(3, COSTS, scheduler=scheduler)
        assert pool.acquire("A", now=0)[0].drive_id == 0
        assert pool.acquire("B", now=1)[0].drive_id == 1
        assert pool.acquire("C", now=2)[0].drive_id == 2
        d, delay = pool.acquire("A", now=3)  # holder, free re-use
        assert (d.drive_id, delay) == (0, 0)
        return pool

    view = MountView(now=4, costs=COSTS, depth={"A": 5, "B": 0, "C": 1})
    greedy = pool_with_history("greedy")
    assert greedy.acquire("D", now=4, view=view)[0].drive_id == 0
    lru = pool_with_history("lru")
    assert lru.acquire("D", now=4, view=view)[0].drive_id == 1  # last_used=1
    look = pool_with_history("lookahead")
    # keep-scores: A=5*remount, B=0, C=1*remount -> evict B's drive
    assert look.acquire("D", now=4, view=view)[0].drive_id == 1
    view2 = MountView(now=4, costs=COSTS, depth={"A": 0, "B": 3, "C": 1})
    look2 = pool_with_history("lookahead")
    assert look2.acquire("D", now=4, view=view2)[0].drive_id == 0


def test_lookahead_urgency_doubles_keep_score():
    sched = LookaheadScheduler()
    pool = DrivePool(2, COSTS, scheduler=sched)
    pool.acquire("A", now=0)
    pool.acquire("B", now=1)
    remount = COSTS.unmount + COSTS.switch
    # equal depths; A's earliest deadline is within one remount -> keep A
    view = MountView(
        now=1_000_000, costs=COSTS, depth={"A": 2, "B": 2},
        urgency={"A": 1_000_000 + remount, "B": None},
    )
    drive, _ = pool.acquire("C", now=1_000_000, view=view)
    assert drive.mounted == "C" and drive.drive_id == 1  # B evicted


def test_mount_scheduler_serving_determinism_and_oracle():
    """Every registered scheduler serves the seeded 240-request
    constrained-pool trace deterministically, all schedules oracle-checked;
    greedy reproduces the PR-4 pin."""
    trace = build_trace()
    for scheduler in ("greedy", "lru", "lookahead"):
        runs = [
            serve_trace(
                build_library(), trace, "per-drive-accumulate", window=400_000,
                policy="dp", n_drives=3, drive_costs=COSTS,
                mount_scheduler=scheduler,
            )
            for _ in range(2)
        ]
        assert _timeline(runs[0]) == _timeline(runs[1]), scheduler
        assert runs[0].summary()["all_verified"], scheduler
        assert runs[0].n_served == 240, scheduler
        assert runs[0].scheduler == scheduler


def test_scheduler_registry_and_validation():
    assert set(MOUNT_SCHEDULERS) == {"greedy", "lowest-numbered", "lru", "lookahead"}
    assert resolve_scheduler("lowest-numbered").name == "greedy"
    custom = LookaheadScheduler()
    assert resolve_scheduler(custom) is custom
    with pytest.raises(ValueError, match="mount scheduler"):
        DrivePool(2, scheduler="mru")
    with pytest.raises(TypeError, match="MountScheduler"):
        resolve_scheduler(object())
    with pytest.raises(ValueError, match="admission"):
        OnlineTapeServer(build_library(), "edf")  # not a registered name


def test_admission_registry_includes_qos_tier():
    assert set(QOS_ADMISSIONS) == {"edf-global", "slack-accumulate"}
    assert set(ADMISSIONS) == (
        set(LEGACY_ADMISSIONS) | set(POOL_ADMISSIONS) | set(QOS_ADMISSIONS)
    )


# ---------------------------------------------------------------------------
# acceptance: JSONL trace write -> read -> replay, bit-exact
# ---------------------------------------------------------------------------
def test_trace_roundtrip_bit_exact(tmp_path):
    records = qos_poisson_trace(
        build_library(), n_requests=80, mean_interarrival=250_000, seed=SEED,
        tightness=8_000_000,
    )
    path = tmp_path / "trace.jsonl"
    write_trace(path, records)
    replayed = read_trace(path)
    assert replayed == records
    # writer bytes are deterministic: write(read(write(r))) == write(r)
    second = tmp_path / "again.jsonl"
    write_trace(second, replayed)
    assert second.read_bytes() == path.read_bytes()
    # ... and the replay reproduces the original run bit for bit
    kw = dict(window=400_000, policy="dp", n_drives=2, drive_costs=COSTS)
    trace_a, qos_a = to_requests(records, build_library())
    trace_b, qos_b = to_requests(replayed, build_library())
    assert trace_a == trace_b and qos_a == qos_b
    a = serve_trace(build_library(), trace_a, "slack-accumulate", qos=qos_a, **kw)
    b = serve_trace(build_library(), trace_b, "slack-accumulate", qos=qos_b, **kw)
    assert _timeline(a) == _timeline(b)
    assert a.summary() == b.summary()


def test_records_of_inverts_to_requests():
    trace = build_trace(n_requests=50)
    qos = {r.req_id: QoSSpec(deadline=r.time + 1_000_000) for r in trace}
    records = records_of(trace, qos)
    back, back_qos = to_requests(records)
    assert back == trace
    assert back_qos == qos


def test_to_requests_expands_multiplicity_and_validates():
    lib = build_library()
    name = sorted(lib.location)[0]
    tid = lib.location[name]
    rec = TraceRecord(arrival=5, tape=tid, file=name, multiplicity=3,
                      deadline=9_000, qos_class="batch")
    trace, qos = to_requests([rec], lib)
    assert len(trace) == 3
    assert [r.req_id for r in trace] == [0, 1, 2]
    assert all(r.time == 5 and r.name == name for r in trace)
    assert all(qos[r.req_id] == QoSSpec(deadline=9_000, qos_class="batch")
               for r in trace)
    with pytest.raises(ValueError, match="not in the library"):
        to_requests([TraceRecord(arrival=0, tape=tid, file="ghost")], lib)
    with pytest.raises(ValueError, match="is on"):
        to_requests([TraceRecord(arrival=0, tape="TAPE999", file=name)], lib)


def test_trace_record_validation():
    with pytest.raises(ValueError, match="arrival"):
        TraceRecord(arrival=-1, tape="T", file="f")
    with pytest.raises(ValueError, match="multiplicity"):
        TraceRecord(arrival=0, tape="T", file="f", multiplicity=0)
    with pytest.raises(ValueError, match="precedes arrival"):
        TraceRecord(arrival=10, tape="T", file="f", deadline=9)
    with pytest.raises(ValueError, match="qos_class"):
        TraceRecord(arrival=0, tape="T", file="f", qos_class="")


def test_read_trace_rejects_malformed_files(tmp_path):
    good = tmp_path / "good.jsonl"
    write_trace(good, [TraceRecord(arrival=0, tape="T", file="f")])
    assert read_trace(good) == [TraceRecord(arrival=0, tape="T", file="f")]

    no_header = tmp_path / "no_header.jsonl"
    no_header.write_text('{"arrival":0,"file":"f","tape":"T"}\n')
    with pytest.raises(ValueError, match="schema header"):
        read_trace(no_header)

    bad_schema = tmp_path / "bad_schema.jsonl"
    bad_schema.write_text('{"schema":"ltsp-trace/v999"}\n')
    with pytest.raises(ValueError, match="unsupported schema"):
        read_trace(bad_schema)

    unknown = tmp_path / "unknown.jsonl"
    unknown.write_text(
        '{"schema":"%s"}\n{"arrival":0,"file":"f","tape":"T","prio":1}\n'
        % TRACE_SCHEMA
    )
    with pytest.raises(ValueError, match="unknown field"):
        read_trace(unknown)

    not_json = tmp_path / "not_json.jsonl"
    not_json.write_text('{"schema":"%s"}\nnot json\n' % TRACE_SCHEMA)
    with pytest.raises(ValueError, match="not valid JSON"):
        read_trace(not_json)

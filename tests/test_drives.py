"""DrivePool: allocation determinism, mount accounting, and the pool-served
online loop — including the ISSUE acceptance bar:

* ``n_drives < n_cartridges`` with nonzero mount costs serves a seeded
  240-request trace deterministically, every emitted schedule oracle-verified;
* at ``n_drives = len(tapes)`` with zero mount cost the pool reduces
  bit-identically to the one-drive-per-cartridge (PR-3) server, and the new
  admission names are aliases of the legacy ones there;
* ``batched`` (one ``solve_batch`` launch per event tick) schedules
  identically to ``per-drive-accumulate`` on any backend.
"""

import pytest

from repro.core import ExecutionContext
from repro.serving.drives import DriveCosts, DrivePool, LRUScheduler, MountView
from repro.serving.queue import (
    ADMISSIONS,
    LEGACY_ADMISSIONS,
    POOL_ADMISSIONS,
    QOS_ADMISSIONS,
    OnlineTapeServer,
    serve_trace,
)
from repro.serving.sim import demo_library, poisson_trace

SEED = 20260731
COSTS = DriveCosts(mount=150_000, unmount=60_000, load_seek=30_000)


def build_library():
    return demo_library(SEED)


def build_trace(n_requests=240, rate=250_000):
    return poisson_trace(
        build_library(), n_requests=n_requests, mean_interarrival=rate, seed=SEED
    )


def _timeline(report):
    return (
        report.summary(),
        [(r.req_id, r.arrival, r.dispatched, r.completed) for r in report.served],
        sorted(
            (b.tape_id, b.drive, b.dispatched, b.mount_delay, b.n_requests,
             b.solver_cost, b.rewind, b.preempted)
            for b in report.batches
        ),
    )


# ---------------------------------------------------------------------------
# pool primitives
# ---------------------------------------------------------------------------
def test_drive_costs_validate_and_switch():
    assert DriveCosts().switch == 0
    assert COSTS.switch == 180_000
    with pytest.raises(ValueError, match=">= 0"):
        DriveCosts(mount=-1)
    with pytest.raises(ValueError, match="at least one drive"):
        DrivePool(0)


def test_pool_allocation_is_deterministic_and_counts_mounts():
    pool = DrivePool(2, COSTS)
    d0, delay = pool.acquire("A")
    assert (d0.drive_id, delay) == (0, COSTS.switch)  # lowest empty drive
    d1, delay = pool.acquire("B")
    assert (d1.drive_id, delay) == (1, COSTS.switch)
    # the holder is preferred and free to re-serve at no mount cost
    again, delay = pool.acquire("A")
    assert again is d0 and delay == 0
    # a third cartridge evicts the lowest-numbered free occupied drive
    d2, delay = pool.acquire("C")
    assert d2.drive_id == 0 and delay == COSTS.unmount + COSTS.switch
    assert d2.mounted == "C" and pool.drive_of("A") is None
    assert pool.stats() == {
        "n_drives": 2,
        "mounts": 3,
        "unmounts": 1,
        "mount_time": 3 * COSTS.switch + COSTS.unmount,
        "alive_drives": 2,
    }


def test_pool_cartridge_exclusivity():
    pool = DrivePool(3)
    drive, _ = pool.acquire("A")
    drive.busy = True
    # A exists once: its holder is busy, so A cannot be served elsewhere even
    # though two drives sit idle
    assert not pool.can_serve("A")
    assert pool.can_serve("B")
    drive.busy = False
    assert pool.can_serve("A")


def test_failed_drive_leaves_every_allocation_path():
    pool = DrivePool(2, COSTS)
    d0, _ = pool.acquire("A")
    d0.busy = True
    pool.fail_drive(d0)
    # failure extracts the cartridge and clears the busy flag
    assert d0.failed and d0.mounted is None and not d0.busy
    assert pool.alive == [pool.drives[1]]
    assert pool.drive_of("A") is None
    # the cartridge remounts on the survivor at full remount cost
    d1, delay = pool.acquire("A")
    assert d1.drive_id == 1 and delay == COSTS.switch
    # failing again is a no-op on the counter
    pool.fail_drive(d0)
    assert pool.n_drive_failures == 1
    assert pool.stats()["drive_failures"] == 1


def test_all_drives_failed_pool_cannot_serve():
    pool = DrivePool(2)
    for d in list(pool.drives):
        pool.fail_drive(d)
    assert pool.alive == []
    assert not pool.can_serve("A")
    assert pool.n_drive_failures == 2


def test_all_drives_failed_stats_report_zero_capacity():
    """A pool failed down to nothing must say so: ``n_drives`` counts the
    configured drives (dead included), so ``alive_drives`` rides along with
    the failure counter — regression for stats() reading as a healthy
    2-drive pool after every drive died."""
    pool = DrivePool(2, COSTS)
    pool.acquire("A")
    for d in list(pool.drives):
        pool.fail_drive(d)
    s = pool.stats()
    assert s["n_drives"] == 2
    assert s["drive_failures"] == 2
    assert s["alive_drives"] == 0
    # mount accounting from before the failures is preserved
    assert s["mounts"] == 1
    # partial failure reports the survivors
    half = DrivePool(2, COSTS)
    half.fail_drive(half.drives[0])
    assert half.stats()["alive_drives"] == 1


def test_dead_drives_never_reach_eviction_selection():
    """Mount-scheduler eviction must only ever pick among surviving free
    drives — a failed drive is out of ``drive_of``/``can_serve``/``acquire``
    even if it still holds state, and a pool failed down to zero capacity
    answers ``can_serve`` False for every cartridge rather than handing the
    scheduler an empty candidate list."""
    pool = DrivePool(3, COSTS, scheduler=LRUScheduler())
    pool.acquire("A", now=10)
    pool.acquire("B", now=20)
    pool.fail_drive(pool.drives[0])  # the LRU drive (held "A") dies
    # eviction selection sees only the survivors: drive 2 (empty) wins over
    # unmounting drive 1, never the dead-but-least-recently-used drive 0
    view = MountView(now=30, costs=pool.costs)
    drive, delay = pool.acquire("C", now=30, view=view)
    assert drive.drive_id == 2 and delay == COSTS.switch
    assert pool.drive_of("A") is None  # extracted by the failure
    # fail the rest: zero capacity, nothing is servable, stats() says why
    for d in list(pool.drives):
        pool.fail_drive(d)
    assert pool.alive == []
    assert not pool.can_serve("A")
    assert not pool.can_serve("C")  # even the just-mounted cartridge
    assert pool.stats()["alive_drives"] == 0


def test_fault_free_pool_stats_hide_failure_key():
    """The failure counter must not appear in fault-free stats — the PR-4
    stats dict is pinned key-for-key elsewhere in this module."""
    pool = DrivePool(2, COSTS)
    pool.acquire("A")
    assert "drive_failures" not in pool.stats()


def test_pool_stats_always_report_alive_drives():
    """``stats()`` reports ``alive_drives`` unconditionally — a monitoring
    consumer polling a healthy pool must not need a fault to learn its
    capacity (the old shape only grew the key after the first failure)."""
    pool = DrivePool(3, COSTS)
    assert pool.stats()["alive_drives"] == 3
    pool.acquire("A")
    s = pool.stats()
    assert s["alive_drives"] == 3 and "drive_failures" not in s
    pool.fail_drive(pool.drives[0])
    assert pool.stats()["alive_drives"] == 2


def test_report_summary_keeps_old_conditional_alive_drives_shape():
    """Compat pin: ``ServiceReport.summary()`` keeps the *old* conditional
    surface even though ``stats()`` is now unconditional — fault-free rows
    carry no ``alive_drives`` key, and faulted rows order it *after*
    ``drive_failures``, exactly as the pre-observability pool reported it
    (the recorded benchmark JSON pins these row bytes)."""
    lib = build_library()
    report = serve_trace(
        lib, build_trace(24), "per-drive-accumulate", window=400_000,
        policy="dp", n_drives=2, drive_costs=COSTS, context=lib.context,
    )
    s = report.summary()  # pool stats splat flat into the summary row
    assert "alive_drives" not in s
    keys = list(s)
    assert keys[keys.index("n_drives"):keys.index("mount_time") + 1] == \
        ["n_drives", "mounts", "unmounts", "mount_time"]
    # a faulted run keeps the key, in the old position
    from repro.serving.faults import DriveFailure, FaultPlan
    from repro.serving.drives import RetryPolicy

    lib = build_library()
    report = serve_trace(
        lib, build_trace(24), "per-drive-accumulate", window=400_000,
        policy="dp", n_drives=2, drive_costs=COSTS, context=lib.context,
        faults=FaultPlan(drive_failures=(DriveFailure(at=1, drive=0),)),
        retry=RetryPolicy(on_exhausted="drop"),
    )
    s = report.summary()
    keys = list(s)
    assert keys[keys.index("drive_failures") + 1] == "alive_drives"
    assert s["alive_drives"] == 1 and s["drive_failures"] == 1


# ---------------------------------------------------------------------------
# acceptance: constrained pool + mount costs on the seeded 240-request trace
# ---------------------------------------------------------------------------
def test_constrained_pool_serves_240_requests_deterministically():
    trace = build_trace(n_requests=240)
    n_tapes = len(build_library().tapes)
    assert len({r.tape_id for r in trace}) >= 4
    for admission in POOL_ADMISSIONS:
        runs = [
            _timeline(
                serve_trace(
                    build_library(), trace, admission, window=400_000,
                    policy="dp", n_drives=2, drive_costs=COSTS,
                )
            )
            for _ in range(2)
        ]
        assert runs[0] == runs[1], admission  # bit-deterministic
        summary = runs[0][0]
        assert summary["n_served"] == 240, admission
        assert summary["all_verified"], admission
        assert summary["n_drives"] == 2 < n_tapes
        assert summary["mounts"] > n_tapes  # cartridges cycled through drives
        assert summary["unmounts"] > 0
        assert summary["mount_time"] > 0


def test_every_pool_schedule_passes_oracle():
    """verify=False run: the recorded per-batch flags are real observations;
    the enforcing run must then agree batch for batch."""
    trace = build_trace(n_requests=220)
    for admission in ("fifo-global", "per-drive-accumulate", "batched"):
        unenforced = serve_trace(
            build_library(), trace, admission, window=300_000, policy="dp",
            n_drives=2, drive_costs=COSTS, verify=False,
        )
        assert unenforced.batches, admission
        for batch in unenforced.batches:
            assert batch.verified, admission
            assert batch.solver_cost == batch.replay_cost, admission
        enforced = serve_trace(
            build_library(), trace, admission, window=300_000, policy="dp",
            n_drives=2, drive_costs=COSTS,
        )
        assert enforced.summary() == unenforced.summary()


def test_mount_legs_shift_completions():
    """With one drive and nonzero mount costs every batch after the first on
    a new cartridge charges its mount delay ahead of the trajectory."""
    trace = build_trace(n_requests=120)
    report = serve_trace(
        build_library(), trace, "per-drive-accumulate", window=200_000,
        policy="dp", n_drives=1, drive_costs=COSTS,
    )
    delays = [b.mount_delay for b in report.batches]
    assert delays[0] == COSTS.switch  # first mount: no unmount charged
    assert all(
        d in (0, COSTS.switch, COSTS.switch + COSTS.unmount) for d in delays
    )
    assert sum(delays) == report.summary()["mount_time"]
    # served completions all land at/after dispatch + that batch's mount leg
    by_dispatch = {b.dispatched: b.mount_delay for b in report.batches}
    for r in report.served:
        assert r.completed > r.dispatched + by_dispatch.get(r.dispatched, 0) - 1


def test_preempt_during_mount_cannot_skip_the_mount():
    """A preemption landing inside the mount legs must not teleport the head:
    the drive stays busy until the in-flight mount completes, so no later
    dispatch on that drive starts its trajectory before the mount could
    physically finish."""
    from repro.serving.sim import Request

    lib = build_library()
    tape_id = lib.tapes[0].tape_id
    names = sorted(n for n, t in lib.location.items() if t == tape_id)
    assert len(names) >= 2
    # second arrival lands deep inside the first dispatch's mount window
    trace = [
        Request(time=0, req_id=0, tape_id=tape_id, name=names[0]),
        Request(time=10, req_id=1, tape_id=tape_id, name=names[1]),
    ]
    report = serve_trace(
        lib, trace, "preempt", policy="dp", n_drives=1, drive_costs=COSTS
    )
    assert report.n_preemptions == 1
    first, second = report.batches
    assert first.preempted and first.mount_delay == COSTS.switch
    # re-dispatch waits for the aborted mount to complete
    assert second.dispatched >= COSTS.switch
    assert second.mount_delay == 0  # the cartridge is threaded by then
    assert report.n_served == 2
    for r in report.served:
        assert r.completed > COSTS.switch  # nothing finishes before the mount


def test_preempt_works_on_constrained_pool():
    trace = build_trace(n_requests=240, rate=150_000)
    report = serve_trace(
        build_library(), trace, "preempt", policy="dp",
        n_drives=2, drive_costs=COSTS,
    )
    assert report.n_served == len(trace)
    assert sorted(r.req_id for r in report.served) == [r.req_id for r in trace]
    assert len({r.req_id for r in report.served}) == len(trace)


# ---------------------------------------------------------------------------
# reduction: dedicated pool + zero costs == the PR-3 one-drive-per-cartridge
# server, and the pool admission names alias the legacy ones there
# ---------------------------------------------------------------------------
def test_dedicated_zero_cost_pool_reduces_to_legacy_server():
    trace = build_trace(n_requests=240)
    n_tapes = len(build_library().tapes)
    default = serve_trace(
        build_library(), trace, "accumulate", window=400_000, policy="dp"
    )
    explicit = serve_trace(
        build_library(), trace, "accumulate", window=400_000, policy="dp",
        n_drives=n_tapes, drive_costs=DriveCosts(),
    )
    assert _timeline(default) == _timeline(explicit)
    assert default.summary()["mounts"] == n_tapes  # one thread per cartridge
    assert default.summary()["mount_time"] == 0


@pytest.mark.parametrize(
    "legacy,pooled",
    [("fifo", "fifo-global"), ("accumulate", "per-drive-accumulate")],
)
def test_pool_admissions_alias_legacy_at_special_case(legacy, pooled):
    trace = build_trace(n_requests=200)
    a = serve_trace(build_library(), trace, legacy, window=300_000, policy="dp")
    b = serve_trace(build_library(), trace, pooled, window=300_000, policy="dp")
    sa, served_a, batches_a = _timeline(a)
    sb, served_b, batches_b = _timeline(b)
    assert {**sa, "admission": pooled} == sb
    assert (served_a, batches_a) == (served_b, batches_b)


def test_batched_schedules_identically_to_per_drive_accumulate():
    trace = build_trace(n_requests=200)
    kw = dict(window=300_000, policy="dp", n_drives=2, drive_costs=COSTS)
    acc = serve_trace(build_library(), trace, "per-drive-accumulate", **kw)
    bat = serve_trace(build_library(), trace, "batched", **kw)
    sa, served_a, batches_a = _timeline(acc)
    sb, served_b, batches_b = _timeline(bat)
    assert {**sa, "admission": "batched"} == sb
    assert (served_a, batches_a) == (served_b, batches_b)


def test_batched_admission_on_device_backend():
    """The batched admission's one-launch-per-tick path through solve_batch
    must agree exactly with the python backend."""
    trace = build_trace(n_requests=60)
    kw = dict(window=400_000, policy="dp", n_drives=2, drive_costs=COSTS)
    py = serve_trace(build_library(), trace, "batched",
                     context=ExecutionContext(), **kw)
    dev = serve_trace(build_library(), trace, "batched",
                      context=ExecutionContext(backend="pallas-interpret"), **kw)
    assert py.total_sojourn == dev.total_sojourn
    assert [r.completed for r in py.served] == [r.completed for r in dev.served]


def test_admission_registry_is_coherent():
    assert (
        set(LEGACY_ADMISSIONS) | set(POOL_ADMISSIONS) | set(QOS_ADMISSIONS)
    ) == set(ADMISSIONS)
    with pytest.raises(ValueError, match="admission"):
        OnlineTapeServer(build_library(), "lifo")
    with pytest.raises(ValueError, match="n_drives"):
        OnlineTapeServer(build_library(), "fifo-global", n_drives=0)

"""CacheBackend protocol: LRU bounds, persistent JSONL journal, key safety.

Acceptance bars:

* the solve-memo key includes every result-affecting execution option —
  ``numeric_policy`` and ``cand_tile`` must never cross-serve hits
  (regression: earlier revisions keyed on neither);
* a bounded LRU *smaller than the working set* on the seeded 240-request
  constrained-pool trace yields bit-identical schedules to an unbounded
  cache — eviction can only cost re-solves, never change a result;
* :class:`~repro.core.JsonlCacheBackend` round-trips its journal across
  restarts (replay -> memo hits without re-solving), tolerates torn/foreign
  lines, and ``compact()``/``clear()`` behave;
* both shipped backends satisfy the runtime-checkable
  :class:`~repro.core.CacheBackend` protocol;
* the journal is single-writer: a second live writer on the same path is
  refused with :class:`~repro.core.CacheLockedError` (two appenders would
  interleave torn lines), while a lockfile left by a dead process is taken
  over silently.
"""

import json
import os

import pytest

from repro.core import (
    CacheBackend,
    CacheLockedError,
    ExecutionContext,
    JsonlCacheBackend,
    SolveCache,
    solve,
)
from repro.serving import DriveCosts, demo_library, poisson_trace, serve_trace

from conftest import random_instance

SEED = 20260731
COSTS = DriveCosts(mount=150_000, unmount=60_000, load_seek=30_000)
DEV = ExecutionContext(backend="pallas-interpret")

#: summary() keys that measure *work done*, not *what was served* — cache
#: behavior is allowed to change these (a memo hit does zero DP work; an
#: evicted entry forces a re-solve), never anything else
WORK_KEYS = ("cache", "cells_evaluated", "cells_reused", "cells_per_batch")


def _scrub_work(summary):
    for key in WORK_KEYS:
        summary.pop(key, None)
    return summary


def build_trace(n_requests=240):
    return poisson_trace(
        demo_library(SEED), n_requests=n_requests, mean_interarrival=250_000,
        seed=SEED,
    )


# ---------------------------------------------------------------------------
# key regression: numeric_policy and cand_tile are part of the identity
# ---------------------------------------------------------------------------
def test_cache_key_separates_numeric_policy_and_cand_tile(rng):
    """A memo populated under one (numeric_policy, cand_tile) must not serve
    hits to another — the options change the execution (error domain,
    launch shape), so a cross-hit would misreport provenance."""
    cache = SolveCache()
    inst = random_instance(rng, lo=3, hi=8)
    ctx = DEV.replace(cache=cache)
    r1 = solve(inst, policy="dp", context=ctx)
    assert cache.stats()["misses"] == 1 and cache.stats()["entries"] == 1
    r2 = solve(inst, policy="dp", context=ctx.replace(cand_tile=8))
    assert cache.stats()["hits"] == 0, "cand_tile variant must not hit"
    r3 = solve(inst, policy="dp", context=ctx.replace(numeric_policy="f64"))
    assert cache.stats()["hits"] == 0, "numeric_policy variant must not hit"
    assert cache.stats() == {
        "hits": 0, "misses": 3, "entries": 3, "warm_entries": 0,
    }
    # all three are exact solves of the same instance -> same answer
    assert (r1.cost, r1.detours) == (r2.cost, r2.detours) == (r3.cost, r3.detours)
    # and each variant re-hits itself
    solve(inst, policy="dp", context=ctx)
    solve(inst, policy="dp", context=ctx.replace(cand_tile=8))
    solve(inst, policy="dp", context=ctx.replace(numeric_policy="f64"))
    assert cache.stats()["hits"] == 3


def test_positional_get_put_defaults_match_default_context(rng):
    """Pre-protocol call sites (3-arg get/put) key as strict/None."""
    cache = SolveCache()
    inst = random_instance(rng, lo=2, hi=6)
    res = solve(inst, policy="dp", context=ExecutionContext(cache=cache))
    hit = cache.get(inst, "dp", "python")  # legacy positional form
    assert hit is not None and (hit.cost, hit.detours) == (res.cost, res.detours)
    assert cache.get(inst, "dp", "python", "f64") is None


# ---------------------------------------------------------------------------
# bounded LRU below the working set: slower, never different
# ---------------------------------------------------------------------------
def test_bounded_lru_below_working_set_is_bit_identical():
    """240-request constrained-pool trace, served twice through each cache:
    maxsize=4 thrashes (evictions force re-solves on the second pass) yet
    every schedule and timeline matches the unbounded run both times."""
    trace = build_trace(240)
    small = SolveCache(maxsize=4)
    big = SolveCache(maxsize=1 << 20)

    def run(cache):
        return _scrub_work(serve_trace(
            demo_library(SEED, with_cache=False), trace, "accumulate",
            window=400_000, policy="dp", n_drives=2, drive_costs=COSTS,
            context=ExecutionContext(cache=cache),
        ).summary())

    assert run(small) == run(big)  # first pass: cold caches
    assert small.stats()["entries"] == 4  # pinned at the bound
    assert big.stats()["entries"] > 4  # the true working set is larger
    assert run(small) == run(big)  # second pass: hits vs evictions
    # eviction forced strictly more solver work on the replay, and only that
    assert small.stats()["misses"] > big.stats()["misses"]
    assert big.stats()["hits"] > small.stats()["hits"]


# ---------------------------------------------------------------------------
# JSONL journal backend
# ---------------------------------------------------------------------------
def test_jsonl_backend_rewarms_across_restart(tmp_path, rng):
    path = tmp_path / "memo.jsonl"
    insts = [random_instance(rng, lo=2, hi=8) for _ in range(5)]
    first = JsonlCacheBackend(path)
    ctx = ExecutionContext(cache=first)
    originals = [solve(i, policy="dp", context=ctx) for i in insts]
    assert first.stats()["misses"] == 5 and first.stats()["loaded"] == 0
    first.close()

    second = JsonlCacheBackend(path)
    assert second.loaded == 5 and len(second) == 5
    replayed = [
        solve(i, policy="dp", context=ExecutionContext(cache=second))
        for i in insts
    ]
    assert second.stats()["hits"] == 5 and second.stats()["misses"] == 0
    assert [(r.cost, r.detours) for r in replayed] == [
        (r.cost, r.detours) for r in originals
    ]
    second.close()


def test_jsonl_backend_skips_torn_and_foreign_lines(tmp_path, rng):
    path = tmp_path / "memo.jsonl"
    inst = random_instance(rng, lo=2, hi=6)
    backend = JsonlCacheBackend(path)
    res = solve(inst, policy="dp", context=ExecutionContext(cache=backend))
    backend.close()
    with open(path, "a", encoding="utf-8") as fh:
        fh.write("not json at all\n")
        fh.write(json.dumps({"unrelated": True}) + "\n")
        fh.write('{"k": ["dp", "python"')  # torn mid-write
    reopened = JsonlCacheBackend(path)
    assert reopened.loaded == 1
    hit = reopened.get(inst, "dp", "python")
    assert hit is not None and (hit.cost, hit.detours) == (res.cost, res.detours)
    reopened.close()


def test_jsonl_backend_compact_and_clear(tmp_path, rng):
    path = tmp_path / "memo.jsonl"
    backend = JsonlCacheBackend(path, maxsize=3)
    ctx = ExecutionContext(cache=backend)
    insts = [random_instance(rng, lo=2, hi=6) for _ in range(6)]
    for i in insts:
        solve(i, policy="dp", context=ctx)
    assert len(backend) == 3  # LRU bound holds in memory
    assert sum(1 for _ in open(path)) == 6  # journal is append-only
    backend.compact()
    assert sum(1 for _ in open(path)) == 3  # rewritten to live entries
    # the three most-recent instances survive compaction as hits
    for i in insts[-3:]:
        assert backend.get(i, "dp", "python") is not None
    backend.clear()
    assert len(backend) == 0 and path.read_text() == ""
    backend.close()


def test_jsonl_backend_compact_survives_crash_midway(tmp_path, rng, monkeypatch):
    """A process killed mid-compaction must leave either the old journal or
    the new one — never a torn mix — and the backend must stay usable when
    the staging write itself fails."""
    import os as _os

    path = tmp_path / "memo.jsonl"
    backend = JsonlCacheBackend(path)
    ctx = ExecutionContext(cache=backend)
    insts = [random_instance(rng, lo=2, hi=6) for _ in range(4)]
    results = [solve(i, policy="dp", context=ctx) for i in insts]
    before = path.read_bytes()

    # kill the process at the atomic-rename instant: the staged temp file is
    # complete but never replaces the journal -> old journal intact
    def boom(*args, **kwargs):
        raise KeyboardInterrupt("killed mid-compact")

    monkeypatch.setattr(_os, "replace", boom)
    try:
        backend.compact()
    except KeyboardInterrupt:
        pass
    monkeypatch.undo()
    assert path.read_bytes() == before  # journal untouched by the crash
    # the backend reopened its append handle: still usable after the crash
    extra = random_instance(rng, lo=2, hi=6)
    solve(extra, policy="dp", context=ctx)
    backend.close()

    reopened = JsonlCacheBackend(path)
    assert reopened.loaded == len(insts) + 1
    for inst, res in zip(insts, results):
        hit = reopened.get(inst, "dp", "python")
        assert hit is not None and hit.cost == res.cost
    # a clean compaction after the crash converges the journal
    reopened.compact()
    assert sum(1 for _ in open(path)) == len(insts) + 1
    reopened.close()


def test_jsonl_backend_serves_trace_identically(tmp_path):
    """The persistent backend behind a serving run changes nothing but the
    journal on disk; a restarted run replays to pure memo hits."""
    trace = build_trace(120)
    path = tmp_path / "serve-memo.jsonl"

    def run(cache):
        return _scrub_work(serve_trace(
            demo_library(SEED, with_cache=False), trace, "accumulate",
            window=400_000, policy="dp",
            context=ExecutionContext(cache=cache),
        ).summary())

    journal = JsonlCacheBackend(path)
    with_journal = run(journal)
    journal.close()
    plain = run(SolveCache())
    assert with_journal == plain

    rewarmed = JsonlCacheBackend(path)
    assert rewarmed.loaded > 0
    assert run(rewarmed) == plain
    assert rewarmed.stats()["misses"] == 0  # every solve was a replayed hit
    rewarmed.close()


# ---------------------------------------------------------------------------
# protocol conformance
# ---------------------------------------------------------------------------
def test_shipped_backends_satisfy_protocol(tmp_path):
    assert isinstance(SolveCache(), CacheBackend)
    backend = JsonlCacheBackend(tmp_path / "p.jsonl")
    assert isinstance(backend, CacheBackend)
    backend.close()


# ---------------------------------------------------------------------------
# single-writer lockfile: concurrent appenders are refused, stale locks
# are taken over
# ---------------------------------------------------------------------------
def test_jsonl_backend_refuses_second_concurrent_writer(tmp_path):
    path = str(tmp_path / "memo.jsonl")
    first = JsonlCacheBackend(path)
    with pytest.raises(CacheLockedError) as exc:
        JsonlCacheBackend(path)
    assert exc.value.path == path
    assert exc.value.pid == os.getpid()
    # the refused constructor must not have stolen or removed the lock
    assert os.path.exists(path + ".lock")
    first.close()


def test_jsonl_backend_close_releases_the_lock(tmp_path, rng):
    path = str(tmp_path / "memo.jsonl")
    first = JsonlCacheBackend(path)
    inst = random_instance(rng, lo=2, hi=6)
    solve(inst, policy="dp", context=ExecutionContext(cache=first))
    first.close()
    assert not os.path.exists(path + ".lock")
    second = JsonlCacheBackend(path)  # reopen after close: allowed
    assert second.loaded == 1
    second.close()


def test_jsonl_backend_takes_over_stale_lock(tmp_path, monkeypatch):
    import repro.core.cache as cache_mod

    path = str(tmp_path / "memo.jsonl")
    # a lockfile whose owner pid is dead (monkeypatched probe — a real pid
    # could be recycled by the OS mid-test)
    (tmp_path / "memo.jsonl.lock").write_text("99999\n")
    monkeypatch.setattr(cache_mod, "_pid_alive", lambda pid: False)
    backend = JsonlCacheBackend(path)
    assert (tmp_path / "memo.jsonl.lock").read_text().strip() == str(os.getpid())
    backend.close()
    # a *live* foreign owner is refused
    (tmp_path / "memo.jsonl.lock").write_text("99999\n")
    monkeypatch.setattr(cache_mod, "_pid_alive", lambda pid: True)
    with pytest.raises(CacheLockedError) as exc:
        JsonlCacheBackend(path)
    assert exc.value.pid == 99999


def test_jsonl_backend_takes_over_corrupt_lock(tmp_path):
    path = str(tmp_path / "memo.jsonl")
    (tmp_path / "memo.jsonl.lock").write_text("not-a-pid\n")
    backend = JsonlCacheBackend(path)  # corrupt lockfile counts as stale
    assert (tmp_path / "memo.jsonl.lock").read_text().strip() == str(os.getpid())
    backend.close()


def test_warm_states_ride_the_backend():
    cache = SolveCache(warm_maxsize=2)
    cache.put_warm(("warm", "t1", "dp"), object())
    cache.put_warm(("warm", "t2", "dp"), object())
    s2 = cache.get_warm(("warm", "t2", "dp"))
    assert s2 is not None
    cache.put_warm(("warm", "t3", "dp"), object())  # evicts the LRU entry
    assert cache.get_warm(("warm", "t1", "dp")) is None
    assert cache.stats()["warm_entries"] == 2
    cache.clear()
    assert cache.get_warm(("warm", "t2", "dp")) is None
    assert cache.stats()["warm_entries"] == 0

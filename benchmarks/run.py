# One function per paper table. Print ``name,us_per_call,derived`` CSV.
"""Benchmark harness.

Paper artefacts reproduced (on the synthetic IN2P3-calibrated dataset):

  * ``bench_performance_profiles``  — Figures 14/15/16: performance profiles
    of all registered policies at U in {0, seg/2, seg}.
  * ``bench_time_to_solution``      — §5.3 running-time table.
  * ``bench_kernel_wavefront``      — wavefront DP device throughput (jnp ref
    jitted + the single-trace Pallas wavefront in interpret mode).
  * ``bench_solve_batch``           — padded multi-instance device launch vs
    per-instance python solving (parity-checked).
  * ``bench_hetero_batch``          — heterogeneous (mixed-size) batch: the
    seed's single maximally-padded launch vs the size-bucketed planner
    (bit-identical results, throughput A/B).
  * ``bench_policy_backends``       — per-policy, per-backend wall time and
    solve throughput matrix.
  * ``bench_tape_restore``          — system table: LTSP-scheduled checkpoint
    restore vs positional sweep (mean shard service time + solve-cache
    hit/miss counters).
  * ``bench_online_serving``        — online queue service: arrival-rate sweep
    of mean/p50/p95/p99 request sojourn per admission policy (fifo /
    accumulate / preempt) on a seeded trace, every emitted schedule re-scored
    by the discrete-event simulator oracle; asserts accumulate-then-solve
    beats per-request FIFO under load.  Plus the drive-pool sweep:
    drive-count x admission-policy (fifo-global / per-drive-accumulate /
    batched) with a nonzero mount/unmount/load-seek cost model, showing how
    mount contention degrades sojourn as the pool shrinks below
    one-drive-per-cartridge.  Plus the QoS sweep: deadline-tightness x
    admission miss-rate curves on a deadline/class-annotated trace
    (``repro.data.traces.qos_poisson_trace``) — asserts the deadline-aware
    admissions (``edf-global`` / ``slack-accumulate``) achieve strictly
    fewer deadline misses than ``fifo-global`` at every swept tightness
    (exact virtual-time ints) — and the mount-scheduler sweep
    (greedy / lru / lookahead) on the constrained pool.
  * ``bench_overload_serving``     — load-adaptive solver selection: arrival-
    rate sweep (light -> overloaded) under a priced ``ComputeBudget``, fixed
    dp/logdp1/nfgs arms vs the ``cost-model`` selector; asserts adaptation
    never misses more deadlines than the best fixed policy at any swept
    rate (exact virtual-time ints) and that the adaptive arm actually
    switches policy across the sweep.
  * ``bench_fleet_serving``        — fleet federation: shard-count x
    placement-strategy sweep on a replicated multi-library archive with one
    injected whole-shard outage; asserts ``replica-affinity`` routing
    strictly beats oblivious ``static-hash`` on deadline misses (served
    misses + dropped requests, exact virtual-time ints) at every swept
    cell.

All scheduling goes through the solver registry (``repro.core.solver``) under
an ``ExecutionContext``; every reported cost is re-validated against the
exact trajectory simulator.

Run: ``PYTHONPATH=src python -m benchmarks.run [--full]``

Recorded trajectory: ``--record [PATH]`` additionally writes a
machine-readable snapshot (default ``BENCH_pr2.json``) of every bench that
ran; ``--baseline PATH`` compares the fresh snapshot against a checked-in one
and exits nonzero if the interpret-backend bucketed solve throughput regressed
more than ``REGRESSION_TOLERANCE`` (runner-calibrated: measured as the speedup
over the padded arm of the same run) — CI runs the smoke profile of this as
the perf gate, so the perf trajectory of the repo is diffable PR over PR.
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import pathlib
import sys
import time

import numpy as np

RESULTS = pathlib.Path("results")

#: allowed fractional drop in recorded throughput before --baseline fails.
REGRESSION_TOLERANCE = 0.25

#: benches append {name: row} snapshots here; --record serialises it.
RECORD: dict = {}


#: set by ``--obs``: a repro.obs.MetricsRegistry every timed serving cell
#: feeds; ``--record`` then lands its snapshot as ``RECORD["obs_metrics"]``.
OBS_METRICS = None


def _emit(name: str, us_per_call: float, derived: str) -> None:
    print(f"{name},{us_per_call:.1f},{derived}")


def _timed_serve(label: str, run):
    """Run one timed serving cell: ``(report, summary, wall_s)``.

    The serving benches (online / overload / fleet) each repeated the same
    time-it / summarise block per swept cell; this is that block, shared.
    With ``--obs`` the cell also lands in the metrics registry as exact-int
    counters and a wall-time histogram (integer microseconds — the registry
    rejects floats by design).
    """
    t0 = time.perf_counter()
    report = run()
    dt = time.perf_counter() - t0
    s = report.summary()
    if OBS_METRICS is not None:
        OBS_METRICS.inc("bench_cells_total", bench=label)
        OBS_METRICS.inc(
            "bench_requests_served_total", int(report.n_served), bench=label
        )
        OBS_METRICS.observe("bench_wall_us", int(dt * 1e6), bench=label)
    return report, s, dt


def _timed_solve(solver, inst):
    """``(cost, detours, seconds)`` timing only schedule *construction*.

    Heuristic solvers score their detours with the exact simulator inside
    ``solve()``; the paper's running-time tables exclude evaluation, so time
    the raw detour computation and score outside the clock (DP solvers get
    their cost from the recurrence itself, i.e. for free).
    """
    from repro.core import evaluate_detours
    from repro.core.solver import HeuristicSolver

    if isinstance(solver, HeuristicSolver):
        t0 = time.perf_counter()
        detours = solver.fn(inst)
        dt = time.perf_counter() - t0
        return evaluate_detours(inst, detours), detours, dt
    t0 = time.perf_counter()
    res = solver.solve(inst)
    dt = time.perf_counter() - t0
    return res.cost, res.detours, dt


# ---------------------------------------------------------------------------
def bench_performance_profiles(full: bool = False):
    """Figures 14-16: fraction of instances within tau of optimal."""
    from repro.core import evaluate_detours, get_solver, list_solvers, lower_bound_gap
    from repro.data import BENCH_PROFILE, PAPER_PROFILE, generate_dataset, u_turn_values

    profile = PAPER_PROFILE if full else BENCH_PROFILE
    ds0 = generate_dataset(profile)
    u_vals = u_turn_values(ds0)
    taus = [0.001, 0.01, 0.025, 0.05, 0.10, 0.25]
    policies = list_solvers()
    out_rows = []
    for u_name, U in u_vals.items():
        import dataclasses

        ds = [dataclasses.replace(i, u_turn=U) for i in ds0]
        costs: dict[str, list[float]] = {a: [] for a in policies}
        gaps: dict[str, list[float]] = {a: [] for a in policies}
        t_algo: dict[str, float] = {a: 0.0 for a in policies}
        for inst in ds:
            per = {}
            for name in policies:
                cost, detours, dt = _timed_solve(get_solver(name), inst)
                t_algo[name] += dt
                assert cost == evaluate_detours(inst, detours), name
                per[name] = cost
                gaps[name].append(lower_bound_gap(inst, cost))
            opt = per["dp"]
            for name, c in per.items():
                costs[name].append(c / opt if opt else 1.0)
        for name in policies:
            ratios = np.array(costs[name])
            fracs = [(ratios <= 1 + tau).mean() for tau in taus]
            mean_gap = float(np.mean(gaps[name]))
            row = {
                "figure": f"perf_profile_U_{u_name}",
                "algorithm": name,
                "mean_ratio": float(ratios.mean()),
                "p95_ratio": float(np.quantile(ratios, 0.95)),
                "mean_lb_gap": mean_gap,
                **{f"within_{tau}": float(fr) for tau, fr in zip(taus, fracs)},
                "total_time_s": t_algo[name],
            }
            out_rows.append(row)
            _emit(
                f"profile/{u_name}/{name}",
                1e6 * t_algo[name] / len(ds),
                f"mean_ratio={ratios.mean():.4f};within_2.5%={fracs[2]:.2f};"
                f"lb_gap={mean_gap:.4f}",
            )
    RESULTS.mkdir(exist_ok=True)
    (RESULTS / "performance_profiles.json").write_text(json.dumps(out_rows, indent=1))
    return out_rows


#: What the paper's §5.3 running-time table establishes (qualitatively — the
#: absolute seconds are theirs, measured on their machine/dataset, and are
#: not restated here to avoid fabricating numbers): the list heuristics are
#: effectively instant, the restricted DPs (SIMPLEDP, LOGDP) stay within
#: interactive running times at full IN2P3 scale, and the exact DP is orders
#: of magnitude slower — minutes-plus per large tape — which is exactly why
#: the low-cost variants exist.  ``check_section_5_3`` verifies the measured
#: medians reproduce this class ordering.
PAPER_5_3_REFERENCE = {
    "source": "arXiv:2112.09384 §5.3 running-time comparison (IN2P3 dataset)",
    "classes": [
        {"name": "heuristics", "policies": ["nodetour", "gs", "fgs", "nfgs",
                                            "lognfgs5"]},
        {"name": "restricted-dp", "policies": ["simpledp", "logdp1", "logdp5"]},
        {"name": "exact-dp", "policies": ["dp"]},
    ],
    "expected": "median(heuristics) <= median(restricted-dp) << median(exact-dp)",
}

#: per-policy wall-time budget for the paper-scale (``--full``) §5.3 table;
#: a policy stops taking new (larger) instances once it has spent this much,
#: and the skipped strata are recorded as such — the exact DP needs hours on
#: the top strata of the 169-tape profile, which a snapshot run can't afford.
FULL_TIME_BUDGET_S = 300.0


def check_section_5_3(rows: list[dict]) -> dict:
    """Compare measured medians against the paper's §5.3 class ordering."""
    med = {r["algorithm"]: r["median_s"] for r in rows if r["median_s"] is not None}
    cls = {
        c["name"]: [med[p] for p in c["policies"] if p in med]
        for c in PAPER_5_3_REFERENCE["classes"]
    }
    cls_med = {k: float(np.median(v)) for k, v in cls.items() if v}
    if all(k in cls_med for k in ("heuristics", "restricted-dp", "exact-dp")):
        ordered = (
            cls_med["heuristics"]
            <= cls_med["restricted-dp"]
            <= cls_med["exact-dp"]
        )
    else:
        ordered = None  # a class has no completed strata: unknown, not "true"
    return {
        "reference": PAPER_5_3_REFERENCE,
        "class_median_s": cls_med,
        "ordering_consistent_with_paper": ordered,
        "dp_vs_heuristic_ratio": (
            cls_med["exact-dp"] / max(cls_med["heuristics"], 1e-9)
            if "exact-dp" in cls_med and "heuristics" in cls_med
            else None
        ),
    }


def bench_time_to_solution(full: bool = False):
    """§5.3 running-time comparison (median seconds per instance).

    Smoke mode keeps the historical CI behaviour: the first 20 bench-profile
    instances, every policy.  ``--full`` is the paper-scale artefact: a
    stratified sample of the 169-tape IN2P3-calibrated profile (one instance
    per ``n_req`` quantile) with a per-policy wall-time budget
    (:data:`FULL_TIME_BUDGET_S`) — policies run their strata smallest-first
    and stop when the budget is spent, so the exact DP reports honest medians
    over the strata it completed instead of hanging the run for hours.  The
    snapshot's summary block (``section_5_3``) compares the measured class
    ordering against the paper's table.
    """
    from repro.core import get_solver, list_solvers
    from repro.data import BENCH_PROFILE, PAPER_PROFILE, generate_dataset

    if full:
        ds_all = sorted(generate_dataset(PAPER_PROFILE), key=lambda i: i.n_req)
        qs = [0.0, 0.25, 0.5, 0.75, 0.9, 1.0]
        idx = sorted({int(q * (len(ds_all) - 1)) for q in qs})
        ds = [ds_all[i] for i in idx]
        budget = FULL_TIME_BUDGET_S
    else:
        ds = generate_dataset(BENCH_PROFILE)[:20]
        budget = float("inf")
    rows = []
    for name in list_solvers():
        ts: list[float] = []
        per_inst: list[dict] = []
        spent = 0.0
        prev: tuple[float, int, int] | None = None  # (seconds, n_req, n)
        for inst in ds:  # ascending n_req in full mode: small strata first
            if spent > budget:
                per_inst.append({"n_req": inst.n_req, "seconds": None,
                                 "skipped": "budget"})
                continue
            if prev is not None:
                # DP-family work scales ~ R^2 * S; refuse to *start* a stratum
                # the extrapolated cost of which blows the budget
                dt0, R0, n0 = prev
                predicted = dt0 * (inst.n_req / R0) ** 2 * (inst.n / max(n0, 1))
                if predicted > 1.0 and spent + predicted > budget:
                    per_inst.append({"n_req": inst.n_req, "seconds": None,
                                     "skipped": "budget-predicted"})
                    continue
            _, _, dt = _timed_solve(get_solver(name), inst)
            ts.append(dt)
            spent += dt
            prev = (dt, inst.n_req, inst.n)
            per_inst.append({"n_req": inst.n_req, "seconds": dt})
        med = float(np.median(ts)) if ts else None
        row = {"algorithm": name, "median_s": med,
               "max_s": float(max(ts)) if ts else None,
               "n_completed": len(ts), "n_instances": len(ds)}
        if full:
            row["per_instance"] = per_inst
        rows.append(row)
        _emit(
            f"time_to_solution/{name}",
            (med or 0.0) * 1e6,
            f"max_s={row['max_s']:.3f};completed={len(ts)}/{len(ds)}"
            if ts else "completed=0",
        )
    out: dict = {"rows": rows, "profile": "paper" if full else "bench"}
    if full:
        out["section_5_3"] = check_section_5_3(rows)
        ratio = out["section_5_3"]["dp_vs_heuristic_ratio"]
        _emit(
            "time_to_solution/section_5_3",
            0.0,
            f"ordering_consistent={out['section_5_3']['ordering_consistent_with_paper']};"
            f"dp_vs_heuristic_ratio={f'{ratio:.3g}' if ratio is not None else 'n/a'}",
        )
    (RESULTS / "time_to_solution.json").write_text(json.dumps(out, indent=1))
    RECORD["time_to_solution"] = out
    return rows


def _small_bench_instance(rng, R):
    from repro.core import make_instance

    sizes = rng.integers(1, 9, size=R)
    gaps = rng.integers(0, 6, size=R + 1)
    left, pos = [], int(gaps[0])
    for i in range(R):
        left.append(pos)
        pos += int(sizes[i] + gaps[i + 1])
    return make_instance(left, sizes, rng.integers(1, 4, size=R), m=pos, u_turn=3)


def bench_kernel_wavefront(full: bool = False):
    """Wavefront DP device throughput: jnp reference (jitted) and the
    single-trace Pallas wavefront (interpret mode is correctness-only on
    CPU, so its time measures one full table build, not TPU speed)."""
    import jax
    import jax.numpy as jnp

    from repro.kernels.ltsp_dp.ltsp_dp import ltsp_dp_tables
    from repro.kernels.ltsp_dp.ops import prepare_arrays
    from repro.kernels.ltsp_dp.ref import ltsp_dp_table_ref

    rng = np.random.default_rng(0)
    R = 24 if not full else 48
    inst = _small_bench_instance(rng, R)
    l, r, x, nl, S = prepare_arrays(inst)

    fn = jax.jit(lambda: ltsp_dp_table_ref(l, r, x, nl, float(inst.u_turn), S))
    fn()  # compile
    t0 = time.perf_counter()
    n_rep = 3
    for _ in range(n_rep):
        fn().block_until_ready()
    dt = (time.perf_counter() - t0) / n_rep
    cells = R * R * S / 2
    _emit("kernel/wavefront_ref", dt * 1e6, f"R={R};S={S};cells_per_s={cells/dt:.3g}")

    u = jnp.asarray([float(inst.u_turn)], l.dtype)
    pf = lambda: ltsp_dp_tables(l[None], r[None], x[None], nl[None], u, S=S)
    T, _ = pf()  # compile (single trace: one retrace total, not R)
    t0 = time.perf_counter()
    T, C = pf()
    jax.block_until_ready((T, C))
    dt_p = time.perf_counter() - t0
    _emit(
        "kernel/wavefront_pallas_interpret",
        dt_p * 1e6,
        f"R={R};S={S};cells_per_s={cells/dt_p:.3g}",
    )
    row = {"R": R, "S": S, "seconds_ref": dt, "seconds_pallas": dt_p,
           "cells_per_s_ref": cells / dt}
    RECORD["kernel_wavefront"] = row
    return row


def bench_solve_batch(full: bool = False):
    """Bucketed multi-instance device launches vs per-instance python DP."""
    from repro.core import ExecutionContext, solve, solve_batch
    from repro.kernels.ltsp_dp.ops import plan_buckets, rescale_instance

    rng = np.random.default_rng(11)
    B = 8 if not full else 16
    insts = [_small_bench_instance(rng, int(rng.integers(6, 14))) for _ in range(B)]
    n_launches = len(plan_buckets([rescale_instance(i)[0] for i in insts]))
    dev_ctx = ExecutionContext(backend="pallas-interpret")

    t0 = time.perf_counter()
    py = [solve(i, policy="dp") for i in insts]
    dt_py = time.perf_counter() - t0

    solve_batch(insts, policy="dp", context=dev_ctx)  # compile
    t0 = time.perf_counter()
    dev = solve_batch(insts, policy="dp", context=dev_ctx)
    dt_dev = time.perf_counter() - t0

    assert [r.cost for r in py] == [r.cost for r in dev], "batch parity violated"
    _emit("solver/batch_python", dt_py * 1e6 / B, f"B={B}")
    _emit(
        "solver/batch_pallas_interpret",
        dt_dev * 1e6 / B,
        f"B={B};launches={n_launches}",
    )
    row = {"B": B, "launches": n_launches,
           "seconds_python": dt_py, "seconds_device": dt_dev}
    RECORD["solve_batch"] = row
    return row


def _hetero_instances(rng, full: bool = False):
    """Mixed-size cartridge batch: mostly small tapes plus a few wide ones
    (the IN2P3 shape — a global pad wastes most of its lanes)."""
    n_small = 8 if not full else 16
    n_wide = 4 if not full else 8
    insts = [_small_bench_instance(rng, int(rng.integers(3, 8)))
             for _ in range(n_small)]
    for _ in range(n_wide):
        insts.append(_small_bench_instance(rng, int(rng.integers(18, 27))))
    # bump a couple of multiplicities so the wide tapes cross the 128-lane
    # S boundary and land in a different (R, S) bucket
    import dataclasses
    for i in range(n_small, n_small + 2):
        mult = insts[i].mult.copy()
        mult[::2] += 9
        insts[i] = dataclasses.replace(insts[i], mult=mult)
    order = rng.permutation(len(insts))
    return [insts[i] for i in order]


def _median_time(fn, n_rep: int = 3) -> float:
    ts = []
    for _ in range(n_rep):
        t0 = time.perf_counter()
        fn()
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def bench_hetero_batch(full: bool = False):
    """Heterogeneous batch: seed-style global padding vs the bucket planner.

    Both paths are the same interpret-mode wavefront; only the launch shapes
    differ.  Results must be bit-identical to per-instance device solving
    (cost *and* detours) — the planner is a pure scheduling optimisation.
    """
    from repro.core import dp_schedule, evaluate_detours
    from repro.kernels.ltsp_dp.ops import (
        ltsp_solve_batch, ltsp_solve_instance, plan_buckets, rescale_instance,
    )

    rng = np.random.default_rng(20260731)
    insts = _hetero_instances(rng, full)
    B = len(insts)
    buckets = plan_buckets([rescale_instance(i)[0] for i in insts])

    padded = ltsp_solve_batch(insts, bucketed=False)  # compile
    bucketed = ltsp_solve_batch(insts, bucketed=True)  # compile (per bucket)
    assert padded == bucketed, "bucketing changed results"
    for inst, (cost, dets) in zip(insts, bucketed):
        assert (cost, dets) == ltsp_solve_instance(inst), "batch != per-instance"
        assert cost == dp_schedule(inst)[0] == evaluate_detours(inst, dets)

    dt_pad = _median_time(lambda: ltsp_solve_batch(insts, bucketed=False))
    dt_buck = _median_time(lambda: ltsp_solve_batch(insts, bucketed=True))
    speedup = dt_pad / dt_buck
    _emit("solver/hetero_padded", dt_pad * 1e6 / B, f"B={B};R_max={max(i.n_req for i in insts)}")
    _emit(
        "solver/hetero_bucketed",
        dt_buck * 1e6 / B,
        f"B={B};buckets={len(buckets)};speedup={speedup:.2f}x",
    )
    row = {
        "backend": "pallas-interpret",
        "B": B,
        "profile": "full" if full else "smoke",
        "buckets": [[r, s, len(idx)] for (r, s), idx in sorted(buckets.items())],
        "padded": {"seconds": dt_pad, "instances_per_s": B / dt_pad},
        "bucketed": {"seconds": dt_buck, "instances_per_s": B / dt_buck},
        "speedup": speedup,
        "parity": True,
    }
    RECORD["hetero_batch"] = row
    return row


def bench_policy_backends(full: bool = False):
    """Per-policy, per-backend wall time + solve throughput matrix.

    Python rows run the full bench dataset slice; device rows run the
    heterogeneous small-tape set (interpret mode emulates the kernel on CPU,
    so paper-scale instances would measure the emulator, not the policy).
    """
    from repro.core import ExecutionContext, evaluate_detours, get_solver
    from repro.core.solver import list_solvers
    from repro.data import BENCH_PROFILE, generate_dataset

    ds_py = generate_dataset(BENCH_PROFILE)[: 12 if not full else 30]
    rng = np.random.default_rng(5)
    ds_dev = _hetero_instances(rng)[:6]
    rows = []
    for name in list_solvers():
        solver = get_solver(name)
        for backend in solver.backends:
            if backend == "pallas":  # compiled TPU: not available in CI
                continue
            ctx = ExecutionContext(backend=backend)
            ds = ds_py if backend == "python" else ds_dev
            if backend != "python":
                solver.solve_batch(ds, ctx)  # compile outside the clock
            t0 = time.perf_counter()
            results = solver.solve_batch(ds, ctx)
            dt = time.perf_counter() - t0
            for inst, res in zip(ds, results):
                assert res.cost == evaluate_detours(inst, res.detours), name
            rows.append({
                "policy": name,
                "backend": backend,
                "n_instances": len(ds),
                "seconds_total": dt,
                "seconds_per_instance": dt / len(ds),
                "solves_per_s": len(ds) / dt,
            })
            _emit(
                f"policy_backend/{name}/{backend}",
                dt * 1e6 / len(ds),
                f"n={len(ds)};solves_per_s={len(ds) / dt:.3g}",
            )
    RECORD["policy_backends"] = rows
    return rows


def bench_tape_restore(full: bool = False):
    """System table: checkpoint-restore mean service time by scheduler.

    The library context carries a solve-memo cache; each policy is planned
    twice and the warm re-plan (what a recovering fleet's next cold start
    pays) plus the cache hit/miss counters land in the summary.
    """
    from repro.core import ExecutionContext, SolveCache
    from repro.distributed.checkpoint import plan_restore
    from repro.storage.tape import TapeLibrary

    rng = np.random.default_rng(7)
    lib = TapeLibrary(
        capacity_per_tape=2 * 10**9, u_turn=10_000_000,
        context=ExecutionContext(cache=SolveCache()),
    )
    shards = []
    for i in range(60):
        name = f"ckpt/shard{i:03d}"
        lib.store(name, int(rng.integers(5_000_000, 120_000_000)))
        shards.append(name)
    consumers = {s: int(rng.integers(1, 9)) for s in shards}
    rows = []
    base = None
    for policy in ("nodetour", "gs", "fgs", "nfgs", "simpledp", "logdp1", "dp"):
        t0 = time.perf_counter()
        plans = plan_restore(lib, shards, consumers, policy=policy)
        dt = time.perf_counter() - t0
        t0 = time.perf_counter()
        replans = plan_restore(lib, shards, consumers, policy=policy)
        dt_warm = time.perf_counter() - t0
        assert [p.total_cost for p in plans] == [p.total_cost for p in replans]
        mean = sum(p.total_cost for p in plans) / sum(consumers.values())
        base = base or mean
        rows.append({
            "policy": policy, "mean_service": mean,
            "plan_s": dt, "replan_s": dt_warm,
        })
        _emit(
            f"tape_restore/{policy}",
            dt * 1e6,
            f"mean_service={mean:.3g};vs_nodetour={mean/base:.3f};"
            f"replan_us={dt_warm*1e6:.0f}",
        )
    stats = lib.cache.stats()
    _emit(
        "tape_restore/cache",
        0.0,
        f"hits={stats['hits']};misses={stats['misses']};entries={stats['entries']}",
    )
    (RESULTS / "tape_restore.json").write_text(
        json.dumps({"rows": rows, "cache": stats}, indent=1)
    )
    RECORD["tape_restore"] = {"rows": rows, "cache": stats}
    return rows


def bench_online_serving(full: bool = False):
    """Online tape-serving tables: admission x arrival rate, then the
    drive-pool sweep (drive count x admission x mount cost model).

    A seeded Poisson-like trace (>= 200 requests, >= 4 cartridges) is served
    through the queue service at several mean inter-arrival times; each cell
    reports the exact per-request sojourn distribution (the service time
    users experience) and the number of LTSP solves.  The discrete-event
    simulator independently re-scores every emitted schedule
    (``all_verified``), and the accumulate-then-solve admission must beat
    per-request FIFO at every swept rate — the online claim of the paper's
    objective, asserted on virtual time (no wall clocks).

    The warm-vs-cold sweep then re-serves each rate with ``warm_start``
    on and off: schedules must be bit-identical (warm start only changes
    how much DP work a re-solve performs), ``preempt`` must evaluate
    strictly fewer cells warm at every rate, and the loaded regime must
    show >= 30% fewer per-tick DP cells — the exact integer cell counts
    land in the record and are gated by ``--baseline``.

    The drive-pool sweep then prices the robotic-arm layer: ``n_drives`` in
    {1, 2, n_tapes} under a nonzero mount/unmount/load-seek model for each
    cross-cartridge admission (``fifo-global`` / ``per-drive-accumulate`` /
    ``batched``); ``batched`` must schedule bit-identically to
    ``per-drive-accumulate`` (it only changes how solves are batched onto
    the device), and the dedicated pool must serve no worse than the
    single-drive pool under every batching admission.

    The QoS sweep replays one deadline/class-annotated trace per swept
    tightness (same arrival process at every tightness — only the deadline
    pressure changes) through ``fifo-global`` and the deadline-aware
    admissions, recording per-admission miss-rate curves and per-class SLO
    summaries; the deadline-aware admissions must achieve *strictly fewer*
    misses than ``fifo-global`` at every tightness, asserted on exact
    integer virtual time.  The mount-scheduler sweep then runs the
    constrained pool under each registered eviction policy.

    The availability sweep prices the fault layer: recorded drive hard-
    failures (0/1/2 of a 3-drive pool, failure instants derived from the
    no-fault run so the first failure is guaranteed to abort live work)
    crossed with the retry policy (``FAIL_STOP`` vs retry+failover),
    reporting completion rate and p99 sojourn per cell; retry+failover must
    complete strictly more requests than fail-stop at every nonzero failure
    count, asserted on exact request counts.
    """
    from repro.data.traces import DEFAULT_QOS_CLASSES, qos_poisson_trace, to_requests
    from repro.serving.drives import DriveCosts
    from repro.serving.qos import slo_report
    from repro.serving.queue import (
        LEGACY_ADMISSIONS,
        POOL_ADMISSIONS,
        QOS_ADMISSIONS,
        WINDOWED_ADMISSIONS,
        serve_trace,
    )
    from repro.serving.sim import demo_library, poisson_trace

    seed = 20260731
    n_requests = 240 if not full else 600
    n_files = 48 if not full else 96

    def build_library():
        return demo_library(seed, n_files=n_files)

    n_tapes = len(build_library().tapes)
    assert n_tapes >= 4, "sweep needs a multi-cartridge library"
    rows = []
    window = 400_000
    for rate in (100_000, 400_000, 1_600_000):
        trace = poisson_trace(
            build_library(), n_requests=n_requests, mean_interarrival=rate, seed=seed
        )
        per_admission: dict[str, float] = {}
        for admission in LEGACY_ADMISSIONS:
            lib = build_library()
            # verify=True inside summary(): the oracle raised on any lie
            report, s, dt = _timed_serve("online", lambda: serve_trace(
                lib,
                trace,
                admission,
                window=window if admission == "accumulate" else 0,
                policy="dp",
                context=lib.context,
            ))
            assert s["n_served"] == n_requests
            per_admission[admission] = s["mean_sojourn"]
            rows.append({"rate": rate, "wall_s": dt, **s})
            _emit(
                f"online/{admission}/rate_{rate}",
                dt * 1e6,
                f"mean_sojourn={s['mean_sojourn']:.4g};"
                f"p50={s['p50_sojourn']:.4g};p95={s['p95_sojourn']:.4g};"
                f"p99={s['p99_sojourn']:.4g};batches={s['n_batches']};"
                f"preempts={s['n_preemptions']};"
                f"cells={s['cells_evaluated']};reused={s['cells_reused']};"
                f"cache_hits={s.get('cache', {}).get('hits', 0)}",
            )
        assert per_admission["accumulate"] < per_admission["fifo"], (
            f"accumulate-then-solve must beat FIFO at rate {rate}"
        )

    # -- warm-vs-cold sweep: per-tick DP work saved by incremental re-solve --
    # Both arms run the same solve_warm plumbing (so counters compare like
    # for like); only warm_start differs.  Schedules must be bit-identical
    # at every swept rate — warm start is a work optimisation, never a
    # scheduling change — and the cells-evaluated reduction is asserted
    # where re-solving dominates: `preempt` re-solves the surviving multiset
    # on every arrival, so reuse must strictly win at every rate and cut
    # >= 30% of the per-tick DP cells in the most-loaded regime.
    def _schedule_keys(s):
        return {
            k: v for k, v in s.items()
            if k not in ("warm_start", "cells_evaluated", "cells_reused",
                         "cells_per_batch", "cache")
        }

    warm_rows = []
    warm_cells: dict[tuple[str, int], dict] = {}
    rates = (100_000, 400_000, 1_600_000)
    loaded_rate = min(rates)  # smallest inter-arrival gap = highest load
    for rate in rates:
        trace = poisson_trace(
            build_library(), n_requests=n_requests, mean_interarrival=rate, seed=seed
        )
        for admission in ("accumulate", "preempt"):
            per_mode = {}
            for warm_start in (True, False):
                lib = build_library()
                report, s, dt = _timed_serve("online/warm", lambda: serve_trace(
                    lib, trace, admission,
                    window=window if admission == "accumulate" else 0,
                    policy="dp", context=lib.context, warm_start=warm_start,
                ))
                assert s["n_served"] == n_requests and s["all_verified"]
                per_mode[warm_start] = s
                warm_rows.append({"rate": rate, "wall_s": dt, **s})
            warm_s, cold_s = per_mode[True], per_mode[False]
            assert _schedule_keys(warm_s) == _schedule_keys(cold_s), (
                f"warm start changed a schedule: {admission} at rate {rate}"
            )
            assert cold_s["cells_reused"] == 0, "cold runs must not reuse"
            assert warm_s["cells_evaluated"] <= cold_s["cells_evaluated"]
            if admission == "preempt":
                # recorded assertion: strictly fewer cells at EVERY rate
                assert warm_s["cells_evaluated"] < cold_s["cells_evaluated"], (
                    f"warm start must strictly reduce DP work at rate {rate}"
                )
            reduction = (
                1.0 - warm_s["cells_evaluated"] / cold_s["cells_evaluated"]
                if cold_s["cells_evaluated"] else 0.0
            )
            warm_cells[(admission, rate)] = {
                "admission": admission,
                "rate": rate,
                "warm_cells": warm_s["cells_evaluated"],
                "cold_cells": cold_s["cells_evaluated"],
                "cells_reused": warm_s["cells_reused"],
                "n_batches": warm_s["n_batches"],
                "warm_cells_per_batch": warm_s["cells_per_batch"],
                "cold_cells_per_batch": cold_s["cells_per_batch"],
                "reduction": reduction,
            }
            _emit(
                f"online/warm/{admission}/rate_{rate}",
                0.0,
                f"cells_warm={warm_s['cells_evaluated']};"
                f"cells_cold={cold_s['cells_evaluated']};"
                f"reused={warm_s['cells_reused']};"
                f"reduction={reduction:.1%};batches={warm_s['n_batches']}",
            )
    headline = warm_cells[("preempt", loaded_rate)]
    assert headline["reduction"] >= 0.30, (
        f"warm start must cut >= 30% of per-tick DP cells in the loaded "
        f"regime (rate={loaded_rate}); measured {headline['reduction']:.1%}"
    )

    # -- drive-pool sweep: contention under an explicit mount cost model -----
    costs = DriveCosts(mount=150_000, unmount=60_000, load_seek=30_000)
    rate = 100_000  # the loaded regime, where drive contention binds
    trace = poisson_trace(
        build_library(), n_requests=n_requests, mean_interarrival=rate, seed=seed
    )
    pool_rows = []
    per_cell: dict[tuple[str, int], float] = {}
    for admission in POOL_ADMISSIONS:
        for n_drives in (1, 2, n_tapes):
            lib = build_library()
            report, s, dt = _timed_serve("online/pool", lambda: serve_trace(
                lib,
                trace,
                admission,
                window=window,
                policy="dp",
                n_drives=n_drives,
                drive_costs=costs,
                context=lib.context,
            ))
            assert s["n_served"] == n_requests and s["all_verified"]
            per_cell[(admission, n_drives)] = s["mean_sojourn"]
            pool_rows.append({"rate": rate, "wall_s": dt, **s})
            _emit(
                f"online/pool/{admission}/drives_{n_drives}",
                dt * 1e6,
                f"mean_sojourn={s['mean_sojourn']:.4g};"
                f"p50={s['p50_sojourn']:.4g};p95={s['p95_sojourn']:.4g};"
                f"p99={s['p99_sojourn']:.4g};batches={s['n_batches']};"
                f"mounts={s['mounts']};unmounts={s['unmounts']}",
            )
    for n_drives in (1, 2, n_tapes):
        # batched == per-drive-accumulate scheduling (one launch per tick is
        # a solve-batching change, not a scheduling change)
        assert per_cell[("batched", n_drives)] == per_cell[
            ("per-drive-accumulate", n_drives)
        ], n_drives
    for admission in ("per-drive-accumulate", "batched"):
        assert per_cell[(admission, n_tapes)] <= per_cell[(admission, 1)], (
            f"{admission}: a dedicated pool must serve no worse than one drive"
        )

    # -- QoS sweep: deadline tightness x admission, miss-rate curves ---------
    qos_rate = 250_000
    qos_admissions = ("fifo-global",) + QOS_ADMISSIONS + ("per-drive-accumulate",)
    tightness_sweep = (2_000_000, 8_000_000, 32_000_000)
    qos_rows = []
    for tightness in tightness_sweep:
        records = qos_poisson_trace(
            build_library(), n_requests=n_requests, mean_interarrival=qos_rate,
            seed=seed, tightness=tightness,
        )
        qtrace, qos = to_requests(records, build_library())
        missed: dict[str, int] = {}
        for admission in qos_admissions:
            lib = build_library()
            report, s, dt = _timed_serve("online/qos", lambda: serve_trace(
                lib,
                qtrace,
                admission,
                window=window if admission in WINDOWED_ADMISSIONS else 0,
                policy="dp",
                qos=qos,
                context=lib.context,
            ))
            assert s["n_served"] == n_requests and s["all_verified"]
            missed[admission] = report.n_missed  # exact virtual-time int
            qos_rows.append({
                "tightness": tightness, "wall_s": dt, **s,
                "slo": slo_report(report).summary(),
            })
            _emit(
                f"online/qos/{admission}/tight_{tightness}",
                dt * 1e6,
                f"missed={s['n_missed']}/{s['n_deadlines']};"
                f"miss_rate={s['miss_rate']:.3f};"
                f"p50={s['p50_sojourn']:.4g};p99={s['p99_sojourn']:.4g}",
            )
        for admission in QOS_ADMISSIONS:
            assert missed[admission] < missed["fifo-global"], (
                f"{admission} must achieve strictly fewer deadline misses "
                f"than fifo-global at tightness {tightness} "
                f"({missed[admission]} vs {missed['fifo-global']})"
            )

    # -- mount-scheduler sweep on the constrained pool -----------------------
    records = qos_poisson_trace(
        build_library(), n_requests=n_requests, mean_interarrival=qos_rate,
        seed=seed, tightness=8_000_000,
    )
    qtrace, qos = to_requests(records, build_library())
    sched_rows = []
    for admission in ("per-drive-accumulate", "slack-accumulate"):
        for sched in ("greedy", "lru", "lookahead"):
            lib = build_library()
            report, s, dt = _timed_serve("online/sched", lambda: serve_trace(
                lib, qtrace, admission, window=window, policy="dp",
                n_drives=2, drive_costs=costs, qos=qos,
                mount_scheduler=sched, context=lib.context,
            ))
            assert s["n_served"] == n_requests and s["all_verified"]
            sched_rows.append({"wall_s": dt, **s})
            _emit(
                f"online/sched/{admission}/{sched}",
                dt * 1e6,
                f"mean_sojourn={s['mean_sojourn']:.4g};"
                f"missed={s['n_missed']}/{s['n_deadlines']};"
                f"mounts={s['mounts']};mount_time={s['mount_time']}",
            )

    # -- availability sweep: recorded drive failures x retry policy ----------
    from repro.serving.drives import FAIL_STOP, RetryPolicy
    from repro.serving.faults import DriveFailure, FaultPlan

    avail_drives = 3
    avail_rate = 100_000
    trace = poisson_trace(
        build_library(), n_requests=n_requests, mean_interarrival=avail_rate,
        seed=seed,
    )
    lib = build_library()
    base = serve_trace(
        lib, trace, "per-drive-accumulate", window=window, policy="dp",
        n_drives=avail_drives, drive_costs=costs, context=lib.context,
    )
    # failure instants come from the no-fault run: one virtual tick after a
    # mid-trace batch starts service every request aboard is still pending,
    # and the pre-failure prefix is shared by construction, so the first
    # failure is guaranteed to abort live work in both policy arms
    mid = sorted(
        (b for b in base.batches if b.n_requests >= 2),
        key=lambda b: b.dispatched,
    )
    mid = mid[len(mid) // 2:]
    first = mid[0]
    second = next(
        b for b in mid + list(base.batches) if b.drive != first.drive
    )
    fail_points = (
        DriveFailure(at=first.dispatched + first.mount_delay + 1,
                     drive=first.drive),
        DriveFailure(at=second.dispatched + second.mount_delay + 1,
                     drive=second.drive),
    )
    retry_arms = {
        "fail-stop": FAIL_STOP,
        "retry-failover": RetryPolicy(on_exhausted="drop"),
    }
    avail_rows = []
    n_completed: dict[tuple[str, int], int] = {}
    for n_failures in (0, 1, 2):
        plan = FaultPlan(drive_failures=fail_points[:n_failures])
        for arm, retry in retry_arms.items():
            lib = build_library()
            report, s, dt = _timed_serve("online/avail", lambda: serve_trace(
                lib, trace, "per-drive-accumulate", window=window,
                policy="dp", n_drives=avail_drives, drive_costs=costs,
                context=lib.context, faults=plan or None, retry=retry,
            ))
            assert report.n_served + report.n_failed == n_requests, (
                "requests must be conserved: served or typed-failed"
            )
            n_completed[(arm, n_failures)] = report.n_served
            avail_rows.append({
                "arm": arm, "n_failures": n_failures, "wall_s": dt, **s,
            })
            _emit(
                f"online/avail/{arm}/failures_{n_failures}",
                dt * 1e6,
                f"completed={report.n_served}/{n_requests};"
                f"rate={report.completion_rate:.3f};"
                f"p99={s['p99_sojourn']:.4g};"
                f"requeued={s.get('faults', {}).get('requeued', 0)}",
            )
    assert (
        n_completed[("fail-stop", 0)]
        == n_completed[("retry-failover", 0)]
        == n_requests
    ), "with no failures both arms must complete everything"
    for n_failures in (1, 2):
        assert (
            n_completed[("retry-failover", n_failures)]
            > n_completed[("fail-stop", n_failures)]
        ), (
            f"retry+failover must complete strictly more requests than "
            f"fail-stop at {n_failures} drive failure(s): "
            f"{n_completed[('retry-failover', n_failures)]} vs "
            f"{n_completed[('fail-stop', n_failures)]}"
        )

    (RESULTS / "online_serving.json").write_text(
        json.dumps(
            rows + warm_rows + pool_rows + qos_rows + sched_rows + avail_rows,
            indent=1,
        )
    )
    RECORD["online_serving"] = {
        "seed": seed,
        "n_requests": n_requests,
        "n_tapes": n_tapes,
        "window": window,
        "rows": rows,
        "warm_sweep": {
            "rates": list(rates),
            "loaded_rate": loaded_rate,
            "headline": headline,
            "cells": list(warm_cells.values()),
            "rows": warm_rows,
        },
        "drive_sweep": {
            "costs": dataclasses.asdict(costs),
            "rate": rate,
            "rows": pool_rows,
        },
        "qos_sweep": {
            "rate": qos_rate,
            "tightness": list(tightness_sweep),
            "classes": [list(c) for c in DEFAULT_QOS_CLASSES],
            "rows": qos_rows,
        },
        "scheduler_sweep": {
            "costs": dataclasses.asdict(costs),
            "n_drives": 2,
            "tightness": 8_000_000,
            "rows": sched_rows,
        },
        "availability_sweep": {
            "costs": dataclasses.asdict(costs),
            "n_drives": avail_drives,
            "rate": avail_rate,
            "fail_points": [
                {"at": f.at, "drive": f.drive} for f in fail_points
            ],
            "completed": {
                f"{arm}/{n}": v for (arm, n), v in sorted(n_completed.items())
            },
            "rows": avail_rows,
        },
    }
    return rows + pool_rows + qos_rows + sched_rows + avail_rows


def bench_overload_serving(full: bool = False):
    """Overload sweep: load-adaptive solver selection vs every fixed policy.

    One seeded deadline-annotated trace per swept mean inter-arrival time
    (light -> overloaded) is served on a constrained 2-drive pool with a
    nonzero :class:`~repro.serving.drives.DriveCosts` model and a *priced*
    :class:`~repro.core.ComputeBudget`: every DP cell evaluated by a solve
    costs ``solve_time_num`` virtual-time units, so the exact DP's optimality
    is no longer free — under load its solve latency eats the very slack it
    optimises.  Four arms run on identical traces: three fixed policies
    (``dp`` / ``logdp1`` / ``nfgs``, pinned via the ``fixed`` selector so
    per-batch policy attribution lands in the record) and the ``cost-model``
    adaptive selector, which predicts per-policy solve cost from queue depth
    and the recorded per-tick timings and picks the strongest tier that fits
    ``per_tick``.

    Recorded assertion (exact integer virtual time, machine-independent):
    at *every* swept rate the adaptive arm misses no more deadlines than the
    best fixed policy at that rate — adaptation never costs you vs the best
    static choice, even though which fixed policy is best flips across the
    sweep (``dp`` wins light, ``nfgs`` wins loaded).  The adaptive arm must
    also actually adapt: its per-batch policy mix spans >= 2 policies across
    the sweep.  Solves run cold (``warm_start=False``): overload pressure
    comes from full re-solves, and pricing identical cold solves keeps the
    fixed arms like-for-like.  The workload is pinned (``--full`` does not
    widen it): the never-worse bound is a *recorded* property of this seeded
    trace + budget — the cost model carries no optimality guarantee, so the
    assertion documents a calibrated operating point, not a theorem over
    arbitrary workloads.
    """
    from repro.data.traces import qos_poisson_trace, to_requests
    from repro.core import ComputeBudget
    from repro.serving.drives import DriveCosts
    from repro.serving.queue import serve_trace
    from repro.serving.sim import demo_library

    del full  # recorded assertion — workload pinned to the calibrated trace
    seed = 20260731
    n_requests = 240
    n_files = 48

    def build_library():
        return demo_library(seed, n_files=n_files)

    window = 400_000
    tightness = 8_000_000
    costs = DriveCosts(mount=150_000, unmount=60_000, load_seek=30_000)
    rates = (400_000, 200_000, 60_000, 25_000)  # mean inter-arrival: light -> overloaded
    fixed_arms = ("dp", "logdp1", "nfgs")
    # calibrated on the seeded trace: at 10_000 units/cell the exact DP's
    # solve delay dominates under load; per_tick=120 cells is the knee where
    # the cost model starts demoting it.  hysteresis=1 because each tick
    # re-solves tiny instances from scratch — switching latency, not
    # flapping, is what hurts in the overloaded regime.
    budget = ComputeBudget(solve_time_num=10_000, per_tick=120, hysteresis=1)

    overload_rows = []
    headline = []
    policies_used: set[str] = set()
    for rate in rates:
        recs = qos_poisson_trace(
            build_library(), n_requests=n_requests,
            mean_interarrival=rate, seed=seed, tightness=tightness,
        )
        qtrace, qos = to_requests(recs, build_library())
        missed: dict[str, int] = {}
        for arm, policy, selector in (
            [(p, p, "fixed") for p in fixed_arms]
            + [("adaptive", "dp", "cost-model")]
        ):
            lib = build_library()
            ctx = lib.context.replace(budget=budget)
            report, s, dt = _timed_serve("overload", lambda: serve_trace(
                lib, qtrace, "slack-accumulate", window=window, qos=qos,
                policy=policy, selector=selector, n_drives=2,
                drive_costs=costs, context=ctx, warm_start=False,
            ))
            assert s["n_served"] == n_requests
            missed[arm] = report.n_missed
            if arm == "adaptive":
                policies_used.update(report.policy_mix)
            overload_rows.append({"rate": rate, "arm": arm, "wall_s": dt, **s})
            _emit(
                f"overload/{arm}/rate_{rate}",
                dt * 1e6,
                f"missed={report.n_missed}/{s['n_deadlines']};"
                f"p99={s['p99_sojourn']:.4g};"
                f"solve_delay={s['total_solve_delay']};"
                f"mix={'+'.join(f'{k}:{v}' for k, v in sorted(s['policy_mix'].items()))}",
            )
        best_fixed = min(missed[p] for p in fixed_arms)
        headline.append({
            "rate": rate,
            "adaptive_missed": missed["adaptive"],
            "best_fixed_missed": best_fixed,
            "fixed_missed": {p: missed[p] for p in fixed_arms},
        })
        assert missed["adaptive"] <= best_fixed, (
            f"adaptive selection must never miss more deadlines than the "
            f"best fixed policy: {missed['adaptive']} vs {best_fixed} "
            f"(fixed arms { {p: missed[p] for p in fixed_arms} }) at rate {rate}"
        )
    assert len(policies_used) >= 2, (
        f"the adaptive arm never switched policy across the sweep "
        f"(used {sorted(policies_used)}); the budget no longer exercises it"
    )

    (RESULTS / "overload_serving.json").write_text(
        json.dumps(overload_rows, indent=1)
    )
    RECORD["overload_serving"] = {
        "seed": seed,
        "n_requests": n_requests,
        "window": window,
        "tightness": tightness,
        "rates": list(rates),
        "budget": dataclasses.asdict(budget),
        "costs": dataclasses.asdict(costs),
        "selector": "cost-model",
        "fixed_arms": list(fixed_arms),
        "adaptive_policies_used": sorted(policies_used),
        "headline": headline,
        "rows": overload_rows,
    }
    return overload_rows


def bench_fleet_serving(full: bool = False):
    """Fleet federation sweep: placement strategies under a shard outage.

    A seeded ``replicas``-way replicated archive (every logical file lives
    on that many shards, :func:`~repro.fleet.demo_fleet`) serves one
    deadline-annotated federation-wide trace per swept arrival rate, for
    each swept shard count, while a
    :class:`~repro.serving.ShardOutage` darkens one whole shard mid-run
    (every drive on it fails at the same virtual instant).  Three routing
    arms run on identical traces: ``static-hash`` (oblivious content-hash
    placement — keeps routing into the dead shard), ``least-loaded``
    (queue-depth routing over live shard state), and ``replica-affinity``
    (queue depth x drive health x remount cost).  Retries are exhausted to
    ``drop`` so a stranded request becomes a recorded failure, not a crash.

    Recorded assertion (exact integer virtual time, machine-independent):
    at *every* swept (shard count, rate) cell, ``replica-affinity``'s
    deadline misses are strictly fewer than ``static-hash``'s, where a
    dropped deadline-carrying request counts as a miss (``n_missed`` among
    served + ``n_failed``).  The workload is pinned (``--full`` does not
    widen it): the strict bound is a *recorded* property of this seeded
    trace + outage, a calibrated operating point rather than a theorem
    over arbitrary workloads.
    """
    from repro.data.traces import qos_poisson_trace, to_requests
    from repro.fleet import demo_fleet, fleet_catalog, serve_fleet_trace
    from repro.serving import DriveCosts, RetryPolicy, ShardOutage

    del full  # recorded assertion — workload pinned to the calibrated sweep
    seed = 20260731
    n_requests = 180
    replicas = 2
    window = 400_000
    tightness = 8_000_000
    costs = DriveCosts(mount=150_000, unmount=60_000, load_seek=30_000)
    shard_counts = (2, 3)
    rates = (60_000, 30_000, 20_000)  # mean inter-arrival: light -> loaded
    outage_at, outage_shard = 1_500_000, 1
    placements = ("static-hash", "least-loaded", "replica-affinity")

    fleet_rows = []
    headline = []
    for n_shards in shard_counts:
        outages = (ShardOutage(at=outage_at, shard=outage_shard),)

        def build_fleet():
            return demo_fleet(seed, n_shards=n_shards, replicas=replicas)

        for rate in rates:
            libs, rmap = build_fleet()
            recs = qos_poisson_trace(
                fleet_catalog(libs, rmap), n_requests=n_requests,
                mean_interarrival=rate, seed=seed, tightness=tightness,
            )
            qtrace, qos = to_requests(recs, fleet_catalog(libs, rmap))
            misses: dict[str, int] = {}
            for pl in placements:
                libs, rmap = build_fleet()  # fresh shards per arm
                fr, s, dt = _timed_serve("fleet", lambda: serve_fleet_trace(
                    libs, qtrace, "slack-accumulate", placement=pl,
                    replica_map=rmap, outages=outages, window=window,
                    n_drives=2, drive_costs=costs, qos=qos,
                    retry=RetryPolicy(on_exhausted="drop"),
                ))
                # a dropped deadline-carrying request is a missed deadline
                misses[pl] = fr.n_missed + fr.n_failed
                fleet_rows.append({
                    "n_shards": n_shards, "rate": rate, "placement": pl,
                    "wall_s": dt, "deadline_misses": misses[pl], **s,
                })
                _emit(
                    f"fleet/{pl}/shards_{n_shards}/rate_{rate}",
                    dt * 1e6,
                    f"served={fr.n_served}/{n_requests};"
                    f"failed={fr.n_failed};missed={fr.n_missed};"
                    f"rerouted={fr.n_rerouted};"
                    f"routes={'/'.join(str(fr.routes[i]) for i in range(n_shards))}",
                )
            headline.append({
                "n_shards": n_shards,
                "rate": rate,
                "affinity_misses": misses["replica-affinity"],
                "static_misses": misses["static-hash"],
                "misses": dict(misses),
            })
            assert misses["replica-affinity"] < misses["static-hash"], (
                f"replica-affinity must strictly beat static-hash on "
                f"deadline misses under a shard outage: "
                f"{misses['replica-affinity']} vs {misses['static-hash']} "
                f"(all arms {misses}) at {n_shards} shards, rate {rate}"
            )

    (RESULTS / "fleet_serving.json").write_text(json.dumps(fleet_rows, indent=1))
    RECORD["fleet_serving"] = {
        "seed": seed,
        "n_requests": n_requests,
        "replicas": replicas,
        "window": window,
        "tightness": tightness,
        "shard_counts": list(shard_counts),
        "rates": list(rates),
        "costs": dataclasses.asdict(costs),
        "outage": {"at": outage_at, "shard": outage_shard},
        "placements": list(placements),
        "headline": headline,
        "rows": fleet_rows,
    }
    return fleet_rows


def check_baseline(record: dict, baseline_path: pathlib.Path) -> int:
    """Compare a fresh record against a checked-in baseline snapshot.

    Gate: the interpret-backend bucketed ``solve_batch`` throughput on the
    heterogeneous profile must not regress more than
    :data:`REGRESSION_TOLERANCE` against the baseline — measured as the
    *speedup over the padded launch from the same run*, so the padded arm
    calibrates away the runner's absolute speed (a checked-in baseline is
    recorded on a different machine than CI; absolute wall time would gate
    hardware, not code).  The absolute numbers are printed alongside for the
    trajectory.

    Second gate, on the serving loop's per-tick solve work: the warm-start
    sweep's headline cell counts are *exact integers on virtual time* —
    deterministic given the seeded trace, so machine-independent.  The
    warm-start reduction in the loaded regime must stay >= 30%, and the
    per-tick warm cell count must not creep above the baseline by more than
    :data:`REGRESSION_TOLERANCE` (a creep means reuse quietly degraded even
    if the ratio still clears the floor).  Returns a shell exit code.
    """
    baseline = json.loads(baseline_path.read_text())
    try:
        base, new = baseline["hetero_batch"], record["hetero_batch"]
        base_speedup, new_speedup = base["speedup"], new["speedup"]
        base_tp = base["bucketed"]["instances_per_s"]
        new_tp = new["bucketed"]["instances_per_s"]
    except KeyError as e:
        print(f"baseline check: missing hetero_batch record ({e})")
        return 2
    if base.get("profile") != new.get("profile"):
        print(
            f"baseline check: profile mismatch — baseline is "
            f"{base.get('profile')!r}, fresh run is {new.get('profile')!r}; "
            f"re-record the baseline with the matching profile"
        )
        return 2
    floor = (1.0 - REGRESSION_TOLERANCE) * base_speedup
    verdict = "OK" if new_speedup >= floor else "REGRESSED"
    print(
        f"baseline check [{verdict}]: bucketed-vs-padded interpret speedup "
        f"{new_speedup:.2f}x vs baseline {base_speedup:.2f}x "
        f"(floor {floor:.2f}x, tolerance {REGRESSION_TOLERANCE:.0%}); "
        f"absolute bucketed throughput {new_tp:.3g} inst/s "
        f"(baseline {base_tp:.3g}, different machine)"
    )
    if new_tp < (1.0 - REGRESSION_TOLERANCE) * base_tp:
        # a uniform slowdown of the shared kernel keeps the speedup ratio
        # flat, and a cross-machine baseline makes absolute wall time an
        # unreliable hard gate — so surface it loudly without failing.
        print(
            "baseline check WARNING: absolute bucketed throughput is >25% "
            "below the baseline; if this runner is comparable hardware, the "
            "shared wavefront path may have uniformly regressed (invisible "
            "to the speedup-ratio gate)."
        )

    # -- per-tick solve-work gate (exact virtual-time cell counts) -----------
    try:
        base_head = baseline["online_serving"]["warm_sweep"]["headline"]
        new_head = record["online_serving"]["warm_sweep"]["headline"]
    except KeyError as e:
        print(f"baseline check: missing warm_sweep record ({e})")
        return 2
    cells_ceiling = (1.0 + REGRESSION_TOLERANCE) * base_head["warm_cells_per_batch"]
    warm_ok = (
        new_head["reduction"] >= 0.30
        and new_head["warm_cells_per_batch"] <= cells_ceiling
    )
    print(
        f"baseline check [{'OK' if warm_ok else 'REGRESSED'}]: warm-start "
        f"per-tick DP work ({new_head['admission']} at rate "
        f"{new_head['rate']}): {new_head['warm_cells_per_batch']:.1f} "
        f"cells/batch vs baseline {base_head['warm_cells_per_batch']:.1f} "
        f"(ceiling {cells_ceiling:.1f}); reduction vs cold "
        f"{new_head['reduction']:.1%} (floor 30%, baseline "
        f"{base_head['reduction']:.1%})"
    )

    # -- adaptation-never-worse gate (exact virtual-time deadline misses) ----
    # Self-contained on the fresh record: the overload sweep's headline is
    # deterministic given the seeded trace, so the gate re-checks the
    # recorded assertion without needing the (possibly older) baseline to
    # carry the section.  A baseline that *does* carry it while the fresh
    # run doesn't means the bench silently stopped running — fail loudly.
    overload_ok = True
    new_over = record.get("overload_serving")
    base_over = baseline.get("overload_serving")
    if new_over is None and base_over is not None:
        print("baseline check: missing overload_serving record (bench not run?)")
        return 2
    if new_over is not None:
        worse = [
            h for h in new_over["headline"]
            if h["adaptive_missed"] > h["best_fixed_missed"]
        ]
        overload_ok = not worse and len(new_over["adaptive_policies_used"]) >= 2
        print(
            f"baseline check [{'OK' if overload_ok else 'REGRESSED'}]: "
            f"adaptive selection vs best fixed policy at rates "
            f"{new_over['rates']}: "
            + "; ".join(
                f"{h['adaptive_missed']}<={h['best_fixed_missed']}"
                for h in new_over["headline"]
            )
            + f" missed deadlines; policies used "
            f"{new_over['adaptive_policies_used']}"
        )

    # -- fleet replica-routing gate (exact virtual-time deadline misses) -----
    # Same self-contained shape as the overload gate: the fleet sweep's
    # headline is deterministic given the seeded trace + outage, so re-check
    # the recorded strict bound on the fresh record; a baseline carrying the
    # section while the fresh run lacks it means the bench silently stopped
    # running — fail loudly.
    fleet_ok = True
    new_fleet = record.get("fleet_serving")
    base_fleet = baseline.get("fleet_serving")
    if new_fleet is None and base_fleet is not None:
        print("baseline check: missing fleet_serving record (bench not run?)")
        return 2
    if new_fleet is not None:
        worse = [
            h for h in new_fleet["headline"]
            if h["affinity_misses"] >= h["static_misses"]
        ]
        fleet_ok = not worse
        print(
            f"baseline check [{'OK' if fleet_ok else 'REGRESSED'}]: "
            f"replica-affinity vs static-hash deadline misses under a shard "
            f"outage at (shards, rate) cells: "
            + "; ".join(
                f"({h['n_shards']},{h['rate']}):"
                f"{h['affinity_misses']}<{h['static_misses']}"
                for h in new_fleet["headline"]
            )
        )

    return 0 if (
        new_speedup >= floor and warm_ok and overload_ok and fleet_ok
    ) else 1


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true", help="paper-scale dataset (slow)")
    ap.add_argument(
        "--only", default=None, metavar="BENCH[,BENCH...]",
        help="run a subset of {profiles,time,kernel,batch,hetero,policies,"
             "restore,online,overload,fleet} (comma-separated)",
    )
    ap.add_argument(
        "--record", nargs="?", const="BENCH_pr2.json", default=None,
        metavar="PATH",
        help="write a machine-readable snapshot of every bench that ran "
             "(default PATH: BENCH_pr2.json)",
    )
    ap.add_argument(
        "--baseline", default=None, metavar="PATH",
        help="compare the fresh snapshot against a checked-in one and exit "
             "nonzero on >25%% interpret solve-throughput regression",
    )
    ap.add_argument(
        "--obs", action="store_true",
        help="feed every timed serving cell into a repro.obs "
             "MetricsRegistry; with --record the snapshot gains an "
             "'obs_metrics' block (off by default so recorded bytes are "
             "unchanged)",
    )
    args = ap.parse_args()
    if args.obs:
        from repro.obs import MetricsRegistry

        global OBS_METRICS
        OBS_METRICS = MetricsRegistry()
    benches = {
        "profiles": bench_performance_profiles,
        "time": bench_time_to_solution,
        "kernel": bench_kernel_wavefront,
        "batch": bench_solve_batch,
        "hetero": bench_hetero_batch,
        "policies": bench_policy_backends,
        "restore": bench_tape_restore,
        "online": bench_online_serving,
        "overload": bench_overload_serving,
        "fleet": bench_fleet_serving,
    }
    selected = list(benches) if args.only is None else args.only.split(",")
    unknown = [s for s in selected if s not in benches]
    if unknown:
        ap.error(f"unknown bench(es) {unknown}; choose from {list(benches)}")
    RESULTS.mkdir(exist_ok=True)
    print("name,us_per_call,derived")
    for name in benches:
        if name in selected:
            benches[name](args.full)
    if OBS_METRICS is not None:
        # key order: after every bench block, so obs-off records keep their
        # exact bytes and obs-on records only append
        RECORD["obs_metrics"] = OBS_METRICS.snapshot()
    if args.record:
        snapshot = {
            "schema": "ltsp-bench/pr2",
            "profile": "full" if args.full else "smoke",
            **RECORD,
        }
        pathlib.Path(args.record).write_text(json.dumps(snapshot, indent=1) + "\n")
        print(f"recorded {sorted(RECORD)} -> {args.record}")
    if args.baseline:
        sys.exit(check_baseline(RECORD, pathlib.Path(args.baseline)))


if __name__ == "__main__":
    main()

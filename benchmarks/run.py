# One function per paper table. Print ``name,us_per_call,derived`` CSV.
"""Benchmark harness.

Paper artefacts reproduced (on the synthetic IN2P3-calibrated dataset):

  * ``bench_performance_profiles``  — Figures 14/15/16: performance profiles
    of all registered policies at U in {0, seg/2, seg}.
  * ``bench_time_to_solution``      — §5.3 running-time table.
  * ``bench_kernel_wavefront``      — wavefront DP device throughput (jnp ref
    jitted + the single-trace Pallas wavefront in interpret mode).
  * ``bench_solve_batch``           — padded multi-instance device launch vs
    per-instance python solving (parity-checked).
  * ``bench_tape_restore``          — system table: LTSP-scheduled checkpoint
    restore vs positional sweep (mean shard service time).

All scheduling goes through the solver registry (``repro.core.solver``); every
reported cost is re-validated against the exact trajectory simulator.

Run: ``PYTHONPATH=src python -m benchmarks.run [--full]``
"""

from __future__ import annotations

import argparse
import json
import pathlib
import time

import numpy as np

RESULTS = pathlib.Path("results")


def _emit(name: str, us_per_call: float, derived: str) -> None:
    print(f"{name},{us_per_call:.1f},{derived}")


def _timed_solve(solver, inst):
    """``(cost, detours, seconds)`` timing only schedule *construction*.

    Heuristic solvers score their detours with the exact simulator inside
    ``solve()``; the paper's running-time tables exclude evaluation, so time
    the raw detour computation and score outside the clock (DP solvers get
    their cost from the recurrence itself, i.e. for free).
    """
    from repro.core import evaluate_detours
    from repro.core.solver import HeuristicSolver

    if isinstance(solver, HeuristicSolver):
        t0 = time.perf_counter()
        detours = solver.fn(inst)
        dt = time.perf_counter() - t0
        return evaluate_detours(inst, detours), detours, dt
    t0 = time.perf_counter()
    res = solver.solve(inst)
    dt = time.perf_counter() - t0
    return res.cost, res.detours, dt


# ---------------------------------------------------------------------------
def bench_performance_profiles(full: bool = False):
    """Figures 14-16: fraction of instances within tau of optimal."""
    from repro.core import evaluate_detours, get_solver, list_solvers, lower_bound_gap
    from repro.data import BENCH_PROFILE, PAPER_PROFILE, generate_dataset, u_turn_values

    profile = PAPER_PROFILE if full else BENCH_PROFILE
    ds0 = generate_dataset(profile)
    u_vals = u_turn_values(ds0)
    taus = [0.001, 0.01, 0.025, 0.05, 0.10, 0.25]
    policies = list_solvers()
    out_rows = []
    for u_name, U in u_vals.items():
        import dataclasses

        ds = [dataclasses.replace(i, u_turn=U) for i in ds0]
        costs: dict[str, list[float]] = {a: [] for a in policies}
        gaps: dict[str, list[float]] = {a: [] for a in policies}
        t_algo: dict[str, float] = {a: 0.0 for a in policies}
        for inst in ds:
            per = {}
            for name in policies:
                cost, detours, dt = _timed_solve(get_solver(name), inst)
                t_algo[name] += dt
                assert cost == evaluate_detours(inst, detours), name
                per[name] = cost
                gaps[name].append(lower_bound_gap(inst, cost))
            opt = per["dp"]
            for name, c in per.items():
                costs[name].append(c / opt if opt else 1.0)
        for name in policies:
            ratios = np.array(costs[name])
            fracs = [(ratios <= 1 + tau).mean() for tau in taus]
            mean_gap = float(np.mean(gaps[name]))
            row = {
                "figure": f"perf_profile_U_{u_name}",
                "algorithm": name,
                "mean_ratio": float(ratios.mean()),
                "p95_ratio": float(np.quantile(ratios, 0.95)),
                "mean_lb_gap": mean_gap,
                **{f"within_{tau}": float(fr) for tau, fr in zip(taus, fracs)},
                "total_time_s": t_algo[name],
            }
            out_rows.append(row)
            _emit(
                f"profile/{u_name}/{name}",
                1e6 * t_algo[name] / len(ds),
                f"mean_ratio={ratios.mean():.4f};within_2.5%={fracs[2]:.2f};"
                f"lb_gap={mean_gap:.4f}",
            )
    RESULTS.mkdir(exist_ok=True)
    (RESULTS / "performance_profiles.json").write_text(json.dumps(out_rows, indent=1))
    return out_rows


def bench_time_to_solution(full: bool = False):
    """§5.3 running-time comparison (median seconds per instance)."""
    from repro.core import get_solver, list_solvers
    from repro.data import BENCH_PROFILE, generate_dataset

    ds = generate_dataset(BENCH_PROFILE)[:20]
    rows = []
    for name in list_solvers():
        ts = []
        for inst in ds:
            _, _, dt = _timed_solve(get_solver(name), inst)
            ts.append(dt)
        med = float(np.median(ts))
        rows.append({"algorithm": name, "median_s": med, "max_s": float(max(ts))})
        _emit(f"time_to_solution/{name}", med * 1e6, f"max_s={max(ts):.3f}")
    (RESULTS / "time_to_solution.json").write_text(json.dumps(rows, indent=1))
    return rows


def _small_bench_instance(rng, R):
    from repro.core import make_instance

    sizes = rng.integers(1, 9, size=R)
    gaps = rng.integers(0, 6, size=R + 1)
    left, pos = [], int(gaps[0])
    for i in range(R):
        left.append(pos)
        pos += int(sizes[i] + gaps[i + 1])
    return make_instance(left, sizes, rng.integers(1, 4, size=R), m=pos, u_turn=3)


def bench_kernel_wavefront(full: bool = False):
    """Wavefront DP device throughput: jnp reference (jitted) and the
    single-trace Pallas wavefront (interpret mode is correctness-only on
    CPU, so its time measures one full table build, not TPU speed)."""
    import jax
    import jax.numpy as jnp

    from repro.kernels.ltsp_dp.ltsp_dp import ltsp_dp_tables
    from repro.kernels.ltsp_dp.ops import prepare_arrays
    from repro.kernels.ltsp_dp.ref import ltsp_dp_table_ref

    rng = np.random.default_rng(0)
    R = 24 if not full else 48
    inst = _small_bench_instance(rng, R)
    l, r, x, nl, S = prepare_arrays(inst)

    fn = jax.jit(lambda: ltsp_dp_table_ref(l, r, x, nl, float(inst.u_turn), S))
    fn()  # compile
    t0 = time.perf_counter()
    n_rep = 3
    for _ in range(n_rep):
        fn().block_until_ready()
    dt = (time.perf_counter() - t0) / n_rep
    cells = R * R * S / 2
    _emit("kernel/wavefront_ref", dt * 1e6, f"R={R};S={S};cells_per_s={cells/dt:.3g}")

    u = jnp.asarray([float(inst.u_turn)], l.dtype)
    pf = lambda: ltsp_dp_tables(l[None], r[None], x[None], nl[None], u, S=S)
    T, _ = pf()  # compile (single trace: one retrace total, not R)
    t0 = time.perf_counter()
    T, C = pf()
    jax.block_until_ready((T, C))
    dt_p = time.perf_counter() - t0
    _emit(
        "kernel/wavefront_pallas_interpret",
        dt_p * 1e6,
        f"R={R};S={S};cells_per_s={cells/dt_p:.3g}",
    )
    return {"R": R, "S": S, "seconds_ref": dt, "seconds_pallas": dt_p,
            "cells_per_s_ref": cells / dt}


def bench_solve_batch(full: bool = False):
    """Padded multi-instance device launch vs per-instance python DP."""
    from repro.core import solve, solve_batch

    rng = np.random.default_rng(11)
    B = 8 if not full else 16
    insts = [_small_bench_instance(rng, int(rng.integers(6, 14))) for _ in range(B)]

    t0 = time.perf_counter()
    py = [solve(i, policy="dp", backend="python") for i in insts]
    dt_py = time.perf_counter() - t0

    solve_batch(insts, policy="dp", backend="pallas-interpret")  # compile
    t0 = time.perf_counter()
    dev = solve_batch(insts, policy="dp", backend="pallas-interpret")
    dt_dev = time.perf_counter() - t0

    assert [r.cost for r in py] == [r.cost for r in dev], "batch parity violated"
    _emit("solver/batch_python", dt_py * 1e6 / B, f"B={B}")
    _emit("solver/batch_pallas_interpret", dt_dev * 1e6 / B, f"B={B};one_launch=1")
    return {"B": B, "seconds_python": dt_py, "seconds_device": dt_dev}


def bench_tape_restore(full: bool = False):
    """System table: checkpoint-restore mean service time by scheduler."""
    from repro.distributed.checkpoint import plan_restore
    from repro.storage.tape import TapeLibrary

    rng = np.random.default_rng(7)
    lib = TapeLibrary(capacity_per_tape=2 * 10**9, u_turn=10_000_000)
    shards = []
    for i in range(60):
        name = f"ckpt/shard{i:03d}"
        lib.store(name, int(rng.integers(5_000_000, 120_000_000)))
        shards.append(name)
    consumers = {s: int(rng.integers(1, 9)) for s in shards}
    rows = []
    base = None
    for policy in ("nodetour", "gs", "fgs", "nfgs", "simpledp", "logdp1", "dp"):
        t0 = time.perf_counter()
        plans = plan_restore(lib, shards, consumers, policy=policy)
        dt = time.perf_counter() - t0
        mean = sum(p.total_cost for p in plans) / sum(consumers.values())
        base = base or mean
        rows.append({"policy": policy, "mean_service": mean, "plan_s": dt})
        _emit(f"tape_restore/{policy}", dt * 1e6, f"mean_service={mean:.3g};vs_nodetour={mean/base:.3f}")
    (RESULTS / "tape_restore.json").write_text(json.dumps(rows, indent=1))
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true", help="paper-scale dataset (slow)")
    ap.add_argument(
        "--only", default=None,
        choices=["profiles", "time", "kernel", "batch", "restore"],
    )
    args = ap.parse_args()
    RESULTS.mkdir(exist_ok=True)
    print("name,us_per_call,derived")
    if args.only in (None, "profiles"):
        bench_performance_profiles(args.full)
    if args.only in (None, "time"):
        bench_time_to_solution(args.full)
    if args.only in (None, "kernel"):
        bench_kernel_wavefront(args.full)
    if args.only in (None, "batch"):
        bench_solve_batch(args.full)
    if args.only in (None, "restore"):
        bench_tape_restore(args.full)


if __name__ == "__main__":
    main()

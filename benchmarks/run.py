# One function per paper table. Print ``name,us_per_call,derived`` CSV.
"""Benchmark harness.

Paper artefacts reproduced (on the synthetic IN2P3-calibrated dataset):

  * ``bench_performance_profiles``  — Figures 14/15/16: performance profiles
    of all registered policies at U in {0, seg/2, seg}.
  * ``bench_time_to_solution``      — §5.3 running-time table.
  * ``bench_kernel_wavefront``      — wavefront DP device throughput (jnp ref
    jitted + the single-trace Pallas wavefront in interpret mode).
  * ``bench_solve_batch``           — padded multi-instance device launch vs
    per-instance python solving (parity-checked).
  * ``bench_hetero_batch``          — heterogeneous (mixed-size) batch: the
    seed's single maximally-padded launch vs the size-bucketed planner
    (bit-identical results, throughput A/B).
  * ``bench_policy_backends``       — per-policy, per-backend wall time and
    solve throughput matrix.
  * ``bench_tape_restore``          — system table: LTSP-scheduled checkpoint
    restore vs positional sweep (mean shard service time + solve-cache
    hit/miss counters).

All scheduling goes through the solver registry (``repro.core.solver``); every
reported cost is re-validated against the exact trajectory simulator.

Run: ``PYTHONPATH=src python -m benchmarks.run [--full]``

Recorded trajectory: ``--record [PATH]`` additionally writes a
machine-readable snapshot (default ``BENCH_pr2.json``) of every bench that
ran; ``--baseline PATH`` compares the fresh snapshot against a checked-in one
and exits nonzero if the interpret-backend bucketed solve throughput regressed
more than ``REGRESSION_TOLERANCE`` (runner-calibrated: measured as the speedup
over the padded arm of the same run) — CI runs the smoke profile of this as
the perf gate, so the perf trajectory of the repo is diffable PR over PR.
"""

from __future__ import annotations

import argparse
import json
import pathlib
import sys
import time

import numpy as np

RESULTS = pathlib.Path("results")

#: allowed fractional drop in recorded throughput before --baseline fails.
REGRESSION_TOLERANCE = 0.25

#: benches append {name: row} snapshots here; --record serialises it.
RECORD: dict = {}


def _emit(name: str, us_per_call: float, derived: str) -> None:
    print(f"{name},{us_per_call:.1f},{derived}")


def _timed_solve(solver, inst):
    """``(cost, detours, seconds)`` timing only schedule *construction*.

    Heuristic solvers score their detours with the exact simulator inside
    ``solve()``; the paper's running-time tables exclude evaluation, so time
    the raw detour computation and score outside the clock (DP solvers get
    their cost from the recurrence itself, i.e. for free).
    """
    from repro.core import evaluate_detours
    from repro.core.solver import HeuristicSolver

    if isinstance(solver, HeuristicSolver):
        t0 = time.perf_counter()
        detours = solver.fn(inst)
        dt = time.perf_counter() - t0
        return evaluate_detours(inst, detours), detours, dt
    t0 = time.perf_counter()
    res = solver.solve(inst)
    dt = time.perf_counter() - t0
    return res.cost, res.detours, dt


# ---------------------------------------------------------------------------
def bench_performance_profiles(full: bool = False):
    """Figures 14-16: fraction of instances within tau of optimal."""
    from repro.core import evaluate_detours, get_solver, list_solvers, lower_bound_gap
    from repro.data import BENCH_PROFILE, PAPER_PROFILE, generate_dataset, u_turn_values

    profile = PAPER_PROFILE if full else BENCH_PROFILE
    ds0 = generate_dataset(profile)
    u_vals = u_turn_values(ds0)
    taus = [0.001, 0.01, 0.025, 0.05, 0.10, 0.25]
    policies = list_solvers()
    out_rows = []
    for u_name, U in u_vals.items():
        import dataclasses

        ds = [dataclasses.replace(i, u_turn=U) for i in ds0]
        costs: dict[str, list[float]] = {a: [] for a in policies}
        gaps: dict[str, list[float]] = {a: [] for a in policies}
        t_algo: dict[str, float] = {a: 0.0 for a in policies}
        for inst in ds:
            per = {}
            for name in policies:
                cost, detours, dt = _timed_solve(get_solver(name), inst)
                t_algo[name] += dt
                assert cost == evaluate_detours(inst, detours), name
                per[name] = cost
                gaps[name].append(lower_bound_gap(inst, cost))
            opt = per["dp"]
            for name, c in per.items():
                costs[name].append(c / opt if opt else 1.0)
        for name in policies:
            ratios = np.array(costs[name])
            fracs = [(ratios <= 1 + tau).mean() for tau in taus]
            mean_gap = float(np.mean(gaps[name]))
            row = {
                "figure": f"perf_profile_U_{u_name}",
                "algorithm": name,
                "mean_ratio": float(ratios.mean()),
                "p95_ratio": float(np.quantile(ratios, 0.95)),
                "mean_lb_gap": mean_gap,
                **{f"within_{tau}": float(fr) for tau, fr in zip(taus, fracs)},
                "total_time_s": t_algo[name],
            }
            out_rows.append(row)
            _emit(
                f"profile/{u_name}/{name}",
                1e6 * t_algo[name] / len(ds),
                f"mean_ratio={ratios.mean():.4f};within_2.5%={fracs[2]:.2f};"
                f"lb_gap={mean_gap:.4f}",
            )
    RESULTS.mkdir(exist_ok=True)
    (RESULTS / "performance_profiles.json").write_text(json.dumps(out_rows, indent=1))
    return out_rows


def bench_time_to_solution(full: bool = False):
    """§5.3 running-time comparison (median seconds per instance)."""
    from repro.core import get_solver, list_solvers
    from repro.data import BENCH_PROFILE, generate_dataset

    ds = generate_dataset(BENCH_PROFILE)[:20]
    rows = []
    for name in list_solvers():
        ts = []
        for inst in ds:
            _, _, dt = _timed_solve(get_solver(name), inst)
            ts.append(dt)
        med = float(np.median(ts))
        rows.append({"algorithm": name, "median_s": med, "max_s": float(max(ts))})
        _emit(f"time_to_solution/{name}", med * 1e6, f"max_s={max(ts):.3f}")
    (RESULTS / "time_to_solution.json").write_text(json.dumps(rows, indent=1))
    RECORD["time_to_solution"] = rows
    return rows


def _small_bench_instance(rng, R):
    from repro.core import make_instance

    sizes = rng.integers(1, 9, size=R)
    gaps = rng.integers(0, 6, size=R + 1)
    left, pos = [], int(gaps[0])
    for i in range(R):
        left.append(pos)
        pos += int(sizes[i] + gaps[i + 1])
    return make_instance(left, sizes, rng.integers(1, 4, size=R), m=pos, u_turn=3)


def bench_kernel_wavefront(full: bool = False):
    """Wavefront DP device throughput: jnp reference (jitted) and the
    single-trace Pallas wavefront (interpret mode is correctness-only on
    CPU, so its time measures one full table build, not TPU speed)."""
    import jax
    import jax.numpy as jnp

    from repro.kernels.ltsp_dp.ltsp_dp import ltsp_dp_tables
    from repro.kernels.ltsp_dp.ops import prepare_arrays
    from repro.kernels.ltsp_dp.ref import ltsp_dp_table_ref

    rng = np.random.default_rng(0)
    R = 24 if not full else 48
    inst = _small_bench_instance(rng, R)
    l, r, x, nl, S = prepare_arrays(inst)

    fn = jax.jit(lambda: ltsp_dp_table_ref(l, r, x, nl, float(inst.u_turn), S))
    fn()  # compile
    t0 = time.perf_counter()
    n_rep = 3
    for _ in range(n_rep):
        fn().block_until_ready()
    dt = (time.perf_counter() - t0) / n_rep
    cells = R * R * S / 2
    _emit("kernel/wavefront_ref", dt * 1e6, f"R={R};S={S};cells_per_s={cells/dt:.3g}")

    u = jnp.asarray([float(inst.u_turn)], l.dtype)
    pf = lambda: ltsp_dp_tables(l[None], r[None], x[None], nl[None], u, S=S)
    T, _ = pf()  # compile (single trace: one retrace total, not R)
    t0 = time.perf_counter()
    T, C = pf()
    jax.block_until_ready((T, C))
    dt_p = time.perf_counter() - t0
    _emit(
        "kernel/wavefront_pallas_interpret",
        dt_p * 1e6,
        f"R={R};S={S};cells_per_s={cells/dt_p:.3g}",
    )
    row = {"R": R, "S": S, "seconds_ref": dt, "seconds_pallas": dt_p,
           "cells_per_s_ref": cells / dt}
    RECORD["kernel_wavefront"] = row
    return row


def bench_solve_batch(full: bool = False):
    """Bucketed multi-instance device launches vs per-instance python DP."""
    from repro.core import solve, solve_batch
    from repro.kernels.ltsp_dp.ops import plan_buckets, rescale_instance

    rng = np.random.default_rng(11)
    B = 8 if not full else 16
    insts = [_small_bench_instance(rng, int(rng.integers(6, 14))) for _ in range(B)]
    n_launches = len(plan_buckets([rescale_instance(i)[0] for i in insts]))

    t0 = time.perf_counter()
    py = [solve(i, policy="dp", backend="python") for i in insts]
    dt_py = time.perf_counter() - t0

    solve_batch(insts, policy="dp", backend="pallas-interpret")  # compile
    t0 = time.perf_counter()
    dev = solve_batch(insts, policy="dp", backend="pallas-interpret")
    dt_dev = time.perf_counter() - t0

    assert [r.cost for r in py] == [r.cost for r in dev], "batch parity violated"
    _emit("solver/batch_python", dt_py * 1e6 / B, f"B={B}")
    _emit(
        "solver/batch_pallas_interpret",
        dt_dev * 1e6 / B,
        f"B={B};launches={n_launches}",
    )
    row = {"B": B, "launches": n_launches,
           "seconds_python": dt_py, "seconds_device": dt_dev}
    RECORD["solve_batch"] = row
    return row


def _hetero_instances(rng, full: bool = False):
    """Mixed-size cartridge batch: mostly small tapes plus a few wide ones
    (the IN2P3 shape — a global pad wastes most of its lanes)."""
    n_small = 8 if not full else 16
    n_wide = 4 if not full else 8
    insts = [_small_bench_instance(rng, int(rng.integers(3, 8)))
             for _ in range(n_small)]
    for _ in range(n_wide):
        insts.append(_small_bench_instance(rng, int(rng.integers(18, 27))))
    # bump a couple of multiplicities so the wide tapes cross the 128-lane
    # S boundary and land in a different (R, S) bucket
    import dataclasses
    for i in range(n_small, n_small + 2):
        mult = insts[i].mult.copy()
        mult[::2] += 9
        insts[i] = dataclasses.replace(insts[i], mult=mult)
    order = rng.permutation(len(insts))
    return [insts[i] for i in order]


def _median_time(fn, n_rep: int = 3) -> float:
    ts = []
    for _ in range(n_rep):
        t0 = time.perf_counter()
        fn()
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def bench_hetero_batch(full: bool = False):
    """Heterogeneous batch: seed-style global padding vs the bucket planner.

    Both paths are the same interpret-mode wavefront; only the launch shapes
    differ.  Results must be bit-identical to per-instance device solving
    (cost *and* detours) — the planner is a pure scheduling optimisation.
    """
    from repro.core import dp_schedule, evaluate_detours
    from repro.kernels.ltsp_dp.ops import (
        ltsp_solve_batch, ltsp_solve_instance, plan_buckets, rescale_instance,
    )

    rng = np.random.default_rng(20260731)
    insts = _hetero_instances(rng, full)
    B = len(insts)
    buckets = plan_buckets([rescale_instance(i)[0] for i in insts])

    padded = ltsp_solve_batch(insts, bucketed=False)  # compile
    bucketed = ltsp_solve_batch(insts, bucketed=True)  # compile (per bucket)
    assert padded == bucketed, "bucketing changed results"
    for inst, (cost, dets) in zip(insts, bucketed):
        assert (cost, dets) == ltsp_solve_instance(inst), "batch != per-instance"
        assert cost == dp_schedule(inst)[0] == evaluate_detours(inst, dets)

    dt_pad = _median_time(lambda: ltsp_solve_batch(insts, bucketed=False))
    dt_buck = _median_time(lambda: ltsp_solve_batch(insts, bucketed=True))
    speedup = dt_pad / dt_buck
    _emit("solver/hetero_padded", dt_pad * 1e6 / B, f"B={B};R_max={max(i.n_req for i in insts)}")
    _emit(
        "solver/hetero_bucketed",
        dt_buck * 1e6 / B,
        f"B={B};buckets={len(buckets)};speedup={speedup:.2f}x",
    )
    row = {
        "backend": "pallas-interpret",
        "B": B,
        "profile": "full" if full else "smoke",
        "buckets": [[r, s, len(idx)] for (r, s), idx in sorted(buckets.items())],
        "padded": {"seconds": dt_pad, "instances_per_s": B / dt_pad},
        "bucketed": {"seconds": dt_buck, "instances_per_s": B / dt_buck},
        "speedup": speedup,
        "parity": True,
    }
    RECORD["hetero_batch"] = row
    return row


def bench_policy_backends(full: bool = False):
    """Per-policy, per-backend wall time + solve throughput matrix.

    Python rows run the full bench dataset slice; device rows run the
    heterogeneous small-tape set (interpret mode emulates the kernel on CPU,
    so paper-scale instances would measure the emulator, not the policy).
    """
    from repro.core import evaluate_detours, get_solver
    from repro.core.solver import list_solvers
    from repro.data import BENCH_PROFILE, generate_dataset

    ds_py = generate_dataset(BENCH_PROFILE)[: 12 if not full else 30]
    rng = np.random.default_rng(5)
    ds_dev = _hetero_instances(rng)[:6]
    rows = []
    for name in list_solvers():
        solver = get_solver(name)
        for backend in solver.backends:
            if backend == "pallas":  # compiled TPU: not available in CI
                continue
            ds = ds_py if backend == "python" else ds_dev
            if backend != "python":
                solver.solve_batch(ds, backend)  # compile outside the clock
            t0 = time.perf_counter()
            results = solver.solve_batch(ds, backend)
            dt = time.perf_counter() - t0
            for inst, res in zip(ds, results):
                assert res.cost == evaluate_detours(inst, res.detours), name
            rows.append({
                "policy": name,
                "backend": backend,
                "n_instances": len(ds),
                "seconds_total": dt,
                "seconds_per_instance": dt / len(ds),
                "solves_per_s": len(ds) / dt,
            })
            _emit(
                f"policy_backend/{name}/{backend}",
                dt * 1e6 / len(ds),
                f"n={len(ds)};solves_per_s={len(ds) / dt:.3g}",
            )
    RECORD["policy_backends"] = rows
    return rows


def bench_tape_restore(full: bool = False):
    """System table: checkpoint-restore mean service time by scheduler.

    The library carries a solve-memo cache; each policy is planned twice and
    the warm re-plan (what a recovering fleet's next cold start pays) plus the
    cache hit/miss counters land in the summary.
    """
    from repro.core import SolveCache
    from repro.distributed.checkpoint import plan_restore
    from repro.storage.tape import TapeLibrary

    rng = np.random.default_rng(7)
    lib = TapeLibrary(
        capacity_per_tape=2 * 10**9, u_turn=10_000_000, cache=SolveCache()
    )
    shards = []
    for i in range(60):
        name = f"ckpt/shard{i:03d}"
        lib.store(name, int(rng.integers(5_000_000, 120_000_000)))
        shards.append(name)
    consumers = {s: int(rng.integers(1, 9)) for s in shards}
    rows = []
    base = None
    for policy in ("nodetour", "gs", "fgs", "nfgs", "simpledp", "logdp1", "dp"):
        t0 = time.perf_counter()
        plans = plan_restore(lib, shards, consumers, policy=policy)
        dt = time.perf_counter() - t0
        t0 = time.perf_counter()
        replans = plan_restore(lib, shards, consumers, policy=policy)
        dt_warm = time.perf_counter() - t0
        assert [p.total_cost for p in plans] == [p.total_cost for p in replans]
        mean = sum(p.total_cost for p in plans) / sum(consumers.values())
        base = base or mean
        rows.append({
            "policy": policy, "mean_service": mean,
            "plan_s": dt, "replan_s": dt_warm,
        })
        _emit(
            f"tape_restore/{policy}",
            dt * 1e6,
            f"mean_service={mean:.3g};vs_nodetour={mean/base:.3f};"
            f"replan_us={dt_warm*1e6:.0f}",
        )
    stats = lib.cache.stats()
    _emit(
        "tape_restore/cache",
        0.0,
        f"hits={stats['hits']};misses={stats['misses']};entries={stats['entries']}",
    )
    (RESULTS / "tape_restore.json").write_text(
        json.dumps({"rows": rows, "cache": stats}, indent=1)
    )
    RECORD["tape_restore"] = {"rows": rows, "cache": stats}
    return rows


def check_baseline(record: dict, baseline_path: pathlib.Path) -> int:
    """Compare a fresh record against a checked-in baseline snapshot.

    Gate: the interpret-backend bucketed ``solve_batch`` throughput on the
    heterogeneous profile must not regress more than
    :data:`REGRESSION_TOLERANCE` against the baseline — measured as the
    *speedup over the padded launch from the same run*, so the padded arm
    calibrates away the runner's absolute speed (a checked-in baseline is
    recorded on a different machine than CI; absolute wall time would gate
    hardware, not code).  The absolute numbers are printed alongside for the
    trajectory.  Returns a shell exit code.
    """
    baseline = json.loads(baseline_path.read_text())
    try:
        base, new = baseline["hetero_batch"], record["hetero_batch"]
        base_speedup, new_speedup = base["speedup"], new["speedup"]
        base_tp = base["bucketed"]["instances_per_s"]
        new_tp = new["bucketed"]["instances_per_s"]
    except KeyError as e:
        print(f"baseline check: missing hetero_batch record ({e})")
        return 2
    if base.get("profile") != new.get("profile"):
        print(
            f"baseline check: profile mismatch — baseline is "
            f"{base.get('profile')!r}, fresh run is {new.get('profile')!r}; "
            f"re-record the baseline with the matching profile"
        )
        return 2
    floor = (1.0 - REGRESSION_TOLERANCE) * base_speedup
    verdict = "OK" if new_speedup >= floor else "REGRESSED"
    print(
        f"baseline check [{verdict}]: bucketed-vs-padded interpret speedup "
        f"{new_speedup:.2f}x vs baseline {base_speedup:.2f}x "
        f"(floor {floor:.2f}x, tolerance {REGRESSION_TOLERANCE:.0%}); "
        f"absolute bucketed throughput {new_tp:.3g} inst/s "
        f"(baseline {base_tp:.3g}, different machine)"
    )
    if new_tp < (1.0 - REGRESSION_TOLERANCE) * base_tp:
        # a uniform slowdown of the shared kernel keeps the speedup ratio
        # flat, and a cross-machine baseline makes absolute wall time an
        # unreliable hard gate — so surface it loudly without failing.
        print(
            "baseline check WARNING: absolute bucketed throughput is >25% "
            "below the baseline; if this runner is comparable hardware, the "
            "shared wavefront path may have uniformly regressed (invisible "
            "to the speedup-ratio gate)."
        )
    return 0 if new_speedup >= floor else 1


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true", help="paper-scale dataset (slow)")
    ap.add_argument(
        "--only", default=None, metavar="BENCH[,BENCH...]",
        help="run a subset of {profiles,time,kernel,batch,hetero,policies,"
             "restore} (comma-separated)",
    )
    ap.add_argument(
        "--record", nargs="?", const="BENCH_pr2.json", default=None,
        metavar="PATH",
        help="write a machine-readable snapshot of every bench that ran "
             "(default PATH: BENCH_pr2.json)",
    )
    ap.add_argument(
        "--baseline", default=None, metavar="PATH",
        help="compare the fresh snapshot against a checked-in one and exit "
             "nonzero on >25%% interpret solve-throughput regression",
    )
    args = ap.parse_args()
    benches = {
        "profiles": bench_performance_profiles,
        "time": bench_time_to_solution,
        "kernel": bench_kernel_wavefront,
        "batch": bench_solve_batch,
        "hetero": bench_hetero_batch,
        "policies": bench_policy_backends,
        "restore": bench_tape_restore,
    }
    selected = list(benches) if args.only is None else args.only.split(",")
    unknown = [s for s in selected if s not in benches]
    if unknown:
        ap.error(f"unknown bench(es) {unknown}; choose from {list(benches)}")
    RESULTS.mkdir(exist_ok=True)
    print("name,us_per_call,derived")
    for name in benches:
        if name in selected:
            benches[name](args.full)
    if args.record:
        snapshot = {
            "schema": "ltsp-bench/pr2",
            "profile": "full" if args.full else "smoke",
            **RECORD,
        }
        pathlib.Path(args.record).write_text(json.dumps(snapshot, indent=1) + "\n")
        print(f"recorded {sorted(RECORD)} -> {args.record}")
    if args.baseline:
        sys.exit(check_baseline(RECORD, pathlib.Path(args.baseline)))


if __name__ == "__main__":
    main()

# One function per paper table. Print ``name,us_per_call,derived`` CSV.
"""Benchmark harness.

Paper artefacts reproduced (on the synthetic IN2P3-calibrated dataset):

  * ``bench_performance_profiles``  — Figures 14/15/16: performance profiles
    of all 9 algorithms at U in {0, seg/2, seg}.
  * ``bench_time_to_solution``      — §5.3 running-time table.
  * ``bench_kernel_wavefront``      — Pallas/jnp wavefront DP throughput.
  * ``bench_tape_restore``          — system table: LTSP-scheduled checkpoint
    restore vs positional sweep (mean shard service time).

Run: ``PYTHONPATH=src python -m benchmarks.run [--full]``
"""

from __future__ import annotations

import argparse
import json
import pathlib
import time

import numpy as np

RESULTS = pathlib.Path("results")


def _emit(name: str, us_per_call: float, derived: str) -> None:
    print(f"{name},{us_per_call:.1f},{derived}")


# ---------------------------------------------------------------------------
def bench_performance_profiles(full: bool = False):
    """Figures 14-16: fraction of instances within tau of optimal."""
    from repro.core import ALGORITHMS, evaluate_detours
    from repro.data import BENCH_PROFILE, PAPER_PROFILE, generate_dataset, u_turn_values

    profile = PAPER_PROFILE if full else BENCH_PROFILE
    ds0 = generate_dataset(profile)
    u_vals = u_turn_values(ds0)
    taus = [0.001, 0.01, 0.025, 0.05, 0.10, 0.25]
    out_rows = []
    for u_name, U in u_vals.items():
        import dataclasses

        ds = [dataclasses.replace(i, u_turn=U) for i in ds0]
        costs: dict[str, list[float]] = {a: [] for a in ALGORITHMS}
        t_algo: dict[str, float] = {a: 0.0 for a in ALGORITHMS}
        for inst in ds:
            per = {}
            for name, algo in ALGORITHMS.items():
                t0 = time.perf_counter()
                dets = algo(inst)
                t_algo[name] += time.perf_counter() - t0
                per[name] = evaluate_detours(inst, dets)
            opt = per["dp"]
            for name, c in per.items():
                costs[name].append(c / opt if opt else 1.0)
        for name in ALGORITHMS:
            ratios = np.array(costs[name])
            fracs = [(ratios <= 1 + tau).mean() for tau in taus]
            row = {
                "figure": f"perf_profile_U_{u_name}",
                "algorithm": name,
                "mean_ratio": float(ratios.mean()),
                "p95_ratio": float(np.quantile(ratios, 0.95)),
                **{f"within_{tau}": float(fr) for tau, fr in zip(taus, fracs)},
                "total_time_s": t_algo[name],
            }
            out_rows.append(row)
            _emit(
                f"profile/{u_name}/{name}",
                1e6 * t_algo[name] / len(ds),
                f"mean_ratio={ratios.mean():.4f};within_2.5%={fracs[2]:.2f}",
            )
    RESULTS.mkdir(exist_ok=True)
    (RESULTS / "performance_profiles.json").write_text(json.dumps(out_rows, indent=1))
    return out_rows


def bench_time_to_solution(full: bool = False):
    """§5.3 running-time comparison (median seconds per instance)."""
    from repro.core import ALGORITHMS
    from repro.data import BENCH_PROFILE, generate_dataset

    ds = generate_dataset(BENCH_PROFILE)[:20]
    rows = []
    for name, algo in ALGORITHMS.items():
        ts = []
        for inst in ds:
            t0 = time.perf_counter()
            algo(inst)
            ts.append(time.perf_counter() - t0)
        med = float(np.median(ts))
        rows.append({"algorithm": name, "median_s": med, "max_s": float(max(ts))})
        _emit(f"time_to_solution/{name}", med * 1e6, f"max_s={max(ts):.3f}")
    (RESULTS / "time_to_solution.json").write_text(json.dumps(rows, indent=1))
    return rows


def bench_kernel_wavefront(full: bool = False):
    """Wavefront DP device throughput (jnp ref, jitted; Pallas in interpret
    mode is correctness-only on CPU)."""
    import jax

    from repro.core import make_instance
    from repro.kernels.ltsp_dp.ops import prepare_arrays
    from repro.kernels.ltsp_dp.ref import ltsp_dp_table_ref

    rng = np.random.default_rng(0)
    R = 24 if not full else 48
    sizes = rng.integers(1, 9, size=R)
    gaps = rng.integers(0, 6, size=R + 1)
    left, pos = [], int(gaps[0])
    for i in range(R):
        left.append(pos)
        pos += int(sizes[i] + gaps[i + 1])
    inst = make_instance(left, sizes, rng.integers(1, 4, size=R), m=pos, u_turn=3)
    l, r, x, nl, S = prepare_arrays(inst)

    fn = jax.jit(lambda: ltsp_dp_table_ref(l, r, x, nl, float(inst.u_turn), S))
    fn()  # compile
    t0 = time.perf_counter()
    n_rep = 3
    for _ in range(n_rep):
        fn().block_until_ready()
    dt = (time.perf_counter() - t0) / n_rep
    cells = R * R * S / 2
    _emit("kernel/wavefront_dp", dt * 1e6, f"R={R};S={S};cells_per_s={cells/dt:.3g}")
    return {"R": R, "S": S, "seconds": dt, "cells_per_s": cells / dt}


def bench_tape_restore(full: bool = False):
    """System table: checkpoint-restore mean service time by scheduler."""
    from repro.distributed.checkpoint import plan_restore
    from repro.storage.tape import TapeLibrary

    rng = np.random.default_rng(7)
    lib = TapeLibrary(capacity_per_tape=2 * 10**9, u_turn=10_000_000)
    shards = []
    for i in range(60):
        name = f"ckpt/shard{i:03d}"
        lib.store(name, int(rng.integers(5_000_000, 120_000_000)))
        shards.append(name)
    consumers = {s: int(rng.integers(1, 9)) for s in shards}
    rows = []
    base = None
    for policy in ("nodetour", "gs", "fgs", "nfgs", "simpledp", "logdp1", "dp"):
        t0 = time.perf_counter()
        plans = plan_restore(lib, shards, consumers, policy=policy)
        dt = time.perf_counter() - t0
        mean = sum(p.total_cost for p in plans) / sum(consumers.values())
        base = base or mean
        rows.append({"policy": policy, "mean_service": mean, "plan_s": dt})
        _emit(f"tape_restore/{policy}", dt * 1e6, f"mean_service={mean:.3g};vs_nodetour={mean/base:.3f}")
    (RESULTS / "tape_restore.json").write_text(json.dumps(rows, indent=1))
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true", help="paper-scale dataset (slow)")
    ap.add_argument(
        "--only", default=None,
        choices=["profiles", "time", "kernel", "restore"],
    )
    args = ap.parse_args()
    print("name,us_per_call,derived")
    if args.only in (None, "profiles"):
        bench_performance_profiles(args.full)
    if args.only in (None, "time"):
        bench_time_to_solution(args.full)
    if args.only in (None, "kernel"):
        bench_kernel_wavefront(args.full)
    if args.only in (None, "restore"):
        bench_tape_restore(args.full)


if __name__ == "__main__":
    main()

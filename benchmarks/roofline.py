"""Three-term roofline from the dry-run's compiled artifacts.

Terms (seconds, per training/serving step):

  compute    = HLO_FLOPs_per_device   / 197e12  (bf16 peak per v5e chip)
  memory     = HLO_bytes_per_device   / 819e9   (HBM bandwidth)
  collective = coll_bytes_per_device  / 50e9    (ICI per-link bandwidth)

The dry-run compiles the SPMD-partitioned module, so cost_analysis numbers
and parsed collective shapes are already per device; dividing global totals
by chip count (the formulas in EXPERIMENTS.md) is algebraically identical.

``MODEL_FLOPS`` is the analytic 6·N_active·D (train) / 2·N_active·B (+ mixer
sequence terms) useful-work estimate; ``MODEL_FLOPS / HLO_FLOPs`` exposes
remat and dispatch overheads.  sLSTM recurrent flops are added analytically:
XLA costs an inner while-loop body once (documented undercount).
"""

from __future__ import annotations

import json
import math
import pathlib

from repro.configs import ARCHS, SHAPES
from repro.models.common import ModelConfig

PEAK_FLOPS = 197e12  # bf16 / chip
HBM_BW = 819e9  # B/s
ICI_BW = 50e9  # B/s per link

MESH_CHIPS = {"pod16x16": 256, "pod2x16x16": 512}


# ---------------------------------------------------------------------------
# analytic parameter / flops model
# ---------------------------------------------------------------------------
def _layer_params(cfg: ModelConfig, kind: str, is_moe: bool, d_ff: int):
    D, Hq, Hkv, dh = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    total = active = 0
    if kind in ("attn", "xattn"):
        if cfg.use_mla and kind == "attn":
            qr, kr = cfg.q_lora_rank, cfg.kv_lora_rank
            nd, rd, vd = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
            n = (D * qr + qr * Hq * (nd + rd) + D * (kr + rd)
                 + kr * Hq * nd + kr * Hq * vd + Hq * vd * D)
        else:
            n = D * Hq * dh + 2 * D * Hkv * dh + Hq * dh * D
        total += n
        active += n
    elif kind == "mamba":
        dI = cfg.mamba_expand * D
        dtr = max(1, math.ceil(D / 16))
        n = D * 2 * dI + cfg.mamba_d_conv * dI + dI * (dtr + 2 * cfg.mamba_d_state) + dtr * dI + dI * D
        total += n
        active += n
    elif kind == "mlstm":
        n = 3 * D * Hq * dh + D * 2 * Hq + Hq * dh * D
        total += n
        active += n
    elif kind == "slstm":
        n = D * 4 * Hq * dh + 4 * Hq * dh * dh + Hq * dh * D
        total += n
        active += n
    if is_moe:
        E, K, F = cfg.num_experts, cfg.top_k, cfg.moe_d_ff or cfg.d_ff
        total += D * E + E * 3 * D * F
        active += D * E + K * 3 * D * F
        if cfg.num_shared_experts:
            s = 3 * D * F * cfg.num_shared_experts
            total += s
            active += s
    elif d_ff > 0:
        total += 3 * D * d_ff
        active += 3 * D * d_ff
    return total, active


def param_counts(cfg: ModelConfig) -> tuple[int, int]:
    """(total, active-per-token) parameters, embeddings included once."""
    D, V = cfg.d_model, cfg.vocab_size
    total = active = V * D  # embedding (head param counted below)
    if not cfg.tie_embeddings:
        total += D * V
    # decoder stack
    for i in range(cfg.first_k_dense):
        t, a = _layer_params(cfg, "attn", False, cfg.dense_d_ff or cfg.d_ff)
        total, active = total + t, active + a
    for _ in range(cfg.n_periods):
        for pos, kind in enumerate(cfg.block_pattern):
            t, a = _layer_params(cfg, kind, cfg.is_moe_layer(pos), cfg.d_ff)
            total, active = total + t, active + a
            if cfg.enc_layers:  # decoder cross-attention sub-block
                t2, _ = _layer_params(cfg, "xattn", False, 0)
                total, active = total + t2, active + t2
    for _ in range(cfg.enc_layers):
        t, a = _layer_params(cfg, "attn", False, cfg.d_ff)
        total, active = total + t, active + a
    return total, active


def _mixer_seq_flops(cfg: ModelConfig, L_q: int, L_kv: int, per_layer=True) -> float:
    """Attention-style O(L^2)/state flops per token-layer (fwd)."""
    D, Hq, dh = cfg.d_model, cfg.num_heads, cfg.head_dim
    fl = 0.0
    counts = {k: 0 for k in ("attn", "xattn", "mamba", "mlstm", "slstm")}
    for k in cfg.block_pattern:
        counts[k] += 1
    n_per = cfg.n_periods
    decode = L_q == 1
    per = {}
    per["attn"] = 4 * L_kv * Hq * (cfg.qk_nope_dim + cfg.qk_rope_dim + cfg.v_head_dim) / 2 if cfg.use_mla else 4 * L_kv * Hq * dh
    per["xattn"] = 4 * (cfg.num_vision_tokens or cfg.num_enc_frames or 0) * Hq * dh
    dI = cfg.mamba_expand * D
    per["mamba"] = 6 * dI * cfg.mamba_d_state
    # mLSTM: O(L) parallel form in train/prefill, O(1) state update in decode
    per["mlstm"] = 6 * Hq * dh * dh if decode else 4 * L_kv * Hq * dh
    per["slstm"] = 8 * Hq * dh * dh
    for k, c in counts.items():
        fl += c * n_per * per[k] * L_q
    if cfg.first_k_dense:
        fl += cfg.first_k_dense * per["attn"] * L_q
    return fl


def model_flops(cfg: ModelConfig, shape_name: str) -> float:
    """Useful-math FLOPs per step (fwd+bwd for train; fwd for serving)."""
    shape = SHAPES[shape_name]
    B, L = shape.global_batch, shape.seq_len
    _, active = param_counts(cfg)
    if shape.kind == "train":
        tokens = B * L
        return 6 * active * tokens + 3 * _mixer_seq_flops(cfg, L, L // 2) * B
    if shape.kind == "prefill":
        tokens = B * L
        return 2 * active * tokens + _mixer_seq_flops(cfg, L, L // 2) * B
    # decode: one token against an L-long state
    return B * (2 * active + _mixer_seq_flops(cfg, 1, L))


def slstm_correction(cfg: ModelConfig, shape_name: str, chips: int) -> float:
    """Per-device fwd(+bwd) flops of inner sLSTM time-scans (XLA counts the
    while body once; add the missing (L-1)/L share analytically)."""
    n_slstm = sum(1 for k in cfg.block_pattern if k == "slstm") * cfg.n_periods
    if n_slstm == 0:
        return 0.0
    shape = SHAPES[shape_name]
    if shape.kind == "decode":
        return 0.0  # single step: no undercount
    B, L = shape.global_batch, shape.seq_len
    per_step = 8 * cfg.num_heads * cfg.head_dim * cfg.head_dim  # recurrent einsum
    factor = 3 if shape.kind == "train" else 1
    return factor * n_slstm * B * (L - 1) * per_step / chips


# ---------------------------------------------------------------------------
# table generation
# ---------------------------------------------------------------------------
def analyse_record(rec: dict) -> dict | None:
    if not rec.get("ok") or rec.get("skipped"):
        return None
    cfg = ARCHS[rec["arch"]]
    chips = MESH_CHIPS[rec["mesh"]]
    flops = (rec.get("flops") or 0.0) + slstm_correction(cfg, rec["shape"], chips)
    byts = rec.get("bytes_accessed") or 0.0
    coll = rec.get("collective_bytes") or 0.0
    t_c = flops / PEAK_FLOPS
    t_m = byts / HBM_BW
    t_x = coll / ICI_BW
    dom = max(("compute", t_c), ("memory", t_m), ("collective", t_x), key=lambda kv: kv[1])
    mf = model_flops(cfg, rec["shape"]) / chips
    hbm = (rec.get("memory") or {}).get("temp_size_in_bytes")
    args = (rec.get("memory") or {}).get("argument_size_in_bytes")
    return {
        "arch": rec["arch"],
        "shape": rec["shape"],
        "mesh": rec["mesh"],
        "kind": rec["kind"],
        "compute_s": t_c,
        "memory_s": t_m,
        "collective_s": t_x,
        "dominant": dom[0],
        "step_lower_bound_s": max(t_c, t_m, t_x),
        "model_flops_per_chip": mf,
        "useful_fraction": (mf / flops) if flops else 0.0,
        "roofline_fraction": (mf / PEAK_FLOPS) / max(t_c, t_m, t_x) if max(t_c, t_m, t_x) > 0 else 0.0,
        "temp_bytes": hbm,
        "arg_bytes": args,
    }


def load_table(dryrun_dir: str = "results/dryrun") -> list[dict]:
    rows = []
    for f in sorted(pathlib.Path(dryrun_dir).glob("*.json")):
        rec = json.loads(f.read_text())
        row = analyse_record(rec)
        if row:
            rows.append(row)
    return rows


def markdown_table(rows: list[dict]) -> str:
    hdr = ("| arch | shape | mesh | compute s | memory s | collective s | "
           "dominant | useful frac | roofline frac | temp GiB |")
    sep = "|" + "---|" * 10
    lines = [hdr, sep]
    for r in rows:
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
            f"{r['compute_s']:.3g} | {r['memory_s']:.3g} | {r['collective_s']:.3g} | "
            f"{r['dominant']} | {r['useful_fraction']:.2f} | {r['roofline_fraction']:.2f} | "
            f"{(r['temp_bytes'] or 0)/2**30:.1f} |"
        )
    return "\n".join(lines)


if __name__ == "__main__":
    import csv
    import sys

    rows = load_table(sys.argv[1] if len(sys.argv) > 1 else "results/dryrun")
    out = pathlib.Path("results/roofline.csv")
    out.parent.mkdir(exist_ok=True)
    with out.open("w", newline="") as fh:
        w = csv.DictWriter(fh, fieldnames=list(rows[0].keys()))
        w.writeheader()
        w.writerows(rows)
    print(markdown_table(rows))
    print(f"\nwrote {out} ({len(rows)} cells)")

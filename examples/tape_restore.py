"""Multi-pod checkpoint restore from the tape archive, scheduled by the
paper's DP — the framework feature the paper becomes.

A 2-pod cluster restores a sharded checkpoint from the tape tier.  Every
shard is requested once per consumer pod (plus extra consumers for the
embedding shards every host needs early).  The LTSP schedulers order the
reads; mean shard arrival time directly bounds how soon pods can begin
resharding/loading.

Policies come from the solver registry (:mod:`repro.core.solver`); the
``ExecutionContext`` built from ``--backend`` selects the execution engine —
pass ``--backend pallas-interpret`` to plan every cartridge in a few bucketed
device launches (DP *and* SIMPLEDP batch on device now).

Run: PYTHONPATH=src python examples/tape_restore.py [--backend python]
"""

from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.configs import ARCHS, reduced
from repro.core import ExecutionContext
from repro.core.solver import BACKENDS, DEFAULT_BACKEND
from repro.distributed.checkpoint import archive_to_tape, plan_restore
from repro.models.model import init_model
from repro.storage.tape import TapeLibrary


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--backend", default=DEFAULT_BACKEND, choices=list(BACKENDS),
                    help="solver backend for the DP policies")
    args = ap.parse_args()
    cfg = reduced(ARCHS["deepseek-v2-236b"], periods=2)
    params = init_model(jax.random.PRNGKey(0), cfg)

    lib = TapeLibrary(capacity_per_tape=4 * 10**9, u_turn=20_000_000)
    shards = archive_to_tape(lib, "step5000", params, bytes_per_elem=4096)
    print(f"archived {len(shards)} shards on {len(lib.tapes)} tape(s)")

    consumers = {s: 2 for s in shards}  # both pods need every shard
    for s in shards:
        if "embed" in s or "router" in s:
            consumers[s] = 8  # hot shards: every host group wants them early

    print(f"\n{'policy':<10} {'mean arrival':>14} {'last arrival':>14} {'vs dp':>7}")
    results = {}
    for policy in ("nodetour", "gs", "fgs", "simpledp", "dp"):
        backend = args.backend if policy in ("dp", "simpledp") else "python"
        ctx = ExecutionContext(backend=backend)
        try:
            plans = plan_restore(lib, shards, consumers, policy=policy, context=ctx)
        except ValueError as e:
            # e.g. the int32 device-DP magnitude guard on byte-scale tapes
            print(f"[{policy}/{backend}] {e}\n -> falling back to backend='python'")
            backend = "python"
            plans = plan_restore(
                lib, shards, consumers, policy=policy,
                context=ExecutionContext(backend=backend),
            )
        n_req = sum(consumers.values())
        mean = sum(p.total_cost for p in plans) / n_req
        last = max(max(p.service_time.values()) for p in plans)
        results[policy] = (mean, last)
        print(f"{policy:<10} {mean:>14.3g} {last:>14.3g}", end="")
        print(f" {mean / results.get('dp', (mean,))[0]:>6.3f}x" if "dp" in results else "       ")

    dp_mean = results["dp"][0]
    nd_mean = results["nodetour"][0]
    print(f"\nDP-scheduled restore improves mean shard arrival by "
          f"{100 * (1 - dp_mean / nd_mean):.1f}% over the positional sweep.")


if __name__ == "__main__":
    main()

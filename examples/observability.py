"""Observability: virtual-time tracing, exact-int metrics, and exporters.

One seeded constrained-pool serving run (``--requests`` requests, 3 shared
drives under a nonzero mount cost model) executes twice — once bare, once
with the opt-in :class:`~repro.obs.Observability` bundle attached to the
:class:`~repro.core.ExecutionContext` — and the demo proves the three
properties the layer is built on:

* **no-op identity** — the instrumented run's served timeline is
  bit-identical to the bare run's: hooks only *read* already-computed
  exact integers, so attaching a tracer/registry never perturbs a
  schedule, a virtual clock, or a journal byte;
* **exact agreement** — the Prometheus counters reconcile with the
  :class:`~repro.serving.sim.ServiceReport` exactly (served requests,
  batches, solve-cache hits/misses, DP cells): same integers, no sampling,
  no estimation;
* **byte determinism** — two identical seeded runs export byte-identical
  JSONL span logs (spans are keyed by exact virtual time; wall clocks are
  opt-in and off here).

The run's artefacts land in ``--out-dir``: the JSONL span log, a
Prometheus text snapshot, and a Chrome ``trace_event`` file (one thread
lane per drive plus the queue lane — load it in Perfetto / chrome://tracing
to scrub through mounts, solve delays, and batch service on the virtual
clock).

Run: PYTHONPATH=src python examples/observability.py
"""

from __future__ import annotations

import argparse
import json
from pathlib import Path

from repro.obs import (
    Observability,
    chrome_trace,
    prometheus_text,
    spans_jsonl,
    write_chrome_trace,
    write_prometheus,
    write_spans_jsonl,
)
from repro.serving import DriveCosts, demo_library, poisson_trace, serve_trace


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=240)
    ap.add_argument("--rate", type=int, default=250_000,
                    help="mean inter-arrival time (virtual units = bytes)")
    ap.add_argument("--window", type=int, default=400_000,
                    help="accumulate-then-solve hold window")
    ap.add_argument("--drives", type=int, default=3,
                    help="shared drive-pool size")
    ap.add_argument("--seed", type=int, default=20260731)
    ap.add_argument("--out-dir", default="results/obs",
                    help="where the span log / metrics / Chrome trace land")
    args = ap.parse_args()

    costs = DriveCosts(mount=150_000, unmount=60_000, load_seek=30_000)
    trace = poisson_trace(
        demo_library(args.seed), n_requests=args.requests,
        mean_interarrival=args.rate, seed=args.seed,
    )

    def run(obs=None):
        lib = demo_library(args.seed)  # fresh library: runs never share state
        ctx = lib.context if obs is None else lib.context.replace(obs=obs)
        return serve_trace(
            lib, trace, "accumulate", window=args.window,
            n_drives=args.drives, drive_costs=costs, context=ctx,
        )

    def timeline(report):
        return [
            (r.req_id, r.arrival, r.dispatched, r.completed)
            for r in report.served
        ]

    bare = run()
    obs = Observability.enabled()
    report = run(obs)
    s = report.summary()

    # -- no-op identity: instrumentation never perturbs the run --------------
    assert timeline(report) == timeline(bare), (
        "attaching observability changed the served timeline"
    )

    # -- exact agreement: registry counters == report integers ---------------
    m = obs.metrics
    checks = {
        "requests_served_total": report.n_served,
        "batches_total": s["n_batches"],
        "cache_hits_total": s["cache"]["hits"],
        "cache_misses_total": s["cache"]["misses"],
        "cells_evaluated_total": s["cells_evaluated"],
    }
    for name, want in checks.items():
        got = sum(v for _, v in m.counters_named(name))
        assert got == want, f"{name}: counter {got} != report {want}"

    # -- byte determinism: same seed, same bytes ------------------------------
    obs2 = Observability.enabled()
    run(obs2)
    assert spans_jsonl(obs.tracer) == spans_jsonl(obs2.tracer), (
        "two identical seeded runs must export byte-identical span logs"
    )

    out = Path(args.out_dir)
    out.mkdir(parents=True, exist_ok=True)
    n = write_spans_jsonl(obs.tracer, out / "spans.jsonl")
    write_prometheus(m, out / "metrics.prom")
    write_chrome_trace(obs.tracer, out / "trace.chrome.json")

    tracks = sorted({sp.track for sp in obs.tracer.spans})
    print(
        f"{args.requests} requests on {args.drives} shared drives: "
        f"{s['n_batches']} batches, {s['mounts']} mounts, mean sojourn "
        f"{s['mean_sojourn']:.4g}\n"
        f"instrumented run is bit-identical to the bare run; "
        f"{len(checks)} counters reconcile exactly with the report\n"
        f"{n} spans (tracks: {', '.join(tracks)}) -> {out / 'spans.jsonl'}\n"
        f"Chrome trace -> {out / 'trace.chrome.json'} "
        f"({len(chrome_trace(obs.tracer)['traceEvents'])} events; open in "
        f"Perfetto)\nPrometheus snapshot -> {out / 'metrics.prom'}"
    )
    sojourn_lines = [
        ln for ln in prometheus_text(m).splitlines() if ln.startswith("sojourn")
    ]
    print("\nsojourn distribution (exact nearest-rank, virtual time):")
    for ln in sojourn_lines:
        print(f"  {ln}")
    # the JSONL log round-trips: every line is one span, sorted keys
    first = json.loads((out / "spans.jsonl").read_text().splitlines()[0])
    print(f"\nfirst span: {first}")


if __name__ == "__main__":
    main()

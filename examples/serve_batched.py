"""Batched serving: prefill a batch of prompts, then greedy-decode with the
cached serve step (the same code path the dry-run lowers for ``decode_*``).

Run: PYTHONPATH=src python examples/serve_batched.py --arch qwen2.5-3b
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import ARCHS, reduced
from repro.models.model import init_cache, init_model
from repro.serving.serve import make_serve_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-3b", choices=sorted(ARCHS))
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=24)
    ap.add_argument("--new-tokens", type=int, default=32)
    args = ap.parse_args()

    cfg = reduced(ARCHS[args.arch], periods=2)
    params = init_model(jax.random.PRNGKey(0), cfg)
    max_len = args.prompt_len + args.new_tokens
    cache = init_cache(cfg, args.batch, max_len=max_len)

    prompts = jax.random.randint(
        jax.random.PRNGKey(1), (args.batch, args.prompt_len), 0, cfg.vocab_size
    )

    # prefill through the decode path (teacher-forcing the prompt): simple,
    # and exercises exactly what the decode_32k dry-run lowers.
    serve = jax.jit(make_serve_step(cfg))
    t0 = time.time()
    for t in range(args.prompt_len - 1):
        _, _, cache = serve(params, cache, prompts[:, t : t + 1], jnp.int32(t))
    t_prefill = time.time() - t0

    generated = [prompts[:, -1:]]
    t0 = time.time()
    tok = prompts[:, -1:]
    for t in range(args.new_tokens):
        tok, logits, cache = serve(params, cache, tok, jnp.int32(args.prompt_len - 1 + t))
        generated.append(tok)
    jax.block_until_ready(tok)
    t_decode = time.time() - t0

    out = jnp.concatenate(generated, axis=1)
    print(f"arch={cfg.arch_id} (reduced) batch={args.batch}")
    print(f"prefill: {args.prompt_len} tokens in {t_prefill:.2f}s")
    print(
        f"decode:  {args.new_tokens} tokens in {t_decode:.2f}s "
        f"({args.batch * args.new_tokens / t_decode:.0f} tok/s batch-aggregate)"
    )
    print("sample continuations (token ids):")
    for b in range(min(3, args.batch)):
        print(f"  seq{b}: {out[b, :12].tolist()} ...")


if __name__ == "__main__":
    main()

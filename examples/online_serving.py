"""Online tape serving: admission policies vs per-request FIFO, oracle-checked.

Requests for archived objects arrive over virtual time against a robotic tape
library; per-cartridge queues and an admission policy decide when a queue
becomes an LTSP batch for the solver engine.  The discrete-event simulator
replays every emitted schedule and independently recomputes its cost, so the
batching-vs-FIFO improvement printed below is an exact integer fact about the
trace, not a wall-clock anecdote.

Run: PYTHONPATH=src python examples/online_serving.py
"""

from __future__ import annotations

import argparse

from repro.serving.queue import ADMISSIONS, serve_trace
from repro.serving.sim import demo_library, poisson_trace


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=400)
    ap.add_argument("--rate", type=int, default=200_000,
                    help="mean inter-arrival time (virtual units = bytes)")
    ap.add_argument("--window", type=int, default=400_000,
                    help="accumulate-then-solve re-plan window")
    ap.add_argument("--policy", default="dp")
    ap.add_argument("--backend", default="python")
    ap.add_argument("--seed", type=int, default=20260731)
    args = ap.parse_args()

    trace = poisson_trace(
        demo_library(args.seed),
        n_requests=args.requests,
        mean_interarrival=args.rate,
        seed=args.seed,
    )
    print(
        f"{args.requests} requests over {len({r.tape_id for r in trace})} "
        f"cartridges, horizon {trace[-1].time:,} (virtual); solver "
        f"{args.policy}/{args.backend}\n"
    )
    print(f"{'admission':<12}{'mean':>12}{'p95':>12}{'batches':>9}"
          f"{'preempts':>10}{'verified':>10}")
    baseline = None
    for admission in ADMISSIONS:
        lib = demo_library(args.seed)
        report = serve_trace(
            lib,
            trace,
            admission,
            window=args.window if admission == "accumulate" else 0,
            policy=args.policy,
            backend=args.backend,
            cache=lib.cache,
        )
        s = report.summary()
        if admission == "fifo":
            baseline = s["mean_sojourn"]
        print(
            f"{admission:<12}{s['mean_sojourn']:>12.4g}{s['p95_sojourn']:>12.4g}"
            f"{s['n_batches']:>9}{s['n_preemptions']:>10}"
            f"{'yes' if s['all_verified'] else 'NO':>10}"
        )
    print(
        f"\naccumulate-then-solve vs FIFO: every schedule oracle-verified; "
        f"FIFO mean sojourn is the {baseline:,.0f}-unit baseline the batching "
        f"policies beat above."
    )


if __name__ == "__main__":
    main()

"""Online tape serving: admission policies vs per-request FIFO, oracle-checked.

Requests for archived objects arrive over virtual time against a robotic tape
library; per-cartridge queues and an admission policy decide when a queue
becomes an LTSP batch for the solver engine.  The discrete-event simulator
replays every emitted schedule and independently recomputes its cost, so the
batching-vs-FIFO improvement printed below is an exact integer fact about the
trace, not a wall-clock anecdote.

A second table shrinks the drive pool below one-drive-per-cartridge under an
explicit mount/unmount/load-seek cost model — the robotic-arm layer: the
cross-cartridge admissions decide which cartridge each freed drive mounts
next, and ``batched`` plans every mount-ready cartridge of an event tick in
one ``solve_batch`` device launch.

A third table prices the solver itself: a :class:`repro.core.ComputeBudget`
charges virtual time per DP cell evaluated, so the exact DP's optimal
schedules are no longer free under load.  The ``cost-model`` selector
re-picks the policy each tick from queue depth and the recorded per-tick
solve timings — exact DP while queues are shallow, heuristics as depth
grows — and the table shows the per-batch policy mix it actually used.

Run: PYTHONPATH=src python examples/online_serving.py
"""

from __future__ import annotations

import argparse

from repro.core import ComputeBudget
from repro.serving.drives import DriveCosts
from repro.serving.queue import LEGACY_ADMISSIONS, POOL_ADMISSIONS, serve_trace
from repro.serving.sim import demo_library, poisson_trace


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=400)
    ap.add_argument("--rate", type=int, default=200_000,
                    help="mean inter-arrival time (virtual units = bytes)")
    ap.add_argument("--window", type=int, default=400_000,
                    help="accumulate-then-solve re-plan window")
    ap.add_argument("--policy", default="dp")
    ap.add_argument("--backend", default="python")
    ap.add_argument("--mount-cost", type=int, default=150_000)
    ap.add_argument("--unmount-cost", type=int, default=60_000)
    ap.add_argument("--load-seek", type=int, default=30_000)
    ap.add_argument("--seed", type=int, default=20260731)
    args = ap.parse_args()

    trace = poisson_trace(
        demo_library(args.seed),
        n_requests=args.requests,
        mean_interarrival=args.rate,
        seed=args.seed,
    )
    n_tapes = len({r.tape_id for r in trace})
    print(
        f"{args.requests} requests over {n_tapes} cartridges, horizon "
        f"{trace[-1].time:,} (virtual); solver {args.policy}/{args.backend}\n"
    )

    def run(admission, window, n_drives=None, costs=None):
        lib = demo_library(args.seed)
        report = serve_trace(
            lib,
            trace,
            admission,
            window=window,
            policy=args.policy,
            n_drives=n_drives,
            drive_costs=costs,
            context=lib.context.replace(backend=args.backend),
        )
        return report.summary()

    print("one drive per cartridge, free mounts (the PR-3 special case):")
    print(f"{'admission':<12}{'mean':>12}{'p95':>12}{'batches':>9}"
          f"{'preempts':>10}{'verified':>10}")
    baseline = None
    for admission in LEGACY_ADMISSIONS:
        s = run(admission, args.window if admission == "accumulate" else 0)
        if admission == "fifo":
            baseline = s["mean_sojourn"]
        print(
            f"{admission:<12}{s['mean_sojourn']:>12.4g}{s['p95_sojourn']:>12.4g}"
            f"{s['n_batches']:>9}{s['n_preemptions']:>10}"
            f"{'yes' if s['all_verified'] else 'NO':>10}"
        )
    print(
        f"\naccumulate-then-solve vs FIFO: every schedule oracle-verified; "
        f"FIFO mean sojourn is the {baseline:,.0f}-unit baseline the batching "
        f"policies beat above."
    )

    costs = DriveCosts(mount=args.mount_cost, unmount=args.unmount_cost,
                       load_seek=args.load_seek)
    print(
        f"\nshared drive pool (mount={costs.mount:,}, unmount="
        f"{costs.unmount:,}, load_seek={costs.load_seek:,}):"
    )
    print(f"{'admission':<22}{'drives':>7}{'mean':>12}{'p95':>12}"
          f"{'mounts':>8}{'unmounts':>9}")
    for admission in POOL_ADMISSIONS:
        for n_drives in (1, 2, n_tapes):
            s = run(admission, args.window, n_drives=n_drives, costs=costs)
            print(
                f"{admission:<22}{n_drives:>7}{s['mean_sojourn']:>12.4g}"
                f"{s['p95_sojourn']:>12.4g}{s['mounts']:>8}{s['unmounts']:>9}"
            )
    print(
        "\nfewer drives -> more mount contention; 'batched' schedules "
        "identically to per-drive-accumulate but plans each event tick in "
        "one bucketed solve_batch device launch."
    )

    budget = ComputeBudget(solve_time_num=10_000, per_tick=120, hysteresis=1)
    print(
        f"\nload-adaptive solver selection (priced solves: "
        f"{budget.solve_time_num:,} units/DP cell, cost-model budget "
        f"{budget.per_tick} cells/tick, cold re-solves):"
    )
    print(f"{'arm':<18}{'mean':>12}{'p95':>12}{'solve_delay':>13}"
          f"  policy_mix")
    for label, policy, selector in (
        ("dp (fixed)", "dp", "fixed"),
        ("nfgs (fixed)", "nfgs", "fixed"),
        ("cost-model", "dp", "cost-model"),
    ):
        lib = demo_library(args.seed)
        report = serve_trace(
            lib, trace, "per-drive-accumulate", window=args.window,
            policy=policy, selector=selector, n_drives=2, drive_costs=costs,
            context=lib.context.replace(backend=args.backend, budget=budget),
            warm_start=False,
        )
        s = report.summary()
        mix = "+".join(f"{p}:{n}" for p, n in sorted(s["policy_mix"].items()))
        print(
            f"{label:<18}{s['mean_sojourn']:>12.4g}{s['p95_sojourn']:>12.4g}"
            f"{s['total_solve_delay']:>13,}  {mix}"
        )
    print(
        "\nthe selector spends exact-DP cells only where the cost model "
        "predicts they fit the per-tick budget; with --tape-selector unset "
        "(and everywhere above) serving is bit-identical to a pinned policy."
    )


if __name__ == "__main__":
    main()

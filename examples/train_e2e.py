"""End-to-end training driver: data pipeline from the tape tier, training
loop with checkpoint/restart, straggler monitoring, and a simulated
preemption mid-run.

The corpus lives as shards on the simulated tape library; each epoch's shard
fetch order is scheduled with the paper's SimpleDP (low-cost near-optimal),
so time-to-first-batch is minimised — the paper's contribution wired into the
training data path.

Defaults train a reduced granite-8b on CPU for 120 steps in a few minutes;
``--arch``/``--steps``/``--d-model`` scale it up on real hardware
(--preset 100m gives the ~100M-parameter configuration).

Run: PYTHONPATH=src python examples/train_e2e.py --steps 120
"""

from __future__ import annotations

import argparse
import dataclasses
import pathlib
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCHS, reduced
from repro.distributed.checkpoint import load_checkpoint, save_checkpoint
from repro.distributed.fault_tolerance import StragglerMonitor, should_checkpoint
from repro.storage.tape import TapeLibrary, schedule_reads
from repro.training.optimizer import OptConfig
from repro.training.train_step import init_train_state, make_train_step


def build_corpus_on_tape(n_shards: int, shard_tokens: int, vocab: int, seed: int = 0):
    """Synthesise a token corpus and archive it as shards on tape."""
    rng = np.random.default_rng(seed)
    lib = TapeLibrary(capacity_per_tape=10**10, u_turn=5_000_000)
    shards = {}
    for i in range(n_shards):
        name = f"corpus/shard{i:03d}"
        # Zipf unigrams: a learnable marginal so the loss visibly decreases
        data = np.minimum(rng.zipf(1.2, size=shard_tokens), vocab - 1).astype(np.int32)
        shards[name] = data
        lib.store(name, int(data.nbytes))
    return lib, shards


def scheduled_shard_stream(lib, shards, policy="simpledp"):
    """Yield shards in LTSP-scheduled order (per tape), minimising the mean
    arrival time of training data."""
    requests = {name: 1 for name in shards}
    for plan in lib.schedule(requests, policy=policy):
        for name in plan.order:
            yield name, shards[name], plan.service_time[name]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-8b", choices=sorted(ARCHS))
    ap.add_argument("--steps", type=int, default=120)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--preset", default="tiny", choices=["tiny", "100m"])
    ap.add_argument("--preempt-at", type=int, default=None,
                    help="simulate a preemption at this step (default: midway)")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    args = ap.parse_args()

    cfg = reduced(ARCHS[args.arch], periods=2)
    if args.preset == "100m":
        cfg = dataclasses.replace(
            cfg, d_model=768, num_heads=12, num_kv_heads=4, d_ff=2048,
            num_layers=cfg.first_k_dense + 12 * len(cfg.block_pattern),
            vocab_size=32768,
        )
    cfg = dataclasses.replace(cfg, vocab_size=min(cfg.vocab_size, 32768))
    preempt_at = args.preempt_at or args.steps // 2

    # --- corpus on tape, fetch order scheduled by the paper's algorithm ----
    lib, shards = build_corpus_on_tape(
        n_shards=12, shard_tokens=args.batch * args.seq * 16, vocab=cfg.vocab_size
    )
    stream = list(scheduled_shard_stream(lib, shards))
    print(f"corpus: {len(stream)} shards; first shard ready at simulated "
          f"t={stream[0][2]:,} (LTSP-scheduled)")

    tokens_pool = np.concatenate([d for _, d, _ in stream])
    n_batches = len(tokens_pool) // (args.batch * args.seq)
    batches = tokens_pool[: n_batches * args.batch * args.seq].reshape(
        n_batches, args.batch, args.seq
    )

    params, opt = init_train_state(jax.random.PRNGKey(0), cfg)
    n_params = sum(int(np.prod(p.shape)) for p in jax.tree.leaves(params))
    print(f"arch={cfg.arch_id} (reduced) params={n_params/1e6:.1f}M steps={args.steps}")

    step_fn = jax.jit(make_train_step(cfg, OptConfig(
        learning_rate=3e-4, warmup_steps=20, total_steps=args.steps)))
    monitor = StragglerMonitor()
    ckpt = pathlib.Path(args.ckpt_dir)

    def batch_at(i):
        return {"tokens": jnp.asarray(batches[i % n_batches])}

    i = 0
    preempted = False
    losses = []
    while i < args.steps:
        t0 = time.time()
        params, opt, m = step_fn(params, opt, batch_at(i))
        dt = time.time() - t0
        monitor.record("worker0", i, dt)
        losses.append(float(m["loss"]))
        i += 1
        if should_checkpoint(i, every=25, alarms=monitor.stragglers()):
            save_checkpoint(ckpt, i, params=params, opt_state=opt)
        if i == preempt_at and not preempted:
            preempted = True
            print(f"step {i}: simulating preemption — dropping live state")
            save_checkpoint(ckpt, i, params=params, opt_state=opt)
            del params, opt
            # restore through the public API (templates from a fresh init)
            p0, o0 = init_train_state(jax.random.PRNGKey(0), cfg)
            step_no, trees = load_checkpoint(ckpt, params=p0, opt_state=o0)
            params, opt = trees["params"], trees["opt_state"]
            assert step_no == i
            print(f"step {i}: restored from checkpoint, continuing")
        if i % 20 == 0 or i == args.steps:
            print(f"step {i:>4d} loss={losses[-1]:.4f} lr={float(m['lr']):.2e} "
                  f"{dt*1000:.0f} ms/step")

    print(f"\nfinal loss {losses[-1]:.4f} (started {losses[0]:.4f}); "
          f"loss decreased: {losses[-1] < losses[0]}")


if __name__ == "__main__":
    main()

"""Fleet federation: sharded serving, replica routing, and shard outages.

A seeded N-shard archive (:func:`~repro.fleet.demo_fleet`) stores every
logical file on ``--replicas`` shards; one federation-wide arrival trace is
served under three placement strategies while a
:class:`~repro.serving.ShardOutage` darkens a whole shard mid-run:

* **static-hash** — oblivious content-hash routing: keeps hashing requests
  into the dead shard, which strands every post-outage arrival whose other
  replica it ignores;
* **least-loaded** / **replica-affinity** — dynamic routing over live shard
  state (queue depth; depth x drive health x remount cost): both steer
  around the dark shard, and the outage's orphaned requests re-route to
  surviving replicas.

The demo then crashes a journaled federation run (truncating each shard's
write-ahead journal at an arbitrary byte) and shows
:func:`~repro.fleet.recover_fleet` re-executing it bit-identically while
completing every shard journal — recovery works from any cut point.

Run: PYTHONPATH=src python examples/fleet_serving.py
"""

from __future__ import annotations

import argparse
import tempfile
from pathlib import Path

from repro.fleet import (
    demo_fleet,
    fleet_catalog,
    merge_journals,
    recover_fleet,
    serve_fleet_trace,
    shard_journal_path,
)
from repro.serving import DriveCosts, RetryPolicy, ShardOutage, poisson_trace

PLACEMENTS = ("static-hash", "least-loaded", "replica-affinity")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=180)
    ap.add_argument("--rate", type=int, default=30_000,
                    help="mean inter-arrival time (virtual units = bytes)")
    ap.add_argument("--window", type=int, default=400_000,
                    help="accumulate-then-solve hold window")
    ap.add_argument("--shards", type=int, default=3)
    ap.add_argument("--replicas", type=int, default=2)
    ap.add_argument("--drives", type=int, default=2,
                    help="drive-pool size per shard")
    ap.add_argument("--outage-at", type=int, default=1_500_000,
                    help="virtual instant the outage darkens a shard")
    ap.add_argument("--outage-shard", type=int, default=1)
    ap.add_argument("--seed", type=int, default=20260731)
    args = ap.parse_args()

    costs = DriveCosts(mount=150_000, unmount=60_000, load_seek=30_000)
    outages = (ShardOutage(at=args.outage_at, shard=args.outage_shard),)

    def build_fleet():
        return demo_fleet(args.seed, n_shards=args.shards,
                          replicas=args.replicas)

    libs, rmap = build_fleet()
    trace = poisson_trace(
        fleet_catalog(libs, rmap), n_requests=args.requests,
        mean_interarrival=args.rate, seed=args.seed,
    )

    def run(placement, journal=None):
        libs, rmap = build_fleet()  # fresh shards: runs never share state
        return serve_fleet_trace(
            libs, trace, "accumulate", placement=placement,
            replica_map=rmap, outages=outages, window=args.window,
            n_drives=args.drives, drive_costs=costs,
            retry=RetryPolicy(on_exhausted="drop"), journal=journal,
        )

    print(
        f"{args.requests} requests over {args.shards} shards x "
        f"{args.drives} drives, {args.replicas}-way replicas; shard "
        f"{args.outage_shard} goes dark at t={args.outage_at:,}\n"
    )
    print(f"{'placement':<18}{'completed':>10}{'failed':>8}{'rerouted':>10}"
          f"{'p95 sojourn':>14}  routes")
    results = {}
    for pl in PLACEMENTS:
        fr = run(pl)
        results[pl] = fr
        s = fr.summary()
        routes = "/".join(str(fr.routes[i]) for i in range(args.shards))
        print(
            f"{pl:<18}{fr.n_served:>6}/{len(trace):<4}{fr.n_failed:>7}"
            f"{fr.n_rerouted:>10}{int(s['p95_sojourn']):>14,}  {routes}"
        )
    assert results["replica-affinity"].n_served > results["static-hash"].n_served, (
        "replica routing must complete strictly more than oblivious hashing "
        "under a shard outage"
    )
    assert results["replica-affinity"].n_rerouted > 0, (
        "the outage must have re-routed orphaned replicas cross-shard"
    )

    # -- crash a journaled federation mid-run, then recover it ---------------
    with tempfile.TemporaryDirectory() as tmp:
        base = Path(tmp) / "fleet.journal"
        full = run("replica-affinity", journal=str(base))
        ref = {
            i: Path(shard_journal_path(base, i)).read_bytes()
            for i in range(args.shards)
        }
        for i, data in ref.items():  # tear every shard at a different byte
            cut = len(data) * (i + 1) // (args.shards + 1)
            Path(shard_journal_path(base, i)).write_bytes(data[:cut])
        libs, rmap = build_fleet()
        recovered = recover_fleet(
            libs, trace, str(base), "accumulate",
            placement="replica-affinity", replica_map=rmap, outages=outages,
            window=args.window, n_drives=args.drives, drive_costs=costs,
            retry=RetryPolicy(on_exhausted="drop"),
        )
        assert [(r.req_id, r.completed) for r in recovered.merged.served] == \
               [(r.req_id, r.completed) for r in full.merged.served]
        assert all(
            Path(shard_journal_path(base, i)).read_bytes() == ref[i]
            for i in range(args.shards)
        ), "every shard journal completed byte-identically"
        stream = merge_journals(base, args.shards)
        print(
            f"\ncrash recovery: {args.shards} shard journals torn at "
            f"arbitrary bytes -> re-executed, cross-checked, and completed "
            f"byte-identically ({len(stream)} events in the merged stream)."
        )


if __name__ == "__main__":
    main()

"""QoS tape serving: deadlines, SLO reports, and recorded-trace replay.

Requests arrive with a per-request :class:`~repro.serving.qos.QoSSpec`
(absolute deadline + priority class) drawn by the annotated trace generator
(``repro.data.traces.qos_poisson_trace``: interactive requests get tight
deadlines, batch jobs sixteen times the slack).  The trace is written to a
JSONL file and read back — the round trip is bit-exact, and serving the
read-back trace reproduces the original run bit for bit — then served
through the deadline-blind baseline (``fifo-global``) and the
deadline-aware admissions (``edf-global``, ``slack-accumulate``).  The
per-class SLO table (exact nearest-rank p50/p99 sojourn, deadline-miss
rate, max lateness) comes from :func:`repro.serving.qos.slo_report`; every
emitted schedule still passes the discrete-event simulator oracle.

Run: PYTHONPATH=src python examples/qos_serving.py
"""

from __future__ import annotations

import argparse
import tempfile
from pathlib import Path

from repro.data.traces import qos_poisson_trace, read_trace, to_requests, write_trace
from repro.serving import MOUNT_SCHEDULERS, demo_library, serve_trace, slo_report


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=240)
    ap.add_argument("--rate", type=int, default=250_000,
                    help="mean inter-arrival time (virtual units = bytes)")
    ap.add_argument("--window", type=int, default=400_000,
                    help="accumulate-then-solve hold window")
    ap.add_argument("--tightness", type=int, default=8_000_000,
                    help="deadline = arrival + tightness * class slack mult")
    ap.add_argument("--policy", default="dp")
    ap.add_argument("--backend", default="python")
    ap.add_argument("--scheduler", default="greedy",
                    choices=sorted(MOUNT_SCHEDULERS))
    ap.add_argument("--seed", type=int, default=20260731)
    args = ap.parse_args()

    records = qos_poisson_trace(
        demo_library(args.seed),
        n_requests=args.requests,
        mean_interarrival=args.rate,
        seed=args.seed,
        tightness=args.tightness,
    )

    # recorded-trace round trip: write -> read is bit-exact
    with tempfile.TemporaryDirectory() as tmp:
        path = Path(tmp) / "trace.jsonl"
        write_trace(path, records)
        replayed = read_trace(path)
        assert replayed == records, "JSONL trace round-trip must be bit-exact"
        print(f"trace round-trip OK: {len(records)} records through {path.name}")

    trace, qos = to_requests(records, demo_library(args.seed))
    n_deadlines = sum(1 for s in qos.values() if s.deadline is not None)
    print(
        f"{len(trace)} requests ({n_deadlines} with deadlines, tightness "
        f"{args.tightness:,}), {len({r.tape_id for r in trace})} cartridges, "
        f"solver {args.policy}/{args.backend}, scheduler {args.scheduler}\n"
    )

    def run(admission, window):
        lib = demo_library(args.seed)
        return serve_trace(
            lib,
            trace,
            admission,
            window=window,
            policy=args.policy,
            qos=qos,
            mount_scheduler=args.scheduler,
            context=lib.context.replace(backend=args.backend),
        )

    sweep = [
        ("fifo-global", 0),  # deadline-blind baseline
        ("edf-global", 0),
        ("per-drive-accumulate", args.window),
        ("slack-accumulate", args.window),
    ]
    print(f"{'admission':<22}{'missed':>10}{'miss_rate':>11}"
          f"{'p50':>12}{'p99':>14}")
    missed = {}
    for admission, window in sweep:
        report = run(admission, window)
        slo = slo_report(report)
        missed[admission] = report.n_missed
        print(
            f"{admission:<22}{report.n_missed:>7}/{report.n_deadlines:<4}"
            f"{slo.miss_rate:>9.3f}{slo.overall.p50_sojourn:>12,}"
            f"{slo.overall.p99_sojourn:>14,}"
        )
    assert missed["edf-global"] < missed["fifo-global"]
    assert missed["slack-accumulate"] < missed["fifo-global"]

    report = run("slack-accumulate", args.window)
    slo = slo_report(report)
    print("\nslack-accumulate per-class SLO (exact ints):")
    print(f"{'class':<14}{'n':>5}{'missed':>8}{'miss_rate':>11}"
          f"{'p50':>12}{'p99':>14}{'max_late':>12}")
    for c in slo.classes:
        print(
            f"{c.qos_class:<14}{c.n:>5}{c.n_missed:>8}{c.miss_rate:>11.3f}"
            f"{c.p50_sojourn:>12,}{c.p99_sojourn:>14,}{c.max_lateness:>12,}"
        )
    print(
        "\ndeadline-aware admissions beat the deadline-blind baseline at "
        "this tightness; every schedule passed the simulator oracle."
    )


if __name__ == "__main__":
    main()

"""Quickstart: schedule a batch of tape reads with the paper's exact DP.

Builds a small tape, issues a request batch, and compares every registered
scheduling policy's mean service time via the solver engine — then re-solves
the optimal policy on the Pallas device backend (interpret mode) and checks
it reproduces the exact schedule cost.  Also renders the head trajectory of
the optimal schedule as ASCII art.

Run: PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

from repro.core import list_solvers, service_times, virtual_lb
from repro.storage.tape import Tape, schedule_reads


def render_trajectory(inst, detours, width=78):
    """ASCII sketch of the head trajectory implied by a detour list."""
    scale = width / inst.m
    print(" tape:", "".join(
        "#" if any(l <= p / scale < r for l, r in zip(inst.left, inst.right)) else "."
        for p in range(width)
    ))
    t = service_times(inst, detours)
    for i in np.argsort(t):
        bar = int(inst.left[i] * scale)
        size = max(1, int((inst.right[i] - inst.left[i]) * scale))
        print(f"  t={int(t[i]):>8d} |{' ' * bar}{'=' * size}  x{inst.mult[i]}")


def main():
    rng = np.random.default_rng(42)
    tape = Tape("DEMO", capacity=1_000_000, u_turn=2_000)
    for i in range(14):
        tape.append(f"file{i:02d}", int(rng.integers(10_000, 90_000)))

    requests = {f"file{i:02d}": int(rng.integers(1, 9)) for i in [1, 3, 4, 7, 8, 11, 13]}
    print("request batch:", requests, "\n")

    print(f"{'policy':<10} {'mean service':>14} {'vs optimal':>11}")
    plans = {}
    for policy in list_solvers():
        plans[policy] = schedule_reads(tape, requests, policy=policy)
    opt = plans["dp"].mean_service
    for policy, plan in sorted(plans.items(), key=lambda kv: kv[1].mean_service):
        print(f"{policy:<10} {plan.mean_service:>14.1f} {plan.mean_service / opt:>10.3f}x")

    # same policy, device backend: the Pallas wavefront + traceback must land
    # on a schedule with the identical optimal cost
    from repro.core import ExecutionContext

    dev = schedule_reads(
        tape, requests, policy="dp",
        context=ExecutionContext(backend="pallas-interpret"),
    )
    assert dev.total_cost == plans["dp"].total_cost
    print(f"\npallas-interpret backend reproduces OPT = {dev.total_cost} exactly")

    inst, _ = tape.instance(requests)
    print(f"VirtualLB = {virtual_lb(inst)}, OPT = {plans['dp'].total_cost}")
    print("optimal detours:", plans["dp"].detours)
    print("\noptimal head trajectory (files served in this order):")
    render_trajectory(inst, plans["dp"].detours)


if __name__ == "__main__":
    main()

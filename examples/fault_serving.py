"""Fault-tolerant tape serving: failover, retries, and crash recovery.

A seeded :class:`~repro.serving.faults.FaultPlan` injects a drive
hard-failure mid-batch, transient mount failures, a bad media span, and a
transient solver fault into the online serving loop — all at exact
virtual-time instants, so every run is bit-deterministic.  The demo
contrasts three retry policies on the same faulted trace:

* the **no-fault baseline** (what PR-6 serving produces, bit-identical);
* **fail-stop** (:data:`~repro.serving.drives.FAIL_STOP`): aborted and
  unservable requests drop as typed ``FailedRequest`` rows;
* **retry + failover** (:class:`~repro.serving.drives.RetryPolicy`):
  mounts retry with exponential backoff charged in virtual time, media
  aborts re-read, the solver degrades through its backend chain, and the
  failed drive's work remounts on surviving capacity — everything
  completes.

It then crashes a journaled run mid-file (truncating the write-ahead event
journal at an arbitrary byte) and shows :func:`~repro.serving.recover_server`
resuming it bit-identically while completing the journal.

Run: PYTHONPATH=src python examples/fault_serving.py
"""

from __future__ import annotations

import argparse
import tempfile
from pathlib import Path

from repro.serving import (
    FAIL_STOP,
    DriveCosts,
    EventJournal,
    RetryPolicy,
    demo_library,
    poisson_trace,
    recover_server,
    seeded_fault_plan,
    serve_trace,
)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=120)
    ap.add_argument("--rate", type=int, default=150_000,
                    help="mean inter-arrival time (virtual units = bytes)")
    ap.add_argument("--window", type=int, default=400_000,
                    help="accumulate-then-solve hold window")
    ap.add_argument("--drives", type=int, default=3)
    ap.add_argument("--seed", type=int, default=20260731)
    ap.add_argument("--fault-seed", type=int, default=3)
    args = ap.parse_args()

    costs = DriveCosts(mount=150_000, unmount=60_000, load_seek=30_000)

    def build_trace():
        return poisson_trace(
            demo_library(args.seed), n_requests=args.requests,
            mean_interarrival=args.rate, seed=args.seed,
        )

    def run(faults=None, retry=None, journal=None):
        lib = demo_library(args.seed)
        return serve_trace(
            lib, build_trace(), "per-drive-accumulate", window=args.window,
            n_drives=args.drives, drive_costs=costs, context=lib.context,
            faults=faults, retry=retry, journal=journal,
        )

    plan = seeded_fault_plan(
        demo_library(args.seed), build_trace(), seed=args.fault_seed,
        n_drives=args.drives,
    )
    print(
        f"{args.requests} requests over {args.drives} drives; seeded fault "
        f"plan: {len(plan.drive_failures)} drive failure(s), "
        f"{len(plan.mount_faults)} mount fault(s), "
        f"{len(plan.media_faults)} media fault(s), "
        f"{len(plan.solver_faults)} solver fault(s)\n"
    )

    baseline = run()
    arms = [
        ("no faults", baseline),
        ("fail-stop", run(faults=plan, retry=FAIL_STOP)),
        ("retry+failover", run(faults=plan, retry=RetryPolicy(on_exhausted="drop"))),
    ]
    print(f"{'policy':<16}{'completed':>10}{'failed':>8}{'requeued':>10}"
          f"{'retries':>9}{'p95 sojourn':>14}")
    for name, report in arms:
        s = report.summary()
        f = report.fault_stats or {}
        print(
            f"{name:<16}{report.n_served:>6}/{len(build_trace()):<4}"
            f"{report.n_failed:>7}{f.get('requeued', 0):>10}"
            f"{f.get('mount_retries', 0):>9}{s['p95_sojourn']:>14,}"
        )
    failstop, failover = arms[1][1], arms[2][1]
    assert failover.n_served > failstop.n_served, (
        "retry+failover must complete strictly more than fail-stop"
    )
    assert failover.n_served == args.requests, "failover completes everything"

    # -- crash a journaled run mid-file, then recover it --------------------
    with tempfile.TemporaryDirectory() as tmp:
        path = Path(tmp) / "journal.jsonl"
        full = run(faults=plan, retry=RetryPolicy(on_exhausted="drop"),
                   journal=str(path))
        data = path.read_bytes()
        cut = len(data) * 2 // 3  # tear mid-line, mid-run
        path.write_bytes(data[:cut])
        n_events = len(EventJournal.load(path))
        lib = demo_library(args.seed)
        recovered = recover_server(
            lib, build_trace(), str(path), admission="per-drive-accumulate",
            window=args.window, n_drives=args.drives, drive_costs=costs,
            context=lib.context, faults=plan,
            retry=RetryPolicy(on_exhausted="drop"),
        )
        assert [(r.req_id, r.completed) for r in recovered.served] == \
               [(r.req_id, r.completed) for r in full.served]
        assert path.read_bytes() == data, "journal completed byte-identically"
        print(
            f"\ncrash recovery: journal torn at byte {cut}/{len(data)} "
            f"({n_events} intact events) -> re-executed, cross-checked, and "
            f"completed; report bit-identical to the uninterrupted run."
        )


if __name__ == "__main__":
    main()

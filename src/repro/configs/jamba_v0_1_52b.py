"""Jamba-v0.1-52B — Mamba+attention 1:7 hybrid with MoE every other layer
[arXiv:2403.19887; hf].  One scanned period = 8 layers with attention at
position 4 (the Jamba paper's placement); MoE on odd positions."""

from ..models.common import ModelConfig

CONFIG = ModelConfig(
    arch_id="jamba-v0.1-52b",
    family="hybrid",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=14336,
    vocab_size=65536,
    num_experts=16,
    top_k=2,
    moe_d_ff=14336,
    moe_every=2,
    block_pattern=(
        "mamba", "mamba", "mamba", "mamba",
        "attn", "mamba", "mamba", "mamba",
    ),
    mamba_d_state=16,
    mamba_d_conv=4,
    mamba_expand=2,
)

"""Config infrastructure: input shapes, reduced (smoke) configs, registry."""

from __future__ import annotations

import dataclasses

from ..models.common import ModelConfig

__all__ = ["InputShape", "SHAPES", "reduced", "runnable_shapes"]


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


#: The assigned LM shape grid (seq_len x global_batch).  ``decode_*`` /
#: ``long_*`` lower ``serve_step`` (one token against a seq_len KV cache).
SHAPES = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}


def runnable_shapes(cfg: ModelConfig) -> dict[str, InputShape]:
    """Shapes applicable to an architecture.

    ``long_500k`` needs sub-quadratic sequence mixing: full-attention stacks
    would hold a 500k-token KV cache per layer, so the cell is skipped for
    them (DESIGN.md §Arch-applicability) and kept for SSM/hybrid stacks whose
    decode state is O(1) in sequence length.
    """
    out = dict(SHAPES)
    if not cfg.is_subquadratic:
        out.pop("long_500k")
    return out


def reduced(cfg: ModelConfig, periods: int = 2) -> ModelConfig:
    """Smoke-test-scale config of the same family (CPU-runnable).

    Keeps the layer pattern, MoE/MLA/cross structure and head grouping ratio;
    shrinks widths, depths, vocab and expert counts.
    """
    pat = cfg.block_pattern
    heads = 4
    kv = max(1, min(cfg.num_kv_heads, heads // max(1, cfg.num_heads // max(1, cfg.num_kv_heads))))
    kv = heads if cfg.num_kv_heads == cfg.num_heads else max(1, min(2, kv))
    return dataclasses.replace(
        cfg,
        num_layers=cfg.first_k_dense + periods * len(pat),
        d_model=64,
        num_heads=heads,
        num_kv_heads=kv,
        d_head=16 if cfg.d_head else 0,
        d_ff=cfg.d_ff and 128,
        dense_d_ff=cfg.dense_d_ff and 160,
        vocab_size=512,
        num_experts=min(cfg.num_experts, 8),
        top_k=min(cfg.top_k, 2),
        num_shared_experts=min(cfg.num_shared_experts, 1),
        moe_d_ff=cfg.moe_d_ff and 96,
        q_lora_rank=cfg.q_lora_rank and 48,
        kv_lora_rank=cfg.kv_lora_rank and 32,
        qk_nope_dim=16 if cfg.use_mla else cfg.qk_nope_dim,
        qk_rope_dim=8 if cfg.use_mla else cfg.qk_rope_dim,
        v_head_dim=16 if cfg.use_mla else cfg.v_head_dim,
        enc_layers=min(cfg.enc_layers, 2),
        num_vision_tokens=min(cfg.num_vision_tokens, 24),
        num_enc_frames=min(cfg.num_enc_frames, 24),
        mamba_d_state=8,
        mamba_chunk=32,
    )

"""xLSTM-1.3B — mLSTM + sLSTM block stack, no FFN (d_ff = 0)
[arXiv:2405.04517; unverified].  Period of 8: seven matrix-memory blocks and
one scalar-memory (recurrent) block, matching the paper's 7:1 ratio."""

from ..models.common import ModelConfig

CONFIG = ModelConfig(
    arch_id="xlstm-1.3b",
    family="ssm",
    num_layers=48,
    d_model=2048,
    num_heads=4,
    num_kv_heads=4,
    d_ff=0,
    vocab_size=50304,
    block_pattern=(
        "mlstm", "mlstm", "mlstm", "mlstm",
        "mlstm", "mlstm", "mlstm", "slstm",
    ),
)

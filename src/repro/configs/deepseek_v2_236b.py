"""DeepSeek-V2-236B — MLA (kv_lora=512) + fine-grained MoE: 160 routed
experts top-6, 2 shared experts, first layer dense [arXiv:2405.04434; hf]."""

from ..models.common import ModelConfig

CONFIG = ModelConfig(
    arch_id="deepseek-v2-236b",
    family="moe",
    num_layers=60,
    d_model=5120,
    num_heads=128,
    num_kv_heads=128,
    d_ff=1536,
    vocab_size=102400,
    num_experts=160,
    top_k=6,
    num_shared_experts=2,
    moe_d_ff=1536,
    first_k_dense=1,
    dense_d_ff=12288,
    use_mla=True,
    q_lora_rank=1536,
    kv_lora_rank=512,
    qk_nope_dim=128,
    qk_rope_dim=64,
    v_head_dim=128,
    rope_theta=10_000.0,
)

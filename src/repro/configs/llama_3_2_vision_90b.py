"""Llama-3.2-Vision-90B — dense decoder with cross-attention image layers
every 5th layer [hf:meta-llama/Llama-3.2-90B-Vision; unverified].  The vision
tower is a stub: ``input_specs`` provides precomputed, projected patch
embeddings (1601 tokens) that the ``xattn`` layers attend to."""

from ..models.common import ModelConfig

CONFIG = ModelConfig(
    arch_id="llama-3.2-vision-90b",
    family="vlm",
    num_layers=100,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    d_ff=28672,
    vocab_size=128256,
    block_pattern=("attn", "attn", "attn", "attn", "xattn"),
    num_vision_tokens=1601,
    rope_theta=500_000.0,
)

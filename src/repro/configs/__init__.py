"""Architecture registry: ``--arch <id>`` resolves here."""

from .base import SHAPES, InputShape, reduced, runnable_shapes
from .granite_8b import CONFIG as GRANITE_8B
from .deepseek_coder_33b import CONFIG as DEEPSEEK_CODER_33B
from .qwen2_5_3b import CONFIG as QWEN2_5_3B
from .yi_34b import CONFIG as YI_34B
from .jamba_v0_1_52b import CONFIG as JAMBA_V0_1_52B
from .xlstm_1_3b import CONFIG as XLSTM_1_3B
from .kimi_k2_1t_a32b import CONFIG as KIMI_K2_1T_A32B
from .deepseek_v2_236b import CONFIG as DEEPSEEK_V2_236B
from .seamless_m4t_large_v2 import CONFIG as SEAMLESS_M4T_LARGE_V2
from .llama_3_2_vision_90b import CONFIG as LLAMA_3_2_VISION_90B

ARCHS = {
    c.arch_id: c
    for c in [
        GRANITE_8B,
        DEEPSEEK_CODER_33B,
        QWEN2_5_3B,
        YI_34B,
        JAMBA_V0_1_52B,
        XLSTM_1_3B,
        KIMI_K2_1T_A32B,
        DEEPSEEK_V2_236B,
        SEAMLESS_M4T_LARGE_V2,
        LLAMA_3_2_VISION_90B,
    ]
}

__all__ = ["ARCHS", "SHAPES", "InputShape", "reduced", "runnable_shapes"]

"""SeamlessM4T-large-v2 — encoder-decoder transformer backbone
[arXiv:2308.11596; hf].  The modality frontend is a stub: ``input_specs``
provides precomputed speech-frame embeddings for the 24-layer encoder; the
24-layer decoder cross-attends to the encoder output (24L per stack)."""

from ..models.common import ModelConfig

CONFIG = ModelConfig(
    arch_id="seamless-m4t-large-v2",
    family="audio",
    num_layers=24,  # decoder layers (each with a cross-attention sub-block)
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    d_ff=8192,
    vocab_size=256206,
    enc_layers=24,
    num_enc_frames=1500,
    rope_theta=10_000.0,
)

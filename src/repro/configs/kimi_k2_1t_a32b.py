"""Kimi K2 (1T total / 32B active) — trillion-parameter MoE per the
paper-table assignment [arXiv:2501.kimi2; unverified]: 61L, GQA 64H/kv8,
384 experts top-8 with d_ff=2048 per expert, one shared expert, first layer
dense."""

from ..models.common import ModelConfig

CONFIG = ModelConfig(
    arch_id="kimi-k2-1t-a32b",
    family="moe",
    num_layers=61,
    d_model=7168,
    num_heads=64,
    num_kv_heads=8,
    d_ff=2048,
    vocab_size=163840,
    num_experts=384,
    top_k=8,
    num_shared_experts=1,
    moe_d_ff=2048,
    first_k_dense=1,
    dense_d_ff=18432,
    rope_theta=50_000.0,
)

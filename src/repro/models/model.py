"""Model assembly: embeddings + scanned layer periods + head.

One code path covers all ten assigned architectures:

* decoder-only LMs (dense / MoE / MLA) — uniform ``("attn",)`` pattern,
* hybrids (Jamba) — ``("attn","mamba",...)`` period patterns with MoE
  interleave,
* SSM stacks (xLSTM) — ``("mlstm",...,"slstm")`` patterns,
* VLM (Llama-3.2-Vision) — ``xattn`` period entries attending to stub patch
  embeddings,
* encoder-decoder (Seamless) — encoder stack + decoder stack whose layers
  carry an extra cross-attention sub-block.

Layers inside one period may be heterogeneous; periods are homogeneous, so
the whole stack is a single ``lax.scan`` over stacked period parameters with
optional remat — the compiled HLO is O(1) in depth.
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from .blocks import (
    MIXER_APPLY,
    MIXER_INIT,
    Ctx,
    apply_ffn,
    apply_xattn,
    init_attn,
    init_attn_cache,
    init_ffn,
    init_mamba_cache,
    init_mla_cache,
    init_mlstm_cache,
    init_slstm_cache,
    init_xattn_cache,
)
from .common import ModelConfig, apply_moe, embed_init, init_moe, rms_norm

Params = Any


def _resolved_kind(cfg: ModelConfig, kind: str) -> str:
    return "mla" if (kind == "attn" and cfg.use_mla) else kind


def _layer_has_cross(cfg: ModelConfig) -> bool:
    """Enc-dec decoders put a cross-attention sub-block in every layer."""
    return cfg.enc_layers > 0


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------
def _init_layer(key, cfg: ModelConfig, kind: str, *, is_moe: bool, cross: bool,
                d_ff: int | None = None):
    ks = jax.random.split(key, 4)
    rk = _resolved_kind(cfg, kind)
    p = {"ln1": jnp.ones((cfg.d_model,), cfg.pdtype), "mixer": MIXER_INIT[rk](ks[0], cfg)}
    if cross and kind != "xattn":
        p["lnx"] = jnp.ones((cfg.d_model,), cfg.pdtype)
        p["xmixer"] = init_attn(ks[1], cfg)
    if is_moe:
        p["ln2"] = jnp.ones((cfg.d_model,), cfg.pdtype)
        p["moe"] = init_moe(ks[2], cfg)
    elif (d_ff or cfg.d_ff) > 0:
        p["ln2"] = jnp.ones((cfg.d_model,), cfg.pdtype)
        p["ffn"] = init_ffn(ks[3], cfg, d_ff=d_ff)
    return p


def _init_period(key, cfg: ModelConfig):
    ks = jax.random.split(key, len(cfg.block_pattern))
    return {
        f"l{i}": _init_layer(
            ks[i], cfg, kind, is_moe=cfg.is_moe_layer(i), cross=_layer_has_cross(cfg)
        )
        for i, kind in enumerate(cfg.block_pattern)
    }


def init_model(key, cfg: ModelConfig) -> Params:
    keys = jax.random.split(key, 8)
    D, V = cfg.d_model, cfg.vocab_size
    params: dict[str, Any] = {
        "embed": embed_init(keys[0], (V, D), cfg.pdtype),
        "final_norm": jnp.ones((D,), cfg.pdtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = embed_init(keys[1], (D, V), cfg.pdtype)

    # prologue: leading dense layers outside the scan (DeepSeek/Kimi style)
    if cfg.first_k_dense:
        pks = jax.random.split(keys[2], cfg.first_k_dense)
        params["prologue"] = [
            _init_layer(pks[i], cfg, "attn", is_moe=False,
                        cross=_layer_has_cross(cfg),
                        d_ff=cfg.dense_d_ff or cfg.d_ff)
            for i in range(cfg.first_k_dense)
        ]

    # scanned periods (stacked leading axis)
    pkeys = jax.random.split(keys[3], cfg.n_periods)
    params["periods"] = jax.vmap(lambda k: _init_period(k, cfg))(pkeys)

    # encoder stack (enc-dec only): uniform self-attention layers
    if cfg.enc_layers:
        ekeys = jax.random.split(keys[4], cfg.enc_layers)
        enc_cfg = cfg  # same dims
        params["encoder"] = jax.vmap(
            lambda k: _init_layer(k, enc_cfg, "attn", is_moe=False, cross=False)
        )(ekeys)
        params["enc_norm"] = jnp.ones((D,), cfg.pdtype)
    return params


# ---------------------------------------------------------------------------
# apply
# ---------------------------------------------------------------------------
def _apply_layer(lp, x, cfg: ModelConfig, kind: str, ctx: Ctx, cache):
    rk = _resolved_kind(cfg, kind)
    y, new_cache = MIXER_APPLY[rk](lp["mixer"], rms_norm(x, lp["ln1"]), cfg, ctx, cache=cache.get("mix") if cache else None)
    x = x + y
    new_cache = {"mix": new_cache} if new_cache is not None else {}
    if "xmixer" in lp:
        y, xc = apply_xattn(
            lp["xmixer"], rms_norm(x, lp["lnx"]), cfg, ctx,
            cache=cache.get("cross") if cache else None,
        )
        x = x + y
        if xc is not None:
            new_cache["cross"] = xc
    aux = jnp.zeros((), jnp.float32)
    if "moe" in lp:
        y, aux = apply_moe(lp["moe"], rms_norm(x, lp["ln2"]), cfg)
        x = x + y
    elif "ffn" in lp:
        x = x + apply_ffn(lp["ffn"], rms_norm(x, lp["ln2"]), cfg)
    return x, (new_cache if new_cache else None), aux


def _apply_period(pp, x, cfg: ModelConfig, ctx: Ctx, caches):
    new_caches = {}
    aux_total = jnp.zeros((), jnp.float32)
    for i, kind in enumerate(cfg.block_pattern):
        c = caches[f"l{i}"] if caches is not None else None
        x, nc, aux = _apply_layer(pp[f"l{i}"], x, cfg, kind, ctx, c)
        aux_total = aux_total + aux
        if nc is not None:
            new_caches[f"l{i}"] = nc
    if (cfg.act_hints or cfg.seq_parallel) and x.ndim == 3:
        from ..distributed.context import dp_spec, shard_hint
        from jax.sharding import PartitionSpec as P

        if cfg.seq_parallel:
            x = shard_hint(x, lambda m: P(dp_spec(m), "model", None))
        else:
            x = shard_hint(x, lambda m: P(dp_spec(m), None, None))
    return x, (new_caches if new_caches else None), aux_total


def _remat(fn, cfg: ModelConfig):
    if not cfg.remat or cfg.remat_policy == "none":
        return fn
    if cfg.remat_policy == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable
        )
    return jax.checkpoint(fn)


def _run_stack(params, x, cfg: ModelConfig, ctx: Ctx):
    """Train/prefill pass over prologue + scanned periods."""
    aux_total = jnp.zeros((), jnp.float32)
    for lp in params.get("prologue", []):
        x, _, aux = _apply_layer(lp, x, cfg, "attn", ctx, None)
        aux_total = aux_total + aux

    def body(carry, pp):
        h, aux = carry
        h, _, a = _apply_period(pp, h, cfg, ctx, None)
        return (h, aux + a), None

    body = _remat(body, cfg)
    if cfg.scan_layers:
        (x, aux_total), _ = jax.lax.scan(body, (x, aux_total), params["periods"])
    else:
        # unrolled: identical math/params; used by the dry-run so that
        # cost_analysis counts every layer (XLA counts a while body once)
        for i in range(cfg.n_periods):
            pp = jax.tree.map(lambda a: a[i], params["periods"])
            (x, aux_total), _ = body((x, aux_total), pp)
    return x, aux_total


def encode(params, cfg: ModelConfig, enc_embeds: jax.Array) -> jax.Array:
    """Encoder stack over stub frontend embeddings (enc-dec models)."""
    ctx = Ctx(positions=jnp.broadcast_to(
        jnp.arange(enc_embeds.shape[1]), enc_embeds.shape[:2]), causal=False)

    def body(h, lp):
        h, _, _ = _apply_layer(lp, h, cfg, "attn", ctx, None)
        return h, None

    body = _remat(body, cfg)
    x = enc_embeds.astype(cfg.cdtype)
    if cfg.scan_layers:
        x, _ = jax.lax.scan(body, x, params["encoder"])
    else:
        for i in range(cfg.enc_layers):
            x, _ = body(x, jax.tree.map(lambda a: a[i], params["encoder"]))
    return rms_norm(x, params["enc_norm"])


def forward(
    params,
    cfg: ModelConfig,
    tokens: jax.Array,
    *,
    memory: jax.Array | None = None,
) -> tuple[jax.Array, jax.Array]:
    """Full-sequence forward -> (logits [B, L, V] f32, moe aux loss)."""
    B, L = tokens.shape
    x = params["embed"][tokens].astype(cfg.cdtype)
    positions = jnp.broadcast_to(jnp.arange(L), (B, L))
    ctx = Ctx(positions=positions, memory=memory, causal=True)
    x, aux = _run_stack(params, x, cfg, ctx)
    x = rms_norm(x, params["final_norm"])
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = x @ head.astype(x.dtype)
    if cfg.logits_bf16_ce:
        # keep logits in compute dtype, model-sharded over the vocab axis;
        # the fused-onehot CE never gathers the full vocabulary
        from ..distributed.context import dp_spec, shard_hint
        from jax.sharding import PartitionSpec as P

        logits = shard_hint(logits, lambda m: P(dp_spec(m), None, "model"))
    else:
        logits = logits.astype(jnp.float32)
    return logits, aux


# ---------------------------------------------------------------------------
# decode (single token, cached)
# ---------------------------------------------------------------------------
def _init_layer_cache(cfg: ModelConfig, kind: str, batch: int, max_len: int,
                      cross_len: int):
    rk = _resolved_kind(cfg, kind)
    c: dict[str, Any] = {}
    if rk == "attn":
        c["mix"] = init_attn_cache(cfg, batch, max_len)
    elif rk == "mla":
        c["mix"] = init_mla_cache(cfg, batch, max_len)
    elif rk == "mamba":
        c["mix"] = init_mamba_cache(cfg, batch)
    elif rk == "mlstm":
        c["mix"] = init_mlstm_cache(cfg, batch)
    elif rk == "slstm":
        c["mix"] = init_slstm_cache(cfg, batch)
    elif rk == "xattn":
        c["mix"] = init_xattn_cache(cfg, batch, cross_len)
    if _layer_has_cross(cfg) and kind != "xattn":
        c["cross"] = init_xattn_cache(cfg, batch, cross_len)
    return c


def init_cache(cfg: ModelConfig, batch: int, max_len: int):
    """Decode cache pytree; period caches stacked to match scanned params."""
    cross_len = cfg.num_vision_tokens or cfg.num_enc_frames or 1

    def one_period(_):
        return {
            f"l{i}": _init_layer_cache(cfg, kind, batch, max_len, cross_len)
            for i, kind in enumerate(cfg.block_pattern)
        }

    periods = jax.vmap(one_period)(jnp.arange(cfg.n_periods))
    cache = {"periods": periods}
    if cfg.first_k_dense:
        cache["prologue"] = [
            _init_layer_cache(cfg, "attn", batch, max_len, cross_len)
            for _ in range(cfg.first_k_dense)
        ]
    return cache


def warm_cross_cache(params, cfg: ModelConfig, cache, memory: jax.Array):
    """Fill cross-attention K/V caches from the static memory.

    Run once before decoding (the serving stack's prefill of encoder output /
    vision embeddings); afterwards ``decode_step`` never touches ``memory``.
    """
    from .blocks import _proj  # local import to avoid cycle at module load

    mem = memory.astype(cfg.cdtype)
    B, M, _ = mem.shape
    Hkv, dh = cfg.num_kv_heads, cfg.head_dim

    def kv_of(attn_p):
        k = _proj(mem, attn_p["wk"], attn_p.get("bk")).reshape(B, M, Hkv, dh)
        v = _proj(mem, attn_p["wv"], attn_p.get("bv")).reshape(B, M, Hkv, dh)
        return {"k": k.astype(cfg.cdtype), "v": v.astype(cfg.cdtype)}

    new_cache = jax.tree.map(lambda x: x, cache)  # shallow-copy containers
    for i, kind in enumerate(cfg.block_pattern):
        key = f"l{i}"
        pp = params["periods"][key]
        if kind == "xattn":  # VLM image layers: memory KV is the mixer cache
            new_cache["periods"][key]["mix"] = jax.vmap(kv_of)(pp["mixer"])
        if _layer_has_cross(cfg) and kind != "xattn":
            new_cache["periods"][key]["cross"] = jax.vmap(kv_of)(pp["xmixer"])
    if cfg.first_k_dense and _layer_has_cross(cfg):
        for j, lp in enumerate(params["prologue"]):
            new_cache["prologue"][j]["cross"] = kv_of(lp["xmixer"])
    return new_cache


def decode_step(
    params,
    cfg: ModelConfig,
    tokens: jax.Array,  # [B, 1]
    cache,
    pos: jax.Array,  # scalar int32 current position
    *,
    memory: jax.Array | None = None,
):
    """One decode step -> (logits [B, 1, V] f32, new cache)."""
    x = params["embed"][tokens].astype(cfg.cdtype)
    ctx = Ctx(pos=pos, memory=memory, causal=True)

    new_cache: dict[str, Any] = {}
    if cfg.first_k_dense:
        new_pro = []
        for lp, lc in zip(params["prologue"], cache["prologue"]):
            x, nc, _ = _apply_layer(lp, x, cfg, "attn", ctx, lc)
            new_pro.append(nc)
        new_cache["prologue"] = new_pro

    def body(h, scanned):
        pp, pc = scanned
        h, nc, _ = _apply_period(pp, h, cfg, ctx, pc)
        return h, nc

    if cfg.scan_layers:
        x, new_periods = jax.lax.scan(body, x, (params["periods"], cache["periods"]))
    else:
        ncs = []
        for i in range(cfg.n_periods):
            sl = jax.tree.map(lambda a: a[i], (params["periods"], cache["periods"]))
            x, nc = body(x, sl)
            ncs.append(nc)
        new_periods = jax.tree.map(lambda *xs: jnp.stack(xs), *ncs)
    new_cache["periods"] = new_periods

    x = rms_norm(x, params["final_norm"])
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = (x @ head.astype(x.dtype)).astype(jnp.float32)
    return logits, new_cache

"""Shared model substrate: config, primitives, attention, MoE, SSM cells.

Pure JAX (no flax): parameters are plain pytrees of ``jnp.ndarray`` built by
``init_*`` functions; every ``apply`` is a pure function.  Layer stacks are
stored with a leading layer axis and executed with ``jax.lax.scan`` so the
compiled HLO is O(1) in depth (critical for the 512-device dry-run).
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

Params = Any  # nested dict pytree


# ---------------------------------------------------------------------------
# configuration
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class ModelConfig:
    arch_id: str
    family: str  # dense | moe | hybrid | ssm | audio | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    d_head: int = 0  # 0 -> d_model // num_heads
    qkv_bias: bool = False
    rope_theta: float = 500000.0
    # --- MoE ---------------------------------------------------------------
    num_experts: int = 0
    top_k: int = 0
    num_shared_experts: int = 0
    moe_d_ff: int = 0
    first_k_dense: int = 0  # leading dense layers (DeepSeek/Kimi style)
    moe_every: int = 1  # MoE on layers with (idx % moe_every == moe_every-1)
    dense_d_ff: int = 0  # FFN width of the leading dense layers
    # --- MLA (DeepSeek-V2 / Kimi) -------------------------------------------
    use_mla: bool = False
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_nope_dim: int = 128
    qk_rope_dim: int = 64
    v_head_dim: int = 128
    # --- layer pattern (one period, scanned) --------------------------------
    # entries: "attn" | "mamba" | "mlstm" | "slstm" | "xattn" (vision cross)
    block_pattern: tuple[str, ...] = ("attn",)
    # --- Mamba --------------------------------------------------------------
    mamba_d_state: int = 16
    mamba_d_conv: int = 4
    mamba_expand: int = 2
    mamba_chunk: int = 256
    # --- encoder-decoder (audio) / VLM stub frontends -----------------------
    enc_layers: int = 0  # >0: enc-dec; num_layers counts decoder layers
    num_vision_tokens: int = 0  # VLM: precomputed patch embeddings
    num_enc_frames: int = 0  # audio: precomputed frame embeddings
    # --- numerics / training -----------------------------------------------
    compute_dtype: str = "bfloat16"
    param_dtype: str = "float32"
    remat: bool = True
    scan_layers: bool = True
    capacity_factor: float = 1.25
    tie_embeddings: bool = False
    # beyond-paper perf knobs (see EXPERIMENTS.md §Perf)
    remat_policy: str = "full"  # full | dots | none
    decode_mla_absorb: bool = True  # absorbed MLA decode (compressed cache)
    logits_bf16_ce: bool = False  # vocab-sharded bf16 logits + fused-onehot CE
    act_hints: bool = False  # with_sharding_constraint on block boundaries
    seq_parallel: bool = False  # shard sequence over "model" between blocks
    moe_hints: bool = False  # constrain MoE dispatch buffers (EP placement)
    attn_scores_f32: bool = True  # False: bf16 score materialisation (HLO
    # proxy for the fused flash-attention kernel's VMEM-resident scores)
    microbatches: int = 1  # gradient-accumulation microbatches per step
    moe_gather_dispatch: bool = False  # permutation-gather MoE dispatch with
    # custom VJP: fwd AND bwd move tokens by gathers (never buffer-sized
    # scatters, which GSPMD lowers to all-reduces over the full expert buffer)
    attn_q_chunk: int = 0  # >0: chunked (flash-style) causal attention with
    # per-chunk KV prefix slices — triangular compute, bounded score buffers

    @property
    def head_dim(self) -> int:
        return self.d_head or self.d_model // self.num_heads

    @property
    def n_periods(self) -> int:
        assert self.layers_after_prologue % len(self.block_pattern) == 0, (
            self.arch_id,
            self.layers_after_prologue,
            self.block_pattern,
        )
        return self.layers_after_prologue // len(self.block_pattern)

    @property
    def layers_after_prologue(self) -> int:
        return self.num_layers - self.first_k_dense

    def is_moe_layer(self, pos_in_pattern: int) -> bool:
        """Static MoE placement within one scanned period."""
        if self.num_experts == 0:
            return False
        return pos_in_pattern % self.moe_every == self.moe_every - 1

    @property
    def is_subquadratic(self) -> bool:
        """Supports the 500k-token long-context decode shape."""
        return any(k in ("mamba", "mlstm", "slstm") for k in self.block_pattern)

    @property
    def cdtype(self):
        return jnp.dtype(self.compute_dtype)

    @property
    def pdtype(self):
        return jnp.dtype(self.param_dtype)


# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------
def dense_init(key, shape, dtype, scale: float | None = None):
    fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
    scale = scale if scale is not None else fan_in**-0.5
    return (jax.random.normal(key, shape) * scale).astype(dtype)


def embed_init(key, shape, dtype):
    return (jax.random.normal(key, shape) * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# primitives
# ---------------------------------------------------------------------------
def rms_norm(x, w, eps: float = 1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * w.astype(jnp.float32)).astype(dt)


def rope_freqs(positions, dim: int, theta: float):
    """positions [*, L] -> (cos, sin) [*, L, dim/2]."""
    inv = 1.0 / (theta ** (jnp.arange(0, dim, 2, dtype=jnp.float32) / dim))
    ang = positions.astype(jnp.float32)[..., None] * inv
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x, cos, sin):
    """x [..., L, H, D] with (cos, sin) [..., L, D/2] (broadcast over heads)."""
    x1, x2 = jnp.split(x, 2, axis=-1)
    c = cos[..., None, :]
    s = sin[..., None, :]
    return jnp.concatenate(
        [x1 * c - x2 * s, x2 * c + x1 * s], axis=-1
    ).astype(x.dtype)


def causal_attention(q, k, v, *, scale: float, causal: bool = True,
                     q_offset=None, scores_f32: bool = True):
    """Grouped-query attention.

    q [B, Lq, Hq, D], k/v [B, Lk, Hkv, D(v)] with Hq % Hkv == 0.
    ``q_offset``: position of q_i is ``q_offset + i`` (decode: the current
    position; None means Lq == Lk aligned).  ``scores_f32=False``
    materialises scores in bf16 — the HLO-cost proxy for a fused attention
    kernel whose f32 accumulator never leaves VMEM.
    """
    B, Lq, Hq, D = q.shape
    Lk, Hkv = k.shape[1], k.shape[2]
    G = Hq // Hkv
    qg = q.reshape(B, Lq, Hkv, G, D)
    sdt = jnp.float32 if scores_f32 else q.dtype
    logits = jnp.einsum(
        "blhgd,bmhd->bhglm", qg, k, preferred_element_type=sdt
    ).astype(sdt) * jnp.asarray(scale, sdt)
    if causal:
        qpos = jnp.arange(Lq)[:, None] + (0 if q_offset is None else q_offset)
        mask = qpos >= jnp.arange(Lk)[None, :]
        logits = jnp.where(mask[None, None, None], logits, jnp.asarray(-30000.0, sdt))
    w = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhglm,bmhe->blhge", w.astype(v.dtype), v)
    return out.reshape(B, Lq, Hq, v.shape[-1])


def chunked_causal_attention(q, k, v, *, scale: float, chunk: int,
                             scores_f32: bool = True):
    """Causal attention computed one query chunk at a time.

    Chunk ``i`` attends only to the key prefix ``[: (i+1)*chunk]`` (a static
    slice), so compute is triangular (~half of the dense mask) and the live
    score buffer is ``chunk x Lk`` instead of ``Lq x Lk`` — the flash-
    attention schedule expressed at the XLA level.
    """
    B, Lq, Hq, Dh = q.shape
    Lk = k.shape[1]
    assert Lq == Lk and Lq % chunk == 0, (Lq, Lk, chunk)
    outs = []
    for i in range(Lq // chunk):
        hi = (i + 1) * chunk
        qc = q[:, i * chunk : hi]
        out = causal_attention(
            qc, k[:, :hi], v[:, :hi], scale=scale, causal=True,
            q_offset=i * chunk, scores_f32=scores_f32,
        )
        outs.append(out)
    return jnp.concatenate(outs, axis=1)


def swiglu(x, w_gate, w_in, w_out):
    h = jax.nn.silu(x @ w_gate) * (x @ w_in)
    return h @ w_out


def softmax_cross_entropy(logits, labels, z_loss: float = 1e-4,
                          sharded_vocab: bool = False):
    """Mean next-token loss with z-loss; logits [B, L, V], labels [B, L].

    ``sharded_vocab=True`` replaces the label gather with a fused
    iota-select-reduce so the vocab axis can stay model-sharded (the gather
    would otherwise force an all-gather of the full logits).
    """
    if sharded_vocab:
        lse = jax.nn.logsumexp(logits.astype(jnp.float32), axis=-1)
        onehot_sel = jnp.where(
            jax.lax.broadcasted_iota(jnp.int32, logits.shape, logits.ndim - 1)
            == labels[..., None],
            logits.astype(jnp.float32),
            0.0,
        )
        ll = onehot_sel.sum(axis=-1)
    else:
        logits = logits.astype(jnp.float32)
        lse = jax.nn.logsumexp(logits, axis=-1)
        ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    loss = lse - ll
    if z_loss:
        loss = loss + z_loss * lse**2
    return loss.mean()


# ---------------------------------------------------------------------------
# permutation gather/ungather with cheap transposes (MoE dispatch primitive)
# ---------------------------------------------------------------------------
@jax.custom_vjp
def _perm_gather(src, idx, inv_idx, k_axis):
    """``out[i] = src[idx[i]]`` whose VJP is ALSO a gather (via ``inv_idx``).

    ``src`` [N+1, D] (last row is a zero pad for sentinel indices);
    ``idx`` [M] indices into src; ``inv_idx`` carries the inverse mapping the
    backward pass needs:
      * if ``k_axis == 0``: ``inv_idx`` [N, K] lists the ≤K output rows fed by
        each src row (sentinel M) -> bwd sums K gathered cotangents;
      * if ``k_axis < 0``:  ``inv_idx`` [N] is a plain inverse permutation
        (sentinel M) -> bwd is a single gather.
    """
    return src[idx]


def _perm_gather_fwd(src, idx, inv_idx, k_axis):
    return src[idx], (inv_idx, k_axis, src.shape[0])


def _perm_gather_bwd(res, g):
    inv_idx, k_axis, n1 = res
    gpad = jnp.concatenate([g, jnp.zeros((1, g.shape[1]), g.dtype)], axis=0)
    if k_axis == 0:  # [N, K] -> sum over K contributions
        contrib = gpad[inv_idx]  # [N, K, D]
        dsrc = contrib.sum(axis=1)
    else:
        dsrc = gpad[inv_idx]  # [N, D]
    dsrc = jnp.concatenate([dsrc, jnp.zeros((1, g.shape[1]), g.dtype)], axis=0)[:n1]
    return dsrc, None, None, None


_perm_gather.defvjp(_perm_gather_fwd, _perm_gather_bwd)


# ---------------------------------------------------------------------------
# Mixture of Experts (sort-based dispatch with capacity, EP-shardable)
# ---------------------------------------------------------------------------
def init_moe(key, cfg: ModelConfig):
    E, D, F = cfg.num_experts, cfg.d_model, cfg.moe_d_ff or cfg.d_ff
    ks = jax.random.split(key, 5)
    p = {
        "router": dense_init(ks[0], (D, E), jnp.float32),  # router in f32
        "w_gate": dense_init(ks[1], (E, D, F), cfg.pdtype),
        "w_in": dense_init(ks[2], (E, D, F), cfg.pdtype),
        "w_out": dense_init(ks[3], (E, F, D), cfg.pdtype, scale=F**-0.5),
    }
    if cfg.num_shared_experts:
        Fs = F * cfg.num_shared_experts
        k1, k2, k3 = jax.random.split(ks[4], 3)
        p["shared"] = {
            "w_gate": dense_init(k1, (D, Fs), cfg.pdtype),
            "w_in": dense_init(k2, (D, Fs), cfg.pdtype),
            "w_out": dense_init(k3, (Fs, D), cfg.pdtype, scale=Fs**-0.5),
        }
    return p


def apply_moe(p, x, cfg: ModelConfig):
    """Top-k token-choice MoE with sort-based dispatch and capacity drop.

    x [B, L, D] -> [B, L, D] plus the load-balancing aux loss.
    The [E, C, D] expert buffer is the EP-shardable tensor.
    """
    B, L, D = x.shape
    E, K = cfg.num_experts, cfg.top_k
    T = B * L
    xt = x.reshape(T, D)

    logits = (xt.astype(jnp.float32) @ p["router"]).astype(jnp.float32)  # [T, E]
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, K)  # [T, K]
    top_p = top_p / jnp.clip(top_p.sum(-1, keepdims=True), 1e-9)  # renormalise

    # aux load-balancing loss (Switch-style)
    density = jnp.zeros((E,), jnp.float32).at[top_e.reshape(-1)].add(1.0) / (T * K)
    aux = E * jnp.sum(density * probs.mean(0))

    # ---- sort-based dispatch ------------------------------------------------
    S = T * K
    flat_e = top_e.reshape(S)
    flat_tok = jnp.repeat(jnp.arange(T), K)
    flat_w = top_p.reshape(S)
    order = jnp.argsort(flat_e)  # stable
    se, stok, sw = flat_e[order], flat_tok[order], flat_w[order]
    # rank within expert group
    counts = jnp.zeros((E,), jnp.int32).at[se].add(1)
    starts = jnp.concatenate([jnp.zeros((1,), jnp.int32), jnp.cumsum(counts)[:-1]])
    rank = jnp.arange(S) - starts[se]
    C = max(1, int(cfg.capacity_factor * S / E))
    keep = rank < C
    slot = jnp.where(keep, se * C + rank, E * C)  # overflow -> dropped row

    if cfg.moe_gather_dispatch:
        # --- permutation-gather dispatch (cheap fwd AND bwd) ---------------
        # integer index maps (scatters on int vectors only: ~MBs, not the
        # token-buffer-sized scatters GSPMD turns into giant all-reduces)
        inv_tok = jnp.full((E * C + 1,), T, jnp.int32).at[slot].set(stok.astype(jnp.int32))
        slot_per_flat = jnp.full((S,), E * C, jnp.int32).at[order].set(slot.astype(jnp.int32))
        slot_tk = slot_per_flat.reshape(T, K)  # token -> its <=K slots
        inv_flat = jnp.full((E * C + 1,), S, jnp.int32).at[slot].set(order.astype(jnp.int32))

        xt1 = jnp.concatenate([xt.astype(cfg.cdtype), jnp.zeros((1, D), cfg.cdtype)], 0)
        buf = _perm_gather(xt1, inv_tok[: E * C], slot_tk, 0).reshape(E, C, D)
    else:
        buf = jnp.zeros((E * C + 1, D), cfg.cdtype)
        buf = buf.at[slot].set(xt[stok].astype(cfg.cdtype))
        buf = buf[: E * C].reshape(E, C, D)
    if cfg.moe_hints:
        from ..distributed.context import shard_hint
        from jax.sharding import PartitionSpec as P

        # pin the dispatch buffer to expert-parallel placement
        buf = shard_hint(buf, lambda m: P("model", None, None))

    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, p["w_gate"].astype(cfg.cdtype)))
    h = h * jnp.einsum("ecd,edf->ecf", buf, p["w_in"].astype(cfg.cdtype))
    y = jnp.einsum("ecf,efd->ecd", h, p["w_out"].astype(cfg.cdtype))

    y_flat = y.reshape(E * C, D)
    if cfg.moe_gather_dispatch:
        # combine: gather each token's <=K expert outputs back (bwd: gather
        # cotangents through inv_flat — again no buffer-sized scatter)
        y1 = jnp.concatenate([y_flat, jnp.zeros((1, D), y_flat.dtype)], 0)
        z = _perm_gather(y1, slot_tk.reshape(-1), inv_flat[: E * C], -1)
        z = z.reshape(T, K, D)
        out = (z * top_p[..., None].astype(z.dtype)).sum(axis=1)
    else:
        gathered = jnp.where(
            keep[:, None], y_flat[jnp.clip(slot, 0, E * C - 1)], 0.0
        )
        out = jnp.zeros((T, D), cfg.cdtype).at[stok].add(
            gathered * sw[:, None].astype(cfg.cdtype)
        )
    if cfg.moe_hints:
        from ..distributed.context import dp_spec, shard_hint
        from jax.sharding import PartitionSpec as P

        out = shard_hint(out, lambda m: P(dp_spec(m), None))

    if cfg.num_shared_experts:
        out = out + swiglu(
            xt.astype(cfg.cdtype),
            p["shared"]["w_gate"].astype(cfg.cdtype),
            p["shared"]["w_in"].astype(cfg.cdtype),
            p["shared"]["w_out"].astype(cfg.cdtype),
        )
    return out.reshape(B, L, D), aux

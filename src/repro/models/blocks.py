"""Sequence-mixer and FFN blocks with train (full-sequence) and decode paths.

Every mixer exposes::

  init_<kind>(key, cfg)                          -> params
  apply_<kind>(p, x, cfg, ctx, cache=None)       -> (y, new_cache)

``cache is None`` selects the parallel full-sequence path (train/prefill);
otherwise the single-token decode path is used.  ``ctx`` carries side inputs
(positions, encoder output / vision embeddings).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax
import jax.numpy as jnp

from .common import (
    ModelConfig,
    apply_rope,
    causal_attention,
    chunked_causal_attention,
    dense_init,
    rms_norm,
    rope_freqs,
    swiglu,
)


@dataclasses.dataclass
class Ctx:
    """Side inputs threaded through the layer stack."""

    positions: jax.Array | None = None  # [B, L] token positions
    pos: jax.Array | None = None  # scalar decode position
    memory: jax.Array | None = None  # encoder output / vision embeddings
    causal: bool = True


# ---------------------------------------------------------------------------
# grouped-query attention (optionally with QKV bias — Qwen2.5)
# ---------------------------------------------------------------------------
def init_attn(key, cfg: ModelConfig, cross: bool = False):
    D, Hq, Hkv, dh = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], (D, Hq * dh), cfg.pdtype),
        "wk": dense_init(ks[1], (D, Hkv * dh), cfg.pdtype),
        "wv": dense_init(ks[2], (D, Hkv * dh), cfg.pdtype),
        "wo": dense_init(ks[3], (Hq * dh, D), cfg.pdtype, scale=(Hq * dh) ** -0.5),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((Hq * dh,), cfg.pdtype)
        p["bk"] = jnp.zeros((Hkv * dh,), cfg.pdtype)
        p["bv"] = jnp.zeros((Hkv * dh,), cfg.pdtype)
    return p


def _proj(x, w, b=None):
    y = x @ w.astype(x.dtype)
    if b is not None:
        y = y + b.astype(x.dtype)
    return y


def apply_attn(p, x, cfg: ModelConfig, ctx: Ctx, cache=None):
    B, L, D = x.shape
    Hq, Hkv, dh = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q = _proj(x, p["wq"], p.get("bq")).reshape(B, L, Hq, dh)
    k = _proj(x, p["wk"], p.get("bk")).reshape(B, L, Hkv, dh)
    v = _proj(x, p["wv"], p.get("bv")).reshape(B, L, Hkv, dh)

    scale = dh**-0.5
    if cache is None:
        cos, sin = rope_freqs(ctx.positions, dh, cfg.rope_theta)
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)
        if cfg.attn_q_chunk and ctx.causal and L % cfg.attn_q_chunk == 0 and L > cfg.attn_q_chunk:
            out = chunked_causal_attention(
                q, k, v, scale=scale, chunk=cfg.attn_q_chunk,
                scores_f32=cfg.attn_scores_f32)
        else:
            out = causal_attention(q, k, v, scale=scale, causal=ctx.causal,
                                   scores_f32=cfg.attn_scores_f32)
        new_cache = None
    else:
        # decode: append one token to the cache, attend over the full cache
        pos = ctx.pos
        cos, sin = rope_freqs(pos[None, None].astype(jnp.float32), dh, cfg.rope_theta)
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)
        k_cache = jax.lax.dynamic_update_slice(cache["k"], k.astype(cache["k"].dtype), (0, pos, 0, 0))
        v_cache = jax.lax.dynamic_update_slice(cache["v"], v.astype(cache["v"].dtype), (0, pos, 0, 0))
        S = k_cache.shape[1]
        mask = jnp.arange(S) <= pos  # valid prefix
        qg = q.reshape(B, 1, Hkv, Hq // Hkv, dh)
        logits = jnp.einsum("blhgd,bmhd->bhglm", qg, k_cache).astype(jnp.float32) * scale
        logits = jnp.where(mask[None, None, None, None, :], logits, -1e30)
        w = jax.nn.softmax(logits, axis=-1)
        out = jnp.einsum("bhglm,bmhd->blhgd", w.astype(v_cache.dtype), v_cache)
        out = out.reshape(B, 1, Hq, dh)
        new_cache = {"k": k_cache, "v": v_cache}
    y = out.reshape(B, -1, Hq * dh) @ p["wo"].astype(x.dtype)
    return y, new_cache


def init_attn_cache(cfg: ModelConfig, batch: int, max_len: int):
    shape = (batch, max_len, cfg.num_kv_heads, cfg.head_dim)
    return {"k": jnp.zeros(shape, cfg.cdtype), "v": jnp.zeros(shape, cfg.cdtype)}


# ---------------------------------------------------------------------------
# cross-attention to a static memory (VLM image layers / enc-dec decoder)
# ---------------------------------------------------------------------------
def apply_xattn(p, x, cfg: ModelConfig, ctx: Ctx, cache=None):
    B, L, D = x.shape
    Hq, Hkv, dh = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q = _proj(x, p["wq"], p.get("bq")).reshape(B, L, Hq, dh)
    if cache is None or "k" not in cache:
        mem = ctx.memory.astype(x.dtype)
        M = mem.shape[1]
        k = _proj(mem, p["wk"], p.get("bk")).reshape(B, M, Hkv, dh)
        v = _proj(mem, p["wv"], p.get("bv")).reshape(B, M, Hkv, dh)
    else:
        k, v = cache["k"], cache["v"]
    out = causal_attention(q, k, v, scale=dh**-0.5, causal=False,
                           scores_f32=cfg.attn_scores_f32)
    y = out.reshape(B, L, Hq * dh) @ p["wo"].astype(x.dtype)
    new_cache = None if cache is None else {"k": k, "v": v}
    return y, new_cache


def init_xattn_cache(cfg: ModelConfig, batch: int, mem_len: int):
    shape = (batch, mem_len, cfg.num_kv_heads, cfg.head_dim)
    return {"k": jnp.zeros(shape, cfg.cdtype), "v": jnp.zeros(shape, cfg.cdtype)}


# ---------------------------------------------------------------------------
# Multi-head Latent Attention (DeepSeek-V2; absorbed decode)
# ---------------------------------------------------------------------------
def init_mla(key, cfg: ModelConfig):
    D, H = cfg.d_model, cfg.num_heads
    nd, rd, vd = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    qr, kr = cfg.q_lora_rank, cfg.kv_lora_rank
    ks = jax.random.split(key, 8)
    return {
        "wq_a": dense_init(ks[0], (D, qr), cfg.pdtype),
        "q_norm": jnp.ones((qr,), cfg.pdtype),
        "wq_b": dense_init(ks[1], (qr, H * (nd + rd)), cfg.pdtype),
        "wkv_a": dense_init(ks[2], (D, kr + rd), cfg.pdtype),
        "kv_norm": jnp.ones((kr,), cfg.pdtype),
        "wk_b": dense_init(ks[3], (kr, H * nd), cfg.pdtype),
        "wv_b": dense_init(ks[4], (kr, H * vd), cfg.pdtype),
        "wo": dense_init(ks[5], (H * vd, D), cfg.pdtype, scale=(H * vd) ** -0.5),
    }


def apply_mla(p, x, cfg: ModelConfig, ctx: Ctx, cache=None):
    B, L, D = x.shape
    H = cfg.num_heads
    nd, rd, vd = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    kr = cfg.kv_lora_rank
    scale = (nd + rd) ** -0.5

    q = rms_norm(x @ p["wq_a"].astype(x.dtype), p["q_norm"])
    q = (q @ p["wq_b"].astype(x.dtype)).reshape(B, L, H, nd + rd)
    qn, qr_ = q[..., :nd], q[..., nd:]

    kv_a = x @ p["wkv_a"].astype(x.dtype)
    ckv = rms_norm(kv_a[..., :kr], p["kv_norm"])  # [B, L, kr]
    k_rope = kv_a[..., kr:].reshape(B, L, 1, rd)

    if cache is None:
        cos, sin = rope_freqs(ctx.positions, rd, cfg.rope_theta)
        qr_ = apply_rope(qr_, cos, sin)
        k_rope = apply_rope(k_rope, cos, sin)
        kn = (ckv @ p["wk_b"].astype(x.dtype)).reshape(B, L, H, nd)
        v = (ckv @ p["wv_b"].astype(x.dtype)).reshape(B, L, H, vd)
        k = jnp.concatenate([kn, jnp.broadcast_to(k_rope, (B, L, H, rd))], -1)
        qcat = jnp.concatenate([qn, qr_], -1)
        if cfg.attn_q_chunk and ctx.causal and L % cfg.attn_q_chunk == 0 and L > cfg.attn_q_chunk:
            out = chunked_causal_attention(
                qcat, k, v, scale=scale, chunk=cfg.attn_q_chunk,
                scores_f32=cfg.attn_scores_f32)
        else:
            out = causal_attention(qcat, k, v, scale=scale, causal=ctx.causal,
                                   scores_f32=cfg.attn_scores_f32)
        y = out.reshape(B, L, H * vd) @ p["wo"].astype(x.dtype)
        return y, None

    # ---- absorbed decode over the compressed cache -------------------------
    pos = ctx.pos
    cos, sin = rope_freqs(pos[None, None].astype(jnp.float32), rd, cfg.rope_theta)
    qr_ = apply_rope(qr_, cos, sin)
    k_rope = apply_rope(k_rope, cos, sin)
    ckv_cache = jax.lax.dynamic_update_slice(
        cache["ckv"], ckv.astype(cache["ckv"].dtype), (0, pos, 0)
    )
    kr_cache = jax.lax.dynamic_update_slice(
        cache["kr"], k_rope[:, :, 0].astype(cache["kr"].dtype), (0, pos, 0)
    )
    S = ckv_cache.shape[1]
    wk_b = p["wk_b"].astype(x.dtype).reshape(kr, H, nd)
    q_abs = jnp.einsum("blhn,khn->blhk", qn, wk_b)  # [B, 1, H, kr]
    logits = (
        jnp.einsum("blhk,bsk->bhls", q_abs, ckv_cache)
        + jnp.einsum("blhr,bsr->bhls", qr_, kr_cache)
    ).astype(jnp.float32) * scale
    mask = jnp.arange(S) <= pos
    logits = jnp.where(mask[None, None, None, :], logits, -1e30)
    w = jax.nn.softmax(logits, axis=-1)
    out_c = jnp.einsum("bhls,bsk->blhk", w.astype(ckv_cache.dtype), ckv_cache)
    wv_b = p["wv_b"].astype(x.dtype).reshape(kr, H, vd)
    out = jnp.einsum("blhk,khv->blhv", out_c, wv_b)
    y = out.reshape(B, 1, H * vd) @ p["wo"].astype(x.dtype)
    return y, {"ckv": ckv_cache, "kr": kr_cache}


def init_mla_cache(cfg: ModelConfig, batch: int, max_len: int):
    return {
        "ckv": jnp.zeros((batch, max_len, cfg.kv_lora_rank), cfg.cdtype),
        "kr": jnp.zeros((batch, max_len, cfg.qk_rope_dim), cfg.cdtype),
    }


# ---------------------------------------------------------------------------
# Mamba (S6 selective scan, chunked associative scan)
# ---------------------------------------------------------------------------
def init_mamba(key, cfg: ModelConfig):
    D = cfg.d_model
    dI = cfg.mamba_expand * D
    dS = cfg.mamba_d_state
    dC = cfg.mamba_d_conv
    dt_rank = max(1, math.ceil(D / 16))
    ks = jax.random.split(key, 6)
    return {
        "in_proj": dense_init(ks[0], (D, 2 * dI), cfg.pdtype),
        "conv_w": dense_init(ks[1], (dC, dI), cfg.pdtype, scale=dC**-0.5),
        "conv_b": jnp.zeros((dI,), cfg.pdtype),
        "x_proj": dense_init(ks[2], (dI, dt_rank + 2 * dS), cfg.pdtype),
        "dt_proj": dense_init(ks[3], (dt_rank, dI), cfg.pdtype),
        "dt_bias": jnp.zeros((dI,), cfg.pdtype),
        "A_log": jnp.log(
            jnp.broadcast_to(jnp.arange(1, dS + 1, dtype=jnp.float32), (dI, dS))
        ).astype(jnp.float32),
        "D_skip": jnp.ones((dI,), cfg.pdtype),
        "out_proj": dense_init(ks[4], (dI, D), cfg.pdtype, scale=dI**-0.5),
    }


def _mamba_ssm_inputs(p, u, cfg: ModelConfig):
    """u [B, L, dI] -> (dA, dBu, C) selective-scan elements (f32)."""
    dS = cfg.mamba_d_state
    dt_rank = p["dt_proj"].shape[0]
    proj = u @ p["x_proj"].astype(u.dtype)
    dt = jax.nn.softplus(
        proj[..., :dt_rank] @ p["dt_proj"].astype(u.dtype)
        + p["dt_bias"].astype(u.dtype)
    ).astype(jnp.float32)  # [B, L, dI]
    Bc = proj[..., dt_rank : dt_rank + dS].astype(jnp.float32)  # [B, L, dS]
    Cc = proj[..., dt_rank + dS :].astype(jnp.float32)  # [B, L, dS]
    A = -jnp.exp(p["A_log"])  # [dI, dS]
    dA = jnp.exp(dt[..., None] * A)  # [B, L, dI, dS]
    dBu = (dt * u.astype(jnp.float32))[..., None] * Bc[..., None, :]  # [B,L,dI,dS]
    return dA, dBu, Cc


def _conv1d_causal(u, w, b, state=None):
    """Depthwise causal conv; ``state`` [B, dC-1, dI] enables streaming."""
    dC = w.shape[0]
    if state is None:
        pad = jnp.zeros((u.shape[0], dC - 1, u.shape[2]), u.dtype)
    else:
        pad = state.astype(u.dtype)
    full = jnp.concatenate([pad, u], axis=1)
    out = sum(
        full[:, i : i + u.shape[1]] * w[i].astype(u.dtype) for i in range(dC)
    ) + b.astype(u.dtype)
    new_state = full[:, -(dC - 1) :] if dC > 1 else pad
    return out, new_state


def apply_mamba(p, x, cfg: ModelConfig, ctx: Ctx, cache=None):
    B, L, D = x.shape
    dI = cfg.mamba_expand * D
    uz = x @ p["in_proj"].astype(x.dtype)
    u, z = uz[..., :dI], uz[..., dI:]

    if cache is None:
        u, _ = _conv1d_causal(u, p["conv_w"], p["conv_b"])
        u = jax.nn.silu(u)
        dA, dBu, Cc = _mamba_ssm_inputs(p, u, cfg)
        Ck = min(cfg.mamba_chunk, L)
        assert L % Ck == 0
        nCh = L // Ck
        dS = cfg.mamba_d_state

        def chunk(h0, elems):
            dA_c, dBu_c, C_c = elems  # [B, Ck, ...]

            def comb(l, r):
                return (r[0] * l[0], r[0] * l[1] + r[1])

            Acum, Bcum = jax.lax.associative_scan(comb, (dA_c, dBu_c), axis=1)
            h_all = Acum * h0[:, None] + Bcum  # [B, Ck, dI, dS]
            y = jnp.einsum("blds,bls->bld", h_all, C_c)
            return h_all[:, -1], y

        if cfg.remat:
            chunk = jax.checkpoint(chunk)
        h0 = jnp.zeros((B, dI, dS), jnp.float32)
        elems = (
            dA.reshape(B, nCh, Ck, dI, dS).swapaxes(0, 1),
            dBu.reshape(B, nCh, Ck, dI, dS).swapaxes(0, 1),
            Cc.reshape(B, nCh, Ck, dS).swapaxes(0, 1),
        )
        _, ys = jax.lax.scan(chunk, h0, elems)
        y = ys.swapaxes(0, 1).reshape(B, L, dI)
        y = y.astype(x.dtype) + u * p["D_skip"].astype(x.dtype)
        new_cache = None
    else:
        u_c, conv_state = _conv1d_causal(u, p["conv_w"], p["conv_b"], cache["conv"])
        u_c = jax.nn.silu(u_c)
        dA, dBu, Cc = _mamba_ssm_inputs(p, u_c, cfg)
        h = cache["ssm"] * dA[:, 0] + dBu[:, 0]  # [B, dI, dS]
        y = jnp.einsum("bds,bs->bd", h, Cc[:, 0])[:, None]
        y = y.astype(x.dtype) + u_c * p["D_skip"].astype(x.dtype)
        new_cache = {"conv": conv_state.astype(cache["conv"].dtype), "ssm": h}
    out = (y * jax.nn.silu(z)) @ p["out_proj"].astype(x.dtype)
    return out, new_cache


def init_mamba_cache(cfg: ModelConfig, batch: int):
    dI = cfg.mamba_expand * cfg.d_model
    return {
        "conv": jnp.zeros((batch, cfg.mamba_d_conv - 1, dI), cfg.cdtype),
        "ssm": jnp.zeros((batch, dI, cfg.mamba_d_state), jnp.float32),
    }


# ---------------------------------------------------------------------------
# xLSTM cells
# ---------------------------------------------------------------------------
def init_mlstm(key, cfg: ModelConfig):
    D, H, dh = cfg.d_model, cfg.num_heads, cfg.head_dim
    ks = jax.random.split(key, 6)
    return {
        "wq": dense_init(ks[0], (D, H * dh), cfg.pdtype),
        "wk": dense_init(ks[1], (D, H * dh), cfg.pdtype),
        "wv": dense_init(ks[2], (D, H * dh), cfg.pdtype),
        "w_if": dense_init(ks[3], (D, 2 * H), cfg.pdtype, scale=0.02),
        "b_if": jnp.zeros((2 * H,), cfg.pdtype),
        "wo": dense_init(ks[4], (H * dh, D), cfg.pdtype, scale=(H * dh) ** -0.5),
        "ln_out": jnp.ones((H * dh,), cfg.pdtype),
    }


def apply_mlstm(p, x, cfg: ModelConfig, ctx: Ctx, cache=None):
    """Matrix-memory LSTM; parallel (stabilised) form for training, O(1)
    recurrent form for decode.  [arXiv:2405.04517]"""
    B, L, D = x.shape
    H, dh = cfg.num_heads, cfg.head_dim
    q = (x @ p["wq"].astype(x.dtype)).reshape(B, L, H, dh)
    k = (x @ p["wk"].astype(x.dtype)).reshape(B, L, H, dh) * dh**-0.5
    v = (x @ p["wv"].astype(x.dtype)).reshape(B, L, H, dh)
    gates = (x @ p["w_if"].astype(x.dtype) + p["b_if"].astype(x.dtype)).astype(
        jnp.float32
    )
    i_raw, f_raw = gates[..., :H], gates[..., H:]  # [B, L, H]
    log_f = -jax.nn.softplus(-f_raw)  # log sigmoid(f)

    if cache is None:
        F = jnp.cumsum(log_f, axis=1)  # [B, L, H]
        a = i_raw - F  # i[s] - F[s]
        amax = jax.lax.cummax(a, axis=1)
        # Dmat[t, s] = exp(F[t]-F[s]+i[s]-m[t]), m[t] = F[t] + amax[t]
        dmat = jnp.exp(a[:, None] - amax[:, :, None])  # [B, t, s, H]
        t_idx = jnp.arange(L)
        causal = (t_idx[:, None] >= t_idx[None, :])[None, :, :, None]
        dmat = jnp.where(causal, dmat, 0.0)
        scores = jnp.einsum("blhd,bmhd->blmh", q, k).astype(jnp.float32) * dmat
        norm = jnp.maximum(
            jnp.abs(scores.sum(axis=2)), jnp.exp(-(F + amax))
        )  # [B, L, H]
        h = jnp.einsum("blmh,bmhd->blhd", (scores / norm[:, :, None]).astype(v.dtype), v)
        new_cache = None
    else:
        m0, C0, n0 = cache["m"], cache["C"], cache["n"]
        lf, ii = log_f[:, 0], i_raw[:, 0]  # [B, H]
        m1 = jnp.maximum(lf + m0, ii)
        c_f = jnp.exp(lf + m0 - m1)[..., None, None]
        c_i = jnp.exp(ii - m1)[..., None, None]
        kv = jnp.einsum("bhd,bhe->bhde", k[:, 0].astype(jnp.float32), v[:, 0].astype(jnp.float32))
        C1 = c_f * C0 + c_i * kv
        n1 = c_f[..., 0] * n0 + c_i[..., 0] * k[:, 0].astype(jnp.float32)
        qh = q[:, 0].astype(jnp.float32)
        num = jnp.einsum("bhd,bhde->bhe", qh, C1)
        den = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", qh, n1)), jnp.exp(-m1))
        h = (num / den[..., None]).astype(x.dtype)[:, None]
        new_cache = {"m": m1, "C": C1, "n": n1}
    h = rms_norm(h.reshape(B, -1, H * dh), p["ln_out"])
    return h @ p["wo"].astype(x.dtype), new_cache


def init_mlstm_cache(cfg: ModelConfig, batch: int):
    H, dh = cfg.num_heads, cfg.head_dim
    return {
        "m": jnp.full((batch, H), -1e30, jnp.float32),
        "C": jnp.zeros((batch, H, dh, dh), jnp.float32),
        "n": jnp.zeros((batch, H, dh), jnp.float32),
    }


def init_slstm(key, cfg: ModelConfig):
    D, H, dh = cfg.d_model, cfg.num_heads, cfg.head_dim
    ks = jax.random.split(key, 3)
    return {
        # input projections for (z, i, f, o)
        "w_in": dense_init(ks[0], (D, 4 * H * dh), cfg.pdtype),
        "b_in": jnp.zeros((4 * H * dh,), cfg.pdtype),
        # per-head recurrent (block-diagonal) matrices for (z, i, f, o)
        "r": dense_init(ks[1], (4, H, dh, dh), cfg.pdtype),
        "wo": dense_init(ks[2], (H * dh, D), cfg.pdtype, scale=(H * dh) ** -0.5),
    }


def _slstm_step(p, carry, xt, cfg: ModelConfig):
    """One sLSTM step; xt [B, 4*H*dh] pre-projected inputs (f32 math)."""
    H, dh = cfg.num_heads, cfg.head_dim
    c0, n0, h0, m0 = carry  # [B, H, dh] each, m0 [B, H, dh]
    r = p["r"].astype(jnp.float32)
    rec = jnp.einsum("bhd,ghde->gbhe", h0, r)  # [4, B, H, dh]
    pre = xt.reshape(xt.shape[0], 4, H, dh).swapaxes(0, 1) + rec
    z = jnp.tanh(pre[0])
    i_t, f_t, o_t = pre[1], pre[2], jax.nn.sigmoid(pre[3])
    lf = -jax.nn.softplus(-f_t)  # log sigmoid(f)
    m1 = jnp.maximum(lf + m0, i_t)
    c1 = jnp.exp(lf + m0 - m1) * c0 + jnp.exp(i_t - m1) * z
    n1 = jnp.exp(lf + m0 - m1) * n0 + jnp.exp(i_t - m1)
    h1 = o_t * c1 / jnp.maximum(n1, 1e-6)
    return (c1, n1, h1, m1), h1


def apply_slstm(p, x, cfg: ModelConfig, ctx: Ctx, cache=None):
    B, L, D = x.shape
    H, dh = cfg.num_heads, cfg.head_dim
    pre = (x @ p["w_in"].astype(x.dtype) + p["b_in"].astype(x.dtype)).astype(jnp.float32)

    if cache is None:
        carry = (
            jnp.zeros((B, H, dh), jnp.float32),
            jnp.zeros((B, H, dh), jnp.float32),
            jnp.zeros((B, H, dh), jnp.float32),
            jnp.full((B, H, dh), -1e30, jnp.float32),
        )
        step = lambda c, xt: _slstm_step(p, c, xt, cfg)
        if cfg.remat:
            step = jax.checkpoint(step)
        _, hs = jax.lax.scan(step, carry, pre.swapaxes(0, 1))
        h = hs.swapaxes(0, 1).reshape(B, L, H * dh).astype(x.dtype)
        new_cache = None
    else:
        carry = (cache["c"], cache["n"], cache["h"], cache["m"])
        carry, h1 = _slstm_step(p, carry, pre[:, 0], cfg)
        h = h1.reshape(B, 1, H * dh).astype(x.dtype)
        new_cache = dict(zip(("c", "n", "h", "m"), carry))
    return h @ p["wo"].astype(x.dtype), new_cache


def init_slstm_cache(cfg: ModelConfig, batch: int):
    H, dh = cfg.num_heads, cfg.head_dim
    z = lambda: jnp.zeros((batch, H, dh), jnp.float32)
    return {"c": z(), "n": z(), "h": z(), "m": jnp.full((batch, H, dh), -1e30, jnp.float32)}


# ---------------------------------------------------------------------------
# dense FFN
# ---------------------------------------------------------------------------
def init_ffn(key, cfg: ModelConfig, d_ff: int | None = None):
    D = cfg.d_model
    F = d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    return {
        "w_gate": dense_init(ks[0], (D, F), cfg.pdtype),
        "w_in": dense_init(ks[1], (D, F), cfg.pdtype),
        "w_out": dense_init(ks[2], (F, D), cfg.pdtype, scale=F**-0.5),
    }


def apply_ffn(p, x, cfg: ModelConfig):
    return swiglu(
        x,
        p["w_gate"].astype(x.dtype),
        p["w_in"].astype(x.dtype),
        p["w_out"].astype(x.dtype),
    )


MIXER_INIT = {
    "attn": init_attn,
    "xattn": init_attn,
    "mla": init_mla,
    "mamba": init_mamba,
    "mlstm": init_mlstm,
    "slstm": init_slstm,
}
MIXER_APPLY = {
    "attn": apply_attn,
    "xattn": apply_xattn,
    "mla": apply_mla,
    "mamba": apply_mamba,
    "mlstm": apply_mlstm,
    "slstm": apply_slstm,
}

"""Tape-tier model: linear cartridges + LTSP-scheduled batch reads.

This is the system integration of the paper: the framework's cold tier
(training corpora, checkpoint archives) lives on linear tape cartridges; any
batch of read requests against one cartridge is an LTSP instance, and the
mass-storage scheduler orders the reads with the paper's algorithms
(``policy="dp"`` optimal, ``"logdp*"``/``"simpledp"`` low-cost, plus all
baselines) to minimise the mean service time experienced by consumers.

Policy and execution context
----------------------------
Scheduling dispatches through the solver engine (:mod:`repro.core.solver`):
``policy`` names any registered solver (``repro.core.list_solvers()``) and an
:class:`~repro.core.ExecutionContext` says how to run it — backend
(``"python"`` exact CPU, ``"pallas"`` compiled TPU wavefront,
``"pallas-interpret"``), solve memo, bucketing and numeric-guard policy.  A
:class:`TapeLibrary` owns a context (constructor ``context=``): every
:meth:`TapeLibrary.schedule` call uses it unless the call passes its own.
On the device backends :meth:`TapeLibrary.schedule` packs every cartridge's
instance into a few size-bucketed device launches
(:func:`repro.core.solve_batch`) and reconstructs each cartridge's detour
schedule from the kernel's argmin planes.

Serving loops re-plan the same cartridges constantly (the same checkpoint
restore, the same hot corpus slice), so hang a
:class:`repro.core.SolveCache` on the library context
(``context=ExecutionContext(cache=SolveCache())``) and repeated identical
request multisets skip the solver entirely — only novel tapes reach a
backend.  The pre-context ``backend=``/``cache=`` keywords remain available
on every entry point as warning-emitting deprecation shims.

Everything is integer-exact and simulation-backed: every plan's
``total_cost`` equals the trajectory simulator's score of its detours
regardless of policy or backend.

For *online* serving the library also owns per-cartridge pending-request
queues (:class:`PendingQueue`, via :meth:`TapeLibrary.enqueue` /
:meth:`TapeLibrary.pending`): requests arriving over virtual time accumulate
per cartridge until the admission policy in :mod:`repro.serving.queue` turns
a queue into an LTSP batch for a drive from the shared
:class:`~repro.serving.drives.DrivePool`.
"""

from __future__ import annotations

import dataclasses
import heapq

import numpy as np

from ..core import make_instance, service_times, solve_batch, virtual_lb
from ..core.context import ExecutionContext, resolve_context
from ..core.instance import Instance
from ..core.solver import SolveCache, SolveResult, solve

__all__ = [
    "TapeFile",
    "Tape",
    "TapeLibrary",
    "PendingQueue",
    "ReadPlan",
    "schedule_reads",
]

#: head repositioning penalty per U-turn, in position units (bytes here).
DEFAULT_U_TURN = 2_000_000


@dataclasses.dataclass(frozen=True)
class TapeFile:
    name: str
    left: int
    size: int

    @property
    def right(self) -> int:
        return self.left + self.size


class Tape:
    """One cartridge: files appended left-to-right (sequential writes)."""

    def __init__(self, tape_id: str, capacity: int, u_turn: int = DEFAULT_U_TURN):
        self.tape_id = tape_id
        self.capacity = capacity
        self.u_turn = u_turn
        self.files: dict[str, TapeFile] = {}
        self._cursor = 0

    @property
    def used(self) -> int:
        return self._cursor

    def append(self, name: str, size: int) -> TapeFile:
        if name in self.files:
            raise ValueError(f"duplicate file {name!r} on {self.tape_id}")
        if self._cursor + size > self.capacity:
            raise ValueError(f"tape {self.tape_id} full")
        f = TapeFile(name, self._cursor, size)
        self.files[name] = f
        self._cursor += size
        return f

    def instance(self, requests: dict[str, int]) -> tuple[Instance, list[str]]:
        """Build the LTSP instance for a request batch {name: multiplicity}."""
        names = sorted(requests, key=lambda n: self.files[n].left)
        fs = [self.files[n] for n in names]
        inst = make_instance(
            left=[f.left for f in fs],
            size=[f.size for f in fs],
            mult=[requests[n] for n in names],
            m=self.capacity,
            u_turn=self.u_turn,
        )
        return inst, names


class PendingQueue:
    """Ordered pending-request queue for one cartridge.

    Items must be mutually comparable (the online serving layer pushes
    :class:`repro.serving.sim.Request`, which orders by arrival time then
    request id); :meth:`pop`/:meth:`drain` return them oldest-first, so a
    preempted request re-enters ahead of later arrivals.
    """

    def __init__(self) -> None:
        self._heap: list = []

    def __len__(self) -> int:
        return len(self._heap)

    def __iter__(self):
        """Non-destructive iteration in arbitrary (heap) order.

        For order-insensitive scans only (e.g. the QoS layer's
        earliest-queued-deadline lookup); use :meth:`drain` for ordered
        removal.
        """
        return iter(self._heap)

    def push(self, item) -> None:
        heapq.heappush(self._heap, item)

    def peek(self):
        if not self._heap:
            raise IndexError("peek from an empty PendingQueue")
        return self._heap[0]

    def pop(self):
        if not self._heap:
            raise IndexError("pop from an empty PendingQueue")
        return heapq.heappop(self._heap)

    def drain(self) -> list:
        """Remove and return every pending item, oldest first."""
        out = [heapq.heappop(self._heap) for _ in range(len(self._heap))]
        return out


@dataclasses.dataclass
class ReadPlan:
    """Scheduled batch read for one tape."""

    tape_id: str
    policy: str
    order: list[str]  # file names in service order
    service_time: dict[str, int]  # per-file completion time
    total_cost: int  # sum over requests (the LTSP objective)
    mean_service: float  # total_cost / n requests
    virtual_lb: int
    detours: list[tuple[int, int]]
    backend: str = "python"


def _plan_from_result(
    tape: Tape, inst: Instance, names: list[str], res: SolveResult
) -> ReadPlan:
    t = service_times(inst, res.detours)
    order = [names[i] for i in np.argsort(t, kind="stable")]
    return ReadPlan(
        tape_id=tape.tape_id,
        policy=res.policy,
        order=order,
        service_time={names[i]: int(t[i]) for i in range(len(names))},
        total_cost=res.cost,
        mean_service=res.cost / inst.n,
        virtual_lb=virtual_lb(inst),
        detours=list(res.detours),
        backend=res.backend,
    )


def schedule_reads(
    tape: Tape,
    requests: dict[str, int],
    policy: str = "simpledp",
    backend: str | None = None,
    cache: SolveCache | None = None,
    *,
    context: ExecutionContext | None = None,
) -> ReadPlan:
    """Order a batch of reads on one tape with an LTSP policy.

    ``context`` selects backend/cache/numeric options;
    ``backend=``/``cache=`` are the deprecated spellings (see
    :mod:`repro.core.context`).
    """
    ctx = resolve_context(context, backend=backend, cache=cache)
    inst, names = tape.instance(requests)
    res = solve(inst, policy=policy, context=ctx)
    return _plan_from_result(tape, inst, names, res)


class TapeLibrary:
    """A robotic library: many cartridges, simple fill placement.

    The library owns an :class:`~repro.core.ExecutionContext` shared by every
    :meth:`schedule` call (hang a :class:`~repro.core.SolveCache` on it so
    serving/restore loops never re-solve an identical tape).  The pre-context
    ``cache=`` constructor keyword is a warning-emitting deprecation shim.
    """

    def __init__(
        self,
        capacity_per_tape: int,
        u_turn: int = DEFAULT_U_TURN,
        cache: SolveCache | None = None,
        *,
        context: ExecutionContext | None = None,
    ):
        self.capacity = capacity_per_tape
        self.u_turn = u_turn
        self.tapes: list[Tape] = []
        self.location: dict[str, str] = {}  # file -> tape_id
        #: execution context shared by every schedule() call on this library.
        self.context = resolve_context(context, cache=cache)
        #: per-cartridge pending read requests (the online serving queues).
        self.queues: dict[str, PendingQueue] = {}

    @property
    def cache(self) -> SolveCache | None:
        """The context's solve memo (read-only convenience view)."""
        return self.context.cache

    def _tape_with_room(self, size: int) -> Tape:
        for t in self.tapes:
            if t.used + size <= t.capacity:
                return t
        t = Tape(f"TAPE{len(self.tapes):03d}", self.capacity, self.u_turn)
        self.tapes.append(t)
        return t

    def store(self, name: str, size: int) -> TapeFile:
        t = self._tape_with_room(size)
        f = t.append(name, size)
        self.location[name] = t.tape_id
        return f

    def tape_of(self, name: str) -> Tape:
        tid = self.location[name]
        return next(t for t in self.tapes if t.tape_id == tid)

    # -- online request queues (used by repro.serving.queue) -----------------
    def enqueue(self, name: str, item) -> str:
        """Queue a pending read of ``name`` on its cartridge; returns tape id."""
        tid = self.location[name]
        self.pending(tid).push(item)
        return tid

    def pending(self, tape_id: str) -> PendingQueue:
        """The cartridge's pending-request queue (created on first use)."""
        return self.queues.setdefault(tape_id, PendingQueue())

    def schedule(
        self,
        requests: dict[str, int],
        policy: str = "simpledp",
        backend: str | None = None,
        cache: SolveCache | None = None,
        *,
        context: ExecutionContext | None = None,
    ) -> list[ReadPlan]:
        """Split a request batch per tape and schedule each.

        Cartridges are independent LTSP instances; device backends solve
        every cartridge's instance in a few size-bucketed launches
        (:func:`repro.core.solve_batch`).  The library's own context applies
        unless the call passes ``context=`` (or the deprecated
        ``backend=``/``cache=`` keywords, which warn and fold over the
        library context).
        """
        ctx = resolve_context(
            context, backend=backend, cache=cache, default=self.context
        )
        per_tape: dict[str, dict[str, int]] = {}
        for name, k in requests.items():
            per_tape.setdefault(self.location[name], {})[name] = k
        tapes = {t.tape_id: t for t in self.tapes}
        triples = []
        for tid, reqs in sorted(per_tape.items()):
            inst, names = tapes[tid].instance(reqs)
            triples.append((tapes[tid], inst, names))
        results = solve_batch(
            [inst for _, inst, _ in triples], policy, context=ctx
        )
        return [
            _plan_from_result(tape, inst, names, res)
            for (tape, inst, names), res in zip(triples, results)
        ]

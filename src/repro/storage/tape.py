"""Tape-tier model: linear cartridges + LTSP-scheduled batch reads.

This is the system integration of the paper: the framework's cold tier
(training corpora, checkpoint archives) lives on linear tape cartridges; any
batch of read requests against one cartridge is an LTSP instance, and the
mass-storage scheduler orders the reads with the paper's algorithms
(``policy="dp"`` optimal, ``"logdp*"``/``"simpledp"`` low-cost, plus all
baselines) to minimise the mean service time experienced by consumers.

Everything is integer-exact and simulation-backed: ``read_batch`` returns the
service time of every request as produced by the trajectory simulator in
:mod:`repro.core.schedule`.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from ..core import ALGORITHMS, evaluate_detours, make_instance, service_times, virtual_lb
from ..core.instance import Instance

__all__ = ["TapeFile", "Tape", "TapeLibrary", "ReadPlan", "schedule_reads"]

#: head repositioning penalty per U-turn, in position units (bytes here).
DEFAULT_U_TURN = 2_000_000


@dataclasses.dataclass(frozen=True)
class TapeFile:
    name: str
    left: int
    size: int

    @property
    def right(self) -> int:
        return self.left + self.size


class Tape:
    """One cartridge: files appended left-to-right (sequential writes)."""

    def __init__(self, tape_id: str, capacity: int, u_turn: int = DEFAULT_U_TURN):
        self.tape_id = tape_id
        self.capacity = capacity
        self.u_turn = u_turn
        self.files: dict[str, TapeFile] = {}
        self._cursor = 0

    @property
    def used(self) -> int:
        return self._cursor

    def append(self, name: str, size: int) -> TapeFile:
        if name in self.files:
            raise ValueError(f"duplicate file {name!r} on {self.tape_id}")
        if self._cursor + size > self.capacity:
            raise ValueError(f"tape {self.tape_id} full")
        f = TapeFile(name, self._cursor, size)
        self.files[name] = f
        self._cursor += size
        return f

    def instance(self, requests: dict[str, int]) -> tuple[Instance, list[str]]:
        """Build the LTSP instance for a request batch {name: multiplicity}."""
        names = sorted(requests, key=lambda n: self.files[n].left)
        fs = [self.files[n] for n in names]
        inst = make_instance(
            left=[f.left for f in fs],
            size=[f.size for f in fs],
            mult=[requests[n] for n in names],
            m=self.capacity,
            u_turn=self.u_turn,
        )
        return inst, names


@dataclasses.dataclass
class ReadPlan:
    """Scheduled batch read for one tape."""

    tape_id: str
    policy: str
    order: list[str]  # file names in service order
    service_time: dict[str, int]  # per-file completion time
    total_cost: int  # sum over requests (the LTSP objective)
    mean_service: float  # total_cost / n requests
    virtual_lb: int
    detours: list[tuple[int, int]]


def schedule_reads(
    tape: Tape, requests: dict[str, int], policy: str = "simpledp"
) -> ReadPlan:
    """Order a batch of reads on one tape with an LTSP policy."""
    if policy not in ALGORITHMS:
        raise KeyError(f"unknown policy {policy!r}; choose from {sorted(ALGORITHMS)}")
    inst, names = tape.instance(requests)
    detours = ALGORITHMS[policy](inst)
    t = service_times(inst, detours)
    cost = evaluate_detours(inst, detours)
    order = [names[i] for i in np.argsort(t, kind="stable")]
    return ReadPlan(
        tape_id=tape.tape_id,
        policy=policy,
        order=order,
        service_time={names[i]: int(t[i]) for i in range(len(names))},
        total_cost=cost,
        mean_service=cost / inst.n,
        virtual_lb=virtual_lb(inst),
        detours=list(detours),
    )


class TapeLibrary:
    """A robotic library: many cartridges, simple fill placement."""

    def __init__(self, capacity_per_tape: int, u_turn: int = DEFAULT_U_TURN):
        self.capacity = capacity_per_tape
        self.u_turn = u_turn
        self.tapes: list[Tape] = []
        self.location: dict[str, str] = {}  # file -> tape_id

    def _tape_with_room(self, size: int) -> Tape:
        for t in self.tapes:
            if t.used + size <= t.capacity:
                return t
        t = Tape(f"TAPE{len(self.tapes):03d}", self.capacity, self.u_turn)
        self.tapes.append(t)
        return t

    def store(self, name: str, size: int) -> TapeFile:
        t = self._tape_with_room(size)
        f = t.append(name, size)
        self.location[name] = t.tape_id
        return f

    def tape_of(self, name: str) -> Tape:
        tid = self.location[name]
        return next(t for t in self.tapes if t.tape_id == tid)

    def schedule(self, requests: dict[str, int], policy: str = "simpledp") -> list[ReadPlan]:
        """Split a request batch per tape and schedule each (one drive per
        cartridge; cartridges are independent LTSP instances)."""
        per_tape: dict[str, dict[str, int]] = {}
        for name, k in requests.items():
            per_tape.setdefault(self.location[name], {})[name] = k
        return [
            schedule_reads(next(t for t in self.tapes if t.tape_id == tid), reqs, policy)
            for tid, reqs in sorted(per_tape.items())
        ]

"""Merged federation accounting: per-shard reports -> one exact report.

:func:`merge_reports` folds the shards'
:class:`~repro.serving.sim.ServiceReport` rows into a single federated
report — served/failed rows re-sorted under the same total orders the
single-server report uses, counters summed, horizon maximised, all exact
integers — so every downstream consumer (SLO accounting via
:func:`repro.serving.qos.slo_report`, benchmark summaries, assertions)
reads a fleet exactly like it reads one server.  :class:`FleetReport`
carries the merged report next to the per-shard originals plus the
federation-level facts (placement, routing counts, cross-shard reroutes,
injected outages).
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

from ..serving.faults import ShardOutage
from ..serving.sim import ServiceReport

__all__ = ["FleetReport", "merge_reports"]


def _sum_dicts(dicts: list[dict | None]) -> dict | None:
    """Key-wise integer sum over the non-None dicts (union of keys)."""
    present = [d for d in dicts if d is not None]
    if not present:
        return None
    out: dict = {}
    for d in present:
        for k, v in d.items():
            out[k] = out.get(k, 0) + v
    return out


def merge_reports(reports: Sequence[ServiceReport]) -> ServiceReport:
    """One federated :class:`~repro.serving.sim.ServiceReport` from shards.

    The merge is exact and deterministic: served rows re-sort under the
    single-server order ``(completed, req_id)``, failed rows under
    ``(failed_at, req_id)``, batch rows concatenate in shard order,
    counters and pool/cache/fault statistics sum key-wise (conditional
    sections stay absent when absent on *every* shard, so a fault-free
    fleet report is key-for-key shaped like a fault-free single-server
    report), the horizon is the latest shard's, and the QoS map is the
    union — request ids are fleet-global, so
    :func:`repro.serving.qos.slo_report` on the merged report yields the
    federation's exact-int quantiles directly.  Shards must agree on the
    run configuration (admission/policy/backend/window/scheduler/
    warm-start/selector); with a cache backend *shared* across shards,
    the summed cache statistics count that backend once per shard.
    """
    reports = list(reports)
    if not reports:
        raise ValueError("merge_reports needs at least one shard report")
    first = reports[0]
    for i, r in enumerate(reports[1:], start=1):
        for field in (
            "admission",
            "policy",
            "backend",
            "window",
            "scheduler",
            "warm_start",
            "selector",
        ):
            if getattr(r, field) != getattr(first, field):
                raise ValueError(
                    f"shard {i} disagrees on {field}: "
                    f"{getattr(r, field)!r} != {getattr(first, field)!r}"
                )
    qos: dict = {}
    for r in reports:
        if r.qos:
            qos.update(r.qos)
    return ServiceReport(
        admission=first.admission,
        policy=first.policy,
        backend=first.backend,
        window=first.window,
        served=sorted(
            (s for r in reports for s in r.served),
            key=lambda s: (s.completed, s.req_id),
        ),
        batches=[b for r in reports for b in r.batches],
        n_preemptions=sum(r.n_preemptions for r in reports),
        horizon=max(r.horizon for r in reports),
        cache_stats=_sum_dicts([r.cache_stats for r in reports]),
        pool_stats=_sum_dicts([r.pool_stats for r in reports]),
        scheduler=first.scheduler,
        qos=qos or None,
        warm_start=first.warm_start,
        failed=sorted(
            (f for r in reports for f in r.failed),
            key=lambda f: (f.failed_at, f.req_id),
        ),
        fault_stats=_sum_dicts([r.fault_stats for r in reports]),
        selector=first.selector,
    )


@dataclasses.dataclass(frozen=True)
class FleetReport:
    """Outcome of one federated serving run (per-shard + merged views)."""

    shards: tuple[ServiceReport, ...]
    merged: ServiceReport
    placement: str
    n_shards: int
    #: shard index -> requests the placement routed there (reroutes included)
    routes: dict[int, int]
    #: queued orphans re-routed cross-shard by outages (``faulted`` rows)
    n_rerouted: int
    outages: tuple[ShardOutage, ...] = ()

    # -- merged-view conveniences (exact ints) -------------------------------
    @property
    def n_served(self) -> int:
        return self.merged.n_served

    @property
    def n_failed(self) -> int:
        return self.merged.n_failed

    @property
    def total_sojourn(self) -> int:
        return self.merged.total_sojourn

    @property
    def n_missed(self) -> int:
        return self.merged.n_missed

    def summary(self) -> dict:
        """Machine-readable row: the merged summary plus federation facts."""
        out = self.merged.summary()
        out["fleet"] = {
            "n_shards": self.n_shards,
            "placement": self.placement,
            "routes": {str(k): v for k, v in sorted(self.routes.items())},
            "n_rerouted": self.n_rerouted,
            "n_outages": len(self.outages),
            "per_shard_served": [r.n_served for r in self.shards],
        }
        return out

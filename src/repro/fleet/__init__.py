"""Fleet federation: sharded multi-library serving on one exact clock.

The serving stack (:mod:`repro.serving`) simulates *one* robotic tape
library.  This package federates N of them — each shard an unmodified
:class:`~repro.serving.queue.OnlineTapeServer` over its own
:class:`~repro.storage.tape.TapeLibrary` — behind a single arrival stream
in shared exact virtual time:

* :mod:`~repro.fleet.placement` — :class:`ReplicaMap` (logical file ->
  replica-holding shards, validated against the libraries) and the
  :class:`PlacementStrategy` protocol + registry: ``single`` (one-shard
  NoOp default, pinned bit-identical to a standalone server),
  ``static-hash``, ``least-loaded``, and ``replica-affinity`` (queue depth
  x drive health x remount cost);
* :mod:`~repro.fleet.server` — :class:`FleetServer` /
  :func:`serve_fleet_trace` (static pre-partition or lock-step interleave),
  :class:`~repro.serving.faults.ShardOutage` handling with cross-shard
  requeue of orphaned replicas, per-shard write-ahead journals with
  :func:`recover_fleet` (byte-identical redo recovery from any cut point)
  and :func:`merge_journals`, plus the :func:`demo_fleet` seeded archive
  and :func:`fleet_catalog` trace-generation facade;
* :mod:`~repro.fleet.report` — :func:`merge_reports` /
  :class:`FleetReport`: one federated
  :class:`~repro.serving.sim.ServiceReport` with exact-int merged
  accounting, feeding :func:`repro.serving.qos.slo_report` unchanged.

Everything is exact-integer and deterministic: same trace + same federation
configuration => bit-identical routing, timelines, journals, and reports.
"""

from .placement import (
    PLACEMENTS,
    FleetView,
    LeastLoadedPlacement,
    PlacementStrategy,
    ReplicaAffinityPlacement,
    ReplicaMap,
    ShardView,
    SinglePlacement,
    StaticHashPlacement,
    get_placement,
    list_placements,
    register_placement,
)
from .report import FleetReport, merge_reports
from .server import (
    FleetServer,
    demo_fleet,
    fleet_catalog,
    merge_journals,
    recover_fleet,
    serve_fleet_trace,
    shard_journal_path,
)

__all__ = [
    "PLACEMENTS",
    "FleetView",
    "ShardView",
    "PlacementStrategy",
    "SinglePlacement",
    "StaticHashPlacement",
    "LeastLoadedPlacement",
    "ReplicaAffinityPlacement",
    "ReplicaMap",
    "register_placement",
    "get_placement",
    "list_placements",
    "FleetReport",
    "merge_reports",
    "FleetServer",
    "serve_fleet_trace",
    "recover_fleet",
    "merge_journals",
    "shard_journal_path",
    "demo_fleet",
    "fleet_catalog",
]

"""Replica maps and placement strategies: *which shard* serves a request.

A federation (:class:`~repro.fleet.server.FleetServer`) runs N per-library
shards; every arriving request names a logical file that may be stored — as
an exact replica — on several shards' tapes.  The router's job is the
placement decision: among the shards holding a replica, pick one,
deterministically.  This module supplies the three pieces:

* :class:`ReplicaMap` — the logical-file -> holder-shards catalogue,
  validated against each shard's :class:`~repro.storage.tape.TapeLibrary`
  (a claimed replica must actually be stored there);
* :class:`FleetView` / :class:`ShardView` — the exact-int snapshot of every
  shard's state (queue depth, surviving drives, currently threaded tapes,
  mount cost model) a dynamic strategy decides against;
* :class:`PlacementStrategy` — the protocol, plus a registry
  (:func:`register_placement` / :func:`get_placement` /
  :func:`list_placements`) mirroring the solver/selector registries.

Registered strategies (:data:`PLACEMENTS`):

``single`` (the NoOp default)
    Requires a one-shard federation and routes everything to it — the
    degenerate federation whose timeline is pinned bit-identical to a
    standalone :class:`~repro.serving.queue.OnlineTapeServer`.  This is the
    ``NoOpStrategy`` of the distributed-strategy idiom: the default path
    adds a layer without changing a single bit.
``static-hash``
    A stable content hash of the file name picks among the holder shards.
    Stateless and oblivious: no queue awareness, no health awareness — the
    baseline a dynamic router must beat, and the one that keeps hashing
    requests into a shard whose every drive is dead.
``least-loaded``
    The holder shard with the fewest queued requests (shard index breaking
    ties); shards with zero surviving drives sort last.
``replica-affinity``
    Exact-int affinity score per holder shard:
    ``(queue depth + 1) x drive-health penalty x remount cost``, where the
    health penalty is ``1 + (failed drives)`` and the remount factor is 1
    when the shard already has the file's tape threaded in a surviving
    drive, else ``1 + unmount + mount + load_seek`` from the shard's cost
    model.  Lowest score wins (shard index breaking ties); shards with zero
    surviving drives are only eligible when *every* holder is dead.  This
    is the router that steers work away from degraded shards.

Strategies are consulted with the *candidate* shard list already restricted
to replica holders, so every pick is feasible by construction.  All
arithmetic is exact integers; two runs with the same trace and federation
configuration route identically.
"""

from __future__ import annotations

import dataclasses
import hashlib
from typing import Mapping, Protocol, Sequence, runtime_checkable

from ..serving.drives import DriveCosts

__all__ = [
    "ReplicaMap",
    "ShardView",
    "FleetView",
    "PlacementStrategy",
    "PLACEMENTS",
    "SinglePlacement",
    "StaticHashPlacement",
    "LeastLoadedPlacement",
    "ReplicaAffinityPlacement",
    "register_placement",
    "get_placement",
    "list_placements",
]


# ---------------------------------------------------------------------------
# replica catalogue
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class ReplicaMap:
    """Logical file -> sorted tuple of shard indices holding a replica.

    The map is pure data; :meth:`validate` checks it against the actual
    shard libraries (every claimed holder must store the file).  Replicas
    are *exact*: the same logical object written to several libraries,
    possibly on differently named tapes — the router rewrites the tape id
    per shard at dispatch.
    """

    holders_of: Mapping[str, tuple[int, ...]]

    def __post_init__(self) -> None:
        for name, holders in self.holders_of.items():
            if not holders:
                raise ValueError(f"file {name!r} has no replica holders")
            if list(holders) != sorted(set(holders)):
                raise ValueError(
                    f"holders of {name!r} must be sorted and unique, "
                    f"got {holders!r}"
                )
            if holders[0] < 0:
                raise ValueError(f"negative shard index for {name!r}")

    @classmethod
    def from_libraries(cls, libraries: Sequence) -> "ReplicaMap":
        """Derive the map from the shard libraries' stored files."""
        holders: dict[str, list[int]] = {}
        for i, lib in enumerate(libraries):
            for name in lib.location:
                holders.setdefault(name, []).append(i)
        return cls({name: tuple(sorted(h)) for name, h in sorted(holders.items())})

    def holders(self, name: str) -> tuple[int, ...]:
        """Shards holding a replica of ``name`` (raises on unknown files)."""
        try:
            return self.holders_of[name]
        except KeyError:
            raise ValueError(f"file {name!r} is not stored on any shard") from None

    def primary(self, name: str) -> int:
        """The lowest-indexed holder (the deterministic default origin)."""
        return self.holders(name)[0]

    def validate(self, libraries: Sequence) -> None:
        """Check every claimed replica is actually stored on its shard."""
        n = len(libraries)
        for name, holders in sorted(self.holders_of.items()):
            for shard in holders:
                if shard >= n:
                    raise ValueError(
                        f"replica of {name!r} claims shard {shard}, but the "
                        f"federation has only {n} shard(s)"
                    )
                if name not in libraries[shard].location:
                    raise ValueError(
                        f"replica map claims {name!r} on shard {shard}, but "
                        f"that library does not store it"
                    )

    def __len__(self) -> int:
        return len(self.holders_of)


# ---------------------------------------------------------------------------
# fleet state snapshot
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class ShardView:
    """One shard's routing-relevant state at a virtual instant (exact ints)."""

    shard: int
    depth: int  # total queued requests across the shard's cartridges
    n_drives: int  # configured drives (dead ones included)
    n_alive: int  # surviving drives
    mounted: frozenset  # tape ids threaded in surviving drives
    costs: DriveCosts = dataclasses.field(default_factory=DriveCosts)

    @property
    def dead(self) -> bool:
        """No surviving drive: the shard can never dispatch again."""
        return self.n_alive == 0


@dataclasses.dataclass(frozen=True)
class FleetView:
    """Per-shard snapshots plus the candidate tapes for the routed file.

    ``tapes`` maps candidate shard index -> the tape id holding the file's
    replica *on that shard* (replicas may live on differently named tapes).
    """

    now: int
    shards: tuple[ShardView, ...]
    tapes: Mapping[int, str] = dataclasses.field(default_factory=dict)


# ---------------------------------------------------------------------------
# strategy protocol + registry
# ---------------------------------------------------------------------------
@runtime_checkable
class PlacementStrategy(Protocol):
    """Routing decision: which holder shard serves this request.

    ``pick`` receives the file name, the candidate shard indices (the
    replica holders, sorted, never empty) and a :class:`FleetView`; it must
    return one of the candidates, deterministically.  ``dynamic`` declares
    whether the strategy reads runtime state: a static strategy (``False``)
    routes from the name alone, so the fleet may pre-partition the whole
    trace and run each shard's event loop standalone — byte-identical to N
    independent servers; a dynamic strategy forces the shared-clock
    interleaved loop.
    """

    name: str
    dynamic: bool

    def pick(
        self, name: str, candidates: tuple[int, ...], view: FleetView
    ) -> int:  # pragma: no cover - protocol signature
        ...


class SinglePlacement:
    """NoOp default: the one-shard federation, pinned bit-identical."""

    name = "single"
    dynamic = False

    def pick(self, name: str, candidates: tuple[int, ...], view: FleetView) -> int:
        return candidates[0]


def _stable_hash(name: str) -> int:
    """Process-stable content hash (``hash(str)`` is salted per process)."""
    return int.from_bytes(
        hashlib.blake2b(name.encode("utf-8"), digest_size=8).digest(), "big"
    )


class StaticHashPlacement:
    """Stable hash of the file name over the holder shards (oblivious)."""

    name = "static-hash"
    dynamic = False

    def pick(self, name: str, candidates: tuple[int, ...], view: FleetView) -> int:
        return candidates[_stable_hash(name) % len(candidates)]


class LeastLoadedPlacement:
    """Fewest queued requests among the holders (dead shards last)."""

    name = "least-loaded"
    dynamic = True

    def pick(self, name: str, candidates: tuple[int, ...], view: FleetView) -> int:
        return min(
            candidates,
            key=lambda i: (view.shards[i].dead, view.shards[i].depth, i),
        )


class ReplicaAffinityPlacement:
    """Queue depth x drive health x remount cost, lowest score wins."""

    name = "replica-affinity"
    dynamic = True

    def pick(self, name: str, candidates: tuple[int, ...], view: FleetView) -> int:
        def score(i: int) -> tuple[bool, int, int]:
            sv = view.shards[i]
            health = 1 + (sv.n_drives - sv.n_alive)
            tape = view.tapes.get(i)
            remount = (
                1
                if tape is not None and tape in sv.mounted
                else 1 + sv.costs.unmount + sv.costs.switch
            )
            return (sv.dead, (sv.depth + 1) * health * remount, i)

        return min(candidates, key=score)


#: registered placement strategies, by name (see the module docstring).
PLACEMENTS: dict[str, type] = {
    "single": SinglePlacement,
    "static-hash": StaticHashPlacement,
    "least-loaded": LeastLoadedPlacement,
    "replica-affinity": ReplicaAffinityPlacement,
}


def register_placement(cls: type, name: str | None = None) -> type:
    """Register a strategy class under ``name`` (default: ``cls.name``)."""
    key = name if name is not None else getattr(cls, "name", None)
    if not key or not isinstance(key, str):
        raise ValueError(f"placement strategy {cls!r} needs a string name")
    PLACEMENTS[key] = cls
    return cls


def get_placement(strategy: "str | PlacementStrategy") -> PlacementStrategy:
    """Name -> registered instance; a strategy object passes through."""
    if isinstance(strategy, str):
        if strategy not in PLACEMENTS:
            raise ValueError(
                f"unknown placement strategy {strategy!r}; choose from "
                f"{sorted(PLACEMENTS)}"
            )
        return PLACEMENTS[strategy]()
    if not isinstance(strategy, PlacementStrategy):
        raise TypeError(f"not a PlacementStrategy: {strategy!r}")
    return strategy


def list_placements() -> list[str]:
    """Registered strategy names, sorted."""
    return sorted(PLACEMENTS)

"""Sharded multi-library serving: N tape servers under one exact clock.

A :class:`FleetServer` federates N *shards* — each an **unmodified**
:class:`~repro.serving.queue.OnlineTapeServer` over its own
:class:`~repro.storage.tape.TapeLibrary` and
:class:`~repro.serving.drives.DrivePool` — behind a single arrival stream.
Every arriving request names a logical file; a
:class:`~repro.fleet.placement.PlacementStrategy` picks which
replica-holding shard serves it (the request's ``tape_id`` is rewritten to
that shard's cartridge), and all shards advance in **shared exact virtual
time**.  Two execution paths, chosen by configuration:

* **Static pre-partition** — when the placement is static (``single``,
  ``static-hash``) and no :class:`~repro.serving.faults.ShardOutage` is
  injected, routing depends only on file names, so the trace is partitioned
  up front and each shard runs its event loop standalone.  A one-shard
  ``single`` federation is therefore *bit-identical* to a standalone
  server: same events, same journal, same report.
* **Lock-step interleave** — dynamic placements (and any outage) need live
  shard state at each arrival instant, so the fleet drives the shards'
  stepping primitives (``_begin``/``_step``/``_finish``) directly: a fleet
  heap holds arrivals and outages, and at every iteration the globally
  earliest event fires — a fleet event when its time is at or before every
  shard's next event (outages strike before same-instant arrivals, so
  those arrivals already route away from the dark shard), else one
  ``_step()`` of the earliest shard (lowest index on ties).  All
  tie-breaks are total orders over exact ints: the interleave is
  deterministic.

Shared fault domains: a :class:`~repro.serving.faults.ShardOutage` fails
every surviving drive of one shard at one virtual instant (each through the
standard abort/requeue machinery, in drive-id order), then re-routes every
orphaned queued request that still has a replica on a surviving shard —
re-picked by the placement strategy over the surviving holders and injected
as a fresh arrival at the outage instant, marked ``faulted``.  Requests
with no surviving replica stay queued on the dark shard and follow its
:class:`~repro.serving.drives.RetryPolicy` exhaustion path at finish
(typed raise, or typed ``no-drive`` drops).

Crash recovery composes shard-wise: each shard journals through its own
:class:`~repro.serving.faults.EventJournal` (``<base>.shardNN``), and
:func:`recover_fleet` resumes every journal's valid prefix, re-executes the
whole federation (deterministic re-execution *is* recovery, exactly as in
:func:`~repro.serving.faults.recover_server`), cross-checks every
re-produced event, and finishes byte-identically from any cut point.
:func:`merge_journals` flattens the per-shard logs into one
deterministically ordered federation stream for inspection.
"""

from __future__ import annotations

import dataclasses
import heapq
import os
from collections import deque
from typing import Mapping, Sequence

import numpy as np

from ..serving.faults import EventJournal, JournalReplayError, ShardOutage
from ..serving.qos import QoSSpec
from ..serving.queue import OnlineTapeServer
from ..serving.sim import Request
from .placement import (
    FleetView,
    PlacementStrategy,
    ReplicaMap,
    ShardView,
    SinglePlacement,
    get_placement,
)
from .report import FleetReport, merge_reports

__all__ = [
    "FleetServer",
    "serve_fleet_trace",
    "recover_fleet",
    "merge_journals",
    "shard_journal_path",
    "demo_fleet",
    "fleet_catalog",
]


def shard_journal_path(base: str | os.PathLike, shard: int) -> str:
    """Shard ``shard``'s journal path under the fleet's base path."""
    return f"{os.fspath(base)}.shard{shard:02d}"


class _Catalog:
    """Minimal ``.location`` facade: logical file -> primary shard's tape.

    :func:`repro.serving.sim.poisson_trace` (and the QoS trace generator on
    top of it) only ever read ``library.location``, so this facade lets the
    existing seeded generators draw federation-wide traces unchanged.
    """

    def __init__(self, location: dict[str, str]):
        self.location = location


def fleet_catalog(libraries: Sequence, replica_map: ReplicaMap | None = None):
    """The federation's unified file catalogue (for trace generation).

    Each logical file maps to its *primary* holder's tape id — a
    placeholder the router rewrites per routed shard at dispatch.
    """
    rmap = replica_map if replica_map is not None else ReplicaMap.from_libraries(libraries)
    rmap.validate(libraries)
    return _Catalog(
        {
            name: libraries[rmap.primary(name)].location[name]
            for name in sorted(rmap.holders_of)
        }
    )


def demo_fleet(
    seed: int,
    n_shards: int = 2,
    n_files: int = 48,
    replicas: int = 1,
    capacity: int = 4_000_000,
    u_turn: int = 20_000,
    with_cache: bool = True,
) -> tuple[list, ReplicaMap]:
    """Seeded N-shard archive: the fleet twin of ``demo_library``.

    Returns ``(libraries, replica_map)``.  File ``i``'s primary shard is
    ``i % n_shards`` (every shard stores files as long as ``n_files >=
    n_shards``) and ``replicas - 1`` further holders are drawn from the
    seed; every replica of a file has the identical size — it is the same
    logical object.  Sizes match :func:`~repro.serving.sim.demo_library`'s
    regime (100-600 KB objects on ~4 MB cartridges), so fleet and
    single-library numbers stay comparable.
    """
    from ..core.solver import ExecutionContext, SolveCache
    from ..storage.tape import TapeLibrary

    if n_shards < 1:
        raise ValueError("n_shards must be >= 1")
    if not (1 <= replicas <= n_shards):
        raise ValueError(f"need 1 <= replicas <= n_shards, got {replicas}")
    rng = np.random.default_rng(seed)
    libs = [
        TapeLibrary(
            capacity_per_tape=capacity,
            u_turn=u_turn,
            context=ExecutionContext(cache=SolveCache() if with_cache else None),
        )
        for _ in range(n_shards)
    ]
    for i in range(n_files):
        size = int(rng.integers(100_000, 600_000))
        holders = {i % n_shards}
        while len(holders) < replicas:
            holders.add(int(rng.integers(0, n_shards)))
        for s in sorted(holders):
            libs[s].store(f"obj{i:04d}", size)
    return libs, ReplicaMap.from_libraries(libs)


class FleetServer:
    """N per-library shards, one placement strategy, one exact clock.

    ``libraries`` are the shard archives (one unmodified
    :class:`~repro.serving.queue.OnlineTapeServer` is built per library
    with the shared ``admission``/``**kwargs``); ``placement`` names a
    registered strategy (or passes an instance).  ``None`` placement reads
    the strategy from ``kwargs["context"].fleet`` when present, else
    ``"single"`` — and a context carrying
    :class:`~repro.core.context.FleetOptions` must agree with
    ``len(libraries)`` on the shard count.  ``replica_map`` defaults to
    what the libraries actually store and is always validated against
    them.  ``outages`` are :class:`~repro.serving.faults.ShardOutage`
    records; ``journal`` is a base path journaled per shard
    (``<base>.shardNN``).

    The ``single`` strategy requires exactly one shard (it is the pinned
    bit-identical NoOp default, not a router).
    """

    def __init__(
        self,
        libraries: Sequence,
        admission: str = "accumulate",
        *,
        placement: str | PlacementStrategy | None = None,
        replica_map: ReplicaMap | None = None,
        outages: Sequence[ShardOutage] = (),
        journal: str | os.PathLike | None = None,
        qos: Mapping[int, QoSSpec] | None = None,
        **kwargs,
    ):
        if not libraries:
            raise ValueError("a fleet needs at least one shard library")
        ctx = kwargs.get("context")
        fleet_opts = getattr(ctx, "fleet", None) if ctx is not None else None
        if placement is None:
            placement = fleet_opts.placement if fleet_opts is not None else "single"
        if fleet_opts is not None and fleet_opts.n_shards != len(libraries):
            raise ValueError(
                f"context.fleet says {fleet_opts.n_shards} shard(s) but "
                f"{len(libraries)} librar{'y was' if len(libraries) == 1 else 'ies were'} given"
            )
        self.placement = get_placement(placement)
        if isinstance(self.placement, SinglePlacement) and len(libraries) != 1:
            raise ValueError(
                f"the 'single' placement is the one-shard NoOp default; "
                f"got {len(libraries)} shards — pick a routing strategy"
            )
        self.libraries = list(libraries)
        self.replicas = (
            replica_map
            if replica_map is not None
            else ReplicaMap.from_libraries(self.libraries)
        )
        self.replicas.validate(self.libraries)
        for o in outages:
            if not isinstance(o, ShardOutage):
                raise TypeError(f"outages must be ShardOutage records, got {o!r}")
            if o.shard >= len(self.libraries):
                raise ValueError(
                    f"outage targets shard {o.shard} but the fleet has "
                    f"only {len(self.libraries)} shard(s)"
                )
        self.outages = tuple(sorted(outages, key=lambda o: (o.at, o.shard)))
        self.journal_base = os.fspath(journal) if journal is not None else None
        self.shards = [
            OnlineTapeServer(
                lib,
                admission,
                qos=qos,
                journal=(
                    shard_journal_path(self.journal_base, i)
                    if self.journal_base is not None
                    else None
                ),
                **kwargs,
            )
            for i, lib in enumerate(self.libraries)
        ]
        self.routes: dict[int, int] = {i: 0 for i in range(len(self.shards))}
        self.n_rerouted = 0
        # observability (opt-in): shards record under their own index, the
        # router under a "router" lane; unset obs changes nothing
        self.obs = ctx.obs if ctx is not None else None
        for i, sh in enumerate(self.shards):
            sh._obs_shard = i

    # -- routing --------------------------------------------------------------
    def _view(self, now: int, name: str, candidates: tuple[int, ...]) -> FleetView:
        """Snapshot every shard's routing-relevant state at ``now``."""
        views = []
        for i, sh in enumerate(self.shards):
            views.append(
                ShardView(
                    shard=i,
                    depth=sum(len(q) for q in sh.lib.queues.values()),
                    n_drives=len(sh.pool.drives),
                    n_alive=len(sh.pool.alive),
                    mounted=frozenset(
                        d.mounted for d in sh.pool.alive if d.mounted is not None
                    ),
                    costs=sh.drive_costs,
                )
            )
        return FleetView(
            now=now,
            shards=tuple(views),
            tapes={i: self.libraries[i].location[name] for i in candidates},
        )

    def _routed(self, req: Request, dest: int) -> Request:
        """The request as shard ``dest`` sees it (its own replica's tape)."""
        return dataclasses.replace(
            req, tape_id=self.libraries[dest].location[req.name]
        )

    def _route_arrival(self, req: Request, now: int) -> None:
        """Pick a holder shard for one live arrival and inject it there."""
        cands = self.replicas.holders(req.name)
        dest = self.placement.pick(req.name, cands, self._view(now, req.name, cands))
        self.routes[dest] += 1
        if self.obs is not None:
            self.obs.event(
                "route", now, track="router", shard=dest, req=req.req_id
            )
            self.obs.inc("fleet_routed_total", shard=str(dest))
        self.shards[dest]._on_arrival(self._routed(req, dest), now)

    # -- shared fault domain --------------------------------------------------
    def _apply_outage(self, outage: ShardOutage) -> None:
        """One shard goes dark; orphans with surviving replicas re-route.

        Drives fail in drive-id order through the shard's own
        ``_fail_drive`` (in-flight batches abort, completions stand,
        survivors requeue into the shard's queues first — so they are
        orphans too and re-route below with everything else).
        """
        now = outage.at
        sh = self.shards[outage.shard]
        if self.obs is not None:
            self.obs.inc("fleet_outages_total")
            self.obs.event("outage", now, track="router", shard=outage.shard)
        for drive in sorted(sh.pool.alive, key=lambda d: d.drive_id):
            sh._fail_drive(drive, now)
        alive = {i for i, s in enumerate(self.shards) if s.pool.alive}
        reroute: list[Request] = []
        for tid in sorted(sh.lib.queues):
            queue = sh.lib.queues[tid]
            if len(queue) == 0:
                continue
            items = queue.drain()
            for r in items:
                if any(i in alive for i in self.replicas.holders(r.name)):
                    reroute.append(r)
                else:
                    # no surviving replica anywhere: stays on the dark
                    # shard for its RetryPolicy exhaustion path at finish
                    queue.push(r)
        for r in sorted(reroute, key=lambda r: (r.time, r.req_id)):
            cands = tuple(
                i for i in self.replicas.holders(r.name) if i in alive
            )
            dest = self.placement.pick(r.name, cands, self._view(now, r.name, cands))
            self.routes[dest] += 1
            self.n_rerouted += 1
            if self.obs is not None:
                self.obs.inc("fleet_rerouted_total", shard=str(dest))
                self.obs.event(
                    "reroute", now, track="router", shard=dest, req=r.req_id
                )
            self.shards[dest]._faulted.add(r.req_id)
            self.shards[dest]._on_arrival(self._routed(r, dest), now)

    # -- execution ------------------------------------------------------------
    def run(self, trace: list[Request]) -> FleetReport:
        """Serve a federation-wide trace; returns the per-shard + merged report."""
        trace = sorted(trace)
        for req in trace:
            self.replicas.holders(req.name)  # unknown files fail fast
        if not self.placement.dynamic and not self.outages:
            reports = self._run_static(trace)
        else:
            reports = self._run_lockstep(trace)
        if self.obs is not None:
            for i, rep in enumerate(reports):
                self.obs.gauge("shard_served", rep.n_served, shard=str(i))
                self.obs.gauge("shard_failed", rep.n_failed, shard=str(i))
                self.obs.gauge(
                    "shard_routed", self.routes.get(i, 0), shard=str(i)
                )
        return FleetReport(
            shards=tuple(reports),
            merged=merge_reports(reports),
            placement=self.placement.name,
            n_shards=len(self.shards),
            routes=dict(self.routes),
            n_rerouted=self.n_rerouted,
            outages=self.outages,
        )

    def _run_static(self, trace: list[Request]) -> list:
        """Static placements, no outages: pre-partition and run standalone.

        Routing depends only on file names here, so each shard's sub-trace
        is known up front and its event loop runs exactly as a standalone
        server would — the one-shard ``single`` federation is bit-identical
        to no federation at all.  Static strategies see an empty shard
        snapshot (there is no runtime state before the runs start).
        """
        subs: list[list[Request]] = [[] for _ in self.shards]
        for req in trace:
            cands = self.replicas.holders(req.name)
            view = FleetView(
                now=0,
                shards=(),
                tapes={i: self.libraries[i].location[req.name] for i in cands},
            )
            dest = self.placement.pick(req.name, cands, view)
            self.routes[dest] += 1
            subs[dest].append(self._routed(req, dest))
        return [sh.run(sub) for sh, sub in zip(self.shards, subs)]

    def _run_lockstep(self, trace: list[Request]) -> list:
        """Dynamic placements / outages: interleave shards on one clock.

        The fleet heap holds arrivals (priority 1) and outages (priority
        0: an outage at ``t`` strikes before arrivals at ``t`` are routed,
        so those arrivals already steer away from the dark shard).  Every
        iteration fires the globally earliest event — fleet events win
        time ties against shard events, shard ties break by index — so the
        interleave is a total order over exact ints.
        """
        for sh in self.shards:
            sh._begin([])
        fleet_events: list[tuple[int, int, int, str, object]] = []
        seq = 0
        for o in self.outages:
            heapq.heappush(fleet_events, (o.at, 0, seq, "outage", o))
            seq += 1
        for req in trace:
            heapq.heappush(fleet_events, (req.time, 1, seq, "arrival", req))
            seq += 1
        while True:
            t_fleet = fleet_events[0][0] if fleet_events else None
            t_shard, i_shard = None, None
            for i, sh in enumerate(self.shards):
                ti = sh._next_time()
                if ti is not None and (t_shard is None or ti < t_shard):
                    t_shard, i_shard = ti, i
            if t_fleet is None and t_shard is None:
                break
            if t_fleet is not None and (t_shard is None or t_fleet <= t_shard):
                now, _, _, kind, data = heapq.heappop(fleet_events)
                if kind == "outage":
                    self._apply_outage(data)
                else:
                    self._route_arrival(data, now)
            else:
                self.shards[i_shard]._step()
        return [sh._finish() for sh in self.shards]


def serve_fleet_trace(
    libraries: Sequence,
    trace: list[Request],
    admission: str = "accumulate",
    *,
    placement: str | PlacementStrategy | None = None,
    replica_map: ReplicaMap | None = None,
    outages: Sequence[ShardOutage] = (),
    journal: str | os.PathLike | None = None,
    qos: Mapping[int, QoSSpec] | None = None,
    **kwargs,
) -> FleetReport:
    """One-shot convenience: build a :class:`FleetServer` and run it."""
    fleet = FleetServer(
        libraries,
        admission,
        placement=placement,
        replica_map=replica_map,
        outages=outages,
        journal=journal,
        qos=qos,
        **kwargs,
    )
    return fleet.run(trace)


def recover_fleet(
    libraries: Sequence,
    trace: list[Request],
    journal: str | os.PathLike,
    admission: str = "accumulate",
    *,
    placement: str | PlacementStrategy | None = None,
    replica_map: ReplicaMap | None = None,
    outages: Sequence[ShardOutage] = (),
    qos: Mapping[int, QoSSpec] | None = None,
    **kwargs,
) -> FleetReport:
    """Resume a crashed federation from its per-shard journals.

    Each shard's ``<base>.shardNN`` journal is truncated to its valid
    prefix; the whole federation then re-executes from the start against
    the same ``(libraries, trace, configuration)`` — the fleet is
    deterministic, so re-execution *is* recovery — with every re-produced
    shard event cross-checked against its journaled prefix (divergence,
    or a journaled event never re-produced, raises
    :class:`~repro.serving.faults.JournalReplayError`).  Past the
    prefixes the run continues live and appends, so every shard journal
    ends complete and **byte-identical** to the uninterrupted run's,
    whatever the cut point.
    """
    base = os.fspath(journal)
    fleet = FleetServer(
        libraries,
        admission,
        placement=placement,
        replica_map=replica_map,
        outages=outages,
        journal=None,
        qos=qos,
        **kwargs,
    )
    for i, sh in enumerate(fleet.shards):
        jr, expected = EventJournal.resume(shard_journal_path(base, i))
        sh._journal = jr
        sh._expect = deque(expected)
    report = fleet.run(trace)
    for i, sh in enumerate(fleet.shards):
        if sh._expect:
            raise JournalReplayError(
                f"shard {i}: {len(sh._expect)} journaled event(s) were never "
                f"re-produced: the journal does not belong to this "
                f"(libraries, trace, config)"
            )
    return report


def merge_journals(journal: str | os.PathLike, n_shards: int) -> list[dict]:
    """Flatten per-shard journals into one deterministic federation stream.

    Each event gains a ``shard`` key; ordering is a total order — start
    events first (by shard), timed events by ``(t, shard, per-shard
    position)``, end events last (by shard) — and preserves every shard's
    internal causal order, so merging the journals of two identical runs
    yields identical streams.
    """
    if n_shards < 1:
        raise ValueError("n_shards must be >= 1")
    rows: list[tuple[int, int, int, int, dict]] = []
    for i in range(n_shards):
        events = EventJournal.load(shard_journal_path(journal, i))
        for idx, ev in enumerate(events):
            kind = ev.get("ev")
            phase = 0 if kind == "start" else 2 if kind == "end" else 1
            rows.append((phase, int(ev.get("t", 0)), i, idx, {"shard": i, **ev}))
    rows.sort(key=lambda r: r[:4])
    return [r[4] for r in rows]

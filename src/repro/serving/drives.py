"""``DrivePool``: N shared tape drives serving all cartridges.

A real mass-storage system (the CC-IN2P3 setting the paper's logs come from)
does not own one drive per cartridge: a robotic arm moves a small pool of
drives across a large cartridge archive, and *which cartridge to mount next*
is a scheduling decision layered on top of the per-cartridge LTSP sequencing.
This module models that layer:

* :class:`DriveCosts` — the explicit mount/unmount/seek-to-load-point cost
  model, in the same integer virtual-time units as the simulator (1 unit per
  byte of head travel).  ``unmount`` is charged when an occupied drive gives
  its cartridge up for another, ``mount`` when a cartridge is threaded, and
  ``load_seek`` for positioning the freshly threaded tape at its load point.
  The all-zero default makes the pool collapse to the PR-3 one-drive-per-
  cartridge model exactly.
* :class:`PoolDrive` — one drive's full timeline state: which cartridge is
  mounted, the in-flight batch (legs, service window, completions), and the
  epoch counter that invalidates stale drive-free events after a preemption.
* :class:`DrivePool` — the allocator: deterministic drive selection
  (prefer the drive that already holds the cartridge — its head is parked at
  the load point after the post-batch rewind, so re-serving it costs no mount
  leg; otherwise a pluggable :class:`MountScheduler` picks among the free
  drives), cartridge exclusivity (a physical tape can be mounted in at most
  one drive), and mount/unmount accounting that the
  :class:`~repro.serving.sim.ServiceReport` surfaces.

Mount scheduling (which drive to use / evict)
---------------------------------------------
Eviction used to be a hardcoded loop; it is now a context-visible choice.
A :class:`MountScheduler` picks the drive for a cartridge that is not
currently mounted, given the free drives and a :class:`MountView` of the
queue state (virtual ``now``, per-cartridge queue depth, per-cartridge
earliest queued deadline, and the cost model).  Registered implementations
(:data:`MOUNT_SCHEDULERS`):

``greedy`` (alias ``lowest-numbered``, the default)
    The PR-4 rule, bit-identical: lowest-numbered empty free drive, else
    evict the lowest-numbered free occupied drive.  Ignores the view.
``lru``
    Evict the least-recently-*used* free drive (smallest ``last_used``
    acquisition time, drive id breaking ties): cartridges that served
    recently tend to be asked for again (the Zipf head), so their drives
    are kept threaded.
``lookahead``
    Keep the cartridge the queues will want next: every eviction candidate's
    mounted cartridge gets a keep-score ``queue depth x remount cost x
    deadline urgency`` (urgency doubles when the cartridge's earliest queued
    deadline is within one remount of ``now``), and the drive with the
    *lowest* keep-score is evicted.  Exact-int, deterministic.

Failure model and retries
-------------------------
A drive can hard-fail (:meth:`DrivePool.fail_drive`, driven by the fault
layer in :mod:`repro.serving.faults`): a failed drive is excluded from every
allocation path — :meth:`DrivePool.drive_of`, :meth:`DrivePool.can_serve`,
:meth:`DrivePool.acquire`, and therefore from every
:class:`MountScheduler`'s candidate list — and its cartridge is extracted so
it can remount on a surviving drive (at full remount cost, charged through
the normal :meth:`DrivePool.acquire` accounting).  When every drive has
failed while requests are still queued, the serving loop raises the typed
:class:`NoDriveAvailableError` (requests stay queued) or drops them as typed
failures, per the pool's :class:`RetryPolicy`.

:class:`RetryPolicy` is the pool's knob set for *transient* faults: maximum
attempts (overridable per fault class — ``mount``/``media``/``solver``),
exponential backoff charged in exact virtual time between attempts, whether
aborted in-flight requests fail over (requeue) or fail stop (drop), and
whether exhausted budgets raise typed errors or record typed
:class:`~repro.serving.sim.FailedRequest` rows.  The policy is pure data;
the event loop in :mod:`repro.serving.queue` enforces it.

The event loop that drives a pool lives in :mod:`repro.serving.queue`
(:class:`~repro.serving.queue.OnlineTapeServer`); everything here is plain
deterministic state — no clocks, no randomness.
"""

from __future__ import annotations

import dataclasses
from typing import Mapping, Protocol, runtime_checkable

from .sim import Leg, Request

__all__ = [
    "DriveCosts",
    "PoolDrive",
    "DrivePool",
    "MountView",
    "MountScheduler",
    "MOUNT_SCHEDULERS",
    "GreedyScheduler",
    "LRUScheduler",
    "LookaheadScheduler",
    "resolve_scheduler",
    "RetryPolicy",
    "FAIL_STOP",
    "NoDriveAvailableError",
]


class NoDriveAvailableError(RuntimeError):
    """Every drive in the pool has failed while requests are still queued.

    Raised by the serving loop under ``RetryPolicy(on_exhausted="error")``
    (the default); the undispatched requests stay in their pending queues so
    a caller can inspect or re-drive them against a repaired pool.
    """

    def __init__(self, n_queued: int):
        self.n_queued = n_queued
        super().__init__(
            f"all drives have failed with {n_queued} request(s) still queued"
        )


@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """How the serving loop reacts to transient faults (pure data, exact-int).

    ``max_attempts`` bounds the attempts per fault site — mount attempts per
    cartridge acquisition, read attempts per bad media span, solve attempts
    per backend tier — with optional per-class overrides.  Between attempts
    the loop charges ``backoff(attempt)`` virtual time units, exponential in
    the attempt number (solver retries are exempt: solving is instantaneous
    in virtual time).  ``failover`` decides whether requests aborted by a
    drive failure or media error are requeued onto surviving capacity
    (``True``, the default) or dropped fail-stop; ``on_exhausted`` decides
    whether an exhausted budget raises the typed error (``"error"``) or
    records the affected requests as typed
    :class:`~repro.serving.sim.FailedRequest` rows (``"drop"``).
    """

    max_attempts: int = 3
    backoff_base: int = 10_000
    backoff_factor: int = 2
    mount_attempts: int | None = None
    media_attempts: int | None = None
    solver_attempts: int | None = None
    failover: bool = True
    on_exhausted: str = "error"  # "error" | "drop"

    def __post_init__(self) -> None:
        if self.max_attempts < 1:
            raise ValueError("max_attempts must be >= 1")
        if self.backoff_base < 0 or self.backoff_factor < 1:
            raise ValueError("backoff_base must be >= 0, backoff_factor >= 1")
        for name in ("mount_attempts", "media_attempts", "solver_attempts"):
            v = getattr(self, name)
            if v is not None and v < 1:
                raise ValueError(f"{name} must be >= 1 when set")
        if self.on_exhausted not in ("error", "drop"):
            raise ValueError("on_exhausted must be 'error' or 'drop'")

    def attempts(self, fault_class: str) -> int:
        """Attempt budget for ``"mount"``/``"media"``/``"solver"``."""
        override = getattr(self, f"{fault_class}_attempts")
        return override if override is not None else self.max_attempts

    def backoff(self, attempt: int) -> int:
        """Virtual-time delay charged after failed attempt ``attempt`` (>=1)."""
        if attempt < 1:
            raise ValueError("attempt numbers start at 1")
        return self.backoff_base * self.backoff_factor ** (attempt - 1)


#: no retries, no failover, no typed raise: aborted/unservable requests are
#: dropped as FailedRequest rows — the baseline the availability sweep beats.
FAIL_STOP = RetryPolicy(max_attempts=1, failover=False, on_exhausted="drop")


@dataclasses.dataclass(frozen=True)
class DriveCosts:
    """Mount-leg cost model, in simulator virtual-time units (exact ints).

    ``switch`` (mount + load_seek) is charged whenever a cartridge is
    threaded into a drive; ``unmount`` is additionally charged when the drive
    first has to give up the cartridge it holds.  A drive re-serving the
    cartridge it already holds pays nothing — the post-batch rewind already
    parked the head at the load point.
    """

    mount: int = 0
    unmount: int = 0
    load_seek: int = 0

    def __post_init__(self) -> None:
        for name in ("mount", "unmount", "load_seek"):
            if getattr(self, name) < 0:
                raise ValueError(f"{name} cost must be >= 0")

    @property
    def switch(self) -> int:
        """Cost of threading + positioning a newly mounted cartridge."""
        return self.mount + self.load_seek


@dataclasses.dataclass
class PoolDrive:
    """One drive's state (all times absolute virtual time)."""

    drive_id: int
    mounted: str | None = None  # tape_id threaded into this drive
    busy: bool = False
    epoch: int = 0  # invalidates stale drive-free events after preemption
    dispatched: int = 0  # when the in-flight batch was handed over
    service_start: int = 0  # dispatched + mount legs (trajectory t=0)
    service_end: int = 0  # service_start + makespan (last completion)
    busy_until: int = 0  # service_end + rewind-to-load-point
    legs: tuple[Leg, ...] = ()
    inflight: list[tuple[Request, int]] = dataclasses.field(default_factory=list)
    batch_idx: int = -1  # index of the in-flight batch's BatchRecord
    load_point: int = 0  # in-flight instance's m (rewind target)
    u_turn: int = 0  # in-flight instance's U-turn penalty
    last_used: int = 0  # virtual time of the last acquire (LRU eviction)
    failed: bool = False  # hard-failed: permanently out of the pool


@dataclasses.dataclass(frozen=True)
class MountView:
    """Queue-state snapshot a :class:`MountScheduler` decides against.

    ``depth`` maps tape id -> pending queue length, ``urgency`` maps tape id
    -> earliest queued deadline (absent/None when no queued request carries
    one).  Both cover only cartridges with pending requests; a mounted
    cartridge absent from ``depth`` has nothing queued.
    """

    now: int = 0
    costs: DriveCosts = dataclasses.field(default_factory=DriveCosts)
    depth: Mapping[str, int] = dataclasses.field(default_factory=dict)
    urgency: Mapping[str, int | None] = dataclasses.field(default_factory=dict)


@runtime_checkable
class MountScheduler(Protocol):
    """Eviction policy: which free drive serves a not-mounted cartridge.

    ``pick`` receives the free drives in drive-id order (never empty — the
    pool checks :meth:`DrivePool.can_serve` first) and the current
    :class:`MountView`; it must return one of them, deterministically.  The
    holder-drive fast path (cartridge already threaded) never reaches the
    scheduler: re-serving a threaded cartridge is free and always preferred.
    """

    name: str

    def pick(
        self, free: list[PoolDrive], view: MountView
    ) -> PoolDrive:  # pragma: no cover - protocol signature
        ...


class GreedyScheduler:
    """PR-4 default, bit-identical: lowest empty drive, else lowest free."""

    name = "greedy"

    def pick(self, free: list[PoolDrive], view: MountView) -> PoolDrive:
        empty = [d for d in free if d.mounted is None]
        return empty[0] if empty else free[0]


class LRUScheduler:
    """Evict the least-recently-acquired free drive (empty drives first)."""

    name = "lru"

    def pick(self, free: list[PoolDrive], view: MountView) -> PoolDrive:
        empty = [d for d in free if d.mounted is None]
        pool = empty if empty else free
        return min(pool, key=lambda d: (d.last_used, d.drive_id))


class LookaheadScheduler:
    """Evict the mounted cartridge the queues want least.

    Keep-score of an eviction candidate's cartridge =
    ``queue depth x remount cost x urgency`` where urgency is 2 when the
    cartridge's earliest queued deadline is within one remount of ``now``
    (evicting it would likely blow that deadline on the round trip back)
    and 1 otherwise.  The lowest keep-score is evicted, drive id breaking
    ties; empty drives (keep-score 0 by construction) always win.
    """

    name = "lookahead"

    def pick(self, free: list[PoolDrive], view: MountView) -> PoolDrive:
        empty = [d for d in free if d.mounted is None]
        if empty:
            return empty[0]
        remount = max(1, view.costs.unmount + view.costs.switch)

        def keep_score(d: PoolDrive) -> int:
            depth = view.depth.get(d.mounted, 0)
            deadline = view.urgency.get(d.mounted)
            urgent = deadline is not None and deadline - view.now <= remount
            return depth * remount * (2 if urgent else 1)

        return min(free, key=lambda d: (keep_score(d), d.drive_id))


#: registered mount schedulers (``lowest-numbered`` aliases the default).
MOUNT_SCHEDULERS: dict[str, type] = {
    "greedy": GreedyScheduler,
    "lowest-numbered": GreedyScheduler,
    "lru": LRUScheduler,
    "lookahead": LookaheadScheduler,
}


def resolve_scheduler(scheduler: str | MountScheduler) -> MountScheduler:
    """Name -> registered instance; a scheduler object passes through."""
    if isinstance(scheduler, str):
        if scheduler not in MOUNT_SCHEDULERS:
            raise ValueError(
                f"unknown mount scheduler {scheduler!r}; choose from "
                f"{sorted(MOUNT_SCHEDULERS)}"
            )
        return MOUNT_SCHEDULERS[scheduler]()
    if not isinstance(scheduler, MountScheduler):
        raise TypeError(f"not a MountScheduler: {scheduler!r}")
    return scheduler


class DrivePool:
    """N drives shared by every cartridge, with deterministic allocation."""

    def __init__(
        self,
        n_drives: int,
        costs: DriveCosts | None = None,
        scheduler: str | MountScheduler = "greedy",
        retry: RetryPolicy | None = None,
    ):
        if n_drives < 1:
            raise ValueError("a drive pool needs at least one drive")
        self.costs = costs if costs is not None else DriveCosts()
        self.scheduler = resolve_scheduler(scheduler)
        self.retry = retry if retry is not None else RetryPolicy()
        self.drives = [PoolDrive(i) for i in range(n_drives)]
        self.n_mounts = 0
        self.n_unmounts = 0
        self.mount_time = 0  # total charged mount/unmount/seek time
        self.n_drive_failures = 0
        # optional Observability bundle (set by the serving loop when the
        # context carries one); reads pre-computed ints only — never state
        self.obs = None

    @property
    def n_drives(self) -> int:
        return len(self.drives)

    @property
    def alive(self) -> list[PoolDrive]:
        """Drives still in service (hard-failed ones are gone for good)."""
        return [d for d in self.drives if not d.failed]

    def fail_drive(self, drive: PoolDrive) -> None:
        """Hard-fail a drive: out of every allocation path, cartridge freed.

        The cartridge (if any) is extracted by the robot so it can remount
        on a surviving drive — the remount cost is charged by the next
        :meth:`acquire` like any other mount.  The caller (the serving
        loop's fault handler) is responsible for aborting the in-flight
        batch and requeueing its unserved requests first.
        """
        if drive.failed:
            return
        drive.failed = True
        drive.mounted = None
        drive.busy = False
        self.n_drive_failures += 1
        if self.obs is not None:
            self.obs.inc("drive_failures_total")
            self.obs.gauge("alive_drives", len(self.alive))

    def drive_of(self, tape_id: str) -> PoolDrive | None:
        """The drive holding ``tape_id``, if any (cartridge exclusivity)."""
        for d in self.drives:
            if d.mounted == tape_id and not d.failed:
                return d
        return None

    def can_serve(self, tape_id: str) -> bool:
        """Whether a dispatch for this cartridge could start right now.

        A mounted cartridge can only be served by its own drive (a physical
        tape exists once); an unmounted one needs any free surviving drive.
        """
        holder = self.drive_of(tape_id)
        if holder is not None:
            return not holder.busy
        return any(not d.busy and not d.failed for d in self.drives)

    def acquire(
        self, tape_id: str, now: int = 0, view: MountView | None = None
    ) -> tuple[PoolDrive, int]:
        """Pick the drive for a dispatch; returns ``(drive, mount_delay)``.

        Only call when :meth:`can_serve` is true.  Selection is deterministic:
        the holder drive (delay 0) always wins — the cartridge is already
        threaded; otherwise the pool's :class:`MountScheduler` picks among
        the free drives (empty: mount + load_seek; occupied: unmount + mount
        + load_seek).  ``view`` gives deadline/queue-aware schedulers their
        decision context; the default greedy scheduler ignores it.
        Mount/unmount counters and the total charged mount time accumulate
        on the pool.
        """
        holder = self.drive_of(tape_id)
        if holder is not None:
            assert not holder.busy, f"{tape_id} is mid-batch in drive {holder.drive_id}"
            holder.last_used = now
            if self.obs is not None:
                self.obs.inc("drive_holder_hits_total")
            return holder, 0
        free = [d for d in self.drives if not d.busy and not d.failed]
        assert free, "acquire() without a free drive; check can_serve() first"
        if view is None:
            view = MountView(now=now, costs=self.costs)
        drive = self.scheduler.pick(free, view)
        assert not drive.busy, "scheduler picked a busy drive"
        delay = 0
        evicted = drive.mounted is not None
        if evicted:
            delay += self.costs.unmount
            self.n_unmounts += 1
        delay += self.costs.switch
        self.n_mounts += 1
        self.mount_time += delay
        if self.obs is not None:
            self.obs.inc("drive_mounts_total")
            if evicted:
                self.obs.inc("drive_evictions_total")
            self.obs.inc("mount_time_total", delay)
        drive.mounted = tape_id
        drive.last_used = now
        return drive, delay

    def stats(self) -> dict[str, int]:
        """Pool counters with a stable schema for metric scrapes.

        ``alive_drives`` is always present (``n_drives`` counts the
        configured drives, dead ones included) so scrapers never branch on
        key existence; ``drive_failures`` stays conditional so fault-free
        reports keep the pre-fault-layer key set.  Human-facing ``summary()``
        surfaces preserve the old conditional ``alive_drives`` shape — see
        :meth:`~repro.serving.sim.ServiceReport.summary`.
        """
        out = {
            "n_drives": self.n_drives,
            "mounts": self.n_mounts,
            "unmounts": self.n_unmounts,
            "mount_time": self.mount_time,
            "alive_drives": len(self.alive),
        }
        if self.n_drive_failures:
            out["drive_failures"] = self.n_drive_failures
        return out

"""Deterministic fault injection + crash recovery for the serving loop.

Real tape libraries are mechanical: drives die mid-read, mounts fail and
succeed on retry, media develops bad spans, and device solvers hiccup.  This
module models all of that *deterministically* — faults are declared up front
in a :class:`FaultPlan` (or drawn from a seed by :func:`seeded_fault_plan`)
and consumed at exact virtual-time instants by a :class:`FaultInjector`, so
two runs with the same plan are bit-identical and every recovery path is
assertable to the integer.

Fault classes (one frozen record type each, all opt-in):

* :class:`DriveFailure` — drive ``drive`` hard-fails at virtual time ``at``:
  it is removed from the :class:`~repro.serving.drives.DrivePool` (and from
  every mount scheduler's view), its in-flight batch is aborted with
  completions at or before the failure standing, the unserved requests are
  requeued (head state discarded — the drive is gone), and the cartridge
  remounts on a surviving drive at full remount cost.
* :class:`MountFault` — the next ``count`` mount attempts of a cartridge
  fail transiently; each failed attempt charges the
  :class:`~repro.serving.drives.RetryPolicy` exponential backoff in exact
  virtual time before the retry.
* :class:`MediaFault` — the next ``count`` read passes over the byte span
  ``[lo, hi]`` of a tape fail: the batch aborts at the exact instant the
  head first touches the span (the ``preempt`` rewind mechanism), backoff is
  charged, and the surviving requests requeue for a retry read.
* :class:`SolverFault` — the next ``count`` solve attempts on a backend
  raise :class:`~repro.core.solver.TransientSolverError`; the solver engine
  degrades ``pallas → pallas-interpret → python``
  (:func:`~repro.core.solver.solve_warm_degraded`), bit-identically.

Exhausted retry budgets surface as typed errors
(:class:`MountFailedError`, :class:`MediaReadError`,
:class:`~repro.serving.drives.NoDriveAvailableError`,
:class:`~repro.core.solver.SolverUnavailableError`) or, under
``RetryPolicy(on_exhausted="drop")``, as typed
:class:`~repro.serving.sim.FailedRequest` rows on the
:class:`~repro.serving.sim.ServiceReport`.

Crash recovery: the write-ahead event journal
---------------------------------------------
:class:`EventJournal` is an append-only JSONL log of the server's
observable events (``start``/``enqueue``/``batch``/``serve``/``abort``/
``drive-fail``/``end``), flushed per event — the same torn-line-tolerant
idiom as :class:`~repro.core.cache.JsonlCacheBackend`.  Because the server
is a deterministic function of ``(library, trace, configuration)``, the
journal does not need to be *replayed into* state: :func:`recover_server`
truncates a crashed journal to its last intact line, re-executes the run
from the start, and cross-checks every re-produced event against the
journaled prefix (any divergence raises :class:`JournalReplayError` —
redo-validated write-ahead logging).  Past the prefix the run continues
live, appending to the same journal, so the final
:class:`~repro.serving.sim.ServiceReport` is bit-identical to the
uninterrupted run *and* the journal ends complete.  A solve memo
(:class:`~repro.core.cache.JsonlCacheBackend`) makes the redo phase cheap:
every re-executed solve is a cache hit.
"""

from __future__ import annotations

import dataclasses
import json
import os

import numpy as np

from ..core.solver import TransientSolverError
from .sim import Leg

__all__ = [
    "DriveFailure",
    "ShardOutage",
    "MountFault",
    "MediaFault",
    "SolverFault",
    "FaultPlan",
    "FaultInjector",
    "seeded_fault_plan",
    "MountFailedError",
    "MediaReadError",
    "EventJournal",
    "JournalReplayError",
    "recover_server",
]


# ---------------------------------------------------------------------------
# typed recovery errors
# ---------------------------------------------------------------------------
class MountFailedError(RuntimeError):
    """A cartridge's transient mount failures exhausted the retry budget."""

    def __init__(self, tape_id: str, attempts: int):
        self.tape_id = tape_id
        self.attempts = attempts
        super().__init__(
            f"mount of {tape_id!r} still failing after {attempts} attempt(s)"
        )


class MediaReadError(RuntimeError):
    """A bad media span kept failing reads past the retry budget."""

    def __init__(self, span: tuple, attempts: int):
        self.span = span
        self.attempts = attempts
        tape_id, lo, hi = span
        super().__init__(
            f"media span [{lo}, {hi}] of {tape_id!r} still failing after "
            f"{attempts} read attempt(s)"
        )


class JournalReplayError(RuntimeError):
    """Journal replay diverged from the deterministic re-execution."""


# ---------------------------------------------------------------------------
# fault records + plan
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class DriveFailure:
    """Drive ``drive`` hard-fails (permanently) at virtual time ``at``."""

    at: int
    drive: int

    def __post_init__(self) -> None:
        if self.at < 0:
            raise ValueError("failure time must be >= 0")
        if self.drive < 0:
            raise ValueError("drive id must be >= 0")


@dataclasses.dataclass(frozen=True)
class ShardOutage:
    """Every drive of federation shard ``shard`` hard-fails at time ``at``.

    The shared-fault-domain analogue of :class:`DriveFailure`: a whole
    robotic library (one :class:`~repro.fleet.FleetServer` shard) goes dark
    at one virtual instant — power loss, arm jam, network partition.  The
    fleet layer expands it into per-drive hard failures on the shard (each
    through the standard :meth:`OnlineTapeServer._fail_drive` abort/requeue
    machinery) and then re-routes every orphaned queued request that has a
    replica on a surviving shard.  Requests without a surviving replica
    follow the shard's own :class:`~repro.serving.drives.RetryPolicy`
    exhaustion path (typed raise or typed drop).
    """

    at: int
    shard: int

    def __post_init__(self) -> None:
        if self.at < 0:
            raise ValueError("outage time must be >= 0")
        if self.shard < 0:
            raise ValueError("shard index must be >= 0")


@dataclasses.dataclass(frozen=True)
class MountFault:
    """The next ``count`` mount attempts of ``tape_id`` fail transiently."""

    tape_id: str
    count: int = 1

    def __post_init__(self) -> None:
        if self.count < 1:
            raise ValueError("count must be >= 1")


@dataclasses.dataclass(frozen=True)
class MediaFault:
    """The next ``count`` read passes over ``[lo, hi]`` of ``tape_id`` fail."""

    tape_id: str
    lo: int
    hi: int
    count: int = 1

    def __post_init__(self) -> None:
        if not (0 <= self.lo <= self.hi):
            raise ValueError("need 0 <= lo <= hi")
        if self.count < 1:
            raise ValueError("count must be >= 1")


@dataclasses.dataclass(frozen=True)
class SolverFault:
    """The next ``count`` solve attempts on ``backend`` raise transiently."""

    backend: str
    count: int = 1

    def __post_init__(self) -> None:
        if self.count < 1:
            raise ValueError("count must be >= 1")


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """A declarative, deterministic fault schedule for one serving run.

    An empty plan is falsy; the server treats ``faults=None`` and
    ``faults=FaultPlan()`` identically (no injector, the fault-free fast
    path).
    """

    drive_failures: tuple[DriveFailure, ...] = ()
    mount_faults: tuple[MountFault, ...] = ()
    media_faults: tuple[MediaFault, ...] = ()
    solver_faults: tuple[SolverFault, ...] = ()

    def __bool__(self) -> bool:
        return bool(
            self.drive_failures
            or self.mount_faults
            or self.media_faults
            or self.solver_faults
        )


class FaultInjector:
    """Mutable per-run consumption state over a frozen :class:`FaultPlan`.

    The injector owns the remaining-count bookkeeping: each query consumes
    at most one planned fault and increments the matching ``fired`` counter,
    so a plan is a *budget* and the report's fault statistics say exactly
    how much of it the run actually hit.
    """

    def __init__(self, plan: FaultPlan):
        self.plan = plan
        self._mount_left = {
            mf.tape_id: mf.count for mf in plan.mount_faults
        }
        self._media = list(plan.media_faults)
        self._media_left = [mf.count for mf in self._media]
        self._solver_left = {
            sf.backend: sf.count for sf in plan.solver_faults
        }
        self.fired = {"drive": 0, "mount": 0, "media": 0, "solver": 0}

    def drive_failures(self) -> tuple[DriveFailure, ...]:
        """The planned hard failures, sorted by time then drive id."""
        return tuple(sorted(self.plan.drive_failures,
                            key=lambda f: (f.at, f.drive)))

    def drive_failed(self) -> None:
        self.fired["drive"] += 1

    def mount_fails(self, tape_id: str) -> bool:
        """Consume one pending transient mount failure for this cartridge."""
        left = self._mount_left.get(tape_id, 0)
        if left <= 0:
            return False
        self._mount_left[tape_id] = left - 1
        self.fired["mount"] += 1
        return True

    def media_fault(
        self, tape_id: str, legs: tuple[Leg, ...]
    ) -> tuple[int, tuple] | None:
        """Earliest failing read over this trajectory, if any (consumes it).

        Scans the replayed read legs against the cartridge's still-armed bad
        spans and returns ``(t_rel, span_key)`` for the earliest instant the
        head touches a faulty byte — ``t_rel`` is trajectory-relative exact
        virtual time, ``span_key`` identifies the span for per-span retry
        accounting.  ``None`` when no armed span is read.
        """
        best: tuple[int, tuple, int] | None = None
        for i, mf in enumerate(self._media):
            if mf.tape_id != tape_id or self._media_left[i] <= 0:
                continue
            for lg in legs:
                if lg.kind != "read":
                    continue
                lo = max(mf.lo, min(lg.p0, lg.p1))
                hi = min(mf.hi, max(lg.p0, lg.p1))
                if lo > hi:
                    continue
                t = lg.t0 + abs(lo - lg.p0)
                if best is None or t < best[0]:
                    best = (t, (tape_id, mf.lo, mf.hi), i)
                break  # legs are time-ordered: first hit is earliest for mf
        if best is None:
            return None
        t, key, i = best
        self._media_left[i] -= 1
        self.fired["media"] += 1
        return t, key

    def solver_fails(self, backend: str) -> bool:
        """Consume one pending transient solver fault for this backend."""
        left = self._solver_left.get(backend, 0)
        if left <= 0:
            return False
        self._solver_left[backend] = left - 1
        self.fired["solver"] += 1
        return True

    def solver_hook(self, backend: str) -> None:
        """``fault_hook`` for :func:`repro.core.solver.solve_warm_degraded`."""
        if self.solver_fails(backend):
            raise TransientSolverError(backend)

    def remaining(self) -> dict[str, int]:
        """Planned faults not yet consumed (budget left), per class."""
        return {
            "drive": len(self.plan.drive_failures) - self.fired["drive"],
            "mount": sum(self._mount_left.values()),
            "media": sum(self._media_left),
            "solver": sum(self._solver_left.values()),
        }


def seeded_fault_plan(
    library,
    trace,
    seed: int,
    *,
    n_drives: int,
    drive_failures: int = 1,
    mount_faults: int = 1,
    media_faults: int = 1,
    solver_faults: int = 1,
    mount_count: int = 2,
    media_count: int = 1,
    solver_count: int = 1,
    backend: str = "python",
) -> FaultPlan:
    """Draw a deterministic :class:`FaultPlan` from a seed.

    Drive failures land at distinct drives, at times spread over the middle
    of the trace's arrival horizon (so they hit live traffic); mount and
    solver faults target seeded cartridges/the given backend; media faults
    cover each chosen cartridge's whole occupied span so the first read
    after arming is guaranteed to trip them.  ``drive_failures`` is clamped
    to ``n_drives``.
    """
    rng = np.random.default_rng(seed)
    horizon = max((r.time for r in trace), default=0)
    tapes = sorted(library.tapes, key=lambda t: t.tape_id)
    tape_ids = [t.tape_id for t in tapes]

    n_fail = min(drive_failures, n_drives)
    drives = [int(d) for d in rng.permutation(n_drives)[:n_fail]]
    lo_t, hi_t = horizon // 4, max(horizon // 4 + 1, (3 * horizon) // 4)
    fail_times = sorted(int(t) for t in rng.integers(lo_t, hi_t, size=n_fail))
    dfs = tuple(DriveFailure(at=t, drive=d) for t, d in zip(fail_times, drives))

    def pick_tapes(k: int) -> list:
        k = min(k, len(tapes))
        return [tapes[int(i)] for i in rng.permutation(len(tape_ids))[:k]]

    mfs = tuple(
        MountFault(t.tape_id, count=mount_count) for t in pick_tapes(mount_faults)
    )
    meds = tuple(
        MediaFault(t.tape_id, 0, t.used, count=media_count)
        for t in pick_tapes(media_faults)
        if t.used > 0
    )
    sfs = (
        (SolverFault(backend, count=solver_count * solver_faults),)
        if solver_faults > 0
        else ()
    )
    return FaultPlan(
        drive_failures=dfs,
        mount_faults=mfs,
        media_faults=meds,
        solver_faults=sfs,
    )


# ---------------------------------------------------------------------------
# write-ahead event journal
# ---------------------------------------------------------------------------
class EventJournal:
    """Append-only JSONL write-ahead log of serving events.

    One JSON object per line (``{"ev": "...", ...}``, JSON-primitive values
    only), flushed per append so a crash loses at most the line being
    written.  :meth:`load` tolerates a torn tail; :meth:`resume` truncates
    the file to its last intact line and returns the surviving prefix for
    :func:`recover_server`'s redo cross-check.  Unlike the solve-memo
    journal (which skips foreign lines and keeps going), replay stops at
    the first corrupt line: a WAL's suffix is untrustworthy past a tear.
    """

    def __init__(self, path: str | os.PathLike):
        self.path = os.fspath(path)
        self._fh = open(self.path, "a", encoding="utf-8")

    def append(self, ev: dict) -> None:
        self._fh.write(json.dumps(ev, sort_keys=True) + "\n")
        self._fh.flush()

    def close(self) -> None:
        self._fh.close()

    @staticmethod
    def _scan(path: str | os.PathLike) -> tuple[list[dict], int]:
        """Valid event prefix + its byte length (tolerating a torn tail)."""
        with open(path, "rb") as fh:
            raw = fh.read()
        events: list[dict] = []
        pos = valid = 0
        while True:
            nl = raw.find(b"\n", pos)
            if nl < 0:
                break  # unterminated tail: a torn write, not an event
            line = raw[pos:nl]
            pos = nl + 1
            if not line.strip():
                valid = pos
                continue
            try:
                ev = json.loads(line)
                if not isinstance(ev, dict) or "ev" not in ev:
                    raise ValueError("not an event object")
            except (ValueError, UnicodeDecodeError, RecursionError):
                # corrupt interior line: the suffix is untrustworthy.  A
                # *newline-terminated* garbage line (half-flushed, then
                # padded by the crash) must truncate like a torn tail, not
                # raise — json.loads escalates pathological bytes (e.g. a
                # deeply nested "[[[[…" run) to RecursionError, not just
                # ValueError
                break
            events.append(ev)
            valid = pos
        return events, valid

    @classmethod
    def load(cls, path: str | os.PathLike) -> list[dict]:
        """The journal's valid event prefix (read-only, no truncation)."""
        return cls._scan(path)[0]

    @classmethod
    def resume(cls, path: str | os.PathLike) -> tuple["EventJournal", list[dict]]:
        """Truncate to the last intact line and reopen for appending.

        Returns ``(journal, prefix_events)``; the journal's write position
        is exactly after the last intact event, so a recovered run extends
        the same file into a complete log.
        """
        events, valid = cls._scan(path)
        with open(path, "r+b") as fh:
            fh.truncate(valid)
        return cls(path), events


def recover_server(
    library,
    trace,
    journal: "EventJournal | str | os.PathLike",
    admission: str = "accumulate",
    **kwargs,
):
    """Resume a crashed serving run from its write-ahead journal.

    Re-executes the run from the start against the *same* ``(library,
    trace, configuration)`` — the server is deterministic, so re-execution
    *is* recovery — while cross-checking every re-produced event against
    the journal's surviving prefix (divergence raises
    :class:`JournalReplayError`: the journal belongs to a different run).
    Past the prefix the run continues live and appends to the same journal
    file, so it ends complete.  Returns the final
    :class:`~repro.serving.sim.ServiceReport`, bit-identical to the
    uninterrupted run's.  Configure the context with a persistent solve
    memo (:class:`~repro.core.cache.JsonlCacheBackend`) to make the redo
    phase near-free.
    """
    from collections import deque

    from .queue import OnlineTapeServer  # local import: avoids a cycle

    path = journal.path if isinstance(journal, EventJournal) else os.fspath(journal)
    jr, expected = EventJournal.resume(path)
    server = OnlineTapeServer(library, admission, journal=jr, **kwargs)
    server._expect = deque(expected)
    report = server.run(trace)
    if server._expect:
        raise JournalReplayError(
            f"{len(server._expect)} journaled event(s) were never re-produced: "
            f"the journal does not belong to this (library, trace, config)"
        )
    return report

"""Deterministic discrete-event tape simulator: the serving test oracle.

Two roles, one integer-exact model:

* **Schedule replay oracle** — :func:`replay_schedule` turns a detour list
  into the explicit head trajectory (:class:`Leg` segments: leftward seeks,
  U-turn dwells, rightward reads) and *independently* recomputes every
  requested file's service time, the LTSP objective, and the makespan from
  those segments.  It shares the detour-execution semantics of
  :mod:`repro.core.schedule` (same normalisation, same degenerate-detour
  handling) but none of its code: service times are derived by scanning the
  materialised trajectory, so a bug in either implementation shows up as a
  cost mismatch.  ``repro.core.verify.verify_schedule`` uses it as the
  independent scorer for every schedule the online queue service emits.

* **Online-serving clock** — :func:`poisson_trace` draws a seeded arrival
  trace (integer virtual time, geometric inter-arrivals, Zipf-skewed file
  popularity) against a :class:`~repro.storage.tape.TapeLibrary`, and the
  drive-model helpers (:func:`head_position`, :func:`rewind_time`) plus the
  report types (:class:`ServedRequest`, :class:`BatchRecord`,
  :class:`ServiceReport`) give :mod:`repro.serving.queue` everything it needs
  to advance virtual time deterministically and report per-request
  wait/service-time distributions.

Timing model (consistent with :mod:`repro.core.instance`): positions are
integers (bytes), the head seeks *and* reads at unit speed (1 time unit per
byte), every U-turn dwells ``U`` time units, and a batch ends with a rewind
to the load point ``m`` (one U-turn plus the seek back) so the next batch
starts from the state the LTSP instance model assumes.  Everything is exact
Python-int arithmetic — no floats anywhere near a cost.
"""

from __future__ import annotations

import dataclasses
from typing import Iterable, Sequence

import numpy as np

from ..core.instance import Instance

__all__ = [
    "Leg",
    "Replay",
    "replay_schedule",
    "head_position",
    "rewind_time",
    "Request",
    "poisson_trace",
    "demo_library",
    "ServedRequest",
    "FailedRequest",
    "BatchRecord",
    "ServiceReport",
]


# ---------------------------------------------------------------------------
# schedule replay: detours -> trajectory -> service times (the oracle)
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class Leg:
    """One constant-velocity (or dwelling) segment of the head trajectory."""

    t0: int
    t1: int
    p0: int
    p1: int
    kind: str  # "seek-left" | "uturn" | "read"


@dataclasses.dataclass(frozen=True)
class Replay:
    """Independent replay of a detour schedule (all exact integers)."""

    service_time: tuple[int, ...]  # per requested file, trajectory-derived
    cost: int  # sum of mult[i] * service_time[i]
    makespan: int  # last service completion
    head_at_makespan: int  # head position when the last request is served
    legs: tuple[Leg, ...]
    distance: int  # total head travel (no dwells)
    n_uturns: int


def _execution_order(
    detours: Iterable[tuple[int, int]], n_req: int
) -> list[tuple[int, int]]:
    """Detours in execution order: decreasing left endpoint, shorter first on
    ties (the semantics of :mod:`repro.core.schedule`), duplicates dropped."""
    seen: set[tuple[int, int]] = set()
    out: list[tuple[int, int]] = []
    for a, b in detours:
        a, b = int(a), int(b)
        if not (0 <= a <= b < n_req):
            raise ValueError(f"detour ({a},{b}) out of range for n_req={n_req}")
        if (a, b) not in seen:
            seen.add((a, b))
            out.append((a, b))
    out.sort(key=lambda ab: (-ab[0], ab[1]))
    return out


def replay_schedule(inst: Instance, detours: Iterable[tuple[int, int]]) -> Replay:
    """Materialise the head trajectory of a detour schedule and score it.

    Builds the full trajectory first (legs), then derives service times by
    scanning the rightward legs: a file is served the first time a single
    rightward run covers it end to end, at the instant its right edge is
    reached.  Raises if the trajectory fails to serve every file.
    """
    R = inst.n_req
    left = inst.left.tolist()
    right = inst.right.tolist()
    mult = inst.mult.tolist()
    U = int(inst.u_turn)

    # ---- pass 1: trajectory ------------------------------------------------
    legs: list[Leg] = []
    t = 0
    pos = int(inst.m)

    def emit(kind: str, to: int | None = None) -> None:
        nonlocal t, pos
        if kind == "uturn":
            legs.append(Leg(t, t + U, pos, pos, "uturn"))
            t += U
            return
        assert to is not None
        legs.append(Leg(t, t + abs(to - pos), pos, to, kind))
        t += abs(to - pos)
        pos = to

    for a, b in _execution_order(detours, R):
        if left[a] > pos:
            # degenerate nested detour starting right of the head: reads
            # nothing, executed as a null movement (matches core.schedule)
            continue
        emit("seek-left", left[a])
        emit("uturn")
        emit("read", right[b])
        emit("uturn")

    emit("seek-left", left[0])
    # final left-to-right pass over whatever a quick scan says is uncovered;
    # service attribution below decides what each rightward run actually reads
    covered = [False] * R
    for lg in legs:
        if lg.kind == "read":
            for i in range(R):
                if not covered[i] and lg.p0 <= left[i] and right[i] <= lg.p1:
                    covered[i] = True
    if not all(covered):
        emit("uturn")
        emit("read", max(right[i] for i in range(R) if not covered[i]))

    # ---- pass 2: service times from the trajectory -------------------------
    t_serve = [-1] * R
    for lg in legs:
        if lg.kind != "read":
            continue
        for i in range(R):
            if t_serve[i] < 0 and lg.p0 <= left[i] and right[i] <= lg.p1:
                t_serve[i] = lg.t0 + (right[i] - lg.p0)
    if any(ts < 0 for ts in t_serve):
        raise ValueError("schedule failed to serve every requested file")

    cost = sum(x * ts for x, ts in zip(mult, t_serve))
    makespan = max(t_serve)
    distance = sum(abs(lg.p1 - lg.p0) for lg in legs)
    n_uturns = sum(lg.kind == "uturn" for lg in legs)
    return Replay(
        service_time=tuple(t_serve),
        cost=cost,
        makespan=makespan,
        head_at_makespan=head_position(legs, makespan),
        legs=tuple(legs),
        distance=distance,
        n_uturns=n_uturns,
    )


def head_position(legs: Sequence[Leg], t: int) -> int:
    """Head position at trajectory-relative time ``t`` (clamped to the ends).

    ``t < 0`` clamps to the trajectory start — a drive preempted during its
    mount legs (before ``service_start``) reads as parked at the load point.
    """
    if not legs or t <= legs[0].t0:
        return legs[0].p0 if legs else 0
    for lg in legs:
        if t <= lg.t1:
            if lg.p1 == lg.p0:  # dwell (U-turn or zero-length seek)
                return lg.p0
            step = t - lg.t0
            return lg.p0 + step if lg.p1 >= lg.p0 else lg.p0 - step
    return legs[-1].p1


def rewind_time(m: int, u_turn: int, pos: int) -> int:
    """Time to return the head to the load point ``m`` (one U-turn + seek)."""
    if pos == m:
        return 0
    return int(u_turn) + abs(int(m) - int(pos))


# ---------------------------------------------------------------------------
# seeded arrival traces
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True, order=True)
class Request:
    """One online read request (ordered by arrival time, then id)."""

    time: int
    req_id: int
    tape_id: str
    name: str


def poisson_trace(
    library,
    n_requests: int,
    mean_interarrival: int,
    seed: int,
    skew: float = 1.1,
) -> list[Request]:
    """Seeded arrival trace against a :class:`~repro.storage.tape.TapeLibrary`.

    Inter-arrival gaps are geometric with the given integer mean (the discrete
    analogue of a Poisson process), file popularity is Zipf-skewed over a
    seeded permutation of the stored files.  Deterministic given ``seed``.
    """
    if mean_interarrival < 1:
        raise ValueError("mean_interarrival must be >= 1")
    names = sorted(library.location)
    if not names:
        raise ValueError("library holds no files")
    rng = np.random.default_rng(seed)
    perm = rng.permutation(len(names))
    weights = 1.0 / (1.0 + np.arange(len(names))) ** skew
    weights = weights[np.argsort(perm)]
    weights /= weights.sum()
    gaps = rng.geometric(1.0 / float(mean_interarrival), size=n_requests)
    times = np.cumsum(gaps.astype(np.int64))
    picks = rng.choice(len(names), size=n_requests, p=weights)
    return [
        Request(
            time=int(times[i]),
            req_id=i,
            tape_id=library.location[names[int(picks[i])]],
            name=names[int(picks[i])],
        )
        for i in range(n_requests)
    ]


def demo_library(
    seed: int,
    n_files: int = 48,
    capacity: int = 4_000_000,
    u_turn: int = 20_000,
    with_cache: bool = True,
):
    """Seeded multi-cartridge archive shared by every online-serving surface.

    The benchmark sweep, the ``--serve-tape-queue`` launcher, the example,
    and the acceptance tests all serve traces against this same library, so
    their numbers stay comparable by construction (100-600 KB objects packed
    onto ~4 MB cartridges; the library's
    :class:`~repro.core.ExecutionContext` carries one
    :class:`~repro.core.SolveCache` unless ``with_cache=False``).
    """
    from ..core.solver import ExecutionContext, SolveCache
    from ..storage.tape import TapeLibrary

    lib = TapeLibrary(
        capacity_per_tape=capacity,
        u_turn=u_turn,
        context=ExecutionContext(cache=SolveCache() if with_cache else None),
    )
    rng = np.random.default_rng(seed)
    for i in range(n_files):
        lib.store(f"obj{i:04d}", int(rng.integers(100_000, 600_000)))
    return lib


# ---------------------------------------------------------------------------
# report types
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class ServedRequest:
    """One completed request with its full timeline."""

    req_id: int
    name: str
    tape_id: str
    arrival: int
    dispatched: int  # when its batch was handed to the drive
    completed: int  # absolute service completion
    #: the request was touched by a fault before completing (requeued by a
    #: drive failure / media abort, or delayed by transient mount retries)
    faulted: bool = False

    @property
    def sojourn(self) -> int:
        """Service time experienced by the user: completion - arrival."""
        return self.completed - self.arrival


@dataclasses.dataclass(frozen=True)
class FailedRequest:
    """A request the fault layer gave up on (typed; only under
    ``RetryPolicy(on_exhausted="drop")`` / ``failover=False``).

    ``reason`` is one of ``"mount-failed"`` (transient mount retries
    exhausted), ``"media-error"`` (bad-span read retries exhausted),
    ``"drive-failure"`` (in-flight on a failed drive, failover disabled),
    ``"solver-failed"`` (every degradation-chain tier exhausted) or
    ``"no-drive"`` (still queued when the last drive died).
    """

    req_id: int
    name: str
    tape_id: str
    arrival: int
    failed_at: int
    reason: str


@dataclasses.dataclass(frozen=True)
class BatchRecord:
    """One dispatched batch (one LTSP solve against one cartridge).

    ``mount_delay`` is the mount leg the drive pool charged before the
    schedule's trajectory started (unmount of the previous cartridge + mount
    + seek to the load point; 0 when the cartridge was already threaded) —
    the replayed completions below all shift by it.
    """

    tape_id: str
    dispatched: int
    n_requests: int
    n_files: int
    solver_cost: int
    replay_cost: int
    makespan: int
    rewind: int
    verified: bool
    preempted: bool = False
    n_completed: int | None = None  # only set when preempted
    drive: int = 0  # drive the pool assigned
    mount_delay: int = 0
    #: exact DP work accounting for this batch's solve (see repro.core.warm):
    #: recurrence folds performed vs. cells transferred from a WarmState;
    #: ``warm_mode`` is the WarmStats mode ("cold"/"warm"/"cache"/...).
    cells_evaluated: int = 0
    cells_reused: int = 0
    warm_mode: str = "cold"
    #: fault that aborted this batch mid-flight ("drive-failure" /
    #: "media-error"); None for clean batches and admission preemptions
    aborted_by: str | None = None
    #: transient mount failures retried (with backoff) before this dispatch
    mount_retries: int = 0
    #: backend that actually solved after a degradation-chain fallback
    #: (None: the requested backend, possibly after same-tier retries)
    degraded_to: str | None = None
    #: policy a SolverSelector picked for this tick (None: no selector ran —
    #: the batch was solved with the server's configured policy)
    policy_used: str | None = None
    #: virtual time the batch's solve work cost under the context's
    #: ComputeBudget (cells_evaluated priced at solve_time_num/den; the
    #: dispatch's service start was delayed by exactly this much)
    solve_delay: int = 0


@dataclasses.dataclass
class ServiceReport:
    """Outcome of one online-serving simulation run."""

    admission: str
    policy: str
    backend: str
    window: int
    served: list[ServedRequest]
    batches: list[BatchRecord]
    n_preemptions: int
    horizon: int  # virtual time when the last drive went idle
    cache_stats: dict[str, int] | None = None
    #: drive-pool accounting (n_drives, mounts, unmounts, mount_time)
    pool_stats: dict[str, int] | None = None
    #: mount-scheduler the pool ran (see repro.serving.drives.MOUNT_SCHEDULERS)
    scheduler: str = "greedy"
    #: req_id -> QoSSpec the server attached at enqueue (None: QoS unset).
    #: Typed loosely to keep sim importable without the QoS layer; entries
    #: only need ``.deadline``.  repro.serving.qos.slo_report joins on it.
    qos: dict | None = None
    #: whether the server carried WarmStates across this run's solves
    warm_start: bool = False
    #: typed FailedRequest rows (only the drop/fail-stop retry policies)
    failed: list = dataclasses.field(default_factory=list)
    #: exact fault/retry accounting (drive_failures, mount_retries,
    #: media_aborts, solver_faults, fallbacks, requeued, retry_delay);
    #: None when the run had no fault plan and no explicit retry policy —
    #: fault-free reports stay key-for-key identical to the PR-6 format
    fault_stats: dict | None = None
    #: SolverSelector the server consulted per tick (None: adaptive
    #: dispatch off — reports stay key-for-key identical to PR 7)
    selector: str | None = None

    # -- exact aggregates (ints, safe to assert on) --------------------------
    @property
    def n_served(self) -> int:
        return len(self.served)

    @property
    def total_sojourn(self) -> int:
        return sum(r.sojourn for r in self.served)

    @property
    def makespan(self) -> int:
        return max((r.completed for r in self.served), default=0)

    @property
    def n_failed(self) -> int:
        """Requests the fault layer dropped (typed rows in ``failed``)."""
        return len(self.failed)

    @property
    def n_faulted(self) -> int:
        """Served requests that were touched by a fault on the way."""
        return sum(1 for r in self.served if r.faulted)

    @property
    def completion_rate(self) -> float:
        """Served / (served + dropped); 1.0 on a fault-free run."""
        total = self.n_served + self.n_failed
        return self.n_served / total if total else 0.0

    @property
    def cells_evaluated(self) -> int:
        """Total DP recurrence folds across every batch solve (exact)."""
        return sum(b.cells_evaluated for b in self.batches)

    @property
    def cells_reused(self) -> int:
        """Total DP cells transferred from warm states instead of folded."""
        return sum(b.cells_reused for b in self.batches)

    @property
    def total_solve_delay(self) -> int:
        """Virtual time charged for solver compute across all batches."""
        return sum(b.solve_delay for b in self.batches)

    @property
    def policy_mix(self) -> dict[str, int]:
        """Batches per policy the selector actually dispatched ({} = off)."""
        mix: dict[str, int] = {}
        for b in self.batches:
            if b.policy_used is not None:
                mix[b.policy_used] = mix.get(b.policy_used, 0) + 1
        return mix

    # -- float conveniences for tables ---------------------------------------
    @property
    def mean_sojourn(self) -> float:
        return self.total_sojourn / self.n_served if self.served else 0.0

    def sojourn_quantile(self, q: float) -> float:
        if not self.served:
            return 0.0
        return float(np.quantile([r.sojourn for r in self.served], q))

    # -- deadline outcomes (exact ints; require a qos map) -------------------
    @property
    def n_deadlines(self) -> int:
        """Served requests that carried a deadline (0 when QoS is unset)."""
        if not self.qos:
            return 0
        return sum(
            1
            for r in self.served
            if (spec := self.qos.get(r.req_id)) is not None
            and spec.deadline is not None
        )

    @property
    def n_missed(self) -> int:
        """Served requests completed strictly after their deadline."""
        if not self.qos:
            return 0
        return sum(
            1
            for r in self.served
            if (spec := self.qos.get(r.req_id)) is not None
            and spec.deadline is not None
            and r.completed > spec.deadline
        )

    @property
    def miss_rate(self) -> float:
        return self.n_missed / self.n_deadlines if self.n_deadlines else 0.0

    def summary(self) -> dict:
        """Machine-readable row for benchmarks (``--record``)."""
        # DrivePool.stats() now always reports alive_drives, but this row's
        # key shape (and order) is pinned by recorded benchmark JSON: keep
        # alive_drives out of fault-free rows and after drive_failures
        # otherwise, exactly as the pre-observability pool reported it.
        pool = dict(self.pool_stats) if self.pool_stats else {}
        alive = pool.pop("alive_drives", None)
        if "drive_failures" in pool and alive is not None:
            pool["alive_drives"] = alive
        out = {
            "admission": self.admission,
            "policy": self.policy,
            "backend": self.backend,
            "window": self.window,
            "scheduler": self.scheduler,
            "n_served": self.n_served,
            "n_batches": len(self.batches),
            "n_preemptions": self.n_preemptions,
            "total_sojourn": self.total_sojourn,
            "mean_sojourn": self.mean_sojourn,
            "p50_sojourn": self.sojourn_quantile(0.50),
            "p95_sojourn": self.sojourn_quantile(0.95),
            "p99_sojourn": self.sojourn_quantile(0.99),
            "max_sojourn": max((r.sojourn for r in self.served), default=0),
            "makespan": self.makespan,
            "horizon": self.horizon,
            "all_verified": all(b.verified for b in self.batches),
            "warm_start": self.warm_start,
            "cells_evaluated": self.cells_evaluated,
            "cells_reused": self.cells_reused,
            "cells_per_batch": (
                self.cells_evaluated / len(self.batches) if self.batches else 0.0
            ),
            **pool,
            **({"cache": dict(self.cache_stats)} if self.cache_stats else {}),
        }
        if self.qos:
            out["n_deadlines"] = self.n_deadlines
            out["n_missed"] = self.n_missed
            out["miss_rate"] = self.miss_rate
        if self.fault_stats is not None:
            out["faults"] = dict(self.fault_stats)
            out["n_failed"] = self.n_failed
            out["n_faulted"] = self.n_faulted
            out["completion_rate"] = self.completion_rate
        if self.selector is not None:
            out["selector"] = self.selector
            out["policy_mix"] = self.policy_mix
            out["total_solve_delay"] = self.total_solve_delay
        return out

"""Serve-step factory: batched single-token decode with greedy sampling.

``make_serve_step(cfg)`` returns ``(params, cache, tokens, pos) ->
(next_tokens, logits, cache)``; the KV/recurrent cache layout and sharding is
described in :mod:`repro.distributed.sharding` (sequence-sharded split-K
decode).

This module is deliberately *not* re-exported from :mod:`repro.serving`
(see that package docstring): it pulls in the neural-network stack
(``repro.models``), which the tape-serving event loop and its callers never
need.  Import it directly — ``from repro.serving.serve import
make_serve_step`` — as :mod:`repro.launch.serve` does."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from ..models.common import ModelConfig
from ..models.model import decode_step, init_cache, warm_cross_cache


def make_serve_step(cfg: ModelConfig):
    def serve_step(params, cache, tokens, pos):
        logits, cache = decode_step(params, cfg, tokens, cache, pos)
        next_tokens = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        return next_tokens[:, None], logits, cache

    return serve_step


def make_prefill(cfg: ModelConfig):
    """Full-sequence forward used for prompt processing (no grads)."""
    from ..models.model import forward

    def prefill(params, tokens, memory=None):
        logits, _ = forward(params, cfg, tokens, memory=memory)
        return logits

    return prefill


__all__ = ["make_serve_step", "make_prefill", "init_cache", "warm_cross_cache"]

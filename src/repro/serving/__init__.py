"""Online tape-serving subsystem: public API.

Callers should import from here rather than the submodules: the event loop
and admission policies (:mod:`.queue`), the shared drive pool with pluggable
mount scheduling (:mod:`.drives`), the discrete-event simulator oracle and
report types (:mod:`.sim`), the QoS layer (:mod:`.qos`), and the opt-in
fault-injection / crash-recovery layer (:mod:`.faults`).

Everything here simulates *one* robotic library; :mod:`repro.fleet`
federates N of these servers (sharded multi-library serving with replica
routing, shard-wide outages, and merged SLO accounting) by driving the
event loop's stepping primitives in shared exact virtual time — each shard
stays an unmodified :class:`~repro.serving.queue.OnlineTapeServer`.

**Observability.**  The serving loop is instrumented end to end through
the opt-in :mod:`repro.obs` bundle: attach one via
``ExecutionContext(obs=Observability.enabled())`` and the event loop,
drive pool, and cache emit virtual-time spans (queue waits, mounts,
batches — one trace track per drive) plus exact-int counters and
histograms that reconcile with :class:`~repro.serving.sim.ServiceReport`
/ :func:`~repro.serving.qos.slo_report` integers with ``==``, never
approximately.  With ``obs`` unset (the default) every hook is a no-op
on a shared null bundle and the serving path is pinned bit-identical to
the uninstrumented stack — same timelines, same journal bytes.  Export
the collected data with :mod:`repro.obs.export` (byte-deterministic
JSONL span logs, Prometheus text, Chrome ``trace_event`` JSON) or from
the CLI via ``launch/serve.py --tape-trace-out/--tape-metrics-out``.

The model-serving step builder (:mod:`.serve`) is deliberately *not*
re-exported: it pulls in the neural-network stack, which tape-serving
callers don't need.
"""

from .drives import (
    FAIL_STOP,
    MOUNT_SCHEDULERS,
    DriveCosts,
    DrivePool,
    GreedyScheduler,
    LookaheadScheduler,
    LRUScheduler,
    MountScheduler,
    MountView,
    NoDriveAvailableError,
    PoolDrive,
    RetryPolicy,
    resolve_scheduler,
)
from .faults import (
    DriveFailure,
    EventJournal,
    FaultInjector,
    FaultPlan,
    JournalReplayError,
    MediaFault,
    MediaReadError,
    MountFailedError,
    MountFault,
    ShardOutage,
    SolverFault,
    recover_server,
    seeded_fault_plan,
)
from .qos import DEFAULT_CLASS, ClassSLO, QoSSpec, SLOReport, int_quantile, slo_report
from .queue import (
    ADMISSIONS,
    LEGACY_ADMISSIONS,
    POOL_ADMISSIONS,
    QOS_ADMISSIONS,
    WINDOWED_ADMISSIONS,
    OnlineTapeServer,
    serve_trace,
)
from .sim import (
    BatchRecord,
    FailedRequest,
    Leg,
    Replay,
    Request,
    ServedRequest,
    ServiceReport,
    demo_library,
    head_position,
    poisson_trace,
    replay_schedule,
    rewind_time,
)

__all__ = [
    # queue / admissions
    "OnlineTapeServer",
    "serve_trace",
    "ADMISSIONS",
    "LEGACY_ADMISSIONS",
    "POOL_ADMISSIONS",
    "QOS_ADMISSIONS",
    "WINDOWED_ADMISSIONS",
    # drive pool + mount scheduling
    "DrivePool",
    "DriveCosts",
    "PoolDrive",
    "MountScheduler",
    "MountView",
    "MOUNT_SCHEDULERS",
    "GreedyScheduler",
    "LRUScheduler",
    "LookaheadScheduler",
    "resolve_scheduler",
    # QoS layer
    "QoSSpec",
    "SLOReport",
    "ClassSLO",
    "slo_report",
    "int_quantile",
    "DEFAULT_CLASS",
    # simulator + reports
    "Request",
    "ServedRequest",
    "BatchRecord",
    "ServiceReport",
    "Replay",
    "Leg",
    "replay_schedule",
    "head_position",
    "rewind_time",
    "poisson_trace",
    "demo_library",
    # fault injection / retries / crash recovery
    "FaultPlan",
    "FaultInjector",
    "DriveFailure",
    "ShardOutage",
    "MountFault",
    "MediaFault",
    "SolverFault",
    "seeded_fault_plan",
    "RetryPolicy",
    "FAIL_STOP",
    "EventJournal",
    "recover_server",
    "JournalReplayError",
    "MountFailedError",
    "MediaReadError",
    "NoDriveAvailableError",
    "FailedRequest",
]

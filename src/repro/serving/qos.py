"""QoS layer: per-request deadlines/priority classes and SLO reporting.

The paper's stated aim is improving the *quality of service* experienced by
users of tape storage systems, not only the peak performance; the
priority-/due-date-flavoured LTSP variants of Cardonha & Villa Real (2018)
and Cardonha, Cire & Villa Real (2021) ground the deadline model.  This
module is the request-facing half of that layer:

* :class:`QoSSpec` — one request's service-level contract: an (absolute,
  virtual-time) ``deadline`` and a ``qos_class`` label.  Specs are attached
  at ``enqueue`` time — :class:`~repro.serving.queue.OnlineTapeServer` takes
  a ``qos`` mapping ``req_id -> QoSSpec`` next to the trace — so the request
  type itself (:class:`~repro.serving.sim.Request`) and every QoS-unaware
  code path stay bit-identical.

* :class:`SLOReport` / :class:`ClassSLO` — derived from a
  :class:`~repro.serving.sim.ServiceReport` by :func:`slo_report`: per-class
  and overall p50/p99 sojourn (exact nearest-rank integers, see
  :func:`int_quantile`), deadline-miss counts/rate, and total/max lateness.
  Everything except the float ``miss_rate`` convenience is exact-int virtual
  time, safe to assert on.  When serving ran under fault injection
  (:mod:`repro.serving.faults`), ``n_missed_faulted`` attributes deadline
  misses to requests a fault touched (retried mount, media abort, drive
  failover requeue) so operators can separate SLO debt caused by hardware
  events from scheduling debt.

The deadline-aware admissions themselves (``edf-global``,
``slack-accumulate``) live with the other admission policies in
:mod:`repro.serving.queue`; the deadline-aware mount scheduling
(``lookahead``) with the other :class:`~repro.serving.drives.MountScheduler`
implementations in :mod:`repro.serving.drives`.  QoS is opt-in everywhere:
with no ``qos`` mapping and the default scheduler, serving reproduces the
QoS-less behaviour bit for bit.
"""

from __future__ import annotations

import dataclasses
from typing import Iterable, Mapping, Sequence

from .sim import ServiceReport

__all__ = [
    "DEFAULT_CLASS",
    "QoSSpec",
    "ClassSLO",
    "SLOReport",
    "slo_report",
    "int_quantile",
]

#: class label a request gets when no spec (or no class) is attached.
DEFAULT_CLASS = "default"


@dataclasses.dataclass(frozen=True)
class QoSSpec:
    """One request's service-level contract (attached at ``enqueue``).

    ``deadline`` is an *absolute* virtual-time instant (same exact-integer
    clock as the simulator): the request's service level is met iff its
    completion lands at or before it.  ``None`` means best-effort — the
    request never counts toward deadline-miss statistics.  ``qos_class`` is
    a free-form label used only for grouping in the :class:`SLOReport`.
    """

    deadline: int | None = None
    qos_class: str = DEFAULT_CLASS

    def __post_init__(self) -> None:
        if self.deadline is not None and self.deadline < 0:
            raise ValueError("deadline must be an absolute virtual time >= 0")
        if not self.qos_class:
            raise ValueError("qos_class must be a non-empty label")

    def slack(self, now: int) -> int | None:
        """Remaining slack at ``now`` (negative once the deadline passed)."""
        return None if self.deadline is None else self.deadline - now


def int_quantile(values: Iterable[int], num: int, den: int) -> int:
    """Exact nearest-rank quantile of integer ``values`` (no floats).

    Returns the smallest element whose rank is >= ``ceil(num/den * n)``
    (the classic nearest-rank definition), computed entirely in integer
    arithmetic so p50/p99 of virtual times are assertable exactly.  An empty
    input returns 0.
    """
    if not (0 <= num <= den) or den <= 0:
        raise ValueError(f"quantile {num}/{den} out of [0, 1]")
    ordered = sorted(values)
    if not ordered:
        return 0
    rank = -(-num * len(ordered) // den)  # ceil without floats
    return ordered[max(rank, 1) - 1]


@dataclasses.dataclass(frozen=True)
class ClassSLO:
    """SLO aggregates for one QoS class (all virtual-time ints exact).

    ``n_deadlines``/``n_missed`` count *dropped* deadline-carrying requests
    too: a request the fault layer dropped never completes, which is the
    definitive way to miss a deadline — before this accounting a class whose
    deadline work was entirely dropped vanished from the report with a
    vacuous 0.0 miss rate.  Dropped requests have no completion instant, so
    they contribute to no sojourn quantile or lateness aggregate.
    """

    qos_class: str
    n: int  # served requests in this class
    p50_sojourn: int  # nearest-rank, exact (over served requests)
    p99_sojourn: int  # nearest-rank, exact (over served requests)
    n_deadlines: int  # requests that carried a deadline (served + dropped)
    n_missed: int  # completed strictly after their deadline, or dropped
    total_lateness: int  # sum of max(0, completed - deadline); served only
    max_lateness: int
    n_missed_faulted: int = 0  # misses on requests a fault touched (retry/requeue)
    n_failed: int = 0  # requests the fault layer dropped (never completed)

    @property
    def miss_rate(self) -> float:
        """Fraction of deadline-carrying requests late or dropped (0.0 if none)."""
        return self.n_missed / self.n_deadlines if self.n_deadlines else 0.0


@dataclasses.dataclass(frozen=True)
class SLOReport:
    """Per-class + overall SLO view of one serving run.

    Derived from a :class:`~repro.serving.sim.ServiceReport` by
    :func:`slo_report`; ``overall`` aggregates every served request (class
    label ``"*"``), ``classes`` holds one :class:`ClassSLO` per observed
    class, sorted by name.
    """

    admission: str
    scheduler: str
    overall: ClassSLO
    classes: tuple[ClassSLO, ...]

    @property
    def n_missed(self) -> int:
        return self.overall.n_missed

    @property
    def n_deadlines(self) -> int:
        return self.overall.n_deadlines

    @property
    def miss_rate(self) -> float:
        return self.overall.miss_rate

    @property
    def n_missed_faulted(self) -> int:
        """Deadline misses on requests that a fault touched (retry/requeue)."""
        return self.overall.n_missed_faulted

    @property
    def n_failed(self) -> int:
        """Requests the fault layer dropped (deadline-carrying ones count missed)."""
        return self.overall.n_failed

    def for_class(self, qos_class: str) -> ClassSLO:
        for c in self.classes:
            if c.qos_class == qos_class:
                return c
        raise KeyError(f"no served requests in class {qos_class!r}")

    def summary(self) -> dict:
        """Machine-readable row for benchmarks and launchers."""
        return {
            "admission": self.admission,
            "scheduler": self.scheduler,
            "n_served": self.overall.n,
            "n_deadlines": self.n_deadlines,
            "n_missed": self.n_missed,
            "n_missed_faulted": self.n_missed_faulted,
            "n_failed": self.n_failed,
            "miss_rate": self.miss_rate,
            "p50_sojourn": self.overall.p50_sojourn,
            "p99_sojourn": self.overall.p99_sojourn,
            "total_lateness": self.overall.total_lateness,
            "max_lateness": self.overall.max_lateness,
            "classes": {
                c.qos_class: {
                    "n": c.n,
                    "p50_sojourn": c.p50_sojourn,
                    "p99_sojourn": c.p99_sojourn,
                    "n_missed": c.n_missed,
                    "n_failed": c.n_failed,
                    "miss_rate": c.miss_rate,
                    "max_lateness": c.max_lateness,
                }
                for c in self.classes
            },
        }


def _class_slo(
    label: str,
    rows: Sequence[tuple[int, int | None, bool]],
    n_failed: int = 0,
    n_failed_deadlines: int = 0,
) -> ClassSLO:
    """Aggregate ``(sojourn, lateness-or-None, faulted)`` rows into one ClassSLO.

    ``n_failed``/``n_failed_deadlines`` fold in the class's dropped
    requests: every dropped deadline-carrying request is a miss (it will
    never complete), but contributes no sojourn or lateness.
    """
    sojourns = [s for s, _, _ in rows]
    late = [(l, f) for _, l, f in rows if l is not None]
    return ClassSLO(
        qos_class=label,
        n=len(rows),
        p50_sojourn=int_quantile(sojourns, 1, 2),
        p99_sojourn=int_quantile(sojourns, 99, 100),
        n_deadlines=len(late) + n_failed_deadlines,
        n_missed=sum(1 for l, _ in late if l > 0) + n_failed_deadlines,
        total_lateness=sum(l for l, _ in late if l > 0),
        max_lateness=max((l for l, _ in late if l > 0), default=0),
        n_missed_faulted=sum(1 for l, f in late if l > 0 and f),
        n_failed=n_failed,
    )


def slo_report(
    report: ServiceReport, qos: Mapping[int, QoSSpec] | None = None
) -> SLOReport:
    """Join a service report against its QoS map into per-class SLO stats.

    ``qos`` defaults to the map the server recorded on the report (a run
    without QoS yields an all-best-effort report: 0 deadlines, 0 misses).
    Requests absent from the map count as best-effort ``default``-class.

    Requests the fault layer *dropped* (``report.failed``) are joined too:
    a dropped deadline-carrying request counts as a deadline and a miss in
    its class (it will never complete), so a class whose deadline work was
    entirely dropped still appears — with a 1.0 miss rate instead of
    silently vanishing from the report.
    """
    specs: Mapping[int, QoSSpec] = (
        qos if qos is not None else (report.qos or {})
    )
    default = QoSSpec()
    per_class: dict[str, list[tuple[int, int | None, bool]]] = {}
    everything: list[tuple[int, int | None, bool]] = []
    for r in report.served:
        spec = specs.get(r.req_id, default)
        lateness = None if spec.deadline is None else r.completed - spec.deadline
        row = (r.sojourn, lateness, r.faulted)
        per_class.setdefault(spec.qos_class, []).append(row)
        everything.append(row)
    failed_by_class: dict[str, tuple[int, int]] = {}  # cls -> (n, n_deadlines)
    n_failed = n_failed_deadlines = 0
    for f in getattr(report, "failed", ()) or ():
        spec = specs.get(f.req_id, default)
        has_deadline = int(spec.deadline is not None)
        n, nd = failed_by_class.get(spec.qos_class, (0, 0))
        failed_by_class[spec.qos_class] = (n + 1, nd + has_deadline)
        per_class.setdefault(spec.qos_class, [])  # class appears even if 0 served
        n_failed += 1
        n_failed_deadlines += has_deadline
    return SLOReport(
        admission=report.admission,
        scheduler=report.scheduler,
        overall=_class_slo("*", everything, n_failed, n_failed_deadlines),
        classes=tuple(
            _class_slo(name, rows, *failed_by_class.get(name, (0, 0)))
            for name, rows in sorted(per_class.items())
        ),
    )

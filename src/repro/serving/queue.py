"""Online tape-serving subsystem: a shared drive pool + admission policies.

This is the robotic-arm layer of the ROADMAP's north star: read requests
arrive over (virtual) time against a :class:`~repro.storage.tape.TapeLibrary`,
accumulate in per-cartridge queues (:class:`~repro.storage.tape.PendingQueue`),
and an *admission policy* decides **when** a cartridge's queue becomes an LTSP
batch and **which cartridge a drive mounts next**.  Service runs on a
:class:`~repro.serving.drives.DrivePool` — ``n_drives`` drives shared across
all cartridges with an explicit mount/unmount/seek-to-load-point cost model
(:class:`~repro.serving.drives.DriveCosts`).  The PR-3 one-drive-per-cartridge
server is the ``n_drives = len(tapes)``, zero-mount-cost special case of this
loop, bit-identically.

Solving dispatches through the solver engine under an
:class:`~repro.core.ExecutionContext` (:func:`repro.core.solve_warm` /
:func:`repro.core.solve_batch_warm` — any registered policy × backend); the
pre-context ``backend=``/``cache=`` keywords survive as warning-emitting
deprecation shims.  The discrete-event simulator in :mod:`repro.serving.sim`
advances virtual time and independently re-scores every emitted schedule, so
online-vs-offline regret, batching-vs-FIFO improvements, and
mount-contention penalties are exact integers, not anecdotes.

Warm-started re-solving and the cache backend
---------------------------------------------
Consecutive solves of one cartridge are usually *perturbations* of each
other — ``preempt`` re-plans the surviving multiset plus one newcomer, and
every ``accumulate``/``slack-accumulate`` tick re-plans whatever overlaps
the previous mix — so the server threads one
:class:`~repro.core.warm.WarmState` per ``(cartridge, policy)`` through its
dispatches (``warm_start=True``, the default): each solve receives the
state captured by the cartridge's previous solve and returns a fresh one,
and only the DP cells invalidated by the multiset diff are re-evaluated.
Warm-starting is a pure accelerator — results are bit-identical with it on,
off, or with states evicted mid-run (differentially asserted in the tests
and the warm benchmark sweep) — and the exact evaluated/reused cell
counters land per batch in :class:`~repro.serving.sim.BatchRecord` and
aggregate on :class:`~repro.serving.sim.ServiceReport`.  With
``warm_start=False`` every solve runs cold but the counters still record,
so warm-vs-cold sweeps compare like for like.

Warm states live wherever the context's cache backend lives: any
:class:`~repro.core.cache.CacheBackend` on the
:class:`~repro.core.ExecutionContext` stores them next to its memoised full
solves (``get_warm``/``put_warm`` keyed ``("warm", tape_id, policy)``), so
servers sharing a cache share warm states; without a cache they live on the
server for the run.  A memoised *solve* hit short-circuits warm handling
entirely (zero DP work beats any warm start) and keeps the cartridge's
previous state for the next miss.  Warm states are advisory and in-memory
only — a persistent backend (:class:`~repro.core.cache.JsonlCacheBackend`)
rewarms a restarted fleet through its journaled solves, then rebuilds warm
states on the first post-restart miss per cartridge.

Admission policies
------------------
Cartridge-cadence policies (when does a queue dispatch):

``fifo`` / ``fifo-global``
    Per-request solving in global arrival order: whenever a drive is
    available, the oldest pending request whose cartridge can be mounted is
    served alone.  Every request pays a full seek from the load point — the
    baseline any batching policy must beat.  (``fifo`` is the legacy PR-3
    name; on a pool both spell the same rule.)
``accumulate`` / ``per-drive-accumulate``
    Accumulate-then-solve with a re-plan window: a cartridge becomes
    *mount-ready* once its oldest pending request has waited ``window`` time
    units; a free drive mounts the mount-ready cartridge with the oldest
    head-of-queue request and serves its whole queue as one batch.
    ``window=0`` degenerates to greedy batching.
``preempt``
    Greedy batching plus preemptive re-solve on arrival: a request arriving
    for a cartridge that is mid-batch aborts the in-flight plan at that
    instant — requests already served keep their completion times, the head
    rewinds from wherever it is, and the survivors plus the newcomer are
    re-solved as one batch.  Wins when late arrivals would otherwise wait
    out a long plan; loses the rewind penalty when arrivals are dense.
``batched``
    Cross-cartridge device batching: in one event tick, *all* mount-ready
    cartridges (up to the number of assignable drives) are gathered and
    planned through a **single** :func:`repro.core.solve_batch` call — on a
    device backend that is one bucketed wavefront launch for the whole tick
    instead of one launch per cartridge.  Scheduling results are identical
    to ``per-drive-accumulate``; only the solve batching differs.

Deadline-aware (QoS) admissions — these read the ``qos`` mapping
(``req_id`` -> :class:`~repro.serving.qos.QoSSpec`) attached at construction;
requests without a spec/deadline are best-effort and sort last:

``edf-global``
    Earliest-deadline-first per-request serving: the next mount is chosen by
    the most urgent *queued* request across all pending queues (live
    deadline, then arrival, then id), and that single request is served —
    the deadline-aware counterpart of ``fifo-global`` (same batching
    discipline, different order).  Expired deadlines demote to best-effort:
    a request already past its deadline is missed regardless, so it must
    not starve still-meetable ones (the EDF overload domino).
``slack-accumulate``
    ``per-drive-accumulate`` whose hold window collapses as slack burns
    down: a cartridge becomes mount-ready at ``min(head arrival + window,
    earliest live queued deadline - window)``, i.e. the moment any queued
    request's slack drops below the hold window itself the whole queue
    dispatches — early enough that the deadline is still reachable.
    Mount-ready cartridges are served most-urgent-first.

Every dispatched schedule is checked by :func:`repro.core.verify.verify_schedule`
(structural validity + the simulator's independent cost recomputation must
equal the solver-reported cost exactly) unless ``verify=False``.  Mount legs
are charged ahead of each batch's trajectory: completions shift by the
drive's mount delay and the pool's mount/unmount accounting lands in the
:class:`~repro.serving.sim.ServiceReport`.

Load-adaptive dispatch and overload control (opt-in)
----------------------------------------------------
Under heavy traffic the exact DP's own runtime is a service-time component:
``selector=`` names a registered :class:`~repro.core.solver.SolverSelector`
(``"fixed"`` / ``"depth-threshold"`` / ``"cost-model"``, see
:mod:`repro.core.solver`) that the server consults at every dispatch tick
with the tick's load (total queued requests, batch size, the run's recorded
per-policy solve timings) and the context's
:class:`~repro.core.context.ComputeBudget` — picking the exact DP while
queues are shallow and restricted DP / heuristics as depth grows.  The
server applies ``budget.hysteresis`` per cartridge (a differing choice must
repeat that many consecutive ticks before it takes effect) so the policy
doesn't flap, and keys warm states per ``(cartridge, policy)`` so switching
never seeds one policy's DP table from another's.  When the budget prices
compute (``solve_time_num/solve_time_den``), every dispatch charges its
solve's evaluated DP cells into the timeline as extra pre-trajectory delay
— the per-batch ``policy_used``/``solve_delay`` land in
:class:`~repro.serving.sim.BatchRecord` and the mix in
:meth:`~repro.serving.sim.ServiceReport.summary`.  Two further overload
controls ride the QoS layer: ``preempt_urgent=True`` lets an urgent arrival
abort a *different* cartridge's all-lax in-flight batch (plain ``preempt``
only ever aborts the arriving cartridge's own batch), and
``class_weights=`` adds per-class virtual time to deadlines as the
scheduler sees them — spending ``batch``-class slack to protect
``interactive`` — while SLO reporting keeps judging the true deadlines.
With ``selector``/``preempt_urgent``/``class_weights`` unset, every
timeline is bit-identical to the pre-adaptive server.

Fault tolerance and crash recovery (opt-in)
-------------------------------------------
``faults=`` takes a deterministic :class:`~repro.serving.faults.FaultPlan`
(drive hard-failures, transient mount failures, bad media spans, transient
solver faults) and ``retry=`` a :class:`~repro.serving.drives.RetryPolicy`
(attempt budgets, exponential backoff charged in exact virtual time,
failover vs. fail-stop, typed-error vs. typed-drop exhaustion).  A failed
drive leaves the pool for good: its in-flight batch aborts through the
``preempt`` machinery (completions at or before the failure stand, the
survivors requeue marked ``faulted``) and its cartridge remounts on a
surviving drive at full remount cost.  Media faults abort at the exact
instant the head touches the bad span; mount faults charge backoff before
the retry; solver faults degrade through
:func:`repro.core.solver.solve_warm_degraded` (``pallas →
pallas-interpret → python``, bit-identical, warm states invalidated on
fallback).  All counts land in :class:`~repro.serving.sim.BatchRecord` /
:class:`~repro.serving.sim.ServiceReport`.  ``journal=`` appends every
observable event to a :class:`~repro.serving.faults.EventJournal`
write-ahead log; :func:`repro.serving.faults.recover_server` resumes a
killed run from it, bit-identical.  With all three unset, every code path
and report is bit-identical to the fault-unaware server.

Observability (opt-in)
----------------------
An :class:`~repro.obs.Observability` bundle on the context
(``context.replace(obs=Observability.enabled())``) makes the server record
*where virtual time goes*: mount / solve-delay / batch spans per drive
lane, arrival / preempt / fault instants, and exact-int counters and
histograms (queue depth, sojourns, deadline outcomes, retry backoff, DP
cell work) into the bundle's tracer and metrics registry — exported by
:mod:`repro.obs.export` as JSONL, Prometheus text, and Chrome trace JSON.
Every hook records integers the loop already computed, after the journal
write, so with ``obs`` unset (the default) timelines, reports, and
journals are bit-identical to the uninstrumented server.
"""

from __future__ import annotations

import dataclasses
import heapq
import os
from collections import deque
from typing import Mapping

from ..core.context import DEFAULT_BUDGET, ExecutionContext, resolve_context
from ..core.solver import (
    LoadView,
    SolveCache,
    SolverSelector,
    SolverUnavailableError,
    get_selector,
    solve_batch_warm,
    solve_batch_warm_degraded,
    solve_warm,
    solve_warm_degraded,
)
from ..core.verify import verify_schedule
from ..storage.tape import PendingQueue, TapeLibrary
from .drives import (
    DriveCosts,
    DrivePool,
    GreedyScheduler,
    MountScheduler,
    MountView,
    NoDriveAvailableError,
    PoolDrive,
    RetryPolicy,
)
from .faults import (
    EventJournal,
    FaultInjector,
    FaultPlan,
    JournalReplayError,
    MediaReadError,
    MountFailedError,
)
from .qos import QoSSpec
from .sim import (
    BatchRecord,
    FailedRequest,
    Replay,
    Request,
    ServedRequest,
    ServiceReport,
    head_position,
    replay_schedule,
    rewind_time,
)

__all__ = [
    "ADMISSIONS",
    "LEGACY_ADMISSIONS",
    "POOL_ADMISSIONS",
    "QOS_ADMISSIONS",
    "WINDOWED_ADMISSIONS",
    "OnlineTapeServer",
    "serve_trace",
]

#: legacy names from the one-drive-per-cartridge era (still fully supported).
LEGACY_ADMISSIONS = ("fifo", "accumulate", "preempt")
#: pool-era names (cross-cartridge; ``batched`` adds one-launch-per-tick).
POOL_ADMISSIONS = ("fifo-global", "per-drive-accumulate", "batched")
#: deadline-aware admissions (read the ``qos`` map; see module docstring).
QOS_ADMISSIONS = ("edf-global", "slack-accumulate")
ADMISSIONS = LEGACY_ADMISSIONS + POOL_ADMISSIONS + QOS_ADMISSIONS

#: admissions whose dispatch is gated on the accumulate ``window`` (callers
#: sweeping admissions use this to decide which ones take a window argument).
WINDOWED_ADMISSIONS = (
    "accumulate",
    "per-drive-accumulate",
    "batched",
    "slack-accumulate",
)

#: admissions that dispatch one request at a time (global arrival order, or
#: global deadline order for ``edf-global``).
_ONE_SHOT = {"fifo", "fifo-global", "edf-global"}
_WINDOWED = set(WINDOWED_ADMISSIONS)
_DEADLINE = set(QOS_ADMISSIONS)


class OnlineTapeServer:
    """Event-driven online serving of an arrival trace against a library.

    One instance simulates one run: virtual time advances over arrival,
    window-expiry, and drive-free events; all arithmetic is exact integers,
    so two runs with the same trace and configuration are bit-identical.

    ``n_drives`` defaults to one drive per cartridge and ``drive_costs`` to
    the all-zero model — exactly the PR-3 server.  Shrink the pool and/or
    price the mount legs to simulate a real robotic library.

    QoS is opt-in: ``qos`` attaches a :class:`~repro.serving.qos.QoSSpec`
    (deadline + priority class) per request id at enqueue time, enabling
    the deadline-aware admissions and the SLO statistics
    (:func:`repro.serving.qos.slo_report`); ``mount_scheduler`` selects the
    :class:`~repro.serving.drives.MountScheduler` eviction policy.  With
    both left at their defaults every admission reproduces the QoS-less
    behaviour bit for bit.
    """

    def __init__(
        self,
        library: TapeLibrary,
        admission: str = "accumulate",
        *,
        window: int = 0,
        policy: str = "dp",
        n_drives: int | None = None,
        drive_costs: DriveCosts | None = None,
        qos: Mapping[int, QoSSpec] | None = None,
        mount_scheduler: str | MountScheduler = "greedy",
        context: ExecutionContext | None = None,
        backend: str | None = None,
        cache: SolveCache | None = None,
        verify: bool = True,
        warm_start: bool = True,
        faults: FaultPlan | None = None,
        retry: RetryPolicy | None = None,
        journal: EventJournal | str | os.PathLike | None = None,
        selector: str | SolverSelector | None = None,
        preempt_urgent: bool = False,
        class_weights: Mapping[str, int] | None = None,
    ):
        if admission not in ADMISSIONS:
            raise ValueError(
                f"unknown admission policy {admission!r}; choose from {ADMISSIONS}"
            )
        if window < 0:
            raise ValueError("window must be >= 0")
        if n_drives is not None and n_drives < 1:
            raise ValueError("n_drives must be >= 1")
        if preempt_urgent and admission not in _DEADLINE:
            raise ValueError(
                "preempt_urgent needs a deadline-aware admission "
                f"(one of {QOS_ADMISSIONS}); got {admission!r}"
            )
        if class_weights:
            for cls, w in class_weights.items():
                if not isinstance(w, int) or w < 0:
                    raise ValueError(
                        f"class weight for {cls!r} must be a non-negative "
                        f"int of virtual time, got {w!r}"
                    )
        self.lib = library
        self.admission = admission
        self.window = int(window)
        self.policy = policy
        self.context = resolve_context(context, backend=backend, cache=cache)
        self.n_drives = n_drives
        self.drive_costs = drive_costs if drive_costs is not None else DriveCosts()
        self.qos: dict[int, QoSSpec] = dict(qos) if qos else {}
        self.mount_scheduler = mount_scheduler
        self.verify = verify
        self.warm_start = warm_start
        self.faults = faults if faults else None  # empty plan == no plan
        self._retry_given = retry is not None
        self.retry = retry if retry is not None else RetryPolicy()
        if isinstance(journal, EventJournal) or journal is None:
            self._journal = journal
        else:
            self._journal = EventJournal(journal)
        # adaptive dispatch (all opt-in; None/False reproduces PR 7 bit-exact)
        self.selector: SolverSelector | None = (
            get_selector(selector) if selector is not None else None
        )
        self.selector_name = self.selector.name if self.selector else None
        self.budget = (
            self.context.budget if self.context.budget is not None else DEFAULT_BUDGET
        )
        self.preempt_urgent = bool(preempt_urgent)
        self.class_weights: dict[str, int] | None = (
            dict(class_weights) if class_weights else None
        )
        # journal-replay cross-check prefix; recover_server fills it
        self._expect: deque = deque()
        # per-(cartridge, policy) WarmState store for runs without a cache
        # backend; with one, states live on the backend (get_warm/put_warm)
        self._warm_local: dict[tuple, object] = {}
        # observability (opt-in, see repro.obs): every hook below is guarded
        # by ``obs is not None`` and records already-computed exact integers,
        # so an unset obs reproduces the uninstrumented run bit for bit
        self.obs = self.context.obs
        self._obs_shard = 0  # the fleet layer stamps each shard's index here

    # -- event plumbing ------------------------------------------------------
    def _push(self, when: int, kind: str, data) -> None:
        self._seq += 1
        heapq.heappush(self._events, (when, self._seq, kind, data))

    # -- warm-state plumbing (see the module docstring) ----------------------
    def _warm_key(self, tape_id: str, policy: str | None = None) -> tuple:
        # keys carry the *solving* policy: with a selector switching policies
        # per tick, each (cartridge, policy) pair keeps its own warm lineage —
        # a warm state from one policy's DP table must never seed another's
        return ("warm", tape_id, policy if policy is not None else self.policy)

    def _get_warm(self, tape_id: str, policy: str | None = None):
        if not self.warm_start:
            return None
        cache = self.context.cache
        if cache is not None and hasattr(cache, "get_warm"):
            return cache.get_warm(self._warm_key(tape_id, policy))
        return self._warm_local.get(self._warm_key(tape_id, policy))

    def _put_warm(self, tape_id: str, state, policy: str | None = None) -> None:
        if not self.warm_start or state is None:
            return
        cache = self.context.cache
        if cache is not None and hasattr(cache, "put_warm"):
            cache.put_warm(self._warm_key(tape_id, policy), state)
        else:
            self._warm_local[self._warm_key(tape_id, policy)] = state

    def _drop_warm(self, tape_id: str, policy: str | None = None) -> None:
        """Invalidate a cartridge's warm state (degradation-chain fallback)."""
        cache = self.context.cache
        if cache is not None and hasattr(cache, "put_warm"):
            cache.put_warm(self._warm_key(tape_id, policy), None)
        else:
            self._warm_local.pop(self._warm_key(tape_id, policy), None)

    # -- adaptive dispatch (see repro.core.solver.SolverSelector) ------------
    def _select_policy(self, key: str, depth: int, n_requests: int, now: int) -> str:
        """The tick's solving policy for ``key`` (a cartridge, or ``"*"``).

        Consults the selector with a :class:`LoadView` and applies
        ``budget.hysteresis``: a differing choice must repeat for that many
        consecutive ticks before it replaces the active policy, so a queue
        depth oscillating around a threshold cannot flap the policy (and
        thrash warm states) every tick.
        """
        want = self.selector.select(
            LoadView(
                depth=depth, n_requests=n_requests, now=now,
                timings=self._sel_timings,
            ),
            self.budget,
        )
        if want is None:
            want = self.policy
        active = self._sel_active.get(key, self.policy)
        if want == active:
            self._sel_pending.pop(key, None)
            return active
        pol, streak = self._sel_pending.get(key, (want, 0))
        streak = streak + 1 if pol == want else 1
        if streak >= self.budget.hysteresis:
            self._sel_pending.pop(key, None)
            self._sel_active[key] = want
            return want
        self._sel_pending[key] = (want, streak)
        return active

    def _note_timing(self, policy: str, n_requests: int, stats) -> None:
        """Feed one real solve's cell count into the cost model's history.

        Cache hits are skipped: they report ``cells_evaluated == 0`` for
        work the cache did earlier, which would teach the cost model that
        solves are free.
        """
        if stats.mode == "cache":
            return
        cells, cubes = self._sel_timings.get(policy, (0, 0))
        self._sel_timings[policy] = (
            cells + stats.cells_evaluated,
            cubes + max(1, n_requests) ** 3,
        )

    # -- write-ahead journal (see repro.serving.faults) ----------------------
    def _log(self, **ev) -> None:
        """Journal one event — or, while recovering, cross-check it.

        Values must be JSON primitives (ints/strs/lists) so a journaled
        event round-trips to an equal dict.  While the recovery prefix
        (``self._expect``) lasts, re-produced events are verified against
        it instead of re-written; any divergence means the journal belongs
        to a different run and raises :class:`JournalReplayError`.
        """
        if self._journal is None:
            return
        if self._expect:
            want = self._expect.popleft()
            if want != ev:
                raise JournalReplayError(
                    f"journal replay diverged: journaled {want!r}, "
                    f"re-execution produced {ev!r}"
                )
            return
        self._journal.append(ev)

    # -- fault handling (see repro.serving.faults) ---------------------------
    def _record_served(self, drive: PoolDrive, pairs) -> None:
        for req, completed in pairs:
            self._served.append(
                ServedRequest(
                    req_id=req.req_id,
                    name=req.name,
                    tape_id=req.tape_id,
                    arrival=req.time,
                    dispatched=drive.dispatched,
                    completed=completed,
                    faulted=req.req_id in self._faulted,
                )
            )
            if self.obs is not None:
                self.obs.inc("requests_served_total")
                self.obs.observe("sojourn", completed - req.time)
                spec = self.qos.get(req.req_id)
                if spec is not None and spec.deadline is not None:
                    self.obs.inc("deadlines_total")
                    if completed > spec.deadline:
                        self.obs.inc("deadline_misses_total")

    def _fail_requests(self, reqs: list[Request], reason: str, now: int) -> None:
        for req in reqs:
            self._failed.append(
                FailedRequest(
                    req_id=req.req_id,
                    name=req.name,
                    tape_id=req.tape_id,
                    arrival=req.time,
                    failed_at=now,
                    reason=reason,
                )
            )
        if self.obs is not None and reqs:
            self.obs.inc("requests_failed_total", len(reqs), reason=reason)
            self.obs.event(
                "drop", now, track="queue", shard=self._obs_shard,
                reason=reason, n=len(reqs),
            )

    def _requeue(self, pending: list[Request], reason: str, now: int) -> list[int]:
        """Re-enqueue aborted in-flight requests (failover) or drop them.

        Requeued requests keep their original arrival times, so they sort
        back to the head of their queue deterministically — same rule as an
        admission preemption.
        """
        if not pending:
            return []
        if self.retry.failover:
            for req in pending:
                self.lib.enqueue(req.name, req)
                self._faulted.add(req.req_id)
            self._n_requeued += len(pending)
        else:
            self._fail_requests(pending, reason, now)
        return [r.req_id for r in pending]

    def _fail_drive(self, drive: PoolDrive, now: int) -> None:
        """Hard drive failure: abort in-flight work, remove from the pool.

        Completions at or before the failure stand (those bytes were read);
        the survivors requeue (failover) or drop (fail-stop).  The head
        state dies with the drive — no rewind to charge — and the pool
        extracts the cartridge so it can remount elsewhere at full remount
        cost.  If fault-injection ever targets an already-failed drive the
        event is a no-op.
        """
        if drive.failed:
            return
        if self._injector is not None:
            self._injector.drive_failed()
        requeued: list[int] = []
        if drive.busy and drive.inflight:
            done = [(r, c) for r, c in drive.inflight if c <= now]
            pending = [r for r, c in drive.inflight if c > now]
            self._record_served(drive, done)
            aborted = self._batches[drive.batch_idx]
            self._batches[drive.batch_idx] = dataclasses.replace(
                aborted, aborted_by="drive-failure", n_completed=len(done)
            )
            requeued = self._requeue(pending, "drive-failure", now)
        drive.epoch += 1  # invalidate any scheduled free/media-abort event
        drive.inflight = []
        drive.legs = ()
        self.pool.fail_drive(drive)
        self._log(ev="drive-fail", t=now, drive=drive.drive_id, requeued=requeued)
        if self.obs is not None:
            self.obs.event(
                "drive-fail", now, track=f"drive{drive.drive_id}",
                shard=self._obs_shard, requeued=len(requeued),
            )

    def _media_abort(self, drive: PoolDrive, now: int, span: tuple) -> None:
        """A read pass hit a bad media span: abort at the touch instant.

        Works like a preemption — completions before the fault stand, the
        head rewinds from its exact trajectory position — plus the retry
        policy's backoff charged before the drive frees.  Survivors requeue
        for a retry read until the span's attempt budget is exhausted, then
        the typed error/drop path applies.
        """
        self._n_media_aborts += 1
        done = [(r, c) for r, c in drive.inflight if c <= now]
        pending = [r for r, c in drive.inflight if c > now]
        self._record_served(drive, done)
        attempts = self._media_attempts.get(span, 0)
        requeued: list[int] = []
        if attempts >= self.retry.attempts("media"):
            if self.retry.on_exhausted == "error":
                raise MediaReadError(span, attempts)
            self._fail_requests(pending, "media-error", now)
        else:
            requeued = self._requeue(pending, "media-error", now)
        aborted = self._batches[drive.batch_idx]
        self._batches[drive.batch_idx] = dataclasses.replace(
            aborted, aborted_by="media-error", n_completed=len(done)
        )
        backoff = self.retry.backoff(max(1, attempts))
        self._retry_delay += backoff
        pos = head_position(drive.legs, now - drive.service_start)
        free_at = now + rewind_time(drive.load_point, drive.u_turn, pos) + backoff
        drive.epoch += 1
        drive.inflight = []
        drive.legs = ()
        drive.service_end = now
        drive.busy_until = free_at
        drive.busy = True
        self._log(
            ev="abort", t=now, drive=drive.drive_id, reason="media-error",
            requeued=requeued,
        )
        if self.obs is not None:
            self.obs.inc("media_aborts_total")
            self.obs.event(
                "media-abort", now, track=f"drive{drive.drive_id}",
                shard=self._obs_shard,
            )
        self._push(drive.busy_until, "free", (drive.drive_id, drive.epoch))

    def _acquire(
        self, tid: str, now: int, view: MountView | None
    ) -> tuple[PoolDrive, int, int] | None:
        """:meth:`DrivePool.acquire` plus transient-mount retry handling.

        Returns ``(drive, delay, retries)`` with the retry backoff folded
        into the mount delay (exact virtual time), or ``None`` when the
        mount budget is exhausted under the drop policy (the cartridge's
        queued requests have been recorded as failed).
        """
        retries = 0
        extra = 0
        if self._injector is not None and self.pool.drive_of(tid) is None:
            while self._injector.mount_fails(tid):
                retries += 1
                self._n_mount_retries += 1
                if retries >= self.retry.attempts("mount"):
                    if self.retry.on_exhausted == "error":
                        raise MountFailedError(tid, retries)
                    reqs = self.lib.pending(tid).drain()
                    self._fail_requests(reqs, "mount-failed", now)
                    self._log(
                        ev="mount-failed", t=now, tape=tid,
                        dropped=[r.req_id for r in reqs],
                    )
                    return None
                extra += self.retry.backoff(retries)
                self._retry_delay += self.retry.backoff(retries)
                if self.obs is not None:
                    self.obs.inc("mount_retries_total")
                    self.obs.inc(
                        "retry_backoff_total", self.retry.backoff(retries)
                    )
        drive, delay = self.pool.acquire(tid, now=now, view=view)
        return drive, delay + extra, retries

    def run(self, trace: list[Request]) -> ServiceReport:
        """Serve a full arrival trace; returns the per-request report."""
        self._begin(trace)
        while self._events:
            self._step()
        return self._finish()

    # -- stepping primitives (the fleet layer in repro.fleet drives these) ----
    # ``run`` is begin -> step-until-drained -> finish, so a federation can
    # interleave several servers in one shared virtual clock by always
    # stepping the server whose next event is globally earliest.  A shard
    # driven this way receives its arrivals one at a time (_on_arrival)
    # instead of pre-seeded, and stays an unmodified OnlineTapeServer.
    def _begin(self, trace: list[Request]) -> None:
        """Initialise run state and seed the event heap (no events popped)."""
        self._events: list = []
        self._seq = 0
        n = self.n_drives if self.n_drives is not None else max(1, len(self.lib.tapes))
        self.pool = DrivePool(
            n, self.drive_costs, scheduler=self.mount_scheduler, retry=self.retry
        )
        if self.obs is not None:
            self.pool.obs = self.obs
            cache = self.context.cache
            if cache is not None and hasattr(cache, "obs"):
                cache.obs = self.obs
        self._served: list[ServedRequest] = []
        self._batches: list[BatchRecord] = []
        self._next_wake: dict[str, int] = {}  # tape_id -> pending window timer
        self._n_preempt = 0
        self._injector = FaultInjector(self.faults) if self.faults else None
        self._failed: list[FailedRequest] = []
        self._faulted: set[int] = set()  # req_ids touched by a fault
        self._media_attempts: dict[tuple, int] = {}  # span -> read attempts
        self._n_mount_retries = 0
        self._n_media_aborts = 0
        self._n_solver_faults = 0
        self._n_fallbacks = 0
        self._n_requeued = 0
        self._retry_delay = 0  # total backoff charged, exact virtual time
        # adaptive-dispatch state: per-policy (cells, n^3) solve history for
        # the cost model, and per-cartridge active/pending-switch hysteresis
        self._sel_timings: dict[str, tuple[int, int]] = {}
        self._sel_active: dict[str, str] = {}
        self._sel_pending: dict[str, tuple[str, int]] = {}
        self._horizon = 0

        for req in sorted(trace):
            self._push(req.time, "arrival", req)
        if self._injector is not None:
            for f in self._injector.drive_failures():
                if f.drive >= n:
                    raise ValueError(
                        f"fault plan fails drive {f.drive} but the pool has "
                        f"only {n} drive(s)"
                    )
                self._push(f.at, "drive-fail", f.drive)
        self._log(
            ev="start", admission=self.admission, policy=self.policy,
            window=self.window, n_trace=len(trace),
        )

    def _next_time(self) -> int | None:
        """Virtual time of the next queued event (None: heap drained)."""
        return self._events[0][0] if self._events else None

    def _on_arrival(self, req: Request, now: int) -> None:
        """Admit one arriving request at ``now`` (the arrival event body)."""
        self._horizon = max(self._horizon, now)
        tape_id = self.lib.enqueue(req.name, req)
        self._log(ev="enqueue", t=now, req=req.req_id, tape=tape_id)
        if self.obs is not None:
            self.obs.event(
                "arrival", now, track="queue", shard=self._obs_shard,
                req=req.req_id, tape=tape_id,
            )
            self.obs.inc("requests_arrived_total")
            self.obs.observe(
                "queue_depth", sum(len(q) for q in self.lib.queues.values())
            )
        if self.admission == "preempt":
            drive = self.pool.drive_of(tape_id)
            if drive is not None and drive.busy and now < drive.service_end:
                self._preempt(drive, now)
        if self.preempt_urgent:
            self._maybe_preempt_urgent(req, tape_id, now)
        self._schedule(now)

    def _step(self) -> None:
        """Pop and process exactly one event from the heap."""
        now, _, kind, data = heapq.heappop(self._events)
        self._horizon = max(self._horizon, now)
        if kind == "arrival":
            self._on_arrival(data, now)
        elif kind == "free":
            drive_id, epoch = data
            drive = self.pool.drives[drive_id]
            if epoch != drive.epoch or not drive.busy:
                return  # superseded by a preemption
            self._complete(drive)
            self._schedule(now)
        elif kind == "wake":
            tape_id, when = data
            if self._next_wake.get(tape_id) != when:
                return  # superseded timer
            del self._next_wake[tape_id]
            self._schedule(now)
        elif kind == "drive-fail":
            self._fail_drive(self.pool.drives[data], now)
            self._schedule(now)
        elif kind == "media-abort":
            drive_id, epoch, span = data
            drive = self.pool.drives[drive_id]
            if epoch != drive.epoch or not drive.busy or drive.failed:
                return  # batch already gone (preempted / drive died)
            self._media_abort(drive, now, span)
            self._schedule(now)

    def _finish(self) -> ServiceReport:
        """Drain unservable leftovers and assemble the final report."""
        horizon = self._horizon
        self._drain_unservable(horizon)
        horizon = max([horizon] + [d.busy_until for d in self.pool.alive])
        fault_stats = None
        if self._injector is not None or self._retry_given:
            fault_stats = {
                "drive_failures": self.pool.n_drive_failures,
                "mount_retries": self._n_mount_retries,
                "media_aborts": self._n_media_aborts,
                "solver_faults": self._n_solver_faults,
                "fallbacks": self._n_fallbacks,
                "requeued": self._n_requeued,
                "retry_delay": self._retry_delay,
            }
        report = ServiceReport(
            admission=self.admission,
            policy=self.policy,
            backend=self.context.backend,
            window=self.window,
            served=sorted(self._served, key=lambda r: (r.completed, r.req_id)),
            batches=self._batches,
            n_preemptions=self._n_preempt,
            horizon=horizon,
            cache_stats=(
                self.context.cache.stats() if self.context.cache is not None else None
            ),
            pool_stats=self.pool.stats(),
            scheduler=self.pool.scheduler.name,
            qos=self.qos or None,
            warm_start=self.warm_start,
            failed=self._failed,
            fault_stats=fault_stats,
            selector=self.selector_name,
        )
        self._log(
            ev="end", horizon=horizon, n_served=report.n_served,
            n_failed=report.n_failed, total_sojourn=report.total_sojourn,
        )
        return report

    def _drain_unservable(self, now: int) -> None:
        """End-of-loop backstop: requests still queued with no drive left.

        The event loop only ends with non-empty queues when every drive has
        hard-failed (nothing can ever free or dispatch again).  Typed raise
        with the requests left queued under ``on_exhausted="error"``;
        typed :class:`~repro.serving.sim.FailedRequest` drops otherwise.
        """
        leftover = sorted(
            (r for q in self.lib.queues.values() for r in q),
            key=lambda r: (r.time, r.req_id),
        )
        if not leftover:
            return
        assert not self.pool.alive, "queued requests with live drives at exit"
        if self.retry.on_exhausted == "error":
            raise NoDriveAvailableError(len(leftover))
        for tid in sorted(self.lib.queues):
            self.lib.queues[tid].drain()
        self._fail_requests(leftover, "no-drive", now)

    # -- admission -----------------------------------------------------------
    def _deadline_of(self, req: Request) -> int | None:
        """The request's deadline *as the scheduler sees it*.

        With ``class_weights`` set, a class's weight (virtual time) is added
        to its members' deadlines for every scheduling decision — a
        ``batch``-class request with weight ``w`` yields as if its deadline
        were ``w`` later, spending its slack to protect lighter classes
        (``interactive`` at weight 0 keeps its true urgency).  SLO reporting
        (:func:`repro.serving.qos.slo_report`) reads the unweighted specs, so
        misses are always judged against the real deadlines.
        """
        spec = self.qos.get(req.req_id)
        if spec is None or spec.deadline is None:
            return None
        if self.class_weights:
            return spec.deadline + self.class_weights.get(spec.qos_class, 0)
        return spec.deadline

    def _queue_deadline(
        self, queue: PendingQueue, now: int | None = None
    ) -> int | None:
        """Earliest deadline among a cartridge's queued requests, if any.

        With ``now`` given, only *live* deadlines (not yet expired) count —
        an expired deadline is missed no matter what happens next, so it
        must not keep reading as maximally urgent.
        """
        deadlines = [
            d
            for d in (self._deadline_of(r) for r in queue)
            if d is not None and (now is None or d > now)
        ]
        return min(deadlines) if deadlines else None

    def _candidates(self, now: int) -> list[str]:
        """Dispatch-ready cartridges, oldest head-of-queue request first.

        Window-gated admissions also (re)arm a wake timer per not-yet-ready
        cartridge; timers deduplicate on the ready instant, and a stale timer
        is discarded on pop when its instant no longer matches.
        """
        if self.admission in _DEADLINE:
            return self._qos_candidates(now)
        ready: list[tuple[int, int, str]] = []
        for tid in sorted(self.lib.queues):
            queue = self.lib.queues[tid]
            if len(queue) == 0:
                continue
            head = queue.peek()
            if self.admission in _WINDOWED:
                at = head.time + self.window
                if now < at:
                    if self._next_wake.get(tid) != at:
                        self._next_wake[tid] = at
                        self._push(at, "wake", (tid, at))
                    continue
            ready.append((head.time, head.req_id, tid))
        ready.sort()
        return [tid for _, _, tid in ready]

    def _qos_candidates(self, now: int) -> list[str]:
        """Dispatch-ready cartridges for the deadline-aware admissions.

        Readiness: ``edf-global`` is always ready (per-request, like
        ``fifo-global``); ``slack-accumulate`` holds a queue until
        ``min(head arrival + window, earliest live deadline - window)`` —
        the accumulate hold collapses once any queued request's slack burns
        below the hold window itself, so the batch dispatches while the
        deadline is still reachable (a new arrival with a nearer deadline
        re-arms the wake timer earlier; the stale timer is discarded on
        pop).  Ready cartridges are ordered most-urgent-first: earliest
        live queued deadline, then head arrival/id; queues with no live
        deadline sort last.
        """
        ready: list[tuple[int, int, int, int, str]] = []
        for tid in sorted(self.lib.queues):
            queue = self.lib.queues[tid]
            if len(queue) == 0:
                continue
            head = queue.peek()
            dmin = self._queue_deadline(queue, now)
            if self.admission == "slack-accumulate":
                at = head.time + self.window
                if dmin is not None:
                    at = min(at, dmin - self.window)
                if now < at:
                    if self._next_wake.get(tid) != at:
                        self._next_wake[tid] = at
                        self._push(at, "wake", (tid, at))
                    continue
            urgency = (1, 0) if dmin is None else (0, dmin)
            ready.append((*urgency, head.time, head.req_id, tid))
        ready.sort()
        return [t[-1] for t in ready]

    def _pop_urgent(self, queue: PendingQueue, now: int) -> Request:
        """Remove the most urgent queued request (EDF, arrival/id tie-break).

        Expired deadlines are demoted to best-effort: a request already past
        its deadline is missed no matter when it is served, so letting it
        keep outranking still-meetable requests would cascade misses (the
        classic EDF overload domino).
        """
        items = queue.drain()
        pick = min(items, key=lambda r: self._edf_key(r, now))
        for r in items:
            if r is not pick:
                queue.push(r)
        return pick

    def _edf_key(self, req: Request, now: int) -> tuple[int, int, int, int]:
        """Total EDF order — ties are deterministic by construction.

        Live deadlines sort first by deadline; two requests sharing a
        deadline order by ``(arrival, req_id)``.  Best-effort requests and
        expired-demoted ones share a single trailing bucket ``(1, 0, ...)``
        — demotion deliberately erases the stale deadline so an
        expired-deadline request ties a live best-effort one and the same
        ``(arrival, req_id)`` rule breaks it (an expired deadline is missed
        no matter what; letting it keep outranking meetable work would
        cascade misses).  ``req_id`` is unique per trace, so the key is a
        total order and `min` is seed-stable.
        """
        d = self._deadline_of(req)
        if d is None or d <= now:  # best-effort, or already missed
            return (1, 0, req.time, req.req_id)
        return (0, d, req.time, req.req_id)

    def _mount_view(self, now: int) -> MountView | None:
        """Queue-state snapshot for the pool's mount scheduler.

        ``None`` under the default greedy scheduler, which ignores the view
        — the per-event depth/urgency scan is only paid when a scheduler
        actually decides on it (``acquire`` substitutes a bare view).
        """
        if isinstance(self.pool.scheduler, GreedyScheduler):
            return None
        pending = {
            tid: q for tid, q in self.lib.queues.items() if len(q) > 0
        }
        return MountView(
            now=now,
            costs=self.drive_costs,
            depth={tid: len(q) for tid, q in pending.items()},
            urgency=(
                {tid: self._queue_deadline(q, now) for tid, q in pending.items()}
                if self.qos
                else {}
            ),
        )

    def _schedule(self, now: int) -> None:
        """Dispatch every cartridge the admission policy admits at ``now``."""
        cands = self._candidates(now)
        if not cands:
            return
        view = self._mount_view(now)
        # the tick's load (total queued requests) is snapshotted before any
        # queue drains, so every selection this tick sees the same depth
        depth = (
            sum(len(q) for q in self.lib.queues.values())
            if self.selector is not None
            else 0
        )
        if self.admission == "batched":
            # one event tick -> one solve_batch over every admitted cartridge
            picks: list[tuple[PoolDrive, int, int, list[Request]]] = []
            for tid in cands:
                if not self.pool.can_serve(tid):
                    continue
                acq = self._acquire(tid, now, view)
                if acq is None:
                    continue  # mount budget exhausted: requests dropped
                drive, delay, retries = acq
                drive.busy = True  # reserve; _dispatch fills in the timeline
                picks.append((drive, delay, retries, self.lib.pending(tid).drain()))
            if not picks:
                return
            # one launch serves the whole tick, so one policy choice covers
            # it (hysteresis keyed on the reserved cross-cartridge key "*")
            pol = (
                self._select_policy(
                    "*", depth, sum(len(b) for *_, b in picks), now
                )
                if self.selector is not None
                else None
            )
            prepared = []
            for _, _, _, batch in picks:
                tape = self.lib.tape_of(batch[0].name)
                inst, names = tape.instance(_multiset(batch))
                prepared.append((tape, inst, names))
            try:
                results, new_warms, stats, rec = self._solve_batch_tick(
                    [inst for _, inst, _ in prepared],
                    [self._get_warm(t.tape_id, pol) for t, _, _ in prepared],
                    policy=pol,
                )
            except SolverUnavailableError:
                if self.retry.on_exhausted == "error":
                    raise
                # one tick = one launch = one fault domain: the whole tick's
                # work drops as typed failures, the reserved drives free up
                for drive, _, _, batch in picks:
                    drive.busy = False
                    self._fail_requests(batch, "solver-failed", now)
                    self._log(
                        ev="solve-failed", t=now, drive=drive.drive_id,
                        dropped=[r.req_id for r in batch],
                    )
                return
            degraded_to = rec.used if rec is not None and rec.fell_back else None
            for (drive, delay, retries, batch), (tape, inst, names), res, warm, st in zip(
                picks, prepared, results, new_warms, stats
            ):
                if rec is not None and rec.n_faults:
                    self._drop_warm(tape.tape_id, pol)  # invalidated on fallback
                else:
                    self._put_warm(tape.tape_id, warm, pol)
                self._dispatch(
                    drive, batch, now, delay, (tape, inst, names, res, st),
                    mount_retries=retries, degraded_to=degraded_to, policy=pol,
                )
            return
        for tid in cands:
            if not self.pool.can_serve(tid):
                continue
            acq = self._acquire(tid, now, view)
            if acq is None:
                continue  # mount budget exhausted: requests dropped
            drive, delay, retries = acq
            queue = self.lib.pending(tid)
            if self.admission == "edf-global":
                batch = [self._pop_urgent(queue, now)]
            elif self.admission in _ONE_SHOT:
                batch = [queue.pop()]
            else:
                batch = queue.drain()
            pol = (
                self._select_policy(tid, depth, len(batch), now)
                if self.selector is not None
                else None
            )
            self._dispatch(drive, batch, now, delay, mount_retries=retries, policy=pol)

    # -- solving (direct, or through the degradation chain under faults) -----
    def _solve_one(self, tape_id: str, inst, policy: str | None = None):
        """One cartridge's solve; returns ``(result, stats, degraded_to)``.

        ``policy`` overrides the server's configured policy for this tick
        (a selector's choice); warm states are read and written under the
        policy that actually solved.
        """
        pol = policy if policy is not None else self.policy
        warm = self._get_warm(tape_id, pol)
        if self._injector is None:
            res, new_warm, stats = solve_warm(
                inst, policy=pol, context=self.context, warm=warm
            )
            self._put_warm(tape_id, new_warm, pol)
            return res, stats, None
        res, new_warm, stats, rec = solve_warm_degraded(
            inst,
            policy=pol,
            context=self.context,
            warm=warm,
            fault_hook=self._injector.solver_hook,
            attempts_per_backend=self.retry.attempts("solver"),
        )
        if rec.n_faults:
            self._n_solver_faults += rec.n_faults
            self._n_fallbacks += rec.fell_back
            self._drop_warm(tape_id, pol)  # invalidated on fallback (new_warm None)
        else:
            self._put_warm(tape_id, new_warm, pol)
        return res, stats, rec.used if rec.fell_back else None

    def _solve_batch_tick(self, insts, warms, policy: str | None = None):
        """The ``batched`` admission's one-launch-per-tick solve."""
        pol = policy if policy is not None else self.policy
        if self._injector is None:
            results, new_warms, stats = solve_batch_warm(
                insts, policy=pol, context=self.context, warms=warms
            )
            return results, new_warms, stats, None
        results, new_warms, stats, rec = solve_batch_warm_degraded(
            insts,
            policy=pol,
            context=self.context,
            warms=warms,
            fault_hook=self._injector.solver_hook,
            attempts_per_backend=self.retry.attempts("solver"),
        )
        if rec.n_faults:
            self._n_solver_faults += rec.n_faults
            self._n_fallbacks += rec.fell_back
        return results, new_warms, stats, rec

    # -- drive actions -------------------------------------------------------
    def _dispatch(
        self,
        drive: PoolDrive,
        batch: list[Request],
        now: int,
        delay: int,
        prepared=None,
        mount_retries: int = 0,
        degraded_to: str | None = None,
        policy: str | None = None,
    ) -> None:
        pol = policy if policy is not None else self.policy
        if prepared is None:
            tape = self.lib.tape_of(batch[0].name)
            inst, names = tape.instance(_multiset(batch))
            try:
                res, stats, degraded_to = self._solve_one(tape.tape_id, inst, pol)
            except SolverUnavailableError:
                if self.retry.on_exhausted == "error":
                    raise
                self._fail_requests(batch, "solver-failed", now)
                self._log(
                    ev="solve-failed", t=now, drive=drive.drive_id,
                    dropped=[r.req_id for r in batch],
                )
                return
        else:
            tape, inst, names, res, stats = prepared
        if self.selector is not None:
            self._note_timing(pol, len(batch), stats)
        assert drive.mounted == tape.tape_id
        replay: Replay = replay_schedule(inst, res.detours)
        # the independent recomputation always lands in the BatchRecord; with
        # verify=True a disagreement (or structural defect) raises right here
        verified = replay.cost == res.cost
        if self.verify:
            verify_schedule(inst, res.detours, cost=res.cost, replay=replay)
        idx = {name: i for i, name in enumerate(names)}
        rewind = rewind_time(inst.m, inst.u_turn, replay.head_at_makespan)
        # mount legs and the budget-priced solve work are both charged before
        # the trajectory begins (with no ComputeBudget the charge is 0)
        solve_delay = self.budget.charge(stats.cells_evaluated)
        start = now + delay + solve_delay

        drive.busy = True
        drive.epoch += 1
        drive.dispatched = now
        drive.service_start = start
        drive.service_end = start + replay.makespan
        drive.busy_until = drive.service_end + rewind
        drive.legs = replay.legs
        drive.load_point = inst.m
        drive.u_turn = inst.u_turn
        drive.inflight = [
            (req, start + replay.service_time[idx[req.name]]) for req in batch
        ]
        drive.batch_idx = len(self._batches)
        if mount_retries:
            for req in batch:  # retried mounts delayed every request aboard
                self._faulted.add(req.req_id)
        self._batches.append(
            BatchRecord(
                tape_id=tape.tape_id,
                dispatched=now,
                n_requests=len(batch),
                n_files=inst.n_req,
                solver_cost=res.cost,
                replay_cost=replay.cost,
                makespan=replay.makespan,
                rewind=rewind,
                verified=verified,
                drive=drive.drive_id,
                mount_delay=delay,
                cells_evaluated=stats.cells_evaluated,
                cells_reused=stats.cells_reused,
                warm_mode=stats.mode,
                mount_retries=mount_retries,
                degraded_to=degraded_to,
                policy_used=pol if self.selector is not None else None,
                solve_delay=solve_delay,
            )
        )
        self._log(
            ev="batch", t=now, tape=tape.tape_id, drive=drive.drive_id,
            reqs=[r.req_id for r in batch], delay=delay, cost=res.cost,
            makespan=replay.makespan,
        )
        if self.obs is not None:
            track = f"drive{drive.drive_id}"
            if delay:
                self.obs.span(
                    "mount", now, now + delay, track=track,
                    shard=self._obs_shard, tape=tape.tape_id,
                )
            if solve_delay:
                self.obs.span(
                    "solve-delay", now + delay, start, track=track,
                    shard=self._obs_shard,
                )
            self.obs.span(
                "batch", start, drive.service_end, track=track,
                shard=self._obs_shard, tape=tape.tape_id,
                n_requests=len(batch), policy=pol,
                cells=stats.cells_evaluated,
            )
            self.obs.inc("batches_total")
            self.obs.inc("mount_delay_total", delay)
            self.obs.inc("solve_delay_total", solve_delay)
            self.obs.inc("cells_evaluated_total", stats.cells_evaluated)
            self.obs.inc("cells_reused_total", stats.cells_reused)
            if self.selector is not None:
                self.obs.inc("selector_decisions_total", policy=pol)
            if degraded_to:
                self.obs.inc("degraded_dispatches_total", backend=degraded_to)
        if self._injector is not None:
            hit = self._injector.media_fault(tape.tape_id, replay.legs)
            if hit is not None:
                t_rel, span = hit
                self._media_attempts[span] = self._media_attempts.get(span, 0) + 1
                self._push(
                    start + t_rel, "media-abort",
                    (drive.drive_id, drive.epoch, span),
                )
        self._push(drive.busy_until, "free", (drive.drive_id, drive.epoch))

    def _complete(self, drive: PoolDrive) -> None:
        self._record_served(drive, drive.inflight)
        self._log(
            ev="serve", t=drive.busy_until, drive=drive.drive_id,
            reqs=[[r.req_id, c] for r, c in drive.inflight],
        )
        drive.inflight = []
        drive.busy = False

    def _maybe_preempt_urgent(self, req: Request, tape_id: str, now: int) -> None:
        """Cross-cartridge preemption: abort a lax batch for an urgent arrival.

        The plain ``preempt`` admission only ever aborts the arriving
        cartridge's *own* in-flight batch; under drive contention an urgent
        arrival can instead be starved by a long lax batch on a *different*
        cartridge.  With ``preempt_urgent=True``, an arrival carrying a live
        (class-weighted) deadline that no drive can currently serve may
        abort one busy drive — but only a drive whose every unserved
        in-flight request is *lax* relative to the arrival (best-effort, or
        deadline strictly later), so urgent work never preempts equally
        urgent work.  Among eligible victims the fewest-survivors drive
        (ties by drive id) is aborted through the standard preemption
        machinery: completions stand, survivors requeue, the head rewinds,
        and the freed drive remounts under the admission's urgency order.
        """
        d = self._deadline_of(req)
        if d is None or d <= now:
            return  # best-effort or already missed: nothing to protect
        if self.pool.can_serve(tape_id):
            return  # a drive can take it without aborting anyone
        victim: PoolDrive | None = None
        victim_key: tuple[int, int] | None = None
        for drive in self.pool.alive:
            if not drive.busy or not drive.inflight:
                continue
            pending = [r for r, c in drive.inflight if c > now]
            if not pending:
                continue  # everything aboard already completed
            lax = all(
                (dl := self._deadline_of(r)) is None or dl > d for r in pending
            )
            if not lax:
                continue
            key = (len(pending), drive.drive_id)
            if victim_key is None or key < victim_key:
                victim, victim_key = drive, key
        if victim is not None:
            self._preempt(victim, now, reason="preempt-urgent")

    def _preempt(self, drive: PoolDrive, now: int, reason: str = "preempt") -> None:
        """Abort the in-flight batch at ``now``; requeue unserved requests.

        Completions at or before ``now`` stand; the head rewinds from its
        current trajectory position (one U-turn + seek to the load point)
        before the next dispatch.  The drive stays busy for the rewind.  A
        preemption landing inside the mount legs (before ``service_start``)
        cannot conjure the head to the load point early: the robot is
        already threading, so the drive stays busy until the mount
        completes (``service_start``), head then parked at the load point,
        no rewind to charge.
        """
        done = [(r, c) for r, c in drive.inflight if c <= now]
        pending = [r for r, c in drive.inflight if c > now]
        self._record_served(drive, done)
        for req in pending:
            self.lib.enqueue(req.name, req)
        if now < drive.service_start:
            # aborted mid-mount: the in-flight mount still runs to completion
            free_at = drive.service_start
        else:
            pos = head_position(drive.legs, now - drive.service_start)
            free_at = now + rewind_time(drive.load_point, drive.u_turn, pos)
        aborted = self._batches[drive.batch_idx]
        assert aborted.tape_id == drive.mounted
        assert aborted.dispatched == drive.dispatched
        self._batches[drive.batch_idx] = dataclasses.replace(
            aborted, preempted=True, n_completed=len(done)
        )
        drive.epoch += 1  # invalidate the scheduled drive-free event
        drive.inflight = []
        drive.legs = ()
        drive.service_end = now
        drive.busy_until = free_at
        drive.busy = True
        self._n_preempt += 1
        self._log(
            ev="abort", t=now, drive=drive.drive_id, reason=reason,
            requeued=[r.req_id for r in pending],
        )
        if self.obs is not None:
            self.obs.inc("preemptions_total", reason=reason)
            self.obs.event(
                "preempt", now, track=f"drive{drive.drive_id}",
                shard=self._obs_shard, reason=reason,
            )
        self._push(drive.busy_until, "free", (drive.drive_id, drive.epoch))


def _multiset(batch: list[Request]) -> dict[str, int]:
    multiset: dict[str, int] = {}
    for req in batch:
        multiset[req.name] = multiset.get(req.name, 0) + 1
    return multiset


def serve_trace(
    library: TapeLibrary,
    trace: list[Request],
    admission: str = "accumulate",
    *,
    window: int = 0,
    policy: str = "dp",
    n_drives: int | None = None,
    drive_costs: DriveCosts | None = None,
    qos: Mapping[int, QoSSpec] | None = None,
    mount_scheduler: str | MountScheduler = "greedy",
    context: ExecutionContext | None = None,
    backend: str | None = None,
    cache: SolveCache | None = None,
    verify: bool = True,
    warm_start: bool = True,
    faults: FaultPlan | None = None,
    retry: RetryPolicy | None = None,
    journal: EventJournal | str | os.PathLike | None = None,
    selector: str | SolverSelector | None = None,
    preempt_urgent: bool = False,
    class_weights: Mapping[str, int] | None = None,
) -> ServiceReport:
    """One-shot convenience: build an :class:`OnlineTapeServer` and run it."""
    server = OnlineTapeServer(
        library,
        admission,
        window=window,
        policy=policy,
        n_drives=n_drives,
        drive_costs=drive_costs,
        qos=qos,
        mount_scheduler=mount_scheduler,
        context=context,
        backend=backend,
        cache=cache,
        verify=verify,
        warm_start=warm_start,
        faults=faults,
        retry=retry,
        journal=journal,
        selector=selector,
        preempt_urgent=preempt_urgent,
        class_weights=class_weights,
    )
    return server.run(trace)

"""Online tape-serving subsystem: per-cartridge request queues + admission.

This is the serving loop the ROADMAP's north star asks for: read requests
arrive over (virtual) time against a :class:`~repro.storage.tape.TapeLibrary`,
accumulate in per-cartridge queues (:class:`~repro.storage.tape.PendingQueue`),
and an *admission policy* decides when a cartridge's queue becomes an LTSP
batch dispatched through the solver engine (:func:`repro.core.solve` — any
registered policy x backend, :class:`~repro.core.SolveCache`-aware).  The
discrete-event simulator in :mod:`repro.serving.sim` advances virtual time and
independently re-scores every emitted schedule, so online-vs-offline regret
and batching-vs-FIFO improvements are exact integers, not anecdotes.

Admission policies
------------------
``fifo``
    Per-request solving: the drive serves one request at a time in arrival
    order.  Every request pays a full seek from the load point — the
    baseline any batching policy must beat.
``accumulate``
    Accumulate-then-solve with a re-plan window: a cartridge's queue is
    dispatched as one batch once the oldest pending request has waited
    ``window`` time units (and the drive is free).  ``window=0`` degenerates
    to greedy batching: dispatch everything queued whenever the drive frees.
``preempt``
    Greedy batching plus preemptive re-solve on arrival: a request arriving
    while the drive is mid-batch aborts the in-flight plan at that instant —
    requests already served keep their completion times, the head rewinds
    from wherever it is, and the survivors plus the newcomer are re-solved
    as one batch.  Wins when late arrivals would otherwise wait out a long
    plan; loses the rewind penalty when arrivals are dense.

Every dispatched schedule is checked by :func:`repro.core.verify.verify_schedule`
(structural validity + the simulator's independent cost recomputation must
equal the solver-reported cost exactly) unless ``verify=False``.
"""

from __future__ import annotations

import dataclasses
import heapq

from ..core.solver import DEFAULT_BACKEND, SolveCache, solve
from ..core.verify import verify_schedule
from ..storage.tape import TapeLibrary
from .sim import (
    BatchRecord,
    Leg,
    Replay,
    Request,
    ServedRequest,
    ServiceReport,
    head_position,
    replay_schedule,
    rewind_time,
)

__all__ = ["ADMISSIONS", "OnlineTapeServer", "serve_trace"]

ADMISSIONS = ("fifo", "accumulate", "preempt")


@dataclasses.dataclass
class _Drive:
    """Per-cartridge drive state (one drive per cartridge)."""

    tape_id: str
    busy: bool = False
    epoch: int = 0  # invalidates stale drive-free events after preemption
    dispatched: int = 0
    service_end: int = 0  # dispatch + makespan (last completion)
    busy_until: int = 0  # service_end + rewind
    legs: tuple[Leg, ...] = ()
    inflight: list[tuple[Request, int]] = dataclasses.field(default_factory=list)
    next_wake: int = -1  # pending accumulate-window timer (dedup)
    batch_idx: int = -1  # index of the in-flight batch's BatchRecord
    load_point: int = 0  # in-flight instance's m (rewind target)
    u_turn: int = 0  # in-flight instance's U-turn penalty


class OnlineTapeServer:
    """Event-driven online serving of an arrival trace against a library.

    One instance simulates one run: virtual time advances over arrival,
    window-expiry, and drive-free events; all arithmetic is exact integers,
    so two runs with the same trace and configuration are bit-identical.
    """

    def __init__(
        self,
        library: TapeLibrary,
        admission: str = "accumulate",
        *,
        window: int = 0,
        policy: str = "dp",
        backend: str = DEFAULT_BACKEND,
        cache: SolveCache | None = None,
        verify: bool = True,
    ):
        if admission not in ADMISSIONS:
            raise ValueError(
                f"unknown admission policy {admission!r}; choose from {ADMISSIONS}"
            )
        if window < 0:
            raise ValueError("window must be >= 0")
        self.lib = library
        self.admission = admission
        self.window = int(window)
        self.policy = policy
        self.backend = backend
        self.cache = cache
        self.verify = verify

    # -- event plumbing ------------------------------------------------------
    def _push(self, when: int, kind: str, data) -> None:
        self._seq += 1
        heapq.heappush(self._events, (when, self._seq, kind, data))

    def run(self, trace: list[Request]) -> ServiceReport:
        """Serve a full arrival trace; returns the per-request report."""
        self._events: list = []
        self._seq = 0
        self._drives: dict[str, _Drive] = {}
        self._served: list[ServedRequest] = []
        self._batches: list[BatchRecord] = []
        self._n_preempt = 0
        horizon = 0

        for req in sorted(trace):
            self._push(req.time, "arrival", req)

        while self._events:
            now, _, kind, data = heapq.heappop(self._events)
            horizon = max(horizon, now)
            if kind == "arrival":
                req: Request = data
                tape_id = self.lib.enqueue(req.name, req)
                drive = self._drives.setdefault(tape_id, _Drive(tape_id))
                if (
                    self.admission == "preempt"
                    and drive.busy
                    and now < drive.service_end
                ):
                    self._preempt(drive, now)
                self._try_dispatch(drive, now)
            elif kind == "free":
                tape_id, epoch = data
                drive = self._drives[tape_id]
                if epoch != drive.epoch or not drive.busy:
                    continue  # superseded by a preemption
                self._complete(drive)
                self._try_dispatch(drive, now)
            elif kind == "wake":
                tape_id, when = data
                drive = self._drives[tape_id]
                if when != drive.next_wake:
                    continue  # superseded timer
                drive.next_wake = -1
                self._try_dispatch(drive, now)

        horizon = max([horizon] + [d.busy_until for d in self._drives.values()])
        report = ServiceReport(
            admission=self.admission,
            policy=self.policy,
            backend=self.backend,
            window=self.window,
            served=sorted(self._served, key=lambda r: (r.completed, r.req_id)),
            batches=self._batches,
            n_preemptions=self._n_preempt,
            horizon=horizon,
            cache_stats=self.cache.stats() if self.cache is not None else None,
        )
        return report

    # -- admission -----------------------------------------------------------
    def _try_dispatch(self, drive: _Drive, now: int) -> None:
        queue = self.lib.pending(drive.tape_id)
        if drive.busy or len(queue) == 0:
            return
        if self.admission == "fifo":
            batch = [queue.pop()]
        elif self.admission == "accumulate":
            ready = queue.peek().time + self.window
            if now < ready:
                if drive.next_wake != ready:
                    drive.next_wake = ready
                    self._push(ready, "wake", (drive.tape_id, ready))
                return
            batch = queue.drain()
        else:  # preempt: greedy batching, preemption handled on arrival
            batch = queue.drain()
        self._dispatch(drive, batch, now)

    # -- drive actions -------------------------------------------------------
    def _dispatch(self, drive: _Drive, batch: list[Request], now: int) -> None:
        tape = self.lib.tape_of(batch[0].name)
        multiset: dict[str, int] = {}
        for req in batch:
            multiset[req.name] = multiset.get(req.name, 0) + 1
        inst, names = tape.instance(multiset)
        res = solve(inst, policy=self.policy, backend=self.backend, cache=self.cache)
        replay: Replay = replay_schedule(inst, res.detours)
        # the independent recomputation always lands in the BatchRecord; with
        # verify=True a disagreement (or structural defect) raises right here
        verified = replay.cost == res.cost
        if self.verify:
            verify_schedule(inst, res.detours, cost=res.cost, replay=replay)
        idx = {name: i for i, name in enumerate(names)}
        rewind = rewind_time(inst.m, inst.u_turn, replay.head_at_makespan)

        drive.busy = True
        drive.epoch += 1
        drive.dispatched = now
        drive.service_end = now + replay.makespan
        drive.busy_until = drive.service_end + rewind
        drive.legs = replay.legs
        drive.load_point = inst.m
        drive.u_turn = inst.u_turn
        drive.inflight = [
            (req, now + replay.service_time[idx[req.name]]) for req in batch
        ]
        drive.batch_idx = len(self._batches)
        self._batches.append(
            BatchRecord(
                tape_id=drive.tape_id,
                dispatched=now,
                n_requests=len(batch),
                n_files=inst.n_req,
                solver_cost=res.cost,
                replay_cost=replay.cost,
                makespan=replay.makespan,
                rewind=rewind,
                verified=verified,
            )
        )
        self._push(drive.busy_until, "free", (drive.tape_id, drive.epoch))

    def _complete(self, drive: _Drive) -> None:
        for req, completed in drive.inflight:
            self._served.append(
                ServedRequest(
                    req_id=req.req_id,
                    name=req.name,
                    tape_id=req.tape_id,
                    arrival=req.time,
                    dispatched=drive.dispatched,
                    completed=completed,
                )
            )
        drive.inflight = []
        drive.busy = False

    def _preempt(self, drive: _Drive, now: int) -> None:
        """Abort the in-flight batch at ``now``; requeue unserved requests.

        Completions at or before ``now`` stand; the head rewinds from its
        current position (one U-turn + seek to the load point) before the
        next dispatch.  The drive stays busy for the rewind.
        """
        done = [(r, c) for r, c in drive.inflight if c <= now]
        pending = [r for r, c in drive.inflight if c > now]
        for req, completed in done:
            self._served.append(
                ServedRequest(
                    req_id=req.req_id,
                    name=req.name,
                    tape_id=req.tape_id,
                    arrival=req.time,
                    dispatched=drive.dispatched,
                    completed=completed,
                )
            )
        for req in pending:
            self.lib.enqueue(req.name, req)
        pos = head_position(drive.legs, now - drive.dispatched)
        rewind = rewind_time(drive.load_point, drive.u_turn, pos)
        aborted = self._batches[drive.batch_idx]
        assert aborted.tape_id == drive.tape_id
        assert aborted.dispatched == drive.dispatched
        self._batches[drive.batch_idx] = dataclasses.replace(
            aborted, preempted=True, n_completed=len(done)
        )
        drive.epoch += 1  # invalidate the scheduled drive-free event
        drive.inflight = []
        drive.legs = ()
        drive.service_end = now
        drive.busy_until = now + rewind
        drive.busy = True
        self._n_preempt += 1
        self._push(drive.busy_until, "free", (drive.tape_id, drive.epoch))


def serve_trace(
    library: TapeLibrary,
    trace: list[Request],
    admission: str = "accumulate",
    *,
    window: int = 0,
    policy: str = "dp",
    backend: str = DEFAULT_BACKEND,
    cache: SolveCache | None = None,
    verify: bool = True,
) -> ServiceReport:
    """One-shot convenience: build an :class:`OnlineTapeServer` and run it."""
    server = OnlineTapeServer(
        library,
        admission,
        window=window,
        policy=policy,
        backend=backend,
        cache=cache,
        verify=verify,
    )
    return server.run(trace)

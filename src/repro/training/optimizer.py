"""AdamW with global-norm clipping and warmup+cosine schedule (pure JAX).

Optimizer state mirrors the parameter pytree, so it inherits the parameter
PartitionSpecs (fully sharded optimizer state — ZeRO-style — comes for free
from the tensor-parallel parameter sharding; the "data" axis replicates it,
which is the standard TPU-pod layout)."""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptConfig:
    learning_rate: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0


def lr_at(cfg: OptConfig, step: jax.Array) -> jax.Array:
    """Linear warmup then cosine decay to 10% of peak."""
    step = step.astype(jnp.float32)
    warm = step / max(1, cfg.warmup_steps)
    prog = jnp.clip(
        (step - cfg.warmup_steps) / max(1, cfg.total_steps - cfg.warmup_steps), 0, 1
    )
    cos = 0.1 + 0.45 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.learning_rate * jnp.minimum(warm, cos)


def adamw_init(params) -> dict[str, Any]:
    zeros = lambda t: jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), t)
    return {"m": zeros(params), "v": zeros(params), "step": jnp.zeros((), jnp.int32)}


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(l.astype(jnp.float32) ** 2) for l in leaves))


def adamw_update(cfg: OptConfig, grads, state, params):
    """One AdamW step -> (new_params, new_state, metrics)."""
    step = state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-9))
    lr = lr_at(cfg, step)
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m1 = b1 * m + (1 - b1) * g
        v1 = b2 * v + (1 - b2) * g * g
        mh = m1 / bc1
        vh = v1 / bc2
        delta = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m1, v1

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state["m"])
    flat_v = jax.tree.leaves(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree.unflatten(treedef, [o[2] for o in out])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_p, {"m": new_m, "v": new_v, "step": step}, metrics

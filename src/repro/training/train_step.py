"""Train-step factory: loss, grad accumulation (microbatching), optimizer.

``make_train_step(cfg, opt_cfg, microbatches=k)`` returns a pure function
``(params, opt_state, batch) -> (params, opt_state, metrics)`` suitable for
``jax.jit`` with explicit in/out shardings.  Gradient accumulation runs as a
``lax.scan`` over k micro-slices of the global batch — the standard memory/
throughput trade-off knob on HBM-bound trainers."""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from ..models.common import ModelConfig, softmax_cross_entropy
from ..models.model import forward
from .optimizer import OptConfig, adamw_init, adamw_update

AUX_LOSS_WEIGHT = 0.01


def loss_fn(params, cfg: ModelConfig, batch) -> tuple[jax.Array, dict[str, Any]]:
    memory = None
    if cfg.enc_layers:  # enc-dec: encoder runs inside the loss (end-to-end)
        from ..models.model import encode

        memory = encode(params, cfg, batch["enc_embeds"])
    elif cfg.num_vision_tokens:  # VLM: stub frontend supplies patch embeddings
        memory = batch["vision_embeds"]
    logits, aux = forward(params, cfg, batch["tokens"], memory=memory)
    loss = softmax_cross_entropy(
        logits[:, :-1], batch["tokens"][:, 1:], sharded_vocab=cfg.logits_bf16_ce
    )
    total = loss + AUX_LOSS_WEIGHT * aux
    return total, {"loss": loss, "aux_loss": aux}


def make_train_step(cfg: ModelConfig, opt_cfg: OptConfig, microbatches: int | None = None):
    """Build the jittable train step (optionally gradient-accumulated)."""

    microbatches = microbatches if microbatches is not None else cfg.microbatches
    grad_fn = jax.value_and_grad(
        lambda p, b: loss_fn(p, cfg, b), has_aux=True
    )

    def accumulate(params, batch):
        if microbatches == 1:
            (_, metrics), grads = grad_fn(params, batch)
            return grads, metrics

        def micro(b, i):
            # split the batch axis with batch OUTERMOST so the data-parallel
            # sharding of dim 0 survives the reshape (innermost-split would
            # make GSPMD replicate every microbatch across the data axis)
            return jax.tree.map(
                lambda x: x.reshape(-1, microbatches, *x.shape[1:])[:, i]
                if x.ndim >= 1
                else x,
                b,
            )

        def body(carry, i):
            acc, _ = carry
            (_, metrics), grads = grad_fn(params, micro(batch, i))
            acc = jax.tree.map(jnp.add, acc, grads)
            return (acc, metrics), None

        zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        init = (zeros, {"loss": jnp.zeros(()), "aux_loss": jnp.zeros(())})
        if cfg.scan_layers:
            (grads, metrics), _ = jax.lax.scan(body, init, jnp.arange(microbatches))
        else:  # unrolled (dry-run cost accounting: a scan body is costed once)
            carry = init
            for i in range(microbatches):
                carry, _ = body(carry, i)
            grads, metrics = carry
        grads = jax.tree.map(lambda g: g / microbatches, grads)
        return grads, metrics

    def train_step(params, opt_state, batch):
        grads, metrics = accumulate(params, batch)
        params, opt_state, opt_metrics = adamw_update(opt_cfg, grads, opt_state, params)
        return params, opt_state, {**metrics, **opt_metrics}

    return train_step


def init_train_state(key, cfg: ModelConfig):
    from ..models.model import init_model

    params = init_model(key, cfg)
    return params, adamw_init(params)

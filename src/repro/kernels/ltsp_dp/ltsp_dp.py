"""Pallas TPU kernel for one anti-diagonal of the LTSP DP wavefront.

TPU adaptation of the paper's CPU dynamic program (DESIGN.md §Hardware
adaptation): the O(n_req) inner minimisation of ``detour_c`` is the compute
hot-spot (O(n_req^3 · n) total).  On TPU we turn the per-cell scalar loop into
a dense ``[d, S]`` candidate tile in VMEM reduced with ``min`` on the VPU —
the ``s`` axis (skip count) is the 128-lane vector axis, the ``c`` candidate
axis is the sublane axis.  One kernel launch computes one anti-diagonal
``d = b - a`` for every window start ``a`` (grid axis) so successive
diagonals — which carry the loop dependency — are separate launches while all
work inside a diagonal is embarrassingly parallel.

Layout notes
------------
* ``T`` is the dense ``[R, R, S]`` table in HBM.  Each program DMAs one row
  block ``T[a, :, :]`` and one column block ``T[:, b, :]`` into VMEM
  (``2 * R * S * 4`` bytes; R ~ a few hundred requested files and S ~ a few
  thousand skip counts fit comfortably in 16 MB VMEM for real tape workloads).
* ``S`` should be padded to a multiple of 128 (lane width).
* The ``skip`` term needs the shifted gather ``row[s + x_b]``; ``x_b`` is a
  scalar per program, so it is a single dynamic-slice + clamp, not a general
  gather.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["diagonal_kernel", "ltsp_dp_diagonal"]


def diagonal_kernel(
    # inputs
    trow_ref,  # [1, R, S] — row a of T
    tcol_ref,  # [R, 1, S] — column b = a + d of T
    left_ref,  # [R] f32
    right_ref,  # [R] f32
    x_ref,  # [R] int32
    nl_ref,  # [R] f32
    # output
    out_ref,  # [1, S] — new T[a, a+d, :]
    *,
    d: int,
    u_turn: float,
    S: int,
):
    a = pl.program_id(0)
    b = a + d

    svec = jax.lax.broadcasted_iota(jnp.float32, (1, S), 1)  # [1, S]
    nl_a = pl.load(nl_ref, (pl.dslice(a, 1),))[0]

    # ---------------- skip(a, b, s) ----------------------------------------
    row_bm1 = pl.load(trow_ref, (0, pl.dslice(b - 1, 1), slice(None)))  # [1, S]
    x_b = pl.load(x_ref, (pl.dslice(b, 1),))[0]
    idx = jnp.clip(
        jax.lax.broadcasted_iota(jnp.int32, (1, S), 1) + x_b, 0, S - 1
    )
    shifted = jnp.take_along_axis(row_bm1, idx, axis=1)  # [1, S]
    r_b = pl.load(right_ref, (pl.dslice(b, 1),))[0]
    r_bm1 = pl.load(right_ref, (pl.dslice(b - 1, 1),))[0]
    l_b = pl.load(left_ref, (pl.dslice(b, 1),))[0]
    skip = (
        shifted
        + 2.0 * (r_b - r_bm1) * (svec + nl_a)
        + 2.0 * (l_b - r_bm1) * x_b.astype(jnp.float32)
    )

    # ---------------- min over detour_c, c = a+1 .. a+d --------------------
    # T[a, c-1, s]: row-a cols [a, a+d)   |   T[c, b, s]: col-b rows [a+1, a+d]
    t_left = pl.load(trow_ref, (0, pl.dslice(a, d), slice(None)))  # [d, S]
    t_right = pl.load(tcol_ref, (pl.dslice(a + 1, d), 0, slice(None)))  # [d, S]
    r_cm1 = pl.load(right_ref, (pl.dslice(a, d),))  # [d]
    nl_c = pl.load(nl_ref, (pl.dslice(a + 1, d),))  # [d]
    svec_d = jax.lax.broadcasted_iota(jnp.float32, (d, S), 1)
    cand = (
        t_left
        + t_right
        + 2.0 * (r_b - r_cm1)[:, None] * (svec_d + nl_a)
        + 2.0 * u_turn * (svec_d + nl_c[:, None])
    )
    det = jnp.min(cand, axis=0, keepdims=True)  # [1, S]

    out_ref[...] = jnp.minimum(skip, det)


@functools.partial(jax.jit, static_argnames=("d", "u_turn", "S", "interpret"))
def ltsp_dp_diagonal(
    T: jax.Array,  # [R, R, S] f32
    left: jax.Array,  # [R] f32
    right: jax.Array,  # [R] f32
    x: jax.Array,  # [R] int32
    nl: jax.Array,  # [R] f32
    *,
    d: int,
    u_turn: float,
    S: int,
    interpret: bool = True,
) -> jax.Array:
    """Compute anti-diagonal ``d`` → array ``[R - d, S]`` of new cell values."""
    R = T.shape[0]
    n_a = R - d
    kern = functools.partial(diagonal_kernel, d=d, u_turn=u_turn, S=S)
    return pl.pallas_call(
        kern,
        grid=(n_a,),
        in_specs=[
            pl.BlockSpec((1, R, S), lambda a: (a, 0, 0)),  # row a
            pl.BlockSpec((R, 1, S), lambda a: (0, a + d, 0)),  # column a+d
            pl.BlockSpec((R,), lambda a: (0,)),
            pl.BlockSpec((R,), lambda a: (0,)),
            pl.BlockSpec((R,), lambda a: (0,)),
            pl.BlockSpec((R,), lambda a: (0,)),
        ],
        out_specs=pl.BlockSpec((1, S), lambda a: (a, 0)),
        out_shape=jax.ShapeDtypeStruct((n_a, S), T.dtype),
        interpret=interpret,
    )(T, T, left, right, x, nl)

"""Pallas TPU kernel for the LTSP DP wavefront — single-trace, batched,
traceback-capable.

TPU adaptation of the paper's CPU dynamic program (DESIGN.md §Hardware
adaptation): the O(n_req) inner minimisation of ``detour_c`` is the compute
hot-spot (O(n_req^3 · n) total).  On TPU the per-cell scalar loop becomes a
dense ``[R-1, S]`` candidate tile in VMEM reduced with ``min``/``argmin`` on
the VPU — the ``s`` axis (skip count) is the 128-lane vector axis, the ``c``
candidate axis is the sublane axis.

Unlike the seed implementation (one Python-level ``pallas_call`` per
anti-diagonal, retraced R times with a full-table ``T.at[...]`` copy each), the
whole table is now built in **one trace**: :func:`ltsp_dp_tables` runs a jitted
``lax.fori_loop`` over the diagonal index ``d`` whose carry is the table
workspace ``(T, C)``; XLA double-buffers/donates the carry so each diagonal is
an in-place scatter, and the kernel receives ``d`` as a scalar (SMEM) operand,
masking the candidate range instead of re-specialising shapes per diagonal.

The kernel additionally emits a per-cell **argmin plane** ``C[a, b, s]``
(-1 = "skip b", else the winning detour start ``c``), matching the exact
Python DP's tie-breaking (skip wins ties; the smallest minimising ``c`` wins
among detours), so a host-side traceback (:mod:`.ops`) can reconstruct the
optimal detour list — the device path is a complete solver, not a value oracle.

Batching: the grid is ``(B, R)`` — several padded instances solve in one
launch.  Padded files (zero width, zero multiplicity, at the rightmost
coordinate) provably never win a detour choice, so padding changes neither the
root value nor the traceback.

Layout notes
------------
* ``T``/``C`` are dense ``[B, R, R, S]`` tables.  Each program reads row ``a``
  and column ``b = a + d`` of its instance's table (``2 * R * S * 4`` bytes of
  live values; R ~ a few hundred requested files and S ~ a few thousand skip
  counts fit in 16 MB VMEM for real tape workloads).  Compiled-TPU runs at
  scale still need a row/column BlockSpec DMA split so only those slices are
  resident — tracked in ROADMAP as an open item; interpret mode (CPU) is the
  validated path today.
* ``S`` should be padded to a multiple of 128 (lane width).
* ``dtype`` is ``float32`` (exact for values < 2**24, the oracle-comparison
  path) or ``int32`` (exact for values < 2**31, the solver path).
* The ``skip`` term needs the shifted gather ``row[s + x_b]``; ``x_b`` is a
  scalar per program, so it is a single dynamic-slice + clamp, not a general
  gather.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["wavefront_kernel", "ltsp_dp_wavefront", "ltsp_dp_tables"]


def wavefront_kernel(
    # scalar inputs
    d_ref,  # [1] int32 (SMEM) — current anti-diagonal
    u_ref,  # [1] dtype (SMEM) — U-turn penalty of this instance
    # tensor inputs
    t_ref,  # [1, R, R, S] — this instance's table, diagonals < d filled
    left_ref,  # [1, R] dtype
    right_ref,  # [1, R] dtype
    x_ref,  # [1, R] int32
    nl_ref,  # [1, R] dtype
    # outputs
    val_ref,  # [1, 1, S] — new T[a, a+d, :]
    cho_ref,  # [1, 1, S] int32 — argmin plane (-1 = skip, else c)
    *,
    S: int,
    span: int | None,
):
    a = pl.program_id(1)
    R = t_ref.shape[1]
    d = d_ref[0]
    # programs with a + d >= R are out of this diagonal: compute at a clamped
    # b (cheap, garbage) and let the host-side scatter drop the result.
    b = jnp.minimum(a + d, R - 1)
    dtype = t_ref.dtype
    big = jnp.asarray(
        jnp.iinfo(jnp.int32).max // 2 if dtype == jnp.int32 else jnp.inf, dtype
    )
    two = jnp.asarray(2, dtype)

    u = u_ref[0]
    lefts = left_ref[0]  # [R]
    rights = right_ref[0]  # [R]
    xs = x_ref[0]  # [R]
    nls = nl_ref[0]  # [R]
    tbl = t_ref[0]  # [R, R, S]

    def at(vec, i):
        return jax.lax.dynamic_index_in_dim(vec, i, keepdims=False)

    nl_a = at(nls, a)
    svec = jax.lax.broadcasted_iota(dtype, (1, S), 1)

    row = jax.lax.dynamic_index_in_dim(tbl, a, 0, keepdims=False)  # [R, S]
    col = jax.lax.dynamic_index_in_dim(tbl, b, 1, keepdims=False)  # [R, S]

    # ---------------- skip(a, b, s) ----------------------------------------
    row_bm1 = jax.lax.dynamic_slice(row, (b - 1, 0), (1, S))  # [1, S]
    x_b = at(xs, b)
    idx = jnp.clip(jax.lax.broadcasted_iota(jnp.int32, (1, S), 1) + x_b, 0, S - 1)
    shifted = jnp.take_along_axis(row_bm1, idx, axis=1)  # T[a, b-1, s + x_b]
    r_b = at(rights, b)
    r_bm1 = at(rights, b - 1)
    l_b = at(lefts, b)
    skip = (
        shifted
        + two * (r_b - r_bm1) * (svec + nl_a)
        + two * (l_b - r_bm1) * x_b.astype(dtype)
    )

    # ---------------- min over detour_c, masked to a < c <= b --------------
    # Candidates are materialised for every c in 1..R-1 (static shape) and
    # invalid ones masked to +inf; T rows outside the wavefront are zeros, so
    # masked candidates stay finite/representable before the mask applies.
    t_left = row[: R - 1, :]  # T[a, c-1, s] for c = 1..R-1
    t_right = col[1:, :]  # T[c, b, s]
    r_cm1 = rights[: R - 1]  # r(c-1)
    nl_c = nls[1:]
    svec_d = jax.lax.broadcasted_iota(dtype, (R - 1, S), 1)
    cand = (
        t_left
        + t_right
        + two * (r_b - r_cm1)[:, None] * (svec_d + nl_a)
        + two * u * (svec_d + nl_c[:, None])
    )
    cvec = jax.lax.broadcasted_iota(jnp.int32, (R - 1, S), 0) + 1
    mask = (cvec > a) & (cvec <= b)
    if span is not None:  # LOGDP restriction: b - c <= span
        mask = mask & (b - cvec <= span)
    cand = jnp.where(mask, cand, big)
    det = jnp.min(cand, axis=0, keepdims=True)  # [1, S]
    # argmin returns the FIRST minimising index == the smallest c, matching
    # the exact DP's ascending-c strict-improvement scan.
    argc = jnp.argmin(cand, axis=0).astype(jnp.int32)[None, :] + 1

    val_ref[0] = jnp.minimum(skip, det)
    cho_ref[0] = jnp.where(skip <= det, jnp.int32(-1), argc)


def ltsp_dp_wavefront(
    T: jax.Array,  # [B, R, R, S]
    left: jax.Array,  # [B, R]
    right: jax.Array,  # [B, R]
    x: jax.Array,  # [B, R] int32
    nl: jax.Array,  # [B, R]
    u: jax.Array,  # [B]
    d: jax.Array,  # scalar int32 (traced — same kernel serves every diagonal)
    *,
    S: int,
    span: int | None,
    interpret: bool = True,
) -> tuple[jax.Array, jax.Array]:
    """One anti-diagonal for every instance: ``([B, R, S], [B, R, S])``."""
    B, R = left.shape
    kern = functools.partial(wavefront_kernel, S=S, span=span)
    return pl.pallas_call(
        kern,
        grid=(B, R),
        in_specs=[
            pl.BlockSpec((1,), lambda i, a: (0,), memory_space=pltpu.SMEM),
            pl.BlockSpec((1,), lambda i, a: (i,), memory_space=pltpu.SMEM),
            pl.BlockSpec((1, R, R, S), lambda i, a: (i, 0, 0, 0)),
            pl.BlockSpec((1, R), lambda i, a: (i, 0)),
            pl.BlockSpec((1, R), lambda i, a: (i, 0)),
            pl.BlockSpec((1, R), lambda i, a: (i, 0)),
            pl.BlockSpec((1, R), lambda i, a: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, S), lambda i, a: (i, a, 0)),
            pl.BlockSpec((1, 1, S), lambda i, a: (i, a, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, R, S), T.dtype),
            jax.ShapeDtypeStruct((B, R, S), jnp.int32),
        ],
        interpret=interpret,
    )(jnp.asarray([d], jnp.int32).reshape(1), u, T, left, right, x, nl)


@functools.partial(jax.jit, static_argnames=("S", "span", "interpret"))
def ltsp_dp_tables(
    left: jax.Array,  # [B, R]
    right: jax.Array,  # [B, R]
    x: jax.Array,  # [B, R] int32
    nl: jax.Array,  # [B, R]
    u: jax.Array,  # [B]
    *,
    S: int,
    span: int | None = None,
    interpret: bool = True,
) -> tuple[jax.Array, jax.Array]:
    """Full batched DP tables ``(T, C)`` in a single jitted wavefront.

    ``T[i, a, b, s]`` is the DP value table of instance ``i`` and
    ``C[i, a, b, s]`` the argmin plane (-1 = skip, else detour start ``c``)
    that the host traceback consumes.  One ``lax.fori_loop`` over the diagonal
    index carries the ``(T, C)`` workspace; each iteration is one Pallas
    launch over the ``(instance, window-start)`` grid plus an in-place
    diagonal scatter (``mode="drop"`` discards the clamped windows past the
    diagonal's end).
    """
    B, R = left.shape
    dtype = left.dtype
    rr = jnp.arange(R)
    # base diagonal T[b, b, s] = 2 s(b) (s + n_l(b)), batched (same op order
    # as ref.base_diagonal so the f32 path stays bit-identical to the oracle)
    svec = jnp.arange(S, dtype=dtype)
    base = 2 * (right - left)[:, :, None] * (svec[None, None, :] + nl[:, :, None])
    T = jnp.zeros((B, R, R, S), dtype)
    T = T.at[:, rr, rr, :].set(base)
    C = jnp.full((B, R, R, S), -1, jnp.int32)
    if R == 1:
        return T, C

    def body(d, carry):
        T, C = carry
        vals, chos = ltsp_dp_wavefront(
            T, left, right, x, nl, u, d, S=S, span=span, interpret=interpret
        )
        T = T.at[:, rr, rr + d, :].set(vals, mode="drop")
        C = C.at[:, rr, rr + d, :].set(chos, mode="drop")
        return T, C

    return jax.lax.fori_loop(1, R, body, (T, C))

"""Pallas TPU kernel for the LTSP DP wavefront — single-trace, batched,
traceback-capable, with a banded candidate scan and per-program DMA slices.

TPU adaptation of the paper's CPU dynamic program (DESIGN.md §Hardware
adaptation): the O(n_req) inner minimisation of ``detour_c`` is the compute
hot-spot (O(n_req^3 · n) total).  On TPU the per-cell scalar loop becomes a
dense candidate tile in VMEM reduced with ``min``/``argmin`` on the VPU — the
``s`` axis (skip count) is the 128-lane vector axis, the ``c`` candidate axis
is the sublane axis.

Unlike the seed implementation (one Python-level ``pallas_call`` per
anti-diagonal, retraced R times with a full-table ``T.at[...]`` copy each), the
whole table is built in **one trace**: :func:`ltsp_dp_tables` runs a jitted
``lax.fori_loop`` over the diagonal index ``d`` whose carry is the table
workspace ``(T, C)``; XLA double-buffers/donates the carry so each diagonal is
an in-place scatter, and the kernel receives ``d`` as a scalar-prefetch
operand, so the same compiled kernel serves every diagonal.

Banded candidate scan
---------------------
A cell ``(a, b)`` on diagonal ``d = b - a`` has exactly ``d`` detour
candidates ``c in (a, b]`` (fewer under a LOGDP span restriction; none on
non-root cells under the SIMPLEDP ``disjoint=True`` restriction, which clips
the candidate band to ``a == 0`` cells — forbidding detours inside detours
collapses the table to SIMPLEDP's 2-D recursion exactly).  The seed
kernel materialised the full ``[R-1, S]`` candidate tile for every cell and
masked the dead rows — about 2x redundant VPU work over the whole table
(``sum_d d`` live rows vs ``sum_d (R-1)`` computed ones).  The kernel now
walks the live band in static ``cand_tile``-row chunks: a ``fori_loop`` over
``ceil(n_live / cand_tile)`` chunks dynamic-slices only the candidate rows it
needs and folds them into a running ``(min, argmin)`` carry.  Chunks ascend in
``c`` and the fold improves strictly, so the argmin is still the *smallest*
minimising ``c`` — identical tie-breaking to the exact Python DP (skip wins
ties against detours; among detours the smallest ``c`` wins).  When
``R - 1 <= cand_tile`` the band never spans more than one chunk and the
kernel statically falls back to the single masked tile (same arithmetic, no
loop overhead) — so small instances compile to exactly the pre-banding code.

Per-program DMA slices
----------------------
A program computing ``T[i, a, b, :]`` reads only row ``a`` and column ``b`` of
its instance's table.  The grid spec is a :class:`pltpu.PrefetchScalarGridSpec`
with ``d`` as the scalar-prefetch operand, so the BlockSpec index maps can
resolve ``b = a + d`` *before* the body runs and DMA just the
``[1, 1, R, S]`` row slice and ``[1, R, 1, S]`` column slice into VMEM —
``2 * R * S * 4`` bytes per program instead of the whole ``[R, R, S]``
instance table (``R`` times that).  This is what lets compiled-TPU runs at
IN2P3 scale (R ~ several hundred, S ~ a few thousand) fit the 16 MB VMEM
budget.

``dimension_semantics`` audit of the ``(B, R)`` grid: the batch dimension
indexes independent instances and the window-start dimension indexes cells of
*one* anti-diagonal, which only read diagonals ``< d`` (frozen in this launch)
and write disjoint output blocks — no program on the grid observes another's
write, so both dimensions are declared ``"parallel"`` (Mosaic may split them
across TensorCores).  Compiled mode only; the interpreter ignores scheduling
hints.

The kernel additionally emits a per-cell **argmin plane** ``C[a, b, s]``
(-1 = "skip b", else the winning detour start ``c``) so a host-side traceback
(:mod:`.ops`) can reconstruct the optimal detour list — the device path is a
complete solver, not a value oracle.

Batching: the grid is ``(B, R)`` — several padded instances solve in one
launch.  Padded files (zero width, zero multiplicity, at the rightmost
coordinate) provably never win a detour choice, so padding changes neither the
root value nor the traceback; all-phantom padding *rows* (batch-dimension
padding, see ``ops.prepare_batch``) are simply never traced back.

Layout notes
------------
* ``S`` should be padded to a multiple of 128 (lane width).
* ``cand_tile`` is the candidate-chunk height (sublane axis); 128 by default
  so instances up to R = 129 take the single-tile fallback, while large
  instances stream the band in 128-row tiles.
* ``dtype`` is ``float32`` (exact for values < 2**24, the oracle-comparison
  path), ``int32`` (exact for values < 2**31, the solver path), or
  ``float64`` (exact for values < 2**53 — the interpret-mode numeric
  fallback in :mod:`.ops` for instances whose coprime byte-scale coordinates
  fail the int32 guard even after gcd/shift rescaling).
* The ``skip`` term needs the shifted gather ``row[s + x_b]``; ``x_b`` is a
  scalar per program, so it is a single dynamic-slice + clamp, not a general
  gather.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["wavefront_kernel", "ltsp_dp_wavefront", "ltsp_dp_tables"]

#: default candidate-chunk height (sublane rows per banded-scan step).
DEFAULT_CAND_TILE = 128


def wavefront_kernel(
    # scalar-prefetch inputs
    d_ref,  # [1] int32 (SMEM) — current anti-diagonal
    # tensor inputs
    u_ref,  # [1] dtype (SMEM) — U-turn penalty of this instance
    row_ref,  # [1, 1, R, S] — T[i, a, :, :] (row slice of this instance)
    col_ref,  # [1, R, 1, S] — T[i, :, b, :] (column slice, b resolved by the
    #           index map from the prefetched d)
    left_ref,  # [1, R] dtype
    right_ref,  # [1, R] dtype
    x_ref,  # [1, R] int32
    nl_ref,  # [1, R] dtype
    # outputs
    val_ref,  # [1, 1, S] — new T[a, a+d, :]
    cho_ref,  # [1, 1, S] int32 — argmin plane (-1 = skip, else c)
    *,
    S: int,
    span: int | None,
    disjoint: bool,
    cand_tile: int,
):
    a = pl.program_id(1)
    R = row_ref.shape[2]
    d = d_ref[0]
    # programs with a + d >= R are out of this diagonal: compute at a clamped
    # b (cheap, garbage) and let the host-side scatter drop the result.
    b = jnp.minimum(a + d, R - 1)
    dtype = row_ref.dtype
    big = jnp.asarray(
        jnp.iinfo(jnp.int32).max // 2 if dtype == jnp.int32 else jnp.inf, dtype
    )
    two = jnp.asarray(2, dtype)

    u = u_ref[0]
    lefts = left_ref[0]  # [R]
    rights = right_ref[0]  # [R]
    xs = x_ref[0]  # [R]
    nls = nl_ref[0]  # [R]

    def at(vec, i):
        return jax.lax.dynamic_index_in_dim(vec, i, keepdims=False)

    nl_a = at(nls, a)
    svec = jax.lax.broadcasted_iota(dtype, (1, S), 1)

    row = row_ref[0, 0]  # [R, S]  — T[a, :, :]
    col = col_ref[0, :, 0, :]  # [R, S]  — T[:, b, :]

    # ---------------- skip(a, b, s) ----------------------------------------
    # index literals pinned to int32: under the scoped x64 context of the f64
    # fallback a bare 0 would arrive as int64 and dynamic_slice rejects
    # mixed-dtype indices
    z = jnp.int32(0)
    row_bm1 = jax.lax.dynamic_slice(row, (b - 1, z), (1, S))  # [1, S]
    x_b = at(xs, b)
    idx = jnp.clip(jax.lax.broadcasted_iota(jnp.int32, (1, S), 1) + x_b, 0, S - 1)
    shifted = jnp.take_along_axis(row_bm1, idx, axis=1)  # T[a, b-1, s + x_b]
    r_b = at(rights, b)
    r_bm1 = at(rights, b - 1)
    l_b = at(lefts, b)
    skip = (
        shifted
        + two * (r_b - r_bm1) * (svec + nl_a)
        + two * (l_b - r_bm1) * x_b.astype(dtype)
    )

    # ---------------- min over detour_c, banded to a < c <= b --------------
    # Live candidates: c in (a, b], further clipped to c >= b - span under a
    # LOGDP restriction, and to the empty band on non-root cells under the
    # SIMPLEDP restriction (disjoint detours = no detour may start inside
    # another, i.e. cells with a > 0 may only skip; the 3-D table then
    # collapses to SIMPLEDP's 2-D recursion exactly, traceback included).
    # T rows outside the wavefront are zeros, so computed candidates stay
    # finite/representable before the mask applies.
    c_min = a + 1
    if span is not None:  # LOGDP restriction: b - c <= span
        c_min = jnp.maximum(c_min, b - span)
    if disjoint:  # SIMPLEDP restriction: detours only at the root level
        c_min = jnp.where(a > 0, b + 1, c_min)

    def chunk_vals(c0, n_rows: int):
        """Candidates ``c = c0 + j`` for ``j in [0, n_rows)`` (+mask tail)."""
        c0 = jnp.asarray(c0, jnp.int32)  # fori_loop index may be int64 (x64)
        t_left = jax.lax.dynamic_slice(row, (c0 - 1, z), (n_rows, S))  # T[a,c-1,s]
        t_right = jax.lax.dynamic_slice(col, (c0, z), (n_rows, S))  # T[c,b,s]
        r_cm1 = jax.lax.dynamic_slice(rights, (c0 - 1,), (n_rows,))
        nl_c = jax.lax.dynamic_slice(nls, (c0,), (n_rows,))
        svec_d = jax.lax.broadcasted_iota(dtype, (n_rows, S), 1)
        cand = (
            t_left
            + t_right
            + two * (r_b - r_cm1)[:, None] * (svec_d + nl_a)
            + two * u * (svec_d + nl_c[:, None])
        )
        cvec = jax.lax.broadcasted_iota(jnp.int32, (n_rows, 1), 0) + c0
        cand = jnp.where((cvec >= c_min) & (cvec <= b), cand, big)
        return cand

    if R - 1 <= cand_tile:
        # static fallback: the whole candidate range c in 1..R-1 is one tile.
        cand = chunk_vals(jnp.int32(1), R - 1)
        det = jnp.min(cand, axis=0, keepdims=True)  # [1, S]
        # argmin returns the FIRST minimising index == the smallest c,
        # matching the exact DP's ascending-c strict-improvement scan.
        argc = jnp.argmin(cand, axis=0).astype(jnp.int32)[None, :] + 1
    else:
        # banded scan: fori_loop over cand_tile-row chunks of the live band,
        # folding a running (min, argmin).  Chunks ascend in c and the fold
        # improves strictly, so ties keep the smallest c (same tie-breaking
        # as the static tile's first-min argmin).
        n_live = b - c_min + 1  # may be <= 0 on clamped programs: 0 chunks
        n_chunks = jnp.maximum((n_live + cand_tile - 1) // cand_tile, 0)

        def body(k, carry):
            run_min, run_arg = carry
            # chunk base, clamped so the slice stays in bounds; the overlap a
            # clamp introduces re-evaluates identical candidates, which the
            # strict fold ignores.  c0 >= 1 because cand_tile <= R - 1 here.
            c0 = jnp.clip(c_min + k * cand_tile, 1, R - cand_tile)
            cand = chunk_vals(c0, cand_tile)
            cmin = jnp.min(cand, axis=0, keepdims=True)  # [1, S]
            carg = jnp.argmin(cand, axis=0).astype(jnp.int32)[None, :] + c0
            improve = cmin < run_min
            return jnp.minimum(run_min, cmin), jnp.where(improve, carg, run_arg)

        det, argc = jax.lax.fori_loop(
            0,
            n_chunks,
            body,
            (jnp.full((1, S), big, dtype), jnp.zeros((1, S), jnp.int32)),
        )

    val_ref[0] = jnp.minimum(skip, det)
    cho_ref[0] = jnp.where(skip <= det, jnp.int32(-1), argc)


def ltsp_dp_wavefront(
    T: jax.Array,  # [B, R, R, S]
    left: jax.Array,  # [B, R]
    right: jax.Array,  # [B, R]
    x: jax.Array,  # [B, R] int32
    nl: jax.Array,  # [B, R]
    u: jax.Array,  # [B]
    d: jax.Array,  # scalar int32 (traced — same kernel serves every diagonal)
    *,
    S: int,
    span: int | None,
    disjoint: bool = False,
    interpret: bool = True,
    cand_tile: int = DEFAULT_CAND_TILE,
) -> tuple[jax.Array, jax.Array]:
    """One anti-diagonal for every instance: ``([B, R, S], [B, R, S])``.

    ``d`` rides as a scalar-prefetch operand so the column BlockSpec can DMA
    exactly the ``T[i, :, a + d, :]`` slice each program reads; the table is
    passed twice (row view + column view) and never mapped whole into VMEM.
    """
    B, R = left.shape
    kern = functools.partial(
        wavefront_kernel, S=S, span=span, disjoint=disjoint, cand_tile=cand_tile
    )
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,  # d — consumed by the column index map below
        grid=(B, R),
        in_specs=[
            pl.BlockSpec((1,), lambda i, a, d: (i,), memory_space=pltpu.SMEM),
            # row slice T[i, a, :, :]
            pl.BlockSpec((1, 1, R, S), lambda i, a, d: (i, a, 0, 0)),
            # column slice T[i, :, b, :] with b = min(a + d, R - 1)
            pl.BlockSpec(
                (1, R, 1, S),
                lambda i, a, d: (i, 0, jnp.minimum(a + d[0], R - 1), 0),
            ),
            pl.BlockSpec((1, R), lambda i, a, d: (i, 0)),
            pl.BlockSpec((1, R), lambda i, a, d: (i, 0)),
            pl.BlockSpec((1, R), lambda i, a, d: (i, 0)),
            pl.BlockSpec((1, R), lambda i, a, d: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, S), lambda i, a, d: (i, a, 0)),
            pl.BlockSpec((1, 1, S), lambda i, a, d: (i, a, 0)),
        ],
    )
    kwargs = {}
    if not interpret:
        # dimension_semantics audit (see module docstring): both grid dims are
        # data-parallel within one diagonal launch — disjoint writes, reads
        # only of diagonals < d.
        kwargs["compiler_params"] = pltpu.TPUCompilerParams(
            dimension_semantics=("parallel", "parallel")
        )
    return pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((B, R, S), T.dtype),
            jax.ShapeDtypeStruct((B, R, S), jnp.int32),
        ],
        interpret=interpret,
        **kwargs,
    )(jnp.asarray([d], jnp.int32).reshape(1), u, T, T, left, right, x, nl)


@functools.partial(
    jax.jit, static_argnames=("S", "span", "disjoint", "interpret", "cand_tile")
)
def ltsp_dp_tables(
    left: jax.Array,  # [B, R]
    right: jax.Array,  # [B, R]
    x: jax.Array,  # [B, R] int32
    nl: jax.Array,  # [B, R]
    u: jax.Array,  # [B]
    *,
    S: int,
    span: int | None = None,
    disjoint: bool = False,
    interpret: bool = True,
    cand_tile: int = DEFAULT_CAND_TILE,
) -> tuple[jax.Array, jax.Array]:
    """Full batched DP tables ``(T, C)`` in a single jitted wavefront.

    ``T[i, a, b, s]`` is the DP value table of instance ``i`` and
    ``C[i, a, b, s]`` the argmin plane (-1 = skip, else detour start ``c``)
    that the host traceback consumes.  One ``lax.fori_loop`` over the diagonal
    index carries the ``(T, C)`` workspace; each iteration is one Pallas
    launch over the ``(instance, window-start)`` grid plus an in-place
    diagonal scatter (``mode="drop"`` discards the clamped windows past the
    diagonal's end).
    """
    B, R = left.shape
    dtype = left.dtype
    rr = jnp.arange(R)
    # base diagonal T[b, b, s] = 2 s(b) (s + n_l(b)), batched (same op order
    # as ref.base_diagonal so the f32 path stays bit-identical to the oracle)
    svec = jnp.arange(S, dtype=dtype)
    base = 2 * (right - left)[:, :, None] * (svec[None, None, :] + nl[:, :, None])
    T = jnp.zeros((B, R, R, S), dtype)
    T = T.at[:, rr, rr, :].set(base)
    C = jnp.full((B, R, R, S), -1, jnp.int32)
    if R == 1:
        return T, C

    def body(d, carry):
        T, C = carry
        vals, chos = ltsp_dp_wavefront(
            T, left, right, x, nl, u, d,
            S=S, span=span, disjoint=disjoint, interpret=interpret,
            cand_tile=cand_tile,
        )
        T = T.at[:, rr, rr + d, :].set(vals, mode="drop")
        C = C.at[:, rr, rr + d, :].set(chos, mode="drop")
        return T, C

    return jax.lax.fori_loop(1, R, body, (T, C))

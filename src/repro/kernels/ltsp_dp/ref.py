"""Pure-jnp oracle for the wavefront LTSP DP (float, bottom-up).

The exact Python DP (:mod:`repro.core.dp`) memoises only reachable
``(a, b, n_skip)`` cells; the device formulation instead materialises the full
table ``T[R, R, S]`` over every skip count ``s in [0, S)`` and fills it one
anti-diagonal ``d = b - a`` at a time.  Every recurrence is valid for an
arbitrary ``s`` parameter, so the dense table contains no garbage: the only
approximation is the clamped gather ``T[a, b-1, min(s + x_b, S-1)]``, which
can only be hit from cells that are themselves unreachable from the root
``(0, R-1, 0)`` (a reachable chain keeps ``s + sum(x) <= n < S``).

This file is the correctness oracle for the Pallas kernel; it mirrors its
clamping semantics exactly.  With integer-valued inputs below 2**20 the f32
arithmetic here is exact, so the oracle can additionally be compared 1:1
against the exact integer DP.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["ltsp_dp_table_ref", "ltsp_opt_ref", "base_diagonal"]


def base_diagonal(right, left, nl, S: int, dtype=jnp.float32):
    """``T[b, b, s] = 2 s(b) (s + n_l(b))`` for all b, s."""
    size = (right - left).astype(dtype)  # [R]
    svec = jnp.arange(S, dtype=dtype)  # [S]
    return 2.0 * size[:, None] * (svec[None, :] + nl[:, None].astype(dtype))


def _diagonal_update(T, d: int, left, right, x, nl, u_turn, S: int):
    """Compute T[a, a+d, :] for every a via the skip/detour recurrence."""
    R = T.shape[0]
    dtype = T.dtype
    n_a = R - d
    a = jnp.arange(n_a)
    b = a + d
    svec = jnp.arange(S, dtype=dtype)

    # ---- skip(a, b, s) = T[a, b-1, s + x_b] + 2 (r_b - r_{b-1})(s + nl_a)
    #                      + 2 (l_b - r_{b-1}) x_b ---------------------------
    rows_bm1 = T[a, b - 1, :]  # [n_a, S]
    gather_idx = jnp.clip(svec[None, :].astype(jnp.int32) + x[b][:, None], 0, S - 1)
    shifted = jnp.take_along_axis(rows_bm1, gather_idx, axis=1)
    xb = x[b].astype(dtype)
    skip = (
        shifted
        + 2.0 * (right[b] - right[b - 1]).astype(dtype)[:, None]
        * (svec[None, :] + nl[a].astype(dtype)[:, None])
        + (2.0 * (left[b] - right[b - 1]).astype(dtype) * xb)[:, None]
    )

    # ---- detour_c over c = a+k, k = 1..d --------------------------------
    # candidates[k-1, a, s] = T[a, c-1, s] + T[c, b, s]
    #   + 2 (r_b - r_{c-1}) (s + nl_a) + 2 U (s + nl_c)
    def one_k(k):
        c = a + k
        t_left = T[a, c - 1, :]  # [n_a, S]
        t_right = T[c, b, :]  # [n_a, S]
        term = (
            t_left
            + t_right
            + 2.0 * (right[b] - right[c - 1]).astype(dtype)[:, None]
            * (svec[None, :] + nl[a].astype(dtype)[:, None])
            + 2.0 * u_turn * (svec[None, :] + nl[c].astype(dtype)[:, None])
        )
        return term

    det = one_k(1)
    for k in range(2, d + 1):
        det = jnp.minimum(det, one_k(k))

    new_diag = jnp.minimum(skip, det)  # [n_a, S]
    return T.at[a, b, :].set(new_diag)


def ltsp_dp_table_ref(left, right, x, nl, u_turn, S: int):
    """Full dense DP table (reference implementation, per-diagonal loop)."""
    R = left.shape[0]
    dtype = jnp.float32
    T = jnp.zeros((R, R, S), dtype=dtype)
    T = T.at[jnp.arange(R), jnp.arange(R), :].set(
        base_diagonal(right, left, nl, S, dtype)
    )
    for d in range(1, R):
        T = _diagonal_update(T, d, left, right, x, nl, u_turn, S)
    return T


def ltsp_opt_ref(left, right, x, nl, u_turn, m, S: int):
    """Optimal objective value: ``T[0, R-1, 0] + VirtualLB`` (float)."""
    R = left.shape[0]
    T = ltsp_dp_table_ref(left, right, x, nl, u_turn, S)
    virt = jnp.sum(
        x.astype(jnp.float32)
        * (m - left + (right - left) + u_turn).astype(jnp.float32)
    )
    return T[0, R - 1, 0] + virt

"""Host-side drivers for the Pallas LTSP wavefront: adapters, traceback,
single- and batched-instance solving.

The device path is a **complete solver**: :func:`ltsp_dp_tables` (one jitted
wavefront, see :mod:`.ltsp_dp`) returns the value table *and* per-cell argmin
planes; :func:`traceback_detours` replays the argmin planes on the host to
reconstruct the optimal detour list, exactly like the Python DP's traceback.

Two numeric modes:

* ``int32`` (solver default) — bit-exact while every table value fits in
  int32; :func:`_check_int32_safe` guards a conservative magnitude bound and
  raises with a rescaling hint otherwise.
* ``float32`` (oracle-comparison default, exact for values < 2**24) — used by
  the seed-compatible :func:`ltsp_dp_table`/:func:`ltsp_opt` wrappers that the
  kernel tests diff against :mod:`.ref`.

Batching (:func:`ltsp_solve_batch`): instances are right-padded with
zero-width, zero-multiplicity phantom files at the rightmost coordinate.  A
phantom file's ``skip`` transition is free and never loses to a detour
(detours only add nonnegative terms there, and skip wins ties), so neither
the root value nor the traceback changes — several tapes' instances solve in
one device launch.
"""

from __future__ import annotations

import math

import jax.numpy as jnp
import numpy as np

from ...core.instance import Instance, virtual_lb
from .ltsp_dp import ltsp_dp_tables

__all__ = [
    "prepare_arrays",
    "prepare_batch",
    "traceback_detours",
    "ltsp_dp_table",
    "ltsp_opt",
    "ltsp_opt_instance",
    "ltsp_solve_instance",
    "ltsp_solve_batch",
]


def _pad_s(S: int) -> int:
    return int(math.ceil(S / 128) * 128)


def prepare_arrays(inst: Instance, S: int | None = None, dtype=jnp.float32):
    """Instance → (left, right, x, nl, S) device arrays for the kernel.

    S defaults to n+1 padded up to a multiple of 128 (TPU lane width).
    """
    if S is None:
        S = inst.n + 1
    S = _pad_s(S)
    left = jnp.asarray(inst.left, dtype=dtype)
    right = jnp.asarray(inst.right, dtype=dtype)
    x = jnp.asarray(inst.mult, dtype=jnp.int32)
    nl = jnp.asarray(inst.n_left(), dtype=dtype)
    return left, right, x, nl, S


def prepare_batch(instances: list[Instance], dtype=jnp.int32):
    """Pack instances into padded ``[B, R_max]`` arrays + shared ``S``.

    Padding appends phantom files (zero width, zero multiplicity) at each
    instance's rightmost coordinate; see the module docstring for why this is
    result-preserving.
    """
    B = len(instances)
    R = max(i.n_req for i in instances)
    S = _pad_s(max(i.n for i in instances) + 1)
    left = np.zeros((B, R), dtype=np.int64)
    right = np.zeros((B, R), dtype=np.int64)
    x = np.zeros((B, R), dtype=np.int64)
    u = np.zeros((B,), dtype=np.int64)
    for i, inst in enumerate(instances):
        r = inst.n_req
        left[i, :r] = inst.left
        right[i, :r] = inst.right
        left[i, r:] = inst.right[-1]
        right[i, r:] = inst.right[-1]
        x[i, :r] = inst.mult
        u[i] = inst.u_turn
    nl = np.concatenate(
        [np.zeros((B, 1), np.int64), np.cumsum(x, axis=1)[:, :-1]], axis=1
    )
    return (
        jnp.asarray(left, dtype),
        jnp.asarray(right, dtype),
        jnp.asarray(x, jnp.int32),
        jnp.asarray(nl, dtype),
        jnp.asarray(u, dtype),
        S,
    )


def _check_int32_safe(instances: list[Instance]) -> None:
    """Conservative guard: every table value must stay well inside int32.

    Expanding any cell's recursion, the ``2 Δr (s + n_l)`` movement terms
    telescope to at most ``2n * 2m``, the base terms add at most ``2n * m``,
    and at most R detours each add ``2 U * 2n`` — so every cell is below
    ``2n (3m + R U)`` and every candidate sum below
    ``2n (7m + (2R + 1) U)``; we require ``2n (8m + (2R + 2) U) < 2**31``.
    Exact tape byte-coordinates overflow this; rescale coordinates (they
    share the tape's block granularity) or use the ``python`` backend.
    """
    for inst in instances:
        bound = 2 * inst.n * (8 * inst.m + (2 * inst.n_req + 2) * inst.u_turn)
        if bound >= 2**31:
            raise ValueError(
                f"instance too large for the int32 device DP "
                f"(m={inst.m}, n={inst.n}, R={inst.n_req}): rescale coordinates "
                f"to a coarser grain or use backend='python'"
            )


def traceback_detours(choice: np.ndarray, mult: np.ndarray) -> list[tuple[int, int]]:
    """Replay an argmin plane ``choice[R, R, S]`` into the detour list.

    Iterative pre-order walk from the root cell ``(0, R-1, 0)``: ``-1`` means
    "skip b" (descend to ``(a, b-1, s + x_b)``), ``c`` means detour ``(c, b)``
    (emit it, descend into its inner structure ``(c, b, s)``, then resume with
    ``(a, c-1, s)``).  Matches the exact Python DP's emission order.
    """
    R = choice.shape[0]
    x = [int(v) for v in mult]
    detours: list[tuple[int, int]] = []
    work: list[tuple[int, int, int]] = [(0, R - 1, 0)]
    while work:
        a, b, s = work.pop()
        while a < b:
            c = int(choice[a, b, s])
            if c == -1:
                s += x[b]
                b -= 1
                continue
            detours.append((c, b))
            work.append((a, c - 1, s))
            a = c
    return detours


# ---------------------------------------------------------------------------
# solver entry points (int32, exact)
# ---------------------------------------------------------------------------
def ltsp_solve_instance(
    inst: Instance, span: int | None = None, interpret: bool = True
) -> tuple[int, list[tuple[int, int]]]:
    """Device-solved ``(opt_cost, detours)`` for one instance (exact int32)."""
    return ltsp_solve_batch([inst], span=span, interpret=interpret)[0]


def ltsp_solve_batch(
    instances: list[Instance], span: int | None = None, interpret: bool = True
) -> list[tuple[int, list[tuple[int, int]]]]:
    """Solve several instances in one padded device launch.

    Returns one ``(opt_cost, detours)`` per instance, in order.  ``opt_cost``
    is ``VirtualLB + T[0, R_pad-1, 0]`` taken from the int32 device table —
    exact under the :func:`_check_int32_safe` bound; detour indices refer to
    each instance's own (unpadded) requested files.
    """
    if not instances:
        return []
    _check_int32_safe(instances)
    left, right, x, nl, u, S = prepare_batch(instances, dtype=jnp.int32)
    T, C = ltsp_dp_tables(left, right, x, nl, u, S=S, span=span, interpret=interpret)
    R_pad = left.shape[1]
    C_host = np.asarray(C)
    T_root = np.asarray(T[:, 0, R_pad - 1, 0])
    out = []
    for i, inst in enumerate(instances):
        dets = traceback_detours(C_host[i], np.asarray(x[i]))
        # padding only ever skips, so emitted detours stay within the real
        # files; guard the invariant anyway.
        assert all(b < inst.n_req for _, b in dets)
        cost = int(T_root[i]) + virtual_lb(inst)
        out.append((cost, dets))
    return out


# ---------------------------------------------------------------------------
# value-only f32 wrappers (seed-compatible API, diffed against ref.py)
# ---------------------------------------------------------------------------
def ltsp_dp_table(
    left, right, x, nl, u_turn: float, S: int, interpret: bool = True
):
    """Dense single-instance DP table (f32) via the single-trace wavefront."""
    dtype = left.dtype
    T, _ = ltsp_dp_tables(
        left[None],
        right[None],
        x[None],
        nl[None],
        jnp.asarray([u_turn], dtype),
        S=S,
        interpret=interpret,
    )
    return T[0]


def ltsp_opt(
    left, right, x, nl, u_turn: float, m: float, S: int, interpret: bool = True
):
    """Optimal LTSP objective (float): ``T[0, R-1, 0] + VirtualLB``."""
    T = ltsp_dp_table(left, right, x, nl, u_turn, S, interpret=interpret)
    virt = jnp.sum(x.astype(jnp.float32) * (m - left + (right - left) + u_turn))
    return T[0, left.shape[0] - 1, 0] + virt


def ltsp_opt_instance(inst: Instance, interpret: bool = True) -> float:
    """Convenience: exact-instance adapter (f32; exact for coords < 2**20)."""
    left, right, x, nl, S = prepare_arrays(inst)
    val = ltsp_opt(
        left, right, x, nl, float(inst.u_turn), float(inst.m), S, interpret=interpret
    )
    return float(val)

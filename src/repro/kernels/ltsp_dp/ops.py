"""Host-side drivers for the Pallas LTSP wavefront: adapters, rescaling,
traceback, and a size-bucketed batch planner.

The device path is a **complete solver**: :func:`ltsp_dp_tables` (one jitted
wavefront, see :mod:`.ltsp_dp`) returns the value table *and* per-cell argmin
planes; :func:`traceback_detours` replays the argmin planes on the host to
reconstruct the optimal detour list, exactly like the Python DP's traceback.

Three numeric modes:

* ``int32`` (solver default) — bit-exact while every table value fits in
  int32.  Before the :func:`_check_int32_safe` magnitude guard runs,
  :func:`rescale_instance` shifts each instance to its leftmost requested
  byte and divides all coordinates (and the U-turn penalty) by their gcd —
  every DP term is a coordinate *difference*, so the whole table scales by
  exactly ``1/g`` and the argmin structure (ties included) is untouched.
  Real cartridge layouts share the tape's block granularity, so byte
  coordinates far beyond int32 rescale into range; the guard rejects only
  genuinely coprime byte-scale layouts.
* ``float64`` (``numeric_policy="f64"`` fallback, exact for values < 2**53) —
  instances the int32 guard rejects are re-solved through the same wavefront
  in float64 **interpret** mode (f64 is emulated on TPU VPUs, so the
  compiled backend is not offered; the fallback is a CPU-side escape hatch
  for the rare coprime layouts).  Integer table values below 2**53 are
  exactly representable, so within :func:`_check_f64_safe`'s bound the
  result is still bit-identical to the python DP; beyond it the guard raises
  either way.  Selected via ``ExecutionContext.numeric_policy``; the default
  ``"strict"`` keeps the old raise.
* ``float32`` (oracle-comparison default, exact for values < 2**24) — used by
  the seed-compatible :func:`ltsp_dp_table`/:func:`ltsp_opt` wrappers that the
  kernel tests diff against :mod:`.ref`.

``disjoint=True`` routes SIMPLEDP through the same kernel: the candidate band
is clipped to root-level cells (no detour may start inside another), which
collapses the 3-D table to SIMPLEDP's 2-D recursion — same mechanism as the
LOGDP ``span`` clip, bit-identical to :func:`repro.core.dp.simpledp_schedule`
(cost *and* traceback).

Batching and the bucket planner
-------------------------------
Instances are right-padded with zero-width, zero-multiplicity phantom files at
the rightmost coordinate.  A phantom file's ``skip`` transition is free and
never loses to a detour (detours only add nonnegative terms there, and skip
wins ties), so neither the root value nor the traceback changes — several
tapes' instances solve in one device launch.

A single launch must share one ``(B, R, S)`` shape, so the seed driver padded
*every* instance to the global ``(R_max, S_max)`` — maximally wasteful on the
heterogeneous cartridge batches the IN2P3 logs actually produce.
:func:`plan_buckets` instead groups instances into a small set of shape
buckets and :func:`ltsp_solve_batch` launches one tight wavefront per bucket.

Bucket-rounding policy (applies to every padded dimension):

* ``R`` (requested files) rounds up to the next power of two;
* ``S`` (skip counts, ``n + 1``) rounds up to the next power-of-two multiple
  of 128 (the TPU lane width): 128, 256, 512, …;
* ``B`` (instances per launch) rounds up to the next power of two, padding
  with all-phantom rows that are never traced back.

Powers-of-two rounding bounds the set of distinct launch shapes
logarithmically, so repeated heterogeneous batches re-hit the ``jit`` cache
instead of retracing the wavefront for every novel ``(B, R, S)``; within a
bucket, padding waste is at most 2x per dimension instead of unbounded.
``ltsp_solve_batch([])`` returns ``[]`` and single-instance batches skip the
planner entirely (one tight launch, no grouping pass).
"""

from __future__ import annotations

import math
import time

import jax.numpy as jnp
import numpy as np

from ...core.instance import Instance, virtual_lb
from ...core.warm import DenseStore, WarmState, WarmStats, align_warm, warm_from_instance
from .ltsp_dp import DEFAULT_CAND_TILE, ltsp_dp_tables

__all__ = [
    "prepare_arrays",
    "prepare_batch",
    "plan_buckets",
    "bucket_shape",
    "rescale_instance",
    "traceback_detours",
    "ltsp_dp_table",
    "ltsp_opt",
    "ltsp_opt_instance",
    "ltsp_solve_instance",
    "ltsp_solve_batch",
    "ltsp_solve_instance_warm",
    "ltsp_solve_batch_warm",
]


def _pad_s(S: int) -> int:
    return int(math.ceil(S / 128) * 128)


def _pow2(v: int) -> int:
    """Smallest power of two >= v (v >= 1)."""
    return 1 << max(0, int(v) - 1).bit_length()


def bucket_shape(inst: Instance) -> tuple[int, int]:
    """``(R_pad, S_pad)`` shape bucket for one instance.

    See the module docstring for the rounding policy: ``R`` to the next power
    of two, ``S = n + 1`` to the next power-of-two multiple of 128.
    """
    return _pow2(inst.n_req), 128 * _pow2(-(-(inst.n + 1) // 128))


def plan_buckets(instances: list[Instance]) -> dict[tuple[int, int], list[int]]:
    """Group instance indices by shape bucket (insertion-ordered)."""
    buckets: dict[tuple[int, int], list[int]] = {}
    for i, inst in enumerate(instances):
        buckets.setdefault(bucket_shape(inst), []).append(i)
    return buckets


def prepare_arrays(inst: Instance, S: int | None = None, dtype=jnp.float32):
    """Instance → (left, right, x, nl, S) device arrays for the kernel.

    S defaults to n+1 padded up to a multiple of 128 (TPU lane width).
    """
    if S is None:
        S = inst.n + 1
    S = _pad_s(S)
    left = jnp.asarray(inst.left, dtype=dtype)
    right = jnp.asarray(inst.right, dtype=dtype)
    x = jnp.asarray(inst.mult, dtype=jnp.int32)
    nl = jnp.asarray(inst.n_left(), dtype=dtype)
    return left, right, x, nl, S


def prepare_batch(
    instances: list[Instance],
    dtype=jnp.int32,
    R_pad: int | None = None,
    S_pad: int | None = None,
    B_pad: int | None = None,
):
    """Pack instances into padded ``[B, R]`` arrays + shared ``S``.

    ``R_pad``/``S_pad``/``B_pad`` override the default tight padding (the
    batch maxima) — the bucket planner passes its power-of-two bucket shape so
    repeated launches share compiled programs.  File padding appends phantom
    files (zero width, zero multiplicity) at each instance's rightmost
    coordinate; batch padding appends all-phantom rows; see the module
    docstring for why both are result-preserving.
    """
    if not instances:
        raise ValueError("prepare_batch needs at least one instance")
    B = len(instances) if B_pad is None else max(B_pad, len(instances))
    R = max(i.n_req for i in instances) if R_pad is None else R_pad
    S = _pad_s(max(i.n for i in instances) + 1 if S_pad is None else S_pad)
    if R < max(i.n_req for i in instances):
        raise ValueError("R_pad smaller than the widest instance")
    if S_pad is not None and S_pad < max(i.n for i in instances) + 1:
        raise ValueError("S_pad smaller than the largest request count + 1")
    left = np.zeros((B, R), dtype=np.int64)
    right = np.zeros((B, R), dtype=np.int64)
    x = np.zeros((B, R), dtype=np.int64)
    u = np.zeros((B,), dtype=np.int64)
    for i, inst in enumerate(instances):
        r = inst.n_req
        left[i, :r] = inst.left
        right[i, :r] = inst.right
        left[i, r:] = inst.right[-1]
        right[i, r:] = inst.right[-1]
        x[i, :r] = inst.mult
        u[i] = inst.u_turn
    nl = np.concatenate(
        [np.zeros((B, 1), np.int64), np.cumsum(x, axis=1)[:, :-1]], axis=1
    )
    return (
        jnp.asarray(left, dtype),
        jnp.asarray(right, dtype),
        jnp.asarray(x, jnp.int32),
        jnp.asarray(nl, dtype),
        jnp.asarray(u, dtype),
        S,
    )


def rescale_instance(inst: Instance) -> tuple[Instance, int]:
    """Shift + gcd-reduce an instance for the int32 device table.

    Returns ``(scaled, g)`` with coordinates ``(coord - left[0]) // g`` where
    ``g = gcd`` of all shifted coordinates and the U-turn penalty.  Every DP
    term (base, skip, detour) is a linear combination of coordinate
    *differences* and ``U`` with scale-free integer coefficients, so the full
    table of ``scaled`` is exactly ``1/g`` times the original's and its argmin
    planes — the traceback — are identical.  Reconstruct original table values
    as ``g * T_scaled``.

    The scaled instance's ``m`` is set to its rightmost coordinate (the head
    start position never enters the device table — only *VirtualLB*, which the
    host computes from the original instance), which tightens the
    :func:`_check_int32_safe` bound to the requested span instead of the
    absolute tape length.
    """
    base = int(inst.left[0])
    g = 0
    for v in inst.left.tolist():
        g = math.gcd(g, v - base)
    for v in inst.right.tolist():
        g = math.gcd(g, v - base)
    g = math.gcd(g, inst.u_turn) or 1
    left = (inst.left - base) // g
    right = (inst.right - base) // g
    scaled = Instance(
        left=left,
        right=right,
        mult=inst.mult,
        m=int(right[-1]),
        u_turn=inst.u_turn // g,
    )
    return scaled, g


def _table_bound(inst: Instance) -> int:
    """Conservative bound on any candidate sum the kernel ever forms.

    Expanding any cell's recursion, the ``2 Δr (s + n_l)`` movement terms
    telescope to at most ``2n * 2m``, the base terms add at most ``2n * m``,
    and at most R detours each add ``2 U * 2n`` — so every cell is below
    ``2n (3m + R U)`` and every candidate sum below
    ``2n (7m + (2R + 1) U)``; we bound with ``2n (8m + (2R + 2) U)``.
    Callers pass :func:`rescale_instance` output, so ``m`` here is already the
    gcd-reduced *requested span*.
    """
    return 2 * inst.n * (8 * inst.m + (2 * inst.n_req + 2) * inst.u_turn)


def _check_int32_safe(instances: list[Instance]) -> None:
    """Magnitude guard for the int32 table: raising means the instance
    genuinely overflows even at tape-block granularity (after gcd/shift
    rescaling)."""
    for inst in instances:
        if _table_bound(inst) >= 2**31:
            raise ValueError(
                f"instance too large for the int32 device DP even after gcd "
                f"rescaling (m={inst.m}, n={inst.n}, R={inst.n_req}): rescale "
                f"coordinates to a coarser grain, use backend='python', or "
                f"opt into the exact float64 interpret fallback with "
                f"numeric_policy='f64'"
            )


def _check_f64_safe(instances: list[Instance]) -> None:
    """Exactness-domain guard for the float64 fallback (< 2**53)."""
    for inst in instances:
        if _table_bound(inst) >= 2**53:
            raise ValueError(
                f"instance too large even for the exact float64 device DP "
                f"(m={inst.m}, n={inst.n}, R={inst.n_req}): integer table "
                f"values would exceed 2**53; use backend='python'"
            )


def traceback_detours(choice: np.ndarray, mult: np.ndarray) -> list[tuple[int, int]]:
    """Replay an argmin plane ``choice[R, R, S]`` into the detour list.

    Iterative pre-order walk from the root cell ``(0, R-1, 0)``: ``-1`` means
    "skip b" (descend to ``(a, b-1, s + x_b)``), ``c`` means detour ``(c, b)``
    (emit it, descend into its inner structure ``(c, b, s)``, then resume with
    ``(a, c-1, s)``).  Matches the exact Python DP's emission order.
    """
    R = choice.shape[0]
    x = [int(v) for v in mult]
    detours: list[tuple[int, int]] = []
    work: list[tuple[int, int, int]] = [(0, R - 1, 0)]
    while work:
        a, b, s = work.pop()
        while a < b:
            c = int(choice[a, b, s])
            if c == -1:
                s += x[b]
                b -= 1
                continue
            detours.append((c, b))
            work.append((a, c - 1, s))
            a = c
    return detours


# ---------------------------------------------------------------------------
# solver entry points (int32 exact; float64 interpret fallback)
# ---------------------------------------------------------------------------
def ltsp_solve_instance(
    inst: Instance,
    span: int | None = None,
    interpret: bool = True,
    cand_tile: int = DEFAULT_CAND_TILE,
    disjoint: bool = False,
    numeric_policy: str = "strict",
    profile=None,
) -> tuple[int, list[tuple[int, int]]]:
    """Device-solved ``(opt_cost, detours)`` for one instance (exact)."""
    return ltsp_solve_batch([inst], span=span, interpret=interpret,
                            cand_tile=cand_tile, disjoint=disjoint,
                            numeric_policy=numeric_policy, profile=profile)[0]


def _solve_packed(
    originals: list[Instance],
    scaled: list[Instance],
    gs: list[int],
    R_pad: int | None,
    S_pad: int | None,
    B_pad: int | None,
    span: int | None,
    interpret: bool,
    cand_tile: int,
    disjoint: bool = False,
    dtype=jnp.int32,
    capture: bool = False,
) -> tuple[list[tuple[int, list[tuple[int, int]]]], list[DenseStore | None]]:
    """One padded device launch; results refer to the *original* instances.

    ``capture=True`` additionally snapshots each instance's dense value and
    argmin planes into a :class:`~repro.core.warm.DenseStore` (kept in the
    launch's gcd-rescaled units together with ``g``, so lookups reconstruct
    original-unit values with python-int arithmetic).
    """
    left, right, x, nl, u, S = prepare_batch(
        scaled, dtype=dtype, R_pad=R_pad, S_pad=S_pad, B_pad=B_pad
    )
    T, C = ltsp_dp_tables(
        left, right, x, nl, u, S=S, span=span, disjoint=disjoint,
        interpret=interpret, cand_tile=cand_tile,
    )
    R = left.shape[1]
    C_host = np.asarray(C)
    T_root = np.asarray(T[:, 0, R - 1, 0])
    T_host = np.asarray(T) if capture else None
    out = []
    stores: list[DenseStore | None] = []
    x_host = np.asarray(x)
    for i, (inst, g) in enumerate(zip(originals, gs)):
        dets = traceback_detours(C_host[i], x_host[i])
        # padding only ever skips, so emitted detours stay within the real
        # files; guard the invariant anyway.
        assert all(b < inst.n_req for _, b in dets)
        # the scaled table is exactly 1/g of the original's (see
        # rescale_instance); VirtualLB comes from the original coordinates.
        cost = g * int(T_root[i]) + virtual_lb(inst)
        out.append((cost, dets))
        if capture:
            prefix = np.cumsum(inst.mult).tolist()
            stores.append(
                DenseStore(T_host[i].copy(), C_host[i].copy(), g, inst.n, prefix)
            )
        else:
            stores.append(None)
    return out, stores


def ltsp_solve_batch(
    instances: list[Instance],
    span: int | None = None,
    interpret: bool = True,
    bucketed: bool = True,
    cand_tile: int = DEFAULT_CAND_TILE,
    disjoint: bool = False,
    numeric_policy: str = "strict",
    capture: bool = False,
    profile=None,
) -> list[tuple[int, list[tuple[int, int]]]]:
    """Solve several instances in a few size-bucketed device launches.

    Returns one ``(opt_cost, detours)`` per instance, in order.  ``opt_cost``
    is ``g * T[0, R_pad-1, 0] + VirtualLB`` taken from the gcd-rescaled int32
    device table — exact under the :func:`_check_int32_safe` bound; detour
    indices refer to each instance's own (unpadded) requested files.

    ``bucketed=True`` (default) launches one wavefront per
    :func:`plan_buckets` shape bucket — tight shapes for heterogeneous
    batches, jit-cache-friendly powers-of-two padding.  ``bucketed=False``
    reproduces the seed behaviour (every instance padded to the global batch
    maxima, one launch) and exists for A/B benchmarking.

    ``numeric_policy="f64"`` re-routes the (rare) instances that fail the
    int32 magnitude guard after gcd/shift rescaling through an exact float64
    **interpret** table instead of raising (see the module docstring); the
    int32-safe majority still takes the int32 launches unchanged.

    ``capture=True`` changes the return to ``(results, stores)`` where
    ``stores[i]`` is a :class:`~repro.core.warm.DenseStore` snapshot of
    instance ``i``'s dense value/argmin planes — the raw material for
    warm-starting the next solve of a perturbed sibling (see
    :func:`ltsp_solve_batch_warm`).

    ``profile`` takes an optional :class:`~repro.obs.KernelProfile`: every
    device launch records its padded bucket shape, the exact
    real-vs-padded DP cell counts, whether its jit signature was cold, and
    (when the profile captures wall time) the host wall time around the
    launch — pure host-side accounting, results unchanged.
    """
    if not instances:
        return ([], []) if capture else []
    pairs = [rescale_instance(inst) for inst in instances]
    scaled = [p[0] for p in pairs]
    gs = [p[1] for p in pairs]
    if numeric_policy == "f64":
        wide = [i for i, s in enumerate(scaled) if _table_bound(s) >= 2**31]
        _check_f64_safe([scaled[i] for i in wide])
    else:
        wide = []
        _check_int32_safe(scaled)
    wide_set = set(wide)
    narrow = [i for i in range(len(instances)) if i not in wide_set]

    stores: list[DenseStore | None] = [None] * len(instances)

    def solve(idxs, R_pad, S_pad, B_pad, dtype=jnp.int32, interp=None):
        interp_eff = interpret if interp is None else interp
        t0 = (
            time.perf_counter_ns()
            if profile is not None and profile.wall
            else None
        )
        out, subs = _solve_packed(
            [instances[i] for i in idxs],
            [scaled[i] for i in idxs],
            [gs[i] for i in idxs],
            R_pad, S_pad, B_pad, span,
            interp_eff, cand_tile,
            disjoint=disjoint, dtype=dtype, capture=capture,
        )
        for i, st in zip(idxs, subs):
            stores[i] = st
        if profile is not None:
            # mirror prepare_batch's padding defaults so the record reports
            # the launch shape that actually ran
            sub = [scaled[i] for i in idxs]
            B_eff = len(sub) if B_pad is None else max(B_pad, len(sub))
            R_eff = max(s.n_req for s in sub) if R_pad is None else R_pad
            S_eff = _pad_s(max(s.n for s in sub) + 1 if S_pad is None else S_pad)
            profile.record(
                signature=(
                    R_eff, S_eff, B_eff, np.dtype(dtype).name, interp_eff,
                    span, disjoint, cand_tile,
                ),
                n_instances=len(sub),
                R_pad=R_eff,
                S_pad=S_eff,
                B_pad=B_eff,
                real_cells=sum(s.n_req * s.n_req * (s.n + 1) for s in sub),
                interpret=interp_eff,
                wall_ns=(
                    time.perf_counter_ns() - t0 if t0 is not None else None
                ),
            )
        return out

    def done(results):
        return (results, stores) if capture else results

    results: list[tuple[int, list[tuple[int, int]]] | None] = [None] * len(instances)
    if wide:
        # float64 is a correctness escape hatch for coprime byte-scale
        # layouts, not a throughput path: interpret mode, one tight launch
        # per instance, under a scoped x64 context (never enabled globally).
        from jax.experimental import enable_x64

        with enable_x64():
            for i in wide:
                R_pad, S_pad = bucket_shape(scaled[i])
                # interp=True: f64 is emulated on TPU, never compiled
                [results[i]] = solve(
                    [i], R_pad, S_pad, None, dtype=jnp.float64, interp=True
                )
    if not narrow:
        return done(results)  # type: ignore[return-value]
    if not bucketed:  # seed behaviour: one launch padded to the batch maxima
        for i, res in zip(narrow, solve(narrow, None, None, None)):
            results[i] = res
        return done(results)  # type: ignore[return-value]
    if len(narrow) == 1:  # fast path: no planner, one tight launch
        [i] = narrow
        R_pad, S_pad = bucket_shape(scaled[i])
        [results[i]] = solve([i], R_pad, S_pad, None)
        return done(results)  # type: ignore[return-value]
    for (R_pad, S_pad), sub in plan_buckets([scaled[i] for i in narrow]).items():
        idxs = [narrow[j] for j in sub]
        for idx, res in zip(idxs, solve(idxs, R_pad, S_pad, _pow2(len(idxs)))):
            results[idx] = res
    return done(results)  # type: ignore[return-value]


# ---------------------------------------------------------------------------
# warm-start entry points
# ---------------------------------------------------------------------------
def ltsp_solve_instance_warm(
    inst: Instance,
    span: int | None = None,
    warm: WarmState | None = None,
    interpret: bool = True,
    cand_tile: int = DEFAULT_CAND_TILE,
    numeric_policy: str = "strict",
    profile=None,
) -> tuple[int, list[tuple[int, int]], WarmState | None, WarmStats]:
    """Warm-startable single-instance solve (see :func:`ltsp_solve_batch_warm`)."""
    results, warms, stats = ltsp_solve_batch_warm(
        [inst], [warm], span=span, interpret=interpret,
        cand_tile=cand_tile, numeric_policy=numeric_policy, profile=profile,
    )
    (cost, dets) = results[0]
    return cost, dets, warms[0], stats[0]


def ltsp_solve_batch_warm(
    instances: list[Instance],
    warms: list[WarmState | None] | None = None,
    span: int | None = None,
    interpret: bool = True,
    bucketed: bool = True,
    cand_tile: int = DEFAULT_CAND_TILE,
    numeric_policy: str = "strict",
    profile=None,
) -> tuple[
    list[tuple[int, list[tuple[int, int]]]],
    list[WarmState | None],
    list[WarmStats],
]:
    """Warm-startable batch solve, bit-identical to :func:`ltsp_solve_batch`.

    Instances whose :class:`~repro.core.warm.WarmState` aligns (same U-turn
    penalty and span, at least one matching file run — see
    :func:`repro.core.warm.align_warm`) re-evaluate **only the invalidated
    cells on the host**, in exact python ints, reading every still-valid cell
    out of the warm store: a device relaunch would recompute the whole dense
    table, which is precisely the work warm-starting exists to avoid, and
    the host incremental path is bit-identical to the device wavefront (the
    python and device backends are pinned bit-identical by the kernel parity
    tests, and warm-vs-cold identity is asserted differentially on top).
    Everything else takes the normal bucketed device launches with
    ``capture=True``, so each cold solve yields a dense
    :class:`~repro.core.warm.DenseStore` warm state for the next tick.

    The numeric-policy magnitude guards run for *every* instance first —
    including warm-aligned ones, which the guards' failure modes could
    otherwise bypass — so strict-mode error behaviour matches the cold path
    exactly.  Returns ``(results, new_warm_states, stats)``, all parallel to
    ``instances``.
    """
    if not instances:
        return [], [], []
    if warms is None:
        warms = [None] * len(instances)
    # same guard discipline as the cold path (before any solving: a batch
    # never fails mid-flight)
    scaled = [rescale_instance(inst)[0] for inst in instances]
    if numeric_policy == "f64":
        _check_f64_safe([s for s in scaled if _table_bound(s) >= 2**31])
    else:
        _check_int32_safe(scaled)

    from ...core.dp import dp_schedule_warm

    results: list[tuple[int, list[tuple[int, int]]] | None] = [None] * len(instances)
    new_warms: list[WarmState | None] = [None] * len(instances)
    stats: list[WarmStats | None] = [None] * len(instances)
    cold: list[int] = []
    for i, (inst, warm) in enumerate(zip(instances, warms)):
        if align_warm(warm, inst, span) is not None:
            cost, dets, new_warm, st = dp_schedule_warm(inst, span=span, warm=warm)
            results[i], new_warms[i], stats[i] = (cost, dets), new_warm, st
        else:
            cold.append(i)
    if cold:
        solved, stores = ltsp_solve_batch(
            [instances[i] for i in cold], span=span, interpret=interpret,
            bucketed=bucketed, cand_tile=cand_tile,
            numeric_policy=numeric_policy, capture=True, profile=profile,
        )
        for i, res, store in zip(cold, solved, stores):
            results[i] = res
            new_warms[i] = (
                warm_from_instance(instances[i], span, store)
                if store is not None else None
            )
            # honest device work accounting: the wavefront evaluates every
            # dense cell of the padded launch shape
            stats[i] = WarmStats(cells_evaluated=len(store) if store else 0)
    return results, new_warms, stats  # type: ignore[return-value]


# ---------------------------------------------------------------------------
# value-only f32 wrappers (seed-compatible API, diffed against ref.py)
# ---------------------------------------------------------------------------
def ltsp_dp_table(
    left, right, x, nl, u_turn: float, S: int, interpret: bool = True
):
    """Dense single-instance DP table (f32) via the single-trace wavefront."""
    dtype = left.dtype
    T, _ = ltsp_dp_tables(
        left[None],
        right[None],
        x[None],
        nl[None],
        jnp.asarray([u_turn], dtype),
        S=S,
        interpret=interpret,
    )
    return T[0]


def ltsp_opt(
    left, right, x, nl, u_turn: float, m: float, S: int, interpret: bool = True
):
    """Optimal LTSP objective (float): ``T[0, R-1, 0] + VirtualLB``."""
    T = ltsp_dp_table(left, right, x, nl, u_turn, S, interpret=interpret)
    virt = jnp.sum(x.astype(jnp.float32) * (m - left + (right - left) + u_turn))
    return T[0, left.shape[0] - 1, 0] + virt


def ltsp_opt_instance(inst: Instance, interpret: bool = True) -> float:
    """Convenience: exact-instance adapter (f32; exact for coords < 2**20)."""
    left, right, x, nl, S = prepare_arrays(inst)
    val = ltsp_opt(
        left, right, x, nl, float(inst.u_turn), float(inst.m), S, interpret=interpret
    )
    return float(val)

"""Jitted wrapper assembling the full LTSP DP table from diagonal launches.

``ltsp_dp_table`` drives the Pallas kernel one anti-diagonal at a time
(the wavefront dependency), scattering each diagonal back into the dense
table.  ``ltsp_opt`` returns the optimal objective value.  ``from_instance``
adapts an exact :class:`repro.core.instance.Instance`, optionally rescaling
coordinates so f32 stays exact (all values < 2**20).
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp
import numpy as np

from ...core.instance import Instance, virtual_lb
from .ltsp_dp import ltsp_dp_diagonal
from .ref import base_diagonal

__all__ = ["ltsp_dp_table", "ltsp_opt", "prepare_arrays", "ltsp_opt_instance"]


def prepare_arrays(inst: Instance, S: int | None = None):
    """Instance → (left, right, x, nl, S) device arrays for the kernel.

    S defaults to n+1 padded up to a multiple of 128 (TPU lane width).
    """
    if S is None:
        S = inst.n + 1
    S = int(math.ceil(S / 128) * 128)
    left = jnp.asarray(inst.left, dtype=jnp.float32)
    right = jnp.asarray(inst.right, dtype=jnp.float32)
    x = jnp.asarray(inst.mult, dtype=jnp.int32)
    nl = jnp.asarray(inst.n_left(), dtype=jnp.float32)
    return left, right, x, nl, S


def ltsp_dp_table(
    left: jax.Array,
    right: jax.Array,
    x: jax.Array,
    nl: jax.Array,
    u_turn: float,
    S: int,
    interpret: bool = True,
) -> jax.Array:
    """Dense DP table via per-diagonal Pallas launches."""
    R = left.shape[0]
    T = jnp.zeros((R, R, S), dtype=jnp.float32)
    rr = jnp.arange(R)
    T = T.at[rr, rr, :].set(base_diagonal(right, left, nl, S))
    for d in range(1, R):
        diag = ltsp_dp_diagonal(
            T, left, right, x, nl, d=d, u_turn=float(u_turn), S=S, interpret=interpret
        )
        a = jnp.arange(R - d)
        T = T.at[a, a + d, :].set(diag)
    return T


def ltsp_opt(
    left, right, x, nl, u_turn: float, m: float, S: int, interpret: bool = True
) -> jax.Array:
    """Optimal LTSP objective (float): ``T[0, R-1, 0] + VirtualLB``."""
    T = ltsp_dp_table(left, right, x, nl, u_turn, S, interpret=interpret)
    virt = jnp.sum(
        x.astype(jnp.float32) * (m - left + (right - left) + u_turn)
    )
    return T[0, left.shape[0] - 1, 0] + virt


def ltsp_opt_instance(inst: Instance, interpret: bool = True) -> float:
    """Convenience: exact-instance adapter (f32; exact for coords < 2**20)."""
    left, right, x, nl, S = prepare_arrays(inst)
    val = ltsp_opt(
        left, right, x, nl, float(inst.u_turn), float(inst.m), S, interpret=interpret
    )
    return float(val)

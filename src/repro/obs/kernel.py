"""Kernel launch profiling: bucket shapes, padding waste, compile-vs-run.

The device path (:mod:`repro.kernels.ltsp_dp.ops`) launches one bucketed
wavefront per power-of-two ``(R, S, B)`` shape.  A :class:`KernelProfile`
attached through ``ExecutionContext.obs`` records one
:class:`LaunchRecord` per launch:

* the padded bucket shape and the **exact** real-vs-padded DP cell counts
  (``padded = B_pad * R_pad * R_pad * S_pad``; ``real`` sums each
  instance's ``n_req^2 * (n + 1)`` table) — the padding-waste ratio the
  ROADMAP's ragged-grid item targets, as an exact fraction;
* ``cold`` — whether this profile has seen the launch's jit signature
  (shape bucket x dtype x interpret x band layout) before: a cold
  launch's wall time includes trace+compile, a warm one is execute-only.
  (Scoped to the profile: a fresh profile on a warm process marks the
  first launch cold even though jax's jit cache may already hold it.)
* ``wall_ns`` — host wall time around the launch (on by default here;
  kernel profiling exists to measure the host clock, unlike the tracer).
"""

from __future__ import annotations

import dataclasses

__all__ = ["LaunchRecord", "KernelProfile"]


@dataclasses.dataclass(frozen=True)
class LaunchRecord:
    """One device launch: shape, exact cell accounting, timing."""

    n_instances: int
    R_pad: int
    S_pad: int
    B_pad: int
    real_cells: int
    padded_cells: int
    interpret: bool
    cold: bool
    wall_ns: int | None = None

    @property
    def waste(self) -> tuple[int, int]:
        """Padding waste as the exact fraction ``(wasted, padded)`` cells."""
        return (self.padded_cells - self.real_cells, self.padded_cells)


class KernelProfile:
    """Accumulates :class:`LaunchRecord` rows across a run."""

    def __init__(self, *, wall: bool = True):
        self.wall = bool(wall)
        self.launches: list[LaunchRecord] = []
        self._seen: set[tuple] = set()

    def record(
        self,
        *,
        signature: tuple,
        n_instances: int,
        R_pad: int,
        S_pad: int,
        B_pad: int,
        real_cells: int,
        interpret: bool,
        wall_ns: int | None = None,
    ) -> None:
        cold = signature not in self._seen
        self._seen.add(signature)
        self.launches.append(
            LaunchRecord(
                n_instances=n_instances,
                R_pad=R_pad,
                S_pad=S_pad,
                B_pad=B_pad,
                real_cells=real_cells,
                padded_cells=B_pad * R_pad * R_pad * S_pad,
                interpret=interpret,
                cold=cold,
                wall_ns=wall_ns,
            )
        )

    def summary(self) -> dict:
        """Exact totals: launch counts, cell accounting, waste fraction."""
        real = sum(r.real_cells for r in self.launches)
        padded = sum(r.padded_cells for r in self.launches)
        return {
            "n_launches": len(self.launches),
            "n_cold": sum(1 for r in self.launches if r.cold),
            "n_instances": sum(r.n_instances for r in self.launches),
            "real_cells": real,
            "padded_cells": padded,
            "wasted_cells": padded - real,
        }

    def __len__(self) -> int:
        return len(self.launches)

"""Virtual-time tracing: structured spans/events on the simulator's clock.

A :class:`Tracer` collects :class:`Span` records keyed by the serving
stack's **exact virtual time** — the same integer clock every event loop,
drive leg, and solve delay runs on — so a trace of a run is as
deterministic as the run itself.  Wall-clock capture is opt-in
(``Tracer(wall=True)`` stamps each span with ``perf_counter_ns``); with it
off (the default) the span stream of two identical seeded runs is
byte-identical through :func:`repro.obs.export.spans_jsonl`.

:class:`NullTracer` is the pinned no-op: every recording method is a
``pass``, so attaching one (or attaching nothing at all — the
``ExecutionContext.obs`` default is ``None``) leaves every timeline,
journal, and report bit-identical to an uninstrumented run.

Spans carry a ``track`` (one per drive / queue / router — the Chrome
trace exporter renders one thread lane per track) and a ``shard`` (the
fleet sets it per federated server; standalone runs use shard 0, which
the exporters render as one process).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Mapping

__all__ = ["Span", "Tracer", "NullTracer"]


@dataclasses.dataclass(frozen=True)
class Span:
    """One traced interval (or instant, when ``t0 == t1``) of virtual time.

    ``t0``/``t1`` are exact virtual-time integers; ``seq`` is the tracer's
    emission index (a total order even among zero-length spans at the same
    instant); ``attrs`` holds free-form JSON-serialisable attributes
    (tape ids, policies, exact cell counts).  ``wall_ns`` is only stamped
    when the tracer was built with ``wall=True``.
    """

    name: str
    t0: int
    t1: int
    cat: str = "serving"
    track: str = "main"
    shard: int = 0
    seq: int = 0
    attrs: Mapping[str, Any] = dataclasses.field(default_factory=dict)
    wall_ns: int | None = None

    @property
    def duration(self) -> int:
        return self.t1 - self.t0

    @property
    def instant(self) -> bool:
        return self.t0 == self.t1


class Tracer:
    """Collects spans/events in emission order (deterministic per run).

    Recording never inspects or mutates the run it observes: hooks hand it
    already-computed exact integers, so an attached tracer cannot perturb
    virtual time, journal bytes, or schedules.
    """

    def __init__(self, *, wall: bool = False):
        self.wall = bool(wall)
        self.spans: list[Span] = []
        self._seq = 0

    def span(
        self,
        name: str,
        t0: int,
        t1: int,
        *,
        cat: str = "serving",
        track: str = "main",
        shard: int = 0,
        **attrs: Any,
    ) -> None:
        """Record a completed virtual-time interval ``[t0, t1]``."""
        if t1 < t0:
            raise ValueError(f"span {name!r} ends before it starts: {t0} > {t1}")
        self.spans.append(
            Span(
                name=name,
                t0=int(t0),
                t1=int(t1),
                cat=cat,
                track=track,
                shard=int(shard),
                seq=self._seq,
                attrs=attrs,
                wall_ns=time.perf_counter_ns() if self.wall else None,
            )
        )
        self._seq += 1

    def event(
        self,
        name: str,
        t: int,
        *,
        cat: str = "serving",
        track: str = "main",
        shard: int = 0,
        **attrs: Any,
    ) -> None:
        """Record an instantaneous event (a zero-length span)."""
        self.span(name, t, t, cat=cat, track=track, shard=shard, **attrs)

    def __len__(self) -> int:
        return len(self.spans)


class NullTracer(Tracer):
    """The no-op tracer: accepts every call, records nothing.

    Attaching one is indistinguishable (bit for bit) from attaching no
    tracer at all — pinned by ``tests/test_obs.py``.
    """

    def span(self, name, t0, t1, **kwargs) -> None:  # noqa: D102
        return None

    def event(self, name, t, **kwargs) -> None:  # noqa: D102
        return None

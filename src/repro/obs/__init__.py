"""Observability: virtual-time tracing, exact-int metrics, and exporters.

The serving stack reports quality of service *after the fact*
(:class:`~repro.serving.sim.ServiceReport`, ``slo_report``); this package
shows **where virtual time and solve work go inside a run** — mount legs
vs seek legs vs solve delay vs retry backoff, per drive and per shard —
without perturbing a single byte of it.

Observability
-------------
Everything hangs off one opt-in :class:`Observability` bundle, attached
through :class:`~repro.core.context.ExecutionContext` (``obs=`` field,
``context.replace(obs=Observability.enabled())``):

* :class:`~repro.obs.trace.Tracer` — spans/events keyed by the exact
  virtual-time integer clock (optional wall-clock stamps); the
  :class:`~repro.obs.trace.NullTracer` no-op and the unset default are
  both pinned bit-identical to an uninstrumented run.
* :class:`~repro.obs.metrics.MetricsRegistry` — counters / gauges /
  exact-int histograms (nearest-rank quantiles via
  :func:`repro.serving.qos.int_quantile`), fed by hooks in the solver
  (per-policy solves, cells, selector decisions, degradation fallbacks),
  the cache (hits/misses/evictions per backend), the drive pool
  (mount/unmount/evict legs, failures), the event loop (arrivals, queue
  depth, batch dispatches, retry backoff, fault events), and the fleet
  (routing, re-routes, outages, per-shard rollups).
* :class:`~repro.obs.kernel.KernelProfile` — per-launch device records:
  bucket shape, exact real-vs-padded cell counts (padding waste), and
  compile-vs-execute wall time.
* :mod:`~repro.obs.export` — a byte-deterministic JSONL span log, a
  Prometheus text snapshot whose integers match the report types exactly,
  and a Chrome ``trace_event`` JSON (one lane per drive, one process per
  shard, virtual microseconds) loadable in Perfetto.

Every hook is guarded by ``obs is not None`` and hands over
already-computed exact integers: with ``obs`` unset the instrumented code
paths are pinned bit-identical (timelines, journals, benchmark records)
to the uninstrumented stack — gated by ``tests/test_obs.py``.
"""

from __future__ import annotations

import dataclasses

from .export import (
    chrome_trace,
    prometheus_text,
    spans_jsonl,
    write_chrome_trace,
    write_prometheus,
    write_spans_jsonl,
)
from .kernel import KernelProfile, LaunchRecord
from .metrics import MetricsRegistry
from .trace import NullTracer, Span, Tracer

__all__ = [
    "Observability",
    "Tracer",
    "NullTracer",
    "Span",
    "MetricsRegistry",
    "KernelProfile",
    "LaunchRecord",
    "spans_jsonl",
    "write_spans_jsonl",
    "prometheus_text",
    "write_prometheus",
    "chrome_trace",
    "write_chrome_trace",
]


@dataclasses.dataclass
class Observability:
    """The opt-in bundle a context carries: tracer + metrics + kernel profile.

    Any part may be ``None`` (that aspect records nothing); the
    convenience recorders below are safe to call either way, so
    instrumentation sites need exactly one guard: ``if obs is not None``.
    The all-``None`` default bundle is as much of a no-op as not attaching
    one at all.
    """

    tracer: Tracer | None = None
    metrics: MetricsRegistry | None = None
    kernel: KernelProfile | None = None

    @classmethod
    def enabled(cls, *, wall: bool = False) -> "Observability":
        """A fully-armed bundle (wall-clock span stamps opt-in)."""
        return cls(
            tracer=Tracer(wall=wall),
            metrics=MetricsRegistry(),
            kernel=KernelProfile(wall=wall),
        )

    # -- no-op-safe recorders ----------------------------------------------
    def span(self, name: str, t0: int, t1: int, **kwargs) -> None:
        if self.tracer is not None:
            self.tracer.span(name, t0, t1, **kwargs)

    def event(self, name: str, t: int, **kwargs) -> None:
        if self.tracer is not None:
            self.tracer.event(name, t, **kwargs)

    def inc(self, name: str, value: int = 1, **labels: str) -> None:
        if self.metrics is not None:
            self.metrics.inc(name, value, **labels)

    def gauge(self, name: str, value: int, **labels: str) -> None:
        if self.metrics is not None:
            self.metrics.gauge(name, value, **labels)

    def observe(self, name: str, value: int, **labels: str) -> None:
        if self.metrics is not None:
            self.metrics.observe(name, value, **labels)

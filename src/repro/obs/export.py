"""Exporters: JSONL span logs, Prometheus text, Chrome ``trace_event`` JSON.

Three machine-readable views of one instrumented run:

* :func:`spans_jsonl` — one sorted-key JSON object per line, in span
  emission order.  With wall-clock capture off (the tracer default) the
  bytes are fully determined by the run's exact virtual-time events, so
  two identical seeded runs export **byte-identical** logs (gated in CI).
* :func:`prometheus_text` — the classic text exposition of a
  :class:`~repro.obs.metrics.MetricsRegistry`: counters and gauges as
  ``name{labels} value`` lines, histograms as exact nearest-rank
  summaries (``quantile="0.5"/"0.95"/"0.99"`` plus ``_sum``/``_count``).
  Every value is an exact integer; series are sorted, so the snapshot is
  byte-deterministic too.
* :func:`chrome_trace` — the Chrome ``trace_event`` format (loadable in
  Perfetto / ``chrome://tracing``): one *process* per fleet shard, one
  *thread lane* per span track (one per drive, plus queue/router lanes),
  timestamps in **virtual microseconds** (``ts``/``dur`` are the exact
  virtual-time integers; the UI's microsecond unit is nominal).
"""

from __future__ import annotations

import json
import os

from .metrics import MetricsRegistry
from .trace import Span, Tracer

__all__ = [
    "spans_jsonl",
    "write_spans_jsonl",
    "prometheus_text",
    "write_prometheus",
    "chrome_trace",
    "write_chrome_trace",
]


# ---------------------------------------------------------------------------
# JSONL span log
# ---------------------------------------------------------------------------
def _span_row(s: Span) -> dict:
    row = {
        "name": s.name,
        "cat": s.cat,
        "t0": s.t0,
        "t1": s.t1,
        "track": s.track,
        "shard": s.shard,
        "seq": s.seq,
        "attrs": dict(s.attrs),
    }
    if s.wall_ns is not None:
        row["wall_ns"] = s.wall_ns
    return row


def spans_jsonl(tracer: Tracer) -> str:
    """The tracer's spans as JSONL (sorted keys, emission order)."""
    return "".join(
        json.dumps(_span_row(s), sort_keys=True, separators=(",", ":")) + "\n"
        for s in tracer.spans
    )


def write_spans_jsonl(tracer: Tracer, path: str | os.PathLike) -> int:
    """Write the JSONL span log; returns the number of spans written."""
    with open(path, "w", encoding="utf-8") as fh:
        fh.write(spans_jsonl(tracer))
    return len(tracer.spans)


# ---------------------------------------------------------------------------
# Prometheus text exposition
# ---------------------------------------------------------------------------
def _labelled(name: str, labels: tuple[tuple[str, str], ...]) -> str:
    if not labels:
        return name
    return name + "{" + ",".join(f'{k}="{v}"' for k, v in labels) + "}"


def prometheus_text(registry: MetricsRegistry) -> str:
    """Render the registry in the Prometheus text exposition format.

    Counters keep their monotonic totals, gauges their last write, and
    each histogram series becomes a summary: exact nearest-rank p50/p95/p99
    (``quantile`` label) plus ``_sum`` and ``_count``.  All integers, all
    series sorted — the output is byte-deterministic.
    """
    from ..serving.qos import int_quantile  # lazy: avoids an import cycle

    lines: list[str] = []
    typed: set[str] = set()

    def head(name: str, kind: str) -> None:
        if name not in typed:
            typed.add(name)
            lines.append(f"# TYPE {name} {kind}")

    for (name, labels), value in sorted(registry._counters.items()):
        head(name, "counter")
        lines.append(f"{_labelled(name, labels)} {value}")
    for (name, labels), value in sorted(registry._gauges.items()):
        head(name, "gauge")
        lines.append(f"{_labelled(name, labels)} {value}")
    for (name, labels), values in sorted(registry._hists.items()):
        head(name, "summary")
        for q_label, num, den in (("0.5", 1, 2), ("0.95", 95, 100), ("0.99", 99, 100)):
            q_labels = labels + (("quantile", q_label),)
            lines.append(f"{_labelled(name, q_labels)} {int_quantile(values, num, den)}")
        lines.append(f"{_labelled(name + '_sum', labels)} {sum(values)}")
        lines.append(f"{_labelled(name + '_count', labels)} {len(values)}")
    return "\n".join(lines) + ("\n" if lines else "")


def write_prometheus(registry: MetricsRegistry, path: str | os.PathLike) -> None:
    with open(path, "w", encoding="utf-8") as fh:
        fh.write(prometheus_text(registry))


# ---------------------------------------------------------------------------
# Chrome trace_event JSON (Perfetto / chrome://tracing)
# ---------------------------------------------------------------------------
def chrome_trace(tracer: Tracer) -> dict:
    """The tracer's spans in Chrome ``trace_event`` form.

    One *process* (``pid``) per shard, one *thread* (``tid``) per distinct
    span track within a shard — so a drive-pool run renders one lane per
    drive.  Complete spans emit ``ph: "X"`` with ``ts``/``dur`` in virtual
    microseconds; instants emit thread-scoped ``ph: "i"`` marks.  Metadata
    records name every process/lane, and all ordering is deterministic.
    """
    tracks: dict[int, list[str]] = {}
    for s in tracer.spans:
        names = tracks.setdefault(s.shard, [])
        if s.track not in names:
            names.append(s.track)
    for names in tracks.values():
        names.sort()

    events: list[dict] = []
    for shard in sorted(tracks):
        events.append(
            {
                "ph": "M",
                "pid": shard,
                "tid": 0,
                "name": "process_name",
                "args": {"name": f"shard{shard}"},
            }
        )
        for tid, track in enumerate(tracks[shard]):
            events.append(
                {
                    "ph": "M",
                    "pid": shard,
                    "tid": tid,
                    "name": "thread_name",
                    "args": {"name": track},
                }
            )
    for s in tracer.spans:
        tid = tracks[s.shard].index(s.track)
        ev = {
            "name": s.name,
            "cat": s.cat,
            "pid": s.shard,
            "tid": tid,
            "ts": s.t0,
            "args": dict(s.attrs),
        }
        if s.instant:
            ev["ph"] = "i"
            ev["s"] = "t"
        else:
            ev["ph"] = "X"
            ev["dur"] = s.duration
        events.append(ev)
    return {
        "traceEvents": events,
        "displayTimeUnit": "ms",
        "otherData": {"clock": "virtual-microseconds"},
    }


def write_chrome_trace(tracer: Tracer, path: str | os.PathLike) -> None:
    with open(path, "w", encoding="utf-8") as fh:
        json.dump(chrome_trace(tracer), fh, sort_keys=True, separators=(",", ":"))
        fh.write("\n")

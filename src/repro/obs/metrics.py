"""Exact-integer metrics: counters, gauges, and nearest-rank histograms.

Everything a :class:`MetricsRegistry` holds is an exact Python integer on
the serving stack's virtual-time scale (sojourns, cells, backoff charges,
queue depths) — never a float — so metric values can be asserted with
``==`` against :class:`~repro.serving.sim.ServiceReport` /
:class:`~repro.serving.qos.SLOReport` fields.  Histogram quantiles reuse
:func:`repro.serving.qos.int_quantile` (exact nearest-rank, no floats),
so a scraped ``p99`` equals the SLO report's ``p99_sojourn`` bit for bit.

Metrics are keyed by ``(name, sorted label items)``; the rendered form
(``name{k="v",...}``) matches the Prometheus text exposition the exporter
emits, and every iteration order is sorted, so snapshots are
byte-deterministic.
"""

from __future__ import annotations

from typing import Iterator

__all__ = ["MetricsRegistry", "metric_key"]

_Key = tuple[str, tuple[tuple[str, str], ...]]


def metric_key(name: str, labels: dict[str, str]) -> _Key:
    """Canonical registry key: name + label items sorted by label name."""
    return (name, tuple(sorted((str(k), str(v)) for k, v in labels.items())))


def _render(key: _Key) -> str:
    name, labels = key
    if not labels:
        return name
    inner = ",".join(f'{k}="{v}"' for k, v in labels)
    return f"{name}{{{inner}}}"


def _check_int(name: str, value) -> int:
    if isinstance(value, bool) or not isinstance(value, int):
        raise TypeError(
            f"metric {name!r} takes exact integers, got {value!r} "
            f"({type(value).__name__}) — convert wall times to integer "
            f"microseconds/nanoseconds before recording"
        )
    return value


class MetricsRegistry:
    """Counters / gauges / exact-int histograms behind one scrape surface.

    * ``inc(name, value=1, **labels)`` — monotonic counter (value >= 0);
    * ``gauge(name, value, **labels)`` — last-write-wins point value;
    * ``observe(name, value, **labels)`` — histogram sample (all samples
      retained, so any nearest-rank quantile is exact).

    Readbacks: :meth:`counter`, :meth:`gauge_value`, :meth:`samples`,
    :meth:`quantile`, and the deterministic :meth:`snapshot`.
    """

    def __init__(self) -> None:
        self._counters: dict[_Key, int] = {}
        self._gauges: dict[_Key, int] = {}
        self._hists: dict[_Key, list[int]] = {}

    # -- recording ----------------------------------------------------------
    def inc(self, name: str, value: int = 1, **labels: str) -> None:
        value = _check_int(name, value)
        if value < 0:
            raise ValueError(f"counter {name!r} cannot decrease (got {value})")
        key = metric_key(name, labels)
        self._counters[key] = self._counters.get(key, 0) + value

    def gauge(self, name: str, value: int, **labels: str) -> None:
        self._gauges[metric_key(name, labels)] = _check_int(name, value)

    def observe(self, name: str, value: int, **labels: str) -> None:
        self._hists.setdefault(metric_key(name, labels), []).append(
            _check_int(name, value)
        )

    # -- readback -----------------------------------------------------------
    def counter(self, name: str, **labels: str) -> int:
        return self._counters.get(metric_key(name, labels), 0)

    def gauge_value(self, name: str, **labels: str) -> int | None:
        return self._gauges.get(metric_key(name, labels))

    def samples(self, name: str, **labels: str) -> list[int]:
        return list(self._hists.get(metric_key(name, labels), ()))

    def quantile(self, name: str, num: int, den: int, **labels: str) -> int:
        """Exact nearest-rank ``num/den`` quantile of a histogram (0 if empty)."""
        from ..serving.qos import int_quantile  # lazy: avoids an import cycle

        return int_quantile(self._hists.get(metric_key(name, labels), ()), num, den)

    def counters_named(self, name: str) -> Iterator[tuple[_Key, int]]:
        """All counter series sharing ``name`` (sorted by labels)."""
        for key in sorted(self._counters):
            if key[0] == name:
                yield key, self._counters[key]

    def __len__(self) -> int:
        return len(self._counters) + len(self._gauges) + len(self._hists)

    # -- snapshots ----------------------------------------------------------
    def snapshot(self) -> dict:
        """Plain nested dict of everything, deterministically ordered.

        Histograms summarise to exact ``count``/``sum``/``min``/``max`` and
        nearest-rank p50/p95/p99 (the raw samples stay queryable via
        :meth:`samples`).
        """
        from ..serving.qos import int_quantile  # lazy: avoids an import cycle

        hists = {}
        for key in sorted(self._hists):
            vs = self._hists[key]
            hists[_render(key)] = {
                "count": len(vs),
                "sum": sum(vs),
                "min": min(vs) if vs else 0,
                "max": max(vs) if vs else 0,
                "p50": int_quantile(vs, 1, 2),
                "p95": int_quantile(vs, 95, 100),
                "p99": int_quantile(vs, 99, 100),
            }
        return {
            "counters": {_render(k): self._counters[k] for k in sorted(self._counters)},
            "gauges": {_render(k): self._gauges[k] for k in sorted(self._gauges)},
            "histograms": hists,
        }

    def render_key(self, key: _Key) -> str:
        return _render(key)

"""Pluggable solve-memo backends: the ``CacheBackend`` protocol.

``ExecutionContext.cache`` used to be hard-wired to
:class:`~repro.core.solver.SolveCache`; it now accepts anything implementing
:class:`CacheBackend` — the structural protocol below.  Two implementations
ship:

* :class:`~repro.core.solver.SolveCache` — in-process bounded LRU (the
  default; unchanged semantics);
* :class:`JsonlCacheBackend` — the same LRU plus an append-only JSONL
  journal on disk, so a restarted serving fleet rewarms its memo from prior
  runs instead of re-solving every cartridge from scratch.

Every backend memoises *exact* results keyed by the canonicalized request
multiset plus the result-affecting execution fingerprint (see the
:mod:`repro.core.solver` docstring for the key layout), so swapping
backends — or bounding one below the working set — can change wall time but
never a schedule.

Warm states (:class:`~repro.core.warm.WarmState`) ride alongside via
``get_warm``/``put_warm``.  They are advisory accelerators, not results:
losing one costs extra DP cell evaluations on the next solve, never
correctness, and they hold live table references — so the JSONL backend
journals only the solve memo.  A restarted fleet rewarms through the
persisted *solves* (a memo hit does zero DP work, which beats any warm
start), and rebuilds warm states on its first post-restart miss per
cartridge.

JSONL journal format: one object per line, ``{"k": [...], "cost": int,
"det": [[c, b], ...]}`` with byte-valued key fields hex-encoded.  Appends
are flushed per put; loading replays the journal in order (later lines win)
into the LRU, and :meth:`JsonlCacheBackend.compact` rewrites the file to
the live entries when restarts have piled up superseded lines.
"""

from __future__ import annotations

import json
import os
from typing import Protocol, runtime_checkable

from .instance import Instance
from .solver import SolveCache, SolveResult

__all__ = ["CacheBackend", "CacheLockedError", "JsonlCacheBackend"]


class CacheLockedError(RuntimeError):
    """Another live writer already owns this cache journal path.

    Two concurrent appenders would interleave half-lines and tear the
    journal, so :class:`JsonlCacheBackend` takes a sidecar lockfile on
    construction and refuses a second writer — in this process (a backend
    not yet :meth:`~JsonlCacheBackend.close`\\ d) or in another live one.  A
    lockfile left behind by a dead process (stale pid) is taken over
    silently.
    """

    def __init__(self, path: str, pid: int):
        self.path = path
        self.pid = pid
        super().__init__(
            f"cache journal {path!r} is already open for writing by live "
            f"process {pid}; close() the other backend first"
        )


def _pid_alive(pid: int) -> bool:
    """Best-effort liveness probe (signal 0; EPERM still means alive)."""
    try:
        os.kill(pid, 0)
    except ProcessLookupError:
        return False
    except (PermissionError, OSError):
        return True
    return True


#: journal paths (absolute) held open for writing by this process; guards
#: the same-process two-writer case the pid probe cannot distinguish.
_OPEN_JOURNALS: set[str] = set()


@runtime_checkable
class CacheBackend(Protocol):
    """Structural protocol every solve-memo backend implements.

    ``numeric_policy``/``cand_tile`` default to the
    :data:`~repro.core.context.DEFAULT_CONTEXT` values so pre-protocol
    call sites (``cache.get(inst, policy, backend)``) keep working.
    """

    def get(
        self,
        inst: Instance,
        policy: str,
        backend: str,
        numeric_policy: str = "strict",
        cand_tile: int | None = None,
    ) -> SolveResult | None:
        """The memoised result for this key, or ``None`` (counts a miss)."""

    def put(
        self,
        inst: Instance,
        policy: str,
        backend: str,
        res: SolveResult,
        numeric_policy: str = "strict",
        cand_tile: int | None = None,
    ) -> None:
        """Memoise ``res`` under this key (evicting LRU entries if bounded)."""

    def get_warm(self, key: tuple):
        """The stored warm state for ``key``, or ``None`` (advisory)."""

    def put_warm(self, key: tuple, state) -> None:
        """Store an advisory warm state under ``key``."""

    def stats(self) -> dict[str, int]:
        """At least ``hits``/``misses``/``entries`` counters."""

    def clear(self) -> None:
        """Drop every entry and reset the counters."""

    def __len__(self) -> int:
        """Number of memoised solve entries."""


class JsonlCacheBackend(SolveCache):
    """:class:`SolveCache` journaled to an append-only JSONL file.

    Construction replays an existing journal into the in-memory LRU
    (most-recent line wins), so a serving fleet restarted against the same
    path starts with its previous memo hot.  Every :meth:`put` appends one
    line and flushes — crash-safe up to the last completed write; a torn
    final line is skipped on load.  Entries evicted from the bounded LRU
    stay in the journal (append-only) and revive on the next restart;
    :meth:`compact` rewrites the file down to the currently-live entries.
    """

    def __init__(self, path: str | os.PathLike, maxsize: int = 4096,
                 warm_maxsize: int = 512):
        super().__init__(maxsize=maxsize, warm_maxsize=warm_maxsize)
        self.path = os.fspath(path)
        self._locked = False
        self._acquire_lock()
        self.loaded = 0
        if os.path.exists(self.path):
            with open(self.path, encoding="utf-8") as fh:
                for line in fh:
                    line = line.strip()
                    if not line:
                        continue
                    try:
                        row = json.loads(line)
                        key = self._decode_key(row["k"])
                        entry = (
                            int(row["cost"]),
                            tuple((int(c), int(b)) for c, b in row["det"]),
                        )
                    except (ValueError, KeyError, TypeError):
                        continue  # torn/foreign line: skip, stay usable
                    self._store[key] = entry
                    self._store.move_to_end(key)
                    self.loaded += 1
            while len(self._store) > self.maxsize:
                self._store.popitem(last=False)
        self._fh = open(self.path, "a", encoding="utf-8")

    # -- key <-> JSON (bytes fields hex-encoded) ------------------------------
    @staticmethod
    def _encode_key(key: tuple) -> list:
        return [v.hex() if isinstance(v, bytes) else v for v in key]

    @staticmethod
    def _decode_key(fields: list) -> tuple:
        # positional layout from SolveCache.key: the last three fields are
        # the hex-encoded left/right/mult array bytes
        head = [tuple(v) if isinstance(v, list) else v for v in fields[:-3]]
        return tuple(head) + tuple(bytes.fromhex(v) for v in fields[-3:])

    def put(
        self,
        inst: Instance,
        policy: str,
        backend: str,
        res: SolveResult,
        numeric_policy: str = "strict",
        cand_tile: int | None = None,
    ) -> None:
        super().put(inst, policy, backend, res, numeric_policy, cand_tile)
        key = self.key(inst, policy, backend, numeric_policy, cand_tile)
        row = {
            "k": self._encode_key(key),
            "cost": res.cost,
            "det": [[int(c), int(b)] for c, b in res.detours],
        }
        self._fh.write(json.dumps(row) + "\n")
        self._fh.flush()

    def compact(self) -> None:
        """Rewrite the journal to the live LRU entries (oldest first).

        Crash-safe: the replacement journal is staged in a temp file that is
        flushed and fsynced *before* the atomic ``os.replace``, so a process
        killed at any point leaves either the old journal or the new one on
        disk — never a torn mix.  The append handle is reopened in a
        ``finally`` block, so a failure mid-stage leaves the backend usable
        (and the old journal intact).
        """
        self._fh.close()
        tmp = self.path + ".tmp"
        try:
            with open(tmp, "w", encoding="utf-8") as fh:
                for key, (cost, det) in self._store.items():
                    fh.write(json.dumps({
                        "k": self._encode_key(key),
                        "cost": cost,
                        "det": [list(d) for d in det],
                    }) + "\n")
                fh.flush()
                os.fsync(fh.fileno())
            os.replace(tmp, self.path)
        finally:
            self._fh = open(self.path, "a", encoding="utf-8")

    def clear(self) -> None:
        super().clear()
        self._fh.close()
        open(self.path, "w", encoding="utf-8").close()
        self._fh = open(self.path, "a", encoding="utf-8")

    def close(self) -> None:
        self._fh.close()
        self._release_lock()

    def stats(self) -> dict[str, int]:
        return {**super().stats(), "loaded": self.loaded}

    # -- single-writer lockfile ------------------------------------------------
    # Shards of a serving fleet may share one persistent memo *object*, but
    # two independent appenders on one journal file would interleave torn
    # lines.  The lock is a sidecar ``<path>.lock`` holding the writer's pid:
    # construction refuses when the pid is a live foreign process or the path
    # is already open in this process; a dead pid (or corrupt lockfile) is
    # stale and taken over.
    @property
    def _lock_path(self) -> str:
        return self.path + ".lock"

    def _acquire_lock(self) -> None:
        key = os.path.abspath(self.path)
        if key in _OPEN_JOURNALS:
            raise CacheLockedError(self.path, os.getpid())
        if os.path.exists(self._lock_path):
            try:
                with open(self._lock_path, encoding="utf-8") as fh:
                    pid = int(fh.read().strip())
            except (ValueError, OSError):
                pid = None  # corrupt/unreadable lockfile: stale
            if pid is not None and pid != os.getpid() and _pid_alive(pid):
                raise CacheLockedError(self.path, pid)
            # stale: dead owner, corrupt file, or a leaked same-process
            # handle that was never close()d (not registered above)
        with open(self._lock_path, "w", encoding="utf-8") as fh:
            fh.write(f"{os.getpid()}\n")
            fh.flush()
        _OPEN_JOURNALS.add(key)
        self._locked = True

    def _release_lock(self) -> None:
        if not self._locked:
            return
        self._locked = False
        _OPEN_JOURNALS.discard(os.path.abspath(self.path))
        try:
            os.remove(self._lock_path)
        except OSError:
            pass

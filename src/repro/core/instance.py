"""LTSP problem instances.

Model (paper §3): a linear tape of length ``m`` holds ``n_f`` disjoint files
read left-to-right.  A subset of ``n_req`` files is requested, file ``f`` with
multiplicity ``x(f) >= 1`` (``n`` total requests).  The head starts at the
right end of the tape, moves at unit speed, and pays a penalty ``U`` per
U-turn.  A request on ``f`` is served the first time ``f`` has been traversed
left-to-right.  Objective: minimise the sum of service times.

All coordinates are integers so every algorithm in :mod:`repro.core` is exact
(int64 / Python ints, no float rounding).
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

__all__ = ["Instance", "make_instance", "virtual_lb"]


@dataclasses.dataclass(frozen=True)
class Instance:
    """An LTSP instance restricted to the requested files.

    Only requested files matter for scheduling decisions; unrequested files
    only contribute dead space between requested ones, which is captured by
    the ``left``/``right`` coordinates.  We therefore store one entry per
    *requested* file, left-to-right.

    Attributes
    ----------
    left:   ``left[i]``  = position of the left edge of requested file ``i``.
    right:  ``right[i]`` = position of the right edge (= left + size).
    mult:   ``mult[i]``  = number of requests x(f_i)  (>= 1).
    m:      total tape length (head starts at position ``m``).
    u_turn: penalty U added per U-turn of the head.
    """

    left: np.ndarray  # int64 [R]
    right: np.ndarray  # int64 [R]
    mult: np.ndarray  # int64 [R]
    m: int
    u_turn: int

    # ---- derived quantities -------------------------------------------------
    @property
    def n_req(self) -> int:
        """Number of distinct requested files (R)."""
        return int(self.left.shape[0])

    @property
    def n(self) -> int:
        """Total number of requests (with multiplicity)."""
        return int(self.mult.sum())

    @property
    def size(self) -> np.ndarray:
        return self.right - self.left

    def n_left(self) -> np.ndarray:
        """``n_left[i]`` = number of requests on files strictly left of i."""
        c = np.zeros(self.n_req, dtype=np.int64)
        c[1:] = np.cumsum(self.mult)[:-1]
        return c

    def validate(self) -> None:
        assert self.left.dtype == np.int64 and self.right.dtype == np.int64
        assert self.n_req >= 1
        assert (self.mult >= 1).all(), "every requested file needs >= 1 request"
        assert (self.right > self.left).all(), "files have positive size"
        # disjoint, sorted left-to-right
        assert (self.left[1:] >= self.right[:-1]).all(), "files must be disjoint/sorted"
        assert self.right[-1] <= self.m, "files must fit on the tape"
        assert self.left[0] >= 0
        assert self.u_turn >= 0


def make_instance(
    left: Sequence[int],
    size: Sequence[int],
    mult: Sequence[int],
    m: int | None = None,
    u_turn: int = 0,
) -> Instance:
    """Build and validate an :class:`Instance` from plain sequences."""
    left_a = np.asarray(left, dtype=np.int64)
    size_a = np.asarray(size, dtype=np.int64)
    mult_a = np.asarray(mult, dtype=np.int64)
    order = np.argsort(left_a, kind="stable")
    left_a, size_a, mult_a = left_a[order], size_a[order], mult_a[order]
    right_a = left_a + size_a
    if m is None:
        m = int(right_a[-1])
    inst = Instance(left=left_a, right=right_a, mult=mult_a, m=int(m), u_turn=int(u_turn))
    inst.validate()
    return inst


def virtual_lb(inst: Instance) -> int:
    """Paper's *VirtualLB*: each request served by its own virtual head.

    ``VirtualLB = sum_f x(f) * (m - l(f) + s(f) + U)``: the head travels from
    the right end (position m) to ``l(f)`` (one U-turn), then reads ``f``.
    """
    # Python-int accumulation: exact for real tape coordinates (~2e13) times
    # large multiplicities, where int64 products could overflow.
    total = 0
    for li, ri, xi in zip(inst.left.tolist(), inst.right.tolist(), inst.mult.tolist()):
        total += xi * (inst.m - li + (ri - li) + inst.u_turn)
    return total

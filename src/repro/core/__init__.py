"""LTSP core: the paper's exact DP algorithm, heuristics, and evaluators."""

from .instance import Instance, make_instance, virtual_lb
from .schedule import evaluate_detours, service_times, no_detour_cost
from .dp import dp_schedule, dp_value, logdp_schedule, simpledp_schedule, logdp_span
from .heuristics import no_detour, gs, fgs, nfgs, lognfgs

ALGORITHMS = {
    "nodetour": lambda inst: no_detour(inst),
    "gs": lambda inst: gs(inst),
    "fgs": lambda inst: fgs(inst),
    "nfgs": lambda inst: nfgs(inst),
    "lognfgs5": lambda inst: lognfgs(inst, lam=5.0),
    "logdp1": lambda inst: logdp_schedule(inst, lam=1.0)[1],
    "logdp5": lambda inst: logdp_schedule(inst, lam=5.0)[1],
    "simpledp": lambda inst: simpledp_schedule(inst)[1],
    "dp": lambda inst: dp_schedule(inst)[1],
}

__all__ = [
    "Instance",
    "make_instance",
    "virtual_lb",
    "evaluate_detours",
    "service_times",
    "no_detour_cost",
    "dp_schedule",
    "dp_value",
    "logdp_schedule",
    "simpledp_schedule",
    "logdp_span",
    "no_detour",
    "gs",
    "fgs",
    "nfgs",
    "lognfgs",
    "ALGORITHMS",
]

"""LTSP core: the paper's exact DP algorithm, heuristics, and evaluators.

Scheduling dispatch goes through the solver engine (:mod:`.solver`): pick a
*policy* (algorithm) and an :class:`ExecutionContext` (backend, solve memo,
bucketing/numeric options — see :mod:`.context`) via
:func:`solve`/:func:`solve_batch`, or register new policies with
:func:`repro.core.solver.register_solver`.  The legacy ``ALGORITHMS`` mapping
is a thin read-only view over the registry; pre-context ``backend=``/
``cache=`` keywords survive as warning-emitting deprecation shims.
"""

from .context import (
    DEFAULT_BUDGET,
    DEFAULT_CONTEXT,
    NUMERIC_POLICIES,
    ComputeBudget,
    ExecutionContext,
    FleetOptions,
    resolve_context,
)
from .instance import Instance, make_instance, virtual_lb
from .schedule import (
    evaluate_detours,
    lower_bound_gap,
    no_detour_cost,
    schedule_makespan,
    service_times,
)
from .dp import (
    dp_schedule,
    dp_schedule_warm,
    dp_value,
    logdp_schedule,
    simpledp_schedule,
    logdp_span,
)
from .heuristics import no_detour, gs, fgs, nfgs, lognfgs
from .solver import (
    ALGORITHMS,
    BACKENDS,
    DEFAULT_LADDER,
    CostModelSelector,
    DepthThresholdSelector,
    FixedSelector,
    LoadView,
    SolveCache,
    SolveResult,
    Solver,
    SolverSelector,
    UnsupportedBackendError,
    get_selector,
    get_solver,
    list_selectors,
    list_solvers,
    predict_cells,
    register_selector,
    register_solver,
    solve,
    solve_batch,
    solve_batch_warm,
    solve_warm,
)
from .cache import CacheBackend, CacheLockedError, JsonlCacheBackend
from .warm import WarmState, WarmStats

__all__ = [
    "ExecutionContext",
    "DEFAULT_CONTEXT",
    "NUMERIC_POLICIES",
    "ComputeBudget",
    "DEFAULT_BUDGET",
    "FleetOptions",
    "resolve_context",
    "Instance",
    "make_instance",
    "virtual_lb",
    "evaluate_detours",
    "service_times",
    "no_detour_cost",
    "schedule_makespan",
    "lower_bound_gap",
    "dp_schedule",
    "dp_schedule_warm",
    "dp_value",
    "logdp_schedule",
    "simpledp_schedule",
    "logdp_span",
    "no_detour",
    "gs",
    "fgs",
    "nfgs",
    "lognfgs",
    "BACKENDS",
    "SolveCache",
    "SolveResult",
    "Solver",
    "UnsupportedBackendError",
    "register_solver",
    "get_solver",
    "list_solvers",
    "solve",
    "solve_batch",
    "solve_warm",
    "solve_batch_warm",
    "CacheBackend",
    "CacheLockedError",
    "JsonlCacheBackend",
    "WarmState",
    "WarmStats",
    "ALGORITHMS",
    "DEFAULT_LADDER",
    "LoadView",
    "SolverSelector",
    "predict_cells",
    "FixedSelector",
    "DepthThresholdSelector",
    "CostModelSelector",
    "register_selector",
    "get_selector",
    "list_selectors",
]

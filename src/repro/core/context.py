"""``ExecutionContext``: one immutable object for *how* a solve executes.

The scheduling API used to thread ``backend=str`` and ``cache=SolveCache``
positionally through every layer (solver engine → tape library → serving
queue → checkpoint restore → benchmarks → launchers), and each new execution
option (bucketing, numeric policy, …) meant another keyword replicated across
a dozen signatures.  :class:`ExecutionContext` bundles all of it:

* ``backend`` — execution engine: ``"python"`` (exact CPU, default),
  ``"pallas"`` (compiled TPU wavefront), ``"pallas-interpret"`` (same kernel
  through the Pallas interpreter — the validated device path in this repo);
* ``cache`` — an optional :class:`~repro.core.cache.CacheBackend` memoising
  repeated solves of identical request multisets (and carrying advisory
  :class:`~repro.core.warm.WarmState` objects for warm-started re-solves);
  :class:`~repro.core.solver.SolveCache` is the in-process LRU default,
  :class:`~repro.core.cache.JsonlCacheBackend` persists across restarts;
* ``bucketed`` — whether device batches go through the size-bucketed launch
  planner (``False`` reproduces the seed's single maximally-padded launch,
  kept for A/B benchmarking);
* ``cand_tile`` — candidate-chunk height override for the banded wavefront
  scan (``None`` = kernel default);
* ``numeric_policy`` — what to do when an instance fails the int32 device
  magnitude guard *after* gcd/shift rescaling: ``"strict"`` raises (default),
  ``"f64"`` falls back to an exact float64 interpret-mode table for just the
  failing instances (exact while every table value stays below 2**53);
* ``budget`` — an optional :class:`ComputeBudget` making solver compute a
  *priced* resource for the serving loop: how much virtual time one DP cell
  costs (so dispatches charge their solve work into the timeline), the
  per-tick cell budget a load-adaptive
  :class:`~repro.core.solver.SolverSelector` plans against, the queue-depth
  thresholds of the ``depth-threshold`` selector, and the hysteresis tick
  count that keeps per-tick policy choices from flapping.  ``None``
  (default) prices nothing and charges nothing — every pre-budget timeline
  is reproduced bit for bit;
* ``obs`` — an optional :class:`~repro.obs.Observability` bundle (tracer +
  metrics registry + kernel profile, see :mod:`repro.obs`): instrumentation
  hooks throughout the solver, cache, drive pool, serving loop, and fleet
  record into it.  ``None`` (default) records nothing, and every hook hands
  over already-computed exact integers, so instrumented and uninstrumented
  runs are bit-identical.

Contexts are frozen: derive variants with :meth:`ExecutionContext.replace`::

    ctx = ExecutionContext(backend="pallas-interpret", cache=SolveCache())
    res = solve(inst, policy="dp", context=ctx)
    strict = ctx.replace(numeric_policy="strict")

Every public scheduling entry point (``solve``/``solve_batch``, ``Solver``
implementations, ``TapeLibrary``, ``schedule_reads``, ``plan_restore``,
``OnlineTapeServer``/``serve_trace``) accepts ``context=``.  The pre-context
``backend=``/``cache=`` keywords still work everywhere but are deprecation
shims: they emit :class:`DeprecationWarning` and forward into a context via
:func:`resolve_context`, bit-identical to the old paths.
"""

from __future__ import annotations

import dataclasses
import warnings
from typing import TYPE_CHECKING

if TYPE_CHECKING:  # pragma: no cover - import cycle guard (solver imports us)
    from ..obs import Observability
    from .cache import CacheBackend

__all__ = [
    "BACKENDS",
    "DEFAULT_BACKEND",
    "NUMERIC_POLICIES",
    "ComputeBudget",
    "DEFAULT_BUDGET",
    "FleetOptions",
    "ExecutionContext",
    "DEFAULT_CONTEXT",
    "resolve_context",
]

BACKENDS = ("python", "pallas", "pallas-interpret")
DEFAULT_BACKEND = "python"

#: int32-guard-failure handling: raise, or fall back to exact f64 interpret.
NUMERIC_POLICIES = ("strict", "f64")


@dataclasses.dataclass(frozen=True)
class ComputeBudget:
    """Solver-compute accounting for the serving loop (exact virtual time).

    The paper's exact DP costs minutes at realistic strata, so under load
    the solver's own runtime is a service-time component.  A budget makes
    that cost explicit in the one unit the rest of the repo asserts on —
    exact integers of virtual time — via the DP *cell* counts every solve
    already reports (:class:`~repro.core.warm.WarmStats`):

    * ``solve_time_num`` / ``solve_time_den`` — virtual time charged per
      evaluated DP cell, as an exact rational: a dispatch that evaluated
      ``c`` cells delays its service start by ``c * num // den``.  The
      default ``0/1`` charges nothing (timelines bit-identical to a
      budget-less run).
    * ``per_tick`` — DP-cell budget one dispatch tick may spend; the
      ``cost-model`` :class:`~repro.core.solver.SolverSelector` picks the
      most exact policy whose predicted cell count fits.  ``None`` leaves
      the cost model unconstrained (it then always picks its most exact
      tier).
    * ``shallow_depth`` / ``deep_depth`` — queue-depth thresholds for the
      ``depth-threshold`` selector: exact DP at or below ``shallow_depth``,
      the cheapest tier at or above ``deep_depth``, the middle tier between.
    * ``hysteresis`` — how many *consecutive* dispatch ticks a selector
      must indicate a different policy before the serving loop switches to
      it (1 = switch immediately); keeps the per-tick choice from flapping
      when the queue depth oscillates around a threshold.
    """

    solve_time_num: int = 0
    solve_time_den: int = 1
    per_tick: int | None = None
    shallow_depth: int = 4
    deep_depth: int = 16
    hysteresis: int = 2

    def __post_init__(self) -> None:
        if self.solve_time_num < 0:
            raise ValueError("solve_time_num must be >= 0")
        if self.solve_time_den < 1:
            raise ValueError("solve_time_den must be >= 1")
        if self.per_tick is not None and self.per_tick < 1:
            raise ValueError("per_tick must be >= 1 (or None for unlimited)")
        if not (1 <= self.shallow_depth <= self.deep_depth):
            raise ValueError(
                "need 1 <= shallow_depth <= deep_depth "
                f"(got {self.shallow_depth} / {self.deep_depth})"
            )
        if self.hysteresis < 1:
            raise ValueError("hysteresis must be >= 1 tick")

    def charge(self, cells: int) -> int:
        """Virtual time charged for ``cells`` evaluated DP cells (exact)."""
        return cells * self.solve_time_num // self.solve_time_den

    def replace(self, **changes) -> "ComputeBudget":
        """A copy with the given fields changed (budgets are immutable)."""
        return dataclasses.replace(self, **changes)


#: The default budget selectors fall back on when the context carries none:
#: free compute (no solve-time charge), unlimited per-tick cells, and the
#: stock depth thresholds / 2-tick hysteresis.
DEFAULT_BUDGET = ComputeBudget()


@dataclasses.dataclass(frozen=True)
class FleetOptions:
    """Federation shape for the fleet serving layer (:mod:`repro.fleet`).

    Rides :class:`ExecutionContext` so launchers and helpers can thread the
    federation configuration through the same object that already carries
    backend/cache/budget choices: ``n_shards`` per-library shards, the
    registered :class:`~repro.fleet.PlacementStrategy` name routing each
    request, and the replication factor seeded fleet archives store each
    logical file at.  The defaults describe the degenerate one-shard
    federation whose timeline is pinned bit-identical to a standalone
    :class:`~repro.serving.queue.OnlineTapeServer`; a context without fleet
    options (``fleet=None``, the default) behaves identically everywhere.
    """

    n_shards: int = 1
    placement: str = "single"
    replicas: int = 1

    def __post_init__(self) -> None:
        if self.n_shards < 1:
            raise ValueError("n_shards must be >= 1")
        if not self.replicas or self.replicas < 1:
            raise ValueError("replicas must be >= 1")
        if self.replicas > self.n_shards:
            raise ValueError(
                f"replication factor {self.replicas} exceeds "
                f"n_shards={self.n_shards}"
            )
        if not self.placement or not isinstance(self.placement, str):
            raise ValueError("placement must be a registered strategy name")

    def replace(self, **changes) -> "FleetOptions":
        """A copy with the given fields changed (options are immutable)."""
        return dataclasses.replace(self, **changes)


@dataclasses.dataclass(frozen=True)
class ExecutionContext:
    """Immutable bundle of execution options for the scheduling API."""

    backend: str = DEFAULT_BACKEND
    cache: "CacheBackend | None" = None
    bucketed: bool = True
    cand_tile: int | None = None
    numeric_policy: str = "strict"
    budget: ComputeBudget | None = None
    fleet: FleetOptions | None = None
    obs: "Observability | None" = None

    def __post_init__(self) -> None:
        if self.backend not in BACKENDS:
            raise KeyError(
                f"unknown backend {self.backend!r}; choose from {BACKENDS}"
            )
        if self.numeric_policy not in NUMERIC_POLICIES:
            raise ValueError(
                f"unknown numeric_policy {self.numeric_policy!r}; "
                f"choose from {NUMERIC_POLICIES}"
            )
        if self.cand_tile is not None and self.cand_tile < 1:
            raise ValueError("cand_tile must be >= 1 (or None for the default)")
        if self.budget is not None and not isinstance(self.budget, ComputeBudget):
            raise TypeError(f"budget must be a ComputeBudget, got {self.budget!r}")
        if self.fleet is not None and not isinstance(self.fleet, FleetOptions):
            raise TypeError(f"fleet must be a FleetOptions, got {self.fleet!r}")
        if self.obs is not None:
            # lazy import: repro.obs pulls in serving helpers at call time,
            # and contexts are constructed during core package import
            from ..obs import Observability

            if not isinstance(self.obs, Observability):
                raise TypeError(
                    f"obs must be an Observability bundle, got {self.obs!r}"
                )

    def replace(self, **changes) -> "ExecutionContext":
        """A copy with the given fields changed (contexts are immutable)."""
        return dataclasses.replace(self, **changes)


#: The default context: python backend, no cache, bucketed, strict numerics.
DEFAULT_CONTEXT = ExecutionContext()


def resolve_context(
    context: ExecutionContext | None = None,
    *,
    backend: str | None = None,
    cache: "CacheBackend | None" = None,
    default: ExecutionContext | None = None,
    stacklevel: int = 3,
) -> ExecutionContext:
    """Merge legacy ``backend=``/``cache=`` keywords into a context.

    This is the single deprecation shim behind every migrated signature:
    ``context`` wins when given; otherwise legacy keywords (if any) emit one
    :class:`DeprecationWarning` and are folded over ``default`` (the enclosing
    object's context, or :data:`DEFAULT_CONTEXT`).  Results are bit-identical
    to the pre-context code paths — only the plumbing changed.
    """
    base = default if default is not None else DEFAULT_CONTEXT
    if context is not None:
        if backend is not None or cache is not None:
            raise TypeError(
                "pass either context= or the deprecated backend=/cache= "
                "keywords, not both"
            )
        return context
    if backend is None and cache is None:
        return base
    legacy = [k for k, v in (("backend", backend), ("cache", cache)) if v is not None]
    warnings.warn(
        f"the {'/'.join(legacy)} keyword(s) are deprecated; pass "
        f"context=ExecutionContext(...) instead (see repro.core.context)",
        DeprecationWarning,
        stacklevel=stacklevel,
    )
    changes: dict = {}
    if backend is not None:
        changes["backend"] = backend
    if cache is not None:
        changes["cache"] = cache
    return base.replace(**changes)

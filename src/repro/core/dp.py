"""The paper's exact **DP** algorithm for LTSP, plus LOGDP and SIMPLEDP.

``T[a, b, n_skip]`` (paper §4.3) is the impact, relative to *VirtualLB*, of
the head movement between the first time it reaches ``r(b)`` and the first
time it reaches ``r(b)`` again after having read ``a``, given

  1. a detour ``(a, f)`` exists for some ``f >= b``,
  2. no detour ``(f1, f2)`` with ``a < f1 < b < f2`` exists,
  3. exactly ``n_skip`` requests are skipped when the head first reaches
     ``r(b)``.

Recurrence (files are requested-file indices, ``left(b) = b-1``)::

  T[b, b, s]    = 2 s(b) (s + n_l(b))
  skip(a,b,s)   = T[a, b-1, s + x(b)] + 2 (r(b)-r(b-1)) (s + n_l(a))
                  + 2 (l(b)-r(b-1)) x(b)
  detour_c(...) = T[a, c-1, s] + T[c, b, s] + 2 (r(b)-r(c-1)) (s + n_l(a))
                  + 2 U (s + n_l(c))
  T[a, b, s]    = min(skip, min_{a < c <= b} detour_c)

and ``OPT = T[0, R-1, 0] + VirtualLB``.

Exact Python-int arithmetic over reachable cells only.  The evaluation is
**iterative**: an explicit post-order work stack expands a cell's
dependencies, then folds them once every one is memoised, so arbitrarily
large instances run without touching the interpreter recursion limit (the
seed implementation had to raise it ~10x n_req).  Cell values
and tie-breaking are bit-identical to the recursive formulation: ``skip``
wins ties, and among detours the smallest ``c`` achieving the minimum wins.

LOGDP is the same recursion with ``c`` restricted to ``b - c <= span`` where
``span = ceil(lambda * ln n_req)``; SIMPLEDP forbids intertwined detours which
collapses the first index to ``f_1`` (2-dimensional table).
"""

from __future__ import annotations

import math

from .instance import Instance, virtual_lb
from .warm import DictStore, WarmState, WarmStats, align_warm, warm_from_instance

__all__ = [
    "dp_schedule",
    "dp_schedule_warm",
    "logdp_schedule",
    "simpledp_schedule",
    "dp_value",
    "logdp_span",
]


def dp_schedule(
    inst: Instance, span: int | None = None
) -> tuple[int, list[tuple[int, int]]]:
    """Optimal LTSP schedule via the paper's DP.

    Returns ``(opt_cost, detours)`` where ``opt_cost`` includes *VirtualLB*
    and ``detours`` is the list of detours realising it (the implicit final
    global pass is not listed).  ``span`` restricts detour spans (LOGDP).
    """
    cost, detours, _, _ = dp_schedule_warm(inst, span=span)
    return cost, detours


def dp_schedule_warm(
    inst: Instance,
    span: int | None = None,
    warm: WarmState | None = None,
) -> tuple[int, list[tuple[int, int]], WarmState, WarmStats]:
    """:func:`dp_schedule` with warm-start reuse and exact work counters.

    ``warm`` is a :class:`~repro.core.warm.WarmState` from a previous solve
    of a *related* instance (same cartridge, perturbed request multiset).
    Cells covered by an aligned segment (see :mod:`repro.core.warm`) are
    installed from the warm store instead of being folded; everything else —
    including the whole table when no alignment exists — evaluates exactly
    as the cold DP does, so ``(cost, detours)`` is bit-identical to
    :func:`dp_schedule` by construction *and* asserted differentially in the
    tests.  Returns ``(cost, detours, new_warm, stats)`` where ``new_warm``
    wraps this solve's memo for the next tick (handed over by reference, no
    copy) and ``stats`` counts recurrence folds vs. warm transfers.
    """
    R = inst.n_req
    left = inst.left.tolist()
    right = inst.right.tolist()
    x = inst.mult.tolist()
    nl = inst.n_left().tolist()
    U = inst.u_turn
    size = [r - l for l, r in zip(left, right)]

    memo: dict[tuple[int, int, int], int] = {}
    choice: dict[tuple[int, int, int], int] = {}  # -1 = skip, else c
    stats = WarmStats(mode="cold")
    al = align_warm(warm, inst, span)
    if al is not None:
        stats.mode = "warm"
        w_store, w_seg, w_map, w_delta, w_off = (
            warm.store, al.seg, al.map_idx, al.delta, al.off,
        )

    def base(b: int, s: int) -> int:
        return 2 * size[b] * (s + nl[b])

    def try_warm(a: int, b: int, s: int) -> bool:
        """Install ``(a, b, s)`` from the warm store if an aligned segment
        covers it (value and index-shifted choice; see repro.core.warm)."""
        sa = w_seg[a]
        if sa < 0 or sa != w_seg[b]:
            return False
        sw = s + w_delta[sa]
        if sw < 0:
            return False
        hit = w_store.lookup(w_map[a], w_map[b], sw)
        if hit is None:
            return False
        v, cw = hit
        memo[(a, b, s)] = v
        choice[(a, b, s)] = cw if cw < 0 else cw - w_off[sa]
        stats.cells_reused += 1
        return True

    def deps(a: int, b: int, s: int):
        """Non-base cells the recurrence for ``(a, b, s)`` reads."""
        out = []
        if a < b - 1:
            out.append((a, b - 1, s + x[b]))  # skip
        lo = a + 1 if span is None else max(a + 1, b - span)
        for c in range(lo, b + 1):
            if a < c - 1:
                out.append((a, c - 1, s))
            if c < b:
                out.append((c, b, s))
        return out

    def value(a: int, b: int, s: int) -> tuple[int, int]:
        """Fold the recurrence assuming every dependency is memoised."""
        t_skip = base(b - 1, s + x[b]) if a == b - 1 else memo[(a, b - 1, s + x[b])]
        best = (
            t_skip
            + 2 * (right[b] - right[b - 1]) * (s + nl[a])
            + 2 * (left[b] - right[b - 1]) * x[b]
        )
        arg = -1
        lo = a + 1 if span is None else max(a + 1, b - span)
        snla = s + nl[a]
        for c in range(lo, b + 1):
            t_left = base(a, s) if c - 1 == a else memo[(a, c - 1, s)]
            t_right = base(b, s) if c == b else memo[(c, b, s)]
            v = (
                t_left
                + t_right
                + 2 * (right[b] - right[c - 1]) * snla
                + 2 * U * (s + nl[c])
            )
            if v < best:
                best, arg = v, c
        return best, arg

    def run(cell: tuple[int, int, int]) -> None:
        """Evaluate ``cell`` (and everything it transitively needs).

        Post-order over the dependency DAG with an explicit stack: a cell is
        pushed unexpanded, re-pushed expanded together with its unresolved
        dependencies, and folded when seen expanded (all deps then memoised).
        A warm transfer at first encounter short-circuits the expansion —
        the reused value stands in for the whole subtree below it.
        """
        stack: list[tuple[int, int, int, bool]] = [(*cell, False)]
        while stack:
            a, b, s, expanded = stack.pop()
            if (a, b, s) in memo:
                continue
            if expanded:
                memo[(a, b, s)], choice[(a, b, s)] = value(a, b, s)
                stats.cells_evaluated += 1
                continue
            if al is not None and try_warm(a, b, s):
                continue
            stack.append((a, b, s, True))
            for dep in deps(a, b, s):
                if dep not in memo:
                    stack.append((*dep, False))

    root = (0, R - 1, 0)
    if R == 1:
        opt_rel = base(0, 0)
    else:
        run(root)
        opt_rel = memo[root]

    opt = opt_rel + virtual_lb(inst)

    # -- traceback: pre-order replay of the recorded choices ------------------
    # A warm-transferred cell carries its choice but not its inner structure;
    # when the optimal path descends past one, run() lazily resolves the
    # missing cell (warm store first, recurrence otherwise) — exact either
    # way, and any extra folds are counted in stats.cells_evaluated.
    detours: list[tuple[int, int]] = []
    work: list[tuple[int, int, int]] = [root]
    while work:
        a, b, s = work.pop()
        while a < b:
            c = choice.get((a, b, s))
            if c is None:
                run((a, b, s))
                c = choice[(a, b, s)]
            if c == -1:  # skip b
                s += x[b]
                b -= 1
                continue
            detours.append((c, b))
            # detour (c, b): descend into its inner structure first, then
            # continue with T[a, c-1, s] (pushed for later — preserves the
            # recursive emission order).
            work.append((a, c - 1, s))
            a = c
        # a == b: base cell, single-file handling folded into parent detour
    new_warm = warm_from_instance(inst, span, DictStore(memo, choice))
    return opt, detours, new_warm, stats


def dp_value(inst: Instance, span: int | None = None) -> int:
    """Optimal cost only (convenience)."""
    return dp_schedule(inst, span=span)[0]


def logdp_span(n_req: int, lam: float) -> int:
    """LOGDP detour-span limit: ``ceil(lambda * ln n_req)`` (>= 1)."""
    return max(1, math.ceil(lam * math.log(max(2, n_req))))


def logdp_schedule(inst: Instance, lam: float = 1.0) -> tuple[int, list[tuple[int, int]]]:
    """LOGDP(lambda): DP restricted to detours spanning <= lam*ln(n_req) files."""
    return dp_schedule(inst, span=logdp_span(inst.n_req, lam))


def simpledp_schedule(inst: Instance) -> tuple[int, list[tuple[int, int]]]:
    """SIMPLEDP: DP restricted to disjoint (non-intertwined) detours.

    The first DP index is always the leftmost requested file, so the table is
    two-dimensional, and ``detour_c`` charges the whole detour ``(c, b)``
    directly (no recursive inner structure)::

      detour_c(b,s) = T[c-1, s] + 2 (r(b)-r(c-1)) s
                      + 2 (U + r(b)-l(c)) (s + n_l(c))
                      + sum_{c < f <= b} 2 (l(f)-l(c)) x(f)

    Evaluated iteratively like :func:`dp_schedule` (explicit work stack over
    reachable ``(b, s)`` cells, exact Python ints).
    """
    R = inst.n_req
    left = inst.left.tolist()
    right = inst.right.tolist()
    x = inst.mult.tolist()
    nl = inst.n_left().tolist()
    U = inst.u_turn
    size = [r - l for l, r in zip(left, right)]

    # prefix sums for the in-detour service cost sum (Python ints: exact,
    # immune to int64 overflow on real tape coordinates ~2e13)
    X = [0]
    WL = [0]
    for li, xi in zip(left, x):
        X.append(X[-1] + xi)
        WL.append(WL[-1] + li * xi)

    def in_detour_cost(c: int, b: int) -> int:
        # sum_{c < f <= b} 2 (l(f) - l(c)) x(f)
        return 2 * ((WL[b + 1] - WL[c + 1]) - left[c] * (X[b + 1] - X[c + 1]))

    memo: dict[tuple[int, int], int] = {}
    choice: dict[tuple[int, int], int] = {}

    def base0(s: int) -> int:
        return 2 * size[0] * (s + nl[0])

    def value(b: int, s: int) -> tuple[int, int]:
        t_skip = base0(s + x[b]) if b == 1 else memo[(b - 1, s + x[b])]
        best = (
            t_skip
            + 2 * (right[b] - right[b - 1]) * s  # n_l(a=0) == 0
            + 2 * (left[b] - right[b - 1]) * x[b]
        )
        arg = -1
        for c in range(1, b + 1):
            t_left = base0(s) if c == 1 else memo[(c - 1, s)]
            v = (
                t_left
                + 2 * (right[b] - right[c - 1]) * s
                + 2 * (U + right[b] - left[c]) * (s + nl[c])
                + in_detour_cost(c, b)
            )
            if v < best:
                best, arg = v, c
        return best, arg

    if R == 1:
        opt_rel = base0(0)
    else:
        stack: list[tuple[int, int, bool]] = [(R - 1, 0, False)]
        while stack:
            b, s, expanded = stack.pop()
            if (b, s) in memo:
                continue
            if expanded:
                memo[(b, s)], choice[(b, s)] = value(b, s)
                continue
            stack.append((b, s, True))
            if b - 1 > 0 and (b - 1, s + x[b]) not in memo:
                stack.append((b - 1, s + x[b], False))
            for c in range(2, b + 1):
                if (c - 1, s) not in memo:
                    stack.append((c - 1, s, False))
        opt_rel = memo[(R - 1, 0)]

    opt = opt_rel + virtual_lb(inst)

    detours: list[tuple[int, int]] = []
    b, s = R - 1, 0
    while b > 0:
        c = choice[(b, s)]
        if c == -1:
            s += x[b]
            b -= 1
        else:
            detours.append((c, b))
            b = c - 1
    return opt, detours

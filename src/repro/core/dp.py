"""The paper's exact **DP** algorithm for LTSP, plus LOGDP and SIMPLEDP.

``T[a, b, n_skip]`` (paper §4.3) is the impact, relative to *VirtualLB*, of
the head movement between the first time it reaches ``r(b)`` and the first
time it reaches ``r(b)`` again after having read ``a``, given

  1. a detour ``(a, f)`` exists for some ``f >= b``,
  2. no detour ``(f1, f2)`` with ``a < f1 < b < f2`` exists,
  3. exactly ``n_skip`` requests are skipped when the head first reaches
     ``r(b)``.

Recurrence (files are requested-file indices, ``left(b) = b-1``)::

  T[b, b, s]    = 2 s(b) (s + n_l(b))
  skip(a,b,s)   = T[a, b-1, s + x(b)] + 2 (r(b)-r(b-1)) (s + n_l(a))
                  + 2 (l(b)-r(b-1)) x(b)
  detour_c(...) = T[a, c-1, s] + T[c, b, s] + 2 (r(b)-r(c-1)) (s + n_l(a))
                  + 2 U (s + n_l(c))
  T[a, b, s]    = min(skip, min_{a < c <= b} detour_c)

and ``OPT = T[0, R-1, 0] + VirtualLB``.

Exact Python-int arithmetic, memoised over reachable cells only.  LOGDP is
the same recursion with ``c`` restricted to ``b - c <= span`` where
``span = ceil(lambda * ln n_req)``; SIMPLEDP forbids intertwined detours which
collapses the first index to ``f_1`` (2-dimensional table).
"""

from __future__ import annotations

import math
import sys
from functools import lru_cache

import numpy as np

from .instance import Instance, virtual_lb

__all__ = ["dp_schedule", "logdp_schedule", "simpledp_schedule", "dp_value"]

_RECURSION_HEADROOM = 50_000


def _raise_recursion_limit(n_req: int) -> None:
    need = 10 * n_req + _RECURSION_HEADROOM
    if sys.getrecursionlimit() < need:
        sys.setrecursionlimit(need)


def dp_schedule(
    inst: Instance, span: int | None = None
) -> tuple[int, list[tuple[int, int]]]:
    """Optimal LTSP schedule via the paper's DP.

    Returns ``(opt_cost, detours)`` where ``opt_cost`` includes *VirtualLB*
    and ``detours`` is the list of detours realising it (the implicit final
    global pass is not listed).  ``span`` restricts detour spans (LOGDP).
    """
    R = inst.n_req
    _raise_recursion_limit(R)
    left = inst.left.tolist()
    right = inst.right.tolist()
    x = inst.mult.tolist()
    nl = inst.n_left().tolist()
    U = inst.u_turn
    size = [r - l for l, r in zip(left, right)]

    memo: dict[tuple[int, int, int], int] = {}
    choice: dict[tuple[int, int, int], int] = {}  # -1 = skip, else c

    def T(a: int, b: int, s: int) -> int:
        if a == b:
            return 2 * size[b] * (s + nl[b])
        key = (a, b, s)
        v = memo.get(key)
        if v is not None:
            return v
        # --- skip b: read it on the detour starting from a -----------------
        best = (
            T(a, b - 1, s + x[b])
            + 2 * (right[b] - right[b - 1]) * (s + nl[a])
            + 2 * (left[b] - right[b - 1]) * x[b]
        )
        arg = -1
        # --- or a detour (c, b) for some a < c <= b -------------------------
        lo = a + 1 if span is None else max(a + 1, b - span)
        snla = s + nl[a]
        for c in range(lo, b + 1):
            v = (
                T(a, c - 1, s)
                + T(c, b, s)
                + 2 * (right[b] - right[c - 1]) * snla
                + 2 * U * (s + nl[c])
            )
            if v < best:
                best, arg = v, c
        memo[key] = best
        choice[key] = arg
        return best

    opt = T(0, R - 1, 0) + virtual_lb(inst)

    detours: list[tuple[int, int]] = []

    def collect(a: int, b: int, s: int) -> None:
        while a < b:
            c = choice[(a, b, s)]
            if c == -1:  # skip b
                s += x[b]
                b -= 1
                continue
            detours.append((c, b))
            collect(c, b, s)  # structure inside the detour (c, b)
            b = c - 1  # continue with T[a, c-1, s]
        # a == b: base cell, single-file handling folded into parent detour

    collect(0, R - 1, 0)
    return opt, detours


def dp_value(inst: Instance, span: int | None = None) -> int:
    """Optimal cost only (convenience)."""
    return dp_schedule(inst, span=span)[0]


def logdp_span(n_req: int, lam: float) -> int:
    """LOGDP detour-span limit: ``ceil(lambda * ln n_req)`` (>= 1)."""
    return max(1, math.ceil(lam * math.log(max(2, n_req))))


def logdp_schedule(inst: Instance, lam: float = 1.0) -> tuple[int, list[tuple[int, int]]]:
    """LOGDP(lambda): DP restricted to detours spanning <= lam*ln(n_req) files."""
    return dp_schedule(inst, span=logdp_span(inst.n_req, lam))


def simpledp_schedule(inst: Instance) -> tuple[int, list[tuple[int, int]]]:
    """SIMPLEDP: DP restricted to disjoint (non-intertwined) detours.

    The first DP index is always the leftmost requested file, so the table is
    two-dimensional, and ``detour_c`` charges the whole detour ``(c, b)``
    directly (no recursive inner structure)::

      detour_c(b,s) = T[c-1, s] + 2 (r(b)-r(c-1)) s
                      + 2 (U + r(b)-l(c)) (s + n_l(c))
                      + sum_{c < f <= b} 2 (l(f)-l(c)) x(f)
    """
    R = inst.n_req
    _raise_recursion_limit(R)
    left = inst.left.tolist()
    right = inst.right.tolist()
    x = inst.mult.tolist()
    nl = inst.n_left().tolist()
    U = inst.u_turn
    size = [r - l for l, r in zip(left, right)]

    # prefix sums for the in-detour service cost sum (Python ints: exact,
    # immune to int64 overflow on real tape coordinates ~2e13)
    X = [0]
    WL = [0]
    for li, xi in zip(left, x):
        X.append(X[-1] + xi)
        WL.append(WL[-1] + li * xi)

    def in_detour_cost(c: int, b: int) -> int:
        # sum_{c < f <= b} 2 (l(f) - l(c)) x(f)
        return 2 * ((WL[b + 1] - WL[c + 1]) - left[c] * (X[b + 1] - X[c + 1]))

    memo: dict[tuple[int, int], int] = {}
    choice: dict[tuple[int, int], int] = {}

    def T(b: int, s: int) -> int:
        if b == 0:
            return 2 * size[0] * (s + nl[0])
        key = (b, s)
        v = memo.get(key)
        if v is not None:
            return v
        best = (
            T(b - 1, s + x[b])
            + 2 * (right[b] - right[b - 1]) * s  # n_l(a=0) == 0
            + 2 * (left[b] - right[b - 1]) * x[b]
        )
        arg = -1
        for c in range(1, b + 1):
            v = (
                T(c - 1, s)
                + 2 * (right[b] - right[c - 1]) * s
                + 2 * (U + right[b] - left[c]) * (s + nl[c])
                + in_detour_cost(c, b)
            )
            if v < best:
                best, arg = v, c
        memo[key] = best
        choice[key] = arg
        return best

    opt = T(R - 1, 0) + virtual_lb(inst)

    detours: list[tuple[int, int]] = []
    b, s = R - 1, 0
    while b > 0:
        c = choice[(b, s)]
        if c == -1:
            s += x[b]
            b -= 1
        else:
            detours.append((c, b))
            b = c - 1
    return opt, detours

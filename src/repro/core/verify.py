"""Brute-force LTSP optima for validating the DP on small instances.

Two independent oracles:

* :func:`bruteforce_trajectory` — Dijkstra over exact head trajectories.
  States are (position, direction, served-mask, last-right-turn).  Turn points
  are restricted to requested-file edges (Lemma 1 shows this is WLOG).  The
  objective accrues at rate ``pending(mask)`` per time unit, which makes the
  sum-of-service-times objective additive along edges.  This oracle does not
  assume anything about detour structure, so it also validates Lemma 1.

* :func:`bruteforce_laminar` — enumerate every strictly laminar detour family
  and score it with the trajectory simulator.  Validates the simulator and the
  detour abstraction against the trajectory oracle.

Plus the polynomial-time schedule validity checker every serving-path caller
uses: :func:`verify_schedule` structurally validates an emitted detour list
and recomputes its cost through the *independent* discrete-event replay in
:mod:`repro.serving.sim`, cross-checked against the inline evaluator in
:mod:`repro.core.schedule` — both must agree with each other (and with the
solver-claimed cost, when given) exactly, in integer arithmetic.
"""

from __future__ import annotations

import heapq
import itertools

import numpy as np

from .instance import Instance
from .schedule import evaluate_detours

__all__ = [
    "bruteforce_trajectory",
    "bruteforce_laminar",
    "laminar_families",
    "verify_schedule",
]


def verify_schedule(
    inst: Instance,
    detours: list[tuple[int, int]],
    cost: int | None = None,
    replay=None,
) -> int:
    """Validate an emitted schedule and return its independently-derived cost.

    Checks, raising ``ValueError`` on the first failure:

    1. **structure** — every detour is an integer pair ``(a, b)`` with
       ``0 <= a <= b < n_req``;
    2. **validity** — the replayed trajectory serves every requested file;
    3. **cost** — the discrete-event replay (:mod:`repro.serving.sim`), the
       inline evaluator (:func:`repro.core.schedule.evaluate_detours`), and —
       when given — the solver-claimed ``cost`` all agree exactly.

    This is the oracle every online serving path runs against each schedule
    it emits; it is polynomial (no brute force), so it scales to paper-size
    instances.  A caller that already replayed the schedule can pass its
    :class:`repro.serving.sim.Replay` as ``replay`` to avoid a second
    trajectory build; the cross-checks still run in full.
    """
    for d in detours:
        a, b = d  # unpacking failure -> malformed pair, let it raise
        if int(a) != a or int(b) != b:
            raise ValueError(f"detour {d!r} has non-integer endpoints")
        if not (0 <= a <= b < inst.n_req):
            raise ValueError(
                f"detour {d!r} out of range for n_req={inst.n_req}"
            )
    if replay is None:
        # deferred import: core must stay importable without the serving layer
        from ..serving.sim import replay_schedule

        replay = replay_schedule(inst, detours)  # raises if a file goes unserved
    inline = evaluate_detours(inst, detours)
    if replay.cost != inline:
        raise ValueError(
            f"replay cost {replay.cost} != inline evaluator cost {inline} "
            f"(simulator/evaluator divergence — this is a bug)"
        )
    if cost is not None and replay.cost != cost:
        raise ValueError(
            f"claimed cost {cost} != independently recomputed cost {replay.cost}"
        )
    return replay.cost


def bruteforce_trajectory(inst: Instance, max_states: int = 2_000_000) -> int:
    """Exact optimum by Dijkstra over head trajectories (small R only)."""
    R = inst.n_req
    if R > 12:
        raise ValueError("trajectory brute force is exponential in n_req")
    left = inst.left.tolist()
    right = inst.right.tolist()
    x = inst.mult.tolist()
    U = inst.u_turn

    # candidate positions: file edges + start position m
    points = sorted({*left, *right, inst.m})
    pidx = {p: i for i, p in enumerate(points)}
    P = len(points)
    full = (1 << R) - 1

    def pending(mask: int) -> int:
        return sum(x[i] for i in range(R) if not (mask >> i) & 1)

    pend = [pending(m_) for m_ in range(1 << R)]

    # state: (pos index, dir(0=left,1=right), mask, q = pos index of last
    # right-turn; only meaningful while dir == 1, else canonicalised to pos)
    start = (pidx[inst.m], 0, 0, pidx[inst.m])
    dist: dict[tuple[int, int, int, int], int] = {start: 0}
    heap: list[tuple[int, tuple[int, int, int, int]]] = [(0, start)]
    visited = set()

    while heap:
        d, st = heapq.heappop(heap)
        if st in visited:
            continue
        visited.add(st)
        if len(visited) > max_states:  # pragma: no cover - guard
            raise RuntimeError("state explosion")
        p, direc, mask, q = st
        if mask == full:
            return d
        pen = pend[mask]
        succs: list[tuple[tuple[int, int, int, int], int]] = []
        if direc == 0:  # moving left
            if p > 0:
                succs.append(((p - 1, 0, mask, p - 1), (points[p] - points[p - 1]) * pen))
            # U-turn to the right (q := here)
            succs.append(((p, 1, mask, p), U * pen))
        else:  # moving right from q (last right-turn)
            if p + 1 < P:
                np_, cost = p + 1, (points[p + 1] - points[p]) * pen
                nmask = mask
                # serve any file whose right edge is the arrival point and
                # whose left edge is right of (or at) the last right-turn
                for i in range(R):
                    if not (nmask >> i) & 1 and right[i] == points[p + 1] and left[i] >= points[q]:
                        nmask |= 1 << i
                succs.append(((np_, 1, nmask, q), cost))
            # U-turn back to the left
            succs.append(((p, 0, mask, p), U * pen))
        for nst, w in succs:
            nd = d + w
            if nst not in dist or nd < dist[nst]:
                dist[nst] = nd
                heapq.heappush(heap, (nd, nst))
    raise RuntimeError("no schedule served all files")  # pragma: no cover


def _laminar_compatible(d1: tuple[int, int], d2: tuple[int, int]) -> bool:
    (a1, b1), (a2, b2) = d1, d2
    if b1 < a2 or b2 < a1:  # disjoint
        return True
    # strict nesting
    return (a1 < a2 and b2 < b1) or (a2 < a1 and b1 < b2)


def laminar_families(n_req: int):
    """Yield every strictly laminar set of detours over ``n_req`` files."""
    pairs = [(a, b) for a in range(n_req) for b in range(a, n_req)]
    for k in range(len(pairs) + 1):
        for combo in itertools.combinations(pairs, k):
            ok = all(
                _laminar_compatible(combo[i], combo[j])
                for i in range(len(combo))
                for j in range(i + 1, len(combo))
            )
            if ok:
                yield list(combo)


def bruteforce_laminar(inst: Instance) -> tuple[int, list[tuple[int, int]]]:
    """Exact optimum over strictly laminar detour families (tiny R only)."""
    R = inst.n_req
    if R > 5:
        raise ValueError("laminar enumeration is doubly exponential in n_req")
    best = None
    best_d: list[tuple[int, int]] = []
    for fam in laminar_families(R):
        c = evaluate_detours(inst, fam)
        if best is None or c < best:
            best, best_d = c, fam
    assert best is not None
    return best, best_d

"""Baseline LTSP algorithms: NODETOUR, GS, FGS, NFGS, LOGNFGS.

Adapted from Cardonha & Real [7] to account for U-turn penalties, following
the paper's Appendix B (including its three corrections to NFGS).  All return
detour lists over requested-file indices; the objective is always scored by
:func:`repro.core.schedule.evaluate_detours`.
"""

from __future__ import annotations

import math

import numpy as np

from .instance import Instance

__all__ = [
    "no_detour",
    "gs",
    "fgs",
    "nfgs",
    "lognfgs",
]


def no_detour(inst: Instance) -> list[tuple[int, int]]:
    """Sweep to the leftmost request, then one left-to-right pass."""
    return []


def gs(inst: Instance) -> list[tuple[int, int]]:
    """Greedy Scheduling: one atomic detour per requested file.

    3-approximation when U == 0 [6].
    """
    return [(f, f) for f in range(inst.n_req)]


def fgs(inst: Instance) -> list[tuple[int, int]]:
    """Filtered GS: drop detours that Lemma 3 (Eq. 5) proves detrimental.

    Removing ``(f, f)`` from a single-file detour list ``L`` strictly helps iff

      2 x(f) (l(f) + sum_{g<f, g in L} (s(g)+U))
        < 2 (s(f)+U) (sum_{g<f} x(g) + sum_{g>f, g not in L} x(g))

    The filter is re-run ``n_req`` times since each removal can make another
    detour detrimental.  O(n_req^2).
    """
    R = inst.n_req
    left = inst.left.tolist()
    size = (inst.right - inst.left).tolist()
    x = inst.mult.tolist()
    U = inst.u_turn

    in_l = [True] * R
    nl_all = inst.n_left().tolist()  # sum_{g<f} x(g), independent of L

    for _ in range(R):
        changed = False
        # suffix of requests on skipped files (g > f, g not in L) from the
        # state of L at the start of the pass; removals during the pass only
        # happen at positions <= f so the suffix stays exact (see paper B.3).
        skip_suffix = [0] * (R + 1)
        for g in range(R - 1, -1, -1):
            skip_suffix[g] = skip_suffix[g + 1] + (0 if in_l[g] else x[g])
        run_det = 0  # sum_{g<f, g in L} (s(g)+U), maintained along the sweep
        for f in range(R):
            if in_l[f]:
                lhs = 2 * x[f] * (left[f] + run_det)
                rhs = 2 * (size[f] + U) * (nl_all[f] + skip_suffix[f + 1])
                if lhs < rhs:
                    in_l[f] = False
                    changed = True
            if in_l[f]:
                run_det += size[f] + U
        if not changed:
            break
    return [(f, f) for f in range(R) if in_l[f]]


def _delta(
    inst: Instance,
    covered: np.ndarray,
    det_left_len: np.ndarray,
    a: int,
    bs: np.ndarray,
) -> np.ndarray:
    """Paper Definition 1, vectorised over candidate right endpoints ``bs``.

    Delta(L,(a,b)) = 2 (r(b)-l(a)+U) (sum_{f<a} x(f) + sum_{f>b, f not in L} x(f))
                   - 2 sum_{f in [a,b], f not in L} x(f)
                       * (l(a) + sum_{(f',g') in L, f'<a} (r(g')-l(f')+U))

    ``covered[f]``       - f lies inside some detour of L.
    ``det_left_len[a]``  - sum of (r(g')-l(f')+U) over detours starting left
                           of a (precomputed prefix).
    """
    x = inst.mult
    nl_all = inst.n_left()
    # suffix of uncovered requests strictly right of b
    unc = np.where(covered, 0, x)
    unc_suffix = np.concatenate([np.cumsum(unc[::-1])[::-1], [0]])
    pending = nl_all[a] + unc_suffix[bs + 1]
    unc_prefix = np.concatenate([[0], np.cumsum(unc)])
    in_ab = unc_prefix[bs + 1] - unc_prefix[a]
    term1 = 2 * (inst.right[bs] - inst.left[a] + inst.u_turn) * pending
    term2 = 2 * in_ab * (inst.left[a] + det_left_len[a])
    return term1 - term2


def _nfgs_impl(inst: Instance, max_span: int | None) -> list[tuple[int, int]]:
    """NFGS / LOGNFGS with the paper's three corrections (Appendix B.4):

    * ``argmin`` ranges over ``f' >= f`` (single-file detours can be kept),
    * a single-file detour lying inside an earlier multi-file detour is never
      removed (the Delta flaw would otherwise force its removal),
    * Delta uses ``f' < a`` in the left-detour-length sum.
    """
    R = inst.n_req
    res: dict[int, int] = {f: f for f, _ in fgs(inst)}  # start from FGS
    rightest = -1

    for f in range(R):
        was_a_detour = f in res and res[f] == f
        # temp = res minus the atomic detour (f, f)
        temp = dict(res)
        if was_a_detour:
            del temp[f]

        # coverage + prefix of detour lengths for temp
        covered = np.zeros(R, dtype=bool)
        starts = np.zeros(R, dtype=np.int64)  # detour length bucketed at start
        for a0, b0 in temp.items():
            covered[a0 : b0 + 1] = True
            starts[a0] += inst.right[b0] - inst.left[a0] + inst.u_turn
        # det_left_len[a] = sum of lengths of detours starting strictly left of a
        det_left_len = np.concatenate([[0], np.cumsum(starts)[:-1]])

        hi = R - 1 if max_span is None else min(R - 1, f + max_span)
        bs = np.arange(f, hi + 1)
        deltas = _delta(inst, covered, det_left_len, f, bs)
        k = int(np.argmin(deltas))
        f_star, d_star = int(bs[k]), int(deltas[k])

        if d_star >= 0 and was_a_detour and rightest > f:
            # inside a longer detour: Delta cannot be negative there, keep the
            # atomic detour rather than losing it (paper's correction)
            res = temp
            res[f] = f
            continue
        res = temp
        if d_star < 0:
            res[f] = f_star
            rightest = max(rightest, f_star)
    return sorted(res.items())


def nfgs(inst: Instance) -> list[tuple[int, int]]:
    """Non-atomic FGS: greedily replace atomic detours by multi-file ones."""
    return _nfgs_impl(inst, None)


def lognfgs(inst: Instance, lam: float = 5.0) -> list[tuple[int, int]]:
    """NFGS restricted to detour spans of at most ``lam * ln(n_req)`` files."""
    span = max(1, math.ceil(lam * math.log(max(2, inst.n_req))))
    return _nfgs_impl(inst, span)

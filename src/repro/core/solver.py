"""First-class solver engine: a ``Solver`` protocol + policy registry.

Every scheduling caller in the repo (``storage/tape.py``, ``serving/queue.py``,
``benchmarks/run.py``, ``launch/serve.py``, the examples) dispatches through
this module instead of a flat name→lambda dict.  A *policy* names an algorithm
from the paper (``"dp"``, ``"simpledp"``, ``"logdp1"``, heuristics …); an
:class:`~repro.core.context.ExecutionContext` says *how* to run it — which
backend, which solve memo, bucketing and numeric options:

* ``"python"`` — exact Python-int CPU implementation (default, always
  available, arbitrary magnitudes);
* ``"pallas"`` — the compiled Pallas TPU wavefront (int32-exact under the
  magnitude guard in :mod:`repro.kernels.ltsp_dp.ops`);
* ``"pallas-interpret"`` — the same kernel through the Pallas interpreter
  (runs on CPU; the validated device path in this repo).

The device backends return full ``(cost, detours)`` solutions via the
kernel's argmin planes + host traceback, and batch several instances into a
few size-bucketed launches through :meth:`Solver.solve_batch`.  The DP family
*and* SIMPLEDP run on all three backends (SIMPLEDP clips the wavefront's
candidate band to root-level detours — the disjoint-detour restriction — via
the same mechanism that clips LOGDP spans); the list heuristics are
python-only.

Usage::

    from repro.core import ExecutionContext, solve, solve_batch

    ctx = ExecutionContext(backend="pallas-interpret", cache=SolveCache())
    res = solve(inst, policy="dp", context=ctx)
    res.cost, res.detours

The pre-context keywords (``solve(inst, policy, backend="...", cache=...)``)
remain available as deprecation shims: they emit ``DeprecationWarning`` and
forward into a context, bit-identical to the old paths.

Registering a custom policy::

    from repro.core.solver import DPSolver, register_solver

    register_solver(DPSolver("logdp2", kind="restricted-dp",
                             span_policy=lambda n_req: logdp_span(n_req, 2.0),
                             description="LOGDP with lambda=2"))

Memoising repeated solves: the ``CacheBackend`` protocol
--------------------------------------------------------
Serving and restore loops frequently re-plan *identical* tapes (the same
request multiset against the same cartridge).  ``ExecutionContext.cache``
accepts any object implementing the
:class:`~repro.core.cache.CacheBackend` protocol —
``get``/``put``/``stats``/``clear``/``__len__`` over canonicalized solve
keys, plus ``get_warm``/``put_warm`` for carrying
:class:`~repro.core.warm.WarmState` objects alongside the memoised full
solves.  :class:`SolveCache` (in-process bounded LRU) is the default
implementation; :class:`~repro.core.cache.JsonlCacheBackend` adds an
append-only on-disk journal so a restarted serving fleet rewarms from its
previous runs.  Backends only ever memoise exact results, so swapping one
for another (or bounding one below the working set) changes wall time, never
a single schedule — asserted in the cache-eviction serving tests.

The cache key is the **canonicalized request multiset** plus the full
result-affecting execution fingerprint: ``(policy, backend, numeric_policy,
cand_tile, m, u_turn, left.tobytes(), right.tobytes(), mult.tobytes())``.
An :class:`~repro.core.instance.Instance` already stores requested files
sorted by position with aggregated multiplicities, so two request batches
that read the same files the same number of times on the same cartridge
canonicalize to the same key regardless of arrival order.  The key captures
array *contents* at call time and hits return a fresh :class:`SolveResult`
(detours copied), so mutating an instance or a returned schedule never
aliases into — or invalidates silently — a cached entry.  ``backend`` is
part of the key because a hit reports the backend that actually computed
it.  ``numeric_policy`` and ``cand_tile`` are part of the key for the same
provenance reason, with a sharper edge: every backend/policy/tile
combination is bit-identical *where it computes at all*, but their error
domains differ — a strict-policy call must raise the int32-guard error on a
wide instance, not silently consume a result an f64-policy call cached
earlier, and a cached result must never claim it was computed under a tile
configuration that never ran.  (Earlier revisions deliberately excluded
both; the serving stack now distinguishes numeric configurations per
cartridge, so the aliasing became an observable bug.)  Only ``bucketed``
stays out of the key: it is launch *packing*, invisible in the result and
carrying no error-domain of its own.

The legacy ``ALGORITHMS`` mapping is kept as a read-only view over the
registry (name → ``inst -> detours`` callable) for downstream code that only
wants detour lists.

Degradation chain (fault tolerance)
-----------------------------------
Device backends can fault transiently (a wedged accelerator runtime, a
driver hiccup — modelled by :class:`TransientSolverError`).  Because every
backend is bit-identical where it computes at all, a faulting backend can be
*degraded* through :data:`DEGRADATION_CHAIN` — ``pallas →
pallas-interpret → python`` — without changing a single schedule:
:func:`solve_warm_degraded` / :func:`solve_batch_warm_degraded` retry each
tier up to ``attempts_per_backend`` times and fall through to the next on a
:class:`TransientSolverError` or :class:`UnsupportedBackendError`, dropping
any incoming warm state on the first fallback (warm states are not
guaranteed portable across tiers) and returning none themselves after one —
invalidation is the safe direction for an advisory accelerator.  The
``python`` tier is the last resort (always available, arbitrary
magnitudes); if even it faults, the typed :class:`SolverUnavailableError`
carries the per-tier failure history.  The memo cache keys on the backend
that actually computed, so a degraded result can never be served to a
healthy-backend call later.

Per-instance failures in a batch: :func:`solve_batch` is all-or-nothing by
default, but ``partial=True`` solves the good instances and returns a typed
:class:`FailedSolve` (policy, backend, index, error) in place of each bad
one — nothing failing ever touches the cache.

Warm-started solving
--------------------
:func:`solve_warm`/:func:`solve_batch_warm` mirror :func:`solve`/
:func:`solve_batch` but additionally thread a
:class:`~repro.core.warm.WarmState` per instance: pass the state returned by
the previous solve of a perturbed sibling (same cartridge, one request
added/completed/aborted) and the DP re-evaluates only the invalidated cells
— bit-identical results, with exact evaluated/reused cell counters in the
returned :class:`~repro.core.warm.WarmStats`.  Policies advertise support
via ``Solver.supports_warm`` (the DP family: ``dp``/``logdp*``); unsupported
policies fall back to a plain full solve with ``mode="unsupported"``.

Load-adaptive solver selection (``SolverSelector``)
---------------------------------------------------
Under heavy traffic the exact DP's own runtime becomes a service-time
component (the paper's DP costs minutes at the median CC-IN2P3 stratum), and
the approximate-sequencing quality bounds justify degrading to restricted DP
or heuristics while queues are deep.  A :class:`SolverSelector` is consulted
by :class:`~repro.serving.queue.OnlineTapeServer` at every dispatch tick with
a :class:`LoadView` (queue depth, batch size, recorded per-policy solve
timings) and the context's :class:`~repro.core.context.ComputeBudget`, and
answers with the policy to solve that tick with — or ``None`` to keep the
server's configured policy.  Three selectors are registered:

* ``"fixed"`` — always the server's configured policy (the adaptive plumbing
  with adaptation turned off; bit-identical to no selector at all);
* ``"depth-threshold"`` — walks :data:`DEFAULT_LADDER` (``dp`` → ``logdp1``
  → ``nfgs``) by queue depth against ``budget.shallow_depth`` /
  ``budget.deep_depth``;
* ``"cost-model"`` — predicts each ladder tier's DP-cell cost for the tick's
  batch size via :func:`predict_cells` (observed cells-per-``n³`` from the
  run's own solve timings, with analytic priors before any observation) and
  picks the most exact tier that fits ``budget.per_tick``.

The *server* applies ``budget.hysteresis`` (a tier must win that many
consecutive ticks before the active policy switches), so selectors stay
stateless and replayable.  Register custom selectors with
:func:`register_selector`; ``list_selectors()`` enumerates.
"""

from __future__ import annotations

import dataclasses
import warnings
from collections import OrderedDict
from collections.abc import Mapping
from typing import Callable, Protocol, runtime_checkable

from .context import (
    BACKENDS,
    DEFAULT_BACKEND,
    DEFAULT_BUDGET,
    DEFAULT_CONTEXT,
    ComputeBudget,
    ExecutionContext,
    resolve_context,
)
from .dp import dp_schedule, dp_schedule_warm, logdp_span, simpledp_schedule
from .heuristics import fgs, gs, lognfgs, nfgs, no_detour
from .instance import Instance
from .schedule import evaluate_detours
from .warm import WarmState, WarmStats

__all__ = [
    "BACKENDS",
    "DEFAULT_BACKEND",
    "ExecutionContext",
    "DEFAULT_CONTEXT",
    "UnsupportedBackendError",
    "TransientSolverError",
    "SolverUnavailableError",
    "DEGRADATION_CHAIN",
    "degraded_backends",
    "FallbackRecord",
    "FailedSolve",
    "SolveResult",
    "SolveCache",
    "Solver",
    "HeuristicSolver",
    "DPSolver",
    "SimpleDPSolver",
    "register_solver",
    "get_solver",
    "list_solvers",
    "solve",
    "solve_batch",
    "solve_warm",
    "solve_batch_warm",
    "solve_warm_degraded",
    "solve_batch_warm_degraded",
    "ALGORITHMS",
    "DEFAULT_LADDER",
    "LoadView",
    "SolverSelector",
    "predict_cells",
    "FixedSelector",
    "DepthThresholdSelector",
    "CostModelSelector",
    "register_selector",
    "get_selector",
    "list_selectors",
]


class UnsupportedBackendError(ValueError):
    """A registered policy was asked for a backend it does not implement.

    Typed (callers can catch it without string-matching) and message-stable:
    the message is always ``policy {name!r} has no {backend!r} backend
    (supported: {backends})`` — tests and serving fallback paths rely on the
    format.  Raised *before* any instance is solved, so a batch never fails
    mid-flight: ``solve_batch`` on an unsupported policy/backend combination
    is all-or-nothing.
    """

    def __init__(self, policy: str, backend: str, supported: tuple[str, ...]):
        self.policy = policy
        self.backend = backend
        self.supported = supported
        super().__init__(
            f"policy {policy!r} has no {backend!r} backend "
            f"(supported: {supported})"
        )


class TransientSolverError(RuntimeError):
    """A backend faulted transiently (device wedge, runtime hiccup).

    Retryable by construction: the same solve on the same backend may
    succeed on the next attempt, and any other tier of
    :data:`DEGRADATION_CHAIN` computes the bit-identical result.  Raised by
    fault-injection hooks and catchable by :func:`solve_warm_degraded`.
    """

    def __init__(self, backend: str, message: str | None = None):
        self.backend = backend
        super().__init__(
            message or f"transient solver fault on backend {backend!r}"
        )


class SolverUnavailableError(RuntimeError):
    """Every tier of the degradation chain failed for this solve."""

    def __init__(self, policy: str, backend: str, failed: tuple[str, ...]):
        self.policy = policy
        self.backend = backend
        self.failed = failed
        super().__init__(
            f"policy {policy!r} could not be solved on any backend tier "
            f"(requested {backend!r}; attempts failed on: {list(failed)})"
        )


#: backend tiers in degradation order: compiled device kernel, interpreted
#: kernel on CPU, pure-Python exact DP (the always-available last resort).
DEGRADATION_CHAIN = ("pallas", "pallas-interpret", "python")


def degraded_backends(backend: str) -> tuple[str, ...]:
    """The degradation-chain suffix starting at ``backend``."""
    if backend not in DEGRADATION_CHAIN:
        raise ValueError(
            f"unknown backend {backend!r}; chain is {DEGRADATION_CHAIN}"
        )
    return DEGRADATION_CHAIN[DEGRADATION_CHAIN.index(backend):]


@dataclasses.dataclass(frozen=True)
class FallbackRecord:
    """How a degraded solve landed: requested tier, used tier, fault trail.

    ``failed`` lists the backend of every faulted attempt in order (a tier
    retried twice before falling through appears twice); ``used ==
    requested`` with a non-empty trail means retries on the requested tier
    eventually succeeded — no fallback happened.
    """

    requested: str
    used: str
    failed: tuple[str, ...] = ()

    @property
    def n_faults(self) -> int:
        return len(self.failed)

    @property
    def fell_back(self) -> bool:
        return self.used != self.requested


@dataclasses.dataclass(frozen=True)
class FailedSolve:
    """Typed per-instance failure returned by ``solve_batch(partial=True)``.

    Sits in the result list at the failing instance's position; ``index``
    is that position in the input batch, ``error`` the exception the solve
    raised.  Never cached.
    """

    policy: str
    backend: str
    index: int
    error: Exception


@dataclasses.dataclass(frozen=True)
class SolveResult:
    """One solved instance: the policy's reported cost and its detour list.

    ``cost`` includes *VirtualLB* (it is the LTSP objective of ``detours`` —
    the parity tests assert it equals the exact simulator score).
    """

    policy: str
    backend: str
    cost: int
    detours: list[tuple[int, int]]


class SolveCache:
    """Bounded LRU memo of solved instances (see the module docstring).

    The reference :class:`~repro.core.cache.CacheBackend` implementation.
    Keys canonicalize the request multiset plus ``(policy, backend,
    numeric_policy, cand_tile)``; values are immutable snapshots (detours
    stored as tuples), re-materialised into a fresh :class:`SolveResult` on
    every hit.  ``hits``/``misses`` counters feed the benchmark summaries.
    A separate, independently bounded LRU side-table carries per-cartridge
    :class:`~repro.core.warm.WarmState` objects
    (:meth:`get_warm`/:meth:`put_warm`) — warm states are advisory (any
    solve is exact without one), so they are never persisted and evicting
    one costs a little extra DP work, never correctness.
    """

    def __init__(self, maxsize: int = 4096, warm_maxsize: int = 512):
        self.maxsize = maxsize
        self.warm_maxsize = warm_maxsize
        self.hits = 0
        self.misses = 0
        self._store: OrderedDict[tuple, tuple] = OrderedDict()
        self._warm: OrderedDict[tuple, object] = OrderedDict()
        # observability sink (the serving loop points this at the context's
        # bundle; None records nothing and the counters above stay canonical)
        self.obs = None

    @staticmethod
    def key(
        inst: Instance,
        policy: str,
        backend: str,
        numeric_policy: str = "strict",
        cand_tile: int | None = None,
    ) -> tuple:
        """Canonical cache key; captures array contents at call time."""
        return (
            policy,
            backend,
            numeric_policy,
            cand_tile,
            inst.m,
            inst.u_turn,
            inst.left.tobytes(),
            inst.right.tobytes(),
            inst.mult.tobytes(),
        )

    def __len__(self) -> int:
        return len(self._store)

    def get(
        self,
        inst: Instance,
        policy: str,
        backend: str,
        numeric_policy: str = "strict",
        cand_tile: int | None = None,
    ) -> SolveResult | None:
        key = self.key(inst, policy, backend, numeric_policy, cand_tile)
        entry = self._store.get(key)
        if entry is None:
            self.misses += 1
            if self.obs is not None:
                self.obs.inc("cache_misses_total", cache=type(self).__name__)
            return None
        self._store.move_to_end(key)
        self.hits += 1
        if self.obs is not None:
            self.obs.inc("cache_hits_total", cache=type(self).__name__)
        cost, detours = entry
        return SolveResult(policy, backend, cost, [tuple(d) for d in detours])

    def put(
        self,
        inst: Instance,
        policy: str,
        backend: str,
        res: SolveResult,
        numeric_policy: str = "strict",
        cand_tile: int | None = None,
    ) -> None:
        key = self.key(inst, policy, backend, numeric_policy, cand_tile)
        self._store[key] = (
            res.cost,
            tuple((int(c), int(b)) for c, b in res.detours),
        )
        while len(self._store) > self.maxsize:
            self._store.popitem(last=False)
            if self.obs is not None:
                self.obs.inc("cache_evictions_total", cache=type(self).__name__)

    # -- warm-state side-table (advisory, in-memory only) ---------------------
    def get_warm(self, key: tuple):
        """The stored :class:`WarmState` for ``key`` (e.g. a cartridge id)."""
        state = self._warm.get(key)
        if state is not None:
            self._warm.move_to_end(key)
        return state

    def put_warm(self, key: tuple, state) -> None:
        self._warm[key] = state
        while len(self._warm) > self.warm_maxsize:
            self._warm.popitem(last=False)

    def stats(self) -> dict[str, int]:
        return {
            "hits": self.hits,
            "misses": self.misses,
            "entries": len(self),
            "warm_entries": len(self._warm),
        }

    def clear(self) -> None:
        self._store.clear()
        self._warm.clear()
        self.hits = 0
        self.misses = 0


def _as_context(context: ExecutionContext | str) -> ExecutionContext:
    """Deprecation shim: accept a bare backend string where a context is due.

    Pre-context code called ``solver.solve(inst, "pallas-interpret")``; that
    keeps working (one ``DeprecationWarning``, then the string becomes the
    context's backend) so the seed surface is source-compatible.
    """
    if isinstance(context, ExecutionContext):
        return context
    warnings.warn(
        "passing a backend string to Solver.solve/solve_batch is deprecated; "
        "pass context=ExecutionContext(backend=...) instead",
        DeprecationWarning,
        stacklevel=3,
    )
    return DEFAULT_CONTEXT.replace(backend=context)


def _device_kwargs(ctx: ExecutionContext, disjoint: bool = False) -> dict:
    """Kernel options a device-backed solver derives from the context."""
    kwargs: dict = {
        "interpret": ctx.backend == "pallas-interpret",
        "numeric_policy": ctx.numeric_policy,
    }
    if disjoint:
        kwargs["disjoint"] = True
    if ctx.cand_tile is not None:
        kwargs["cand_tile"] = ctx.cand_tile
    if ctx.obs is not None and ctx.obs.kernel is not None:
        kwargs["profile"] = ctx.obs.kernel
    return kwargs


@runtime_checkable
class Solver(Protocol):
    """Protocol every registered policy implements."""

    name: str
    kind: str  # "heuristic" | "restricted-dp" | "exact-dp"
    description: str

    @property
    def backends(self) -> tuple[str, ...]:
        """Backends this solver accepts (subset of :data:`BACKENDS`)."""

    @property
    def supports_device(self) -> bool:
        """Capability flag: True iff a ``pallas*`` backend is implemented."""

    @property
    def supports_warm(self) -> bool:
        """Capability flag: True iff warm-start re-solve is implemented.

        Warm-capable solvers additionally expose ``solve_warm`` /
        ``solve_batch_warm`` with the :func:`solve_warm` module-function
        signatures (minus policy/cache handling).
        """

    def solve(
        self, inst: Instance, context: ExecutionContext = DEFAULT_CONTEXT
    ) -> SolveResult:
        """Solve one instance under the given execution context."""

    def solve_batch(
        self, instances: list[Instance], context: ExecutionContext = DEFAULT_CONTEXT
    ) -> list[SolveResult]:
        """Solve several instances (device backends: bucketed launches)."""


def _check_backend(solver: "Solver", backend: str) -> None:
    if backend not in BACKENDS:
        raise KeyError(f"unknown backend {backend!r}; choose from {BACKENDS}")
    if backend not in solver.backends:
        raise UnsupportedBackendError(solver.name, backend, solver.backends)


@dataclasses.dataclass(frozen=True)
class HeuristicSolver:
    """Detour-list heuristic (NODETOUR/GS/FGS/NFGS/…); python backend only.

    The reported cost is the exact simulator score of the emitted detours.
    """

    name: str
    fn: Callable[[Instance], list[tuple[int, int]]]
    description: str = ""
    kind: str = "heuristic"

    @property
    def backends(self) -> tuple[str, ...]:
        return ("python",)

    @property
    def supports_device(self) -> bool:
        return False

    @property
    def supports_warm(self) -> bool:
        return False

    def solve(
        self, inst: Instance, context: ExecutionContext | str = DEFAULT_CONTEXT
    ) -> SolveResult:
        ctx = _as_context(context)
        _check_backend(self, ctx.backend)
        detours = self.fn(inst)
        return SolveResult(
            self.name, ctx.backend, evaluate_detours(inst, detours), detours
        )

    def solve_batch(
        self,
        instances: list[Instance],
        context: ExecutionContext | str = DEFAULT_CONTEXT,
    ) -> list[SolveResult]:
        ctx = _as_context(context)
        _check_backend(self, ctx.backend)  # all-or-nothing: never fail mid-batch
        return [self.solve(inst, ctx) for inst in instances]


@dataclasses.dataclass(frozen=True)
class DPSolver:
    """The paper's exact DP, optionally span-restricted (LOGDP family).

    ``span_policy`` maps ``n_req`` to the maximum detour span (``None`` =
    unrestricted = exact DP).  All three backends are available; the device
    backends batch by span value so one launch serves every instance that
    shares a span, and honour the context's bucketing/numeric options.
    """

    name: str
    span_policy: Callable[[int], int | None] | None = None
    description: str = ""
    kind: str = "exact-dp"

    @property
    def backends(self) -> tuple[str, ...]:
        return BACKENDS

    @property
    def supports_device(self) -> bool:
        return True

    @property
    def supports_warm(self) -> bool:
        return True

    def _span(self, inst: Instance) -> int | None:
        return None if self.span_policy is None else self.span_policy(inst.n_req)

    def solve(
        self, inst: Instance, context: ExecutionContext | str = DEFAULT_CONTEXT
    ) -> SolveResult:
        ctx = _as_context(context)
        _check_backend(self, ctx.backend)
        if ctx.backend == "python":
            cost, detours = dp_schedule(inst, span=self._span(inst))
        else:
            from ..kernels.ltsp_dp.ops import ltsp_solve_instance

            cost, detours = ltsp_solve_instance(
                inst, span=self._span(inst), **_device_kwargs(ctx)
            )
        return SolveResult(self.name, ctx.backend, cost, detours)

    def solve_batch(
        self,
        instances: list[Instance],
        context: ExecutionContext | str = DEFAULT_CONTEXT,
    ) -> list[SolveResult]:
        ctx = _as_context(context)
        _check_backend(self, ctx.backend)
        if ctx.backend == "python":
            return [self.solve(inst, ctx) for inst in instances]
        from ..kernels.ltsp_dp.ops import ltsp_solve_batch

        # one bucketed launch set per distinct span (the span is a static
        # kernel parameter; unrestricted DP always groups into one set)
        groups: dict[int | None, list[int]] = {}
        for i, inst in enumerate(instances):
            groups.setdefault(self._span(inst), []).append(i)
        results: list[SolveResult | None] = [None] * len(instances)
        for span, idxs in groups.items():
            solved = ltsp_solve_batch(
                [instances[i] for i in idxs],
                span=span,
                bucketed=ctx.bucketed,
                **_device_kwargs(ctx),
            )
            for i, (cost, detours) in zip(idxs, solved):
                results[i] = SolveResult(self.name, ctx.backend, cost, detours)
        return results  # type: ignore[return-value]

    def solve_warm(
        self,
        inst: Instance,
        context: ExecutionContext | str = DEFAULT_CONTEXT,
        warm=None,
    ):
        """Warm-startable solve: ``(SolveResult, new WarmState, WarmStats)``.

        Bit-identical to :meth:`solve` whatever ``warm`` holds (asserted
        differentially in the tests); the state/counters travel alongside.
        """
        ctx = _as_context(context)
        _check_backend(self, ctx.backend)
        if ctx.backend == "python":
            cost, detours, new_warm, stats = dp_schedule_warm(
                inst, span=self._span(inst), warm=warm
            )
        else:
            from ..kernels.ltsp_dp.ops import ltsp_solve_instance_warm

            cost, detours, new_warm, stats = ltsp_solve_instance_warm(
                inst, span=self._span(inst), warm=warm, **_device_kwargs(ctx)
            )
        return SolveResult(self.name, ctx.backend, cost, detours), new_warm, stats

    def solve_batch_warm(
        self,
        instances: list[Instance],
        context: ExecutionContext | str = DEFAULT_CONTEXT,
        warms=None,
    ):
        """Batch :meth:`solve_warm`; device backends group launches by span."""
        ctx = _as_context(context)
        _check_backend(self, ctx.backend)
        if warms is None:
            warms = [None] * len(instances)
        if ctx.backend == "python":
            out = [self.solve_warm(inst, ctx, warm=w)
                   for inst, w in zip(instances, warms)]
            return ([r for r, _, _ in out], [w for _, w, _ in out],
                    [s for _, _, s in out])
        from ..kernels.ltsp_dp.ops import ltsp_solve_batch_warm

        groups: dict[int | None, list[int]] = {}
        for i, inst in enumerate(instances):
            groups.setdefault(self._span(inst), []).append(i)
        results: list[SolveResult | None] = [None] * len(instances)
        new_warms: list = [None] * len(instances)
        stats: list = [None] * len(instances)
        for span, idxs in groups.items():
            solved, ws, sts = ltsp_solve_batch_warm(
                [instances[i] for i in idxs],
                [warms[i] for i in idxs],
                span=span,
                bucketed=ctx.bucketed,
                **_device_kwargs(ctx),
            )
            for i, (cost, detours), w, st in zip(idxs, solved, ws, sts):
                results[i] = SolveResult(self.name, ctx.backend, cost, detours)
                new_warms[i], stats[i] = w, st
        return results, new_warms, stats


@dataclasses.dataclass(frozen=True)
class SimpleDPSolver:
    """SIMPLEDP (disjoint detours, 2-D table); all three backends.

    The python backend evaluates the dedicated 2-D recursion
    (:func:`repro.core.dp.simpledp_schedule`).  The device backends reuse the
    full wavefront kernel with its candidate band clipped to root-level cells
    (``disjoint=True``) — forbidding detours inside detours collapses the 3-D
    table to SIMPLEDP's exactly (same mechanism as the LOGDP span clip), so
    cost *and* traceback are bit-identical to the python recursion.
    """

    name: str = "simpledp"
    description: str = "DP restricted to non-intertwined detours"
    kind: str = "restricted-dp"

    @property
    def backends(self) -> tuple[str, ...]:
        return BACKENDS

    @property
    def supports_device(self) -> bool:
        return True

    @property
    def supports_warm(self) -> bool:
        # the 2-D table collapses the first index: its cells are not the
        # 3-D cells WarmState stores, so transfer does not apply
        return False

    def solve(
        self, inst: Instance, context: ExecutionContext | str = DEFAULT_CONTEXT
    ) -> SolveResult:
        ctx = _as_context(context)
        _check_backend(self, ctx.backend)
        if ctx.backend == "python":
            cost, detours = simpledp_schedule(inst)
        else:
            from ..kernels.ltsp_dp.ops import ltsp_solve_instance

            cost, detours = ltsp_solve_instance(
                inst, **_device_kwargs(ctx, disjoint=True)
            )
        return SolveResult(self.name, ctx.backend, cost, detours)

    def solve_batch(
        self,
        instances: list[Instance],
        context: ExecutionContext | str = DEFAULT_CONTEXT,
    ) -> list[SolveResult]:
        ctx = _as_context(context)
        _check_backend(self, ctx.backend)
        if ctx.backend == "python":
            return [self.solve(inst, ctx) for inst in instances]
        from ..kernels.ltsp_dp.ops import ltsp_solve_batch

        solved = ltsp_solve_batch(
            instances, bucketed=ctx.bucketed, **_device_kwargs(ctx, disjoint=True)
        )
        return [
            SolveResult(self.name, ctx.backend, cost, detours)
            for cost, detours in solved
        ]


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------
_REGISTRY: dict[str, Solver] = {}


def register_solver(solver: Solver, overwrite: bool = False) -> Solver:
    """Add a solver to the registry (name collisions require ``overwrite``)."""
    if solver.name in _REGISTRY and not overwrite:
        raise ValueError(f"solver {solver.name!r} already registered")
    _REGISTRY[solver.name] = solver
    return solver


def get_solver(name: str) -> Solver:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(
            f"unknown policy {name!r}; choose from {sorted(_REGISTRY)}"
        ) from None


def list_solvers() -> list[str]:
    """Registered policy names, in registration order."""
    return list(_REGISTRY)


def solve(
    inst: Instance,
    policy: str = "dp",
    backend: str | None = None,
    cache: SolveCache | None = None,
    *,
    context: ExecutionContext | None = None,
) -> SolveResult:
    """Solve one instance with a registered policy.

    ``context`` carries the execution options (backend, memo cache, bucketing,
    numeric policy); ``backend=``/``cache=`` are the deprecated pre-context
    spellings and forward into one (with a ``DeprecationWarning``).
    """
    ctx = resolve_context(context, backend=backend, cache=cache)
    solver = get_solver(policy)
    _check_backend(solver, ctx.backend)  # before the cache: no miss-count pollution
    memo = ctx.cache
    if memo is not None:
        hit = memo.get(inst, policy, ctx.backend, ctx.numeric_policy, ctx.cand_tile)
        if hit is not None:
            return hit
    res = solver.solve(inst, ctx)
    if memo is not None:
        memo.put(inst, policy, ctx.backend, res, ctx.numeric_policy, ctx.cand_tile)
    return res


def solve_batch(
    instances: list[Instance],
    policy: str = "dp",
    backend: str | None = None,
    cache: SolveCache | None = None,
    *,
    context: ExecutionContext | None = None,
    partial: bool = False,
) -> list["SolveResult | FailedSolve"]:
    """Solve a batch; device backends pack it into size-bucketed launches.

    With a cache on the context, hits are served from the memo and only the
    misses go to the backend (in one bucketed batch), so re-planning a
    mostly-repeated request mix only pays for the novel tapes.

    An unsupported policy/backend combination raises
    :class:`UnsupportedBackendError` before any instance is solved or any
    cache entry is touched — a batch is all-or-nothing, never mid-flight.
    One *bad instance* (e.g. an int32-guard overflow under the strict
    numeric policy) is also all-or-nothing by default, but ``partial=True``
    relaxes that: the good instances are solved (and cached) normally while
    each failing one yields a typed :class:`FailedSolve` at its position —
    failures never pollute the cache, so a later retry (on another backend
    or numeric policy) starts clean.  ``backend=``/``cache=`` are
    deprecation shims, as in :func:`solve`.
    """
    ctx = resolve_context(context, backend=backend, cache=cache)
    solver = get_solver(policy)
    _check_backend(solver, ctx.backend)
    memo = ctx.cache
    if memo is None and not partial:
        return solver.solve_batch(instances, ctx)
    results: list[SolveResult | FailedSolve | None] = [
        memo.get(inst, policy, ctx.backend, ctx.numeric_policy, ctx.cand_tile)
        if memo is not None
        else None
        for inst in instances
    ]
    miss = [i for i, r in enumerate(results) if r is None]
    if miss:
        solved: list[SolveResult | FailedSolve]
        if not partial:
            solved = solver.solve_batch([instances[i] for i in miss], ctx)
        else:
            try:
                solved = solver.solve_batch([instances[i] for i in miss], ctx)
            except Exception:
                # the fast whole-batch path failed somewhere mid-bucket:
                # fall back to per-instance solves so the good ones survive
                solved = []
                for i in miss:
                    try:
                        solved.append(solver.solve(instances[i], ctx))
                    except Exception as err:  # noqa: BLE001 - typed re-wrap
                        solved.append(FailedSolve(policy, ctx.backend, i, err))
        for i, res in zip(miss, solved):
            if isinstance(res, SolveResult) and memo is not None:
                memo.put(instances[i], policy, ctx.backend, res,
                         ctx.numeric_policy, ctx.cand_tile)
            results[i] = res
    return results  # type: ignore[return-value]


def solve_warm(
    inst: Instance,
    policy: str = "dp",
    *,
    context: ExecutionContext | None = None,
    warm: WarmState | None = None,
) -> tuple[SolveResult, WarmState | None, WarmStats]:
    """:func:`solve` with warm-start threading and exact work counters.

    Returns ``(result, new_warm, stats)``.  ``result`` is bit-identical to
    :func:`solve` — a warm state can only change *how much work* the solve
    performs, never its outcome (differentially asserted in the tests).
    ``new_warm`` is the state to pass into the next solve of a perturbed
    sibling instance (``None`` when the policy cannot produce one); on a
    cache hit the incoming ``warm`` is handed back unchanged — it stays
    valid, the alignment revalidates per file on the next miss.  ``stats``
    counts DP cells evaluated vs. reused (``mode="cache"`` marks a memo hit
    that did no DP work at all).
    """
    ctx = context if context is not None else DEFAULT_CONTEXT
    solver = get_solver(policy)
    _check_backend(solver, ctx.backend)
    memo = ctx.cache
    if memo is not None:
        hit = memo.get(inst, policy, ctx.backend, ctx.numeric_policy, ctx.cand_tile)
        if hit is not None:
            if ctx.obs is not None:
                ctx.obs.inc(
                    "solves_total", policy=policy, backend=ctx.backend,
                    mode="cache",
                )
            return hit, warm, WarmStats(mode="cache")
    if getattr(solver, "supports_warm", False):
        res, new_warm, stats = solver.solve_warm(inst, ctx, warm=warm)
    else:
        res, new_warm, stats = (
            solver.solve(inst, ctx), None, WarmStats(mode="unsupported")
        )
    if memo is not None:
        memo.put(inst, policy, ctx.backend, res, ctx.numeric_policy, ctx.cand_tile)
    if ctx.obs is not None:
        ctx.obs.inc(
            "solves_total", policy=policy, backend=ctx.backend, mode=stats.mode
        )
        ctx.obs.observe("solve_cells", stats.cells_evaluated, policy=policy)
    return res, new_warm, stats


def solve_batch_warm(
    instances: list[Instance],
    policy: str = "dp",
    *,
    context: ExecutionContext | None = None,
    warms: list[WarmState | None] | None = None,
) -> tuple[list[SolveResult], list[WarmState | None], list[WarmStats]]:
    """Batch :func:`solve_warm`: per-instance warm states in, results +
    fresh states + counters out (all parallel to ``instances``).

    Cache hits skip the solver and keep the incoming state, exactly like
    :func:`solve_warm`; misses go to the backend in one warm-aware batch.
    """
    ctx = context if context is not None else DEFAULT_CONTEXT
    solver = get_solver(policy)
    _check_backend(solver, ctx.backend)
    if warms is None:
        warms = [None] * len(instances)
    memo = ctx.cache
    results: list[SolveResult | None] = [None] * len(instances)
    new_warms: list[WarmState | None] = list(warms)
    stats: list[WarmStats] = [WarmStats(mode="cache") for _ in instances]
    if memo is not None:
        for i, inst in enumerate(instances):
            results[i] = memo.get(
                inst, policy, ctx.backend, ctx.numeric_policy, ctx.cand_tile
            )
    miss = [i for i, r in enumerate(results) if r is None]
    if miss:
        if getattr(solver, "supports_warm", False):
            solved, ws, sts = solver.solve_batch_warm(
                [instances[i] for i in miss], ctx, warms=[warms[i] for i in miss]
            )
        else:
            solved = solver.solve_batch([instances[i] for i in miss], ctx)
            ws = [None] * len(miss)
            sts = [WarmStats(mode="unsupported") for _ in miss]
        for i, res, w, st in zip(miss, solved, ws, sts):
            if memo is not None:
                memo.put(instances[i], policy, ctx.backend, res,
                         ctx.numeric_policy, ctx.cand_tile)
            results[i], new_warms[i], stats[i] = res, w, st
    if ctx.obs is not None:
        for st in stats:
            ctx.obs.inc(
                "solves_total", policy=policy, backend=ctx.backend, mode=st.mode
            )
            ctx.obs.observe("solve_cells", st.cells_evaluated, policy=policy)
    return results, new_warms, stats  # type: ignore[return-value]


def solve_warm_degraded(
    inst: Instance,
    policy: str = "dp",
    *,
    context: ExecutionContext | None = None,
    warm: WarmState | None = None,
    fault_hook: Callable[[str], None] | None = None,
    attempts_per_backend: int = 1,
) -> tuple[SolveResult, WarmState | None, WarmStats, FallbackRecord]:
    """:func:`solve_warm` through the backend degradation chain.

    Walks :func:`degraded_backends` from the context's backend, retrying
    each tier up to ``attempts_per_backend`` times on a
    :class:`TransientSolverError` before falling through (an
    :class:`UnsupportedBackendError` falls through immediately — retrying
    cannot help).  ``fault_hook(backend)`` runs before every attempt; fault
    injectors raise :class:`TransientSolverError` from it.  Results are
    bit-identical across tiers, so only the :class:`FallbackRecord` tells a
    degraded solve from a healthy one.  After any fault the incoming warm
    state is dropped and no new one is returned (``new_warm is None``):
    warm states are advisory accelerators and invalidation is the safe
    direction across tiers.  Raises :class:`SolverUnavailableError` when
    every tier (including ``python``) failed.
    """
    ctx = context if context is not None else DEFAULT_CONTEXT
    failed: list[str] = []
    for b in degraded_backends(ctx.backend):
        bctx = ctx if b == ctx.backend else ctx.replace(backend=b)
        for _ in range(max(1, attempts_per_backend)):
            try:
                if fault_hook is not None:
                    fault_hook(b)
                res, new_warm, stats = solve_warm(
                    inst, policy, context=bctx,
                    warm=warm if not failed else None,
                )
            except UnsupportedBackendError:
                failed.append(b)
                break
            except TransientSolverError:
                failed.append(b)
                continue
            if failed:
                new_warm = None
            if ctx.obs is not None and failed:
                ctx.obs.inc("solver_faults_total", len(failed))
                if b != ctx.backend:
                    ctx.obs.inc("solver_fallbacks_total", backend=b)
            return res, new_warm, stats, FallbackRecord(
                requested=ctx.backend, used=b, failed=tuple(failed)
            )
    raise SolverUnavailableError(policy, ctx.backend, tuple(failed))


def solve_batch_warm_degraded(
    instances: list[Instance],
    policy: str = "dp",
    *,
    context: ExecutionContext | None = None,
    warms: list[WarmState | None] | None = None,
    fault_hook: Callable[[str], None] | None = None,
    attempts_per_backend: int = 1,
) -> tuple[
    list[SolveResult], list[WarmState | None], list[WarmStats], FallbackRecord
]:
    """:func:`solve_batch_warm` through the degradation chain.

    One batch is one launch and therefore one fault domain: a transient
    fault retries/degrades the *whole* batch (per-instance bad-input
    errors are :func:`solve_batch`'s ``partial=True`` concern, not a
    backend-health one).  Semantics otherwise match
    :func:`solve_warm_degraded`, including warm-state invalidation after
    any fault.
    """
    ctx = context if context is not None else DEFAULT_CONTEXT
    failed: list[str] = []
    for b in degraded_backends(ctx.backend):
        bctx = ctx if b == ctx.backend else ctx.replace(backend=b)
        for _ in range(max(1, attempts_per_backend)):
            try:
                if fault_hook is not None:
                    fault_hook(b)
                results, new_warms, stats = solve_batch_warm(
                    instances, policy, context=bctx,
                    warms=warms if not failed else None,
                )
            except UnsupportedBackendError:
                failed.append(b)
                break
            except TransientSolverError:
                failed.append(b)
                continue
            if failed:
                new_warms = [None] * len(instances)
            if ctx.obs is not None and failed:
                ctx.obs.inc("solver_faults_total", len(failed))
                if b != ctx.backend:
                    ctx.obs.inc("solver_fallbacks_total", backend=b)
            return results, new_warms, stats, FallbackRecord(
                requested=ctx.backend, used=b, failed=tuple(failed)
            )
    raise SolverUnavailableError(policy, ctx.backend, tuple(failed))


# the paper's nine policies
register_solver(HeuristicSolver("nodetour", no_detour, "single left-to-right sweep"))
register_solver(HeuristicSolver("gs", gs, "greedy: one atomic detour per file"))
register_solver(HeuristicSolver("fgs", fgs, "GS filtered by Lemma 3"))
register_solver(HeuristicSolver("nfgs", nfgs, "non-atomic FGS (corrected)"))
register_solver(
    HeuristicSolver(
        "lognfgs5", lambda inst: lognfgs(inst, lam=5.0), "NFGS, spans <= 5 ln n"
    )
)
register_solver(
    DPSolver(
        "logdp1",
        span_policy=lambda n_req: logdp_span(n_req, 1.0),
        description="DP, spans <= ln n",
        kind="restricted-dp",
    )
)
register_solver(
    DPSolver(
        "logdp5",
        span_policy=lambda n_req: logdp_span(n_req, 5.0),
        description="DP, spans <= 5 ln n",
        kind="restricted-dp",
    )
)
register_solver(SimpleDPSolver())
register_solver(DPSolver("dp", description="the paper's exact DP (optimal)"))


class _AlgorithmsView(Mapping):
    """Legacy ``ALGORITHMS`` shim: registry view as name → ``inst -> detours``.

    Prefer :func:`solve`/:func:`get_solver`; this exists so downstream code
    and the seed tests that only want detour lists keep working.
    """

    def __getitem__(self, name: str) -> Callable[[Instance], list[tuple[int, int]]]:
        solver = get_solver(name)
        if isinstance(solver, HeuristicSolver):
            return solver.fn  # detours directly, no throwaway simulator score
        return lambda inst: solver.solve(inst).detours

    def __iter__(self):
        return iter(_REGISTRY)

    def __len__(self) -> int:
        return len(_REGISTRY)


ALGORITHMS = _AlgorithmsView()


# ---------------------------------------------------------------------------
# Load-adaptive solver selection
# ---------------------------------------------------------------------------

#: Exactness ladder the built-in adaptive selectors walk, most exact first:
#: the paper's optimal DP, the log-span restricted DP, then the corrected
#: non-atomic filtered-greedy heuristic (cells-free).  Bachmat's
#: expected-tour-length asymptotics order these by cost as ~n^3 / ~n^2 log n
#: / ~n log n, which is exactly the shape :func:`predict_cells` assumes
#: before a run has recorded its own timings.
DEFAULT_LADDER = ("dp", "logdp1", "nfgs")


@dataclasses.dataclass(frozen=True)
class LoadView:
    """What a :class:`SolverSelector` sees at one dispatch tick.

    Built by the serving loop just before it solves a batch; selectors must
    treat it as read-only.  ``timings`` maps policy name to the run's
    accumulated ``(cells_evaluated, n_cubed)`` totals over real (non-cache)
    solves, the empirical basis for :func:`predict_cells`.
    """

    depth: int  #: queued requests behind this dispatch, incl. the batch
    n_requests: int  #: requests in the batch about to be solved
    now: int = 0  #: virtual time of the tick
    timings: Mapping[str, tuple[int, int]] = dataclasses.field(
        default_factory=dict
    )  #: policy -> (total cells evaluated, total n^3) observed this run


@runtime_checkable
class SolverSelector(Protocol):
    """Per-tick policy chooser for the serving loop.

    ``select`` answers with a registered policy name — or ``None`` to keep
    the server's configured policy.  Selectors are stateless: hysteresis
    (``budget.hysteresis`` consecutive ticks before a switch takes effect)
    is applied by the server so recovery replays re-derive identical
    choices from the journal alone.
    """

    name: str
    description: str
    ladder: tuple[str, ...]

    def select(
        self, view: LoadView, budget: ComputeBudget
    ) -> str | None: ...


def predict_cells(
    policy: str,
    n_requests: int,
    timings: Mapping[str, tuple[int, int]] | None = None,
) -> int:
    """Predicted DP cells a ``policy`` solve of ``n_requests`` will evaluate.

    With an observation for the policy in ``timings`` (accumulated
    ``(cells, n^3)`` totals from this run's real solves), scales the
    observed cells-per-``n^3`` ratio to the new size — exact integer
    arithmetic, ``cells * n^3 // observed_cubes``.  Without one, falls back
    to analytic priors by solver kind: heuristics evaluate no DP cells,
    restricted DP is ~``n^2 log n``, exact DP is ``n^3``.
    """
    solver = get_solver(policy)
    n = max(0, n_requests)
    if timings:
        observed = timings.get(solver.name)
        if observed is not None:
            cells, cubes = observed
            if cubes > 0:
                return cells * n**3 // cubes
    if solver.kind == "heuristic":
        return 0
    if solver.kind == "restricted-dp":
        return n * n * max(1, n.bit_length())
    return n**3


@dataclasses.dataclass(frozen=True)
class FixedSelector:
    """Always the same policy (``None`` = the server's configured one).

    The adaptive plumbing with adaptation turned off: with ``policy=None``
    every tick keeps the server's policy, so timelines are bit-identical to
    running with no selector at all — the control arm of the overload sweep.
    """

    policy: str | None = None
    name: str = "fixed"
    description: str = "always the server's configured policy"

    def __post_init__(self) -> None:
        if self.policy is not None:
            get_solver(self.policy)  # raises KeyError on unknown policies

    @property
    def ladder(self) -> tuple[str, ...]:
        return (self.policy,) if self.policy is not None else ()

    def select(self, view: LoadView, budget: ComputeBudget) -> str | None:
        return self.policy


def _check_ladder(ladder: tuple[str, ...]) -> tuple[str, ...]:
    ladder = tuple(ladder)
    if not ladder:
        raise ValueError("selector ladder must name at least one policy")
    for p in ladder:
        get_solver(p)  # raises KeyError on unknown policies
    return ladder


@dataclasses.dataclass(frozen=True)
class DepthThresholdSelector:
    """Walk the ladder by queue depth against the budget's thresholds.

    Depth at or below ``budget.shallow_depth`` plays the most exact tier,
    at or above ``budget.deep_depth`` the cheapest, in between the middle
    tier.  Crude but dependency-free: no timing observations needed.
    """

    ladder: tuple[str, ...] = DEFAULT_LADDER
    name: str = "depth-threshold"
    description: str = "exact DP when shallow, cheaper tiers as depth grows"

    def __post_init__(self) -> None:
        object.__setattr__(self, "ladder", _check_ladder(self.ladder))

    def select(self, view: LoadView, budget: ComputeBudget) -> str | None:
        if view.depth <= budget.shallow_depth:
            return self.ladder[0]
        if view.depth >= budget.deep_depth:
            return self.ladder[-1]
        return self.ladder[len(self.ladder) // 2]


@dataclasses.dataclass(frozen=True)
class CostModelSelector:
    """Most exact ladder tier whose predicted cell cost fits the budget.

    Estimates each tier's solve cost for the tick's batch size with
    :func:`predict_cells` — the run's own recorded solve timings once any
    exist, analytic priors before that — and returns the first (most exact)
    tier at or under ``budget.per_tick`` cells.  An unlimited budget
    (``per_tick=None``) always picks the most exact tier; if no tier fits,
    the cheapest is returned rather than refusing to serve.
    """

    ladder: tuple[str, ...] = DEFAULT_LADDER
    name: str = "cost-model"
    description: str = "most exact policy whose predicted cells fit per_tick"

    def __post_init__(self) -> None:
        object.__setattr__(self, "ladder", _check_ladder(self.ladder))

    def select(self, view: LoadView, budget: ComputeBudget) -> str | None:
        if budget.per_tick is None:
            return self.ladder[0]
        for policy in self.ladder:
            if predict_cells(policy, view.n_requests, view.timings) <= budget.per_tick:
                return policy
        return self.ladder[-1]


_SELECTORS: "OrderedDict[str, SolverSelector]" = OrderedDict()


def register_selector(selector: SolverSelector, *, replace: bool = False) -> None:
    """Add a selector to the registry (``replace=True`` to overwrite)."""
    name = getattr(selector, "name", "")
    if not name or not isinstance(name, str):
        raise ValueError(f"selector must carry a non-empty string name: {selector!r}")
    if not replace and name in _SELECTORS:
        raise ValueError(
            f"selector {name!r} is already registered (pass replace=True)"
        )
    _SELECTORS[name] = selector


def get_selector(name: "str | SolverSelector") -> SolverSelector:
    """Look up a registered selector by name (instances pass through)."""
    if not isinstance(name, str):
        if isinstance(name, SolverSelector):
            return name
        raise TypeError(f"not a selector name or SolverSelector: {name!r}")
    try:
        return _SELECTORS[name]
    except KeyError:
        raise KeyError(
            f"unknown selector {name!r}; choose from {list_selectors()}"
        ) from None


def list_selectors() -> tuple[str, ...]:
    """Registered selector names, in registration order."""
    return tuple(_SELECTORS)


register_selector(FixedSelector())
register_selector(DepthThresholdSelector())
register_selector(CostModelSelector())

"""Warm-start state for incremental LTSP re-solves.

Serving loops re-solve *slightly perturbed* instances over and over: one
arrival bumps a multiplicity, a preemption drops the files already served,
an abort removes one request.  The DP table rows that only cover unchanged
files are still valid — this module captures them after a solve
(:class:`WarmState`) and maps them into the next solve so only invalidated
cells are re-evaluated.

Why transfer is sound (and bit-identical)
-----------------------------------------
``T[a, b, s]`` (see :mod:`repro.core.dp`) is a function of *only* the
coordinate differences and multiplicities of requested files ``a..b``, the
U-turn penalty ``U``, the span restriction, and the combination
``w = s + n_l(a)``: every term of the recurrence — base, skip movement,
detour movement, U-turn charge — is a linear combination of coordinate
*differences* within ``[a, b]`` and of ``w`` plus multiplicity sums local to
``[a, b]``; the head-start position ``m`` never enters (only *VirtualLB*
does, which the caller recomputes from the new instance).  By induction the
same holds for every dependent cell, and the candidate scan order — skip
first, then ``c`` ascending, strict ``<`` to replace — is index-shifted but
order-preserved, so the argmin *choice* transfers too, ties included.

Concretely: align the new instance's requested files against the warm
instance's by exact ``(left, right, mult)`` equality (both are sorted with
strictly increasing ``left``, so a single merge walk suffices), then group
maximal runs that are contiguous *in both* instances into segments.  A cell
``(a, b, s)`` is transferable iff ``a`` and ``b`` fall in the same segment;
its warm twin is ``(a + off, b + off, s + delta)`` where ``off`` is the
segment's index offset and ``delta = n_l_new(a) - n_l_warm(a + off)`` — both
constant per segment because the multiplicities inside the segment match.
A warm choice ``c`` maps back as ``c - off`` (``-1`` = skip is unchanged).

Two store layouts back a :class:`WarmState`:

* :class:`DictStore` — the python DP's sparse ``memo``/``choice`` dicts,
  handed over by reference (no copy);
* :class:`DenseStore` — the device wavefront's dense value/argmin planes,
  kept in the kernel's gcd-rescaled int32 (or exact-f64) units together
  with the scale ``g``; lookups rescale to original units with python-int
  arithmetic, so no overflow guard is needed.  Dense cells outside the
  reachable envelope (``s`` too large for the padded skip axis) may hold
  clamped garbage, so :meth:`DenseStore.lookup` admits only cells whose
  entire dependency cone stays in range: ``s + sum(mult[a+1..b]) <= n``.

Reuse degrades gracefully: a warm state produced by a solve that itself
reused cells contains the reused cells' *values* but not their inner
structure, so a later solve that descends past them simply re-evaluates
(counted honestly in :class:`WarmStats`) — correctness never depends on
how much of the table transfers.
"""

from __future__ import annotations

import dataclasses

from .instance import Instance

__all__ = [
    "WarmState",
    "WarmStats",
    "DictStore",
    "DenseStore",
    "align_warm",
    "warm_from_instance",
]


@dataclasses.dataclass
class WarmStats:
    """Exact work accounting for one solve.

    ``cells_evaluated`` counts recurrence folds actually performed (for the
    dense device path: dense cells computed on device); ``cells_reused``
    counts cells installed or read from a warm state instead of being
    evaluated.  ``mode`` records which path ran: ``"cold"`` (no usable warm
    state), ``"warm"`` (some alignment existed — reuse may still be 0 if no
    aligned cell was needed), ``"cache"`` (memoised full solve, no DP work),
    or ``"unsupported"`` (policy/backend without warm support).
    """

    cells_evaluated: int = 0
    cells_reused: int = 0
    mode: str = "cold"


class DictStore:
    """Sparse store: the python DP's ``memo``/``choice`` dicts by reference."""

    kind = "dict"

    def __init__(
        self,
        memo: dict[tuple[int, int, int], int],
        choice: dict[tuple[int, int, int], int],
    ):
        self._memo = memo
        self._choice = choice

    def __len__(self) -> int:
        return len(self._memo)

    def lookup(self, a: int, b: int, s: int) -> tuple[int, int] | None:
        v = self._memo.get((a, b, s))
        if v is None:
            return None
        return v, self._choice[(a, b, s)]


class DenseStore:
    """Dense store: device value/argmin planes in gcd-rescaled units.

    ``table``/``choice`` are the ``[R_pad, R_pad, S_pad]`` planes of *one*
    instance (host numpy, int32 or f64); ``g`` is the
    :func:`repro.kernels.ltsp_dp.ops.rescale_instance` scale, so the
    original-unit value is ``g * int(table[a, b, s])`` (python ints — exact
    at any magnitude).  ``prefix[i] = sum(mult[:i+1])`` bounds the admissible
    ``s`` per cell (see the module docstring).
    """

    kind = "dense"

    def __init__(self, table, choice, g: int, n: int, prefix: list[int]):
        self._table = table
        self._choice = choice
        self._g = g
        self._n = n
        self._prefix = prefix

    def __len__(self) -> int:
        return int(self._table.size)

    def lookup(self, a: int, b: int, s: int) -> tuple[int, int] | None:
        # admit only cells whose whole dependency cone is inside the
        # reachable envelope: the deepest skip chain reads the diagonal at
        # s + sum(mult[a+1..b]), which must stay <= n (< S_pad).
        if s + self._prefix[b] - self._prefix[a] > self._n:
            return None
        return self._g * int(self._table[a, b, s]), int(self._choice[a, b, s])


class WarmState:
    """Reusable DP state captured from one solve of one instance.

    The signature (``left``/``right``/``mult``/``u_turn``/``span``) pins the
    instance and restriction the store was computed under; ``store`` is a
    :class:`DictStore` or :class:`DenseStore`.  Warm states are
    backend-agnostic — both stores answer in original integer units, so a
    state captured from a device solve warms a python solve and vice versa.
    """

    __slots__ = ("left", "right", "mult", "u_turn", "span", "nl", "n", "store")

    def __init__(
        self,
        left: tuple[int, ...],
        right: tuple[int, ...],
        mult: tuple[int, ...],
        u_turn: int,
        span: int | None,
        store,
    ):
        self.left = left
        self.right = right
        self.mult = mult
        self.u_turn = u_turn
        self.span = span
        nl = [0]
        for xi in mult[:-1]:
            nl.append(nl[-1] + xi)
        self.nl = nl
        self.n = (nl[-1] + mult[-1]) if mult else 0
        self.store = store


def warm_from_instance(inst: Instance, span: int | None, store) -> WarmState:
    """Wrap a just-solved instance's store into a :class:`WarmState`."""
    return WarmState(
        left=tuple(inst.left.tolist()),
        right=tuple(inst.right.tolist()),
        mult=tuple(inst.mult.tolist()),
        u_turn=inst.u_turn,
        span=span,
        store=store,
    )


class _Alignment:
    """Per-file mapping from a new instance into a warm state's instance."""

    __slots__ = ("map_idx", "seg", "delta", "off")

    def __init__(
        self,
        map_idx: list[int],
        seg: list[int],
        delta: list[int],
        off: list[int],
    ):
        self.map_idx = map_idx  # warm index of new file i, or -1
        self.seg = seg  # segment id of new file i, or -1
        self.delta = delta  # per-segment skip-count shift (s_warm = s + delta)
        self.off = off  # per-segment index offset (warm = new + off)


def align_warm(warm: WarmState | None, inst: Instance, span: int | None):
    """Match ``inst``'s files against ``warm``'s; ``None`` if nothing maps.

    Requires equal U-turn penalty and span restriction (both enter the
    recurrence).  Files match on exact ``(left, right, mult)``; maximal runs
    contiguous in both instances become segments (see the module docstring).
    """
    if warm is None or warm.u_turn != inst.u_turn or warm.span != span:
        return None
    n_left = inst.left.tolist()
    n_right = inst.right.tolist()
    n_mult = inst.mult.tolist()
    w_left, w_right, w_mult = warm.left, warm.right, warm.mult
    R, W = len(n_left), len(w_left)
    map_idx = [-1] * R
    i = j = 0
    matched = 0
    while i < R and j < W:
        li, lj = n_left[i], w_left[j]
        if li == lj:
            if n_right[i] == w_right[j] and n_mult[i] == w_mult[j]:
                map_idx[i] = j
                matched += 1
            i += 1
            j += 1
        elif li < lj:
            i += 1
        else:
            j += 1
    if not matched:
        return None
    # segments: maximal runs matched contiguously in *both* instances
    seg = [-1] * R
    delta: list[int] = []
    off: list[int] = []
    nl_new = 0
    for i in range(R):
        if map_idx[i] >= 0:
            if i > 0 and seg[i - 1] >= 0 and map_idx[i - 1] == map_idx[i] - 1:
                seg[i] = seg[i - 1]
            else:
                seg[i] = len(delta)
                delta.append(nl_new - warm.nl[map_idx[i]])
                off.append(map_idx[i] - i)
        nl_new += n_mult[i]
    return _Alignment(map_idx, seg, delta, off)

"""Exact trajectory simulator for LTSP detour schedules.

A *schedule* is described by a list of detours ``(a, b)`` over requested-file
indices (paper §4.1): while sweeping left from the right end of the tape, when
the head first reaches ``l(a)`` it U-turns, moves right to ``r(b)``, U-turns,
and resumes the leftward sweep.  Detours are executed in non-increasing order
of their left endpoint.  After the leftmost requested file is reached the head
performs the final left-to-right pass which serves every file still unread
(the implicit global detour ``(f_1, f_{n_f})``).

A request on file ``f`` is served the first time ``f`` is fully traversed
left-to-right.  Every U-turn costs ``U`` time.  This simulator is the single
source of truth against which every algorithm (DP included) is scored, exactly
as the paper scores the list of detours emitted by each algorithm.

Everything is exact integer arithmetic.
"""

from __future__ import annotations

from typing import Iterable, Sequence

import numpy as np

from .instance import Instance, virtual_lb

__all__ = [
    "evaluate_detours",
    "service_times",
    "no_detour_cost",
    "schedule_makespan",
    "lower_bound_gap",
]


def _normalise(detours: Iterable[tuple[int, int]], n_req: int) -> list[tuple[int, int]]:
    """Sort detours for execution and sanity-check indices."""
    seen = set()
    out = []
    for a, b in detours:
        a, b = int(a), int(b)
        if not (0 <= a <= b < n_req):
            raise ValueError(f"detour ({a},{b}) out of range for n_req={n_req}")
        if (a, b) not in seen:
            seen.add((a, b))
            out.append((a, b))
    # executed while sweeping left: decreasing left endpoint; for equal left
    # endpoints execute the shorter detour first (it is encountered "inside").
    out.sort(key=lambda ab: (-ab[0], ab[1]))
    return out


def service_times(inst: Instance, detours: Iterable[tuple[int, int]]) -> np.ndarray:
    """Exact service time of each requested file under the detour schedule.

    Returns ``t`` with ``t[i]`` = time at which file ``i``'s requests are all
    served (they are served simultaneously: a file is read once).
    """
    R = inst.n_req
    dets = _normalise(detours, R)
    left, right = inst.left, inst.right
    U = inst.u_turn

    served = np.zeros(R, dtype=bool)
    t_serve = np.full(R, -1, dtype=np.int64)

    t = 0  # clock
    pos = inst.m  # head position, currently sweeping left

    def pass_right(to: int) -> None:
        """U-turn at ``pos`` then move right to ``to``, serving files."""
        nonlocal t, pos
        t += U  # U-turn penalty before the rightward movement
        # files fully inside [pos, to] and not yet served
        idx = np.nonzero((~served) & (left >= pos) & (right <= to))[0]
        for i in idx:
            t_serve[i] = t + (right[i] - pos)
            served[i] = True
        t += to - pos
        pos = to

    def move_left(to: int) -> None:
        nonlocal t, pos
        if to > pos:
            raise ValueError("leftward move target is right of head")
        t += pos - to
        pos = to

    for a, b in dets:
        if left[a] > pos:
            # Detour starts right of the head: it was nested inside an earlier
            # detour with the same or righter span and reads nothing new.
            # Execute it as a null movement (matches 'useless detour' in Fig 2
            # being representable); a well-formed algorithm never emits this.
            continue
        move_left(left[a])
        pass_right(right[b])
        t += U  # U-turn at r(b) back to the leftward sweep

    # final pass: reach the leftmost requested file, then serve the rest
    move_left(left[0])
    if not served.all():
        to = right[int(np.nonzero(~served)[0].max())]
        pass_right(to)
    if not served.all():  # pragma: no cover - defensive
        raise AssertionError("schedule failed to serve every file")
    return t_serve


def evaluate_detours(inst: Instance, detours: Iterable[tuple[int, int]]) -> int:
    """Sum of service times (the LTSP objective) of a detour schedule."""
    t = service_times(inst, detours)
    # Python-int accumulation to avoid int64 overflow on extreme instances.
    return sum(int(m) * int(ti) for m, ti in zip(inst.mult, t))


def no_detour_cost(inst: Instance) -> int:
    """Cost of the NODETOUR schedule (empty detour list)."""
    return evaluate_detours(inst, [])


def schedule_makespan(inst: Instance, detours: Iterable[tuple[int, int]]) -> int:
    """Time at which the last request is served."""
    return int(service_times(inst, detours).max())


def lower_bound_gap(inst: Instance, cost: int) -> float:
    """cost / VirtualLB, a unitless quality measure (>= 1 is not guaranteed
    for VirtualLB == 0 degenerate instances; guarded)."""
    lb = virtual_lb(inst)
    return float(cost) / float(lb) if lb > 0 else float("inf")

"""Dataset substrate: synthetic IN2P3-like tape workloads + adversarial families."""

from .generator import (
    BENCH_PROFILE,
    DatasetProfile,
    PAPER_PROFILE,
    SMALL_PROFILE,
    generate_instance,
    generate_dataset,
    u_turn_values,
)
from .paper_instances import (
    gs_worst_case,
    simpledp_worst_case,
    logdp_worst_case,
)

__all__ = [
    "DatasetProfile",
    "PAPER_PROFILE",
    "SMALL_PROFILE",
    "BENCH_PROFILE",
    "generate_instance",
    "generate_dataset",
    "u_turn_values",
    "gs_worst_case",
    "simpledp_worst_case",
    "logdp_worst_case",
]

"""Dataset substrate: synthetic IN2P3-like tape workloads + adversarial families."""

from .generator import (
    BENCH_PROFILE,
    DatasetProfile,
    PAPER_PROFILE,
    SMALL_PROFILE,
    generate_instance,
    generate_dataset,
    u_turn_values,
)
from .paper_instances import (
    gs_worst_case,
    simpledp_worst_case,
    logdp_worst_case,
)
from .traces import (
    DEFAULT_QOS_CLASSES,
    TRACE_SCHEMA,
    TraceRecord,
    qos_poisson_trace,
    read_trace,
    records_of,
    to_requests,
    write_trace,
)

__all__ = [
    "DatasetProfile",
    "PAPER_PROFILE",
    "SMALL_PROFILE",
    "BENCH_PROFILE",
    "generate_instance",
    "generate_dataset",
    "u_turn_values",
    "gs_worst_case",
    "simpledp_worst_case",
    "logdp_worst_case",
    "TRACE_SCHEMA",
    "DEFAULT_QOS_CLASSES",
    "TraceRecord",
    "write_trace",
    "read_trace",
    "to_requests",
    "records_of",
    "qos_poisson_trace",
]

"""The paper's adversarial instance families (approximation-ratio witnesses).

* :func:`gs_worst_case`       — GS approaches its factor 3 (U=0): a small,
  heavily requested file on the left of one large file spanning the tape.
* :func:`simpledp_worst_case` — Lemma 2's family where forbidding intertwined
  detours costs a factor approaching 5/3.
* :func:`logdp_worst_case`    — §4.5's family where bounding detour spans
  keeps LOGDP at ratio ~3 (U = 0).
"""

from __future__ import annotations

from ..core.instance import Instance, make_instance

__all__ = ["gs_worst_case", "simpledp_worst_case", "logdp_worst_case"]


def gs_worst_case(big: int = 10_000, requests: int = 10_000) -> Instance:
    """f1: unit file with many requests; f2: huge file, single request."""
    return make_instance(
        left=[0, 1],
        size=[1, big],
        mult=[requests, 1],
        m=1 + big,
        u_turn=0,
    )


def simpledp_worst_case(z: int = 50) -> Instance:
    """Lemma 2 family: OPT uses intertwined detours, SIMPLEDP cannot.

    f1 far left (forces detours); f2, f3 urgent unit files separated so that
    r(f4) - l(f2) = 2z; f4 large (size z), less urgent, contiguous to f3.
    OPT ~ 3 z^3 via detours [(f3,f3), (f2,f4)]; any non-intertwined solution
    costs >= ~5 z^3.
    """
    l2 = 3 * z * z
    return make_instance(
        left=[0, l2, l2 + z - 1, l2 + z],
        size=[1, 1, 1, z],
        mult=[1, z * z, z * z, z],
        m=l2 + 2 * z,
        u_turn=0,
    )


def logdp_worst_case(z: int = 40) -> Instance:
    """§4.5 family: z requested files; one far-left non-urgent unit file, then
    z-1 contiguous files starting at 2 z^3 — unit sized except the last of
    size z^2; x(f2) = z^2 (urgent), x(f_z) = z, others 1."""
    left = [0]
    size = [1]
    mult = [1]
    for i in range(z - 1):
        left.append(2 * z**3 + i)
        size.append(1 if i < z - 2 else z * z)
        mult.append(1)
    mult[1] = z * z  # f2 urgent
    mult[-1] = z  # f_z less urgent
    m = left[-1] + size[-1]
    return make_instance(left=left, size=size, mult=mult, m=m, u_turn=0)

"""Synthetic tape-workload generator calibrated to the paper's dataset.

The real IN2P3 dataset (paper Appendix C.1) is not redistributable here, so we
generate instances whose marginal statistics match the published Tables 1-2:

  =========================  =====  ======  =====  ======
  statistic                   min   median   mean    max
  =========================  =====  ======  =====  ======
  files per tape (n_f)        111     490     709   4,142
  requested files (n_req)      31     148     170     852
  total requests (n)        1,182   2,669   3,640  15,477
  avg file size (GB)          4.9      40      50     167
  file-size CV (%)              6      56      94     379
  =========================  =====  ======  =====  ======

Tapes are 20 TB Jaguar E cartridges; sizes are drawn lognormal with a
per-tape coefficient of variation, multiplicities are Zipf-like (aggregates
replace per-file requests, hence the heavy tail).  Positions are integer MB,
keeping every algorithm exact while staying far from int64 limits.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from ..core.instance import Instance, make_instance

__all__ = [
    "DatasetProfile",
    "PAPER_PROFILE",
    "SMALL_PROFILE",
    "generate_instance",
    "generate_dataset",
    "u_turn_values",
]

MB = 1
GB = 1000 * MB
TB = 1000 * GB
TAPE_CAPACITY = 20 * TB  # Jaguar E


@dataclasses.dataclass(frozen=True)
class DatasetProfile:
    """Statistical profile for the generator."""

    name: str
    n_tapes: int
    # lognormal parameters for files-per-tape, clipped to [lo, hi]
    nf_median: float
    nf_sigma: float
    nf_clip: tuple[int, int]
    # fraction of files requested, clipped
    req_frac_median: float
    req_frac_sigma: float
    req_frac_clip: tuple[float, float]
    # per-file request multiplicity: 1 + Zipf(alpha), capped
    mult_alpha: float
    mult_cap: int
    # absolute cap on requested files per tape (paper max: 852)
    n_req_cap: int
    # per-tape file-size coefficient of variation, lognormal, clipped
    cv_median: float
    cv_sigma: float
    cv_clip: tuple[float, float]
    tape_capacity: int = TAPE_CAPACITY


#: Matches the published IN2P3 statistics (use for paper-scale runs).
PAPER_PROFILE = DatasetProfile(
    name="paper",
    n_tapes=169,
    nf_median=490.0,
    nf_sigma=0.78,
    nf_clip=(111, 4142),
    req_frac_median=0.22,
    req_frac_sigma=0.55,
    req_frac_clip=(0.04, 0.80),
    mult_alpha=1.5,
    mult_cap=350,
    n_req_cap=860,
    cv_median=0.56,
    cv_sigma=0.80,
    cv_clip=(0.06, 3.79),
)

#: ~10x smaller instances for CI/benchmarks (same shape of distributions).
SMALL_PROFILE = dataclasses.replace(
    PAPER_PROFILE,
    name="small",
    n_tapes=40,
    nf_median=60.0,
    nf_clip=(16, 400),
    mult_cap=120,
    n_req_cap=120,
)

#: benchmark default: bounded so the exact DP finishes in ~1s/instance (the
#: paper's own single-thread Python DP needs minutes at full scale).
BENCH_PROFILE = dataclasses.replace(
    SMALL_PROFILE,
    name="bench",
    n_tapes=30,
    nf_clip=(16, 200),
    mult_cap=60,
    n_req_cap=44,
)


def _lognormal(rng: np.ndarray, median: float, sigma: float, lo, hi):
    v = median * np.exp(sigma * rng)
    return np.clip(v, lo, hi)


def generate_instance(
    profile: DatasetProfile, seed: int, u_turn: int = 0
) -> Instance:
    """Generate one tape (one LTSP instance) from the profile."""
    rng = np.random.default_rng(seed)

    n_f = int(_lognormal(rng.standard_normal(), profile.nf_median, profile.nf_sigma, *profile.nf_clip))
    frac = float(
        _lognormal(rng.standard_normal(), profile.req_frac_median, profile.req_frac_sigma, *profile.req_frac_clip)
    )
    n_req = max(2, min(n_f, profile.n_req_cap, int(round(frac * n_f))))
    cv = float(_lognormal(rng.standard_normal(), profile.cv_median, profile.cv_sigma, *profile.cv_clip))

    # lognormal sizes with target mean (tape full) and coefficient of variation
    mean_size = profile.tape_capacity / n_f
    sigma2 = np.log1p(cv**2)
    mu = np.log(mean_size) - sigma2 / 2
    sizes = np.exp(rng.normal(mu, np.sqrt(sigma2), size=n_f))
    sizes = np.maximum(1, np.round(sizes * profile.tape_capacity / sizes.sum())).astype(np.int64)

    # files are written back-to-back (segments), left to right
    lefts_all = np.concatenate([[0], np.cumsum(sizes)[:-1]])
    m = int(sizes.sum())

    # which files are requested + Zipf-like multiplicities
    req_idx = np.sort(rng.choice(n_f, size=n_req, replace=False))
    mult = 1 + np.minimum(rng.zipf(profile.mult_alpha, size=n_req), profile.mult_cap - 1)

    return make_instance(
        left=lefts_all[req_idx],
        size=sizes[req_idx],
        mult=mult.astype(np.int64),
        m=m,
        u_turn=u_turn,
    )


def generate_dataset(
    profile: DatasetProfile = SMALL_PROFILE, u_turn: int = 0, base_seed: int = 20210917
) -> list[Instance]:
    """Generate the full multi-tape dataset (one Instance per tape)."""
    return [
        generate_instance(profile, seed=base_seed + i, u_turn=u_turn)
        for i in range(profile.n_tapes)
    ]


def u_turn_values(instances: list[Instance]) -> dict[str, int]:
    """Paper §5.3's three U-turn penalties: 0, half the average segment size
    across the dataset, and the average segment size."""
    tot = sum(int(i.size.sum()) for i in instances)
    cnt = sum(i.n_req for i in instances)
    avg_seg = tot // max(1, cnt)
    return {"zero": 0, "half_seg": avg_seg // 2, "full_seg": avg_seg}

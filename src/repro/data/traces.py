"""Recorded request traces: a JSONL format with deadlines and QoS classes.

The paper evaluates against *replayed logs of a real mass-storage system*;
this module gives the online-serving stack the same capability: a trace is a
list of :class:`TraceRecord` rows (arrival, tape, file, multiplicity,
deadline, class), serialised one JSON object per line.  The writer is
byte-deterministic (sorted keys, fixed separators), so a trace round-trips
**bit-exactly** through ``write_trace -> read_trace`` — and, expanded by
:func:`to_requests`, replays to the identical
:class:`~repro.serving.sim.ServiceReport` timeline.

Three surfaces:

* :func:`write_trace` / :func:`read_trace` — the JSONL round trip.
* :func:`to_requests` — expand records (multiplicity becomes that many
  requests) into the ``(trace, qos)`` pair
  :func:`repro.serving.queue.serve_trace` consumes: a sorted
  :class:`~repro.serving.sim.Request` list plus the ``req_id ->``
  :class:`~repro.serving.qos.QoSSpec` map.  :func:`records_of` is the
  inverse (one record per request).
* :func:`qos_poisson_trace` — the deadline/class-annotated extension of
  :func:`repro.serving.sim.poisson_trace`: identical seeded arrival process
  (same seed -> same arrivals/files), plus a seeded class draw
  (:data:`DEFAULT_QOS_CLASSES`) assigning each request a slack multiplier;
  ``deadline = arrival + tightness * slack_multiplier``, exact ints.  The
  ``tightness`` knob sweeps deadline pressure without touching arrivals.
"""

from __future__ import annotations

import dataclasses
import json
import pathlib
from typing import Iterable, Mapping, Sequence

import numpy as np

from ..serving.qos import DEFAULT_CLASS, QoSSpec
from ..serving.sim import Request, poisson_trace

__all__ = [
    "TRACE_SCHEMA",
    "TRACE_SCHEMA_V2",
    "DEFAULT_QOS_CLASSES",
    "TraceRecord",
    "write_trace",
    "read_trace",
    "to_requests",
    "records_of",
    "qos_poisson_trace",
]

#: schema tag written into (and required from) every trace file's header line.
#: v1 traces carry no ``library`` field; the writer only emits the v2 tag
#: when at least one record uses it, so a v1 file round-trips byte-identically.
TRACE_SCHEMA = "ltsp-trace/v1"
TRACE_SCHEMA_V2 = "ltsp-trace/v2"
_TRACE_SCHEMAS = (TRACE_SCHEMA, TRACE_SCHEMA_V2)

#: (class name, draw weight, slack multiplier): interactive users get tight
#: deadlines, batch jobs sixteen times the slack.  Weights are relative.
DEFAULT_QOS_CLASSES: tuple[tuple[str, float, int], ...] = (
    ("interactive", 0.25, 1),
    ("production", 0.50, 4),
    ("batch", 0.25, 16),
)


@dataclasses.dataclass(frozen=True)
class TraceRecord:
    """One recorded arrival: ``multiplicity`` reads of ``file`` on ``tape``.

    ``deadline`` is absolute virtual time (``None`` = best-effort) and
    applies to every expanded request of the record; ``qos_class`` is the
    priority-class label carried into the
    :class:`~repro.serving.qos.QoSSpec`.
    """

    arrival: int
    tape: str
    file: str
    multiplicity: int = 1
    deadline: int | None = None
    qos_class: str = DEFAULT_CLASS
    #: origin-library label for federated (multi-library) traces; ``None``
    #: (the default, and the only v1 value) expands and replays identically
    #: to a pre-fleet record — the field is advisory routing metadata, never
    #: part of the expansion.
    library: str | None = None

    def __post_init__(self) -> None:
        if self.arrival < 0:
            raise ValueError("arrival must be >= 0")
        if self.multiplicity < 1:
            raise ValueError("multiplicity must be >= 1")
        if self.deadline is not None and self.deadline < self.arrival:
            raise ValueError(
                f"deadline {self.deadline} precedes arrival {self.arrival}"
            )
        if not self.qos_class:
            raise ValueError("qos_class must be a non-empty label")
        if self.library is not None and not self.library:
            raise ValueError("library must be a non-empty label (or None)")


def write_trace(path, records: Iterable[TraceRecord]) -> pathlib.Path:
    """Serialise records as JSONL (schema header + one object per line).

    Output bytes are deterministic: sorted keys, fixed separators, ``\\n``
    line ends — ``write(read(write(r)))`` is byte-identical to
    ``write(r)``.
    """
    path = pathlib.Path(path)
    records = list(records)
    # schema-versioned: the v2 tag (and the ``library`` key) only appear when
    # a record actually carries a library, so pre-fleet traces keep writing
    # the exact v1 bytes they always did
    fleet = any(rec.library is not None for rec in records)
    schema = TRACE_SCHEMA_V2 if fleet else TRACE_SCHEMA
    lines = [json.dumps({"schema": schema}, sort_keys=True, separators=(",", ":"))]
    for rec in records:
        row = dataclasses.asdict(rec)
        if row["library"] is None:
            del row["library"]
        lines.append(json.dumps(row, sort_keys=True, separators=(",", ":")))
    path.write_text("\n".join(lines) + "\n")
    return path


def read_trace(path) -> list[TraceRecord]:
    """Parse a JSONL trace written by :func:`write_trace` (strict)."""
    path = pathlib.Path(path)
    fields = {f.name for f in dataclasses.fields(TraceRecord)}
    records: list[TraceRecord] = []
    schema: str | None = None
    for lineno, line in enumerate(path.read_text().splitlines(), start=1):
        if not line.strip():
            continue
        try:
            obj = json.loads(line)
        except json.JSONDecodeError as e:
            raise ValueError(f"{path}:{lineno}: not valid JSON ({e})") from None
        if not isinstance(obj, dict):
            raise ValueError(f"{path}:{lineno}: expected a JSON object")
        if "schema" in obj:
            if obj["schema"] not in _TRACE_SCHEMAS:
                raise ValueError(
                    f"{path}:{lineno}: unsupported schema {obj['schema']!r} "
                    f"(expected one of {_TRACE_SCHEMAS})"
                )
            schema = obj["schema"]
            continue
        unknown = set(obj) - fields
        if unknown:
            raise ValueError(f"{path}:{lineno}: unknown field(s) {sorted(unknown)}")
        if "library" in obj and schema == TRACE_SCHEMA:
            # strictness the schema tag buys: a v1 file smuggling the v2
            # field is malformed, not silently accepted
            raise ValueError(
                f"{path}:{lineno}: 'library' needs a {TRACE_SCHEMA_V2!r} header"
            )
        try:
            records.append(TraceRecord(**obj))
        except (TypeError, ValueError) as e:
            raise ValueError(f"{path}:{lineno}: bad record ({e})") from None
    if schema is None:
        raise ValueError(f"{path}: missing {TRACE_SCHEMA!r} schema header line")
    return records


def to_requests(
    records: Sequence[TraceRecord], library=None
) -> tuple[list[Request], dict[int, QoSSpec]]:
    """Expand records into the ``(trace, qos)`` pair the server consumes.

    Records are ordered by arrival (stable on ties, so the file's row order
    is the tie-break) and each record expands into ``multiplicity`` requests
    with consecutive ids — deterministic, so replaying a read-back trace
    reproduces the original run bit for bit.  Passing the target
    :class:`~repro.storage.tape.TapeLibrary` validates that every record's
    file exists and lives on the tape the record claims.
    """
    if library is not None:
        for rec in records:
            actual = library.location.get(rec.file)
            if actual is None:
                raise ValueError(f"trace file {rec.file!r} not in the library")
            if actual != rec.tape:
                raise ValueError(
                    f"trace file {rec.file!r} is on {actual}, not {rec.tape!r}"
                )
    trace: list[Request] = []
    qos: dict[int, QoSSpec] = {}
    rid = 0
    for rec in sorted(records, key=lambda r: r.arrival):
        spec = QoSSpec(deadline=rec.deadline, qos_class=rec.qos_class)
        for _ in range(rec.multiplicity):
            trace.append(
                Request(time=rec.arrival, req_id=rid, tape_id=rec.tape, name=rec.file)
            )
            qos[rid] = spec
            rid += 1
    return trace, qos


def records_of(
    trace: Sequence[Request], qos: Mapping[int, QoSSpec] | None = None
) -> list[TraceRecord]:
    """One record per request (multiplicity 1): the :func:`to_requests` inverse."""
    qos = qos or {}
    default = QoSSpec()
    out = []
    for req in sorted(trace):
        spec = qos.get(req.req_id, default)
        out.append(
            TraceRecord(
                arrival=req.time,
                tape=req.tape_id,
                file=req.name,
                multiplicity=1,
                deadline=spec.deadline,
                qos_class=spec.qos_class,
            )
        )
    return out


def qos_poisson_trace(
    library,
    n_requests: int,
    mean_interarrival: int,
    seed: int,
    skew: float = 1.1,
    tightness: int = 4_000_000,
    classes: tuple[tuple[str, float, int], ...] = DEFAULT_QOS_CLASSES,
    libraries: Sequence[str] | None = None,
) -> list[TraceRecord]:
    """Deadline/class-annotated seeded trace (extends ``poisson_trace``).

    The arrival process is *exactly* :func:`repro.serving.sim.poisson_trace`
    with the same arguments — a QoS-annotated trace and its plain twin share
    arrivals bit for bit, so miss-rate comparisons isolate the admission
    policy.  An independent seeded stream then draws each request's class
    from ``classes`` and sets ``deadline = arrival + tightness *
    slack_multiplier`` (exact ints; ``tightness`` is the deadline-pressure
    knob the benchmarks sweep).

    ``libraries`` names the shards of a federation: when given, a *third*
    independent seeded stream draws each record's origin ``library`` label
    uniformly from the sequence.  The draw never perturbs arrivals, files,
    classes, or deadlines (separate :class:`numpy.random.SeedSequence`
    branch), so a fleet trace and its single-library twin replay the same
    workload — and the labels round-trip through :func:`write_trace` under
    the v2 schema.
    """
    if tightness < 1:
        raise ValueError("tightness must be >= 1")
    if not classes:
        raise ValueError("classes must be non-empty")
    if libraries is not None and not libraries:
        raise ValueError("libraries must be non-empty when given")
    base = poisson_trace(library, n_requests, mean_interarrival, seed, skew)
    rng = np.random.default_rng(np.random.SeedSequence([seed, 0x51A0]))
    weights = np.array([w for _, w, _ in classes], dtype=float)
    weights /= weights.sum()
    picks = rng.choice(len(classes), size=len(base), p=weights)
    lib_labels: list[str | None] = [None] * len(base)
    if libraries is not None:
        lib_rng = np.random.default_rng(np.random.SeedSequence([seed, 0xF1EE]))
        draws = lib_rng.integers(0, len(libraries), size=len(base))
        lib_labels = [str(libraries[int(d)]) for d in draws]
    records = []
    for req, pick, lib_label in zip(base, picks, lib_labels):
        name, _, slack_mult = classes[int(pick)]
        records.append(
            TraceRecord(
                arrival=req.time,
                tape=req.tape_id,
                file=req.name,
                multiplicity=1,
                deadline=req.time + tightness * int(slack_mult),
                qos_class=name,
                library=lib_label,
            )
        )
    return records

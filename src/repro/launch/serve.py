"""Production serving launcher: batched greedy decoding with sharded caches,
optionally warm-started from the tape-archive tier.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen2.5-3b --reduced \
      --batch 8 --new-tokens 32

``--restore-from-tape`` simulates the cold-start path: the checkpoint shards
are archived to the tape library and the restore reads are ordered by an LTSP
solver from the registry (``--tape-policy``, any of
``repro.core.list_solvers()``; ``--tape-backend`` python / pallas /
pallas-interpret), reporting the mean shard arrival time the serving fleet
would observe before weights are resident.
"""

from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import ARCHS, reduced
from ..core.solver import BACKENDS, DEFAULT_BACKEND, list_solvers
from ..distributed.context import set_active_mesh
from ..distributed.sharding import cache_pspecs, param_pspecs, to_shardings
from ..models.model import init_cache, init_model
from ..serving.serve import make_serve_step
from .train import _auto_mesh


def _restore_from_tape(params, policy: str, backend: str) -> None:
    """Archive ``params`` to a simulated tape library and plan the restore.

    The library owns a :class:`~repro.core.SolveCache`, so the re-plan a
    recovering serving fleet issues for the *same* archive (every cold start
    requests the identical shard multiset per cartridge) never re-solves a
    tape — the second pass below is all cache hits and its time is the pure
    memo-lookup cost.
    """
    from ..core.solver import SolveCache
    from ..distributed.checkpoint import archive_to_tape, plan_restore
    from ..storage.tape import TapeLibrary

    lib = TapeLibrary(
        capacity_per_tape=4 * 10**6, u_turn=20_000, cache=SolveCache()
    )
    shards = archive_to_tape(lib, "serve-warmup", params, bytes_per_elem=1)
    consumers = {s: 2 for s in shards}  # every host group needs every shard
    t0 = time.time()
    try:
        plans = plan_restore(lib, shards, consumers, policy=policy, backend=backend)
    except ValueError as e:
        # unsupported policy/backend combo or the int32 device-DP magnitude
        # guard — cold-start planning must not kill the serving launcher
        print(f"tape restore [{policy}/{backend}] unavailable: {e}\n"
              f" -> falling back to backend='python'")
        backend = "python"
        lib.cache.clear()  # drop the failed attempt's miss counts
        plans = plan_restore(lib, shards, consumers, policy=policy, backend=backend)
    dt = time.time() - t0
    # warm re-plan: what the next cold start in the fleet pays
    t0 = time.time()
    plan_restore(lib, shards, consumers, policy=policy, backend=backend)
    dt_warm = time.time() - t0
    n_req = sum(consumers.values())
    mean = sum(p.total_cost for p in plans) / n_req
    last = max(max(p.service_time.values()) for p in plans)
    stats = lib.cache.stats()
    print(
        f"tape restore [{policy}/{backend}]: {len(shards)} shards on "
        f"{len(lib.tapes)} tape(s), mean arrival {mean:.3g}, last {last:.3g} "
        f"(planned in {dt * 1e3:.0f} ms; re-plan {dt_warm * 1e3:.0f} ms, "
        f"cache {stats['hits']} hits / {stats['misses']} misses)"
    )


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-3b", choices=sorted(ARCHS))
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=32)
    ap.add_argument("--mesh", default="auto", choices=["auto", "pod", "multipod"])
    ap.add_argument("--restore-from-tape", action="store_true",
                    help="simulate an LTSP-scheduled checkpoint restore first")
    ap.add_argument("--tape-policy", default="dp", choices=list_solvers())
    ap.add_argument("--tape-backend", default=DEFAULT_BACKEND, choices=list(BACKENDS))
    args = ap.parse_args()

    cfg = ARCHS[args.arch]
    if args.reduced:
        cfg = reduced(cfg, periods=2)
        cfg = dataclasses.replace(cfg, vocab_size=min(cfg.vocab_size, 32768))

    mesh = _auto_mesh(args.mesh)
    set_active_mesh(mesh)
    max_len = args.prompt_len + args.new_tokens

    params = init_model(jax.random.PRNGKey(0), cfg)
    if args.restore_from_tape:
        _restore_from_tape(params, args.tape_policy, args.tape_backend)
    params = jax.device_put(params, to_shardings(param_pspecs(params), mesh, params))
    cache = init_cache(cfg, args.batch, max_len=max_len)
    cache = jax.device_put(cache, to_shardings(cache_pspecs(cache, mesh), mesh))

    serve = jax.jit(make_serve_step(cfg))
    prompts = jax.random.randint(
        jax.random.PRNGKey(1), (args.batch, args.prompt_len), 0, cfg.vocab_size
    )
    with mesh:
        for t in range(args.prompt_len - 1):  # teacher-forced prefill
            _, _, cache = serve(params, cache, prompts[:, t : t + 1], jnp.int32(t))
        tok = prompts[:, -1:]
        t0 = time.time()
        outs = []
        for t in range(args.new_tokens):
            tok, _, cache = serve(params, cache, tok, jnp.int32(args.prompt_len - 1 + t))
            outs.append(np.asarray(tok))
        jax.block_until_ready(tok)
    dt = time.time() - t0
    set_active_mesh(None)
    print(f"{cfg.arch_id}: {args.batch}x{args.new_tokens} tokens in {dt:.2f}s "
          f"({args.batch*args.new_tokens/dt:.0f} tok/s)")
    print("first sequence:", np.concatenate(outs, 1)[0][:16].tolist(), "...")


if __name__ == "__main__":
    main()

"""Production serving launcher: batched greedy decoding with sharded caches.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen2.5-3b --reduced \
      --batch 8 --new-tokens 32
"""

from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import ARCHS, reduced
from ..distributed.context import set_active_mesh
from ..distributed.sharding import cache_pspecs, param_pspecs, to_shardings
from ..models.model import init_cache, init_model
from ..serving.serve import make_serve_step
from .train import _auto_mesh


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-3b", choices=sorted(ARCHS))
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=32)
    ap.add_argument("--mesh", default="auto", choices=["auto", "pod", "multipod"])
    args = ap.parse_args()

    cfg = ARCHS[args.arch]
    if args.reduced:
        cfg = reduced(cfg, periods=2)
        cfg = dataclasses.replace(cfg, vocab_size=min(cfg.vocab_size, 32768))

    mesh = _auto_mesh(args.mesh)
    set_active_mesh(mesh)
    max_len = args.prompt_len + args.new_tokens

    params = init_model(jax.random.PRNGKey(0), cfg)
    params = jax.device_put(params, to_shardings(param_pspecs(params), mesh, params))
    cache = init_cache(cfg, args.batch, max_len=max_len)
    cache = jax.device_put(cache, to_shardings(cache_pspecs(cache, mesh), mesh))

    serve = jax.jit(make_serve_step(cfg))
    prompts = jax.random.randint(
        jax.random.PRNGKey(1), (args.batch, args.prompt_len), 0, cfg.vocab_size
    )
    with mesh:
        for t in range(args.prompt_len - 1):  # teacher-forced prefill
            _, _, cache = serve(params, cache, prompts[:, t : t + 1], jnp.int32(t))
        tok = prompts[:, -1:]
        t0 = time.time()
        outs = []
        for t in range(args.new_tokens):
            tok, _, cache = serve(params, cache, tok, jnp.int32(args.prompt_len - 1 + t))
            outs.append(np.asarray(tok))
        jax.block_until_ready(tok)
    dt = time.time() - t0
    set_active_mesh(None)
    print(f"{cfg.arch_id}: {args.batch}x{args.new_tokens} tokens in {dt:.2f}s "
          f"({args.batch*args.new_tokens/dt:.0f} tok/s)")
    print("first sequence:", np.concatenate(outs, 1)[0][:16].tolist(), "...")


if __name__ == "__main__":
    main()

"""Production serving launcher: batched greedy decoding with sharded caches,
optionally warm-started from the tape-archive tier.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen2.5-3b --reduced \
      --batch 8 --new-tokens 32

``--restore-from-tape`` simulates the cold-start path: the checkpoint shards
are archived to the tape library and the restore reads are ordered by an LTSP
solver from the registry (``--tape-policy``, any of
``repro.core.list_solvers()``; ``--tape-backend`` builds the
:class:`~repro.core.ExecutionContext` the planner runs under), reporting the
mean shard arrival time the serving fleet would observe before weights are
resident.

Online tape serving (``--serve-tape-queue``)
--------------------------------------------
The tape tier also serves *online*: read requests arrive while drives are
busy, so batch composition — and, with a shared
:class:`~repro.serving.drives.DrivePool`, *which cartridge each drive mounts
next* — is a scheduling decision, not a given.  This mode drives
:mod:`repro.serving.queue`: per-cartridge request queues, ``--tape-drives``
drives shared across all cartridges (default: one per cartridge), an
explicit mount cost model (``--tape-mount-cost`` / ``--tape-unmount-cost`` /
``--tape-load-seek``), and a pluggable **admission policy**:

* ``fifo`` / ``fifo-global`` — per-request solving in global arrival order
  (every request pays a full seek from the load point; the baseline);
* ``accumulate`` / ``per-drive-accumulate`` — accumulate-then-solve: a free
  drive mounts the cartridge whose oldest request has waited
  ``--tape-window`` time units and serves its whole queue (``0`` = greedy
  batching on drive-free);
* ``preempt`` — greedy batching plus preemptive re-solve: an arrival mid-batch
  aborts the in-flight plan, keeps already-served completions, rewinds, and
  re-solves the survivors together with the newcomer;
* ``batched`` — cross-cartridge device batching: all mount-ready cartridges
  in an event tick are planned via a **single** ``solve_batch`` bucketed
  launch;
* ``edf-global`` / ``slack-accumulate`` — the deadline-aware (QoS)
  admissions: earliest-deadline-first per-request serving, and
  accumulate-then-solve whose hold window collapses as a queued request's
  slack burns down.  They need deadlines on the trace: pass
  ``--tape-tightness`` to annotate the generated trace
  (:func:`repro.data.traces.qos_poisson_trace`) or replay a recorded one.

**Recorded traces & SLOs** — ``--trace-file PATH`` replays a JSONL trace
(:mod:`repro.data.traces`: arrival, tape, file, multiplicity, deadline,
class) instead of generating one; ``--record-trace PATH`` writes the trace
that was served (round-trips bit-exactly).  ``--tape-scheduler`` picks the
drive-eviction policy (``greedy`` / ``lru`` / ``lookahead``,
:data:`repro.serving.drives.MOUNT_SCHEDULERS`).  With deadlines present the
table gains deadline-miss columns, and ``--slo-target RATE`` turns the run
into a check: exit status 1 unless some swept admission meets the target
miss rate.

**Load-adaptive solver selection** — ``--tape-selector`` (any of
``repro.core.list_selectors()``: ``fixed`` / ``depth-threshold`` /
``cost-model``) lets the server re-pick the solve policy *each tick* from
queue depth and recorded per-tick solve timings instead of pinning
``--tape-policy`` for the whole run: exact DP when queues are shallow,
restricted DP / heuristics as depth grows.  ``--tape-budget CELLS`` sets
the per-tick DP cell budget the ``cost-model`` selector fits under
(:class:`~repro.core.ComputeBudget`).  The table gains a ``policy_mix``
column showing how many batches each policy actually planned.

**Warm starts & persistent caching** — re-solving admissions warm-start
each cartridge's DP from the previous tick's table by default
(bit-identical schedules, fewer DP cells evaluated; disable with
``--no-tape-warm`` to A/B the work counters).  ``--tape-cache-file PATH``
swaps the in-process solve memo for a persistent
:class:`~repro.core.JsonlCacheBackend`: re-running the launcher against the
same path replays the journal into memo hits, the restart story for a
serving fleet.

**Observability** — ``--tape-trace-out PATH`` attaches the opt-in
:class:`~repro.obs.Observability` bundle and exports the run's
virtual-time span log as byte-deterministic JSONL at ``PATH`` plus a
Chrome ``trace_event`` file at ``PATH + ".chrome.json"`` (one Perfetto
track per drive/queue/router, one process per fleet shard);
``--tape-metrics-out PATH`` writes the exact-int counter/histogram
registry as a Prometheus text snapshot whose sojourn/miss totals match
the printed report exactly.  Both record exactly one run (single
admission / single placement).  Leaving them unset attaches nothing:
timelines, journals, and tables are bit-identical to an uninstrumented
run.

**Fault injection & crash recovery** — ``--tape-fault-profile light|heavy``
injects a seeded :class:`~repro.serving.faults.FaultPlan` (drive hard-
failures, transient mount faults; ``heavy`` adds media read errors and
solver faults) with a ``--tape-retries``-deep retry/backoff budget per
fault site; the table gains completed/failed/requeued columns.
``--tape-journal PATH`` writes a write-ahead event journal; pointed at a
(possibly torn) journal from a crashed run it recovers bit-identically and
completes the log (single-admission runs only).

Fleet federation (``--serve-tape-fleet``)
-----------------------------------------
``--serve-tape-fleet`` scales the queue simulation out to a *federation*
(:mod:`repro.fleet`): ``--fleet-shards`` per-library shards serve one
arrival stream in shared exact virtual time, each logical file stored on
``--fleet-replicas`` shards, and ``--fleet-placement`` picks the routing
strategy (``single`` / ``static-hash`` / ``least-loaded`` /
``replica-affinity``; ``all`` sweeps every strategy valid for the shard
count).  ``--fleet-outage-at T`` (with ``--fleet-outage-shard I``) injects
a :class:`~repro.serving.faults.ShardOutage` — shard ``I`` goes dark at
``T``, its orphaned requests re-route to surviving replicas — and the
printed table compares placements on served/failed/rerouted counts,
service times, and deadline misses.  The federation configuration rides
the :class:`~repro.core.ExecutionContext` as
:class:`~repro.core.FleetOptions`.

Every emitted schedule is validated by the **simulator oracle**
(:mod:`repro.serving.sim` via :func:`repro.core.verify.verify_schedule`): the
discrete-event replay independently recomputes the schedule's cost from the
materialised head trajectory and must match the solver-reported cost exactly
(integer arithmetic).  The printed table compares admission policies on one
seeded arrival trace: mean/p50/p95 service time (sojourn), batches,
preemptions, mounts, solve-cache hits, and exact DP cells
evaluated/reused.  ``--tape-admission all`` sweeps every policy.
"""

from __future__ import annotations

import argparse
import dataclasses
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import ARCHS, reduced
from ..core.context import ComputeBudget
from ..core.solver import (
    BACKENDS,
    DEFAULT_BACKEND,
    ExecutionContext,
    list_selectors,
    list_solvers,
)
from ..distributed.context import set_active_mesh
from ..distributed.sharding import cache_pspecs, param_pspecs, to_shardings
from ..models.model import init_cache, init_model
from ..serving.serve import make_serve_step
from .train import _auto_mesh


def _restore_from_tape(params, policy: str, backend: str) -> None:
    """Archive ``params`` to a simulated tape library and plan the restore.

    The library context owns a :class:`~repro.core.SolveCache`, so the
    re-plan a recovering serving fleet issues for the *same* archive (every
    cold start requests the identical shard multiset per cartridge) never
    re-solves a tape — the second pass below is all cache hits and its time
    is the pure memo-lookup cost.
    """
    from ..core.solver import SolveCache
    from ..distributed.checkpoint import archive_to_tape, plan_restore
    from ..storage.tape import TapeLibrary

    ctx = ExecutionContext(backend=backend, cache=SolveCache())
    lib = TapeLibrary(capacity_per_tape=4 * 10**6, u_turn=20_000, context=ctx)
    shards = archive_to_tape(lib, "serve-warmup", params, bytes_per_elem=1)
    consumers = {s: 2 for s in shards}  # every host group needs every shard
    t0 = time.time()
    try:
        plans = plan_restore(lib, shards, consumers, policy=policy)
    except ValueError as e:
        # unsupported policy/backend combo or the int32 device-DP magnitude
        # guard — cold-start planning must not kill the serving launcher
        print(f"tape restore [{policy}/{backend}] unavailable: {e}\n"
              f" -> falling back to backend='python'")
        backend = "python"
        ctx.cache.clear()  # drop the failed attempt's miss counts
        ctx = ctx.replace(backend=backend)
        plans = plan_restore(lib, shards, consumers, policy=policy, context=ctx)
    dt = time.time() - t0
    # warm re-plan: what the next cold start in the fleet pays
    t0 = time.time()
    plan_restore(lib, shards, consumers, policy=policy, context=ctx)
    dt_warm = time.time() - t0
    n_req = sum(consumers.values())
    mean = sum(p.total_cost for p in plans) / n_req
    last = max(max(p.service_time.values()) for p in plans)
    stats = ctx.cache.stats()
    print(
        f"tape restore [{policy}/{backend}]: {len(shards)} shards on "
        f"{len(lib.tapes)} tape(s), mean arrival {mean:.3g}, last {last:.3g} "
        f"(planned in {dt * 1e3:.0f} ms; re-plan {dt_warm * 1e3:.0f} ms, "
        f"cache {stats['hits']} hits / {stats['misses']} misses)"
    )


def _export_obs(obs, args) -> None:
    """Write the observability exporters a run's flags asked for.

    JSONL + Chrome trace to ``--tape-trace-out`` (the Chrome file rides
    next to the span log at ``PATH + ".chrome.json"``), Prometheus text
    to ``--tape-metrics-out``.  Shared by the queue and fleet modes.
    """
    from ..obs.export import write_chrome_trace, write_prometheus, write_spans_jsonl

    if args.tape_trace_out:
        n = write_spans_jsonl(obs.tracer, args.tape_trace_out)
        chrome = args.tape_trace_out + ".chrome.json"
        write_chrome_trace(obs.tracer, chrome)
        print(f"trace: {n} span(s) -> {args.tape_trace_out} (+ {chrome})")
    if args.tape_metrics_out:
        write_prometheus(obs.metrics, args.tape_metrics_out)
        print(f"metrics -> {args.tape_metrics_out}")


def _serve_tape_queue(args) -> int:
    """Drive the online tape-serving subsystem on one arrival trace.

    The trace is either replayed from a recorded JSONL file
    (``--trace-file``), generated with deadline/class annotations
    (``--tape-tightness``), or the plain seeded Poisson-like trace; each
    requested admission policy serves it on a shared drive pool under the
    chosen mount scheduler, and the per-policy service-time table is
    printed (with deadline-miss columns when the trace carries deadlines).
    Every dispatched schedule passes the simulator oracle (see the module
    docstring); the run is bit-deterministic given ``--tape-seed`` (or the
    trace file).  Returns a shell exit code: nonzero iff ``--slo-target``
    is set and no swept admission met it.
    """
    from ..data.traces import (
        qos_poisson_trace,
        read_trace,
        records_of,
        to_requests,
        write_trace,
    )
    from ..serving.drives import DriveCosts, RetryPolicy
    from ..serving.faults import recover_server, seeded_fault_plan
    from ..serving.queue import ADMISSIONS, WINDOWED_ADMISSIONS, serve_trace
    from ..serving.sim import demo_library, poisson_trace

    def build_library():
        return demo_library(args.tape_seed, n_files=args.tape_files)

    qos = {}
    if args.trace_file:
        if args.tape_tightness is not None:
            print("--trace-file replays recorded deadlines; it cannot be "
                  "combined with --tape-tightness")
            return 2
        records = read_trace(args.trace_file)
        trace, qos = to_requests(records, build_library())
        source = args.trace_file
    elif args.tape_tightness is not None:
        records = qos_poisson_trace(
            build_library(),
            n_requests=args.tape_requests,
            mean_interarrival=args.tape_rate,
            seed=args.tape_seed,
            tightness=args.tape_tightness,
        )
        trace, qos = to_requests(records, build_library())
        source = f"generated (tightness {args.tape_tightness})"
    else:
        trace = poisson_trace(
            build_library(),
            n_requests=args.tape_requests,
            mean_interarrival=args.tape_rate,
            seed=args.tape_seed,
        )
        records = None  # only materialised if the trace is being recorded
        source = "generated (best-effort)"
    if args.record_trace:
        if records is None:
            records = records_of(trace)
        write_trace(args.record_trace, records)
        print(f"recorded {len(records)} trace record(s) -> {args.record_trace}")
    admissions = (
        list(ADMISSIONS) if args.tape_admission == "all" else [args.tape_admission]
    )
    if args.tape_journal and len(admissions) != 1:
        print("--tape-journal records exactly one run; pick a single "
              "--tape-admission")
        return 2
    obs = None
    if args.tape_trace_out or args.tape_metrics_out:
        if len(admissions) != 1:
            print("--tape-trace-out/--tape-metrics-out record exactly one "
                  "run; pick a single --tape-admission")
            return 2
        from ..obs import Observability

        obs = Observability.enabled()
    costs = DriveCosts(
        mount=args.tape_mount_cost,
        unmount=args.tape_unmount_cost,
        load_seek=args.tape_load_seek,
    )
    n_drives = args.tape_drives  # None = one per cartridge (the PR-3 model)
    faults = None
    retry = None
    if args.tape_fault_profile != "off":
        pool_size = n_drives if n_drives else len(build_library().tapes)
        heavy = args.tape_fault_profile == "heavy"
        faults = seeded_fault_plan(
            build_library(), trace, seed=args.tape_seed, n_drives=pool_size,
            drive_failures=2 if heavy else 1,
            mount_faults=1,
            media_faults=1 if heavy else 0,
            solver_faults=2 if heavy else 0,
            backend=args.tape_backend,
        )
        # drop (typed FailedRequest rows) rather than raise: the table below
        # reports completion per admission instead of dying on the first run
        retry = RetryPolicy(max_attempts=args.tape_retries, on_exhausted="drop")
        print(
            f"fault profile {args.tape_fault_profile}: "
            f"{len(faults.drive_failures)} drive failure(s), "
            f"{len(faults.mount_faults)} mount fault(s), "
            f"{len(faults.media_faults)} media fault(s), "
            f"{len(faults.solver_faults)} solver fault(s); "
            f"{args.tape_retries} retr{'y' if args.tape_retries == 1 else 'ies'} "
            f"per fault site"
        )
    journal = None
    if args.tape_cache_file:
        from ..core.cache import JsonlCacheBackend

        journal = JsonlCacheBackend(args.tape_cache_file)
        print(
            f"persistent solve memo: {args.tape_cache_file} "
            f"({journal.loaded} journaled solve(s) replayed)"
        )
    print(
        f"online tape serving: {len(trace)} requests ({source}), "
        f"{len({r.tape_id for r in trace})} cartridge(s), "
        f"{n_drives if n_drives else 'dedicated'} drive(s), "
        f"scheduler {args.tape_scheduler}, policy {args.tape_policy}/"
        f"{args.tape_backend}, warm start "
        f"{'off' if args.no_tape_warm else 'on'}"
        + (f", selector {args.tape_selector}"
           f"{f' (budget {args.tape_budget} cells/tick)' if args.tape_budget else ''}"
           if args.tape_selector else "")
    )
    deadline_cols = ",missed,miss_rate" if qos else ""
    fault_cols = ",completed,failed,requeued" if faults is not None else ""
    selector_cols = ",policy_mix" if args.tape_selector else ""
    print("admission,window,mean_sojourn,p50_sojourn,p95_sojourn,batches,"
          f"preempts,mounts,cache_hits,cells,reused"
          f"{deadline_cols}{fault_cols}{selector_cols}")
    best_miss_rate = None
    for admission in admissions:
        lib = build_library()
        ctx = lib.context.replace(backend=args.tape_backend)
        if obs is not None:
            ctx = ctx.replace(obs=obs)
        if journal is not None:
            ctx = ctx.replace(cache=journal)
        if args.tape_budget is not None:
            ctx = ctx.replace(budget=ComputeBudget(per_tick=args.tape_budget))
        common = dict(
            window=args.tape_window if admission in WINDOWED_ADMISSIONS else 0,
            policy=args.tape_policy,
            selector=args.tape_selector,
            n_drives=n_drives,
            drive_costs=costs,
            qos=qos or None,
            mount_scheduler=args.tape_scheduler,
            context=ctx,
            warm_start=not args.no_tape_warm,
            faults=faults,
            retry=retry,
        )
        t0 = time.time()
        if args.tape_journal and os.path.exists(args.tape_journal) \
                and os.path.getsize(args.tape_journal) > 0:
            report = recover_server(
                lib, trace, args.tape_journal, admission=admission, **common
            )
            print(f"recovered from journal {args.tape_journal}")
        else:
            report = serve_trace(
                lib, trace, admission, journal=args.tape_journal, **common
            )
        dt = time.time() - t0
        s = report.summary()  # oracle runs per dispatch: a failure raised above
        extra = ""
        if qos:
            extra = f",{s['n_missed']}/{s['n_deadlines']},{s['miss_rate']:.3f}"
            best_miss_rate = (
                s["miss_rate"]
                if best_miss_rate is None
                else min(best_miss_rate, s["miss_rate"])
            )
        if faults is not None:
            extra += (
                f",{report.n_served}/{len(trace)},{report.n_failed},"
                f"{s['faults']['requeued']}"
            )
        if args.tape_selector:
            extra += "," + "+".join(
                f"{p}:{n}" for p, n in sorted(s["policy_mix"].items())
            )
        print(
            f"{admission},{s['window']},{s['mean_sojourn']:.4g},"
            f"{s['p50_sojourn']:.4g},{s['p95_sojourn']:.4g},{s['n_batches']},"
            f"{s['n_preemptions']},{s['mounts']},{s['cache']['hits']},"
            f"{s['cells_evaluated']},{s['cells_reused']}{extra} "
            f"({dt*1e3:.0f} ms wall)"
        )
    if journal is not None:
        journal.close()
    if obs is not None:
        _export_obs(obs, args)
    if args.slo_target is not None:
        if not any(s.deadline is not None for s in qos.values()):
            print("--slo-target needs a deadline-annotated trace "
                  "(--tape-tightness or --trace-file with deadlines)")
            return 2
        ok = best_miss_rate is not None and best_miss_rate <= args.slo_target
        print(
            f"SLO {'PASS' if ok else 'FAIL'}: best miss rate "
            f"{best_miss_rate:.3f} vs target {args.slo_target:.3f}"
        )
        return 0 if ok else 1
    return 0


def _serve_tape_fleet(args) -> int:
    """Drive the fleet federation on one federation-wide arrival trace.

    Builds a seeded ``--fleet-shards``-shard archive with
    ``--fleet-replicas``-way replication, generates one trace over the
    unified catalogue, and serves it under each requested placement
    strategy (fresh shard libraries per run, so runs never share state).
    The federation configuration rides the
    :class:`~repro.core.ExecutionContext` as
    :class:`~repro.core.FleetOptions` — ``serve_fleet_trace`` reads the
    placement from there.  Deterministic given ``--tape-seed``.
    """
    from ..core.context import FleetOptions
    from ..core.solver import SolveCache
    from ..data.traces import qos_poisson_trace, to_requests
    from ..fleet import demo_fleet, fleet_catalog, serve_fleet_trace
    from ..serving.drives import DriveCosts, RetryPolicy
    from ..serving.faults import ShardOutage
    from ..serving.queue import WINDOWED_ADMISSIONS
    from ..serving.sim import poisson_trace

    n_shards = args.fleet_shards
    if n_shards < 1:
        print("--fleet-shards must be >= 1")
        return 2
    if not (1 <= args.fleet_replicas <= n_shards):
        print("--fleet-replicas must be between 1 and --fleet-shards")
        return 2
    if args.fleet_placement == "all":
        placements = (
            ["single"]
            if n_shards == 1
            else ["static-hash", "least-loaded", "replica-affinity"]
        )
    else:
        placements = [args.fleet_placement]
    if "single" in placements and n_shards != 1:
        print("placement 'single' is the one-shard NoOp default; pick a "
              "routing strategy (or --fleet-shards 1)")
        return 2
    obs = None
    if args.tape_trace_out or args.tape_metrics_out:
        if len(placements) != 1:
            print("--tape-trace-out/--tape-metrics-out record exactly one "
                  "run; pick a single --fleet-placement")
            return 2
        from ..obs import Observability

        obs = Observability.enabled()

    def build_fleet():
        return demo_fleet(
            args.tape_seed,
            n_shards=n_shards,
            n_files=args.tape_files,
            replicas=args.fleet_replicas,
            with_cache=False,  # the run's shared memo lives on the context
        )

    libs, rmap = build_fleet()
    catalog = fleet_catalog(libs, rmap)
    qos = {}
    if args.tape_tightness is not None:
        records = qos_poisson_trace(
            catalog,
            n_requests=args.tape_requests,
            mean_interarrival=args.tape_rate,
            seed=args.tape_seed,
            tightness=args.tape_tightness,
        )
        trace, qos = to_requests(records)
    else:
        trace = poisson_trace(
            catalog,
            n_requests=args.tape_requests,
            mean_interarrival=args.tape_rate,
            seed=args.tape_seed,
        )
    outages = ()
    retry = None
    if args.fleet_outage_at is not None:
        if not (0 <= args.fleet_outage_shard < n_shards):
            print("--fleet-outage-shard must name a shard in the fleet")
            return 2
        outages = (ShardOutage(at=args.fleet_outage_at,
                               shard=args.fleet_outage_shard),)
        # drop (typed FailedRequest rows) rather than raise when a dark
        # shard strands replicas-of-one requests: the table compares
        # placements on completion instead of dying on the first run
        retry = RetryPolicy(on_exhausted="drop")
    admission = (
        "accumulate" if args.tape_admission == "all" else args.tape_admission
    )
    costs = DriveCosts(
        mount=args.tape_mount_cost,
        unmount=args.tape_unmount_cost,
        load_seek=args.tape_load_seek,
    )
    print(
        f"fleet serving: {n_shards} shard(s) x "
        f"{args.tape_drives if args.tape_drives else 'dedicated'} drive(s), "
        f"{args.fleet_replicas}-way replicas, {len(trace)} requests, "
        f"admission {admission}, policy {args.tape_policy}/{args.tape_backend}"
        + (f", outage: shard {args.fleet_outage_shard} at "
           f"{args.fleet_outage_at}" if outages else "")
    )
    deadline_cols = ",missed,miss_rate" if qos else ""
    print(f"placement,served,failed,rerouted,mean_sojourn,p95_sojourn,"
          f"mounts{deadline_cols}")
    for pl in placements:
        libs, rmap = build_fleet()
        ctx = ExecutionContext(
            backend=args.tape_backend,
            cache=SolveCache(),
            fleet=FleetOptions(
                n_shards=n_shards, placement=pl, replicas=args.fleet_replicas
            ),
            obs=obs,
        )
        t0 = time.time()
        fr = serve_fleet_trace(
            libs,
            trace,
            admission,
            replica_map=rmap,
            outages=outages,
            window=(
                args.tape_window if admission in WINDOWED_ADMISSIONS else 0
            ),
            policy=args.tape_policy,
            n_drives=args.tape_drives,
            drive_costs=costs,
            qos=qos or None,
            context=ctx,
            warm_start=not args.no_tape_warm,
            retry=retry,
        )
        dt = time.time() - t0
        s = fr.summary()
        extra = ""
        if qos:
            extra = f",{s['n_missed']}/{s['n_deadlines']},{s['miss_rate']:.3f}"
        print(
            f"{pl},{fr.n_served}/{len(trace)},{fr.n_failed},{fr.n_rerouted},"
            f"{s['mean_sojourn']:.4g},{s['p95_sojourn']:.4g},{s['mounts']}"
            f"{extra} ({dt*1e3:.0f} ms wall; routes "
            + "/".join(str(fr.routes[i]) for i in range(n_shards))
            + ")"
        )
    if obs is not None:
        _export_obs(obs, args)
    return 0


def main() -> None:
    from ..serving.drives import MOUNT_SCHEDULERS
    from ..serving.queue import ADMISSIONS

    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-3b", choices=sorted(ARCHS))
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=32)
    ap.add_argument("--mesh", default="auto", choices=["auto", "pod", "multipod"])
    ap.add_argument("--restore-from-tape", action="store_true",
                    help="simulate an LTSP-scheduled checkpoint restore first")
    ap.add_argument("--tape-policy", default="dp", choices=list_solvers())
    ap.add_argument("--tape-backend", default=DEFAULT_BACKEND, choices=list(BACKENDS))
    ap.add_argument("--serve-tape-queue", action="store_true",
                    help="run the online tape-serving queue simulation "
                         "(admission-policy comparison) instead of model serving")
    ap.add_argument("--tape-admission", default="all",
                    choices=[*ADMISSIONS, "all"])
    ap.add_argument("--serve-tape-fleet", action="store_true",
                    help="run the sharded fleet-federation simulation "
                         "(placement-strategy comparison) instead of model "
                         "serving")
    ap.add_argument("--fleet-shards", type=int, default=3, metavar="N",
                    help="per-library shards in the federation")
    ap.add_argument("--fleet-placement", default="all",
                    choices=["single", "static-hash", "least-loaded",
                             "replica-affinity", "all"],
                    help="replica routing strategy ('all' sweeps every "
                         "strategy valid for the shard count)")
    ap.add_argument("--fleet-replicas", type=int, default=2, metavar="K",
                    help="shards each logical file is replicated on")
    ap.add_argument("--fleet-outage-at", type=int, default=None, metavar="T",
                    help="inject a ShardOutage (whole shard dark) at this "
                         "virtual time")
    ap.add_argument("--fleet-outage-shard", type=int, default=0, metavar="I",
                    help="shard the injected outage darkens")
    ap.add_argument("--tape-selector", default=None,
                    choices=list_selectors(),
                    help="load-adaptive solver selection: re-pick the solve "
                         "policy each tick from queue depth / recorded solve "
                         "timings (unset = pin --tape-policy, bit-identical "
                         "to previous behaviour)")
    ap.add_argument("--tape-budget", type=int, default=None, metavar="CELLS",
                    help="per-tick DP cell budget the 'cost-model' selector "
                         "fits under (repro.core.ComputeBudget.per_tick)")
    ap.add_argument("--tape-scheduler", default="greedy",
                    choices=sorted(MOUNT_SCHEDULERS),
                    help="drive-pool mount/eviction scheduler")
    ap.add_argument("--trace-file", default=None, metavar="PATH",
                    help="replay a recorded JSONL trace (repro.data.traces) "
                         "instead of generating one")
    ap.add_argument("--record-trace", default=None, metavar="PATH",
                    help="write the served trace as JSONL (round-trips "
                         "bit-exactly through --trace-file)")
    ap.add_argument("--tape-tightness", type=int, default=None,
                    help="annotate the generated trace with deadlines: "
                         "deadline = arrival + tightness * class slack "
                         "multiplier (enables the QoS admissions)")
    ap.add_argument("--slo-target", type=float, default=None, metavar="RATE",
                    help="deadline-miss-rate target; exit 1 unless some "
                         "swept admission meets it")
    ap.add_argument("--no-tape-warm", action="store_true",
                    help="disable warm-started re-solves (bit-identical "
                         "schedules either way; cold re-solves every tick)")
    ap.add_argument("--tape-cache-file", default=None, metavar="PATH",
                    help="persist the solve memo to a JSONL journal "
                         "(replayed on the next run against the same path)")
    ap.add_argument("--tape-fault-profile", default="off",
                    choices=["off", "light", "heavy"],
                    help="inject a seeded fault plan into the serving run: "
                         "'light' = 1 drive failure + 1 transient mount "
                         "fault, 'heavy' adds media + solver faults "
                         "(deterministic given --tape-seed)")
    ap.add_argument("--tape-retries", type=int, default=3, metavar="N",
                    help="retry budget per fault site (mount attempts, media "
                         "read attempts, solver attempts per backend tier); "
                         "exhausted budgets drop requests as typed failures")
    ap.add_argument("--tape-trace-out", default=None, metavar="PATH",
                    help="attach the observability tracer and export the "
                         "virtual-time span log as JSONL at PATH plus a "
                         "Chrome trace_event file at PATH + '.chrome.json' "
                         "(single-admission/-placement runs only)")
    ap.add_argument("--tape-metrics-out", default=None, metavar="PATH",
                    help="attach the observability metrics registry and "
                         "export a Prometheus text snapshot at PATH "
                         "(single-admission/-placement runs only)")
    ap.add_argument("--tape-journal", default=None, metavar="PATH",
                    help="write-ahead event journal; if PATH already holds a "
                         "(possibly torn) journal from a crashed run, the "
                         "run recovers from it bit-identically")
    ap.add_argument("--tape-window", type=int, default=400_000,
                    help="accumulate-then-solve re-plan window (virtual time)")
    ap.add_argument("--tape-drives", type=int, default=None,
                    help="shared drive-pool size (default: one per cartridge)")
    ap.add_argument("--tape-mount-cost", type=int, default=0,
                    help="cost of threading a cartridge into a drive")
    ap.add_argument("--tape-unmount-cost", type=int, default=0,
                    help="cost of ejecting the cartridge a drive holds")
    ap.add_argument("--tape-load-seek", type=int, default=0,
                    help="seek from thread point to load point after mounting")
    ap.add_argument("--tape-rate", type=int, default=250_000,
                    help="mean request inter-arrival time (virtual time)")
    ap.add_argument("--tape-requests", type=int, default=300)
    ap.add_argument("--tape-files", type=int, default=40)
    ap.add_argument("--tape-seed", type=int, default=20260731)
    args = ap.parse_args()

    if args.serve_tape_queue:
        raise SystemExit(_serve_tape_queue(args))
    if args.serve_tape_fleet:
        raise SystemExit(_serve_tape_fleet(args))

    cfg = ARCHS[args.arch]
    if args.reduced:
        cfg = reduced(cfg, periods=2)
        cfg = dataclasses.replace(cfg, vocab_size=min(cfg.vocab_size, 32768))

    mesh = _auto_mesh(args.mesh)
    set_active_mesh(mesh)
    max_len = args.prompt_len + args.new_tokens

    params = init_model(jax.random.PRNGKey(0), cfg)
    if args.restore_from_tape:
        _restore_from_tape(params, args.tape_policy, args.tape_backend)
    params = jax.device_put(params, to_shardings(param_pspecs(params), mesh, params))
    cache = init_cache(cfg, args.batch, max_len=max_len)
    cache = jax.device_put(cache, to_shardings(cache_pspecs(cache, mesh), mesh))

    serve = jax.jit(make_serve_step(cfg))
    prompts = jax.random.randint(
        jax.random.PRNGKey(1), (args.batch, args.prompt_len), 0, cfg.vocab_size
    )
    with mesh:
        for t in range(args.prompt_len - 1):  # teacher-forced prefill
            _, _, cache = serve(params, cache, prompts[:, t : t + 1], jnp.int32(t))
        tok = prompts[:, -1:]
        t0 = time.time()
        outs = []
        for t in range(args.new_tokens):
            tok, _, cache = serve(params, cache, tok, jnp.int32(args.prompt_len - 1 + t))
            outs.append(np.asarray(tok))
        jax.block_until_ready(tok)
    dt = time.time() - t0
    set_active_mesh(None)
    print(f"{cfg.arch_id}: {args.batch}x{args.new_tokens} tokens in {dt:.2f}s "
          f"({args.batch*args.new_tokens/dt:.0f} tok/s)")
    print("first sequence:", np.concatenate(outs, 1)[0][:16].tolist(), "...")


if __name__ == "__main__":
    main()

"""Shared CLI helpers (importable without touching jax device state)."""

from __future__ import annotations

__all__ = ["parse_overrides"]


def parse_overrides(items: list[str] | None):
    """``k=v`` config overrides (bools/ints/floats/str)."""
    out = {}
    for item in items or []:
        k, v = item.split("=", 1)
        if v in ("true", "True"):
            val: object = True
        elif v in ("false", "False"):
            val = False
        else:
            try:
                val = int(v)
            except ValueError:
                try:
                    val = float(v)
                except ValueError:
                    val = v
        out[k] = val
    return out

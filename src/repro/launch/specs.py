"""ShapeDtypeStruct stand-ins for every model input (no device allocation).

``input_specs(cfg, shape)`` returns the abstract arguments of the step
function selected by the shape kind:

* ``train``   -> (params, opt_state, batch)            for ``train_step``
* ``prefill`` -> (params, tokens[, memory inputs])     for ``prefill``
* ``decode``  -> (params, cache, tokens, pos)          for ``serve_step``

Stub modality frontends: the audio encoder consumes precomputed frame
embeddings, the VLM consumes precomputed projected patch embeddings.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from ..configs.base import InputShape
from ..models.common import ModelConfig
from ..models.model import init_cache, init_model
from ..training.optimizer import adamw_init

__all__ = ["abstract_params", "abstract_opt_state", "batch_specs", "input_specs"]


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def abstract_params(cfg: ModelConfig, key=None):
    """Parameter ShapeDtypeStructs via eval_shape (no memory)."""
    k = jax.random.PRNGKey(0)
    return jax.eval_shape(lambda kk: init_model(kk, cfg), k)


def abstract_opt_state(cfg: ModelConfig, params_abs=None):
    params_abs = params_abs if params_abs is not None else abstract_params(cfg)
    return jax.eval_shape(adamw_init, params_abs)


def batch_specs(cfg: ModelConfig, shape: InputShape):
    """Training/prefill batch ShapeDtypeStructs."""
    B, L = shape.global_batch, shape.seq_len
    batch = {"tokens": _sds((B, L), jnp.int32)}
    if cfg.enc_layers:
        batch["enc_embeds"] = _sds((B, cfg.num_enc_frames, cfg.d_model), cfg.cdtype)
    if cfg.num_vision_tokens:
        batch["vision_embeds"] = _sds((B, cfg.num_vision_tokens, cfg.d_model), cfg.cdtype)
    return batch


def abstract_cache(cfg: ModelConfig, batch: int, max_len: int):
    return jax.eval_shape(lambda: init_cache(cfg, batch, max_len))


def input_specs(cfg: ModelConfig, shape: InputShape):
    """Abstract step-function arguments for a (arch x input-shape) cell."""
    params = abstract_params(cfg)
    if shape.kind == "train":
        return {
            "params": params,
            "opt_state": abstract_opt_state(cfg, params),
            "batch": batch_specs(cfg, shape),
        }
    if shape.kind == "prefill":
        out = {"params": params, "batch": batch_specs(cfg, shape)}
        return out
    if shape.kind == "decode":
        B = shape.global_batch
        return {
            "params": params,
            "cache": abstract_cache(cfg, B, shape.seq_len),
            "tokens": _sds((B, 1), jnp.int32),
            "pos": _sds((), jnp.int32),
        }
    raise ValueError(shape.kind)

"""Production mesh construction (function, not constant: importing this module
never touches jax device state)."""

from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "PODS", "POD_SHAPE"]

PODS = 2
POD_SHAPE = (16, 16)  # 256 chips per pod (TPU v5e-256)


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    """16x16 single-pod mesh or 2x16x16 two-pod mesh.

    Axis semantics: "pod" — pure data parallelism across pods (gradient
    all-reduce over DCN/inter-pod links); "data" — in-pod data parallelism;
    "model" — tensor/expert parallelism (and KV-cache sequence sharding for
    decode).
    """
    shape = (PODS, *POD_SHAPE) if multi_pod else POD_SHAPE
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = 1
    for s in shape:
        n *= s
    devices = jax.devices()[:n]
    if len(devices) < n:
        raise RuntimeError(
            f"need {n} devices for mesh {shape}; have {len(devices)} "
            "(dryrun.py must set XLA_FLAGS=--xla_force_host_platform_device_count=512 "
            "before importing jax)"
        )
    import numpy as np

    return jax.sharding.Mesh(np.asarray(devices).reshape(shape), axes)

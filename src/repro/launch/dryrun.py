import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any other import (jax locks the device
# count on first initialisation).

import argparse  # noqa: E402
import json  # noqa: E402
import pathlib  # noqa: E402
import re  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from ..configs import ARCHS, SHAPES, runnable_shapes  # noqa: E402
from ..distributed.sharding import (  # noqa: E402
    batch_pspecs,
    cache_pspecs,
    dp_axes,
    param_pspecs,
    to_shardings,
)
from ..launch.mesh import make_production_mesh  # noqa: E402
from ..launch.specs import input_specs  # noqa: E402
from ..models.model import decode_step, forward  # noqa: E402
from ..training.optimizer import OptConfig  # noqa: E402
from ..training.train_step import make_train_step  # noqa: E402

"""Multi-pod dry-run: ``lower().compile()`` every (arch x shape x mesh) cell.

For each cell this proves the distribution config is coherent: shardings
divide, collectives exist, and the compiled memory/cost analysis feeds the
roofline (§Roofline in EXPERIMENTS.md).  Results are dumped incrementally as
JSON under ``results/dryrun/``.
"""

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
    "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1,
    "c64": 8, "c128": 16,
}

_COLL_RE = re.compile(
    r"=\s+(\(?[a-z0-9\[\],{}: ]+?\)?)\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\("
)
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\{?\{([0-9,]+)\}")


def _shape_bytes(sig: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(sig):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def parse_collectives(hlo_text: str) -> dict[str, float]:
    """Approximate per-device bytes moved by collective ops (ring model).

    Shapes in the partitioned module are already per-device.  Ring factors:
    all-reduce 2s(g-1)/g, all-gather s_out(g-1)/g, reduce-scatter s_out(g-1),
    all-to-all s(g-1)/g, collective-permute s.
    """
    out: dict[str, float] = {}
    for m in _COLL_RE.finditer(hlo_text):
        sig, op = m.group(1), m.group(2)
        s = _shape_bytes(sig)
        line = hlo_text[m.start() : hlo_text.find("\n", m.start())]
        gm = _GROUPS_RE.search(line)
        g = len(gm.group(1).split(",")) if gm else 2
        if op == "all-reduce":
            moved = 2 * s * (g - 1) / g
        elif op == "all-gather":
            moved = s * (g - 1) / g
        elif op == "reduce-scatter":
            moved = s * (g - 1)
        elif op == "all-to-all":
            moved = s * (g - 1) / g
        else:  # collective-permute
            moved = s
        out[op] = out.get(op, 0.0) + moved
    return out


def _step_fn_and_shardings(cfg, shape, mesh):
    """Build (fn, abstract args, in_shardings) for the cell."""
    specs = input_specs(cfg, shape)
    pspec = param_pspecs(specs["params"])
    psh = to_shardings(pspec, mesh, specs["params"])
    if shape.kind == "train":
        opt_sh = {
            "m": to_shardings(pspec, mesh, specs["params"]),
            "v": to_shardings(pspec, mesh, specs["params"]),
            "step": jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec()),
        }
        bsh = to_shardings(batch_pspecs(specs["batch"], mesh), mesh)
        fn = make_train_step(cfg, OptConfig())
        args = (specs["params"], specs["opt_state"], specs["batch"])
        in_sh = (psh, opt_sh, bsh)
    elif shape.kind == "prefill":
        def fn(params, batch):
            memory = None
            if cfg.enc_layers:
                from ..models.model import encode

                memory = encode(params, cfg, batch["enc_embeds"])
            elif cfg.num_vision_tokens:
                memory = batch["vision_embeds"]
            logits, _ = forward(params, cfg, batch["tokens"], memory=memory)
            return logits

        bsh = to_shardings(batch_pspecs(specs["batch"], mesh), mesh)
        args = (specs["params"], specs["batch"])
        in_sh = (psh, bsh)
    else:  # decode
        def fn(params, cache, tokens, pos):
            logits, new_cache = decode_step(params, cfg, tokens, cache, pos)
            return jnp.argmax(logits[:, -1], axis=-1), new_cache

        from ..distributed.sharding import safe_pspec

        csh = to_shardings(cache_pspecs(specs["cache"], mesh), mesh)
        P = jax.sharding.PartitionSpec
        tsh = jax.sharding.NamedSharding(
            mesh,
            safe_pspec(P(dp_axes(mesh), None), specs["tokens"].shape, mesh),
        )
        possh = jax.sharding.NamedSharding(mesh, P())
        args = (specs["params"], specs["cache"], specs["tokens"], specs["pos"])
        in_sh = (psh, csh, tsh, possh)
    return fn, args, in_sh


def _depth_variant(cfg, k: int, seq_len: int):
    """Same architecture with k periods (and k encoder layers), unrolled.

    Used for two-point cost extrapolation: XLA costs a while-loop body once,
    so the production-depth scanned compile under-counts per-layer work.  Two
    small unrolled compiles give exact per-period deltas:
    ``cost(L) = d1 + (n_periods - 1) * (d2 - d1)``.  Inner Mamba chunk scans
    are widened to one chunk for the same reason.
    """
    import dataclasses

    return dataclasses.replace(
        cfg,
        num_layers=cfg.first_k_dense + k * len(cfg.block_pattern),
        enc_layers=min(cfg.enc_layers, k),
        scan_layers=False,
        mamba_chunk=max(seq_len, cfg.mamba_chunk),
    )


def _analyse(cfg, shape, mesh):
    """lower+compile one configuration; return (lowered, compiled) metrics."""
    fn, args, in_sh = _step_fn_and_shardings(cfg, shape, mesh)
    lowered = jax.jit(fn, in_shardings=in_sh).lower(*args)
    compiled = lowered.compile()
    mem = None
    try:
        ma = compiled.memory_analysis()
        if ma is not None:
            mem = {
                k: int(getattr(ma, k))
                for k in (
                    "argument_size_in_bytes",
                    "output_size_in_bytes",
                    "temp_size_in_bytes",
                    "generated_code_size_in_bytes",
                )
                if hasattr(ma, k)
            }
    except Exception as e:
        mem = {"error": str(e)}
    cost = {}
    try:
        ca = compiled.cost_analysis()
        if isinstance(ca, (list, tuple)):
            ca = ca[0]
        cost = {
            k: float(v)
            for k, v in ca.items()
            if isinstance(v, (int, float)) and k in ("flops", "bytes accessed")
        }
    except Exception as e:
        cost = {"error": str(e)}
    colls = parse_collectives(compiled.as_text())
    return {"memory": mem, "cost": cost, "collectives": colls}


def run_cell(arch: str, shape_name: str, multi_pod: bool, out_dir: pathlib.Path,
             cfg_override=None):
    cfg = cfg_override if cfg_override is not None else ARCHS[arch]
    shape = SHAPES[shape_name]
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    out_path = out_dir / f"{arch}__{shape_name}__{mesh_name}.json"
    rec = {
        "arch": arch,
        "shape": shape_name,
        "mesh": mesh_name,
        "kind": shape.kind,
        "ok": False,
    }
    t0 = time.time()
    try:
        if shape_name not in runnable_shapes(cfg):
            rec["skipped"] = "full-attention arch: long-context decode inapplicable"
            rec["ok"] = True
            out_path.write_text(json.dumps(rec, indent=1))
            print(f"SKIP {arch} {shape_name} {mesh_name}")
            return rec
        from ..distributed.context import set_active_mesh

        mesh = make_production_mesh(multi_pod=multi_pod)
        set_active_mesh(mesh)
        try:
            with mesh:
                # 1) production-depth scanned compile: proves the cell
                #    compiles; its memory_analysis reflects the real buffers.
                full = _analyse(cfg, shape, mesh)
                t_full = time.time() - t0
                # 2) two-point depth extrapolation for exact per-layer costs
                d1 = _analyse(_depth_variant(cfg, 1, shape.seq_len), shape, mesh)
                d2 = _analyse(_depth_variant(cfg, 2, shape.seq_len), shape, mesh)
        finally:
            set_active_mesh(None)
        n = cfg.n_periods

        def extrap(key):
            a = d1["cost"].get(key, 0.0) or 0.0
            b = d2["cost"].get(key, 0.0) or 0.0
            # clamp: depth-2 can occasionally optimise below depth-1 on tiny
            # terms; per-layer cost is never negative
            return a + (n - 1) * max(0.0, b - a)

        colls = {}
        for op in set(d1["collectives"]) | set(d2["collectives"]):
            a = d1["collectives"].get(op, 0.0)
            b = d2["collectives"].get(op, 0.0)
            colls[op] = a + (n - 1) * max(0.0, b - a)

        rec.update(
            ok=True,
            total_s=round(time.time() - t0, 2),
            full_compile_s=round(t_full, 2),
            n_periods=n,
            flops=extrap("flops"),
            bytes_accessed=extrap("bytes accessed"),
            flops_scanned=full["cost"].get("flops"),
            memory=full["memory"],
            collectives=colls,
            collective_bytes=sum(colls.values()),
            collectives_scanned=full["collectives"],
        )
        print(
            f"PASS {arch} {shape_name} {mesh_name} "
            f"({rec['total_s']:.0f}s flops={rec['flops']:.3g} "
            f"coll={rec['collective_bytes']:.3g}B "
            f"temp={ (full['memory'] or {}).get('temp_size_in_bytes', -1)/2**30:.1f}GiB)"
        )
    except Exception:
        rec["error"] = traceback.format_exc()
        print(f"FAIL {arch} {shape_name} {mesh_name}")
        print(rec["error"][-2000:])
    out_path.write_text(json.dumps(rec, indent=1))
    return rec


def _parse_variant(items):
    from .cli import parse_overrides

    return parse_overrides(items)


def main() -> None:
    ap = argparse.ArgumentParser(description="multi-pod dry-run")
    ap.add_argument("--arch", default=None, help="arch id (default: all)")
    ap.add_argument("--shape", default=None, help="shape name (default: all)")
    ap.add_argument("--mesh", default="both", choices=["pod", "multipod", "both"])
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--force", action="store_true", help="recompute existing")
    ap.add_argument("--set", nargs="*", default=None, metavar="K=V",
                    help="config overrides for perf variants, e.g. "
                         "--set logits_bf16_ce=true remat_policy=dots")
    args = ap.parse_args()
    overrides = _parse_variant(args.set)

    out_dir = pathlib.Path(args.out)
    out_dir.mkdir(parents=True, exist_ok=True)
    archs = [args.arch] if args.arch else list(ARCHS)
    shapes = [args.shape] if args.shape else list(SHAPES)
    meshes = {"pod": [False], "multipod": [True], "both": [False, True]}[args.mesh]

    n_pass = n_fail = 0
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                mesh_name = "pod2x16x16" if mp else "pod16x16"
                f = out_dir / f"{arch}__{shape}__{mesh_name}.json"
                if f.exists() and not args.force:
                    rec = json.loads(f.read_text())
                    print(("PASS" if rec.get("ok") else "FAIL") + f" {arch} {shape} {mesh_name} (cached)")
                else:
                    cfg_override = None
                    if overrides:
                        import dataclasses

                        cfg_override = dataclasses.replace(ARCHS[arch], **overrides)
                    rec = run_cell(arch, shape, mp, out_dir, cfg_override=cfg_override)
                n_pass += bool(rec.get("ok"))
                n_fail += not rec.get("ok")
    print(f"\ndry-run complete: {n_pass} pass / {n_fail} fail")
    raise SystemExit(1 if n_fail else 0)


if __name__ == "__main__":
    main()
